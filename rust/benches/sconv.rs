//! Bench: the §V-B SCONV case study — convolution as MMA outer products
//! vs the materialized-im2col alternative the paper argues against.
//!
//! Reports: (a) POWER10 cycles for the 8×27×16 kernel, (b) the modeled
//! overhead an im2col GEMM would add (materializing the 27×(m−2) matrix:
//! extra stores+loads), (c) functional-simulator wall-clock.
//!
//! Run: `cargo bench --bench sconv`

use power_mma::benchkit::{bench, report};
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::kernels::sconv::{run_sconv_8x27x16, sconv_8x27x16_program};
use power_mma::metrics::Table;
use power_mma::testkit::Rng;

fn main() {
    let width = 20usize;
    let prog = sconv_8x27x16_program((width * 4) as i32);

    let mut sim = CoreSim::new(MachineConfig::power10());
    sim.gpr[3] = 0;
    sim.gpr[6] = 4096;
    sim.gpr[7] = 8192;
    sim.gpr[8] = 12288;
    sim.gpr[10] = 16384;
    let direct = sim.run(&prog, 1 << 20);

    // im2col alternative: materialize the 27x16 patch matrix first.
    // 27*16 fp32 stores + the same count of loads back = 2*27 extra
    // 16-byte vector memory ops through the LSU, plus the buffer write
    // allocation — modeled as added LSU traffic on the same machine.
    let extra_vec_ops = 2 * 27 * (16 * 4 / 16);
    let lsu_ports = 4;
    let im2col_extra_cycles = extra_vec_ops as u64 / lsu_ports;
    let mut table = Table::new(&["variant", "cycles", "fp32 flops/cycle", "notes"]);
    table.row(&[
        "MMA direct (Fig 9)".into(),
        direct.cycles.to_string(),
        format!("{:.2}", direct.flops_per_cycle()),
        "no patch materialization".into(),
    ]);
    table.row(&[
        "im2col + GEMM".into(),
        (direct.cycles + im2col_extra_cycles).to_string(),
        format!("{:.2}", direct.flops as f64 / (direct.cycles + im2col_extra_cycles) as f64),
        format!("+{im2col_extra_cycles} cycles materializing A-bar"),
    ]);
    println!("SCONV 8x27x16 on POWER10 (paper §V-B):\n{}", table.render());
    println!(
        "paper: \"convolution can be done directly on the input matrix A\" — the direct \
         schedule wins by {:.1}%\n",
        100.0 * im2col_extra_cycles as f64 / direct.cycles as f64
    );

    // functional wall-clock
    let mut rng = Rng::new(1);
    let filters = rng.f32_vec(8 * 27);
    let r = rng.f32_vec(3 * width);
    let g = rng.f32_vec(3 * width);
    let b = rng.f32_vec(3 * width);
    let s = bench("sconv_functional_exec", 3, 100, || {
        run_sconv_8x27x16(&filters, &r, &g, &b, width).unwrap();
    });
    report(&s);

    // ---- §VIII future-work kernels on the same machinery ----------------
    use power_mma::kernels::dft::dft_mma;
    use power_mma::kernels::stencil::run_stencil_8x16;
    let n = 32;
    let batch = 8;
    let xr = rng.f64_vec(n * batch);
    let xi = rng.f64_vec(n * batch);
    let s = bench("dft32_batch8_mma", 1, 20, || {
        dft_mma(&xr, &xi, n, batch).unwrap();
    });
    report(&s);
    let (_, _, stats) = dft_mma(&xr, &xi, n, batch).unwrap();
    println!(
        "DFT-as-GEMM (§VIII): {} MMA instructions for a batched 32-point complex DFT",
        stats.mma_instructions
    );
    let coeffs = rng.f32_vec(8 * 5);
    let row = rng.f32_vec(32);
    let s = bench("stencil_8x5x16_mma", 3, 200, || {
        run_stencil_8x16(&coeffs, 5, &row).unwrap();
    });
    report(&s);
}
