//! Bench: coordinator serving throughput/latency — the §I data-in-flight
//! scenario. Uses a synthetic engine (fixed per-batch cost) to isolate
//! router/batcher overhead, plus the real native **plan** backend
//! (`Runtime::cpu`: compiled plans + fused blocked GEMM) over the
//! embedded artifacts. The same end-to-end number is tracked across PRs
//! by `power-mma bench serve` (the `coordinator` block of
//! `BENCH_runtime.json`).
//!
//! Also sweeps the continuous-batching knob (the bucket ladder), the
//! serving analogue of the paper's throughput-vs-latency trade.
//!
//! Run: `cargo bench --bench coordinator`

use power_mma::coordinator::{Coordinator, CoordinatorConfig, InferenceEngine, MlpWeights, Payload};
use power_mma::metrics::Table;
use power_mma::runtime::{det_input, Runtime};
use std::time::{Duration, Instant};

/// Engine with a fixed per-invocation cost (models a constant-latency
/// accelerator call).
struct SyntheticEngine {
    cost: Duration,
    cfg: CoordinatorConfig,
}

impl InferenceEngine for SyntheticEngine {
    fn run(&mut self, model: &str, inputs: &[&[f32]]) -> power_mma::error::Result<Vec<f32>> {
        std::thread::sleep(self.cost);
        // the batcher names the bucket it picked (`mlp_b{m}`)
        if let Some(b) = model.strip_prefix("mlp_b").and_then(|b| b.parse::<usize>().ok()) {
            Ok(vec![0.5; b * self.cfg.classes])
        } else {
            Ok(inputs[0].to_vec())
        }
    }
}

fn drive(cfg: CoordinatorConfig, n: usize, engine_cost: Duration) -> (f64, u64, f64) {
    let weights = MlpWeights::deterministic(&cfg);
    let cfg2 = cfg.clone();
    let coord = Coordinator::start(cfg.clone(), weights, move |_shard| {
        Ok(SyntheticEngine { cost: engine_cost, cfg: cfg2.clone() })
    });
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        rxs.push(coord.submit(Payload::Classify { features: det_input(cfg.features, i as u64) }).1);
    }
    for rx in rxs {
        rx.recv().unwrap().result.unwrap();
    }
    let dt = t0.elapsed();
    let stats = coord.shutdown();
    (n as f64 / dt.as_secs_f64(), stats.latency.quantile_us(0.5), stats.mean_batch_occupancy())
}

fn main() {
    println!("batching ablation (synthetic engine, 200us per batch call):");
    let mut table = Table::new(&["bucket", "req/s", "p50 us", "occupancy"]);
    for batch in [1usize, 4, 8, 16, 32] {
        // a singleton ladder [b] pins every window to one compiled bucket
        let cfg = CoordinatorConfig {
            buckets: vec![batch],
            max_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let (tput, p50, occ) = drive(cfg, 2000, Duration::from_micros(200));
        table.row(&[batch.to_string(), format!("{tput:.0}"), p50.to_string(), format!("{occ:.1}")]);
    }
    println!("{}", table.render());
    println!("batching amortizes the fixed per-call cost: throughput scales with bucket size\n");

    // the full ladder: partial windows execute in the smallest
    // sufficient bucket instead of padding to the maximum
    let ladder_cfg = CoordinatorConfig {
        buckets: vec![1, 8, 32],
        max_delay: Duration::from_millis(1),
        ..Default::default()
    };
    let (tput, p50, occ) = drive(ladder_cfg, 2000, Duration::from_micros(200));
    println!(
        "bucket ladder [1, 8, 32]: {tput:.0} req/s, p50 {p50} us, occupancy {occ:.1}\n"
    );

    // the real native engine (plan backend) over the AOT artifacts,
    // swept across coordinator shard counts — every shard's runtime
    // shares the process-wide device pool, so this measures engine
    // concurrency at a fixed GEMM worker budget
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if power_mma::runtime::artifacts::ensure_artifacts(&dir).is_ok() {
        for shards in [1usize, 2] {
            // single-model traffic: round-robin so both shards serve it
            let cfg = CoordinatorConfig {
                shards,
                routing: power_mma::coordinator::ShardRouting::RoundRobin,
                ..Default::default()
            };
            let weights = MlpWeights::deterministic(&cfg);
            let dir2 = dir.clone();
            let ladder = cfg.ladder();
            let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
            let coord = Coordinator::start(cfg.clone(), weights, move |_shard| {
                let mut rt = Runtime::cpu(&dir2)?;
                rt.load_all()?;
                rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
                Ok(rt)
            });
            // warm up every shard (first call compiles/faults in)
            for _ in 0..shards * 2 {
                let (_, rx) =
                    coord.submit(Payload::Classify { features: det_input(cfg.features, 0) });
                rx.recv().unwrap().result.unwrap();
            }
            let n = 5000;
            let t0 = Instant::now();
            let mut rxs = Vec::with_capacity(n);
            for i in 0..n {
                rxs.push(
                    coord
                        .submit(Payload::Classify { features: det_input(cfg.features, i as u64) })
                        .1,
                );
            }
            for rx in rxs {
                rx.recv().unwrap().result.unwrap();
            }
            let dt = t0.elapsed();
            let stats = coord.shutdown();
            println!(
                "real plan-backend engine, {shards} shard(s) (bucket ladder, fused epilogues): \
                 {n} requests in {dt:.2?} -> {:.0} req/s, p50 {} us, occupancy {:.1}",
                n as f64 / dt.as_secs_f64(),
                stats.latency.quantile_us(0.5),
                stats.mean_batch_occupancy()
            );
        }
    } else {
        println!("(skipping native-engine phase: artifact directory unavailable)");
    }
}
