//! Bench: regenerate **Figure 12** — average power draw of the 128×128
//! DGEMM, per configuration, split CORE-without-MME / MME / TOTAL, using
//! the §VII methodology (5000-instruction windows, averaged).
//!
//! Paper reference points (§VII): POWER10-MMA ≈ +8% total power vs
//! POWER10-VSX (+12% with the MME power-gated during VSX runs) for 2.5×
//! the performance; ≈ −24% power vs POWER9 at 5× the performance — almost
//! 7× less energy per computation.
//!
//! Run: `cargo bench --bench fig12_power`

use power_mma::benchkit::f2;
use power_mma::hpl::{CycleCost, Setup};
use power_mma::metrics::Table;

fn main() {
    for gate in [false, true] {
        let mut table = Table::new(&[
            "config",
            "CORE w/o MME",
            "MME",
            "TOTAL",
            "flops/cycle",
            "energy/flop",
            "windows",
        ]);
        let mut rows = Vec::new();
        for setup in Setup::ALL {
            let mut cost = CycleCost::new(setup);
            cost.sim_mut().set_mme_gated(gate);
            let r = cost.kernel_report(2048); // long run -> many windows
            let e = r.energy.clone();
            rows.push((setup, e.total_power, r.flops_per_cycle()));
            table.row(&[
                setup.label().to_string(),
                f2(e.core_power),
                f2(e.mme_power),
                f2(e.total_power),
                f2(r.flops_per_cycle()),
                format!("{:.3}", e.total_power / r.flops_per_cycle()),
                e.windows.to_string(),
            ]);
        }
        println!(
            "Figure 12 — average power of DGEMM (arbitrary units){}:\n{}",
            if gate { ", MME power-gated when idle" } else { "" },
            table.render()
        );
        let p9 = rows[0];
        let vsx = rows[1];
        let mma = rows[2];
        println!(
            "ratios: MMA/VSX power {:.3} (paper ~{}), MMA/P9 power {:.3} (paper ~0.76), \
             energy/flop gain vs P9 {:.2}x (paper ~6.8x)\n",
            mma.1 / vsx.1,
            if gate { "1.12" } else { "1.08" },
            mma.1 / p9.1,
            (p9.1 / p9.2) / (mma.1 / mma.2),
        );
    }
}
