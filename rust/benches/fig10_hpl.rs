//! Bench: regenerate **Figure 10** — HPL (Linpack) performance in
//! flops/cycle vs problem size, for POWER9 / POWER10-VSX / POWER10-MMA.
//!
//! Paper reference points (read off Figure 10 / §VI text): all curves rise
//! with N; at large N POWER10-VSX ≈ 2× POWER9 and POWER10-MMA ≈ 2× the
//! vector code ( = 4× POWER9 per core).
//!
//! Run: `cargo bench --bench fig10_hpl`

use power_mma::benchkit::{bench, f2, report};
use power_mma::hpl::{hpl_cycles, CycleCost, Setup};
use power_mma::metrics::Table;

fn main() {
    let sizes = [256usize, 512, 1024, 2048, 4096, 8192, 16384];
    let mut table = Table::new(&[
        "N",
        "POWER9",
        "POWER10-VSX",
        "POWER10-MMA",
        "VSX/P9",
        "MMA/VSX",
        "MMA/P9",
    ]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut v = Vec::new();
        for (i, &setup) in Setup::ALL.iter().enumerate() {
            v.push(hpl_cycles(setup, n, 128, &mut costs[i]).flops_per_cycle());
        }
        table.row(&[
            n.to_string(),
            f2(v[0]),
            f2(v[1]),
            f2(v[2]),
            f2(v[1] / v[0]),
            f2(v[2] / v[1]),
            f2(v[2] / v[0]),
        ]);
    }
    println!("Figure 10 — HPL performance (flops/cycle):\n{}", table.render());
    println!(
        "paper: POWER10-VSX ~2x POWER9; POWER10-MMA ~2x POWER10-VSX (4x POWER9) at large N\n"
    );

    // wall-clock cost of regenerating the figure (the harness itself)
    let s = bench("fig10_full_sweep", 1, 5, || {
        let mut cost = CycleCost::new(Setup::Power10Mma);
        let t = hpl_cycles(Setup::Power10Mma, 4096, 128, &mut cost);
        assert!(t.flops_per_cycle() > 1.0);
    });
    report(&s);
}
