//! Bench: **Table I throughput** — sustained rank-k updates/cycle and
//! MACs/cycle for every MMA instruction family on the POWER10 model, plus
//! the functional simulator's wall-clock execution rate per kind.
//!
//! The paper's Table I implies a throughput hierarchy: at 2 gers/cycle the
//! MME sustains 16 fp64, 32 fp32, 64 fp16/bf16, 64 int16, 128 int8, 256
//! int4 MACs per cycle. This bench verifies the model reproduces it.
//!
//! Run: `cargo bench --bench inst_throughput`

use power_mma::benchkit::{bench, report};
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::inst::{AccOp, Ger, GerKind, Inst};
use power_mma::isa::Machine;
use power_mma::metrics::Table;

/// A tight loop of independent gers over all 8 accumulators.
fn ger_loop(kind: GerKind, iters: i32) -> Vec<Inst> {
    let mut prog = vec![Inst::Addi { rt: 9, ra: 0, si: iters }, Inst::Mtctr { rs: 9 }];
    for a in 0..8u8 {
        let xa = if kind == GerKind::F64Ger { 32 + 2 * a } else { 32 + a };
        prog.push(Inst::Ger(Ger::new(kind, AccOp::New, a, xa, 56 + (a % 8))));
    }
    prog.push(Inst::Bdnz { bd: -32 });
    prog.push(Inst::Blr);
    prog
}

fn main() {
    let mut table = Table::new(&[
        "instruction",
        "rank",
        "MACs/inst",
        "gers/cycle",
        "MACs/cycle",
        "sim Minst/s",
    ]);
    for kind in GerKind::ALL {
        let prog = ger_loop(kind, 2000);
        // timing model
        let mut sim = CoreSim::new(MachineConfig::power10());
        let r = sim.run(&prog, 1 << 22);
        let gers_per_cycle = r.units.mma_ops as f64 / r.cycles as f64;
        let macs_per_cycle = r.flops as f64 / 2.0 / r.cycles as f64;
        // functional simulator wall-clock
        let mut m = Machine::new(64);
        let s = bench(&format!("exec_{}", kind.mnemonic()), 1, 10, || {
            m.run(&prog, 1 << 22).unwrap();
        });
        let minst = r.instructions as f64 / s.median.as_secs_f64() / 1e6;
        table.row(&[
            kind.mnemonic().to_string(),
            kind.rank().to_string(),
            (kind.flops() / 2).to_string(),
            format!("{gers_per_cycle:.2}"),
            format!("{macs_per_cycle:.1}"),
            format!("{minst:.1}"),
        ]);
    }
    println!("\nTable I — MMA instruction throughput on the POWER10 model:\n{}", table.render());
    println!("paper: 2 MME pipes -> 2 gers/cycle; MACs scale 8/16/32/32/64/128 per ger");

    // accumulator move instruction costs (§III bus transfers)
    let mut sim = CoreSim::new(MachineConfig::power10());
    let mt = sim.run(&[Inst::XxMtAcc { acc: 0 }, Inst::Blr], 10);
    let mf = sim.run(&[Inst::XxSetAccZ { acc: 0 }, Inst::XxMfAcc { acc: 0 }, Inst::Blr], 10);
    println!(
        "\naccumulator transfers: xxmtacc {} cycles (paper: 2), xxsetaccz+xxmfacc {} cycles (paper: 4+e)",
        mt.cycles, mf.cycles
    );

    let s = bench("encode_decode_fig7_loop", 10, 1000, || {
        let bytes =
            power_mma::isa::encode::encode_program(&power_mma::kernels::dgemm::fig7_loop_body())
                .unwrap();
        let prog = power_mma::isa::encode::decode_program(&bytes).unwrap();
        assert_eq!(prog.len(), 17);
    });
    report(&s);
}
