//! Bench: the §VI ResNet-50 claim — "For more performance results on both
//! HPL and ResNet-50 (also 4x the per core performance of POWER9)".
//!
//! ResNet-50's convolution layers lower to GEMMs (im2col shapes). For a
//! representative set of layer shapes we time the fp32 GEMM work on the
//! three configurations: POWER9 (VSX sgemm), POWER10-VSX (same code),
//! POWER10-MMA (the Figure 8 xvf32ger kernel), and report per-layer and
//! network-weighted speedups.
//!
//! Run: `cargo bench --bench resnet_conv`

use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::inst::GerKind;
use power_mma::kernels::gemm_rp::rp_gemm_program;
use power_mma::kernels::vsx::vsx_sgemm_8x8_program;
use power_mma::metrics::Table;

/// Representative ResNet-50 conv layers as im2col GEMMs:
/// (name, M = out-channels, N = out-pixels (56x56 etc.), K = Cin*kh*kw).
const LAYERS: &[(&str, usize, usize, usize)] = &[
    ("conv1 7x7/2", 64, 112 * 112, 147),
    ("res2 1x1", 64, 56 * 56, 64),
    ("res2 3x3", 64, 56 * 56, 576),
    ("res3 3x3", 128, 28 * 28, 1152),
    ("res4 3x3", 256, 14 * 14, 2304),
    ("res5 3x3", 512, 7 * 7, 4608),
    ("fc", 1000, 1, 2048),
];

/// Cycles for an MxNxK fp32 GEMM on a configuration.
fn gemm_cycles(sim: &mut CoreSim, mma: bool, m: usize, n: usize, k: usize) -> u64 {
    // one micro-kernel call, scaled by tile count (trace-cache style)
    let (tile_m, tile_n, per_call) = if mma {
        let prog = rp_gemm_program(GerKind::F32Ger, k.max(1), None);
        (8, 16, sim.run(&prog, 1 << 26).cycles)
    } else {
        let prog = vsx_sgemm_8x8_program(k.max(1));
        (8, 8, sim.run(&prog, 1 << 26).cycles)
    };
    (m.div_ceil(tile_m) as u64) * (n.div_ceil(tile_n) as u64) * per_call
}

fn main() {
    let mut table = Table::new(&["layer", "GEMM (MxNxK)", "P9 f/c", "P10-VSX f/c", "P10-MMA f/c", "MMA/P9"]);
    let mut total = [0u64; 3];
    let mut total_flops = 0f64;
    for &(name, m, n, k) in LAYERS {
        let flops = 2.0 * (m * n * k) as f64;
        total_flops += flops;
        let mut vals = Vec::new();
        for (i, mma) in [(0, false), (1, false), (2, true)] {
            let cfg = if i == 0 { MachineConfig::power9() } else { MachineConfig::power10() };
            let mut sim = CoreSim::new(cfg);
            let cycles = gemm_cycles(&mut sim, mma, m, n, k);
            total[i] += cycles;
            vals.push(flops / cycles as f64);
        }
        table.row(&[
            name.to_string(),
            format!("{m}x{n}x{k}"),
            format!("{:.2}", vals[0]),
            format!("{:.2}", vals[1]),
            format!("{:.2}", vals[2]),
            format!("{:.2}", vals[2] / vals[0]),
        ]);
    }
    println!("ResNet-50 conv layers as fp32 GEMMs (flops/cycle):\n{}", table.render());
    let agg: Vec<f64> = total.iter().map(|&c| total_flops / c as f64).collect();
    println!(
        "network-weighted: P9 {:.2}, P10-VSX {:.2}, P10-MMA {:.2} flops/cycle -> \
         P10-MMA = {:.2}x P9 per core (paper §VI: \"also 4x\")",
        agg[0],
        agg[1],
        agg[2],
        agg[2] / agg[0]
    );
    assert!(agg[2] / agg[0] > 3.0, "the ResNet-50 4x claim must reproduce");
}
