//! Bench: ablations over the design choices §III argues for.
//!
//! 1. **MME pipes** — 1 vs 2 pipes (the "two rank-k updates per cycle"
//!    organization of Figure 2);
//! 2. **accumulator-local issue latency** — §III point 5: MMA wins partly
//!    because the accumulator never round-trips the register file; sweep
//!    the ger accumulate latency to see when the 8-accumulator software
//!    pipeline stops hiding it;
//! 3. **vector-width alternative** — §III point 2's comparison: the VSX
//!    kernel's splat overhead vs the MMA kernel's none;
//! 4. **prefixed masked forms** — residual-tile handling cost vs
//!    zero-padding (the §II-C motivation).
//!
//! Run: `cargo bench --bench ablations`

use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::inst::Inst;
use power_mma::kernels::dgemm::dgemm_8xnx8_program;
use power_mma::kernels::gemm_rp::rp_gemm_program;
use power_mma::kernels::vsx::vsx_dgemm_8x4_program;
use power_mma::metrics::Table;

fn main() {
    let kernel = dgemm_8xnx8_program(128);

    // ---- 1. MME pipe count ------------------------------------------------
    let mut table = Table::new(&["MME pipes", "flops/cycle", "% of 2-pipe"]);
    let mut base = 0.0;
    for pipes in [1u32, 2, 4] {
        let mut cfg = MachineConfig::power10();
        cfg.mma_pipes = pipes;
        let r = CoreSim::new(cfg).run(&kernel, 1 << 22);
        if pipes == 2 {
            base = r.flops_per_cycle();
        }
        table.row(&[
            pipes.to_string(),
            format!("{:.2}", r.flops_per_cycle()),
            String::new(),
        ]);
    }
    println!("ablation 1 — MME pipes (paper: 2, fed from slices 2/3):\n{}", table.render());
    println!("2 pipes double 1-pipe throughput; 4 pipes would outrun the 8-wide front end\n");

    // ---- 2. accumulator forwarding latency --------------------------------
    let mut table = Table::new(&["ger acc latency", "flops/cycle", "hidden?"]);
    for lat in [1u32, 2, 4, 8, 16, 32] {
        let mut cfg = MachineConfig::power10();
        cfg.ger_acc_latency = lat;
        let r = CoreSim::new(cfg).run(&kernel, 1 << 22);
        let hidden = r.flops_per_cycle() > 0.95 * base;
        table.row(&[
            lat.to_string(),
            format!("{:.2}", r.flops_per_cycle()),
            if hidden { "yes".into() } else { "NO".into() },
        ]);
    }
    println!(
        "ablation 2 — same-accumulator issue-to-accumulate latency (§III point 5):\n{}",
        table.render()
    );
    println!("8 accumulators x 2 pipes hide up to ~8 cycles; register-file round trips would not\n");

    // ---- 3. the vector-width alternative -----------------------------------
    let vsx = vsx_dgemm_8x4_program(128);
    let splats = vsx.iter().filter(|i| matches!(i, Inst::XxSpltd { .. })).count();
    let r10v = CoreSim::new(MachineConfig::power10()).run(&vsx, 1 << 22);
    let r10m = CoreSim::new(MachineConfig::power10()).run(&kernel, 1 << 22);
    println!(
        "ablation 3 — vector alternative (§III point 2/4): VSX kernel spends {splats} splat \
         ops per loop feeding the FMAs; {:.2} vs {:.2} flops/cycle ({:.2}x for MMA)\n",
        r10v.flops_per_cycle(),
        r10m.flops_per_cycle(),
        r10m.flops_per_cycle() / r10v.flops_per_cycle()
    );

    // ---- 4. masked residual handling ---------------------------------------
    // k = 33 with a rank-2 kind: 16 full steps + 1 masked step, vs padding
    // to 17 full steps (the pre-ISA-3.1 alternative)
    use power_mma::isa::inst::GerKind;
    let masked = rp_gemm_program(GerKind::Bf16Ger2, 16, Some(0b01));
    let padded = rp_gemm_program(GerKind::Bf16Ger2, 17, None);
    let rm = CoreSim::new(MachineConfig::power10()).run(&masked, 1 << 22);
    let rp = CoreSim::new(MachineConfig::power10()).run(&padded, 1 << 22);
    let mut table = Table::new(&["variant", "cycles", "useful MACs", "MACs/cycle"]);
    let useful = 8 * 16 * 33 / 2; // per-ger MACs are halved by the tail mask
    table.row(&[
        "pm-masked tail (§II-C)".into(),
        rm.cycles.to_string(),
        (rm.flops / 2).to_string(),
        format!("{:.1}", rm.flops as f64 / 2.0 / rm.cycles as f64),
    ]);
    table.row(&[
        "zero-padded".into(),
        rp.cycles.to_string(),
        (rp.flops / 2).to_string(),
        format!("{:.1}", useful as f64 / rp.cycles as f64),
    ]);
    println!("ablation 4 — residual k handling (bf16, k=33):\n{}", table.render());
    println!(
        "the masked form does not execute disabled products (\"computations on disabled rows \
         and columns are not performed\", §II-C)"
    );
}
