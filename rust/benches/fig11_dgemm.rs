//! Bench: regenerate **Figure 11** — DGEMM performance, multiplying an
//! N×128 matrix by a 128×N matrix, in flops/cycle.
//!
//! Paper reference points: POWER9 ≈ 4.5 (56% of its 8 peak), POWER10-VSX
//! ≈ 10 (62% of 16), POWER10-MMA ≈ 26 (>80% of 32); MMA > 2.5× the P10
//! vector code and > 5.5× POWER9.
//!
//! Run: `cargo bench --bench fig11_dgemm`

use power_mma::benchkit::{bench, report};
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::hpl::{CycleCost, Setup};
use power_mma::kernels::dgemm::dgemm_8xnx8_program;
use power_mma::metrics::Table;

fn main() {
    let sizes = [128usize, 256, 512, 1024, 2048, 4096];
    let mut table = Table::new(&[
        "N",
        "POWER9",
        "%peak",
        "POWER10-VSX",
        "%peak",
        "POWER10-MMA",
        "%peak",
        "MMA/VSX",
        "MMA/P9",
    ]);
    let mut costs: Vec<CycleCost> = Setup::ALL.iter().map(|&s| CycleCost::new(s)).collect();
    for &n in &sizes {
        let mut v = Vec::new();
        for (i, _) in Setup::ALL.iter().enumerate() {
            let cycles = costs[i].dgemm_cycles(n, n, 128);
            v.push(2.0 * (n * n * 128) as f64 / cycles as f64);
        }
        table.row(&[
            n.to_string(),
            format!("{:.2}", v[0]),
            format!("{:.0}%", 100.0 * v[0] / Setup::Power9Vsx.peak()),
            format!("{:.2}", v[1]),
            format!("{:.0}%", 100.0 * v[1] / Setup::Power10Vsx.peak()),
            format!("{:.2}", v[2]),
            format!("{:.0}%", 100.0 * v[2] / Setup::Power10Mma.peak()),
            format!("{:.2}", v[2] / v[1]),
            format!("{:.2}", v[2] / v[0]),
        ]);
    }
    println!("Figure 11 — DGEMM Nx128 * 128xN (flops/cycle):\n{}", table.render());
    println!("paper: P9 ~4.5 (56%), P10-VSX ~10 (62%), P10-MMA ~26 (>80%)\n");

    // simulator wall-clock throughput on the hot kernel
    let prog = dgemm_8xnx8_program(128);
    let mut sim = CoreSim::new(MachineConfig::power10());
    let insts = 2231f64; // dynamic instructions of the 8x128x8 kernel
    let s = bench("coresim_dgemm_8x128x8", 3, 50, || {
        let r = sim.run(&prog, 1 << 22);
        assert!(r.cycles > 0);
    });
    report(&s);
    println!(
        "timing-simulator speed: {:.1} M simulated instructions/s",
        insts / s.median.as_secs_f64() / 1e6
    );
}
