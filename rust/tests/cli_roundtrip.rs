//! Smoke tests for the `power-mma` binary's subcommand paths, driven
//! through the real executable (`CARGO_BIN_EXE_*`): the `asm`/`disasm`
//! round trip over the paper's Figure 7 object-code listing, the
//! `gen-artifacts` writer, and a small `serve` self-test load on the
//! native plan backend.

use power_mma::isa::encode::FIG7_WORDS;
use std::io::Write;
use std::process::{Command, Stdio};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_power-mma"))
}

/// Run the binary with `args`, feeding `stdin`, returning (status, stdout).
fn run(args: &[&str], stdin: &str) -> (bool, String) {
    let mut child = bin()
        .args(args)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn power-mma");
    child
        .stdin
        .as_mut()
        .expect("stdin piped")
        .write_all(stdin.as_bytes())
        .expect("write stdin");
    let out = child.wait_with_output().expect("wait for power-mma");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    if !out.status.success() {
        eprintln!("stderr: {}", String::from_utf8_lossy(&out.stderr));
    }
    (out.status.success(), stdout)
}

/// The Figure 7 listing as the hex-word text the CLI consumes/emits.
fn fig7_hex() -> String {
    FIG7_WORDS.iter().map(|w| format!("{w:08x}\n")).collect()
}

#[test]
fn disasm_then_asm_round_trips_figure7() {
    // bytes -> mnemonics
    let (ok, asm_text) = run(&["disasm"], &fig7_hex());
    assert!(ok, "disasm must succeed on the Figure 7 words");
    assert!(
        asm_text.contains("xvf64gerpp"),
        "Figure 7 contains rank-2 fp64 updates, got:\n{asm_text}"
    );
    assert!(asm_text.contains("lxvp"), "Figure 7 starts with paired loads");

    // mnemonics -> bytes: must reproduce the paper listing word for word
    let (ok, hex_text) = run(&["asm"], &asm_text);
    assert!(ok, "asm must accept its own disassembly");
    let words: Vec<&str> = hex_text.split_whitespace().collect();
    let expect: Vec<String> = FIG7_WORDS.iter().map(|w| format!("{w:08x}")).collect();
    assert_eq!(words, expect, "asm(disasm(fig7)) != fig7");
}

#[test]
fn asm_rejects_garbage_with_nonzero_exit() {
    let (ok, _) = run(&["asm"], "xvnonsense a0, vs32, vs33\n");
    assert!(!ok, "an unknown mnemonic must fail the assembler");
}

#[test]
fn gen_artifacts_writes_a_loadable_set() {
    let dir = std::env::temp_dir().join(format!("mma-cli-gen-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, stdout) = run(&["gen-artifacts", "--out", dir.to_str().unwrap()], "");
    assert!(ok, "gen-artifacts must succeed");
    assert!(stdout.contains("wrote 4 artifacts"), "{stdout}");
    for name in ["gemm_f32", "gemm_bf16", "conv2d_k3", "mlp_b32"] {
        assert!(dir.join(format!("{name}.hlo.txt")).exists(), "{name} hlo");
        assert!(dir.join(format!("{name}.meta")).exists(), "{name} meta");
        assert!(dir.join(format!("{name}.expected.bin")).exists(), "{name} expected");
    }
    assert!(dir.join("manifest.txt").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_self_test_runs_on_the_native_backend() {
    let dir = std::env::temp_dir().join(format!("mma-cli-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, stdout) = run(
        &["serve", "--artifacts", dir.to_str().unwrap(), "--requests", "40"],
        "",
    );
    assert!(ok, "serve self-test must complete green: {stdout}");
    assert!(stdout.contains("served 40/40"), "all requests must succeed: {stdout}");
    std::fs::remove_dir_all(&dir).ok();
}
