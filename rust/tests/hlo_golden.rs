//! Golden tests for the native HLO interpreter.
//!
//! 1. **Parse goldens** — every embedded artifact (the HLO text emitted by
//!    `python/compile/aot.py` for each `python/compile` kernel's serving
//!    graph) must parse, with parameters/shapes agreeing with its meta.
//! 2. **Numerics goldens** — the interpreter's output on the deterministic
//!    inputs must match *two* independent oracles: the python-computed
//!    `.expected.bin` fixtures (JAX), and a rust reimplementation of
//!    `python/compile/kernels/ref.py` built on `blas::gemm::RefGemm`'s
//!    kernel (`ref_gemm`).

use power_mma::blas::gemm::ref_gemm;
use power_mma::runtime::artifacts::EMBEDDED;
use power_mma::runtime::hlo::{bf16_round, HloModule};
use power_mma::runtime::{det_inputs, ModelMeta};
use power_mma::testkit::assert_allclose_f32;

fn expected_f32(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect()
}

/// f32 GEMM oracle (ref.py::gemm_ref): f64 accumulation via `ref_gemm`,
/// rounded to f32 — the same BLAS kernel the interpreter's `dot` uses.
fn gemm_oracle(x: &[f32], y: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let xf: Vec<f64> = x.iter().map(|&v| f64::from(v)).collect();
    let yf: Vec<f64> = y.iter().map(|&v| f64::from(v)).collect();
    ref_gemm(&xf, &yf, m, n, k).iter().map(|&v| v as f32).collect()
}

/// bf16 GEMM oracle (ref.py::gemm_bf16_ref): inputs rounded to the bf16
/// grid, products and sums wide.
fn gemm_bf16_oracle(x: &[f32], y: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let xb: Vec<f32> = x.iter().map(|&v| bf16_round(v)).collect();
    let yb: Vec<f32> = y.iter().map(|&v| bf16_round(v)).collect();
    gemm_oracle(&xb, &yb, m, n, k)
}

/// Direct 3×3 multi-channel valid convolution (ref.py::conv3x3_ref):
/// taps ordered `9c + 3ky + kx`, f32 accumulation in the same tap order
/// as the lowered serving graph.
fn conv_oracle(h: &[f32], img: &[f32], rows: usize, width: usize) -> Vec<f32> {
    let (out_rows, out_w) = (rows - 2, width - 2);
    let mut out = vec![0f32; 8 * out_rows * out_w];
    for c in 0..3 {
        for ky in 0..3 {
            for kx in 0..3 {
                for f in 0..8 {
                    let tap = h[f * 27 + 9 * c + 3 * ky + kx];
                    for r in 0..out_rows {
                        for x in 0..out_w {
                            out[f * out_rows * out_w + r * out_w + x] +=
                                tap * img[c * rows * width + (r + ky) * width + (x + kx)];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Two-layer MLP oracle (ref.py::mlp_ref): relu(x·W1 + b1)·W2 + b2, both
/// matmuls through `ref_gemm`, bias/relu in f32 like the lowered graph.
fn mlp_oracle(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    batch: usize,
    features: usize,
    hidden: usize,
    classes: usize,
) -> Vec<f32> {
    let mut h = gemm_oracle(x, w1, batch, hidden, features);
    for r in 0..batch {
        for j in 0..hidden {
            h[r * hidden + j] = (h[r * hidden + j] + b1[j]).max(0.0);
        }
    }
    let mut out = gemm_oracle(&h, w2, batch, classes, hidden);
    for r in 0..batch {
        for j in 0..classes {
            out[r * classes + j] += b2[j];
        }
    }
    out
}

#[test]
fn every_compile_kernel_artifact_parses() {
    assert_eq!(EMBEDDED.len(), 5, "gemm_f32, gemm_bf16, conv2d_k3, mlp_b32, dft_b32");
    for a in EMBEDDED {
        let meta = ModelMeta::parse(a.meta).unwrap();
        let module = HloModule::parse(a.hlo_text)
            .unwrap_or_else(|e| panic!("{}: HLO text must parse: {e}", a.name));
        assert!(
            module.num_instructions() >= 4,
            "{}: implausibly small entry computation",
            a.name
        );
        assert_eq!(
            module.num_parameters(),
            meta.input_shapes.len(),
            "{}: parameter count",
            a.name
        );
        for (i, shape) in meta.input_shapes.iter().enumerate() {
            let dims = module
                .parameter_dims(i)
                .unwrap_or_else(|| panic!("{}: missing parameter {i}", a.name));
            assert_eq!(dims, shape.as_slice(), "{}: parameter {i} shape", a.name);
        }
        assert!(module.name.contains("jit_"), "{}: jax-lowered module name", a.name);
    }
}

#[test]
fn interpreter_matches_python_expected_fixtures() {
    for a in EMBEDDED {
        let meta = ModelMeta::parse(a.meta).unwrap();
        let module = HloModule::parse(a.hlo_text).unwrap();
        let inputs = det_inputs(&meta);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = module.evaluate(&refs).unwrap();
        // multi-root graphs (the DFT family's (yr, yi) pair) stack their
        // outputs along axis 0 — the same root-order concatenation
        // aot.py applies before writing `.meta`/`.expected.bin`
        assert!(!out.is_empty(), "{}: empty output tuple", a.name);
        let mut stacked_dims = out[0].dims.clone();
        for t in &out[1..] {
            assert_eq!(t.dims[1..], out[0].dims[1..], "{}: root shapes", a.name);
            stacked_dims[0] += t.dims[0];
        }
        assert_eq!(stacked_dims, meta.output_shape, "{}: output shape", a.name);
        let data: Vec<f32> = out.iter().flat_map(|t| t.data.iter().copied()).collect();
        let expect = expected_f32(a.expected);
        assert_allclose_f32(&data, &expect, 1e-5, 1e-5);
    }
}

#[test]
fn gemm_f32_matches_refgemm_oracle() {
    let a = EMBEDDED.iter().find(|a| a.name == "gemm_f32").unwrap();
    let meta = ModelMeta::parse(a.meta).unwrap();
    let module = HloModule::parse(a.hlo_text).unwrap();
    let inputs = det_inputs(&meta);
    let g = meta.input_shapes[0][0];
    let out = module.evaluate(&[&inputs[0], &inputs[1]]).unwrap();
    let oracle = gemm_oracle(&inputs[0], &inputs[1], g, g, g);
    // same ref_gemm kernel underneath -> bit-identical
    assert_eq!(out[0].data, oracle, "interpreter dot must be the blas ref_gemm kernel");
}

#[test]
fn gemm_bf16_matches_bf16_oracle_and_differs_from_f32() {
    let a = EMBEDDED.iter().find(|a| a.name == "gemm_bf16").unwrap();
    let meta = ModelMeta::parse(a.meta).unwrap();
    let module = HloModule::parse(a.hlo_text).unwrap();
    let inputs = det_inputs(&meta);
    let g = meta.input_shapes[0][0];
    let out = module.evaluate(&[&inputs[0], &inputs[1]]).unwrap();
    let oracle = gemm_bf16_oracle(&inputs[0], &inputs[1], g, g, g);
    assert_eq!(out[0].data, oracle, "bf16 convert + dot must equal the rounded oracle");
    // the bf16 rounding must actually bite (different numbers than f32)
    let f32_result = gemm_oracle(&inputs[0], &inputs[1], g, g, g);
    assert_ne!(out[0].data, f32_result, "bf16 path must round inputs");
}

#[test]
fn conv2d_matches_direct_convolution_oracle() {
    let a = EMBEDDED.iter().find(|a| a.name == "conv2d_k3").unwrap();
    let meta = ModelMeta::parse(a.meta).unwrap();
    let module = HloModule::parse(a.hlo_text).unwrap();
    let inputs = det_inputs(&meta);
    let (rows, width) = (meta.input_shapes[1][1], meta.input_shapes[1][2]);
    let out = module.evaluate(&[&inputs[0], &inputs[1]]).unwrap();
    let oracle = conv_oracle(&inputs[0], &inputs[1], rows, width);
    // identical f32 accumulation order -> very tight
    assert_allclose_f32(&out[0].data, &oracle, 1e-6, 1e-6);
}

#[test]
fn mlp_matches_refgemm_oracle() {
    let a = EMBEDDED.iter().find(|a| a.name == "mlp_b32").unwrap();
    let meta = ModelMeta::parse(a.meta).unwrap();
    let module = HloModule::parse(a.hlo_text).unwrap();
    let inputs = det_inputs(&meta);
    let (batch, features) = (meta.input_shapes[0][0], meta.input_shapes[0][1]);
    let hidden = meta.input_shapes[1][1];
    let classes = meta.input_shapes[3][1];
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let out = module.evaluate(&refs).unwrap();
    let oracle = mlp_oracle(
        &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4],
        batch, features, hidden, classes,
    );
    assert_allclose_f32(&out[0].data, &oracle, 1e-6, 1e-6);
}
