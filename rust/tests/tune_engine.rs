//! Differential coverage for the microkernel family + shape autotuner:
//! every monomorphized `GemmVariant` (register tile × blocking grid
//! point) must be **bitwise identical** to the canonical engine — and to
//! the existing scalar oracles — under every accumulation contract,
//! epilogue, and column-chunk parallel policy, across shapes that
//! straddle the MR/NR tile seams and every KC tail. On top rides the
//! `TuneTable` contract: first sight of a class measures and memoizes,
//! re-compiles reuse the row without re-measuring, pre-seeded rows are
//! honored verbatim (baked into compiled plan steps), and `tune: None`
//! reproduces the pre-autotuner canonical configuration exactly.

use power_mma::blas::bf16_gemm::{
    gemm_bf16_reference, gemm_bf16_reference_pairs, gemm_bf16_tuned_into, Bf16Accum, Bf16Scratch,
    Bf16Src,
};
use power_mma::blas::block_gemm::{
    chunk_plan_nr, gemm_f32_tuned_into, threads_for, threads_for_pooled, Accum, BlockCfg,
    Epilogue, GemmScratch, GemmVariant, PanelB, Par,
};
use power_mma::blas::i8_gemm::{
    gemm_i8_dequant_reference, gemm_i8_dequant_tuned_into, gemm_i8_packed_tuned_into,
    gemm_i8_reference, I8Accum, I8Epilogue, I8Scratch, I8SrcA, I8SrcB, QuantParams,
};
use power_mma::runtime::tune::heuristic_variant;
use power_mma::runtime::{TuneChoice, TuneDtype, TuneEpi, TuneKey, TunePanel, TuneTable};
use power_mma::testkit::{check, Rng};

/// Scalar f32 oracle with the `Accum::F64` contract: one per-element f64
/// chain in strictly ascending `k`, narrowed once, then the fused
/// epilogue — exactly the interpreter's elementwise image.
fn ref_f32_f64acc(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    bias: Option<&[f32]>,
    relu: bool,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += f64::from(a[i * k + p]) * f64::from(b[p * n + j]);
            }
            let mut v = acc as f32;
            if let Some(bias) = bias {
                v += bias[j];
            }
            if relu {
                v = v.max(0.0);
            }
            c[i * n + j] = v;
        }
    }
    c
}

fn run_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    accum: Accum,
    epi: Epilogue<'_>,
    par: Par<'_>,
    v: GemmVariant,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    let mut scratch = GemmScratch::new();
    gemm_f32_tuned_into(&mut c, a, PanelB::Matrix(b), m, n, k, accum, epi, par, &mut scratch, v);
    c
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Random signed operand with the extremes present (the i8 sweeps).
fn spiked_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    let mut v: Vec<i8> = (0..len).map(|_| rng.irange(-128, 127) as i8).collect();
    for (i, &s) in [-128i8, 127, 0, -1, 1].iter().enumerate() {
        v[(i * 11 + 5) % len.max(1)] = s;
    }
    v
}

fn spiked_u8(rng: &mut Rng, len: usize) -> Vec<u8> {
    let mut v: Vec<u8> = (0..len).map(|_| rng.irange(0, 255) as u8).collect();
    for (i, &s) in [255u8, 0, 128, 1, 254].iter().enumerate() {
        v[(i * 13 + 7) % len.max(1)] = s;
    }
    v
}

// ---------------------------------------------------------------- tentpole

#[test]
fn every_f32_variant_matches_canonical_and_the_oracle_bitwise() {
    // the whole family (3 register tiles × 8 blocking grid points) vs
    // the canonical engine and the scalar f64-chain oracle, across tile
    // seams, KC tails, both accumulation contracts, fused epilogues,
    // and the scoped parallel policy — not one bit may move
    check("tune f32 variant family", 10, |rng: &mut Rng| {
        let m = *rng.pick(&[1usize, 3, 4, 5, 7, 8, 9, 17, 33]);
        let n = *rng.pick(&[1usize, 7, 8, 9, 15, 16, 17, 33]);
        let k = *rng.pick(&[1usize, 2, 5, 8, 127, 128, 129, 255, 256, 257]);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let oracle = ref_f32_f64acc(&a, &b, m, n, k, None, false);
        let oracle_relu = ref_f32_f64acc(&a, &b, m, n, k, Some(&bias), true);
        let canon = GemmVariant::CANONICAL_F32;
        let base_f32 =
            run_f32(&a, &b, m, n, k, Accum::F32, Epilogue::Bias(&bias), Par::Seq, canon);
        for v in GemmVariant::f32_candidates() {
            let plain = run_f32(&a, &b, m, n, k, Accum::F64, Epilogue::None, Par::Seq, v);
            assert_eq!(bits(&plain), bits(&oracle), "{} vs f64 oracle m={m} n={n} k={k}", v.name());
            let relu =
                run_f32(&a, &b, m, n, k, Accum::F64, Epilogue::BiasRelu(&bias), Par::Scoped(3), v);
            assert_eq!(bits(&relu), bits(&oracle_relu), "{} bias_relu scoped", v.name());
            let f32acc = run_f32(&a, &b, m, n, k, Accum::F32, Epilogue::Bias(&bias), Par::Seq, v);
            assert_eq!(bits(&f32acc), bits(&base_f32), "{} f32-chain vs canonical", v.name());
        }
    });
}

#[test]
fn every_bf16_variant_matches_the_references_bitwise() {
    // both bf16 accumulation contracts (widened f64 image, f32 k-pair
    // chain) against their elementwise references for every wide-family
    // variant — the grid keeps kc even, so no pair is ever split
    check("tune bf16 variant family", 8, |rng: &mut Rng| {
        let m = *rng.pick(&[1usize, 7, 8, 9, 17]);
        let n = *rng.pick(&[1usize, 8, 15, 16, 17, 33]);
        let k = *rng.pick(&[1usize, 2, 3, 127, 128, 129, 255, 256, 257]);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let widened = gemm_bf16_reference(&a, &b, m, n, k);
        let pairs = gemm_bf16_reference_pairs(&a, &b, m, n, k);
        for v in GemmVariant::wide_candidates() {
            for (accum, want) in [(Bf16Accum::Widened, &widened), (Bf16Accum::F32Pairs, &pairs)] {
                for par in [Par::Seq, Par::Scoped(3)] {
                    let mut c = vec![0f32; m * n];
                    let mut scratch = Bf16Scratch::new();
                    gemm_bf16_tuned_into(
                        &mut c,
                        Bf16Src::F32(&a),
                        Bf16Src::F32(&b),
                        m,
                        n,
                        k,
                        accum,
                        Epilogue::None,
                        par,
                        &mut scratch,
                        v,
                    );
                    assert_eq!(
                        bits(&c),
                        bits(want),
                        "{} {accum:?} m={m} n={n} k={k}",
                        v.name()
                    );
                }
            }
            // fused bias / bias+relu tails: bitwise the separate
            // elementwise instructions applied after the widened oracle
            for relu in [false, true] {
                let want: Vec<f32> = widened
                    .iter()
                    .enumerate()
                    .map(|(idx, &x)| {
                        let s = x + bias[idx % n];
                        if relu {
                            s.max(0.0)
                        } else {
                            s
                        }
                    })
                    .collect();
                let epi =
                    if relu { Epilogue::BiasRelu(&bias) } else { Epilogue::Bias(&bias) };
                let mut c = vec![0f32; m * n];
                let mut scratch = Bf16Scratch::new();
                gemm_bf16_tuned_into(
                    &mut c,
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    Bf16Accum::Widened,
                    epi,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                assert_eq!(bits(&c), bits(&want), "{} relu={relu} m={m} n={n} k={k}", v.name());
            }
        }
    });
}

#[test]
fn every_i8_variant_matches_the_references_bitwise() {
    // the raw integer dot under both chains (wrapping / saturating) and
    // the fused quantize→dot→dequantize serving path with every
    // epilogue, for every wide-family variant — kc stays a multiple of
    // 4, so no rank-4 quad is ever split across a depth block
    check("tune i8 variant family", 8, |rng: &mut Rng| {
        let m = *rng.pick(&[1usize, 7, 8, 9, 17]);
        let n = *rng.pick(&[1usize, 8, 15, 16, 17, 33]);
        let k = *rng.pick(&[1usize, 3, 4, 5, 127, 128, 129, 255, 256, 257]);
        let aq = spiked_i8(rng, m * k);
        let bq = spiked_u8(rng, k * n);
        let af = rng.f32_vec(m * k);
        let bf = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let q = QuantParams {
            a_scale: 1.0 / 127.0,
            a_zp: rng.irange(-8, 8) as i32,
            b_scale: 1.0 / 255.0,
            b_zp: rng.irange(96, 160) as i32,
        };
        for v in GemmVariant::wide_candidates() {
            for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
                let want = gemm_i8_reference(&aq, &bq, m, n, k, accum);
                let mut c = vec![0i32; m * n];
                let mut scratch = I8Scratch::new();
                gemm_i8_packed_tuned_into(
                    &mut c,
                    I8SrcA::Q(&aq),
                    I8SrcB::Q(&bq),
                    m,
                    n,
                    k,
                    accum,
                    Par::Scoped(3),
                    &mut scratch,
                    v,
                );
                assert_eq!(c, want, "{} {accum:?} m={m} n={n} k={k}", v.name());
            }
            let cases: [(I8Epilogue<'_>, Option<&[f32]>, bool); 3] = [
                (I8Epilogue::None, None, false),
                (I8Epilogue::Bias(&bias), Some(&bias), false),
                (I8Epilogue::BiasRelu(&bias), Some(&bias), true),
            ];
            for (epi, rbias, relu) in cases {
                let want = gemm_i8_dequant_reference(&af, &bf, m, n, k, &q, rbias, relu);
                let mut c = vec![0f32; m * n];
                let mut scratch = I8Scratch::new();
                gemm_i8_dequant_tuned_into(
                    &mut c, &af, &bf, m, n, k, &q, epi, Par::Seq, &mut scratch, v,
                );
                assert_eq!(bits(&c), bits(&want), "{} dequant relu={relu}", v.name());
            }
        }
    });
}

// ------------------------------------------- satellite: chunk-plan laws

#[test]
fn chunk_plan_covers_every_column_exactly_once_for_every_nr() {
    // exact coverage, no overlap, nr-aligned chunk starts, cap clamped
    // to the column-panel count, last chunk never empty — for both
    // register-tile widths in the family
    for nr in [8usize, 16] {
        for n in [1usize, 2, 7, 8, 9, 15, 16, 17, 31, 32, 33, 127, 128, 129, 255, 256, 529] {
            for cap in [1usize, 2, 3, 5, 8, 16, 64] {
                let (nchunks, cols_per) = chunk_plan_nr(n, cap, nr);
                let col_panels = n.div_ceil(nr);
                assert!(cols_per % nr == 0, "chunk width must be tile-aligned");
                assert!(nchunks >= 1 && nchunks <= cap.clamp(1, col_panels));
                assert!(
                    (nchunks - 1) * cols_per < n,
                    "last chunk must own at least one column (n={n} cap={cap} nr={nr})"
                );
                let mut owned = vec![0u32; n];
                for w in 0..nchunks {
                    let j0 = w * cols_per;
                    let wcols = cols_per.min(n - j0);
                    for c in &mut owned[j0..j0 + wcols] {
                        *c += 1;
                    }
                }
                assert!(
                    owned.iter().all(|&c| c == 1),
                    "every column owned exactly once (n={n} cap={cap} nr={nr})"
                );
            }
        }
    }
}

#[test]
fn worker_budgets_stay_inside_their_clamps() {
    // both budget policies: >= 1 always, never above the cap, small
    // problems stay sequential, huge problems take the whole budget —
    // and the pooled bar (cheaper dispatch) never picks fewer workers
    // than the scoped bar on the same problem
    for &(m, n, k) in
        &[(1usize, 1usize, 1usize), (8, 8, 8), (64, 64, 64), (512, 512, 512), (1, 529, 257)]
    {
        for cap in [1usize, 2, 4, 8, 64] {
            let t = threads_for(m, n, k, cap);
            let tp = threads_for_pooled(m, n, k, cap);
            assert!(t >= 1 && t <= cap.max(1), "threads_for out of [1, cap]");
            assert!(tp >= 1 && tp <= cap.max(1), "threads_for_pooled out of [1, cap]");
            assert!(tp >= t, "the pooled bar is lower, so its budget can only grow");
        }
    }
    assert_eq!(threads_for(2, 2, 2, 8), 1, "tiny problems must stay sequential");
    assert_eq!(threads_for(512, 512, 512, 8), 8, "big problems take the whole budget");
}

// ------------------------------------- satellite: scratch at grid extremes

#[test]
fn scratch_sizing_holds_at_the_blocking_grid_extremes() {
    // the smallest and largest grid points, at shapes that straddle
    // every cache-block boundary (mc+1, nc+1, kc+1): panel scratch is
    // sized from the variant's own blocking, so the slicing inside the
    // column workers must never overrun — and the bits must still equal
    // the canonical engine's
    let small = BlockCfg { mc: 64, kc: 128, nc: 256 };
    let large = BlockCfg { mc: 128, kc: 256, nc: 512 };
    let mut rng = Rng::new(0x50c7);
    for (block, m, n, k) in [(small, 65, 257, 129), (large, 129, 513, 257), (small, 1, 1, 1)] {
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let cv = GemmVariant::CANONICAL_F32;
        let canon = run_f32(&a, &b, m, n, k, Accum::F64, Epilogue::None, Par::Seq, cv);
        for mr_nr in [(4usize, 8usize), (8, 8), (8, 16)] {
            let v = GemmVariant { mr: mr_nr.0, nr: mr_nr.1, block };
            let got = run_f32(&a, &b, m, n, k, Accum::F64, Epilogue::None, Par::Scoped(3), v);
            assert_eq!(bits(&got), bits(&canon), "f32 {} at {m}x{n}x{k}", v.name());
        }
        // the interleaved engines at the same extremes (smaller m keeps
        // the scalar references cheap)
        let bm = m.min(9);
        let wide_ref = gemm_bf16_reference(&a[..bm * k], &b, bm, n, k);
        let i8_q = QuantParams { a_scale: 0.02, a_zp: -5, b_scale: 0.017, b_zp: 120 };
        let i8_ref =
            gemm_i8_dequant_reference(&a[..bm * k], &b, bm, n, k, &i8_q, None, false);
        for mr_nr in [(8usize, 8usize), (8, 16)] {
            let v = GemmVariant { mr: mr_nr.0, nr: mr_nr.1, block };
            let mut c = vec![0f32; bm * n];
            let mut bs = Bf16Scratch::new();
            gemm_bf16_tuned_into(
                &mut c,
                Bf16Src::F32(&a[..bm * k]),
                Bf16Src::F32(&b),
                bm,
                n,
                k,
                Bf16Accum::Widened,
                Epilogue::None,
                Par::Scoped(3),
                &mut bs,
                v,
            );
            assert_eq!(bits(&c), bits(&wide_ref), "bf16 {} at {bm}x{n}x{k}", v.name());
            let mut ci = vec![0f32; bm * n];
            let mut is = I8Scratch::new();
            gemm_i8_dequant_tuned_into(
                &mut ci,
                &a[..bm * k],
                &b,
                bm,
                n,
                k,
                &i8_q,
                I8Epilogue::None,
                Par::Scoped(3),
                &mut is,
                v,
            );
            assert_eq!(bits(&ci), bits(&i8_ref), "i8 {} at {bm}x{n}x{k}", v.name());
        }
    }
}

// --------------------------------------- the table through compiled plans

#[test]
fn preseeded_rows_bake_into_plan_steps_without_remeasuring() {
    use power_mma::runtime::hlo::HloModule;
    use power_mma::runtime::plan::{Plan, PlanOptions};
    let module = HloModule::parse(&power_mma::runtime::mlp_hlo_text(1, 24, 40, 12)).unwrap();

    // tune: None compiles the deterministic heuristic — exactly the
    // canonical pre-autotuner engine for every class
    let untuned = Plan::compile_with_options(&module, PlanOptions::default()).unwrap();
    let classes = untuned.gemm_variants();
    assert!(classes.len() >= 2, "the MLP must compile at least two GEMM classes");
    for (key, v) in &classes {
        assert_eq!(v.name(), heuristic_variant(key.dtype).name(), "tune:None must be canonical");
    }

    // pre-seed every class with a forced non-canonical variant: the
    // compile must bake it verbatim, without a single measurement
    let forced = GemmVariant { mr: 4, nr: 8, block: BlockCfg { mc: 64, kc: 128, nc: 256 } };
    assert_ne!(forced.name(), GemmVariant::CANONICAL_F32.name());
    let table = std::sync::Arc::new(TuneTable::new());
    for (key, _) in &classes {
        let choice =
            TuneChoice { variant: forced, chosen_ms: 0.0, default_ms: 0.0, measured: false };
        table.insert(*key, choice);
    }
    let opts = PlanOptions { tune: Some(table.clone()), ..Default::default() };
    let tuned = Plan::compile_with_options(&module, opts).unwrap();
    for (key, v) in tuned.gemm_variants() {
        assert_eq!(v.name(), forced.name(), "class {key:?} must carry the pre-seeded variant");
    }
    assert_eq!(table.measure_count(), 0, "pre-seeded rows must never re-measure");
}

#[test]
fn first_sight_measures_once_and_recompiles_reuse_the_row() {
    use power_mma::runtime::hlo::HloModule;
    use power_mma::runtime::plan::{Plan, PlanOptions};
    let module = HloModule::parse(&power_mma::runtime::mlp_hlo_text(2, 24, 40, 12)).unwrap();
    let table = std::sync::Arc::new(TuneTable::new());
    let opts = || PlanOptions { tune: Some(table.clone()), ..Default::default() };
    let first = Plan::compile_with_options(&module, opts()).unwrap();
    let classes = first.gemm_variants();
    let measured_after_first = table.measure_count();
    assert!(!table.is_empty(), "the compile must populate the table");
    assert!(measured_after_first >= 1, "these classes sit under the flop cap: they measure");
    for (key, v) in &classes {
        let row = table.lookup(*key).expect("every compiled class is memoized");
        assert_eq!(row.variant.name(), v.name(), "the step carries the table's choice");
        assert!(row.measured && row.chosen_ms <= row.default_ms, "canonical-first argmin");
    }
    // an identical re-compile must hit the memo, not the stopwatch
    let second = Plan::compile_with_options(&module, opts()).unwrap();
    assert_eq!(table.measure_count(), measured_after_first, "re-compiles must not re-measure");
    let names = |cs: &[(TuneKey, GemmVariant)]| -> Vec<String> {
        cs.iter().map(|(_, v)| v.name()).collect()
    };
    assert_eq!(names(&classes), names(&second.gemm_variants()), "deterministic re-compile");
}

#[test]
fn forced_variants_serve_bitwise_identical_results_end_to_end() {
    // through the public runtime API: a backend tuned with forced
    // non-canonical variants for every class must serve byte-for-byte
    // the same responses as the untuned backend — for the f32 MLP and
    // the calibrated int8 MLP both
    use power_mma::runtime::{det_input, HloPlanBackend, Runtime};
    let dir = std::env::temp_dir(); // nothing is read: buckets compile from generated text
    let (b, f, h, c) = (3usize, 24usize, 40usize, 12usize);
    let x = det_input(b * f, 1);
    let w1 = det_input(f * h, 2);
    let b1 = det_input(h, 3);
    let w2 = det_input(h * c, 4);
    let b2 = det_input(c, 5);
    let args: [&[f32]; 5] = [&x, &w1, &b1, &w2, &b2];
    let name = format!("mlp_b{b}");

    let forced_f32 = GemmVariant { mr: 4, nr: 8, block: BlockCfg { mc: 64, kc: 128, nc: 512 } };
    let forced_wide = GemmVariant { mr: 8, nr: 8, block: BlockCfg { mc: 128, kc: 128, nc: 256 } };
    let seed = |dtype: TuneDtype| {
        let table = std::sync::Arc::new(TuneTable::new());
        let forced = if dtype == TuneDtype::F32 { forced_f32 } else { forced_wide };
        let classes =
            [(b, h, f, TuneEpi::BiasRelu), (b, c, h, TuneEpi::Bias), (b, c, h, TuneEpi::None)];
        for (m, n, k, epi) in classes {
            let key = TuneKey { m, n, k, dtype, epi, panel: TunePanel::Matrix };
            let choice =
                TuneChoice { variant: forced, chosen_ms: 0.0, default_ms: 0.0, measured: false };
            table.insert(key, choice);
        }
        table
    };

    let mut rt_plain = Runtime::with_backend(Box::new(HloPlanBackend::new()), &dir);
    rt_plain.load_mlp_buckets(&[b], f, h, c).unwrap();
    let want = rt_plain.execute(&name, &args).unwrap();
    let tuned_backend = HloPlanBackend::new().with_tuning(seed(TuneDtype::F32));
    let mut rt_tuned = Runtime::with_backend(Box::new(tuned_backend), &dir);
    rt_tuned.load_mlp_buckets(&[b], f, h, c).unwrap();
    let got = rt_tuned.execute(&name, &args).unwrap();
    assert_eq!(bits(&got), bits(&want), "forced f32 variants changed served bits");

    let mut rt_i8_plain = Runtime::with_backend(Box::new(HloPlanBackend::int8()), &dir);
    rt_i8_plain.load_mlp_buckets_int8(&[b], f, h, c).unwrap();
    let want_i8 = rt_i8_plain.execute(&name, &args).unwrap();
    let tuned_i8 = HloPlanBackend::int8().with_tuning(seed(TuneDtype::I8));
    let mut rt_i8_tuned = Runtime::with_backend(Box::new(tuned_i8), &dir);
    rt_i8_tuned.load_mlp_buckets_int8(&[b], f, h, c).unwrap();
    let got_i8 = rt_i8_tuned.execute(&name, &args).unwrap();
    assert_eq!(bits(&got_i8), bits(&want_i8), "forced i8 variants changed served bits");
}

#[test]
fn tune_cache_roundtrips_measured_rows() {
    use power_mma::runtime::tune::TUNE_CACHE_HEADER;
    let path = std::env::temp_dir().join(format!("mma-tunecache-rt-{}.txt", std::process::id()));
    let table = TuneTable::new();
    let key_a = TuneKey {
        m: 32,
        n: 40,
        k: 24,
        dtype: TuneDtype::F32,
        epi: TuneEpi::BiasRelu,
        panel: TunePanel::Matrix,
    };
    let v_a = GemmVariant { mr: 4, nr: 8, block: BlockCfg { mc: 64, kc: 128, nc: 512 } };
    table.insert(
        key_a,
        TuneChoice { variant: v_a, chosen_ms: 0.125, default_ms: 0.5, measured: true },
    );
    let key_b = TuneKey {
        m: 32,
        n: 16,
        k: 16,
        dtype: TuneDtype::F32,
        epi: TuneEpi::None,
        panel: TunePanel::DftPacked,
    };
    let v_b = GemmVariant { mr: 8, nr: 8, block: BlockCfg { mc: 128, kc: 256, nc: 256 } };
    table.insert(
        key_b,
        TuneChoice { variant: v_b, chosen_ms: 0.25, default_ms: 0.25, measured: true },
    );
    // pre-seeded (unmeasured) rows must not persist: they carry no timing
    let key_seed = TuneKey { m: 1, n: 1, k: 1, ..key_a };
    table.insert(
        key_seed,
        TuneChoice { variant: v_a, chosen_ms: 0.0, default_ms: 0.0, measured: false },
    );
    assert_eq!(table.save(&path).unwrap(), 2, "only the measured rows persist");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with(TUNE_CACHE_HEADER), "versioned header first: {text:?}");

    let fresh = TuneTable::new();
    assert_eq!(fresh.load_into(&path).unwrap(), 2);
    for (key, want) in [(key_a, v_a), (key_b, v_b)] {
        let row = fresh.lookup(key).expect("persisted row restored");
        assert_eq!(row.variant, want);
        assert!(row.measured, "restored rows count as measured (no stopwatch on reuse)");
    }
    assert!(fresh.lookup(key_seed).is_none(), "unmeasured seed must not roundtrip");
    // a restored table resolves the class without measuring
    assert_eq!(fresh.choose(key_a).variant, v_a);
    assert_eq!(fresh.measure_count(), 0, "cache hits never re-measure");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tune_cache_rejects_corruption_and_version_drift() {
    use power_mma::runtime::tune::TUNE_CACHE_HEADER;
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let cases: [(&str, String); 4] = [
        ("missing-header", "32 40 24 f32 bias_relu matrix 4 8 64 128 512 0.1 0.2\n".into()),
        ("version-drift", "power-mma-tune-table v0\n".into()),
        (
            "short-row",
            format!("{TUNE_CACHE_HEADER}\n32 40 24 f32 bias_relu matrix 4 8 64\n"),
        ),
        (
            "bad-blocking",
            // mc=65 is not a multiple of mr=4: inconsistent variant
            format!("{TUNE_CACHE_HEADER}\n32 40 24 f32 bias_relu matrix 4 8 65 128 512 0.1 0.2\n"),
        ),
    ];
    for (name, text) in cases {
        let path = dir.join(format!("mma-tunecache-{name}-{pid}.txt"));
        std::fs::write(&path, text).unwrap();
        let table = TuneTable::new();
        let err = table.load_into(&path).expect_err(name);
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{name}");
        assert!(table.is_empty(), "{name}: a failed load must leave the table untouched");
        let _ = std::fs::remove_file(&path);
    }
    // a missing file is an io error too (the serve path treats any Err
    // as "no cache" and falls back to measuring)
    let table = TuneTable::new();
    assert!(table.load_into(&dir.join(format!("mma-tunecache-absent-{pid}.txt"))).is_err());
    assert!(table.is_empty());
}
