//! Integration tests over the real AOT artifacts: rust loads the HLO text
//! produced by `python/compile/aot.py`, compiles it on the default native
//! plan backend, executes with the shared deterministic inputs, and
//! checks the numbers against the python-side expected outputs — the
//! proof that L2 (JAX serving graphs) → AOT → L3 (rust) compose.
//!
//! The artifact set ships embedded in the crate (`runtime::artifacts`),
//! so these tests always run — no python, no network, no `make artifacts`.

use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload};
use power_mma::runtime::{artifacts, det_input, det_inputs, Runtime};

/// Materialize the embedded artifact set once per test process.
fn artifact_dir() -> std::path::PathBuf {
    static DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("power-mma-integration-artifacts-{}", std::process::id()));
        artifacts::write_artifacts(&dir).expect("materialize embedded artifacts");
        dir
    })
    .clone()
}

fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol + rtol * y.abs(),
            "element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn artifacts_match_python_expectations() {
    let dir = artifact_dir();
    let mut rt = Runtime::cpu(&dir).unwrap();
    let names = rt.load_all().unwrap();
    assert!(names.len() >= 4, "expected gemm_f32/gemm_bf16/conv2d_k3/mlp artifacts");
    for name in &names {
        let meta = rt.meta(name).unwrap().clone();
        let inputs = det_inputs(&meta);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute(name, &refs).unwrap();
        let expect = rt.expected(name).unwrap();
        // same graph on both sides (f64 vs f32 dot accumulation) -> tight tolerance
        allclose(&out, &expect, 1e-5, 1e-5);
        println!("{name}: {} outputs match python", out.len());
    }
}

#[test]
fn gemm_artifact_is_a_real_matmul() {
    let dir = artifact_dir();
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load("gemm_f32").unwrap();
    let meta = rt.meta("gemm_f32").unwrap().clone();
    let n = meta.input_shapes[0][0];
    // x = diag(2), y = pattern -> out = 2*y
    let mut x = vec![0f32; n * n];
    for i in 0..n {
        x[i * n + i] = 2.0;
    }
    let y = det_input(n * n, 9);
    let out = rt.execute("gemm_f32", &[&x, &y]).unwrap();
    let expect: Vec<f32> = y.iter().map(|v| 2.0 * v).collect();
    allclose(&out, &expect, 1e-6, 1e-6);
}

#[test]
fn runtime_validates_inputs() {
    let dir = artifact_dir();
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load("gemm_f32").unwrap();
    let short = vec![0f32; 7];
    assert!(rt.execute("gemm_f32", &[&short, &short]).is_err());
    assert!(rt.execute("nonexistent", &[]).is_err());
}

#[test]
fn coordinator_serves_real_models_end_to_end() {
    let dir = artifact_dir();
    let cfg = CoordinatorConfig { max_delay: std::time::Duration::from_millis(5), ..Default::default() };
    let weights = MlpWeights::deterministic(&cfg);
    let dir2 = dir.clone();
    let coord = Coordinator::start(cfg.clone(), weights, move |_shard| {
        let mut rt = Runtime::cpu(&dir2)?;
        rt.load_all()?;
        Ok(rt)
    });

    // 1) classification requests with the deterministic features must give
    // the python-computed logits (the aot expected fixture for mlp_b32)
    let mlp_name = cfg.mlp_model();
    let rt_check = Runtime::cpu(&dir).unwrap();
    let expect = rt_check.expected(&mlp_name).unwrap();
    let features_all = det_input(cfg.max_bucket() * cfg.features, 1);
    let mut rxs = Vec::new();
    for r in 0..cfg.max_bucket() {
        let f = features_all[r * cfg.features..(r + 1) * cfg.features].to_vec();
        rxs.push((r, coord.submit(Payload::Classify { features: f }).1));
    }
    for (r, rx) in rxs {
        let resp = rx.recv().unwrap();
        let row = resp.result.unwrap();
        allclose(&row, &expect[r * cfg.classes..(r + 1) * cfg.classes], 1e-5, 1e-5);
    }

    // 2) a GEMM request
    let g = 128;
    let (_, rx) = coord.submit(Payload::Gemm {
        model: "gemm_f32".into(),
        x: det_input(g * g, 1),
        y: det_input(g * g, 2),
    });
    let gemm_expect = rt_check.expected("gemm_f32").unwrap();
    allclose(&rx.recv().unwrap().result.unwrap(), &gemm_expect, 1e-5, 1e-5);

    // 3) a conv request
    let (_, rx) = coord.submit(Payload::Conv {
        filters: det_input(8 * 27, 1),
        image: det_input(3 * 18 * 130, 2),
    });
    let conv_expect = rt_check.expected("conv2d_k3").unwrap();
    allclose(&rx.recv().unwrap().result.unwrap(), &conv_expect, 1e-4, 1e-5);

    let stats = coord.shutdown();
    assert_eq!(stats.failed.get(), 0);
    assert!(stats.completed.get() >= cfg.max_bucket() as u64 + 2);
}

#[test]
fn sharded_coordinator_matches_single_shard_bitwise() {
    // the same classify request served by a 1-shard and a 2-shard
    // coordinator (real plan backend, shared device pool) must produce
    // bitwise-identical logits: each output row depends only on its own
    // features, never on shard assignment or batch-mates
    let dir = artifact_dir();
    let features = det_input(64, 3);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for shards in [1usize, 2] {
        let cfg = CoordinatorConfig {
            max_delay: std::time::Duration::from_millis(2),
            shards,
            ..Default::default()
        };
        let weights = MlpWeights::deterministic(&cfg);
        let dir2 = dir.clone();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            let mut rt = Runtime::cpu(&dir2)?;
            rt.load_all()?;
            Ok(rt)
        });
        // a few extra requests so both shards actually serve traffic
        let mut rxs = Vec::new();
        for _ in 0..4 {
            rxs.push(coord.submit(Payload::Classify { features: features.clone() }).1);
        }
        let mut got: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().result.unwrap())
            .collect();
        // all responses to the same features must agree with each other
        for r in &got[1..] {
            assert_eq!(r, &got[0], "shards={shards}: same request, different answer");
        }
        rows.push(got.remove(0));
        coord.shutdown();
    }
    let (one, two) = (&rows[0], &rows[1]);
    assert_eq!(one.len(), two.len());
    for (i, (x, y)) in one.iter().zip(two).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "logit {i} differs between shards=1 and shards=2 ({x} vs {y})"
        );
    }
}

#[test]
fn failure_injection_corrupt_artifacts() {
    // a runtime over a directory with malformed artifacts must fail
    // loudly at load time, not at serve time
    let tmp = std::env::temp_dir().join(format!("mma-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    // case 1: meta exists, HLO text is garbage
    std::fs::write(tmp.join("broken.meta"), "broken;4x4;4x4\n").unwrap();
    std::fs::write(tmp.join("broken.hlo.txt"), "this is not HLO").unwrap();
    let mut rt = Runtime::cpu(&tmp).unwrap();
    assert!(rt.load("broken").is_err(), "garbage HLO must not load");
    // case 2: malformed meta line
    std::fs::write(tmp.join("badmeta.meta"), "badmeta;;;;\n").unwrap();
    std::fs::write(tmp.join("badmeta.hlo.txt"), "x").unwrap();
    assert!(rt.load("badmeta").is_err());
    // case 3: missing files
    assert!(rt.load("absent").is_err());
    // case 4: manifest referencing a missing artifact
    std::fs::write(tmp.join("manifest.txt"), "ghost;1x1;1x1\n").unwrap();
    assert!(rt.load_all().is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn coordinator_survives_engine_init_failure_with_real_runtime() {
    // pointing the real Runtime at an empty dir: every request must get an
    // error response (not a hang)
    let tmp = std::env::temp_dir().join(format!("mma-empty-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let cfg = CoordinatorConfig::default();
    let weights = MlpWeights::deterministic(&cfg);
    let tmp2 = tmp.clone();
    let coord = Coordinator::start(cfg.clone(), weights, move |_shard| {
        let mut rt = Runtime::cpu(&tmp2)?;
        rt.load_all()?; // fails: no manifest
        Ok(rt)
    });
    let (_, rx) = coord.submit(Payload::Classify { features: vec![0.0; cfg.features] });
    let resp = rx.recv().unwrap();
    assert!(resp.result.is_err());
    coord.shutdown();
    std::fs::remove_dir_all(&tmp).ok();
}
