//! Plan-compiler coverage over the real embedded artifacts: compiled
//! [`Plan`] execution must be **bit-identical** to the legacy interpreter
//! walk on every fixture (the acceptance bar for the compiled serving
//! path), the buffer arena must never alias two live values, and buffer
//! reuse across requests must be stateless.

use power_mma::runtime::hlo::HloModule;
use power_mma::runtime::plan::Plan;
use power_mma::runtime::{artifacts, det_inputs, ModelMeta};
use power_mma::testkit::Rng;

fn fixture_plans() -> Vec<(&'static str, HloModule, Plan, ModelMeta)> {
    artifacts::EMBEDDED
        .iter()
        .map(|a| {
            let module = HloModule::parse(a.hlo_text).expect(a.name);
            let plan = Plan::compile(&module).expect(a.name);
            let meta = ModelMeta::parse(a.meta).expect(a.name);
            (a.name, module, plan, meta)
        })
        .collect()
}

fn assert_bitwise_eq(name: &str, what: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{name}: {what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{name}: {what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// Property: on every embedded fixture, for deterministic and randomized
/// inputs and any thread count, plan execution equals the interpreter
/// walk bit for bit.
#[test]
fn plan_matches_interpreter_on_every_fixture() {
    let mut rng = Rng::new(0x9a7);
    for (name, module, plan, meta) in fixture_plans() {
        let mut bufs = plan.new_buffers();
        for round in 0..4 {
            let inputs: Vec<Vec<f32>> = if round == 0 {
                det_inputs(&meta)
            } else {
                meta.input_shapes
                    .iter()
                    .map(|s| rng.f32_vec(s.iter().product()))
                    .collect()
            };
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let want = module.evaluate(&refs).unwrap();
            for threads in [1usize, 4] {
                let got = plan.execute_into(&mut bufs, &refs, threads).unwrap();
                assert_eq!(got.len(), want.len(), "{name}: output arity");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.dims, w.dims, "{name}: output dims");
                    let what = format!("round {round} threads {threads}");
                    assert_bitwise_eq(name, &what, &g.data, &w.data);
                }
            }
        }
    }
}

/// The compiled plan must still match the python-side ground truth.
#[test]
fn plan_matches_python_expected_outputs() {
    for (name, _, plan, meta) in fixture_plans() {
        let art = artifacts::EMBEDDED.iter().find(|a| a.name == name).unwrap();
        let expect: Vec<f32> = art
            .expected
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let inputs = det_inputs(&meta);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = &plan.execute(&refs, 2).unwrap()[0];
        assert_eq!(out.data.len(), expect.len(), "{name}");
        for (i, (&x, &y)) in out.data.iter().zip(&expect).enumerate() {
            assert!(
                (x - y).abs() <= 1e-5 + 1e-5 * y.abs(),
                "{name}: element {i}: {x} vs {y}"
            );
        }
    }
}

/// Allocator invariant: two values assigned the same arena slot have
/// disjoint live ranges — the earlier value's last use strictly precedes
/// the later value's definition — and every slot is big enough for every
/// value it hosts.
#[test]
fn arena_never_aliases_two_live_values() {
    for (name, module, plan, _) in fixture_plans() {
        let assigns = plan.assignments();
        assert!(!assigns.is_empty(), "{name}: no assignments");
        for (ai, a) in assigns.iter().enumerate() {
            for b in &assigns[ai + 1..] {
                if a.slot != b.slot {
                    continue;
                }
                let (first, second) = if a.def <= b.def { (a, b) } else { (b, a) };
                assert!(
                    first.last_use < second.def,
                    "{name}: slot {} hosts '{}' (live {}..{}) and '{}' (live {}..{}) concurrently",
                    a.slot,
                    first.name,
                    first.def,
                    first.last_use,
                    second.name,
                    second.def,
                    second.last_use
                );
            }
        }
        // capacity covers every hosted value; the arena is genuinely
        // smaller than one-slot-per-instruction on the big graphs
        for a in assigns {
            assert!(
                plan.slot_caps()[a.slot] >= a.elems,
                "{name}: slot {} cap {} < value '{}' ({} elems)",
                a.slot,
                plan.slot_caps()[a.slot],
                a.name,
                a.elems
            );
        }
        assert!(plan.num_slots() <= module.num_instructions(), "{name}");
        if module.num_instructions() > 50 {
            assert!(
                plan.num_slots() * 4 < module.num_instructions(),
                "{name}: {} slots for {} instructions — liveness reuse broken?",
                plan.num_slots(),
                module.num_instructions()
            );
        }
    }
}

/// Executing through the same buffers must be stateless: interleaving
/// other requests never changes a request's answer, and results equal a
/// fresh-buffer run bit for bit.
#[test]
fn buffer_reuse_is_stateless_across_requests() {
    let mut rng = Rng::new(0xeb5);
    for (name, _, plan, meta) in fixture_plans() {
        let mk = |rng: &mut Rng| -> Vec<Vec<f32>> {
            meta.input_shapes.iter().map(|s| rng.f32_vec(s.iter().product())).collect()
        };
        let in1 = mk(&mut rng);
        let in2 = mk(&mut rng);
        let refs1: Vec<&[f32]> = in1.iter().map(|v| v.as_slice()).collect();
        let refs2: Vec<&[f32]> = in2.iter().map(|v| v.as_slice()).collect();
        let fresh1 = plan.execute(&refs1, 1).unwrap();
        let mut bufs = plan.new_buffers();
        let first = plan.execute_into(&mut bufs, &refs1, 1).unwrap();
        let _other = plan.execute_into(&mut bufs, &refs2, 1).unwrap();
        let again = plan.execute_into(&mut bufs, &refs1, 1).unwrap();
        for ((f, a), fr) in first.iter().zip(&again).zip(&fresh1) {
            assert_bitwise_eq(name, "reused-vs-reused", &a.data, &f.data);
            assert_bitwise_eq(name, "reused-vs-fresh", &f.data, &fr.data);
        }
    }
}

/// Shape validation stays as strict as the interpreter's: wrong input
/// count and wrong input length are rejected.
#[test]
fn plan_validates_request_inputs() {
    let (_, _, plan, meta) = fixture_plans().remove(0);
    assert!(plan.execute(&[], 1).is_err(), "missing inputs");
    let bad = vec![0f32; meta.input_len(0) + 1];
    let good = vec![0f32; meta.input_len(1)];
    assert!(plan.execute(&[&bad, &good], 1).is_err(), "wrong length");
}
