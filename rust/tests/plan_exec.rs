//! Plan-compiler coverage over the real embedded artifacts: compiled
//! [`Plan`] execution must be **bit-identical** to the legacy interpreter
//! walk on every fixture (the acceptance bar for the compiled serving
//! path), the buffer arena must never alias two live values, and buffer
//! reuse across requests must be stateless.

use power_mma::runtime::hlo::HloModule;
use power_mma::runtime::plan::Plan;
use power_mma::runtime::{artifacts, det_inputs, ModelMeta};
use power_mma::testkit::Rng;

fn fixture_plans() -> Vec<(&'static str, HloModule, Plan, ModelMeta)> {
    artifacts::EMBEDDED
        .iter()
        .map(|a| {
            let module = HloModule::parse(a.hlo_text).expect(a.name);
            let plan = Plan::compile(&module).expect(a.name);
            let meta = ModelMeta::parse(a.meta).expect(a.name);
            (a.name, module, plan, meta)
        })
        .collect()
}

fn assert_bitwise_eq(name: &str, what: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{name}: {what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{name}: {what}: element {i} differs ({x} vs {y})"
        );
    }
}

/// Property: on every embedded fixture, for deterministic and randomized
/// inputs and any thread count, plan execution equals the interpreter
/// walk bit for bit.
#[test]
fn plan_matches_interpreter_on_every_fixture() {
    let mut rng = Rng::new(0x9a7);
    for (name, module, plan, meta) in fixture_plans() {
        let mut bufs = plan.new_buffers();
        for round in 0..4 {
            let inputs: Vec<Vec<f32>> = if round == 0 {
                det_inputs(&meta)
            } else {
                meta.input_shapes
                    .iter()
                    .map(|s| rng.f32_vec(s.iter().product()))
                    .collect()
            };
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let want = module.evaluate(&refs).unwrap();
            for threads in [1usize, 4] {
                let got = plan.execute_into(&mut bufs, &refs, threads).unwrap();
                assert_eq!(got.len(), want.len(), "{name}: output arity");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.dims, w.dims, "{name}: output dims");
                    let what = format!("round {round} threads {threads}");
                    assert_bitwise_eq(name, &what, &g.data, &w.data);
                }
            }
        }
    }
}

/// The compiled plan must still match the python-side ground truth.
#[test]
fn plan_matches_python_expected_outputs() {
    for (name, _, plan, meta) in fixture_plans() {
        let art = artifacts::EMBEDDED.iter().find(|a| a.name == name).unwrap();
        let expect: Vec<f32> = art
            .expected
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let inputs = det_inputs(&meta);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = &plan.execute(&refs, 2).unwrap()[0];
        assert_eq!(out.data.len(), expect.len(), "{name}");
        for (i, (&x, &y)) in out.data.iter().zip(&expect).enumerate() {
            assert!(
                (x - y).abs() <= 1e-5 + 1e-5 * y.abs(),
                "{name}: element {i}: {x} vs {y}"
            );
        }
    }
}

/// Allocator invariant: two values assigned the same arena slot have
/// disjoint live ranges — the earlier value's last use strictly precedes
/// the later value's definition — and every slot is big enough for every
/// value it hosts.
#[test]
fn arena_never_aliases_two_live_values() {
    for (name, module, plan, _) in fixture_plans() {
        let assigns = plan.assignments();
        assert!(!assigns.is_empty(), "{name}: no assignments");
        for (ai, a) in assigns.iter().enumerate() {
            for b in &assigns[ai + 1..] {
                if a.slot != b.slot {
                    continue;
                }
                let (first, second) = if a.def <= b.def { (a, b) } else { (b, a) };
                assert!(
                    first.last_use < second.def,
                    "{name}: slot {} hosts '{}' (live {}..{}) and '{}' (live {}..{}) concurrently",
                    a.slot,
                    first.name,
                    first.def,
                    first.last_use,
                    second.name,
                    second.def,
                    second.last_use
                );
            }
        }
        // capacity covers every hosted value; the arena is genuinely
        // smaller than one-slot-per-instruction on the big graphs
        for a in assigns {
            assert!(
                plan.slot_caps()[a.slot] >= a.elems,
                "{name}: slot {} cap {} < value '{}' ({} elems)",
                a.slot,
                plan.slot_caps()[a.slot],
                a.name,
                a.elems
            );
        }
        // pinned (constant) slots are dedicated and immortal: live to the
        // end and never shared with another value — the compile-time
        // recycler also asserts they never reach the free list
        for a in assigns {
            if !a.pinned {
                continue;
            }
            assert_eq!(a.last_use, usize::MAX, "{name}: pinned '{}' must stay live", a.name);
            for b in assigns {
                assert!(
                    a.instr == b.instr || a.slot != b.slot,
                    "{name}: pinned slot {} shared by '{}' and '{}'",
                    a.slot,
                    a.name,
                    b.name
                );
            }
        }
        assert!(plan.num_slots() <= module.num_instructions(), "{name}");
        if module.num_instructions() > 50 {
            assert!(
                plan.num_slots() * 4 < module.num_instructions(),
                "{name}: {} slots for {} instructions — liveness reuse broken?",
                plan.num_slots(),
                module.num_instructions()
            );
        }
    }
}

/// Executing through the same buffers must be stateless: interleaving
/// other requests never changes a request's answer, and results equal a
/// fresh-buffer run bit for bit.
#[test]
fn buffer_reuse_is_stateless_across_requests() {
    let mut rng = Rng::new(0xeb5);
    for (name, _, plan, meta) in fixture_plans() {
        let mk = |rng: &mut Rng| -> Vec<Vec<f32>> {
            meta.input_shapes.iter().map(|s| rng.f32_vec(s.iter().product())).collect()
        };
        let in1 = mk(&mut rng);
        let in2 = mk(&mut rng);
        let refs1: Vec<&[f32]> = in1.iter().map(|v| v.as_slice()).collect();
        let refs2: Vec<&[f32]> = in2.iter().map(|v| v.as_slice()).collect();
        let fresh1 = plan.execute(&refs1, 1).unwrap();
        let mut bufs = plan.new_buffers();
        let first = plan.execute_into(&mut bufs, &refs1, 1).unwrap();
        let _other = plan.execute_into(&mut bufs, &refs2, 1).unwrap();
        let again = plan.execute_into(&mut bufs, &refs1, 1).unwrap();
        for ((f, a), fr) in first.iter().zip(&again).zip(&fresh1) {
            assert_bitwise_eq(name, "reused-vs-reused", &a.data, &f.data);
            assert_bitwise_eq(name, "reused-vs-fresh", &f.data, &fr.data);
        }
    }
}

/// The rewrite-pass acceptance bar: the 299-instruction conv fixture
/// must compile to a single fused im2col GEMM (plus the parameter
/// copies), and the MLP must fuse both post-dot tails into epilogues.
#[test]
fn conv_fixture_compiles_to_a_single_im2col_gemm() {
    for (name, _, plan, _) in fixture_plans() {
        let names = plan.step_names();
        match name {
            "conv2d_k3" => {
                assert_eq!(
                    names,
                    ["param", "param", "im2col_gemm"],
                    "conv must collapse to one fused GEMM"
                );
                assert!(plan.num_steps() <= 10, "{} steps", plan.num_steps());
            }
            "mlp_b32" => {
                let fused: Vec<&str> = names
                    .iter()
                    .copied()
                    .filter(|s| s.starts_with("dot"))
                    .collect();
                assert_eq!(
                    fused,
                    ["dot_bias_relu", "dot_bias"],
                    "both MLP layers must fuse their epilogues: {names:?}"
                );
                assert!(
                    names.iter().all(|&s| s != "binary" && s != "gather"),
                    "no post-dot sweeps may remain: {names:?}"
                );
            }
            "gemm_bf16" => {
                // the bf16 serving graph collapses to one packed-panel
                // GEMM: both convert round-trips fuse into the packers
                assert_eq!(
                    names,
                    ["param", "param", "dot_bf16"],
                    "bf16 converts must fold into the packed GEMM"
                );
                assert!(plan.param_packs_bf16(0) && plan.param_packs_bf16(1));
            }
            // the pure f32 GEMM graph has nothing to fuse
            _ => assert!(
                names.iter().all(|&s| matches!(s, "param" | "dot")),
                "{name}: {names:?}"
            ),
        }
    }
}

/// Generate the HLO text of a `k3` convolution the way
/// `python/compile/aot.py` lowers it (9·Cin shifted multiply-add taps),
/// for boundary-shape coverage beyond the committed fixture.
fn gen_conv_hlo(cout: usize, cin: usize, h: usize, w: usize) -> String {
    let (ih, iw) = (h + 2, w + 2);
    let kk = 9 * cin;
    let od = format!("f32[{cout},{h},{w}]{{2,1,0}}");
    let mut s = String::from("HloModule jit_conv_gen\n\nENTRY main {\n");
    s.push_str(&format!("  Arg_0.1 = f32[{cout},{kk}]{{1,0}} parameter(0)\n"));
    s.push_str(&format!("  Arg_1.2 = f32[{cin},{ih},{iw}]{{2,1,0}} parameter(1)\n"));
    let mut prev: Option<String> = None;
    let mut first_mul = String::new();
    let mut id = 3usize;
    for c in 0..cin {
        for dy in 0..3 {
            for dx in 0..3 {
                let t = c * 9 + dy * 3 + dx;
                s.push_str(&format!(
                    "  s{id} = f32[{cout},1]{{1,0}} slice(Arg_0.1), slice={{[0:{cout}], [{t}:{}]}}\n",
                    t + 1
                ));
                s.push_str(&format!("  r{id} = f32[{cout}]{{0}} reshape(s{id})\n"));
                s.push_str(&format!("  bw{id} = {od} broadcast(r{id}), dimensions={{0}}\n"));
                s.push_str(&format!(
                    "  si{id} = f32[1,{h},{w}]{{2,1,0}} slice(Arg_1.2), \
                     slice={{[{c}:{}], [{dy}:{}], [{dx}:{}]}}\n",
                    c + 1,
                    dy + h,
                    dx + w
                ));
                s.push_str(&format!("  ri{id} = f32[{h},{w}]{{1,0}} reshape(si{id})\n"));
                s.push_str(&format!("  bi{id} = {od} broadcast(ri{id}), dimensions={{1,2}}\n"));
                s.push_str(&format!("  m{id} = {od} multiply(bw{id}, bi{id})\n"));
                if t == 0 {
                    first_mul = format!("m{id}");
                } else {
                    let lhs = if t == 1 {
                        first_mul.clone()
                    } else {
                        prev.clone().expect("chain in progress")
                    };
                    s.push_str(&format!("  a{id} = {od} add({lhs}, m{id})\n"));
                    prev = Some(format!("a{id}"));
                }
                id += 1;
            }
        }
    }
    s.push_str(&format!(
        "  ROOT tup = ({od}) tuple({})\n}}\n",
        prev.expect("at least two taps")
    ));
    s
}

/// Boundary shapes for the im2col gather: 1×1 spatial output, Cin=1,
/// Cout and H·W far off the 8-wide microkernel tiles. Every shape must
/// fuse to a single im2col GEMM and stay bit-identical to the
/// interpreter.
#[test]
fn conv_boundary_shapes_fuse_and_match_interpreter_bitwise() {
    let mut rng = Rng::new(0x51de);
    for &(cout, cin, h, w) in
        &[(8usize, 1usize, 1usize, 1usize), (5, 1, 3, 5), (3, 2, 4, 7), (16, 2, 2, 9), (1, 1, 1, 2)]
    {
        let text = gen_conv_hlo(cout, cin, h, w);
        let module = HloModule::parse(&text).expect("generated conv parses");
        let plan = Plan::compile(&module).expect("generated conv compiles");
        assert_eq!(
            plan.step_names(),
            ["param", "param", "im2col_gemm"],
            "cout={cout} cin={cin} {h}x{w}"
        );
        for round in 0..3usize {
            let wts = rng.f32_vec(cout * 9 * cin);
            let img = rng.f32_vec(cin * (h + 2) * (w + 2));
            let want = module.evaluate(&[&wts, &img]).unwrap();
            let got = plan.execute(&[&wts, &img], 1 + round % 2).unwrap();
            assert_eq!(got[0].dims, vec![cout, h, w]);
            assert_bitwise_eq(
                "conv_boundary",
                &format!("cout={cout} cin={cin} {h}x{w} round {round}"),
                &got[0].data,
                &want[0].data,
            );
        }
    }
}

/// Every `Epilogue` variant against the interpreter: a dot with no
/// tail, a bias tail, and a bias+relu tail, at shapes straddling the
/// microkernel tiles, must all be bitwise identical to the unfused
/// instruction-by-instruction walk.
#[test]
fn epilogue_variants_match_interpreter_bitwise() {
    fn gen_dot_hlo(m: usize, n: usize, k: usize, tail: &str) -> String {
        let mut s = String::from("HloModule jit_dot_epi\n\nENTRY main {\n");
        s.push_str(&format!("  x = f32[{m},{k}]{{1,0}} parameter(0)\n"));
        s.push_str(&format!("  w = f32[{k},{n}]{{1,0}} parameter(1)\n"));
        s.push_str(&format!("  bias = f32[{n}]{{0}} parameter(2)\n"));
        s.push_str(&format!(
            "  dot.1 = f32[{m},{n}]{{1,0}} dot(x, w), \
             lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n"
        ));
        let root = match tail {
            "none" => {
                // bias still consumed so the parameter count is uniform
                s.push_str(&format!("  bb.2 = f32[{m},{n}]{{1,0}} broadcast(bias), dimensions={{1}}\n"));
                s.push_str(&format!("  mul.3 = f32[{m},{n}]{{1,0}} multiply(bb.2, bb.2)\n"));
                s.push_str(&format!("  sub.4 = f32[{m},{n}]{{1,0}} multiply(dot.1, mul.3)\n"));
                "sub.4"
            }
            "bias" => {
                s.push_str(&format!("  bb.2 = f32[{m},{n}]{{1,0}} broadcast(bias), dimensions={{1}}\n"));
                s.push_str(&format!("  add.3 = f32[{m},{n}]{{1,0}} add(dot.1, bb.2)\n"));
                "add.3"
            }
            _ => {
                s.push_str(&format!("  bb.2 = f32[{m},{n}]{{1,0}} broadcast(bias), dimensions={{1}}\n"));
                s.push_str(&format!("  add.3 = f32[{m},{n}]{{1,0}} add(dot.1, bb.2)\n"));
                s.push_str("  zero.4 = f32[] constant(0)\n");
                s.push_str(&format!("  zb.5 = f32[{m},{n}]{{1,0}} broadcast(zero.4), dimensions={{}}\n"));
                s.push_str(&format!("  max.6 = f32[{m},{n}]{{1,0}} maximum(add.3, zb.5)\n"));
                "max.6"
            }
        };
        s.push_str(&format!("  ROOT tup = (f32[{m},{n}]{{1,0}}) tuple({root})\n}}\n"));
        s
    }
    let mut rng = Rng::new(0xe9109);
    for &(m, n, k) in &[(32usize, 128usize, 64usize), (5, 7, 300), (9, 17, 3), (1, 1, 1)] {
        for tail in ["none", "bias", "bias_relu"] {
            let text = gen_dot_hlo(m, n, k, tail);
            let module = HloModule::parse(&text).expect("generated dot parses");
            let plan = Plan::compile(&module).expect("generated dot compiles");
            let names = plan.step_names();
            match tail {
                "bias" => assert!(names.contains(&"dot_bias"), "{names:?}"),
                "bias_relu" => assert!(names.contains(&"dot_bias_relu"), "{names:?}"),
                _ => assert!(names.contains(&"dot"), "{names:?}"),
            }
            for round in 0..2usize {
                let x = rng.f32_vec(m * k);
                let w = rng.f32_vec(k * n);
                let bias = rng.f32_vec(n);
                let want = module.evaluate(&[&x, &w, &bias]).unwrap();
                let got = plan.execute(&[&x, &w, &bias], 1 + round).unwrap();
                assert_bitwise_eq(
                    "dot_epilogue",
                    &format!("m={m} n={n} k={k} tail={tail} round {round}"),
                    &got[0].data,
                    &want[0].data,
                );
            }
        }
    }
}

/// Shape validation stays as strict as the interpreter's: wrong input
/// count and wrong input length are rejected.
#[test]
fn plan_validates_request_inputs() {
    let (_, _, plan, meta) = fixture_plans().remove(0);
    assert!(plan.execute(&[], 1).is_err(), "missing inputs");
    let bad = vec![0f32; meta.input_len(0) + 1];
    let good = vec![0f32; meta.input_len(1)];
    assert!(plan.execute(&[&bad, &good], 1).is_err(), "wrong length");
}
