//! The Figure 7 ground-truth test: the generated DGEMM kernel's inner loop
//! must reproduce the paper's g++ 11 object-code listing **byte for byte**,
//! disassemble to the paper's mnemonics, and compute the right numbers.

use power_mma::isa::asm::disassemble_program;
use power_mma::isa::encode::{decode_program, encode_program, FIG7_WORDS};
use power_mma::kernels::dgemm::{fig7_loop_body, run_dgemm_8xnx8};

#[test]
fn generated_loop_equals_paper_listing() {
    let bytes = encode_program(&fig7_loop_body()).unwrap();
    let expect: Vec<u8> = FIG7_WORDS.iter().flat_map(|w| w.to_le_bytes()).collect();
    assert_eq!(bytes, expect);
}

#[test]
fn disassembly_matches_paper_mnemonics() {
    let text = disassemble_program(&fig7_loop_body());
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "lxvp vs44, 64(r4)");
    assert_eq!(lines[1], "lxvp vs32, 96(r4)");
    assert_eq!(lines[2], "addi r5, r5, 64");
    assert_eq!(lines[3], "addi r4, r4, 64");
    assert_eq!(lines[4], "lxv vs40, 0(r5)");
    assert_eq!(lines[8], "xvf64gerpp a4, vs44, vs40");
    assert_eq!(lines[9], "xvf64gerpp a3, vs32, vs40");
    assert_eq!(lines[15], "xvf64gerpp a0, vs32, vs43");
    assert_eq!(lines[16], "bdnz -64");
}

#[test]
fn paper_bytes_decode_and_reencode() {
    let bytes: Vec<u8> = FIG7_WORDS.iter().flat_map(|w| w.to_le_bytes()).collect();
    let prog = decode_program(&bytes).unwrap();
    assert_eq!(prog.len(), 17);
    assert_eq!(encode_program(&prog).unwrap(), bytes);
}

#[test]
fn kernel_computes_correct_product() {
    // end-to-end: the same instruction stream produces X·Yᵀ
    let n = 16;
    let x: Vec<f64> = (0..8 * n).map(|i| (i % 13) as f64 - 6.0).collect();
    let y: Vec<f64> = (0..8 * n).map(|i| (i % 7) as f64 * 0.5).collect();
    let c = run_dgemm_8xnx8(&x, &y, n).unwrap();
    for i in 0..8 {
        for j in 0..8 {
            let expect: f64 = (0..n).map(|k| x[k * 8 + i] * y[k * 8 + j]).sum();
            assert_eq!(c[i][j], expect, "({i},{j})");
        }
    }
}
