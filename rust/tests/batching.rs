//! Integration tests for **cross-request continuous batching** over the
//! real plan backend: the coordinator compiles the MLP classifier at a
//! ladder of batch buckets (`mlp_b1`/`mlp_b8`/`mlp_b32`), drains each
//! window into the smallest sufficient bucket, and scatters output rows
//! back per request. The load-bearing property checked here is bitwise
//! identity: because every output row of the fused MLP plan depends only
//! on its own feature row, a request's response must be the same bits
//! whether it executed alone in `mlp_b1` or padded inside `mlp_b32` with
//! 31 strangers.

use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload, ShardRouting};
use power_mma::runtime::{artifacts, det_input, Runtime};
use std::time::Duration;

/// Materialize the embedded artifact set once per test process.
fn artifact_dir() -> std::path::PathBuf {
    static DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("power-mma-batching-artifacts-{}", std::process::id()));
        artifacts::write_artifacts(&dir).expect("materialize embedded artifacts");
        dir
    })
    .clone()
}

/// Start a real-runtime coordinator whose engines load the full bucket
/// ladder, serve `n` deterministic classify requests, and return the
/// responses in submission order.
fn serve_classifies(cfg: CoordinatorConfig, n: usize) -> Vec<Vec<f32>> {
    let dir = artifact_dir();
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let coord = Coordinator::start(cfg, weights, move |_shard| {
        let mut rt = Runtime::cpu(&dir)?;
        rt.load_all()?;
        rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
        Ok(rt)
    });
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let f = det_input(features, i as u64);
        rxs.push(coord.submit(Payload::Classify { features: f }).1);
    }
    let outs: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("response").result.expect("classify ok"))
        .collect();
    coord.shutdown();
    outs
}

#[test]
fn batched_ladder_matches_singleton_bitwise() {
    // 41 requests: not a multiple of any bucket, so the ladder run mixes
    // full 32-row flushes with deadline/shutdown flushes in smaller
    // buckets (and padding) — while the singleton run executes each
    // request alone in mlp_b1
    let n = 41;
    let ladder = serve_classifies(
        CoordinatorConfig {
            buckets: vec![1, 8, 32],
            max_delay: Duration::from_millis(2),
            ..Default::default()
        },
        n,
    );
    let singleton = serve_classifies(
        CoordinatorConfig {
            buckets: vec![1],
            max_delay: Duration::from_millis(2),
            ..Default::default()
        },
        n,
    );
    assert_eq!(ladder.len(), n);
    assert_eq!(singleton.len(), n);
    for (i, (a, b)) in ladder.iter().zip(&singleton).enumerate() {
        assert_eq!(a.len(), b.len(), "request {i}: response lengths differ");
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "request {i} logit {j}: batched {x} != singleton {y}"
            );
        }
    }
}

#[test]
fn ladder_run_actually_uses_multiple_buckets() {
    // a 40-request burst with an effectively infinite window: the shard
    // queue is FIFO and the Shutdown message trails every request, so
    // the engine deterministically drains one full 32-row flush and then
    // a shutdown flush of the 8-row tail — which the ladder lands in
    // bucket 8, not padded to 32
    let dir = artifact_dir();
    let cfg = CoordinatorConfig {
        buckets: vec![1, 8, 32],
        max_delay: Duration::from_secs(600),
        ..Default::default()
    };
    let ladder = cfg.ladder();
    let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
    let weights = MlpWeights::deterministic(&cfg);
    let features = cfg.features;
    let coord = Coordinator::start(cfg, weights, move |_shard| {
        let mut rt = Runtime::cpu(&dir)?;
        rt.load_all()?;
        rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
        Ok(rt)
    });
    let mut rxs = Vec::new();
    for i in 0..40u64 {
        rxs.push(coord.submit(Payload::Classify { features: det_input(features, i) }).1);
    }
    // shutdown drains the tail; buffered replies survive channel close
    let stats = coord.shutdown();
    for rx in rxs {
        rx.recv().expect("response").result.expect("classify ok");
    }
    let total_rows: u64 = stats.buckets.iter().map(|b| b.rows.get()).sum();
    assert_eq!(total_rows, 40, "every submitted row must execute exactly once");
    let b32 = stats.bucket(32).expect("bucket 32 tracked");
    assert_eq!(b32.full.get(), 1, "the burst fills bucket 32 exactly once");
    assert_eq!(b32.rows.get(), 32);
    let b8 = stats.bucket(8).expect("bucket 8 tracked");
    assert_eq!(b8.shutdown.get(), 1, "the 8-row tail flushes in bucket 8 at shutdown");
    assert_eq!(b8.rows.get(), 8);
}

#[test]
fn sticky_routing_serves_the_ladder_from_one_shard() {
    // three shards, sticky routing: the classify family hashes as one
    // unit (its canonical largest-bucket name), so every bucket of the
    // ladder stays on the same shard and responses remain row-exact
    let outs = serve_classifies(
        CoordinatorConfig {
            shards: 3,
            routing: ShardRouting::ModelSticky,
            buckets: vec![1, 8, 32],
            max_delay: Duration::from_millis(2),
            ..Default::default()
        },
        37,
    );
    let single = serve_classifies(
        CoordinatorConfig {
            buckets: vec![1],
            max_delay: Duration::from_millis(2),
            ..Default::default()
        },
        37,
    );
    for (i, (a, b)) in outs.iter().zip(&single).enumerate() {
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "request {i}: sharded-sticky response differs from singleton"
        );
    }
}
