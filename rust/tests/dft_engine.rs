//! End-to-end coverage for the DFT serving subsystem: the Fourier-matrix
//! generators (structure + unitarity), the split re/im packed twiddle
//! panels, the fused `dft_gemm` plan step against the interpreter oracle
//! **bitwise** across batch seam shapes (including non-multiples of the
//! microkernel tile), the simulated-MMA kernel against the scalar
//! reference across `n` seams, and the served two-family path: mixed
//! classify + DFT traffic through a real coordinator + runtime must
//! scatter every DFT response back bit-exact to its per-request oracle.

use power_mma::coordinator::{Coordinator, CoordinatorConfig, MlpWeights, Payload, ShardRouting};
use power_mma::kernels::dft::{dft16_twiddles_f32, dft_mma, dft_reference, fourier_matrix};
use power_mma::kernels::pack::{pack_b_panel_f32, DftPanels};
use power_mma::runtime::hlo::HloModule;
use power_mma::runtime::plan::Plan;
use power_mma::runtime::{artifacts, det_input, dft_hlo_text, dft_meta, Runtime};
use power_mma::testkit::assert_allclose;

fn assert_bitwise(name: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{name}: element {i} differs ({g} vs {w})");
    }
}

/// Bitwise f32 oracle for one 16-point serving transform under the
/// interpreter accumulation contract: each of the four real dots
/// accumulates its products in f64 in ascending k and narrows once to
/// f32; the ± combine then happens in f32. Returns `(yr, yi)` rows.
fn oracle_row(re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = 16usize;
    let (fr, fi) = dft16_twiddles_f32();
    let dot = |x: &[f32], f: &[f32], j: usize| {
        let mut acc = 0f64;
        for k in 0..n {
            acc += x[k] as f64 * f[k * n + j] as f64;
        }
        acc as f32
    };
    let mut yr = Vec::with_capacity(n);
    let mut yi = Vec::with_capacity(n);
    for j in 0..n {
        let neg = -1f32 * dot(im, &fi, j);
        yr.push(dot(re, &fr, j) + neg);
        yi.push(dot(re, &fi, j) + dot(im, &fr, j));
    }
    (yr, yi)
}

#[test]
fn fourier_matrix_is_symmetric_and_unitary() {
    for n in [4usize, 8, 13, 16] {
        let (re, im) = fourier_matrix(n);
        // F depends on j*k only, so the matrix is symmetric — the
        // property that lets the serving path run row-per-request X·F
        // without a transpose
        for j in 0..n {
            for k in 0..n {
                assert_eq!(re[j * n + k], re[k * n + j], "n={n} re ({j},{k})");
                assert_eq!(im[j * n + k], im[k * n + j], "n={n} im ({j},{k})");
            }
        }
        // unitarity up to the 1/n normalization: F·F^H = n·I
        for j in 0..n {
            for l in 0..n {
                let (mut sr, mut si) = (0f64, 0f64);
                for k in 0..n {
                    let (ar, ai) = (re[j * n + k], im[j * n + k]);
                    // conj of row l
                    let (br, bi) = (re[l * n + k], -im[l * n + k]);
                    sr += ar * br - ai * bi;
                    si += ar * bi + ai * br;
                }
                let want = if j == l { n as f64 } else { 0.0 };
                assert!((sr - want).abs() < 1e-9, "n={n} F*F^H re ({j},{l}) = {sr}");
                assert!(si.abs() < 1e-9, "n={n} F*F^H im ({j},{l}) = {si}");
            }
        }
    }
}

#[test]
fn twiddle_table_matches_the_libm_fourier_matrix() {
    let n = 16usize;
    let (fr, fi) = dft16_twiddles_f32();
    let (lr, li) = fourier_matrix(n);
    for i in 0..n * n {
        assert!((fr[i] as f64 - lr[i]).abs() < 1e-7, "re[{i}]: {} vs {}", fr[i], lr[i]);
        assert!((fi[i] as f64 - li[i]).abs() < 1e-7, "im[{i}]: {} vs {}", fi[i], li[i]);
        // and the sqrt-table values are symmetric like the matrix itself
        let (j, k) = (i / n, i % n);
        assert_eq!(fr[i].to_bits(), fr[k * n + j].to_bits());
        assert_eq!(fi[i].to_bits(), fi[k * n + j].to_bits());
    }
}

#[test]
fn split_panels_replay_the_generic_packer_bitwise() {
    let n = 16usize;
    let (fr, fi) = dft16_twiddles_f32();
    // geometries straddling the n=16 twiddle matrix: exact fit, wide
    // panels with an n-tail, and a short depth tail
    for &(nr, kc) in &[(8usize, 8usize), (16, 16), (16, 8), (12, 5), (16, 7)] {
        let panels = DftPanels::pack(&fr, &fi, n, n, nr, kc);
        for (label, packed, src) in [("re", &panels.re, &fr), ("im", &panels.im, &fi)] {
            assert_eq!(packed.geometry(), (n, n, nr, kc), "{label} geometry");
            let mut want = vec![0f32; kc * nr];
            for k0 in (0..n).step_by(kc) {
                let kcl = kc.min(n - k0);
                for j0 in (0..n).step_by(nr) {
                    let cols = nr.min(n - j0);
                    pack_b_panel_f32(src, n, k0, kcl, j0, cols, nr, &mut want[..kcl * nr]);
                    assert_bitwise(
                        &format!("{label} nr={nr} kc={kc} panel ({k0},{j0})"),
                        packed.panel(k0, kcl, j0),
                        &want[..kcl * nr],
                    );
                }
            }
        }
    }
}

#[test]
fn fused_plan_matches_interpreter_and_oracle_across_batch_seams() {
    // batch seams straddling the 8-row microkernel tile: 1, odd,
    // just-off-tile, tile-aligned, and the served bucket size
    for batch in [1usize, 3, 5, 8, 13, 32] {
        let text = dft_hlo_text(batch);
        let module = HloModule::parse(&text).unwrap_or_else(|e| panic!("b{batch}: {e}"));
        let plan = Plan::compile(&module).unwrap_or_else(|e| panic!("b{batch}: {e}"));
        assert_eq!(
            plan.step_names(),
            vec!["param", "param", "dft_gemm"],
            "b{batch}: the four dots + combines must fuse to one dft_gemm"
        );
        let meta = dft_meta(batch);
        assert_eq!(meta.output_shape, vec![2 * batch, 16]);
        let re = det_input(batch * 16, 1);
        let im = det_input(batch * 16, 2);
        let refs: Vec<&[f32]> = vec![&re, &im];
        let want = module.evaluate(&refs).unwrap_or_else(|e| panic!("b{batch}: {e}"));
        assert_eq!(want.len(), 2, "b{batch}: (yr, yi) roots");
        let mut bufs = plan.new_buffers();
        for threads in [1usize, 4] {
            let got = plan.execute_into(&mut bufs, &refs, threads).unwrap();
            assert_eq!(got.len(), 2, "b{batch}: plan root arity");
            for (half, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.dims, vec![batch, 16]);
                assert_bitwise(
                    &format!("b{batch} threads {threads} half {half} vs interpreter"),
                    &g.data,
                    &w.data,
                );
            }
            // and bitwise against the row-wise twiddle-table oracle
            for r in 0..batch {
                let (yr, yi) = oracle_row(&re[r * 16..(r + 1) * 16], &im[r * 16..(r + 1) * 16]);
                assert_bitwise(
                    &format!("b{batch} threads {threads} row {r} yr"),
                    &got[0].data[r * 16..(r + 1) * 16],
                    &yr,
                );
                assert_bitwise(
                    &format!("b{batch} threads {threads} row {r} yi"),
                    &got[1].data[r * 16..(r + 1) * 16],
                    &yi,
                );
            }
        }
    }
}

#[test]
fn mma_kernel_matches_the_scalar_reference_across_n_seams() {
    // n off the 8-tile grid exercises the zero-padded panels; the valid
    // region must match the O(n²) scalar reference
    for &(n, batch) in &[(3usize, 1usize), (5, 2), (8, 7), (12, 3), (16, 9)] {
        let xr: Vec<f64> =
            (0..n * batch).map(|i| ((i * 31 + 7) % 61) as f64 / 61.0 - 0.5).collect();
        let xi: Vec<f64> =
            (0..n * batch).map(|i| ((i * 17 + 5) % 53) as f64 / 53.0 - 0.5).collect();
        let (yr, yi, stats) = dft_mma(&xr, &xi, n, batch).unwrap();
        let (rr, ri) = dft_reference(&xr, &xi, n, batch);
        assert_allclose(&yr, &rr, 1e-10, 1e-10);
        assert_allclose(&yi, &ri, 1e-10, 1e-10);
        assert!(stats.mma_instructions > 0, "n={n}: the kernel path must run on MMA");
    }
}

#[test]
fn served_two_family_traffic_scatters_back_exactly() {
    let dir = std::env::temp_dir()
        .join(format!("mma-dft-engine-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    artifacts::ensure_artifacts(&dir).unwrap();
    for routing in [ShardRouting::RoundRobin, ShardRouting::ModelSticky] {
        let cfg = CoordinatorConfig {
            routing,
            buckets: vec![1, 8],
            max_delay: std::time::Duration::from_micros(500),
            ..Default::default()
        };
        let ladder = cfg.ladder();
        let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
        let weights = MlpWeights::deterministic(&cfg);
        let features = cfg.features;
        let dft_n = cfg.dft_n;
        let dir2 = dir.clone();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            let mut rt = Runtime::cpu(&dir2)?;
            rt.load_all()?;
            rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
            rt.load_dft_buckets(&ladder)?;
            Ok(rt)
        });
        // a burst larger than the biggest bucket, alternating families,
        // so DFT windows flush both full and on the deadline while
        // classify traffic interleaves through the same engines
        let n = 24usize;
        let mut pending = Vec::with_capacity(n);
        for i in 0..n {
            if i % 2 == 0 {
                let re = det_input(dft_n, i as u64);
                let im = det_input(dft_n, i as u64 + 100);
                let rx = coord.submit(Payload::Dft { re: re.clone(), im: im.clone() }).1;
                pending.push((rx, Some((re, im))));
            } else {
                let rx =
                    coord.submit(Payload::Classify { features: det_input(features, i as u64) }).1;
                pending.push((rx, None));
            }
        }
        let mut dft_seen = 0usize;
        for (i, (rx, dft_in)) in pending.into_iter().enumerate() {
            let r = rx.recv().unwrap_or_else(|_| panic!("request {i} dropped"));
            let out = r.result.unwrap_or_else(|e| panic!("request {i} failed: {e}"));
            if let Some((re, im)) = dft_in {
                dft_seen += 1;
                let (yr, yi) = oracle_row(&re, &im);
                assert_eq!(out.len(), 2 * dft_n, "request {i}: (yr ‖ yi) row");
                assert_bitwise(&format!("request {i} yr"), &out[..dft_n], &yr);
                assert_bitwise(&format!("request {i} yi"), &out[dft_n..], &yi);
            } else {
                assert!(!out.is_empty(), "request {i}: classify row");
            }
        }
        assert_eq!(dft_seen, n / 2);
        let stats = coord.shutdown();
        let dft_rows: u64 = stats.dft_buckets.iter().map(|b| b.rows.get()).sum();
        assert_eq!(dft_rows, (n / 2) as u64, "every DFT row executed in a DFT bucket");
        // malformed requests are rejected before they reach a window
        let cfg = CoordinatorConfig { routing, ..Default::default() };
        let ladder = cfg.ladder();
        let (feat, hid, cls) = (cfg.features, cfg.hidden, cfg.classes);
        let weights = MlpWeights::deterministic(&cfg);
        let dir3 = dir.clone();
        let coord = Coordinator::start(cfg, weights, move |_shard| {
            let mut rt = Runtime::cpu(&dir3)?;
            rt.load_all()?;
            rt.load_mlp_buckets(&ladder, feat, hid, cls)?;
            rt.load_dft_buckets(&ladder)?;
            Ok(rt)
        });
        let (_, rx) = coord.submit(Payload::Dft { re: vec![0.0; 3], im: vec![0.0; 3] });
        let r = rx.recv().expect("malformed response delivered");
        assert!(r.result.is_err(), "a short DFT request must be rejected");
        coord.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}
