//! Contract coverage for the per-step roofline profiler: the
//! synthesized MMA instruction stream of every GEMM-bearing plan step
//! must retire **exactly** `gemms · m · n · k` multiply-accumulates —
//! across dtypes, tuner variants, and shapes straddling every register-
//! and cache-tile seam — and `microkernel_fpc` must reproduce the three
//! Table-I ratio probes `bench serve` used to compute inline
//! **bit-for-bit**. On top: `Plan::profile()` agrees with the plan's own
//! `gemm_variants()` audit (same steps, shapes, variants), a `dft_gemm`
//! step profiles as its real packed-panel 4-GEMM structure, and mem
//! steps profile MAC-free.

use power_mma::blas::bf16_gemm::executed_kernel_bf16;
use power_mma::blas::block_gemm::{executed_kernel_f32, ExecutedKernel, GemmVariant};
use power_mma::blas::i8_gemm::executed_kernel_i8;
use power_mma::core_model::{CoreSim, MachineConfig};
use power_mma::isa::GerKind;
use power_mma::kernels::gemm_rp::rp_gemm_program;
use power_mma::runtime::hlo::HloModule;
use power_mma::runtime::plan::{Plan, PlanOptions};
use power_mma::runtime::profile::{profile_step, table1_peak, StepKernel, StepSpec};
use power_mma::runtime::{
    dft_hlo_text, microkernel_fpc, mlp_hlo_text, mlp_int8_calib, TuneEpi, TunePanel,
};

fn spec_of(ek: ExecutedKernel, epi: TuneEpi, panel: TunePanel, gemms: usize) -> StepSpec {
    StepSpec { index: 0, step: "test".into(), kernel: StepKernel::Gemm { ek, epi, panel, gemms } }
}

/// Shapes that hit every seam class: unit, sub-tile, exact-tile,
/// m/n/k tails against MR/NR/KC, multi-cache-block, and rank tails
/// (k ≢ 0 mod 2 for bf16, mod 4 for i8).
fn seam_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (3, 5, 2),
        (7, 9, 5),
        (8, 8, 8),
        (8, 16, 31),
        (16, 8, 33),
        (33, 17, 129),
        (64, 64, 257),
        (5, 130, 7),
        (130, 5, 258),
    ]
}

#[test]
fn f32_mac_count_exact_across_variants() {
    for v in GemmVariant::f32_candidates() {
        for (m, n, k) in seam_shapes() {
            let p = profile_step(&spec_of(
                executed_kernel_f32(m, n, k, v),
                TuneEpi::None,
                TunePanel::Matrix,
                1,
            ));
            assert_eq!(p.mix.macs, (m * n * k) as u64, "f32 {m}x{n}x{k} {}", v.name());
        }
    }
}

#[test]
fn bf16_mac_count_exact_across_variants() {
    for v in GemmVariant::wide_candidates() {
        for (m, n, k) in seam_shapes() {
            let p = profile_step(&spec_of(
                executed_kernel_bf16(m, n, k, v),
                TuneEpi::Bias,
                TunePanel::Matrix,
                1,
            ));
            assert_eq!(p.mix.macs, (m * n * k) as u64, "bf16 {m}x{n}x{k} {}", v.name());
        }
    }
}

#[test]
fn i8_mac_count_exact_across_variants() {
    for v in GemmVariant::wide_candidates() {
        for (m, n, k) in seam_shapes() {
            let p = profile_step(&spec_of(
                executed_kernel_i8(m, n, k, v),
                TuneEpi::BiasRelu,
                TunePanel::Matrix,
                1,
            ));
            assert_eq!(p.mix.macs, (m * n * k) as u64, "i8 {m}x{n}x{k} {}", v.name());
        }
    }
}

#[test]
fn epilogues_never_change_mac_count() {
    let (m, n, k) = (33, 17, 29);
    let base = profile_step(&spec_of(
        executed_kernel_f32(m, n, k, GemmVariant::CANONICAL_F32),
        TuneEpi::None,
        TunePanel::Matrix,
        1,
    ));
    for epi in [TuneEpi::Bias, TuneEpi::BiasRelu] {
        let p = profile_step(&spec_of(
            executed_kernel_f32(m, n, k, GemmVariant::CANONICAL_F32),
            epi,
            TunePanel::Matrix,
            1,
        ));
        assert_eq!(p.mix.macs, base.mix.macs, "{epi:?}");
        // bias/relu adds vector work + loads, never ger work
        assert!(p.mix.insts > base.mix.insts, "{epi:?}");
    }
}

#[test]
fn dft_step_profiles_as_four_gemms() {
    let (m, n, k) = (32, 16, 16);
    let p = profile_step(&spec_of(
        executed_kernel_f32(m, n, k, GemmVariant::CANONICAL_F32),
        TuneEpi::None,
        TunePanel::DftPacked,
        4,
    ));
    assert_eq!(p.gemms, 4);
    assert_eq!(p.mix.macs, (4 * m * n * k) as u64);
    // the two DftCombine writebacks contribute vector-FMA combines
    assert!(p.mix.counts.iter().any(|(op, _)| op == "xvmaddasp"), "{:?}", p.mix.counts);
}

#[test]
fn mem_steps_have_no_macs() {
    for (lb, sb, fma) in [(4096usize, 4096usize, 0usize), (1024, 256, 64), (0, 0, 0)] {
        let p = profile_step(&StepSpec {
            index: 9,
            step: "copy".into(),
            kernel: StepKernel::Mem { load_bytes: lb, store_bytes: sb, fma_ops: fma },
        });
        assert_eq!(p.mix.macs, 0);
        assert!(!p.is_gemm());
        assert_eq!(p.mix.loads, lb.div_ceil(16) as u64);
        assert_eq!(p.mix.stores, sb.div_ceil(16) as u64);
        assert!(p.achieved_macs_per_cycle.is_none());
    }
}

#[test]
fn ceiling_respects_table1_peak_and_occupancies_are_fractions() {
    for (ek, rank) in [
        (executed_kernel_f32(64, 64, 64, GemmVariant::CANONICAL_F32), 1usize),
        (executed_kernel_bf16(64, 64, 64, GemmVariant::CANONICAL_WIDE), 2),
        (executed_kernel_i8(64, 64, 64, GemmVariant::CANONICAL_WIDE), 4),
    ] {
        let p = profile_step(&spec_of(ek, TuneEpi::None, TunePanel::Matrix, 1));
        let peak = table1_peak(&MachineConfig::power10(), rank);
        assert_eq!(p.table1_peak_macs_per_cycle, peak);
        assert!(p.sim_macs_per_cycle > 0.0, "{}", ek.elem);
        assert!(p.sim_macs_per_cycle <= peak, "{}: {} > {peak}", ek.elem, p.sim_macs_per_cycle);
        for (unit, f) in p.occupancies {
            assert!((0.0..=1.0).contains(&f), "{unit} occupancy {f}");
        }
        assert!(!p.bound.is_empty() && !p.bound_unit.is_empty());
    }
}

/// The generalized probe must be **bit-for-bit** what the bench's three
/// inline closures computed: same program builder, same simulator
/// construction, same fuel. The four call sites `bench serve` issues
/// are pinned here with `sim_steps = 64`.
#[test]
fn microkernel_fpc_reproduces_bench_probes_bitwise() {
    let inline = |kind: GerKind, steps: usize| -> f64 {
        let mut sim = CoreSim::new(MachineConfig::power10());
        sim.run(&rp_gemm_program(kind, steps, None), 1 << 22).flops_per_cycle()
    };
    let sim_steps = 64;
    for (kind, steps) in [
        (GerKind::F32Ger, 2 * sim_steps),
        (GerKind::Bf16Ger2, sim_steps),
        (GerKind::F32Ger, 4 * sim_steps),
        (GerKind::I8Ger4, sim_steps),
    ] {
        let got = microkernel_fpc(kind, steps);
        let want = inline(kind, steps);
        assert_eq!(got.to_bits(), want.to_bits(), "{kind:?}/{steps}: {got} vs {want}");
    }
}

/// `Plan::profile()` must describe exactly the GEMMs the plan says it
/// executes: one roofline row per `gemm_variants()` entry, same shapes,
/// same baked variants, in step order — across all four served families.
#[test]
fn plan_profile_agrees_with_gemm_variants_audit() {
    let calib = mlp_int8_calib(64, 96, 10);
    let plans = [
        ("mlp_f32", mlp_hlo_text(8, 64, 96, 10), None),
        ("mlp_int8", mlp_hlo_text(8, 64, 96, 10), Some(calib)),
        ("dft_b8", dft_hlo_text(8), None),
    ];
    for (name, text, calib) in plans {
        let module = HloModule::parse(&text).unwrap();
        let opts = PlanOptions { int8_calib: calib, ..Default::default() };
        let plan = Plan::compile_with_options(&module, opts).unwrap();
        let audit = plan.gemm_variants();
        let rows: Vec<_> = plan.profile().into_iter().filter(|p| p.is_gemm()).collect();
        assert_eq!(rows.len(), audit.len(), "{name}");
        for (p, (key, v)) in rows.iter().zip(&audit) {
            assert_eq!((p.m, p.n, p.k), (key.m, key.n, key.k), "{name}/{}", p.step);
            assert_eq!(p.variant, Some(*v), "{name}/{}", p.step);
            let expect_gemms = if key.panel == TunePanel::DftPacked { 4 } else { 1 };
            assert_eq!(p.gemms, expect_gemms, "{name}/{}", p.step);
            assert_eq!(p.mix.macs, (p.gemms * p.m * p.n * p.k) as u64, "{name}/{}", p.step);
        }
        // every step (GEMM or mem) yields a profile row
        assert_eq!(plan.profile().len(), plan.step_names().len(), "{name}");
    }
}
