//! Cross-cutting property tests: random instruction streams round-trip
//! through the binary codec and the assembler; random masked rank-k updates
//! match a scalar model of equations (1)–(3); the timing model is
//! deterministic and mass-conserving.

use power_mma::isa::asm::{assemble, disassemble_program};
use power_mma::isa::encode::{decode_program, encode_program};
use power_mma::isa::inst::{AccOp, Ger, GerKind, Inst};
use power_mma::isa::regs::Vsr;
use power_mma::isa::Machine;
use power_mma::testkit::{check, Rng};

/// Generate a random *encodable* instruction.
fn arb_inst(rng: &mut Rng) -> Inst {
    let ops = [AccOp::New, AccOp::NewS, AccOp::PP, AccOp::NP, AccOp::PN, AccOp::NN, AccOp::SPP];
    loop {
        match rng.below(12) {
            0 => return Inst::XxSetAccZ { acc: rng.below(8) as u8 },
            1 => return Inst::XxMfAcc { acc: rng.below(8) as u8 },
            2 => return Inst::XxMtAcc { acc: rng.below(8) as u8 },
            3 => {
                return Inst::Lxv {
                    xt: rng.below(64) as u8,
                    ra: rng.below(32) as u8,
                    dq: rng.irange(-128, 127) as i32 * 16,
                }
            }
            4 => {
                return Inst::Lxvp {
                    xtp: (rng.below(32) * 2) as u8,
                    ra: rng.below(32) as u8,
                    dq: rng.irange(-128, 127) as i32 * 16,
                }
            }
            5 => {
                return Inst::Stxv {
                    xs: rng.below(64) as u8,
                    ra: rng.below(32) as u8,
                    dq: rng.irange(-128, 127) as i32 * 16,
                }
            }
            6 => {
                return Inst::Addi {
                    rt: rng.below(32) as u8,
                    ra: rng.below(32) as u8,
                    si: rng.irange(-32768, 32767) as i32,
                }
            }
            7 => return Inst::Mtctr { rs: rng.below(32) as u8 },
            8 => {
                return Inst::XvMaddaDp {
                    xt: rng.below(64) as u8,
                    xa: rng.below(64) as u8,
                    xb: rng.below(64) as u8,
                }
            }
            9 => {
                return Inst::XxSpltd { xt: rng.below(64) as u8, xa: rng.below(64) as u8, h: rng.below(2) as u8 }
            }
            10 => return Inst::Nop,
            _ => {
                let kind = *rng.pick(&GerKind::ALL);
                let op = *rng.pick(&ops);
                if !op.valid_for(kind) {
                    continue;
                }
                let acc = rng.below(8) as u8;
                let xa = if kind == GerKind::F64Ger { (rng.below(16) * 2 + 32) as u8 } else { rng.below(64) as u8 };
                let yb = rng.below(64) as u8;
                if rng.bool() {
                    return Inst::Ger(Ger::new(kind, op, acc, xa, yb));
                }
                let yw = if kind == GerKind::F64Ger { 2 } else { 4 };
                let pw = kind.rank();
                let pmsk = if pw == 1 { 0xff } else { rng.below(1 << pw) as u8 };
                return Inst::Ger(Ger::prefixed(
                    kind,
                    op,
                    acc,
                    xa,
                    yb,
                    rng.below(16) as u8,
                    rng.below(1 << yw) as u8,
                    pmsk,
                ));
            }
        }
    }
}

#[test]
fn encode_decode_round_trip() {
    check("encode/decode round trip", 300, |rng| {
        let prog: Vec<Inst> = (0..rng.range(1, 40)).map(|_| arb_inst(rng)).collect();
        let bytes = encode_program(&prog).unwrap();
        let back = decode_program(&bytes).unwrap();
        assert_eq!(back, prog);
    });
}

#[test]
fn asm_round_trip() {
    check("asm round trip", 300, |rng| {
        let prog: Vec<Inst> = (0..rng.range(1, 30)).map(|_| arb_inst(rng)).collect();
        let text = disassemble_program(&prog);
        let back = assemble(&text).unwrap();
        assert_eq!(back, prog, "\n{text}");
    });
}

/// Scalar model of eq. (1)-(3) for the integer kinds.
fn scalar_int_ger(g: &Ger, x: &Vsr, y: &Vsr, acc: [[i32; 4]; 4]) -> [[i32; 4]; 4] {
    let rank = g.kind.rank();
    let mut out = acc;
    for i in 0..4 {
        for j in 0..4 {
            let enabled = (g.xmsk >> i) & 1 == 1 && (g.ymsk >> j) & 1 == 1;
            if !enabled {
                if !g.op.accumulates() {
                    out[i][j] = 0;
                }
                continue;
            }
            let mut sum: i64 = 0;
            for k in 0..rank {
                if (g.pmsk >> k) & 1 == 0 {
                    continue;
                }
                let (xe, ye): (i64, i64) = match g.kind {
                    GerKind::I16Ger2 => (x.i16(2 * i + k).into(), y.i16(2 * j + k).into()),
                    GerKind::I8Ger4 => ((x.i8(4 * i + k) as i64), y.u8(4 * j + k).into()),
                    GerKind::I4Ger8 => (x.i4(8 * i + k).into(), y.i4(8 * j + k).into()),
                    _ => unreachable!(),
                };
                sum += xe * ye;
            }
            let prev = if g.op.accumulates() { i64::from(acc[i][j]) } else { 0 };
            let v = prev + sum;
            out[i][j] = match g.op {
                AccOp::NewS | AccOp::SPP => v.clamp(i32::MIN as i64, i32::MAX as i64) as i32,
                _ => v as i32, // wrapping
            };
        }
    }
    out
}

#[test]
fn integer_ger_matches_scalar_model() {
    check("integer ger == eq.(1)+(3) scalar model", 200, |rng| {
        let kinds = [GerKind::I16Ger2, GerKind::I8Ger4, GerKind::I4Ger8];
        let kind = *rng.pick(&kinds);
        let ops: Vec<AccOp> = [AccOp::New, AccOp::NewS, AccOp::PP, AccOp::SPP]
            .into_iter()
            .filter(|o| o.valid_for(kind))
            .collect();
        let op = *rng.pick(&ops);
        let mut xb = [0u8; 16];
        let mut yb = [0u8; 16];
        for b in 0..16 {
            xb[b] = rng.below(256) as u8;
            yb[b] = rng.below(256) as u8;
        }
        let (x, y) = (Vsr::from_u8x16(xb), Vsr::from_u8x16(yb));
        let prefixed = rng.bool();
        let g = if prefixed {
            let pw = kind.rank();
            Ger::prefixed(
                kind,
                op,
                0,
                40,
                41,
                rng.below(16) as u8,
                rng.below(16) as u8,
                rng.below(1 << pw) as u8,
            )
        } else {
            Ger::new(kind, op, 0, 40, 41)
        };
        let mut m = Machine::new(64);
        m.regs.vsr[40] = x;
        m.regs.vsr[41] = y;
        let acc0 = {
            let mut a = [[0i32; 4]; 4];
            for (i, row) in a.iter_mut().enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = rng.irange(i32::MIN as i64, i32::MAX as i64) as i32;
                }
                let _ = i;
            }
            a
        };
        m.regs.acc[0] = power_mma::isa::regs::Acc::from_i32_4x4(acc0);
        m.regs.primed[0] = true;
        m.exec_ger(&g).unwrap();
        let expect = scalar_int_ger(&g, &x, &y, acc0);
        assert_eq!(m.regs.acc[0].to_i32_4x4(), expect, "{g:?}");
    });
}

#[test]
fn float_masked_ger_matches_scalar_model() {
    check("pmxvf32ger == eq.(3)", 200, |rng| {
        let mut m = Machine::new(64);
        let xs: Vec<f32> = (0..4).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        let ys: Vec<f32> = (0..4).map(|_| rng.f32_range(-4.0, 4.0)).collect();
        m.regs.vsr[50] = Vsr::from_f32x4(xs.clone().try_into().unwrap());
        m.regs.vsr[51] = Vsr::from_f32x4(ys.clone().try_into().unwrap());
        let acc0: Vec<f32> = (0..16).map(|_| rng.f32_range(-8.0, 8.0)).collect();
        let mut a0 = [[0f32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                a0[i][j] = acc0[4 * i + j];
            }
        }
        m.regs.acc[3] = power_mma::isa::regs::Acc::from_f32_4x4(a0);
        m.regs.primed[3] = true;
        let ops = [AccOp::PP, AccOp::NP, AccOp::PN, AccOp::NN];
        let op = *rng.pick(&ops);
        let (xm, ym) = (rng.below(16) as u8, rng.below(16) as u8);
        let g = Ger::prefixed(GerKind::F32Ger, op, 3, 50, 51, xm, ym, 0xff);
        m.exec_ger(&g).unwrap();
        let got = m.regs.acc[3].to_f32_4x4();
        for i in 0..4 {
            for j in 0..4 {
                let enabled = (xm >> i) & 1 == 1 && (ym >> j) & 1 == 1;
                let expect = if !enabled {
                    a0[i][j]
                } else {
                    let p = xs[i] * ys[j];
                    match op {
                        AccOp::PP => p + a0[i][j],
                        AccOp::NP => -p + a0[i][j],
                        AccOp::PN => p - a0[i][j],
                        AccOp::NN => -p - a0[i][j],
                        _ => unreachable!(),
                    }
                };
                assert_eq!(got[i][j], expect, "({i},{j}) {op:?}");
            }
        }
    });
}

#[test]
fn functional_and_timing_models_agree_on_instruction_count() {
    use power_mma::core_model::{CoreSim, MachineConfig};
    use power_mma::kernels::dgemm::dgemm_8xnx8_program;
    check("CoreSim executes the same dynamic stream", 10, |rng| {
        let n = rng.range(1, 64);
        let prog = dgemm_8xnx8_program(n);
        // functional
        let mut m = Machine::new(1 << 16);
        m.gpr[3] = 32768;
        m.gpr[4] = 0;
        m.gpr[5] = 8192;
        m.run(&prog, 1 << 20).unwrap();
        // timing
        let mut sim = CoreSim::new(MachineConfig::power10());
        sim.gpr = [0; 32];
        sim.gpr[3] = 32768;
        sim.gpr[5] = 8192;
        let r = sim.run(&prog, 1 << 20);
        assert_eq!(r.instructions, m.stats.instructions);
        assert_eq!(r.flops, m.stats.flops);
    });
}

#[test]
fn exhaustive_mask_sweep_f16ger2() {
    // every (xmsk, ymsk, pmsk) combination of pmxvf16ger2pp: 16*16*4
    // cases, each checked against the eq. (3) scalar model
    use power_mma::isa::types::f32_to_f16;
    let xs: Vec<f32> = (0..8).map(|i| (i as f32) * 0.5 - 1.75).collect();
    let ys: Vec<f32> = (0..8).map(|i| 2.0 - (i as f32) * 0.25).collect();
    let xh: Vec<u16> = xs.iter().map(|&v| f32_to_f16(v)).collect();
    let yh: Vec<u16> = ys.iter().map(|&v| f32_to_f16(v)).collect();
    let mut m = Machine::new(64);
    m.regs.vsr[34] = Vsr::from_u16x8(xh.try_into().unwrap());
    m.regs.vsr[35] = Vsr::from_u16x8(yh.try_into().unwrap());
    let base = [[5.0f32; 4]; 4];
    for xmsk in 0..16u8 {
        for ymsk in 0..16u8 {
            for pmsk in 0..4u8 {
                m.regs.acc[0] = power_mma::isa::regs::Acc::from_f32_4x4(base);
                m.regs.primed[0] = true;
                let g = Ger::prefixed(GerKind::F16Ger2, AccOp::PP, 0, 34, 35, xmsk, ymsk, pmsk);
                m.exec_ger(&g).unwrap();
                let got = m.regs.acc[0].to_f32_4x4();
                for i in 0..4 {
                    for j in 0..4 {
                        let enabled = (xmsk >> i) & 1 == 1 && (ymsk >> j) & 1 == 1;
                        let expect = if !enabled {
                            base[i][j]
                        } else {
                            let mut p = 0f32;
                            for k in 0..2 {
                                if (pmsk >> k) & 1 == 1 {
                                    p += xs[2 * i + k] * ys[2 * j + k];
                                }
                            }
                            p + base[i][j]
                        };
                        assert_eq!(
                            got[i][j], expect,
                            "x={xmsk:04b} y={ymsk:04b} p={pmsk:02b} ({i},{j})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn vsx_and_mma_kernels_agree_numerically() {
    // differential test: the two §VI code paths must compute identical
    // products (modulo f64 association, which is identical here since both
    // sum in k order)
    use power_mma::kernels::dgemm::run_dgemm_8xnx8;
    use power_mma::kernels::vsx::run_vsx_dgemm_8x4;
    check("vsx == mma dgemm", 10, |rng| {
        let k = rng.range(1, 30);
        let x = rng.f64_vec(8 * k);
        let y8 = rng.f64_vec(8 * k);
        let mma = run_dgemm_8xnx8(&x, &y8, k).unwrap();
        // VSX computes 8x4 blocks: columns 0..4 use y rows 0..4 of each column
        let mut y4 = vec![0f64; 4 * k];
        for kk in 0..k {
            y4[kk * 4..kk * 4 + 4].copy_from_slice(&y8[kk * 8..kk * 8 + 4]);
        }
        let vsx = run_vsx_dgemm_8x4(&x, &y4, k).unwrap();
        for i in 0..8 {
            for j in 0..4 {
                assert!(
                    (mma[i][j] - vsx[i][j]).abs() < 1e-12 * mma[i][j].abs().max(1.0),
                    "({i},{j})"
                );
            }
        }
    });
}
