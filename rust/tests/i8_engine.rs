//! Differential coverage for the int8 rank-4 quantized GEMM engine: the
//! packed-panel microkernel (`blas::i8_gemm`) must replay the Machine's
//! `xvi8ger4` prime + `xvi8ger4[s]pp` accumulate chains **bitwise** — for
//! every `k % 4` tail, at operand extremes (i8 −128/127, u8 0/255), and
//! across the i32 overflow boundary where the `spp` chain clamps while
//! the modulo chain wraps. The oracle on one side is `isa::exec` itself
//! (via the register-pressure kernels `gemm_i8_8x16[_sat]`), on the
//! other the stepwise `gemm_i8_reference`; blocking (KC) and column-chunk
//! parallel policies must never change a single bit. On top rides the
//! quantized f32→f32 serving contract: fused quantize→dot→dequantize
//! equal to its elementwise reference, up to the int8-served MLP bucket
//! behind the public runtime API.

use power_mma::blas::block_gemm::{Par, KC};
use power_mma::blas::i8_gemm::{
    gemm_i8_dequant_into, gemm_i8_dequant_reference, gemm_i8_packed_into, gemm_i8_reference,
    I8Accum, I8Epilogue, I8Scratch, I8SrcA, I8SrcB, QuantParams,
};
use power_mma::kernels::gemm_rp::{gemm_i8_8x16, gemm_i8_8x16_sat};
use power_mma::testkit::{check, Rng};

fn run_packed(
    a: I8SrcA<'_>,
    b: I8SrcB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: I8Accum,
    par: Par<'_>,
) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    let mut scratch = I8Scratch::new();
    gemm_i8_packed_into(&mut c, a, b, m, n, k, accum, par, &mut scratch);
    c
}

/// The `isa::exec` oracle at the fixed 8×16 tile: packs the operands into
/// Machine memory, runs the `xvi8ger4` prime + `xvi8ger4[s]pp` program
/// (masked-tail prefixed forms for `k % 4 != 0`) instruction by
/// instruction, and reads the accumulators back. `b` comes in engine
/// layout (`k×16` row-major) and is transposed to the kernel's 16 rows
/// of `k`.
fn machine_8x16(a: &[i8], b: &[u8], k: usize, accum: I8Accum) -> Vec<i32> {
    let mut yt = vec![0u8; 16 * k];
    for r in 0..k {
        for j in 0..16 {
            yt[j * k + r] = b[r * 16 + j];
        }
    }
    let tile = match accum {
        I8Accum::Wrapping => gemm_i8_8x16(a, &yt, k),
        I8Accum::Saturating => gemm_i8_8x16_sat(a, &yt, k),
    }
    .expect("the xvi8ger4 program must execute");
    tile.iter().flatten().copied().collect()
}

/// Random signed operand with the extreme values guaranteed present.
fn spiked_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    let mut v: Vec<i8> = (0..len).map(|_| rng.irange(-128, 127) as i8).collect();
    for (i, &s) in [-128i8, 127, 0, -1, 1].iter().enumerate() {
        v[(i * 11 + 5) % len.max(1)] = s;
    }
    v
}

/// Random unsigned operand with the extreme values guaranteed present.
fn spiked_u8(rng: &mut Rng, len: usize) -> Vec<u8> {
    let mut v: Vec<u8> = (0..len).map(|_| rng.irange(0, 255) as u8).collect();
    for (i, &s) in [255u8, 0, 128, 1, 254].iter().enumerate() {
        v[(i * 13 + 7) % len.max(1)] = s;
    }
    v
}

#[test]
fn every_k_tail_matches_the_isa_machine_bitwise() {
    // k = 1..=16 walks every k % 4 tail through the masked prefixed
    // forms, with both accumulate chains, at operand extremes — the
    // engine, the stepwise reference, and the Machine must agree on
    // every one of the 8×16 i32 accumulators exactly
    let mut rng = Rng::new(0x18e4);
    for k in 1..=16usize {
        for trial in 0..2 {
            let a = spiked_i8(&mut rng, 8 * k);
            let b = spiked_u8(&mut rng, k * 16);
            for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
                let want = machine_8x16(&a, &b, k, accum);
                let got = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, accum, Par::Seq);
                assert_eq!(got, want, "engine vs machine k={k} trial={trial} {accum:?}");
                let reference = gemm_i8_reference(&a, &b, 8, 16, k, accum);
                assert_eq!(reference, want, "reference vs machine k={k} {accum:?}");
            }
        }
    }
}

#[test]
fn kc_boundary_blocks_replay_the_machine_chain() {
    // the Machine accumulates one flat chain; the engine re-packs per
    // KC block — KC % 4 == 0 means blocks never split a quad, so the
    // chains must be the same chain, bit for bit, on both contracts
    let mut rng = Rng::new(0xb10c);
    for &k in &[KC - 1, KC + 1] {
        let a = spiked_i8(&mut rng, 8 * k);
        let b = spiked_u8(&mut rng, k * 16);
        for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
            let want = machine_8x16(&a, &b, k, accum);
            let got = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, accum, Par::Seq);
            assert_eq!(got, want, "KC straddle k={k} {accum:?}");
        }
    }
}

#[test]
fn spp_clamps_at_i32_min_where_the_modulo_chain_wraps() {
    // every product pinned at the most negative value: each rank-4 step
    // adds 4·(−128·255) = −130560 exactly, so 16500 steps drive the
    // exact sum to −2_154_240_000, past i32::MIN — spp clamps there,
    // pp wraps to +2_140_727_296. A k % 4 tail rides the padded lanes
    // through the overflow crossing.
    for &tail in &[0usize, 3] {
        let k = 4 * 16_500 + tail;
        let a = vec![-128i8; 8 * k];
        let b = vec![255u8; k * 16];
        let sat = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, I8Accum::Saturating, Par::Seq);
        let wrap = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, I8Accum::Wrapping, Par::Seq);
        assert!(sat.iter().all(|&v| v == i32::MIN), "spp must clamp (tail={tail})");
        assert_ne!(sat, wrap, "the chains must diverge past the boundary");
        assert_eq!(sat, machine_8x16(&a, &b, k, I8Accum::Saturating), "spp vs machine tail={tail}");
        assert_eq!(wrap, gemm_i8_reference(&a, &b, 8, 16, k, I8Accum::Wrapping));
        if tail == 0 {
            assert!(wrap.iter().all(|&v| v == 2_140_727_296), "pp wraps to the exact residue");
            assert_eq!(wrap, machine_8x16(&a, &b, k, I8Accum::Wrapping), "pp vs machine");
        }
    }
}

#[test]
fn spp_clamps_at_i32_max_on_the_positive_side() {
    // the positive boundary needs more steps (4·127·255 = 129540 per
    // step): 16600 steps reach +2_150_364_000 > i32::MAX
    let k = 4 * 16_600;
    let a = vec![127i8; 8 * k];
    let b = vec![255u8; k * 16];
    let sat = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, I8Accum::Saturating, Par::Seq);
    let wrap = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), 8, 16, k, I8Accum::Wrapping, Par::Seq);
    assert!(sat.iter().all(|&v| v == i32::MAX), "spp must clamp at i32::MAX");
    assert_ne!(sat, wrap);
    assert_eq!(wrap, gemm_i8_reference(&a, &b, 8, 16, k, I8Accum::Wrapping));
}

#[test]
fn random_shapes_across_blocking_boundaries_match_the_reference() {
    // shapes straddling the microkernel tile, the KC depth blocks, and
    // the column-chunk split; the parallel policies redistribute work
    // but must never change bits
    check("i8 engine blocking boundaries", 12, |rng: &mut Rng| {
        let m = *rng.pick(&[1usize, 3, 8, 9, 17, 33]);
        let n = *rng.pick(&[1usize, 15, 16, 17, 48, 130]);
        let k = *rng.pick(&[1usize, 5, 16, KC - 1, KC, KC + 1, KC + 3, 2 * KC + 2]);
        let a = spiked_i8(rng, m * k);
        let b = spiked_u8(rng, k * n);
        let accum = if rng.bool() { I8Accum::Wrapping } else { I8Accum::Saturating };
        let want = gemm_i8_reference(&a, &b, m, n, k, accum);
        for threads in [1usize, 3, 5] {
            let par = if threads == 1 { Par::Seq } else { Par::Scoped(threads) };
            let got = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), m, n, k, accum, par);
            assert_eq!(got, want, "m={m} n={n} k={k} threads={threads} {accum:?}");
        }
    });
}

#[test]
fn fused_quantize_dot_dequantize_matches_the_reference_bitwise() {
    // the serving path: quantization fused into packing, the exact
    // zero-point correction and bias/relu at writeback — bit-equal to
    // the elementwise staged reference for every epilogue shape
    check("i8 dequant serving path", 8, |rng: &mut Rng| {
        let m = rng.range(1, 20);
        let n = rng.range(1, 40);
        let k = *rng.pick(&[3usize, 17, 64, KC + 1]);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let q = QuantParams {
            a_scale: 1.0 / 127.0,
            a_zp: rng.irange(-8, 8) as i32,
            b_scale: 1.0 / 255.0,
            b_zp: rng.irange(96, 160) as i32,
        };
        let bias = rng.f32_vec(n);
        let cases: [(I8Epilogue<'_>, Option<&[f32]>, bool); 3] = [
            (I8Epilogue::None, None, false),
            (I8Epilogue::Bias(&bias), Some(&bias), false),
            (I8Epilogue::BiasRelu(&bias), Some(&bias), true),
        ];
        for (epi, rbias, relu) in cases {
            let want = gemm_i8_dequant_reference(&a, &b, m, n, k, &q, rbias, relu);
            let mut got = vec![0f32; m * n];
            let mut scratch = I8Scratch::new();
            gemm_i8_dequant_into(&mut got, &a, &b, m, n, k, &q, epi, Par::Scoped(2), &mut scratch);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "m={m} n={n} k={k} relu={relu} element {i}: {g} vs {w}"
                );
            }
        }
    });
}

#[test]
fn served_int8_bucket_equals_the_quantized_reference_composition() {
    // end to end through the public runtime API: an int8-served MLP
    // bucket (calibration in the meta, quantized dots lowered by the
    // plan compiler) must equal composing the two quantized layers by
    // hand — and must *differ* from the f32 serving path, proving the
    // integer engine actually ran
    use power_mma::runtime::{det_input, mlp_int8_calib, HloPlanBackend, Runtime};
    let dir = std::env::temp_dir(); // nothing is read: the buckets compile from generated text
    let (b, f, h, c) = (6usize, 24usize, 40usize, 12usize);
    let mut rt = Runtime::with_backend(Box::new(HloPlanBackend::int8()), &dir);
    let names = rt.load_mlp_buckets_int8(&[b], f, h, c).unwrap();
    assert_eq!(names, vec![format!("mlp_b{b}")]);
    assert!(rt.meta("mlp_b6").unwrap().calib.is_some(), "the bucket meta must carry the record");

    let calib = mlp_int8_calib(f, h, c);
    let qp = |xn: &str, yn: &str| {
        let (x, y) = (calib.get(xn).unwrap(), calib.get(yn).unwrap());
        assert!(x.signed && !y.signed, "activation feeds X (i8), weight feeds Y (u8)");
        QuantParams { a_scale: x.scale, a_zp: x.zp, b_scale: y.scale, b_zp: y.zp }
    };
    let x = det_input(b * f, 1);
    let w1 = det_input(f * h, 2);
    let b1 = det_input(h, 3);
    let w2 = det_input(h * c, 4);
    let b2 = det_input(c, 5);
    let got = rt.execute("mlp_b6", &[&x, &w1, &b1, &w2, &b2]).unwrap();
    let hid =
        gemm_i8_dequant_reference(&x, &w1, b, h, f, &qp("Arg_0.1", "Arg_1.2"), Some(&b1), true);
    let want =
        gemm_i8_dequant_reference(&hid, &w2, b, c, h, &qp("maximum.14", "Arg_3.4"), Some(&b2), false);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "served vs composed reference, element {i}");
    }

    let mut f32_rt = Runtime::with_backend(Box::new(HloPlanBackend::new()), &dir);
    f32_rt.load_mlp_buckets(&[b], f, h, c).unwrap();
    let exact = f32_rt.execute("mlp_b6", &[&x, &w1, &b1, &w2, &b2]).unwrap();
    assert!(
        got.iter().zip(&exact).any(|(g, e)| g.to_bits() != e.to_bits()),
        "quantization must actually bite"
    );
    let max_err = got
        .iter()
        .zip(&exact)
        .map(|(g, e)| (g - e).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 0.5, "quantized output strayed too far from f32: {max_err}");
}
