//! Edge-numerics coverage for the bf16 packed-panel GEMM engine: NaN
//! payloads, infinities, signed zeros, subnormals, and odd-`k` tails
//! must all flow through pack → rank-2 microkernel → writeback **bitwise
//! identical** to the elementwise-rounding reference (round to the bf16
//! grid, widen exactly, ascending-`k` `f64` accumulation, one narrowing
//! store — the interpreter's `convert → dot` contract), on both the
//! f32-source path (round fused into packing) and the raw-bits path
//! (NaNs canonicalized at pack time). Plus the end-to-end check: the
//! `gemm_bf16` artifact served from raw bf16 storage through the typed
//! device API equals the interpreter oracle bit for bit.

use power_mma::blas::bf16_gemm::{
    gemm_bf16_packed_into, gemm_bf16_reference, Bf16Accum, Bf16Scratch, Bf16Src,
};
use power_mma::blas::block_gemm::Par;
use power_mma::isa::types::{bf16_to_f32, f32_to_bf16_canonical};
use power_mma::testkit::Rng;

fn run_packed(a: Bf16Src<'_>, b: Bf16Src<'_>, m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    let mut scratch = Bf16Scratch::new();
    gemm_bf16_packed_into(&mut c, a, b, m, n, k, Bf16Accum::Widened, Par::Seq, &mut scratch);
    c
}

fn assert_bitwise(name: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{name}: element {i} differs ({g} vs {w})");
    }
}

/// Sprinkle edge values into otherwise-random operands.
fn spiked(rng: &mut Rng, len: usize, spikes: &[f32]) -> Vec<f32> {
    let mut v = rng.f32_vec(len);
    for (i, &s) in spikes.iter().enumerate() {
        let pos = (i * 7 + 3) % len.max(1);
        v[pos] = s;
    }
    v
}

#[test]
fn edge_values_match_the_reference_bitwise() {
    let spikes = [
        f32::NAN,
        f32::from_bits(0x7f81_2345), // signaling NaN with payload
        f32::from_bits(0xffc0_0001), // negative NaN with payload
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0,
        0.0,
        f32::from_bits(0x0000_0001), // smallest f32 subnormal
        f32::from_bits(0x8000_ffff), // negative subnormal
        6.1e-39,
        f32::MAX, // rounds up to bf16 inf
        1e38,
    ];
    let mut rng = Rng::new(0xedbe);
    // shapes straddling the 8x16 microkernel and the odd-k tail
    for &(m, n, k) in &[
        (1usize, 1usize, 1usize),
        (1, 1, 2),
        (3, 5, 7),
        (8, 16, 9),
        (9, 17, 27),
        (4, 40, 31),
    ] {
        let a = spiked(&mut rng, m * k, &spikes);
        let b = spiked(&mut rng, k * n, &spikes);
        let want = gemm_bf16_reference(&a, &b, m, n, k);
        let got = run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), m, n, k);
        assert_bitwise(&format!("f32-src m={m} n={n} k={k}"), &got, &want);
        // the raw-bits path: pre-round (canonical) and hand over bits
        let ab: Vec<u16> = a.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
        let bb: Vec<u16> = b.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
        let got = run_packed(Bf16Src::Bits(&ab), Bf16Src::Bits(&bb), m, n, k);
        assert_bitwise(&format!("bits-src m={m} n={n} k={k}"), &got, &want);
    }
}

#[test]
fn raw_nan_payload_bits_canonicalize_like_the_staged_path() {
    // hand the engine *non-canonical* NaN bf16 bits (payloads, signaling
    // patterns): the packers must canonicalize exactly the way
    // widen-then-round does, so both routes agree bitwise
    let nan_bits: [u16; 4] = [0x7f81, 0x7fff, 0xff90, 0xffc7];
    let (m, n, k) = (2usize, 3usize, 4usize);
    let mut rng = Rng::new(0x4a4);
    let mut ab: Vec<u16> = rng.f32_vec(m * k).iter().map(|&v| f32_to_bf16_canonical(v)).collect();
    let mut bb: Vec<u16> = rng.f32_vec(k * n).iter().map(|&v| f32_to_bf16_canonical(v)).collect();
    ab[1] = nan_bits[0];
    ab[5] = nan_bits[1];
    bb[2] = nan_bits[2];
    bb[7] = nan_bits[3];
    // the staged route: widen the raw bits exactly, let packing re-round
    let aw: Vec<f32> = ab.iter().map(|&b| bf16_to_f32(b)).collect();
    let bw: Vec<f32> = bb.iter().map(|&b| bf16_to_f32(b)).collect();
    let staged = run_packed(Bf16Src::F32(&aw), Bf16Src::F32(&bw), m, n, k);
    let raw = run_packed(Bf16Src::Bits(&ab), Bf16Src::Bits(&bb), m, n, k);
    assert_bitwise("raw vs staged NaN payloads", &raw, &staged);
    // and both equal the reference over the widened values
    assert_bitwise("staged vs reference", &staged, &gemm_bf16_reference(&aw, &bw, m, n, k));
    // NaN actually propagated into the output
    assert!(staged.iter().any(|v| v.is_nan()), "NaN rows must produce NaN outputs");
}

#[test]
fn negative_zero_and_subnormal_flush_contract() {
    // -0.0 products: the accumulator starts at +0.0, so a column of
    // -0.0 products yields +0.0 (IEEE: +0.0 + -0.0 = +0.0) — same as
    // the interpreter's f64 chain, *not* the assigned-first f32 conv
    // chain. Pin it.
    let a = [-1.0f32, -1.0];
    let b = [0.0f32, 0.0];
    let got = run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), 1, 1, 2);
    assert_eq!(got[0].to_bits(), 0.0f32.to_bits(), "+0.0, sign from the f64 chain");
    // subnormal behavior: bf16 rounding does NOT flush — an f32
    // subnormal rounds to the nearest bf16 subnormal (or zero), and the
    // widened product is computed exactly; the engine must agree with
    // the reference on the full subnormal sweep
    let tiny: Vec<f32> = (0..8)
        .map(|i| f32::from_bits(0x0000_0001u32 << i))
        .chain((0..8).map(|i| f32::from_bits(0x8000_0000 | (0x100u32 << i))))
        .collect();
    let scale = [2.0f32.powi(120); 16];
    let want = gemm_bf16_reference(&tiny, &scale, 1, 1, 16);
    let got = run_packed(Bf16Src::F32(&tiny), Bf16Src::F32(&scale), 1, 1, 16);
    assert_bitwise("subnormal sweep", &got, &want);
    // the smallest f32 subnormals underflow to (signed) zero on the
    // bf16 grid; scaled back up they must stay zero, not reappear
    assert_eq!(f32_to_bf16_canonical(f32::from_bits(1)) & 0x7fff, 0);
}

#[test]
fn odd_k_tails_across_the_kc_boundary() {
    // k values that leave every kind of tail: odd within one KC block,
    // odd straddling blocks, exactly one pair short of a block
    use power_mma::blas::block_gemm::KC;
    let mut rng = Rng::new(0x0dd);
    for &k in &[1usize, 3, 15, KC - 1, KC + 1, KC + 3] {
        let (m, n) = (3usize, 19usize);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let want = gemm_bf16_reference(&a, &b, m, n, k);
        let got = run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), m, n, k);
        assert_bitwise(&format!("odd-k {k}"), &got, &want);
    }
}

#[test]
fn served_bf16_artifact_from_raw_bits_equals_the_interpreter() {
    // end to end through the typed device API: raw bf16 storage (with a
    // NaN payload spiked in) served by the plan backend's packed path
    // must equal the interpreter oracle staging the same bits to f32
    use power_mma::runtime::{
        artifacts, det_inputs, Device, HloInterpreterBackend, Runtime, TensorMut, TensorRef,
    };
    let dir = std::env::temp_dir().join(format!("mma-bf16eng-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    artifacts::write_artifacts(&dir).unwrap();
    let device = Device::new(2);
    let plan_backend = Box::new(power_mma::runtime::HloPlanBackend::new());
    let mut plan_rt = Runtime::with_device(device.clone(), plan_backend, &dir);
    let mut oracle_rt =
        Runtime::with_device(device.clone(), Box::new(HloInterpreterBackend), &dir);
    plan_rt.load("gemm_bf16").unwrap();
    oracle_rt.load("gemm_bf16").unwrap();
    let meta = plan_rt.meta("gemm_bf16").unwrap().clone();
    let mut bits: Vec<Vec<u16>> = det_inputs(&meta)
        .iter()
        .map(|v| v.iter().map(|&x| f32_to_bf16_canonical(x)).collect())
        .collect();
    bits[0][7] = 0x7f99; // non-canonical NaN payload
    bits[1][3] = 0xff80; // -inf
    let trefs: Vec<TensorRef<'_>> = bits
        .iter()
        .zip(&meta.input_shapes)
        .map(|(d, s)| TensorRef::bf16(d, s))
        .collect();
    let mut ctx = device.ctx();
    let mut via_plan = vec![0f32; meta.output_len()];
    let mut out = TensorMut::f32(&mut via_plan, &meta.output_shape);
    plan_rt.execute_typed("gemm_bf16", &mut ctx, &trefs, &mut out).unwrap();
    let mut via_oracle = vec![0f32; meta.output_len()];
    let mut out = TensorMut::f32(&mut via_oracle, &meta.output_shape);
    oracle_rt.execute_typed("gemm_bf16", &mut ctx, &trefs, &mut out).unwrap();
    assert_bitwise("plan vs interpreter on raw bf16 bits", &via_plan, &via_oracle);
    assert!(via_plan.iter().any(|v| v.is_nan()), "the NaN input must reach the output");
    std::fs::remove_dir_all(&dir).ok();
}
