//! Integration coverage for the device/session execution API: typed
//! tensors ([`TensorRef`]/[`TensorMut`]) through [`Runtime::execute_typed`]
//! on an explicit [`Device`] (persistent pool), bitwise-checked against
//! the untyped compat shim and the interpreter oracle, plus bf16-typed
//! buffers end to end.

use power_mma::runtime::{
    artifacts, bf16_to_f32, det_inputs, f32_to_bf16, Device, HloInterpreterBackend, Runtime,
    TensorMut, TensorRef,
};

/// Materialize the embedded artifact set once per test process.
fn artifact_dir() -> std::path::PathBuf {
    static DIR: std::sync::OnceLock<std::path::PathBuf> = std::sync::OnceLock::new();
    DIR.get_or_init(|| {
        let dir =
            std::env::temp_dir().join(format!("power-mma-device-artifacts-{}", std::process::id()));
        artifacts::write_artifacts(&dir).expect("materialize embedded artifacts");
        dir
    })
    .clone()
}

fn assert_bits_eq(what: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} ({x} vs {y})");
    }
}

/// The typed path on an explicit pooled device must match both the
/// untyped compat shim and the interpreter oracle bit for bit, on every
/// embedded fixture, across repeated requests through one reused ctx.
#[test]
fn typed_pooled_execution_matches_shim_and_interpreter() {
    let dir = artifact_dir();
    let device = Device::new(3); // explicit small pool, distinct from shared()
    let backend = Box::new(power_mma::runtime::HloPlanBackend::new());
    let mut rt = Runtime::with_device(device.clone(), backend, &dir);
    let names = rt.load_all().unwrap();
    let mut oracle = Runtime::with_backend(Box::new(HloInterpreterBackend), &dir);
    oracle.load_all().unwrap();
    let mut ctx = device.ctx();
    for name in &names {
        let meta = rt.meta(name).unwrap().clone();
        let inputs = det_inputs(&meta);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let shim = rt.execute(name, &refs).unwrap();
        let want = oracle.execute(name, &refs).unwrap();
        for round in 0..2 {
            let trefs: Vec<TensorRef<'_>> = inputs
                .iter()
                .zip(&meta.input_shapes)
                .map(|(d, s)| TensorRef::f32(d, s))
                .collect();
            let mut typed = vec![0f32; meta.output_len()];
            let mut out = TensorMut::f32(&mut typed, &meta.output_shape);
            rt.execute_typed(name, &mut ctx, &trefs, &mut out).unwrap();
            assert_bits_eq(&format!("{name} typed-vs-shim round {round}"), &typed, &shim);
            assert_bits_eq(&format!("{name} typed-vs-oracle round {round}"), &typed, &want);
        }
    }
}

/// Typed validation catches what the untyped API could not: wrong dims
/// with the right element count, wrong input count, wrong output shape.
#[test]
fn typed_validation_rejects_shape_mismatches() {
    let dir = artifact_dir();
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load("gemm_f32").unwrap();
    let meta = rt.meta("gemm_f32").unwrap().clone();
    let inputs = det_inputs(&meta);
    let device = rt.device().clone();
    let mut ctx = device.ctx();
    let mut result = vec![0f32; meta.output_len()];

    // transposed dims: same element count, different shape -> rejected
    let n = meta.input_shapes[0][0];
    let transposed = vec![n * 2, n / 2];
    let bad: Vec<TensorRef<'_>> =
        inputs.iter().map(|d| TensorRef::f32(d, &transposed)).collect();
    let mut out = TensorMut::f32(&mut result, &meta.output_shape);
    let e = rt.execute_typed("gemm_f32", &mut ctx, &bad, &mut out).unwrap_err().to_string();
    assert!(e.contains("dims"), "{e}");

    // wrong input count
    let good: Vec<TensorRef<'_>> = inputs
        .iter()
        .zip(&meta.input_shapes)
        .map(|(d, s)| TensorRef::f32(d, s))
        .collect();
    let mut out = TensorMut::f32(&mut result, &meta.output_shape);
    assert!(rt.execute_typed("gemm_f32", &mut ctx, &good[..1], &mut out).is_err());

    // wrong output shape
    let bad_odims = vec![1usize];
    let mut short = vec![0f32; 1];
    let mut out = TensorMut::f32(&mut short, &bad_odims);
    assert!(rt.execute_typed("gemm_f32", &mut ctx, &good, &mut out).is_err());
}

/// bf16 tensors end to end: bf16 inputs are widened exactly (equal to
/// pre-rounding on the caller side), bf16 outputs round on store, and
/// the gemm_bf16 artifact — whose HLO converts to bf16 internally —
/// accepts bf16 storage without the caller round-tripping through f32.
#[test]
fn bf16_typed_tensors_round_trip() {
    let dir = artifact_dir();
    let mut rt = Runtime::cpu(&dir).unwrap();
    rt.load("gemm_bf16").unwrap();
    let meta = rt.meta("gemm_bf16").unwrap().clone();
    let inputs = det_inputs(&meta);
    let device = rt.device().clone();
    let mut ctx = device.ctx();

    // path A: caller pre-rounds to the bf16 grid, feeds f32
    let widened: Vec<Vec<f32>> = inputs
        .iter()
        .map(|v| v.iter().map(|&x| bf16_to_f32(f32_to_bf16(x))).collect())
        .collect();
    let refs: Vec<&[f32]> = widened.iter().map(|v| v.as_slice()).collect();
    let via_f32 = rt.execute("gemm_bf16", &refs).unwrap();

    // path B: caller hands over raw bf16 bits
    let bits: Vec<Vec<u16>> =
        inputs.iter().map(|v| v.iter().map(|&x| f32_to_bf16(x)).collect()).collect();
    let trefs: Vec<TensorRef<'_>> = bits
        .iter()
        .zip(&meta.input_shapes)
        .map(|(d, s)| TensorRef::bf16(d, s))
        .collect();
    let mut via_bf16 = vec![0f32; meta.output_len()];
    let mut out = TensorMut::f32(&mut via_bf16, &meta.output_shape);
    rt.execute_typed("gemm_bf16", &mut ctx, &trefs, &mut out).unwrap();
    assert_bits_eq("bf16-in vs prerounded-f32-in", &via_bf16, &via_f32);

    // bf16 output storage: every element equals the rounded f32 result
    let mut hout = vec![0u16; meta.output_len()];
    let mut out = TensorMut::bf16(&mut hout, &meta.output_shape);
    rt.execute_typed("gemm_bf16", &mut ctx, &trefs, &mut out).unwrap();
    for (i, (&h, &v)) in hout.iter().zip(&via_bf16).enumerate() {
        assert_eq!(h, f32_to_bf16(v), "output element {i}");
    }
}

/// Two runtimes sharing one device share its pool; a runtime created
/// via `cpu()` uses the process-shared device.
#[test]
fn runtimes_share_devices() {
    let dir = artifact_dir();
    let device = Device::new(2);
    let rt1 = Runtime::with_device(
        device.clone(),
        Box::new(power_mma::runtime::HloPlanBackend::new()),
        &dir,
    );
    let rt2 = Runtime::with_device(
        device.clone(),
        Box::new(power_mma::runtime::HloPlanBackend::new()),
        &dir,
    );
    assert!(std::sync::Arc::ptr_eq(rt1.device(), rt2.device()));
    assert_eq!(rt1.device().threads(), 2);
    let shared = Runtime::cpu(&dir).unwrap();
    assert!(std::sync::Arc::ptr_eq(shared.device(), &Device::shared()));
}
