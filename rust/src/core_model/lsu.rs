//! Load/store unit memory-latency model: a two-level cache with a
//! stream prefetcher (POWER cores prefetch ascending streams aggressively,
//! which is what lets the paper's kernels stream X/Y panels at L1 latency).

use crate::core_model::config::MachineConfig;

const NUM_STREAMS: usize = 8;

/// Per-access latency model. Tags only (no data): direct-mapped L1 and
/// 8-way-ish hashed L2, plus an ascending-stream detector that services
/// detected streams at L1 latency.
pub struct CacheModel {
    line: usize,
    l1_sets: usize,
    l2_sets: usize,
    l1_tags: Vec<u64>,
    l2_tags: Vec<u64>,
    l1_latency: u32,
    l2_latency: u32,
    mem_latency: u32,
    streams: [u64; NUM_STREAMS], // next expected line address per stream
    next_stream: usize,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub misses: u64,
    pub prefetched: u64,
}

impl CacheModel {
    pub fn new(cfg: &MachineConfig) -> Self {
        let l1_sets = cfg.l1_bytes / cfg.line_bytes;
        let l2_sets = cfg.l2_bytes / cfg.line_bytes;
        CacheModel {
            line: cfg.line_bytes,
            l1_sets,
            l2_sets,
            l1_tags: vec![u64::MAX; l1_sets],
            l2_tags: vec![u64::MAX; l2_sets],
            l1_latency: cfg.l1_latency,
            l2_latency: cfg.l2_latency,
            mem_latency: cfg.mem_latency,
            streams: [u64::MAX; NUM_STREAMS],
            next_stream: 0,
            l1_hits: 0,
            l2_hits: 0,
            misses: 0,
            prefetched: 0,
        }
    }

    /// Latency (cycles) of an access at byte address `addr`.
    pub fn access(&mut self, addr: u64) -> u32 {
        let line_addr = addr / self.line as u64;
        let l1_idx = (line_addr as usize) % self.l1_sets;
        let l2_idx = (line_addr as usize) % self.l2_sets;

        // stream detection: an access to the expected next line of a
        // tracked stream is treated as prefetched (L1 latency) and advances
        // the stream
        let mut streamed = false;
        for s in self.streams.iter_mut() {
            if *s == line_addr {
                *s = line_addr + 1;
                streamed = true;
                break;
            }
        }

        let lat = if self.l1_tags[l1_idx] == line_addr {
            self.l1_hits += 1;
            self.l1_latency
        } else if streamed {
            self.prefetched += 1;
            self.l1_latency
        } else if self.l2_tags[l2_idx] == line_addr {
            self.l2_hits += 1;
            self.l2_latency
        } else {
            self.misses += 1;
            // allocate a new stream on a demand miss
            self.streams[self.next_stream] = line_addr + 1;
            self.next_stream = (self.next_stream + 1) % NUM_STREAMS;
            self.mem_latency
        };
        self.l1_tags[l1_idx] = line_addr;
        self.l2_tags[l2_idx] = line_addr;
        lat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CacheModel {
        CacheModel::new(&MachineConfig::power10())
    }

    #[test]
    fn repeated_access_hits_l1() {
        let mut c = model();
        let cold = c.access(0x1000);
        let warm = c.access(0x1000);
        assert!(cold > warm);
        assert_eq!(warm, 4);
    }

    #[test]
    fn sequential_stream_prefetches() {
        let mut c = model();
        c.access(0); // cold miss allocates the stream
        let mut slow = 0;
        for i in 1..64u64 {
            if c.access(i * 128) > 4 {
                slow += 1;
            }
        }
        assert_eq!(slow, 0, "ascending stream must run at L1 latency");
        assert!(c.prefetched > 50);
    }

    #[test]
    fn random_far_accesses_miss() {
        let mut c = model();
        let mut total = 0u64;
        // strided by 1MB+line so neither cache nor streams help
        for i in 0..16u64 {
            total += u64::from(c.access(i * (1 << 20) + i * 128));
        }
        assert!(total >= 16 * 100, "far scattered accesses pay memory latency");
    }

    #[test]
    fn l2_catches_l1_evictions() {
        let mut c = model();
        // two lines that conflict in L1 (32KB apart) but not in L2; defeat
        // the stream detector by alternating
        c.access(0);
        c.access(32 * 1024);
        c.access(64 * 1024);
        c.access(0);
        let lat = c.access(32 * 1024);
        assert_eq!(lat, 13, "L1-conflicting line should hit in L2");
    }
}
