//! Event-based power model (paper §VII).
//!
//! The paper evaluates power with "a simulation-based IBM internal power
//! methodology": run the same code through a pre-silicon model, capture
//! 5000-instruction windows, evaluate the power draw in each, average
//! across windows, and report CORE-without-MME, MME, and TOTAL.
//!
//! This model mirrors that methodology over the timing simulator's event
//! stream: each issued µop contributes class-specific dynamic energy to its
//! unit (front end, VSU, MME, LSU, FXU), each cycle contributes static
//! power, and the run is chopped into 5000-instruction windows whose
//! per-window power is averaged. All values are in arbitrary *power units*
//! calibrated so that the Figure 12 ratios hold (see EXPERIMENTS.md §Fig12
//! for the calibration); absolute watts are not claimed.

use crate::core_model::config::MachineConfig;

/// Window size of the §VII methodology.
pub const WINDOW_INSTS: u64 = 5000;

/// Energy/power result of one simulated run.
#[derive(Clone, Debug, Default)]
pub struct EnergyReport {
    /// Dynamic energy in the core excluding the MME.
    pub core_dynamic: f64,
    /// Dynamic energy in the MME.
    pub mme_dynamic: f64,
    /// Static energy, core excluding MME.
    pub core_static: f64,
    /// Static energy, MME (0 if gated).
    pub mme_static: f64,
    /// Average power (energy/cycle) of the core without the MME, averaged
    /// over 5000-instruction windows (the Figure 12 "CORE w/o MME" bar).
    pub core_power: f64,
    /// Figure 12 "MME" bar.
    pub mme_power: f64,
    /// Figure 12 "TOTAL" bar.
    pub total_power: f64,
    /// Number of full windows measured.
    pub windows: usize,
}

/// Accumulates per-class energy during a run.
pub struct PowerModel {
    e_frontend: f64,
    e_vsu: f64,
    e_mma: f64,
    e_lsu: f64,
    e_fx: f64,
    p_static_core: f64,
    p_static_mme: f64,
    scale: f64,
    /// When true, the MME draws no static power while unused (§VII's
    /// power-gating comparison).
    pub mme_gated: bool,
    // per-run accumulation
    core_dyn: f64,
    mme_dyn: f64,
    mme_used: bool,
    // windowing: (insts_boundary, core_dyn, mme_dyn) snapshots
    window_marks: Vec<(u64, f64, f64)>,
}

impl PowerModel {
    pub fn new(cfg: &MachineConfig) -> Self {
        PowerModel {
            e_frontend: cfg.e_frontend,
            e_vsu: cfg.e_vsu_op,
            e_mma: cfg.e_mma_op,
            e_lsu: cfg.e_lsu_op,
            e_fx: cfg.e_fx_op,
            p_static_core: cfg.p_static_core,
            p_static_mme: cfg.p_static_mme,
            scale: cfg.tech_scale,
            mme_gated: false,
            core_dyn: 0.0,
            mme_dyn: 0.0,
            mme_used: false,
            window_marks: Vec::new(),
        }
    }

    pub fn begin_run(&mut self) {
        self.core_dyn = 0.0;
        self.mme_dyn = 0.0;
        self.mme_used = false;
        self.window_marks.clear();
    }

    /// Front-end energy for each dispatched instruction; also snapshots
    /// window boundaries every [`WINDOW_INSTS`] instructions.
    pub fn frontend(&mut self, inst_count: u64) {
        self.core_dyn += self.e_frontend * self.scale;
        if inst_count % WINDOW_INSTS == 0 {
            self.window_marks.push((inst_count, self.core_dyn, self.mme_dyn));
        }
    }

    pub fn vsu_op(&mut self, weight: f64) {
        self.core_dyn += self.e_vsu * weight * self.scale;
    }

    pub fn mma_op(&mut self, weight: f64) {
        self.mme_dyn += self.e_mma * weight * self.scale;
        self.mme_used = true;
    }

    pub fn lsu_op(&mut self) {
        self.core_dyn += self.e_lsu * self.scale;
    }

    pub fn fx_op(&mut self) {
        self.core_dyn += self.e_fx * self.scale;
    }

    /// Close the run: fold in static energy and compute window-averaged
    /// power. `cycles` is the run length from the timing model.
    pub fn finish(&mut self, cycles: u64, instructions: u64) -> EnergyReport {
        let mme_static_per_cycle = if self.p_static_mme == 0.0 || (self.mme_gated && !self.mme_used) {
            0.0
        } else {
            self.p_static_mme * self.scale
        };
        let core_static_per_cycle = self.p_static_core * self.scale;
        let core_static = core_static_per_cycle * cycles as f64;
        let mme_static = mme_static_per_cycle * cycles as f64;

        // window-averaged power: dynamic energy per window / cycles per
        // window (approximated as cycles scaled by the window's share of
        // instructions — the IPC within these kernels is steady), plus the
        // static component.
        let windows = self.window_marks.len();
        let (core_power, mme_power) = if windows >= 2 {
            let mut core_acc = 0.0;
            let mut mme_acc = 0.0;
            let cycles_per_inst = cycles as f64 / instructions.max(1) as f64;
            for w in 1..windows {
                let (i0, c0, m0) = self.window_marks[w - 1];
                let (i1, c1, m1) = self.window_marks[w];
                let wcycles = (i1 - i0) as f64 * cycles_per_inst;
                core_acc += (c1 - c0) / wcycles;
                mme_acc += (m1 - m0) / wcycles;
            }
            (core_acc / (windows - 1) as f64, mme_acc / (windows - 1) as f64)
        } else {
            (self.core_dyn / cycles.max(1) as f64, self.mme_dyn / cycles.max(1) as f64)
        };
        let core_power = core_power + core_static_per_cycle;
        let mme_power = mme_power + mme_static_per_cycle;
        EnergyReport {
            core_dynamic: self.core_dyn,
            mme_dynamic: self.mme_dyn,
            core_static,
            mme_static,
            core_power,
            mme_power,
            total_power: core_power + mme_power,
            windows: windows.saturating_sub(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core_model::{CoreSim, MachineConfig};
    use crate::kernels::dgemm::dgemm_8xnx8_program;
    use crate::kernels::vsx::vsx_dgemm_8x4_program;

    /// Run the paper's 128x128 DGEMM workload shape on a configuration.
    fn run_dgemm(sim: &mut CoreSim, mma: bool) -> crate::core_model::sched::SimReport {
        if mma {
            sim.run(&dgemm_8xnx8_program(128), 1 << 22)
        } else {
            sim.run(&vsx_dgemm_8x4_program(128), 1 << 22)
        }
    }

    #[test]
    fn fig12_mma_vs_vsx_power_ratio() {
        // §VII: "the POWER10 core running MMA code delivers 2.5x the
        // performance ... while drawing only 8% more power" (12% with the
        // MME gated during VSX runs). Accept a generous band.
        let mut sim_v = CoreSim::new(MachineConfig::power10());
        let rv = run_dgemm(&mut sim_v, false);
        let mut sim_m = CoreSim::new(MachineConfig::power10());
        let rm = run_dgemm(&mut sim_m, true);
        let ratio = rm.energy.total_power / rv.energy.total_power;
        assert!(
            (1.02..1.25).contains(&ratio),
            "MMA/VSX total power ratio {ratio:.3} (paper: ~1.08)"
        );
        // and the MME accounts for a visible but minority share
        let share = rm.energy.mme_power / rm.energy.total_power;
        assert!((0.05..0.45).contains(&share), "MME power share {share:.3}");
    }

    #[test]
    fn fig12_gating_increases_the_gap() {
        let mut ungated = CoreSim::new(MachineConfig::power10());
        let r_ungated = run_dgemm(&mut ungated, false);
        let mut gated = CoreSim::new(MachineConfig::power10());
        gated.set_mme_gated(true);
        let r_gated = run_dgemm(&mut gated, false);
        assert!(
            r_gated.energy.total_power < r_ungated.energy.total_power,
            "gating the idle MME must reduce VSX-run power"
        );
        assert_eq!(r_gated.energy.mme_power, 0.0);
    }

    #[test]
    fn fig12_p9_draws_more_than_p10() {
        // §VII: P10-MMA achieves 5x P9 performance at ~24% less power
        let mut p9 = CoreSim::new(MachineConfig::power9());
        let r9 = run_dgemm(&mut p9, false);
        let mut p10 = CoreSim::new(MachineConfig::power10());
        let r10 = run_dgemm(&mut p10, true);
        assert!(
            r10.energy.total_power < r9.energy.total_power,
            "P10-MMA ({:.2}) must draw less than P9 ({:.2})",
            r10.energy.total_power,
            r9.energy.total_power
        );
        // energy per flop: ~7x better (§VII "almost 7x reduction on energy
        // per computation"); accept 4x..12x
        let e9 = r9.energy.total_power / r9.flops_per_cycle();
        let e10 = r10.energy.total_power / r10.flops_per_cycle();
        let gain = e9 / e10;
        assert!((4.0..12.0).contains(&gain), "energy/flop gain {gain:.2} (paper ~6.8x)");
    }

    #[test]
    fn windows_are_measured() {
        let mut sim = CoreSim::new(MachineConfig::power10());
        let r = sim.run(&dgemm_8xnx8_program(2048), 1 << 22);
        assert!(r.energy.windows >= 5, "long runs must span multiple 5000-inst windows");
    }

    #[test]
    fn p9_has_no_mme_power() {
        let mut p9 = CoreSim::new(MachineConfig::power9());
        let r = run_dgemm(&mut p9, false);
        assert_eq!(r.energy.mme_power, 0.0);
        assert_eq!(r.energy.mme_dynamic, 0.0);
    }
}
