//! The timing simulator: a dataflow + resource model of the POWER9/POWER10
//! backend running real instruction streams.
//!
//! For every dynamic instruction the simulator computes the earliest issue
//! cycle consistent with (1) front-end dispatch bandwidth (plus a taken-
//! branch redirect bubble), (2) source-operand readiness, (3) a free
//! execution resource (VSU pipe / MME pipe / LSU port / FXU), and (4)
//! memory latency from the cache model. This "greedy list scheduling"
//! approximates a balanced out-of-order core well for the loop-dominated
//! kernels of the paper, at ~10⁷–10⁸ instructions/second of simulation.
//!
//! The simulator interprets GPR/CTR values (needed for addresses and the
//! CTR loop) but does not touch vector data — numerics live in
//! [`crate::isa::Machine`], which runs the *same* streams.

use crate::core_model::config::MachineConfig;
use crate::core_model::lsu::CacheModel;
use crate::core_model::power::{EnergyReport, PowerModel};
use crate::isa::inst::{GerKind, Inst};

/// Per-unit-class busy counters and stall attribution.
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitStats {
    pub vsu_ops: u64,
    pub mma_ops: u64,
    pub lsu_ops: u64,
    pub fx_ops: u64,
    pub branches: u64,
}

/// Result of one timing simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub name: &'static str,
    pub cycles: u64,
    pub instructions: u64,
    pub flops: u64,
    pub units: UnitStats,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub mem_misses: u64,
    /// Energy by component and average power (see [`PowerModel`]).
    pub energy: EnergyReport,
}

impl SimReport {
    pub fn flops_per_cycle(&self) -> f64 {
        self.flops as f64 / self.cycles.max(1) as f64
    }

    pub fn ipc(&self) -> f64 {
        self.instructions as f64 / self.cycles.max(1) as f64
    }

    /// Busy fraction of a unit class with `n` instances over the run
    /// (each op occupies one instance-cycle).
    fn util(ops: u64, n: u32, cycles: u64) -> f64 {
        if n == 0 || cycles == 0 {
            0.0
        } else {
            ops as f64 / (n as f64 * cycles as f64)
        }
    }

    /// Per-unit utilization `(vsu, mme, lsu, fxu)` given the machine the
    /// run used — the profile view of Figure 2's backend.
    pub fn utilization(&self, cfg: &MachineConfig) -> (f64, f64, f64, f64) {
        (
            Self::util(self.units.vsu_ops, cfg.vsu_pipes, self.cycles),
            Self::util(self.units.mma_ops, cfg.mma_pipes, self.cycles),
            Self::util(self.units.lsu_ops, cfg.lsu_ports, self.cycles),
            Self::util(self.units.fx_ops, cfg.fxu_units, self.cycles),
        )
    }

    /// Named per-resource occupancies, in a fixed order — the
    /// machine-readable form of [`SimReport::utilization`] consumed by
    /// the roofline layer ([`crate::runtime::profile`]): each entry is
    /// `(unit class, busy fraction in [0, 1])`.
    pub fn occupancies(&self, cfg: &MachineConfig) -> [(&'static str, f64); 4] {
        let (v, m, l, f) = self.utilization(cfg);
        [("vsu", v), ("mme", m), ("lsu", l), ("fxu", f)]
    }

    /// The unit class that bounds this run (highest utilization) — the
    /// "top bottleneck" pointer of the §Perf process.
    pub fn bottleneck(&self, cfg: &MachineConfig) -> (&'static str, f64) {
        let (v, m, l, f) = self.utilization(cfg);
        let mut best = ("vsu", v);
        for cand in [("mme", m), ("lsu", l), ("fxu", f)] {
            if cand.1 > best.1 {
                best = cand;
            }
        }
        best
    }
}

/// The timing simulator. Reusable across programs: architectural timing
/// state resets per [`CoreSim::run`], cache contents persist (matching a
/// warm cache across kernel invocations, as in the paper's measurement
/// loop).
pub struct CoreSim {
    pub cfg: MachineConfig,
    cache: CacheModel,
    power: PowerModel,
    /// Taken-branch front-end redirect bubble (cycles).
    redirect_penalty: u64,
    /// Initial GPR values for the next run (addressing bases).
    pub gpr: [u64; 32],
}

struct TimingState {
    vsr_ready: [u64; 64],
    acc_ready: [u64; 8],
    gpr_ready: [u64; 32],
    ctr_ready: u64,
    vsu_free: Vec<u64>,
    mma_free: Vec<u64>,
    lsu_free: Vec<u64>,
    fxu_free: Vec<u64>,
    /// Next cycle the front end can dispatch from, and slots left in it.
    dispatch_cycle: u64,
    dispatch_slots: u32,
    horizon: u64,
}

impl Default for TimingState {
    fn default() -> Self {
        TimingState {
            vsr_ready: [0; 64],
            acc_ready: [0; 8],
            gpr_ready: [0; 32],
            ctr_ready: 0,
            vsu_free: Vec::new(),
            mma_free: Vec::new(),
            lsu_free: Vec::new(),
            fxu_free: Vec::new(),
            dispatch_cycle: 0,
            dispatch_slots: 0,
            horizon: 0,
        }
    }
}

fn alloc_unit(frees: &mut [u64], ready: u64) -> u64 {
    // earliest-free instance; issue at max(ready, free); busy for 1 cycle
    let (idx, &free) =
        frees.iter().enumerate().min_by_key(|(_, &f)| f).expect("unit class with no instances");
    let issue = ready.max(free);
    frees[idx] = issue + 1;
    issue
}

impl CoreSim {
    pub fn new(cfg: MachineConfig) -> Self {
        let cache = CacheModel::new(&cfg);
        let power = PowerModel::new(&cfg);
        let redirect_penalty = if cfg.mma_pipes > 0 { 1 } else { 2 };
        CoreSim { cfg, cache, power, redirect_penalty, gpr: [0; 32] }
    }

    /// Enable/disable MME power gating for subsequent runs (§VII: "when the
    /// MME unit is power gated ... when running the VSX code").
    pub fn set_mme_gated(&mut self, gated: bool) {
        self.power.mme_gated = gated;
    }

    /// Simulate one program to `blr` and return the timing/energy report.
    /// `fuel` bounds dynamic instructions.
    pub fn run(&mut self, prog: &[Inst], fuel: u64) -> SimReport {
        // instruction byte offsets for bdnz targets; branch targets are
        // resolved once up front (§Perf: no search on the hot path)
        let mut offsets = Vec::with_capacity(prog.len() + 1);
        let mut off = 0u64;
        for i in prog {
            offsets.push(off);
            off += u64::from(i.size());
        }
        offsets.push(off);
        let mut targets: Vec<usize> = vec![usize::MAX; prog.len()];
        for (i, inst) in prog.iter().enumerate() {
            if let Inst::Bdnz { bd } = inst {
                let target = offsets[i].wrapping_add(*bd as i64 as u64);
                targets[i] = offsets
                    .binary_search(&target)
                    .expect("bdnz target not an instruction boundary");
            }
        }

        let cfg = &self.cfg;
        let mut st = TimingState {
            vsu_free: vec![0; cfg.vsu_pipes as usize],
            mma_free: vec![0; cfg.mma_pipes.max(1) as usize],
            lsu_free: vec![0; cfg.lsu_ports as usize],
            fxu_free: vec![0; cfg.fxu_units as usize],
            dispatch_slots: cfg.dispatch_width,
            ..Default::default()
        };
        if cfg.mma_pipes == 0 {
            // no MME: an MMA instruction in the stream is a config error
            st.mma_free.clear();
        }
        let mut gpr = self.gpr;
        let mut ctr = 0u64;
        let mut units = UnitStats::default();
        let mut instructions = 0u64;
        let mut flops = 0u64;
        self.power.begin_run();
        let (l1_0, l2_0, mm_0) = (self.cache.l1_hits, self.cache.l2_hits, self.cache.misses);

        let mut idx = 0usize;
        while idx < prog.len() {
            if instructions >= fuel {
                panic!("CoreSim: fuel exhausted after {instructions} instructions (missing blr?)");
            }
            let inst = &prog[idx];
            instructions += 1;

            // ---- front-end dispatch ----
            if st.dispatch_slots == 0 {
                st.dispatch_cycle += 1;
                st.dispatch_slots = cfg.dispatch_width;
            }
            st.dispatch_slots -= 1;
            let disp = st.dispatch_cycle;
            self.power.frontend(instructions);

            let advance = |issue_end: u64, st: &mut TimingState| {
                st.horizon = st.horizon.max(issue_end);
            };

            match *inst {
                Inst::Blr => {
                    advance(disp, &mut st);
                    break;
                }
                Inst::Bdnz { .. } => {
                    units.branches += 1;
                    let issue = disp.max(st.ctr_ready);
                    ctr = ctr.wrapping_sub(1);
                    advance(issue, &mut st);
                    if ctr != 0 {
                        idx = targets[idx];
                        // taken-branch redirect bubble
                        st.dispatch_cycle = issue.max(st.dispatch_cycle) + self.redirect_penalty;
                        st.dispatch_slots = cfg.dispatch_width;
                        continue;
                    }
                }
                Inst::Addi { rt, ra, si } => {
                    units.fx_ops += 1;
                    self.power.fx_op();
                    let ready = disp.max(if ra == 0 { 0 } else { st.gpr_ready[ra as usize] });
                    let issue = alloc_unit(&mut st.fxu_free, ready);
                    let base = if ra == 0 { 0 } else { gpr[ra as usize] };
                    gpr[rt as usize] = base.wrapping_add(si as i64 as u64);
                    st.gpr_ready[rt as usize] = issue + u64::from(cfg.fx_latency);
                    advance(issue + u64::from(cfg.fx_latency), &mut st);
                }
                Inst::Mtctr { rs } => {
                    units.fx_ops += 1;
                    self.power.fx_op();
                    let ready = disp.max(st.gpr_ready[rs as usize]);
                    let issue = alloc_unit(&mut st.fxu_free, ready);
                    ctr = gpr[rs as usize];
                    st.ctr_ready = issue + u64::from(cfg.fx_latency);
                    advance(st.ctr_ready, &mut st);
                }
                Inst::Lxv { xt, ra, dq } | Inst::Lxvp { xtp: xt, ra, dq } => {
                    units.lsu_ops += 1;
                    self.power.lsu_op();
                    let ready = disp.max(st.gpr_ready[ra as usize]);
                    let issue = alloc_unit(&mut st.lsu_free, ready);
                    let addr = gpr[ra as usize].wrapping_add(dq as i64 as u64);
                    let lat = u64::from(self.cache.access(addr));
                    let done = issue + lat;
                    st.vsr_ready[xt as usize] = done;
                    if matches!(inst, Inst::Lxvp { .. }) {
                        st.vsr_ready[xt as usize + 1] = done;
                    }
                    advance(done, &mut st);
                }
                Inst::Stxv { xs, ra, dq } | Inst::Stxvp { xsp: xs, ra, dq } => {
                    units.lsu_ops += 1;
                    self.power.lsu_op();
                    let mut ready = disp.max(st.gpr_ready[ra as usize]).max(st.vsr_ready[xs as usize]);
                    if matches!(inst, Inst::Stxvp { .. }) {
                        ready = ready.max(st.vsr_ready[xs as usize + 1]);
                    }
                    let issue = alloc_unit(&mut st.lsu_free, ready);
                    let addr = gpr[ra as usize].wrapping_add(dq as i64 as u64);
                    let _ = self.cache.access(addr);
                    advance(issue + 1, &mut st);
                }
                Inst::XvMaddaDp { xt, xa, xb } | Inst::XvMaddaSp { xt, xa, xb } => {
                    units.vsu_ops += 1;
                    self.power.vsu_op(1.0);
                    flops += inst.flops();
                    let ready = disp
                        .max(st.vsr_ready[xt as usize])
                        .max(st.vsr_ready[xa as usize])
                        .max(st.vsr_ready[xb as usize]);
                    let issue = alloc_unit(&mut st.vsu_free, ready);
                    st.vsr_ready[xt as usize] = issue + u64::from(cfg.fma_latency);
                    advance(st.vsr_ready[xt as usize], &mut st);
                }
                Inst::XxSpltd { xt, xa, .. } | Inst::XxSpltw { xt, xa, .. } => {
                    units.vsu_ops += 1;
                    self.power.vsu_op(0.5);
                    let ready = disp.max(st.vsr_ready[xa as usize]);
                    let issue = alloc_unit(&mut st.vsu_free, ready);
                    st.vsr_ready[xt as usize] = issue + u64::from(cfg.perm_latency);
                    advance(st.vsr_ready[xt as usize], &mut st);
                }
                Inst::Xxlor { xt, xa, xb } | Inst::Xxlxor { xt, xa, xb } => {
                    units.vsu_ops += 1;
                    self.power.vsu_op(0.4);
                    let ready = disp.max(st.vsr_ready[xa as usize]).max(st.vsr_ready[xb as usize]);
                    let issue = alloc_unit(&mut st.vsu_free, ready);
                    st.vsr_ready[xt as usize] = issue + u64::from(cfg.perm_latency);
                    advance(st.vsr_ready[xt as usize], &mut st);
                }
                Inst::Ger(ref g) => {
                    assert!(
                        !st.mma_free.is_empty(),
                        "MMA instruction on a machine without an MME ({})",
                        cfg.name
                    );
                    units.mma_ops += 1;
                    let f = inst.flops();
                    flops += f;
                    self.power.mma_op(f as f64 / g.kind.flops().max(1) as f64);
                    let mut ready = disp.max(st.vsr_ready[g.xa as usize]).max(st.vsr_ready[g.yb as usize]);
                    if g.kind == GerKind::F64Ger {
                        ready = ready.max(st.vsr_ready[g.xa as usize + 1]);
                    }
                    if g.op.accumulates() {
                        ready = ready.max(st.acc_ready[g.acc as usize]);
                    }
                    let issue = alloc_unit(&mut st.mma_free, ready);
                    st.acc_ready[g.acc as usize] = issue + u64::from(cfg.ger_acc_latency);
                    advance(st.acc_ready[g.acc as usize], &mut st);
                }
                Inst::XxSetAccZ { acc } => {
                    assert!(!st.mma_free.is_empty(), "MMA instruction without an MME");
                    units.mma_ops += 1;
                    self.power.mma_op(0.1);
                    let issue = alloc_unit(&mut st.mma_free, disp);
                    st.acc_ready[acc as usize] = issue + 1;
                    advance(issue + 1, &mut st);
                }
                Inst::XxMtAcc { acc } => {
                    assert!(!st.mma_free.is_empty(), "MMA instruction without an MME");
                    units.mma_ops += 1;
                    self.power.mma_op(0.2);
                    let mut ready = disp;
                    for r in 0..4 {
                        ready = ready.max(st.vsr_ready[acc as usize * 4 + r]);
                    }
                    // "two cycles to transfer four VSRs to an accumulator"
                    let issue = alloc_unit(&mut st.mma_free, ready);
                    let done = issue + u64::from(cfg.mtacc_cycles);
                    st.acc_ready[acc as usize] = done;
                    advance(done, &mut st);
                }
                Inst::XxMfAcc { acc } => {
                    assert!(!st.mma_free.is_empty(), "MMA instruction without an MME");
                    units.mma_ops += 1;
                    self.power.mma_op(0.2);
                    let ready = disp.max(st.acc_ready[acc as usize]);
                    // "four cycles to transfer one accumulator to 4 VSRs"
                    let issue = alloc_unit(&mut st.mma_free, ready);
                    let done = issue + u64::from(cfg.mfacc_cycles);
                    for r in 0..4 {
                        st.vsr_ready[acc as usize * 4 + r] = done;
                    }
                    advance(done, &mut st);
                }
                Inst::Nop => {}
            }
            idx += 1;
        }

        let cycles = st.horizon.max(st.dispatch_cycle) + 1;
        let energy = self.power.finish(cycles, instructions);
        SimReport {
            name: self.cfg.name,
            cycles,
            instructions,
            flops,
            units,
            l1_hits: self.cache.l1_hits - l1_0,
            l2_hits: self.cache.l2_hits - l2_0,
            mem_misses: self.cache.misses - mm_0,
            energy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::{AccOp, Ger};
    use crate::kernels::dgemm::dgemm_8xnx8_program;
    use crate::kernels::vsx::vsx_dgemm_8x4_program;

    fn p10() -> CoreSim {
        CoreSim::new(MachineConfig::power10())
    }

    fn p9() -> CoreSim {
        CoreSim::new(MachineConfig::power9())
    }

    #[test]
    fn synthetic_peak_mma_throughput() {
        // back-to-back independent gers on 8 accumulators reach ~2/cycle
        // (the two MME pipes of §III)
        let mut prog = Vec::new();
        prog.push(Inst::Addi { rt: 9, ra: 0, si: 1000 });
        prog.push(Inst::Mtctr { rs: 9 });
        for a in 0..8u8 {
            prog.push(Inst::Ger(Ger::new(GerKind::F64Ger, AccOp::New, a, 32, 40)));
        }
        prog.push(Inst::Bdnz { bd: -(8 * 4) }); // back to the first ger
        prog.push(Inst::Blr);
        let mut sim = p10();
        let r = sim.run(&prog, 100_000);
        let per_cycle = r.units.mma_ops as f64 / r.cycles as f64;
        assert!(per_cycle > 1.6, "two MME pipes should sustain ~2 gers/cycle, got {per_cycle:.2}");
        // flops/cycle close to the 32-peak
        assert!(r.flops_per_cycle() > 26.0, "got {:.2}", r.flops_per_cycle());
    }

    #[test]
    fn dgemm_kernel_lands_near_paper_efficiency() {
        // Figure 11: POWER10-MMA ≈ 26 flops/cycle (>80% of 32-peak)
        let mut sim = p10();
        let r = sim.run(&dgemm_8xnx8_program(128), 1 << 20);
        let fpc = r.flops_per_cycle();
        assert!(fpc > 24.0 && fpc <= 32.0, "POWER10-MMA DGEMM kernel: {fpc:.2} flops/cycle");
    }

    #[test]
    fn vsx_kernel_efficiency_p10_vs_p9() {
        // Figure 11: vector code ≈ 10 flops/cycle on P10, ≈ 4.5 on P9
        let prog = vsx_dgemm_8x4_program(128);
        let r10 = p10().run(&prog, 1 << 20);
        let r9 = p9().run(&prog, 1 << 20);
        let (f10, f9) = (r10.flops_per_cycle(), r9.flops_per_cycle());
        assert!(f10 > 7.5 && f10 < 12.5, "POWER10-VSX: {f10:.2}");
        assert!(f9 > 3.5 && f9 < 6.0, "POWER9: {f9:.2}");
        assert!(f10 / f9 > 1.5, "P10 vector should beat P9 vector ~2x, got {:.2}", f10 / f9);
    }

    #[test]
    fn mma_beats_vsx_on_p10_by_papers_factor() {
        let rm = p10().run(&dgemm_8xnx8_program(128), 1 << 20);
        // VSX computes an 8x4 block per call; 2 calls = same flops as one
        // MMA 8x128x8 call. flops/cycle is size-independent here.
        let rv = p10().run(&vsx_dgemm_8x4_program(128), 1 << 20);
        let ratio = rm.flops_per_cycle() / rv.flops_per_cycle();
        assert!(ratio > 2.0 && ratio < 3.6, "§VI: MMA ≈ 2.5x the vector code on P10, got {ratio:.2}");
    }

    #[test]
    fn p9_rejects_mma_instructions() {
        let prog = vec![Inst::XxSetAccZ { acc: 0 }, Inst::Blr];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p9().run(&prog, 100)));
        assert!(r.is_err(), "POWER9 has no MME");
    }

    #[test]
    fn determinism() {
        let prog = dgemm_8xnx8_program(32);
        let a = p10().run(&prog, 1 << 20);
        let b = p10().run(&prog, 1 << 20);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
    }

    #[test]
    fn utilization_identifies_the_bottleneck() {
        // the MMA DGEMM kernel is MME-bound on POWER10 (§III: the two MME
        // pipes are the throughput limit, everything else has slack)
        let cfg = MachineConfig::power10();
        let mut sim = CoreSim::new(cfg.clone());
        let r = sim.run(&dgemm_8xnx8_program(128), 1 << 22);
        let (unit, util) = r.bottleneck(&cfg);
        assert_eq!(unit, "mme", "DGEMM must be MME-bound, got {unit} at {util:.2}");
        assert!(util > 0.75, "MME is the saturating unit: {util:.2}");
        let (vsu, _, lsu, fxu) = r.utilization(&cfg);
        assert!(vsu < 0.2 && lsu < 0.8 && fxu < 0.5, "other units have slack");

        // the VSX kernel is VSU-bound
        let r = sim.run(&vsx_dgemm_8x4_program(128), 1 << 22);
        assert_eq!(r.bottleneck(&cfg).0, "vsu");
    }

    #[test]
    fn acc_transfer_costs_respected() {
        // xxmtacc (2 cycles) then xxmfacc (4 cycles) on an empty machine:
        // the two transfers must serialize through the accumulator
        let prog = vec![Inst::XxMtAcc { acc: 0 }, Inst::XxMfAcc { acc: 0 }, Inst::Blr];
        let r = p10().run(&prog, 100);
        assert!(r.cycles >= 6, "2 + 4 transfer cycles, got {}", r.cycles);
    }
}
