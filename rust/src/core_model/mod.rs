//! Cycle-approximate model of the POWER9 / POWER10 core backends (paper
//! §III, Figures 2–3) and the event-based power model of §VII.
//!
//! The model is a dataflow-plus-resources timing simulator: it interprets
//! the same instruction streams the functional machine executes (tracking
//! only GPR/CTR values, which control flow and addressing need), and for
//! each dynamic instruction computes the earliest cycle at which it can
//! issue given
//!
//! * operand readiness (register ready times, incl. accumulator RAW),
//! * execution resources (VSU pipes, the two MME pipes of Figure 2, LSU
//!   ports, fixed-point units),
//! * front-end dispatch bandwidth,
//! * memory latency from a small cache + stream-prefetcher model
//!   ([`lsu`]),
//! * the accumulator transfer costs of §III ("two cycles to transfer four
//!   vector-scalar registers to an accumulator and four cycles to transfer
//!   one accumulator to 4 vector-scalar registers").
//!
//! Three machine configurations reproduce the paper's measurement setups
//! ([`config::MachineConfig::power9`], [`config::MachineConfig::power10`]):
//! POWER9 runs only VSX code; POWER10 runs either the VSX baseline
//! (POWER10-VSX) or the MMA kernels (POWER10-MMA).

pub mod config;
pub mod lsu;
pub mod power;
pub mod sched;

pub use config::MachineConfig;
pub use power::{EnergyReport, PowerModel};
pub use sched::{CoreSim, SimReport};
