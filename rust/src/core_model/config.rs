//! Machine configurations: structural parameters of the POWER9 and POWER10
//! core backends as described in the paper (§I: "four vector pipelines per
//! core" on POWER10 vs two on POWER9; §III: two MMA pipes fed from slices
//! 2/3, ACC-resident accumulators, bus transfer costs) plus cache and
//! energy parameters.
//!
//! Cycle parameters are frequency-independent (the paper reports
//! flops/**cycle** and runs all machines "at constant frequency").

/// Structural + timing + energy description of one core configuration.
#[derive(Clone, Debug)]
pub struct MachineConfig {
    pub name: &'static str,
    /// 128-bit vector execution pipes (VSU): POWER9 = 2, POWER10 = 4 (§I).
    pub vsu_pipes: u32,
    /// Matrix Math Engine pipes (MU2/MU3, Figure 2): POWER10 = 2, else 0.
    pub mma_pipes: u32,
    /// Load/store ports.
    pub lsu_ports: u32,
    /// Fixed-point units (addi etc.) — never binding for these kernels.
    pub fxu_units: u32,
    /// Front-end dispatch width (instructions/cycle).
    pub dispatch_width: u32,
    /// FP FMA result latency (cycles) — the vector pipeline depth.
    pub fma_latency: u32,
    /// Permute/splat/logical latency.
    pub perm_latency: u32,
    /// ger issue-to-accumulate latency on the *same* accumulator.
    /// "The issue-to-issue latency for the matrix math facility
    /// instructions is reduced ... since the accumulators are already in
    /// the functional unit" (§III point 5).
    pub ger_acc_latency: u32,
    /// VSR-group → accumulator transfer (`xxmtacc`): 2 cycles (§III).
    pub mtacc_cycles: u32,
    /// Accumulator → VSR-group transfer (`xxmfacc`): 4 cycles (§III).
    pub mfacc_cycles: u32,
    /// Fixed-point result latency.
    pub fx_latency: u32,
    // ---- memory hierarchy ----
    pub l1_bytes: usize,
    pub l2_bytes: usize,
    pub line_bytes: usize,
    pub l1_latency: u32,
    pub l2_latency: u32,
    pub mem_latency: u32,
    // ---- energy model (arbitrary energy units; see power.rs) ----
    /// Per-instruction front-end (fetch/decode/dispatch) energy.
    pub e_frontend: f64,
    /// Per-µop VSU energy (FMA-class; permutes cost half).
    pub e_vsu_op: f64,
    /// Per-ger MME energy — per 128 bits of datapath activity the MME grid
    /// switches far less than an equivalent chain of vector ops (§III:
    /// "the accumulator data stays local to the matrix math engine").
    pub e_mma_op: f64,
    /// Per-LSU-access energy.
    pub e_lsu_op: f64,
    /// Per-fixed-point-op energy.
    pub e_fx_op: f64,
    /// Static (leakage + clock-grid) power per cycle: core without MME.
    pub p_static_core: f64,
    /// Static power per cycle of the MME (0 when power-gated).
    pub p_static_mme: f64,
    /// Technology/global scale factor: POWER9's older silicon draws more
    /// per switch (§VII: P10 delivers 5x perf "at 24% less power ...
    /// almost 7x reduction on energy per computation").
    pub tech_scale: f64,
}

impl MachineConfig {
    /// The POWER9 core (SMT4 slice pair, 2×128-bit VSU pipes, no MME);
    /// older 14 nm technology (`tech_scale` > 1).
    pub fn power9() -> Self {
        MachineConfig {
            name: "POWER9",
            vsu_pipes: 2,
            mma_pipes: 0,
            lsu_ports: 2,
            fxu_units: 4,
            dispatch_width: 6,
            fma_latency: 7,
            perm_latency: 3,
            ger_acc_latency: 4,
            mtacc_cycles: 2,
            mfacc_cycles: 4,
            fx_latency: 1,
            l1_bytes: 32 * 1024,
            l2_bytes: 512 * 1024,
            line_bytes: 128,
            l1_latency: 4,
            l2_latency: 13,
            mem_latency: 140,
            e_frontend: 0.22,
            e_vsu_op: 1.0,
            e_mma_op: 0.0,
            e_lsu_op: 0.55,
            e_fx_op: 0.12,
            p_static_core: 7.0,
            p_static_mme: 0.0,
            tech_scale: 1.55,
        }
    }

    /// The POWER10 core: 4 VSU pipes, the Matrix Math Engine (2 pipes,
    /// Figure 2), 7 nm technology.
    pub fn power10() -> Self {
        MachineConfig {
            name: "POWER10",
            vsu_pipes: 4,
            mma_pipes: 2,
            lsu_ports: 4,
            fxu_units: 4,
            dispatch_width: 8,
            fma_latency: 6,
            perm_latency: 3,
            ger_acc_latency: 4,
            mtacc_cycles: 2,
            mfacc_cycles: 4,
            fx_latency: 1,
            l1_bytes: 32 * 1024,
            l2_bytes: 2 * 1024 * 1024,
            line_bytes: 128,
            l1_latency: 4,
            l2_latency: 13,
            mem_latency: 120,
            e_frontend: 0.20,
            e_vsu_op: 0.80,
            // one ger = up to 16 FMAs but switches one 2-D grid locally and
            // moves no accumulator data over the result buses: per-flop
            // energy far below the vector datapath (§III/§VII). Calibrated
            // so the Figure 12 ratios hold: MMA ≈ +8% total power vs VSX
            // on POWER10, ≈ −24% vs POWER9, ≈ 7x less energy/flop.
            e_mma_op: 1.7,
            e_lsu_op: 0.45,
            e_fx_op: 0.10,
            p_static_core: 6.2,
            p_static_mme: 0.85,
            tech_scale: 1.0,
        }
    }

    /// Peak fp64 flops/cycle of the *vector* datapath (2 lanes × FMA).
    pub fn vsx_peak_f64_flops_per_cycle(&self) -> f64 {
        f64::from(self.vsu_pipes) * 2.0 * 2.0
    }

    /// Peak fp64 flops/cycle of the MME (2 pipes × 4×2 accumulator × FMA).
    pub fn mma_peak_f64_flops_per_cycle(&self) -> f64 {
        f64::from(self.mma_pipes) * 8.0 * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_rates() {
        // §VI: POWER9 peak 8 flops/cycle, POWER10 vector peak 16,
        // POWER10 MMA peak 32
        assert_eq!(MachineConfig::power9().vsx_peak_f64_flops_per_cycle(), 8.0);
        assert_eq!(MachineConfig::power10().vsx_peak_f64_flops_per_cycle(), 16.0);
        assert_eq!(MachineConfig::power10().mma_peak_f64_flops_per_cycle(), 32.0);
        assert_eq!(MachineConfig::power9().mma_peak_f64_flops_per_cycle(), 0.0);
    }

    #[test]
    fn pipe_counts_match_paper() {
        let p9 = MachineConfig::power9();
        let p10 = MachineConfig::power10();
        assert_eq!(p9.vsu_pipes, 2, "§VI: two vector pipes in POWER9");
        assert_eq!(p10.vsu_pipes, 4, "§I: four vector pipelines per core");
        assert_eq!(p10.mma_pipes, 2, "§III: two execution pipelines MU2/MU3");
        // §III bus costs
        assert_eq!(p10.mtacc_cycles, 2);
        assert_eq!(p10.mfacc_cycles, 4);
    }
}
