//! Execution substrate: a work-stealing-free but sharded thread pool, an
//! unbounded MPMC channel, and a bounded channel with backpressure — the
//! pieces the coordinator's event loop needs (tokio is unavailable offline;
//! the request path is CPU-bound PJRT execution, so OS threads are the
//! right tool anyway).
//!
//! Beyond fire-and-forget [`ThreadPool::spawn`], the pool offers a
//! **blocking data-parallel primitive**, [`ThreadPool::par_for`]: run a
//! borrowed closure over `0..tasks` across the workers *and the calling
//! thread*, returning only when every index has completed. This is what
//! lets the blocked GEMM of [`crate::blas::block_gemm`] fan its
//! column-chunk panel work out over one long-lived, process-wide pool
//! (owned by [`crate::runtime::device::Device`]) instead of spawning and
//! joining scoped threads on every call.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Deterministic FNV-1a (64-bit). `DefaultHasher`'s algorithm is
/// unspecified and may change between toolchains; everything in this
/// crate that needs a *stable* string hash — the coordinator's sticky
/// model→shard router, testkit's name→seed derivation — goes through
/// this one definition.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// MPMC channel
// ---------------------------------------------------------------------------

struct ChanInner<T> {
    queue: Mutex<ChanState<T>>,
    available: Condvar,
    space: Condvar,
    cap: Option<usize>,
}

struct ChanState<T> {
    items: VecDeque<T>,
    senders: usize,
    closed: bool,
}

/// Sending half of a channel. Cloneable.
pub struct Sender<T>(Arc<ChanInner<T>>);

/// Receiving half of a channel. Cloneable (MPMC).
pub struct Receiver<T>(Arc<ChanInner<T>>);

/// Error returned by [`Sender::send`] when all receivers are gone or the
/// channel was closed.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned by [`Receiver::recv`] when the channel is empty and all
/// senders are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.queue.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            self.0.available.notify_all();
        }
    }
}

fn channel_inner<T>(cap: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(ChanInner {
        queue: Mutex::new(ChanState { items: VecDeque::new(), senders: 1, closed: false }),
        available: Condvar::new(),
        space: Condvar::new(),
        cap,
    });
    (Sender(inner.clone()), Receiver(inner))
}

/// Unbounded MPMC channel.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    channel_inner(None)
}

/// Bounded MPMC channel: `send` blocks when `cap` items are queued
/// (backpressure).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap > 0);
    channel_inner(Some(cap))
}

impl<T> Sender<T> {
    /// Blocking send (waits for space on bounded channels).
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.0.queue.lock().unwrap();
        if let Some(cap) = self.0.cap {
            while st.items.len() >= cap && !st.closed {
                st = self.0.space.wait(st).unwrap();
            }
        }
        if st.closed {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        drop(st);
        self.0.available.notify_one();
        Ok(())
    }

    /// Non-blocking send: fails with the item if the channel is full/closed.
    pub fn try_send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.0.queue.lock().unwrap();
        if st.closed || self.0.cap.is_some_and(|c| st.items.len() >= c) {
            return Err(SendError(item));
        }
        st.items.push_back(item);
        drop(st);
        self.0.available.notify_one();
        Ok(())
    }

    /// Close the channel: wakes all receivers; subsequent sends fail.
    pub fn close(&self) {
        let mut st = self.0.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.0.available.notify_all();
        self.0.space.notify_all();
    }

    /// Queue depth (for backpressure decisions / metrics).
    pub fn len(&self) -> usize {
        self.0.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Err(RecvError)` once the channel is drained and
    /// all senders are gone (or it was closed).
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.0.space.notify_one();
                return Ok(item);
            }
            if st.senders == 0 || st.closed {
                return Err(RecvError);
            }
            st = self.0.available.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.queue.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            drop(st);
            self.0.space.notify_one();
        }
        item
    }

    /// Receive with a deadline; `None` on timeout or closed-and-drained.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.0.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.0.space.notify_one();
                return Some(item);
            }
            if st.senders == 0 || st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _tmo) = self.0.available.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    pub fn len(&self) -> usize {
        self.0.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Thread pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with graceful shutdown and a blocking
/// data-parallel dispatch ([`ThreadPool::par_for`]).
///
/// A job that panics is contained (`catch_unwind`): the worker thread
/// survives and keeps draining the queue, so a long-lived pool (the
/// process-wide GEMM pool of [`crate::runtime::device::Device`]) cannot
/// be silently bled dry by one bad task.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
}

impl ThreadPool {
    /// Pool with `n` worker threads named `{name}-{i}`.
    pub fn new(name: &str, n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let active = Arc::new(AtomicUsize::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                let active = active.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            active.fetch_add(1, Ordering::SeqCst);
                            // contain panics: the pool must outlive any one
                            // job. The default panic hook has already printed
                            // the payload/location; this line keeps the
                            // containment itself loud (par_for additionally
                            // re-raises on its caller).
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                            if r.is_err() {
                                eprintln!(
                                    "thread-pool job panicked (contained; pool keeps serving)"
                                );
                            }
                            active.fetch_sub(1, Ordering::SeqCst);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, active, shutdown }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        assert!(!self.shutdown.load(Ordering::SeqCst), "pool is shut down");
        self.tx.as_ref().unwrap().send(Box::new(job)).ok();
    }

    /// Run `f(i)` for every `i in 0..tasks` across the pool workers **and
    /// the calling thread**, returning once every index has completed —
    /// the blocking primitive behind the persistent-pool GEMM (each index
    /// is one column-chunk panel job of [`crate::blas::block_gemm`]).
    ///
    /// The closure is *borrowed*: it may capture non-`'static` state
    /// (packed panels, the output image) exactly like a
    /// `std::thread::scope` body. The calling thread claims indices too,
    /// so progress is guaranteed even when every worker is busy with
    /// other callers' tasks (several coordinator shards share one pool),
    /// and a call with `tasks <= 1` runs inline without touching the
    /// queue. If any task panics, the panic is re-raised on the calling
    /// thread after all tasks finish.
    pub fn par_for(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if tasks == 1 {
            f(0);
            return;
        }
        assert!(!self.shutdown.load(Ordering::SeqCst), "pool is shut down");
        // SAFETY (lifetime erasure): the closure reference is smuggled to
        // the workers as a raw pointer. It is dereferenced only for a
        // claimed index `i < tasks` (see `ParFor::run`), and every claimed
        // index decrements `remaining` exactly once — on the normal path
        // and on unwind (the `Done` drop guard). `wait()` blocks this
        // frame until `remaining == 0`, i.e. until every dereference has
        // completed, so the pointee outlives all uses. Late-waking helper
        // jobs only touch the (Arc-owned) atomics, never the pointer.
        #[allow(clippy::useless_transmute)]
        let erased: *const (dyn Fn(usize) + Sync + 'static) = unsafe { std::mem::transmute(f) };
        let task = Arc::new(ParFor {
            f: erased,
            tasks,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(tasks),
            done: Mutex::new(()),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        // one helper job per worker (capped at tasks - 1: the caller is a
        // worker too); helpers that wake late simply find nothing to claim
        let helpers = self.workers.len().min(tasks - 1);
        for _ in 0..helpers {
            let t = task.clone();
            self.tx.as_ref().unwrap().send(Box::new(move || t.run())).ok();
        }
        // the caller's own share must not unwind past `wait`: helpers may
        // still be inside `f`, and this frame owns what `f` borrows
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task.run()));
        task.wait();
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if task.panicked.load(Ordering::SeqCst) {
            panic!("par_for task panicked");
        }
    }

    /// Number of jobs currently executing.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Queued (not yet started) jobs.
    pub fn queued(&self) -> usize {
        self.tx.as_ref().map_or(0, |t| t.len())
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.tx.take(); // drop sender -> workers exit after draining
        for w in self.workers.drain(..) {
            w.join().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if self.tx.is_some() {
            self.shutdown_impl();
        }
    }
}

/// Shared state of one [`ThreadPool::par_for`] call: a claim counter
/// (`next`), a completion latch (`remaining` + condvar), and the erased
/// closure pointer. Helpers and the caller all run [`ParFor::run`].
struct ParFor {
    /// Erased pointer to the caller's borrowed closure; only dereferenced
    /// for claimed indices (see the safety comment in `par_for`).
    f: *const (dyn Fn(usize) + Sync + 'static),
    tasks: usize,
    next: AtomicUsize,
    remaining: AtomicUsize,
    done: Mutex<()>,
    cv: Condvar,
    panicked: AtomicBool,
}

// SAFETY: `ParFor` is shared across threads only through `Arc` inside
// `par_for`. The raw pointer is read-only, points at a `Sync` closure,
// and the completion latch guarantees it is never dereferenced after the
// owning stack frame returns (argued at the transmute site).
unsafe impl Send for ParFor {}
unsafe impl Sync for ParFor {}

impl ParFor {
    /// Claim and execute indices until none are left.
    fn run(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.tasks {
                return;
            }
            // the latch must tick even if `f` panics, or `wait` deadlocks
            struct Done<'a>(&'a ParFor);
            impl Drop for Done<'_> {
                fn drop(&mut self) {
                    if std::thread::panicking() {
                        self.0.panicked.store(true, Ordering::SeqCst);
                    }
                    if self.0.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
                        // lock-then-notify pairs with the wait loop, so
                        // the final decrement cannot race past a caller
                        // that is just about to sleep
                        drop(self.0.done.lock().unwrap());
                        self.0.cv.notify_all();
                    }
                }
            }
            let _done = Done(self);
            // SAFETY: `i < tasks` was claimed, so this index's `remaining`
            // decrement has not happened yet and `par_for` is still
            // blocked in `wait` — the closure behind the pointer is alive.
            let f = unsafe { &*self.f };
            f(i);
        }
    }

    /// Block until every claimed index has completed.
    fn wait(&self) {
        let mut g = self.done.lock().unwrap();
        while self.remaining.load(Ordering::SeqCst) != 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Run `f` on `n` values in parallel over a temporary scope of threads and
/// collect the results in input order (a minimal `rayon`-like map).
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let work = Mutex::new(work);
    let results = Mutex::new(&mut out);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let item = work.lock().unwrap().pop();
                let Some((i, v)) = item else { break };
                let r = f(v);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|r| r.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn channel_fifo() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn channel_close_semantics() {
        let (tx, rx) = channel::<u32>();
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn bounded_backpressure() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err(), "full channel rejects try_send");
        let t = {
            let tx = tx.clone();
            std::thread::spawn(move || tx.send(3).unwrap()) // blocks until a recv
        };
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        t.join().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn recv_timeout() {
        let (tx, rx) = channel::<u32>();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), None);
        tx.send(7).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)), Some(7));
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let (tx, rx) = channel::<u64>();
        let n_senders = 4u8;
        let per = 500u64;
        let senders: Vec<_> = (0..n_senders)
            .map(|s| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        tx.send(u64::from(s) * per + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let receivers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for s in senders {
            s.join().unwrap();
        }
        let mut all: Vec<u64> = receivers.into_iter().flat_map(|r| r.join().unwrap()).collect();
        all.sort();
        let expect: Vec<u64> = (0..u64::from(n_senders) * per).collect();
        assert_eq!(all, expect, "every message delivered exactly once");
    }

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new("test", 4);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = c.clone();
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(c.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_for_covers_every_index_exactly_once() {
        let pool = ThreadPool::new("pf", 4);
        for tasks in [0usize, 1, 2, 3, 4, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.par_for(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} of {tasks}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn par_for_borrows_and_mutates_caller_state() {
        // the scoped-threads replacement: disjoint &mut chunks handed to
        // workers through per-index mutexes, exactly like the GEMM does
        let pool = ThreadPool::new("pfm", 3);
        let mut data = vec![0u64; 64];
        {
            let chunks: Vec<Mutex<&mut [u64]>> =
                data.chunks_mut(16).map(Mutex::new).collect();
            pool.par_for(chunks.len(), &|w| {
                let mut g = chunks[w].lock().unwrap();
                for (j, slot) in g.iter_mut().enumerate() {
                    *slot = (w * 16 + j) as u64;
                }
            });
        }
        let expect: Vec<u64> = (0..64).collect();
        assert_eq!(data, expect);
        pool.shutdown();
    }

    #[test]
    fn par_for_is_reentrant_across_callers() {
        // several threads sharing one pool must all make progress (the
        // caller participates, so a saturated queue cannot deadlock)
        let pool = Arc::new(ThreadPool::new("pfc", 2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    let sum = AtomicUsize::new(0);
                    pool.par_for(100, &|i| {
                        sum.fetch_add(i, Ordering::SeqCst);
                    });
                    sum.load(Ordering::SeqCst)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 4950);
        }
    }

    #[test]
    fn par_for_propagates_task_panics() {
        let pool = ThreadPool::new("pfp", 2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.par_for(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must surface on the caller");
        // the pool survives a panicking task and keeps serving
        let c = AtomicUsize::new(0);
        pool.par_for(16, &|_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 16);
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_jobs() {
        let pool = ThreadPool::new("drain", 2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = c.clone();
            pool.spawn(move || {
                std::thread::sleep(Duration::from_micros(200));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown(); // must run everything already queued
        assert_eq!(c.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn active_and_queued_accounting() {
        let pool = ThreadPool::new("acct", 2);
        let (gate_tx, gate_rx) = channel::<()>();
        let (ready_tx, ready_rx) = channel::<()>();
        // occupy both workers, then queue three more jobs behind them
        for _ in 0..2 {
            let gate = gate_rx.clone();
            let ready = ready_tx.clone();
            pool.spawn(move || {
                ready.send(()).unwrap();
                gate.recv().unwrap();
            });
        }
        ready_rx.recv().unwrap();
        ready_rx.recv().unwrap();
        for _ in 0..3 {
            pool.spawn(|| {});
        }
        assert_eq!(pool.active(), 2, "both workers busy");
        assert_eq!(pool.queued(), 3, "three jobs waiting");
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        // released: the pool must quiesce (counters back to zero)
        let t0 = std::time::Instant::now();
        while (pool.active() != 0 || pool.queued() != 0)
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.active(), 0);
        assert_eq!(pool.queued(), 0);
        pool.shutdown();
    }

    #[test]
    fn par_map_order_preserved() {
        let out = par_map((0..100).collect(), 8, |i: i32| i * i);
        let expect: Vec<i32> = (0..100).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |i| i);
        assert!(out.is_empty());
    }
}
