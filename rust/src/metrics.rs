//! Metrics substrate: counters, latency histograms with quantiles, and an
//! aligned table printer used by the benchmark harnesses to emit
//! paper-style rows (Figures 10–12).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A monotonically increasing counter, safe to share across threads.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (microsecond resolution, ~4% bucket
/// granularity) supporting p50/p95/p99 queries without storing samples.
#[derive(Debug)]
pub struct Histogram {
    /// Buckets: value v (µs) goes to bucket `floor(log2(v+1) * SUB)`.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const SUB: f64 = 16.0; // sub-buckets per octave
const NBUCKETS: usize = 16 * 40; // covers up to ~2^40 µs

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        let idx = (((us + 1) as f64).log2() * SUB) as usize;
        idx.min(NBUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        (2f64.powf(idx as f64 / SUB) - 1.0).round() as u64
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`q` in [0,1]) in microseconds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_us()
    }
}

/// Fixed-width table printer for paper-style benchmark output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_us(0.5);
        assert!((450..=560).contains(&p50), "p50 = {p50}");
        let p99 = h.quantile_us(0.99);
        assert!((930..=1050).contains(&p99), "p99 = {p99}");
        assert!(h.mean_us() > 450.0 && h.mean_us() < 560.0);
        assert!(h.max_us() >= 990);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn histogram_single_sample() {
        let h = Histogram::new();
        h.record(Duration::from_micros(250));
        assert_eq!(h.count(), 1);
        assert_eq!(h.max_us(), 250);
        assert_eq!(h.mean_us(), 250.0);
        // Every quantile of a one-sample distribution is that sample's
        // bucket — within the ~4% log-bucket granularity.
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let v = h.quantile_us(q);
            assert!((240..=261).contains(&v), "q{q} = {v}");
        }
    }

    #[test]
    fn histogram_max_bucket_overflow_clamps() {
        let h = Histogram::new();
        // ~2^50 µs lands beyond the last octave the buckets cover; the
        // recording must clamp to the final bucket, not index out of
        // bounds, and quantiles must stay finite (falling back to the
        // exact tracked max rather than the saturated bucket value).
        let huge = Duration::from_micros(1 << 50);
        h.record(huge);
        h.record(Duration::from_micros(10));
        assert_eq!(h.count(), 2);
        assert_eq!(h.max_us(), 1 << 50);
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= Histogram::bucket_value(NBUCKETS - 1) || p99 == h.max_us(), "p99 = {p99}");
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        // p50 ≤ p95 ≤ p99 must hold for any sample set; exercise a
        // skewed multimodal one (many fast, few slow).
        let h = Histogram::new();
        for _ in 0..900 {
            h.record(Duration::from_micros(40));
        }
        for _ in 0..80 {
            h.record(Duration::from_micros(2_000));
        }
        for _ in 0..20 {
            h.record(Duration::from_micros(150_000));
        }
        let (p50, p95, p99) = (h.quantile_us(0.5), h.quantile_us(0.95), h.quantile_us(0.99));
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!((35..=45).contains(&p50), "p50 = {p50}");
        assert!((1_800..=2_200).contains(&p95), "p95 = {p95}");
        assert!((130_000..=170_000).contains(&p99), "p99 = {p99}");
    }

    #[test]
    fn counter_add_accumulates_across_threads() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (1..=6u64)
            .map(|amount| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        c.add(amount);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Σ 500·a for a in 1..=6 = 500 · 21
        assert_eq!(c.get(), 500 * 21);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["N", "flops/cycle"]);
        t.row(&["128".into(), "25.9".into()]);
        t.row(&["4096".into(), "26.1".into()]);
        let s = t.render();
        assert!(s.contains("flops/cycle"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().filter(|&c| c == '-').count(), lines[1].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
