//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with median/mean/min reporting, a
//! `black_box` to defeat dead-code elimination, and a tiny runner so each
//! `cargo bench` target can register named benchmarks and also emit the
//! paper-style figure tables.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` under the criterion-style name.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u32,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Sample {
    /// ns per iteration (median).
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Items/second given `items` of work per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median.as_secs_f64()
    }
}

/// Benchmark `f`, returning timing statistics.
///
/// Runs `warmup` untimed iterations, then `iters` timed ones; each timed
/// iteration is measured individually so the median is robust to OS noise.
pub fn bench(name: &str, warmup: u32, iters: u32, mut f: impl FnMut()) -> Sample {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<Duration>() / iters;
    Sample { name: name.to_string(), iters, median, mean, min }
}

/// Auto-calibrating variant: picks an iteration count so the whole
/// benchmark takes roughly `budget`.
pub fn bench_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> Sample {
    // calibrate with one run
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as u32;
    bench(name, (iters / 10).max(1), iters, f)
}

/// Pretty-print a sample line (the `cargo bench`-style output).
pub fn report(s: &Sample) {
    println!(
        "bench {:<48} {:>12.3} ms/iter (median; mean {:.3} ms, min {:.3} ms, n={})",
        s.name,
        s.median.as_secs_f64() * 1e3,
        s.mean.as_secs_f64() * 1e3,
        s.min.as_secs_f64() * 1e3,
        s.iters
    );
}

/// Format a flops/cycle-style float column.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0u64;
        let s = bench("count", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.median);
    }

    #[test]
    fn bench_budget_terminates() {
        let s = bench_budget("sleepless", Duration::from_millis(20), || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 3);
    }

    #[test]
    fn throughput_math() {
        let s = Sample {
            name: "t".into(),
            iters: 1,
            median: Duration::from_millis(10),
            mean: Duration::from_millis(10),
            min: Duration::from_millis(10),
        };
        let tput = s.throughput(100.0);
        assert!((tput - 10_000.0).abs() < 1.0);
    }
}
