//! The instruction set modeled by the simulator: every MMA instruction of
//! the paper's Table I (accumulator moves, integer and floating-point rank-k
//! updates, conventional and prefixed forms) plus the minimal Power ISA
//! support subset that the paper's kernels use (Figure 7: `lxv`, `lxvp`,
//! `stxv`, `addi`, `mtctr`, `bdnz`, `blr`).
//!
//! Mask convention: the prefixed (`pm…`) forms carry X/Y/P masks. In this
//! crate a mask is a `u8` where **bit `i` (LSB-first) enables element `i`**
//! (row `i` of X, column `j` of Y^T, or product `k`). The binary encoder
//! converts to the MSB-first immediate field order used by the ISA
//! (`x = x0x1x2x3` in eq. 3).

/// Input element type / shape family of a rank-k update (Table I b, c).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum GerKind {
    /// `xvi4ger8`: X, Y are 4×8 int4 matrices; A is 4×4 int32. k = 8.
    I4Ger8,
    /// `xvi8ger4`: X is 4×4 int8, Y is 4×4 **u**int8; A is 4×4 int32. k = 4.
    I8Ger4,
    /// `xvi16ger2`: X, Y are 4×2 int16 matrices; A is 4×4 int32. k = 2.
    I16Ger2,
    /// `xvbf16ger2`: X, Y are 4×2 bfloat16; A is 4×4 fp32. k = 2.
    Bf16Ger2,
    /// `xvf16ger2`: X, Y are 4×2 IEEE fp16; A is 4×4 fp32. k = 2.
    F16Ger2,
    /// `xvf32ger`: X, Y are 4-element fp32 vectors; A is 4×4 fp32. k = 1.
    F32Ger,
    /// `xvf64ger`: X is a 4-element fp64 vector (an even-odd VSR *pair*),
    /// Y a 2-element fp64 vector; A is 4×2 fp64. k = 1.
    F64Ger,
}

impl GerKind {
    /// The rank `k` of the update (inner dimension).
    pub fn rank(self) -> usize {
        match self {
            GerKind::I4Ger8 => 8,
            GerKind::I8Ger4 => 4,
            GerKind::I16Ger2 | GerKind::Bf16Ger2 | GerKind::F16Ger2 => 2,
            GerKind::F32Ger | GerKind::F64Ger => 1,
        }
    }

    /// Accumulator shape `(rows, cols)`.
    pub fn acc_shape(self) -> (usize, usize) {
        match self {
            GerKind::F64Ger => (4, 2),
            _ => (4, 4),
        }
    }

    /// True for the integer kinds (int32 accumulation).
    pub fn is_integer(self) -> bool {
        matches!(self, GerKind::I4Ger8 | GerKind::I8Ger4 | GerKind::I16Ger2)
    }

    /// Floating-point multiply-add *flops* performed by one unmasked
    /// instruction (2 flops per multiply-add). Integer kinds report their
    /// equivalent int-op count.
    pub fn flops(self) -> u64 {
        let (r, c) = self.acc_shape();
        (r * c * self.rank() * 2) as u64
    }

    /// Base mnemonic (without suffix), e.g. `xvf64ger`.
    pub fn mnemonic(self) -> &'static str {
        match self {
            GerKind::I4Ger8 => "xvi4ger8",
            GerKind::I8Ger4 => "xvi8ger4",
            GerKind::I16Ger2 => "xvi16ger2",
            GerKind::Bf16Ger2 => "xvbf16ger2",
            GerKind::F16Ger2 => "xvf16ger2",
            GerKind::F32Ger => "xvf32ger",
            GerKind::F64Ger => "xvf64ger",
        }
    }

    pub const ALL: [GerKind; 7] = [
        GerKind::I4Ger8,
        GerKind::I8Ger4,
        GerKind::I16Ger2,
        GerKind::Bf16Ger2,
        GerKind::F16Ger2,
        GerKind::F32Ger,
        GerKind::F64Ger,
    ];
}

/// How the product `XYᵀ` combines with the target accumulator (§II-B):
/// the 2-letter float suffixes (`pp`/`np`/`pn`/`nn`), the integer modulo
/// (`pp`) and saturating (`s`, `spp`) models, and the suffix-less priming
/// forms.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum AccOp {
    /// No suffix: `A = XYᵀ`. Writes (and thereby *primes*) the accumulator.
    New,
    /// `s` (integer only, `xvi16ger2s`): `A = sat(XYᵀ)`. Primes.
    NewS,
    /// `pp`: `A = XYᵀ + A` (requires a primed accumulator).
    PP,
    /// `np` (float only): `A = -XYᵀ + A`.
    NP,
    /// `pn` (float only): `A = XYᵀ - A`.
    PN,
    /// `nn` (float only): `A = -XYᵀ - A`.
    NN,
    /// `spp` (integer only): `A = sat(XYᵀ + A)`.
    SPP,
}

impl AccOp {
    /// True for the forms that read the previous accumulator value.
    pub fn accumulates(self) -> bool {
        !matches!(self, AccOp::New | AccOp::NewS)
    }

    /// Mnemonic suffix, e.g. `"pp"`.
    pub fn suffix(self) -> &'static str {
        match self {
            AccOp::New => "",
            AccOp::NewS => "s",
            AccOp::PP => "pp",
            AccOp::NP => "np",
            AccOp::PN => "pn",
            AccOp::NN => "nn",
            AccOp::SPP => "spp",
        }
    }

    /// Is this (kind, op) combination architected? (Table I.)
    pub fn valid_for(self, kind: GerKind) -> bool {
        use AccOp::*;
        match kind {
            // xvi4ger8[pp]
            GerKind::I4Ger8 => matches!(self, New | PP),
            // xvi8ger4[pp,spp]
            GerKind::I8Ger4 => matches!(self, New | PP | SPP),
            // xvi16ger2[s][pp] — i.e. base, s, pp, spp
            GerKind::I16Ger2 => matches!(self, New | NewS | PP | SPP),
            // float: base, pp, np, pn, nn
            GerKind::Bf16Ger2 | GerKind::F16Ger2 | GerKind::F32Ger | GerKind::F64Ger => {
                matches!(self, New | PP | NP | PN | NN)
            }
        }
    }
}

/// A rank-k update instruction instance (conventional or prefixed form).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Ger {
    pub kind: GerKind,
    pub op: AccOp,
    /// Target accumulator, 0..8.
    pub acc: u8,
    /// X source VSR (for `F64Ger` the *even* register of the even-odd pair).
    pub xa: u8,
    /// Y source VSR.
    pub yb: u8,
    /// True for the `pm…` prefixed form; masks below apply only then.
    pub prefixed: bool,
    /// Row mask for X: bit `i` enables row `i` (4 bits used).
    pub xmsk: u8,
    /// Column mask for Yᵀ: bit `j` enables column `j` (4 bits; 2 for f64).
    pub ymsk: u8,
    /// Product mask: bit `k` enables partial product `k` (rank bits used;
    /// absent — always all-ones — for the rank-1 `xvf32ger`/`xvf64ger`).
    pub pmsk: u8,
}

impl Ger {
    /// Conventional (non-prefixed) form: all masks enabled.
    pub fn new(kind: GerKind, op: AccOp, acc: u8, xa: u8, yb: u8) -> Self {
        Ger { kind, op, acc, xa, yb, prefixed: false, xmsk: 0xf, ymsk: 0xf, pmsk: 0xff }
    }

    /// Prefixed (`pm…`) masked form.
    pub fn prefixed(kind: GerKind, op: AccOp, acc: u8, xa: u8, yb: u8, xmsk: u8, ymsk: u8, pmsk: u8) -> Self {
        Ger { kind, op, acc, xa, yb, prefixed: true, xmsk, ymsk, pmsk }
    }

    /// Full mnemonic including `pm` prefix and suffix.
    pub fn mnemonic(&self) -> String {
        let pm = if self.prefixed { "pm" } else { "" };
        format!("{}{}{}", pm, self.kind.mnemonic(), self.op.suffix())
    }
}

/// One instruction of the simulated machine.
///
/// MMA instructions implement paper §II; the rest is the support subset the
/// paper's kernels rely on (Figure 7). Memory operands address the
/// `Machine`'s flat memory through a GPR base plus displacement, exactly like
/// the `DQ`-form loads in the paper's object code.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Inst {
    // ---- MMA: accumulator moves (Table I a) ----
    /// `xxsetaccz a` — zero + prime the accumulator.
    XxSetAccZ { acc: u8 },
    /// `xxmfacc a` — move accumulator to its VSR group (deprimes).
    XxMfAcc { acc: u8 },
    /// `xxmtacc a` — move the VSR group into the accumulator (primes).
    XxMtAcc { acc: u8 },
    // ---- MMA: rank-k updates (Table I b, c) ----
    Ger(Ger),
    // ---- VSX memory (DQ-form) ----
    /// `lxv xt, dq(ra)` — load 16 bytes.
    Lxv { xt: u8, ra: u8, dq: i32 },
    /// `lxvp xtp, dq(ra)` — load 32 bytes into the even-odd pair `xtp, xtp+1`.
    Lxvp { xtp: u8, ra: u8, dq: i32 },
    /// `stxv xs, dq(ra)` — store 16 bytes.
    Stxv { xs: u8, ra: u8, dq: i32 },
    /// `stxvp xsp, dq(ra)` — store the pair `xsp, xsp+1` (32 bytes).
    Stxvp { xsp: u8, ra: u8, dq: i32 },
    // ---- VSX vector arithmetic (the POWER9-compliant baseline path, §VI) ----
    /// `xvmaddadp xt, xa, xb` — two-lane f64 fused multiply-add:
    /// `xt[i] += xa[i] * xb[i]`.
    XvMaddaDp { xt: u8, xa: u8, xb: u8 },
    /// `xvmaddasp xt, xa, xb` — four-lane f32 fused multiply-add.
    XvMaddaSp { xt: u8, xa: u8, xb: u8 },
    /// `xxspltd xt, xa, h` — splat f64 lane `h` of `xa` to both lanes
    /// (the broadcast step vector code needs to build an outer product,
    /// §III comparison point 4).
    XxSpltd { xt: u8, xa: u8, h: u8 },
    /// `xxspltw xt, xa, w` — splat f32 lane `w` of `xa` to all four lanes.
    XxSpltw { xt: u8, xa: u8, w: u8 },
    /// `xxlor xt, xa, xb` — bitwise OR; `xxlor t,a,a` is the canonical
    /// vector-register copy (what compilers emit around
    /// `__builtin_mma_assemble_acc` / `disassemble_acc`).
    Xxlor { xt: u8, xa: u8, xb: u8 },
    /// `xxlxor xt, xa, xb` — bitwise XOR; `xxlxor t,t,t` is the canonical
    /// register-zeroing idiom used by vector kernels.
    Xxlxor { xt: u8, xa: u8, xb: u8 },
    // ---- fixed-point bookkeeping ----
    /// `addi rt, ra, si` (`li rt, si` when `ra = 0`).
    Addi { rt: u8, ra: u8, si: i32 },
    /// `mtctr rs` — move GPR to the count register.
    Mtctr { rs: u8 },
    // ---- control ----
    /// `bdnz target` — decrement CTR, branch to byte offset `bd` (relative
    /// to this instruction) if CTR ≠ 0.
    Bdnz { bd: i32 },
    /// `blr` — end of kernel.
    Blr,
    /// `nop` (`ori 0,0,0`).
    Nop,
}

impl Inst {
    /// Byte size in the instruction stream: prefixed instructions are 64-bit
    /// (§II-C), everything else 32-bit.
    pub fn size(&self) -> u32 {
        match self {
            Inst::Ger(g) if g.prefixed => 8,
            _ => 4,
        }
    }

    /// True for instructions executed by the Matrix Math Engine.
    pub fn is_mma(&self) -> bool {
        matches!(
            self,
            Inst::Ger(_) | Inst::XxSetAccZ { .. } | Inst::XxMfAcc { .. } | Inst::XxMtAcc { .. }
        )
    }

    /// Bytes moved to/from memory.
    pub fn mem_bytes(&self) -> u32 {
        match self {
            Inst::Lxv { .. } | Inst::Stxv { .. } => 16,
            Inst::Lxvp { .. } | Inst::Stxvp { .. } => 32,
            _ => 0,
        }
    }

    /// Floating-point (or integer-op) work of the instruction, for
    /// flops/cycle accounting. Masked (prefixed) forms count only enabled
    /// multiply-adds, mirroring "computations on disabled rows and columns
    /// are not performed" (§II-C).
    pub fn flops(&self) -> u64 {
        match self {
            Inst::XvMaddaDp { .. } => 4,  // 2 lanes x FMA
            Inst::XvMaddaSp { .. } => 8,  // 4 lanes x FMA
            Inst::Ger(g) => {
                if !g.prefixed {
                    g.kind.flops()
                } else {
                    let (rows, cols) = g.kind.acc_shape();
                    let r = (g.xmsk & ((1 << rows) - 1)).count_ones() as u64;
                    let c = (g.ymsk & ((1u16 << cols) - 1) as u8).count_ones() as u64;
                    let p = (g.pmsk & ((1u16 << g.kind.rank()) - 1) as u8).count_ones() as u64;
                    r * c * p * 2
                }
            }
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_validity_matrix() {
        use AccOp::*;
        // Float kinds accept exactly {New, PP, NP, PN, NN}
        for kind in [GerKind::Bf16Ger2, GerKind::F16Ger2, GerKind::F32Ger, GerKind::F64Ger] {
            for op in [New, PP, NP, PN, NN] {
                assert!(op.valid_for(kind), "{kind:?} {op:?}");
            }
            for op in [NewS, SPP] {
                assert!(!op.valid_for(kind), "{kind:?} {op:?}");
            }
        }
        // xvi16ger2[s][pp]
        assert!(New.valid_for(GerKind::I16Ger2));
        assert!(NewS.valid_for(GerKind::I16Ger2));
        assert!(PP.valid_for(GerKind::I16Ger2));
        assert!(SPP.valid_for(GerKind::I16Ger2));
        assert!(!NP.valid_for(GerKind::I16Ger2));
        // xvi8ger4[pp,spp]: saturating only in accumulation form (§II-B.2)
        assert!(!NewS.valid_for(GerKind::I8Ger4));
        assert!(SPP.valid_for(GerKind::I8Ger4));
        // xvi4ger8[pp]: modulo only
        assert!(!NewS.valid_for(GerKind::I4Ger8));
        assert!(!SPP.valid_for(GerKind::I4Ger8));
    }

    #[test]
    fn shapes_and_flops() {
        assert_eq!(GerKind::F64Ger.acc_shape(), (4, 2));
        assert_eq!(GerKind::F32Ger.acc_shape(), (4, 4));
        assert_eq!(GerKind::F64Ger.flops(), 16);
        assert_eq!(GerKind::F32Ger.flops(), 32);
        assert_eq!(GerKind::F16Ger2.flops(), 64);
        assert_eq!(GerKind::I8Ger4.flops(), 128);
        assert_eq!(GerKind::I4Ger8.flops(), 256);
        assert_eq!(GerKind::I16Ger2.rank(), 2);
    }

    #[test]
    fn masked_flops_eq3() {
        // pmxvf16ger2 with 2 rows, 3 cols, 1 product enabled:
        // 2*3*1 MACs = 12 flops
        let g = Ger::prefixed(GerKind::F16Ger2, AccOp::PP, 0, 32, 33, 0b0011, 0b0111, 0b01);
        assert_eq!(Inst::Ger(g).flops(), 12);
        // unmasked conventional form counts the full tile
        let g = Ger::new(GerKind::F16Ger2, AccOp::PP, 0, 32, 33);
        assert_eq!(Inst::Ger(g).flops(), 64);
    }

    #[test]
    fn sizes() {
        let conv = Inst::Ger(Ger::new(GerKind::F32Ger, AccOp::New, 0, 32, 33));
        let pfx = Inst::Ger(Ger::prefixed(GerKind::F32Ger, AccOp::New, 0, 32, 33, 0xf, 0xf, 0xff));
        assert_eq!(conv.size(), 4);
        assert_eq!(pfx.size(), 8);
        assert_eq!(Inst::Blr.size(), 4);
    }

    #[test]
    fn mnemonics() {
        assert_eq!(Ger::new(GerKind::F64Ger, AccOp::PP, 0, 0, 0).mnemonic(), "xvf64gerpp");
        assert_eq!(Ger::new(GerKind::I16Ger2, AccOp::NewS, 0, 0, 0).mnemonic(), "xvi16ger2s");
        assert_eq!(
            Ger::prefixed(GerKind::Bf16Ger2, AccOp::NN, 0, 0, 0, 0xf, 0xf, 0x3).mnemonic(),
            "pmxvbf16ger2nn"
        );
    }
}
