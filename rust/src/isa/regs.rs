//! Register state of the MMA facility (paper §II-A, Figure 1):
//!
//! * 64 vector-scalar registers (`VSR[0:63]`), 128 bits each;
//! * 8 accumulator registers (`ACC[0:7]`), 512 bits each, where `ACC[i]` is
//!   architecturally associated with the VSR group `VSR[4i .. 4i+3]`;
//! * the *priming* state machine: while an accumulator is primed its VSR
//!   group must not be touched, and an unprimed accumulator must not be read
//!   or accumulated into.
//!
//! Layout conventions (used consistently by `exec`, `builtins` and the
//! kernels): an accumulator holds its 4×4 (or 4×2) matrix **row-major**, one
//! row per associated VSR — `xxmfacc` moves row `r` of `ACC[i]` into
//! `VSR[4i + r]`. A VSR holding a `4×k` input matrix stores element `(i, k)`
//! at flat element index `i*k_dim + k` (row-major), matching the operand
//! packing of the paper's Figures 5–9 kernels.

use crate::isa::types::{bf16_to_f32, f16_to_f32, int4_sext};

/// Number of architected vector-scalar registers.
pub const NUM_VSRS: usize = 64;
/// Number of architected accumulator registers.
pub const NUM_ACCS: usize = 8;

/// A 128-bit vector-scalar register.
///
/// Stored as 16 little-endian bytes; the typed views below interpret the
/// register as a packed row-major matrix of the given element type.
#[derive(Clone, Copy, PartialEq, Eq, Default)]
pub struct Vsr(pub [u8; 16]);

impl std::fmt::Debug for Vsr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Vsr({:02x?})", self.0)
    }
}

impl Vsr {
    /// Build from two `f64` values (a 4×2 accumulator row or a 2-element Y).
    pub fn from_f64x2(v: [f64; 2]) -> Self {
        let mut b = [0u8; 16];
        b[..8].copy_from_slice(&v[0].to_le_bytes());
        b[8..].copy_from_slice(&v[1].to_le_bytes());
        Vsr(b)
    }

    /// Build from four `f32` values.
    pub fn from_f32x4(v: [f32; 4]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
        Vsr(b)
    }

    /// Build from four `i32` values.
    pub fn from_i32x4(v: [i32; 4]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
        Vsr(b)
    }

    /// Build from eight 16-bit lanes (raw bits: i16 / fp16 / bf16).
    pub fn from_u16x8(v: [u16; 8]) -> Self {
        let mut b = [0u8; 16];
        for (i, x) in v.iter().enumerate() {
            b[2 * i..2 * i + 2].copy_from_slice(&x.to_le_bytes());
        }
        Vsr(b)
    }

    /// Build from sixteen bytes (int8 / uint8 lanes).
    pub fn from_u8x16(v: [u8; 16]) -> Self {
        Vsr(v)
    }

    #[inline(always)]
    pub fn f64(&self, lane: usize) -> f64 {
        f64::from_le_bytes(self.0[8 * lane..8 * lane + 8].try_into().unwrap())
    }

    #[inline(always)]
    pub fn f32(&self, lane: usize) -> f32 {
        f32::from_le_bytes(self.0[4 * lane..4 * lane + 4].try_into().unwrap())
    }

    #[inline(always)]
    pub fn u16(&self, lane: usize) -> u16 {
        u16::from_le_bytes(self.0[2 * lane..2 * lane + 2].try_into().unwrap())
    }

    #[inline(always)]
    pub fn i16(&self, lane: usize) -> i16 {
        self.u16(lane) as i16
    }

    #[inline(always)]
    pub fn f16(&self, lane: usize) -> f32 {
        f16_to_f32(self.u16(lane))
    }

    #[inline(always)]
    pub fn bf16(&self, lane: usize) -> f32 {
        bf16_to_f32(self.u16(lane))
    }

    #[inline(always)]
    pub fn i8(&self, lane: usize) -> i8 {
        self.0[lane] as i8
    }

    #[inline(always)]
    pub fn u8(&self, lane: usize) -> u8 {
        self.0[lane]
    }

    /// Signed 4-bit lane `lane` in 0..32 (two lanes per byte, low nibble
    /// first).
    #[inline(always)]
    pub fn i4(&self, lane: usize) -> i32 {
        let byte = self.0[lane / 2];
        let nib = if lane % 2 == 0 { byte & 0xf } else { byte >> 4 };
        int4_sext(nib)
    }
}

/// A 512-bit accumulator value: a 4×4 matrix of 32-bit elements or a 4×2
/// matrix of 64-bit elements (§II-A). Stored as 64 bytes, row-major, 16
/// bytes (= one associated VSR) per row.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Acc(pub [u8; 64]);

impl Default for Acc {
    fn default() -> Self {
        Acc([0u8; 64])
    }
}

impl std::fmt::Debug for Acc {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Acc(f32x4x4 {:?})", self.to_f32_4x4())
    }
}

impl Acc {
    /// Zero accumulator (the `xxsetaccz` value).
    pub fn zero() -> Self {
        Self::default()
    }

    /// Row `r` as a [`Vsr`] (the value `xxmfacc` deposits in `VSR[4a+r]`).
    pub fn row(&self, r: usize) -> Vsr {
        let mut b = [0u8; 16];
        b.copy_from_slice(&self.0[16 * r..16 * r + 16]);
        Vsr(b)
    }

    /// Overwrite row `r` from a VSR (the `xxmtacc` direction).
    pub fn set_row(&mut self, r: usize, v: Vsr) {
        self.0[16 * r..16 * r + 16].copy_from_slice(&v.0);
    }

    #[inline(always)]
    pub fn f32_at(&self, i: usize, j: usize) -> f32 {
        let o = 16 * i + 4 * j;
        f32::from_le_bytes(self.0[o..o + 4].try_into().unwrap())
    }

    #[inline(always)]
    pub fn set_f32_at(&mut self, i: usize, j: usize, v: f32) {
        let o = 16 * i + 4 * j;
        self.0[o..o + 4].copy_from_slice(&v.to_le_bytes());
    }

    #[inline(always)]
    pub fn i32_at(&self, i: usize, j: usize) -> i32 {
        let o = 16 * i + 4 * j;
        i32::from_le_bytes(self.0[o..o + 4].try_into().unwrap())
    }

    #[inline(always)]
    pub fn set_i32_at(&mut self, i: usize, j: usize, v: i32) {
        let o = 16 * i + 4 * j;
        self.0[o..o + 4].copy_from_slice(&v.to_le_bytes());
    }

    #[inline(always)]
    pub fn f64_at(&self, i: usize, j: usize) -> f64 {
        let o = 16 * i + 8 * j;
        f64::from_le_bytes(self.0[o..o + 8].try_into().unwrap())
    }

    #[inline(always)]
    pub fn set_f64_at(&mut self, i: usize, j: usize, v: f64) {
        let o = 16 * i + 8 * j;
        self.0[o..o + 8].copy_from_slice(&v.to_le_bytes());
    }

    pub fn from_f32_4x4(m: [[f32; 4]; 4]) -> Self {
        let mut a = Acc::zero();
        for i in 0..4 {
            for j in 0..4 {
                a.set_f32_at(i, j, m[i][j]);
            }
        }
        a
    }

    pub fn to_f32_4x4(&self) -> [[f32; 4]; 4] {
        let mut m = [[0f32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                m[i][j] = self.f32_at(i, j);
            }
        }
        m
    }

    pub fn from_i32_4x4(m: [[i32; 4]; 4]) -> Self {
        let mut a = Acc::zero();
        for i in 0..4 {
            for j in 0..4 {
                a.set_i32_at(i, j, m[i][j]);
            }
        }
        a
    }

    pub fn to_i32_4x4(&self) -> [[i32; 4]; 4] {
        let mut m = [[0i32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                m[i][j] = self.i32_at(i, j);
            }
        }
        m
    }

    pub fn from_f64_4x2(m: [[f64; 2]; 4]) -> Self {
        let mut a = Acc::zero();
        for i in 0..4 {
            for j in 0..2 {
                a.set_f64_at(i, j, m[i][j]);
            }
        }
        a
    }

    pub fn to_f64_4x2(&self) -> [[f64; 2]; 4] {
        let mut m = [[0f64; 2]; 4];
        for i in 0..4 {
            for j in 0..2 {
                m[i][j] = self.f64_at(i, j);
            }
        }
        m
    }
}

/// The full MMA-visible register state with priming bookkeeping.
#[derive(Clone)]
pub struct RegFile {
    pub vsr: [Vsr; NUM_VSRS],
    pub acc: [Acc; NUM_ACCS],
    /// `primed[i]` ⇔ `ACC[i]` is currently primed: its value lives in the
    /// MME and the associated `VSR[4i..4i+3]` must not be used (§II-A).
    pub primed: [bool; NUM_ACCS],
}

impl Default for RegFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegFile {
    pub fn new() -> Self {
        RegFile {
            vsr: [Vsr::default(); NUM_VSRS],
            acc: [Acc::zero(); NUM_ACCS],
            primed: [false; NUM_ACCS],
        }
    }

    /// The accumulator (if any) whose VSR group contains `vsr`.
    /// `VSR[32:63]` are not associated with any accumulator (Figure 1).
    pub fn acc_of_vsr(vsr: u8) -> Option<u8> {
        if vsr < 32 {
            Some(vsr / 4)
        } else {
            None
        }
    }

    /// True if touching `vsr` would conflict with a *primed* accumulator.
    pub fn vsr_conflicts(&self, vsr: u8) -> bool {
        Self::acc_of_vsr(vsr).is_some_and(|a| self.primed[a as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::types::{f32_to_bf16, f32_to_f16, int4_pack};

    #[test]
    fn vsr_lane_views() {
        let v = Vsr::from_f32x4([1.0, -2.0, 3.5, 0.25]);
        assert_eq!(v.f32(0), 1.0);
        assert_eq!(v.f32(3), 0.25);

        let v = Vsr::from_f64x2([std::f64::consts::PI, -1.0]);
        assert_eq!(v.f64(0), std::f64::consts::PI);
        assert_eq!(v.f64(1), -1.0);

        let v = Vsr::from_u16x8([1, 2, 3, 4, 0xffff, 6, 7, 8]);
        assert_eq!(v.i16(4), -1);
        assert_eq!(v.u16(7), 8);

        let v = Vsr::from_u16x8([f32_to_f16(1.5); 8]);
        assert_eq!(v.f16(3), 1.5);
        let v = Vsr::from_u16x8([f32_to_bf16(-2.0); 8]);
        assert_eq!(v.bf16(5), -2.0);

        let mut bytes = [0u8; 16];
        bytes[0] = int4_pack(-8, 7);
        let v = Vsr::from_u8x16(bytes);
        assert_eq!(v.i4(0), -8);
        assert_eq!(v.i4(1), 7);
    }

    #[test]
    fn acc_rows_round_trip() {
        let m = [[1.0f32, 2.0, 3.0, 4.0], [5.0, 6.0, 7.0, 8.0], [9.0, 10.0, 11.0, 12.0], [13.0, 14.0, 15.0, 16.0]];
        let a = Acc::from_f32_4x4(m);
        assert_eq!(a.to_f32_4x4(), m);
        // row r of the accumulator is the VSR image of that row
        let r2 = a.row(2);
        assert_eq!([r2.f32(0), r2.f32(1), r2.f32(2), r2.f32(3)], m[2]);

        let d = [[1.0f64, 2.0], [3.0, 4.0], [5.0, 6.0], [7.0, 8.0]];
        let a = Acc::from_f64_4x2(d);
        assert_eq!(a.to_f64_4x2(), d);
        assert_eq!(a.row(1).f64(0), 3.0);
    }

    #[test]
    fn vsr_acc_association() {
        assert_eq!(RegFile::acc_of_vsr(0), Some(0));
        assert_eq!(RegFile::acc_of_vsr(3), Some(0));
        assert_eq!(RegFile::acc_of_vsr(4), Some(1));
        assert_eq!(RegFile::acc_of_vsr(31), Some(7));
        assert_eq!(RegFile::acc_of_vsr(32), None);
        assert_eq!(RegFile::acc_of_vsr(63), None);

        let mut rf = RegFile::new();
        rf.primed[2] = true;
        assert!(rf.vsr_conflicts(8));
        assert!(rf.vsr_conflicts(11));
        assert!(!rf.vsr_conflicts(12));
        assert!(!rf.vsr_conflicts(40));
    }
}
