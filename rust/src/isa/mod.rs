//! Functional model of the Power ISA v3.1 **VSX Matrix-Multiply Assist**
//! facility (paper §II) plus the minimal surrounding Power ISA subset needed
//! to run the paper's kernels (VSX loads/stores, fixed-point bookkeeping and
//! the CTR loop).
//!
//! Submodules:
//!
//! * [`types`]  — scalar formats: IEEE fp16, bfloat16, signed int4 packing,
//!   saturating 32-bit accumulation.
//! * [`regs`]   — the register state: 64×128-bit VSRs, 8×512-bit accumulators
//!   with the VSR-group aliasing and priming rules of §II-A.
//! * [`inst`]   — the instruction set: every Table I instruction (all suffix
//!   forms) plus the support subset; shape/type metadata.
//! * [`exec`]   — the functional interpreter (`Machine`): rank-k update
//!   semantics (eq. 1–3), the priming state machine, memory, and the CTR
//!   loop, with strict architectural checking.
//! * [`encode`] — 32-bit word and 64-bit prefixed binary encodings;
//!   validated against the paper's Figure 7 object code.
//! * [`asm`]    — textual assembler / disassembler in the paper's syntax
//!   (e.g. `xvf64gerpp a4, vs44, vs40`).

pub mod asm;
pub mod encode;
pub mod exec;
pub mod inst;
pub mod regs;
pub mod types;

pub use exec::{ExecError, Machine};
pub use inst::{AccOp, GerKind, Inst};
pub use regs::{Acc, RegFile, Vsr, NUM_ACCS, NUM_VSRS};
