//! Scalar element formats used by the MMA facility (paper Table I):
//! IEEE binary16 (`fp16`), bfloat16 (`bf16`), signed 4-bit integers packed
//! two per byte (`int4`), and the modulo vs. saturating 32-bit accumulation
//! models of the integer rank-k update instructions (§II-B.2).
//!
//! All conversions are implemented from first principles (no external
//! softfloat dependency) with round-to-nearest-even, the rounding mode the
//! POWER10 MME applies to rank-k update results.

/// Convert an IEEE binary16 bit pattern to `f32`.
///
/// Handles subnormals, infinities and NaNs (NaN payloads are propagated into
/// the top mantissa bits, quietly).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = u32::from(h >> 15) << 31;
    let exp = (h >> 10) & 0x1f;
    let man = u32::from(h & 0x3ff);
    let bits = match (exp, man) {
        (0, 0) => sign,                            // +-0
        (0, m) => {
            // subnormal: value = m * 2^-24; renormalize around the msb of m
            let p = 31 - m.leading_zeros(); // msb position of the 10-bit mantissa
            let exp32 = 127 + p - 24;
            let man32 = (m << (23 - p)) & 0x7f_ffff; // drop implicit bit
            sign | (exp32 << 23) | man32
        }
        (0x1f, 0) => sign | 0x7f80_0000,           // inf
        (0x1f, m) => sign | 0x7fc0_0000 | (m << 13), // NaN (quiet)
        (e, m) => {
            let exp32 = u32::from(e) + 127 - 15;
            sign | (exp32 << 23) | (m << 13)
        }
    };
    f32::from_bits(bits)
}

/// Convert an `f32` to IEEE binary16 with round-to-nearest-even.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp == 0xff {
        // inf / NaN
        return if man == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 | ((man >> 13) as u16 & 0x3ff) | u16::from(man >> 13 == 0)
        };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal range; round mantissa from 23 to 10 bits (RNE)
        let man16 = man >> 13;
        let rem = man & 0x1fff;
        let mut h = sign | (((e + 15) as u16) << 10) | man16 as u16;
        if rem > 0x1000 || (rem == 0x1000 && (man16 & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent: that is correct RNE
        }
        return h;
    }
    if e < -25 {
        return sign; // underflow to zero
    }
    // subnormal result
    let man_full = man | 0x80_0000; // implicit bit
    let shift = (-14 - e) as u32 + 13;
    let man16 = man_full >> shift;
    let rem_mask = (1u32 << shift) - 1;
    let rem = man_full & rem_mask;
    let half = 1u32 << (shift - 1);
    let mut h = sign | man16 as u16;
    if rem > half || (rem == half && (man16 & 1) == 1) {
        h = h.wrapping_add(1);
    }
    h
}

/// Convert a bfloat16 bit pattern to `f32` (exact: bf16 is truncated f32).
#[inline(always)]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits(u32::from(b) << 16)
}

/// The shared round-to-nearest-even core of every f32→bf16 conversion in
/// the crate: rounds a **non-NaN** f32 bit pattern to the nearest bf16
/// (a rounded-away carry propagating into the exponent — including
/// overflow to infinity — is correct RNE). NaN policy is the *only*
/// thing the public converters disagree on, so it stays out of here.
#[inline(always)]
fn bf16_rne_bits(bits: u32) -> u16 {
    let round_bit = 0x8000u32;
    let lsb = (bits >> 16) & 1;
    let rem = bits & 0xffff;
    let mut b = (bits >> 16) as u16;
    if rem > round_bit || (rem == round_bit && lsb == 1) {
        b = b.wrapping_add(1);
    }
    b
}

/// Convert an `f32` to bfloat16 with round-to-nearest-even — the MMA
/// hardware input contract. NaNs are quieted (payload preserved in the
/// top bits) so that a NaN never rounds to infinity.
///
/// This is the crate's **single source** of the f32→bf16 rounding
/// (`runtime::device` re-exports it; `runtime::hlo::bf16_round` wraps
/// the canonical-NaN variant [`f32_to_bf16_canonical`] over the same
/// RNE core).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // force quiet bit
    }
    bf16_rne_bits(bits)
}

/// Convert an `f32` to bfloat16 with round-to-nearest-even and the XLA
/// `convert` NaN policy: any NaN becomes the **canonical quiet NaN**
/// with its sign preserved and payload dropped (`0x7fc0` / `0xffc0`).
/// Identical to [`f32_to_bf16`] on every non-NaN input (same RNE core).
/// This is the rounding the bf16 panel packers fuse into packing.
pub fn f32_to_bf16_canonical(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16 & 0x8000) | 0x7fc0;
    }
    bf16_rne_bits(bits)
}

/// Canonicalize **raw bf16 bits**: NaN patterns collapse to the
/// sign-preserved canonical quiet NaN (exactly what
/// [`f32_to_bf16_canonical`] would produce after an exact widening),
/// everything else passes through untouched. The raw-bits panel packers
/// apply this so the no-widening path stays bitwise identical to the
/// widen-then-round path on every input, NaN payloads included.
#[inline(always)]
pub fn bf16_canon_nan(b: u16) -> u16 {
    if (b & 0x7fff) > 0x7f80 {
        (b & 0x8000) | 0x7fc0
    } else {
        b
    }
}

/// Sign-extend a 4-bit value (stored in the low nibble) to `i32`.
#[inline(always)]
pub fn int4_sext(nibble: u8) -> i32 {
    ((nibble as i32) << 28) >> 28
}

/// Pack two signed 4-bit values `(lo, hi)` into one byte.
/// `lo` occupies bits 0..4, `hi` bits 4..8 (little-nibble order, matching
/// the element order used by [`crate::isa::regs::Vsr::i4`]).
#[inline(always)]
pub fn int4_pack(lo: i32, hi: i32) -> u8 {
    debug_assert!((-8..=7).contains(&lo) && (-8..=7).contains(&hi));
    ((lo & 0xf) as u8) | (((hi & 0xf) as u8) << 4)
}

/// 32-bit signed saturating add (the `s`-suffix arithmetic model, §II-B.2):
/// "adding positive values to the largest representable integer ... does not
/// change the target value".
#[inline(always)]
pub fn sat_add_i32(a: i32, b: i64) -> i32 {
    let r = i64::from(a) + b;
    r.clamp(i64::from(i32::MIN), i64::from(i32::MAX)) as i32
}

/// 32-bit modulo (wrapping) add — the default integer accumulation model.
#[inline(always)]
pub fn mod_add_i32(a: i32, b: i64) -> i32 {
    a.wrapping_add(b as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trip_exact_values() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.103515625e-5] {
            let h = f32_to_f16(v);
            assert_eq!(f16_to_f32(h), v, "value {v}");
        }
    }

    #[test]
    fn f16_inf_nan() {
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(1e9), 0x7c00, "overflow saturates to inf");
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn f16_subnormals() {
        // smallest positive subnormal: 2^-24
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        // largest subnormal
        let largest_sub = 2.0f32.powi(-14) * (1023.0 / 1024.0);
        assert_eq!(f16_to_f32(0x03ff), largest_sub);
        assert_eq!(f32_to_f16(largest_sub), 0x03ff);
        // underflow to zero
        assert_eq!(f32_to_f16(1e-10), 0);
    }

    #[test]
    fn f16_rne_ties() {
        // 1 + 2^-11 is exactly half way between 1.0 and 1+2^-10 -> ties to even (1.0)
        let tie = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie), f32_to_f16(1.0));
        // 1 + 3*2^-11 ties upward to 1+2^-9's neighbour (even mantissa 2)
        let tie_up = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie_up), 0x3c02);
    }

    #[test]
    fn bf16_round_trip() {
        for &v in &[0.0f32, 1.0, -2.5, 3.140625, 1e30, -1e-30] {
            let b = f32_to_bf16(v);
            let back = bf16_to_f32(b);
            let rel = if v == 0.0 { 0.0 } else { ((back - v) / v).abs() };
            assert!(rel <= 1.0 / 128.0, "value {v} -> {back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn bf16_rne() {
        // 1.0 + 2^-9 rounds to nearest-even bf16 of 1.0
        assert_eq!(f32_to_bf16(1.0 + 2.0f32.powi(-9)), f32_to_bf16(1.0));
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 3.0 * 2.0f32.powi(-9))), 1.0 + 2.0f32.powi(-7));
    }

    #[test]
    fn bf16_converters_share_one_rne_core() {
        // the satellite contract: every f32->bf16 conversion in the crate
        // rounds through bf16_rne_bits, so the two public converters (and
        // the runtime re-exports / bf16_round wrapper over them) can only
        // disagree on NaN policy. Pin that on a value sweep that crosses
        // ties, carries, subnormals, signed zeros and infinities.
        let cases = [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            1.0 + 2.0f32.powi(-9),        // exact tie -> even (down)
            1.0 + 3.0 * 2.0f32.powi(-9),  // exact tie -> even (up)
            1.0 + 2.0f32.powi(-8),        // above halfway
            f32::from_bits(0x7f7f_ffff),  // max finite: rounds up to inf
            f32::from_bits(0x0000_0001),  // smallest subnormal
            f32::from_bits(0x0080_0000),  // smallest normal
            6.1e-39,                      // subnormal range
            f32::INFINITY,
            f32::NEG_INFINITY,
            1e30,
            -1e-30,
        ];
        for &v in &cases {
            assert_eq!(
                f32_to_bf16(v),
                f32_to_bf16_canonical(v),
                "non-NaN value {v:?} must round identically through both converters"
            );
        }
        assert_eq!(f32_to_bf16_canonical(f32::from_bits(0x7f7f_ffff)), 0x7f80, "overflow -> inf");
        // NaN is where the contracts differ: the ISA converter quiets and
        // keeps the payload, the XLA converter canonicalizes.
        let snan = f32::from_bits(0x7f81_2345);
        assert_eq!(f32_to_bf16(snan), 0x7f81 | 0x0040);
        assert_eq!(f32_to_bf16_canonical(snan), 0x7fc0);
        let neg_nan = f32::from_bits(0xffc1_0000);
        assert_eq!(f32_to_bf16_canonical(neg_nan), 0xffc0, "sign survives canonicalization");
    }

    #[test]
    fn bf16_canon_nan_matches_widen_then_round() {
        // raw-bits canonicalization must equal "widen exactly, then
        // convert with the canonical-NaN policy" for every u16 pattern —
        // the invariant that keeps the raw-bf16 panel path bitwise
        // identical to the staged f32 path.
        for bits in 0..=u16::MAX {
            let via_f32 = f32_to_bf16_canonical(bf16_to_f32(bits));
            assert_eq!(bf16_canon_nan(bits), via_f32, "bits {bits:#06x}");
        }
        // spot-check the interesting classes
        assert_eq!(bf16_canon_nan(0x7f80), 0x7f80, "inf passes through");
        assert_eq!(bf16_canon_nan(0xff80), 0xff80, "-inf passes through");
        assert_eq!(bf16_canon_nan(0x7f81), 0x7fc0, "sNaN canonicalizes");
        assert_eq!(bf16_canon_nan(0xffff), 0xffc0, "-NaN keeps its sign");
        assert_eq!(bf16_canon_nan(0x8000), 0x8000, "-0.0 passes through");
        assert_eq!(bf16_canon_nan(0x0001), 0x0001, "subnormal passes through");
    }

    #[test]
    fn int4() {
        assert_eq!(int4_sext(0x0), 0);
        assert_eq!(int4_sext(0x7), 7);
        assert_eq!(int4_sext(0x8), -8);
        assert_eq!(int4_sext(0xf), -1);
        let b = int4_pack(-3, 5);
        assert_eq!(int4_sext(b & 0xf), -3);
        assert_eq!(int4_sext(b >> 4), 5);
    }

    #[test]
    fn saturating_vs_modulo() {
        assert_eq!(sat_add_i32(i32::MAX, 1), i32::MAX);
        assert_eq!(sat_add_i32(i32::MIN, -1), i32::MIN);
        assert_eq!(sat_add_i32(5, -10), -5);
        assert_eq!(mod_add_i32(i32::MAX, 1), i32::MIN);
        assert_eq!(mod_add_i32(-1, 2), 1);
    }
}
