//! Binary encodings of the simulated instruction subset.
//!
//! The rank-k update instructions use the XX3 form in primary opcode space
//! 59 with the XO assignments of Power ISA v3.1 (as shipped in binutils'
//! `ppc-opc.c`); the accumulator moves use X-form opcode 31 / XO 177; the
//! prefixed (`pm…`) forms carry an MMIRR prefix word (`0x0790_0000`-class)
//! holding the PMSK/XMSK/YMSK immediates (§II-C).
//!
//! Ground truth: the encoder reproduces, byte for byte, the object-code
//! listing of the paper's **Figure 7** (`lxvp`/`lxv`/`addi`/`xvf64gerpp`/
//! `bdnz` loop) — see `fig7_object_code` in the tests and
//! `rust/tests/fig7.rs`.
//!
//! Field-order note: mask immediates are MSB-first in the ISA (`x = x0…x3`,
//! eq. 3) while [`crate::isa::inst::Ger`] stores masks LSB-first (bit i =
//! element i); `msk_to_field`/`field_to_msk` convert.

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};

/// Encoding/decoding failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The instruction has no defined encoding (e.g. unarchitected form).
    Unencodable(String),
    /// The word (pair) does not decode to a supported instruction.
    Undecodable(u32),
    /// A prefixed instruction straddled the end of the buffer.
    Truncated,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Unencodable(m) => write!(f, "no encoding for {m}"),
            CodecError::Undecodable(w) => write!(f, "cannot decode word {w:#010x}"),
            CodecError::Truncated => write!(f, "truncated prefixed instruction"),
        }
    }
}

impl std::error::Error for CodecError {}

/// XO (bits 21–28) for a (kind, accop) pair — Power ISA v3.1 assignments.
pub fn ger_xo(kind: GerKind, op: AccOp) -> Option<u32> {
    use AccOp::*;
    use GerKind::*;
    Some(match (kind, op) {
        (I8Ger4, PP) => 2,
        (I8Ger4, New) => 3,
        (F16Ger2, PP) => 18,
        (F16Ger2, New) => 19,
        (F32Ger, PP) => 26,
        (F32Ger, New) => 27,
        (I4Ger8, PP) => 34,
        (I4Ger8, New) => 35,
        (I16Ger2, SPP) => 42,
        (I16Ger2, NewS) => 43,
        (Bf16Ger2, PP) => 50,
        (Bf16Ger2, New) => 51,
        (F64Ger, PP) => 58,
        (F64Ger, New) => 59,
        (I16Ger2, New) => 75,
        (F16Ger2, NP) => 82,
        (F32Ger, NP) => 90,
        (I8Ger4, SPP) => 99,
        (I16Ger2, PP) => 107,
        (Bf16Ger2, NP) => 114,
        (F64Ger, NP) => 122,
        (F16Ger2, PN) => 146,
        (F32Ger, PN) => 154,
        (Bf16Ger2, PN) => 178,
        (F64Ger, PN) => 186,
        (F16Ger2, NN) => 210,
        (F32Ger, NN) => 218,
        (Bf16Ger2, NN) => 242,
        (F64Ger, NN) => 250,
        _ => return None,
    })
}

fn xo_to_ger(xo: u32) -> Option<(GerKind, AccOp)> {
    use AccOp::*;
    use GerKind::*;
    Some(match xo {
        2 => (I8Ger4, PP),
        3 => (I8Ger4, New),
        18 => (F16Ger2, PP),
        19 => (F16Ger2, New),
        26 => (F32Ger, PP),
        27 => (F32Ger, New),
        34 => (I4Ger8, PP),
        35 => (I4Ger8, New),
        42 => (I16Ger2, SPP),
        43 => (I16Ger2, NewS),
        50 => (Bf16Ger2, PP),
        51 => (Bf16Ger2, New),
        58 => (F64Ger, PP),
        59 => (F64Ger, New),
        75 => (I16Ger2, New),
        82 => (F16Ger2, NP),
        90 => (F32Ger, NP),
        99 => (I8Ger4, SPP),
        107 => (I16Ger2, PP),
        114 => (Bf16Ger2, NP),
        122 => (F64Ger, NP),
        146 => (F16Ger2, PN),
        154 => (F32Ger, PN),
        178 => (Bf16Ger2, PN),
        186 => (F64Ger, PN),
        210 => (F16Ger2, NN),
        218 => (F32Ger, NN),
        242 => (Bf16Ger2, NN),
        250 => (F64Ger, NN),
        _ => return None,
    })
}

/// LSB-first mask (bit i = element i) → MSB-first immediate field of `w` bits.
fn msk_to_field(m: u8, w: u32) -> u32 {
    let mut f = 0u32;
    for i in 0..w {
        if (m >> i) & 1 == 1 {
            f |= 1 << (w - 1 - i);
        }
    }
    f
}

fn field_to_msk(f: u32, w: u32) -> u8 {
    let mut m = 0u8;
    for i in 0..w {
        if (f >> (w - 1 - i)) & 1 == 1 {
            m |= 1 << i;
        }
    }
    m
}

/// Width of the PMSK field for a kind (0 = rank-1, no product mask).
fn pmsk_width(kind: GerKind) -> u32 {
    match kind.rank() {
        1 => 0,
        r => r as u32,
    }
}

fn ymsk_width(kind: GerKind) -> u32 {
    match kind {
        GerKind::F64Ger => 2,
        _ => 4,
    }
}

/// Encode the 32-bit suffix word of a ger instruction (also the whole
/// conventional form).
fn encode_ger_word(g: &Ger) -> Result<u32, CodecError> {
    let xo = ger_xo(g.kind, g.op).ok_or_else(|| CodecError::Unencodable(g.mnemonic()))?;
    let at = u32::from(g.acc & 0x7);
    let a = u32::from(g.xa);
    let b = u32::from(g.yb);
    let (a5, ax) = (a & 0x1f, a >> 5);
    let (b5, bx) = (b & 0x1f, b >> 5);
    Ok((59 << 26) | (at << 23) | (a5 << 16) | (b5 << 11) | (xo << 3) | (ax << 2) | (bx << 1))
}

/// Encode the MMIRR prefix word (masks MSB-first per eq. 3):
/// `PMSK` left-aligned at bit 16, `XMSK` at bits 24–27, `YMSK` at bit 28.
fn encode_ger_prefix(g: &Ger) -> u32 {
    let pw = pmsk_width(g.kind);
    let yw = ymsk_width(g.kind);
    let mut p = 0x0790_0000u32;
    if pw > 0 {
        p |= msk_to_field(g.pmsk, pw) << (16 - pw); // field occupies bits 16..16+pw (MSB-first) => shift from bit 15 downwards
    }
    p |= msk_to_field(g.xmsk, 4) << 4;
    p |= msk_to_field(g.ymsk, yw) << (4 - yw);
    p
}

fn decode_ger_prefix(prefix: u32, kind: GerKind) -> (u8, u8, u8) {
    let pw = pmsk_width(kind);
    let yw = ymsk_width(kind);
    let pmsk = if pw > 0 {
        field_to_msk((prefix >> (16 - pw)) & ((1 << pw) - 1), pw)
    } else {
        0xff
    };
    let xmsk = field_to_msk((prefix >> 4) & 0xf, 4);
    let ymsk = field_to_msk((prefix >> (4 - yw)) & ((1 << yw) - 1), yw);
    (xmsk, ymsk, pmsk)
}

/// Encode one instruction, appending 4 or 8 bytes (little-endian words, the
/// byte order of the paper's Figure 7 listing).
pub fn encode(inst: &Inst, out: &mut Vec<u8>) -> Result<(), CodecError> {
    let mut push = |w: u32| out.extend_from_slice(&w.to_le_bytes());
    match *inst {
        Inst::Ger(ref g) => {
            if g.prefixed {
                push(encode_ger_prefix(g));
            }
            push(encode_ger_word(g)?);
        }
        Inst::XxMfAcc { acc } => push((31 << 26) | (u32::from(acc) << 23) | (177 << 1)),
        Inst::XxMtAcc { acc } => push((31 << 26) | (u32::from(acc) << 23) | (1 << 16) | (177 << 1)),
        Inst::XxSetAccZ { acc } => push((31 << 26) | (u32::from(acc) << 23) | (3 << 16) | (177 << 1)),
        Inst::Lxv { xt, ra, dq } => {
            let t = u32::from(xt);
            let dq16 = ((dq >> 4) as u32) & 0xfff;
            push((61 << 26) | ((t & 0x1f) << 21) | (u32::from(ra) << 16) | (dq16 << 4) | ((t >> 5) << 3) | 0b001);
        }
        Inst::Stxv { xs, ra, dq } => {
            let t = u32::from(xs);
            let dq16 = ((dq >> 4) as u32) & 0xfff;
            push((61 << 26) | ((t & 0x1f) << 21) | (u32::from(ra) << 16) | (dq16 << 4) | ((t >> 5) << 3) | 0b101);
        }
        Inst::Lxvp { xtp, ra, dq } => {
            let tp = (u32::from(xtp) & 0x1f) / 2;
            let tx = u32::from(xtp) >> 5;
            let dq16 = ((dq >> 4) as u32) & 0xfff;
            push((6 << 26) | (tp << 22) | (tx << 21) | (u32::from(ra) << 16) | (dq16 << 4));
        }
        Inst::Stxvp { xsp, ra, dq } => {
            let tp = (u32::from(xsp) & 0x1f) / 2;
            let tx = u32::from(xsp) >> 5;
            let dq16 = ((dq >> 4) as u32) & 0xfff;
            push((6 << 26) | (tp << 22) | (tx << 21) | (u32::from(ra) << 16) | (dq16 << 4) | 0b0001);
        }
        Inst::XvMaddaDp { xt, xa, xb }
        | Inst::XvMaddaSp { xt, xa, xb }
        | Inst::Xxlor { xt, xa, xb }
        | Inst::Xxlxor { xt, xa, xb } => {
            // XX3-form, opcode 60: xvmaddadp XO=97, xvmaddasp XO=65,
            // xxlor XO=146, xxlxor XO=154
            let xo = match inst {
                Inst::XvMaddaDp { .. } => 97u32,
                Inst::XvMaddaSp { .. } => 65,
                Inst::Xxlor { .. } => 146,
                _ => 154,
            };
            let (t5, tx) = (u32::from(xt) & 0x1f, u32::from(xt) >> 5);
            let (a5, ax) = (u32::from(xa) & 0x1f, u32::from(xa) >> 5);
            let (b5, bx) = (u32::from(xb) & 0x1f, u32::from(xb) >> 5);
            push((60 << 26) | (t5 << 21) | (a5 << 16) | (b5 << 11) | (xo << 3) | (ax << 2) | (bx << 1) | tx);
        }
        Inst::XxSpltd { xt, xa, h } => {
            // xxpermdi with DM = h ? 0b11 : 0b00 (both halves from lane h)
            let dm = if h & 1 == 1 { 0b11u32 } else { 0b00 };
            let (t5, tx) = (u32::from(xt) & 0x1f, u32::from(xt) >> 5);
            let (a5, ax) = (u32::from(xa) & 0x1f, u32::from(xa) >> 5);
            push((60 << 26) | (t5 << 21) | (a5 << 16) | (a5 << 11) | (dm << 8) | (10 << 3) | (ax << 2) | (ax << 1) | tx);
        }
        Inst::XxSpltw { xt, xa, w } => {
            // XX2-form xxspltw: opcode 60, XO(bits 21-29) = 164, UIM at bits 14-15
            let (t5, tx) = (u32::from(xt) & 0x1f, u32::from(xt) >> 5);
            let (a5, ax) = (u32::from(xa) & 0x1f, u32::from(xa) >> 5);
            push((60 << 26) | (t5 << 21) | (u32::from(w & 3) << 16) | (a5 << 11) | (164 << 2) | (ax << 1) | tx);
        }
        Inst::Addi { rt, ra, si } => {
            push((14 << 26) | (u32::from(rt) << 21) | (u32::from(ra) << 16) | ((si as u32) & 0xffff));
        }
        Inst::Mtctr { rs } => {
            // mtspr CTR: SPR=9, field halves swapped
            let spr = ((9u32 & 0x1f) << 5) | (9 >> 5);
            push((31 << 26) | (u32::from(rs) << 21) | (spr << 11) | (467 << 1));
        }
        Inst::Bdnz { bd } => {
            push((16 << 26) | (16 << 21) | (((bd >> 2) as u32 & 0x3fff) << 2));
        }
        Inst::Blr => push(0x4E80_0020),
        Inst::Nop => push(0x6000_0000),
    }
    Ok(())
}

/// Encode a whole program to bytes.
pub fn encode_program(prog: &[Inst]) -> Result<Vec<u8>, CodecError> {
    let mut out = Vec::with_capacity(prog.len() * 4);
    for i in prog {
        encode(i, &mut out)?;
    }
    Ok(out)
}

/// Decode one instruction from `bytes[off..]`; returns `(inst, size)`.
pub fn decode(bytes: &[u8], off: usize) -> Result<(Inst, usize), CodecError> {
    if off + 4 > bytes.len() {
        return Err(CodecError::Truncated);
    }
    let w = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    let opcd = w >> 26;
    // prefixed instruction?
    if opcd == 1 {
        if off + 8 > bytes.len() {
            return Err(CodecError::Truncated);
        }
        let suffix = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        let inst = decode_word(suffix, Some(w))?;
        return Ok((inst, 8));
    }
    Ok((decode_word(w, None)?, 4))
}

fn decode_word(w: u32, prefix: Option<u32>) -> Result<Inst, CodecError> {
    let opcd = w >> 26;
    match opcd {
        59 => {
            let xo = (w >> 3) & 0xff;
            let (kind, op) = xo_to_ger(xo).ok_or(CodecError::Undecodable(w))?;
            let at = ((w >> 23) & 0x7) as u8;
            let a = (((w >> 16) & 0x1f) | ((w >> 2) & 1) << 5) as u8;
            let b = (((w >> 11) & 0x1f) | ((w >> 1) & 1) << 5) as u8;
            let g = match prefix {
                None => Ger::new(kind, op, at, a, b),
                Some(p) => {
                    let (xmsk, ymsk, pmsk) = decode_ger_prefix(p, kind);
                    Ger::prefixed(kind, op, at, a, b, xmsk, ymsk, pmsk)
                }
            };
            Ok(Inst::Ger(g))
        }
        31 => {
            let xo10 = (w >> 1) & 0x3ff;
            match xo10 {
                177 => {
                    let at = ((w >> 23) & 0x7) as u8;
                    match (w >> 16) & 0x1f {
                        0 => Ok(Inst::XxMfAcc { acc: at }),
                        1 => Ok(Inst::XxMtAcc { acc: at }),
                        3 => Ok(Inst::XxSetAccZ { acc: at }),
                        _ => Err(CodecError::Undecodable(w)),
                    }
                }
                467 => {
                    let spr = (w >> 11) & 0x3ff;
                    let spr = ((spr >> 5) & 0x1f) | ((spr & 0x1f) << 5);
                    if spr == 9 {
                        Ok(Inst::Mtctr { rs: ((w >> 21) & 0x1f) as u8 })
                    } else {
                        Err(CodecError::Undecodable(w))
                    }
                }
                _ => Err(CodecError::Undecodable(w)),
            }
        }
        61 => {
            let t = (((w >> 21) & 0x1f) | ((w >> 3) & 1) << 5) as u8;
            let ra = ((w >> 16) & 0x1f) as u8;
            let dq16 = (w >> 4) & 0xfff;
            // sign-extend the 12-bit DQ then scale by 16
            let dq = (((dq16 as i32) << 20) >> 20) * 16;
            match w & 0b111 {
                0b001 => Ok(Inst::Lxv { xt: t, ra, dq }),
                0b101 => Ok(Inst::Stxv { xs: t, ra, dq }),
                _ => Err(CodecError::Undecodable(w)),
            }
        }
        6 => {
            let tp = (w >> 22) & 0xf;
            let tx = (w >> 21) & 1;
            let reg = (tx << 5 | tp * 2) as u8;
            let ra = ((w >> 16) & 0x1f) as u8;
            let dq16 = (w >> 4) & 0xfff;
            let dq = (((dq16 as i32) << 20) >> 20) * 16;
            match w & 0xf {
                0b0000 => Ok(Inst::Lxvp { xtp: reg, ra, dq }),
                0b0001 => Ok(Inst::Stxvp { xsp: reg, ra, dq }),
                _ => Err(CodecError::Undecodable(w)),
            }
        }
        60 => {
            if (w >> 2) & 0x1ff == 164 {
                // XX2 xxspltw
                let xt = (((w >> 21) & 0x1f) | ((w & 1) << 5)) as u8;
                let xa = (((w >> 11) & 0x1f) | ((w >> 1) & 1) << 5) as u8;
                return Ok(Inst::XxSpltw { xt, xa, w: ((w >> 16) & 3) as u8 });
            }
            let xo8 = (w >> 3) & 0xff;
            let xt = (((w >> 21) & 0x1f) | ((w & 1) << 5)) as u8;
            let xa = (((w >> 16) & 0x1f) | ((w >> 2) & 1) << 5) as u8;
            let xb = (((w >> 11) & 0x1f) | ((w >> 1) & 1) << 5) as u8;
            match xo8 {
                97 => Ok(Inst::XvMaddaDp { xt, xa, xb }),
                65 => Ok(Inst::XvMaddaSp { xt, xa, xb }),
                146 => Ok(Inst::Xxlor { xt, xa, xb }),
                154 => Ok(Inst::Xxlxor { xt, xa, xb }),
                10 => Ok(Inst::XxSpltd { xt, xa, h: 0 }),
                106 => Ok(Inst::XxSpltd { xt, xa, h: 1 }),
                _ => Err(CodecError::Undecodable(w)),
            }
        }
        14 => Ok(Inst::Addi {
            rt: ((w >> 21) & 0x1f) as u8,
            ra: ((w >> 16) & 0x1f) as u8,
            si: ((w & 0xffff) as i32) << 16 >> 16,
        }),
        16 => {
            let bo = (w >> 21) & 0x1f;
            if bo != 16 {
                return Err(CodecError::Undecodable(w));
            }
            let bd14 = (w >> 2) & 0x3fff;
            let bd = (((bd14 as i32) << 18) >> 18) * 4;
            Ok(Inst::Bdnz { bd })
        }
        19 if w == 0x4E80_0020 => Ok(Inst::Blr),
        24 if w == 0x6000_0000 => Ok(Inst::Nop),
        _ => Err(CodecError::Undecodable(w)),
    }
}

/// Decode a whole byte buffer into a program.
pub fn decode_program(bytes: &[u8]) -> Result<Vec<Inst>, CodecError> {
    let mut prog = Vec::new();
    let mut off = 0;
    while off < bytes.len() {
        let (inst, sz) = decode(bytes, off)?;
        prog.push(inst);
        off += sz;
    }
    Ok(prog)
}

/// The paper's Figure 7: the DGEMM kernel computation loop, as compiled
/// by g++ 11 (IBM Advance Toolchain 15). Words transcribed from the
/// listing (byte columns are little-endian in the listing). Ground truth
/// for the encoder and for the generated DGEMM kernel.
pub const FIG7_WORDS: [u32; 17] = [
    0x19A4_0040, // lxvp  vs44, 64(r4)
    0x1824_0060, // lxvp  vs32, 96(r4)
    0x38A5_0040, // addi  r5, r5, 64
    0x3884_0040, // addi  r4, r4, 64
    0xF505_0009, // lxv   vs40, 0(r5)
    0xF525_0019, // lxv   vs41, 16(r5)
    0xF545_0029, // lxv   vs42, 32(r5)
    0xF565_0039, // lxv   vs43, 48(r5)
    0xEE0C_41D6, // xvf64gerpp a4, vs44, vs40
    0xED80_41D6, // xvf64gerpp a3, vs32, vs40
    0xEE8C_49D6, // xvf64gerpp a5, vs44, vs41
    0xEC80_49D6, // xvf64gerpp a1, vs32, vs41
    0xEF0C_51D6, // xvf64gerpp a6, vs44, vs42
    0xED00_51D6, // xvf64gerpp a2, vs32, vs42
    0xEF8C_59D6, // xvf64gerpp a7, vs44, vs43
    0xEC00_59D6, // xvf64gerpp a0, vs32, vs43
    0x4200_FFC0, // bdnz  -64
];

#[cfg(test)]
mod tests {
    use super::*;



    fn fig7_program() -> Vec<Inst> {
        use crate::isa::inst::{AccOp::PP, GerKind::F64Ger};
        let ger = |acc, xa, yb| Inst::Ger(Ger::new(F64Ger, PP, acc, xa, yb));
        vec![
            Inst::Lxvp { xtp: 44, ra: 4, dq: 64 },
            Inst::Lxvp { xtp: 32, ra: 4, dq: 96 },
            Inst::Addi { rt: 5, ra: 5, si: 64 },
            Inst::Addi { rt: 4, ra: 4, si: 64 },
            Inst::Lxv { xt: 40, ra: 5, dq: 0 },
            Inst::Lxv { xt: 41, ra: 5, dq: 16 },
            Inst::Lxv { xt: 42, ra: 5, dq: 32 },
            Inst::Lxv { xt: 43, ra: 5, dq: 48 },
            ger(4, 44, 40),
            ger(3, 32, 40),
            ger(5, 44, 41),
            ger(1, 32, 41),
            ger(6, 44, 42),
            ger(2, 32, 42),
            ger(7, 44, 43),
            ger(0, 32, 43),
            Inst::Bdnz { bd: -64 },
        ]
    }

    #[test]
    fn fig7_object_code() {
        // our assembler must reproduce the paper's listing byte-for-byte
        let prog = fig7_program();
        let bytes = encode_program(&prog).unwrap();
        let mut expect = Vec::new();
        for w in super::FIG7_WORDS {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(bytes, expect);
        // and the disassembler must round-trip it
        assert_eq!(decode_program(&bytes).unwrap(), prog);
    }

    #[test]
    fn xo_table_is_injective() {
        use crate::isa::inst::{AccOp, GerKind};
        let ops = [AccOp::New, AccOp::NewS, AccOp::PP, AccOp::NP, AccOp::PN, AccOp::NN, AccOp::SPP];
        let mut seen = std::collections::HashMap::new();
        for kind in GerKind::ALL {
            for op in ops {
                if let Some(xo) = ger_xo(kind, op) {
                    assert!(op.valid_for(kind), "{kind:?} {op:?} encoded but not architected");
                    if let Some(prev) = seen.insert(xo, (kind, op)) {
                        panic!("XO {xo} assigned to both {prev:?} and {:?}", (kind, op));
                    }
                } else {
                    assert!(!op.valid_for(kind), "{kind:?} {op:?} architected but unencodable");
                }
            }
        }
        assert_eq!(seen.len(), 29, "Table I lists 29 ger forms");
    }

    #[test]
    fn mask_field_order() {
        // eq.3 order: x0 is the MSB of the immediate field
        assert_eq!(msk_to_field(0b0001, 4), 0b1000);
        assert_eq!(msk_to_field(0b1010, 4), 0b0101);
        assert_eq!(field_to_msk(0b1000, 4), 0b0001);
        for m in 0..16u8 {
            assert_eq!(field_to_msk(msk_to_field(m, 4), 4), m);
        }
    }

    #[test]
    fn prefixed_round_trip_all_kinds() {
        use crate::isa::inst::{AccOp, GerKind};
        for kind in GerKind::ALL {
            let yw = super::ymsk_width(kind);
            let pw = super::pmsk_width(kind);
            let g = Ger::prefixed(
                kind,
                AccOp::New,
                3,
                34,
                35,
                0b0101,
                if yw == 2 { 0b01 } else { 0b1001 },
                if pw == 0 { 0xff } else { (1 << (pw - 1)) | 1 },
            );
            let mut bytes = Vec::new();
            encode(&Inst::Ger(g), &mut bytes).unwrap();
            assert_eq!(bytes.len(), 8);
            let (inst, sz) = decode(&bytes, 0).unwrap();
            assert_eq!(sz, 8);
            assert_eq!(inst, Inst::Ger(g), "{kind:?}");
        }
    }

    #[test]
    fn moves_round_trip() {
        for acc in 0..8u8 {
            for inst in [Inst::XxSetAccZ { acc }, Inst::XxMfAcc { acc }, Inst::XxMtAcc { acc }] {
                let mut b = Vec::new();
                encode(&inst, &mut b).unwrap();
                assert_eq!(decode(&b, 0).unwrap(), (inst, 4));
            }
        }
    }

    #[test]
    fn support_round_trip() {
        let insts = [
            Inst::Lxv { xt: 63, ra: 3, dq: -32 },
            Inst::Stxv { xs: 0, ra: 31, dq: 2032 },
            Inst::Lxvp { xtp: 62, ra: 1, dq: 480 },
            Inst::Stxvp { xsp: 4, ra: 2, dq: -16 },
            Inst::Addi { rt: 1, ra: 0, si: -1 },
            Inst::Mtctr { rs: 9 },
            Inst::Bdnz { bd: -128 },
            Inst::Blr,
            Inst::Nop,
        ];
        for inst in insts {
            let mut b = Vec::new();
            encode(&inst, &mut b).unwrap();
            assert_eq!(decode(&b, 0).unwrap(), (inst, 4), "{inst:?}");
        }
    }

    #[test]
    fn truncated_prefix_rejected() {
        let g = Ger::prefixed(GerKind::F32Ger, AccOp::PP, 0, 32, 33, 0xf, 0xf, 0xff);
        let mut b = Vec::new();
        encode(&Inst::Ger(g), &mut b).unwrap();
        assert_eq!(decode(&b[..4], 0), Err(CodecError::Truncated));
    }
}
