//! Textual assembler / disassembler in the syntax of the paper's Figure 7
//! listing (`xvf64gerpp a4, vs44, vs40`, `lxv vs40, 0(r5)`, `bdnz -64` …).
//!
//! Prefixed forms take three trailing immediates — the XMSK, YMSK and PMSK
//! fields in the ISA's MSB-first order (`pmxvf16ger2pp a0, vs32, vs34, 13,
//! 9, 2` means x-mask `1101`, y-mask `1001`, p-mask `10`), matching how an
//! assembler programmer writes them in §II-C.

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};

/// Assembly syntax error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AsmError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for AsmError {}

fn msk_to_field(m: u8, w: u32) -> u32 {
    (0..w).filter(|i| (m >> i) & 1 == 1).fold(0, |f, i| f | 1 << (w - 1 - i))
}

fn field_to_msk(f: u32, w: u32) -> u8 {
    (0..w).filter(|i| (f >> (w - 1 - i)) & 1 == 1).fold(0, |m, i| m | 1 << i)
}

fn pmsk_width(kind: GerKind) -> u32 {
    match kind.rank() {
        1 => 0,
        r => r as u32,
    }
}

fn ymsk_width(kind: GerKind) -> u32 {
    if kind == GerKind::F64Ger {
        2
    } else {
        4
    }
}

/// Render one instruction to its assembly line.
pub fn disassemble(inst: &Inst) -> String {
    match *inst {
        Inst::Ger(ref g) => {
            let base = format!("{} a{}, vs{}, vs{}", g.mnemonic(), g.acc, g.xa, g.yb);
            if !g.prefixed {
                base
            } else {
                let xw = msk_to_field(g.xmsk, 4);
                let yw = msk_to_field(g.ymsk, ymsk_width(g.kind));
                let pw = pmsk_width(g.kind);
                if pw == 0 {
                    format!("{base}, {xw}, {yw}")
                } else {
                    format!("{base}, {xw}, {yw}, {}", msk_to_field(g.pmsk, pw))
                }
            }
        }
        Inst::XxSetAccZ { acc } => format!("xxsetaccz a{acc}"),
        Inst::XxMfAcc { acc } => format!("xxmfacc a{acc}"),
        Inst::XxMtAcc { acc } => format!("xxmtacc a{acc}"),
        Inst::Lxv { xt, ra, dq } => format!("lxv vs{xt}, {dq}(r{ra})"),
        Inst::Lxvp { xtp, ra, dq } => format!("lxvp vs{xtp}, {dq}(r{ra})"),
        Inst::Stxv { xs, ra, dq } => format!("stxv vs{xs}, {dq}(r{ra})"),
        Inst::Stxvp { xsp, ra, dq } => format!("stxvp vs{xsp}, {dq}(r{ra})"),
        Inst::XvMaddaDp { xt, xa, xb } => format!("xvmaddadp vs{xt}, vs{xa}, vs{xb}"),
        Inst::XvMaddaSp { xt, xa, xb } => format!("xvmaddasp vs{xt}, vs{xa}, vs{xb}"),
        Inst::XxSpltd { xt, xa, h } => format!("xxspltd vs{xt}, vs{xa}, {h}"),
        Inst::XxSpltw { xt, xa, w } => format!("xxspltw vs{xt}, vs{xa}, {w}"),
        Inst::Xxlor { xt, xa, xb } => format!("xxlor vs{xt}, vs{xa}, vs{xb}"),
        Inst::Xxlxor { xt, xa, xb } => format!("xxlxor vs{xt}, vs{xa}, vs{xb}"),
        Inst::Addi { rt, ra: 0, si } => format!("li r{rt}, {si}"),
        Inst::Addi { rt, ra, si } => format!("addi r{rt}, r{ra}, {si}"),
        Inst::Mtctr { rs } => format!("mtctr r{rs}"),
        Inst::Bdnz { bd } => format!("bdnz {bd}"),
        Inst::Blr => "blr".to_string(),
        Inst::Nop => "nop".to_string(),
    }
}

/// Render a whole program.
pub fn disassemble_program(prog: &[Inst]) -> String {
    let mut s = String::new();
    for i in prog {
        s.push_str(&disassemble(i));
        s.push('\n');
    }
    s
}

struct LineParser<'a> {
    toks: Vec<&'a str>,
    pos: usize,
    line: usize,
}

impl<'a> LineParser<'a> {
    fn new(s: &'a str, line: usize) -> Self {
        let toks = s
            .split(|c: char| c == ',' || c.is_whitespace() || c == '(' || c == ')')
            .filter(|t| !t.is_empty())
            .collect();
        LineParser { toks, pos: 0, line }
    }

    fn err<T>(&self, msg: impl Into<String>) -> Result<T, AsmError> {
        Err(AsmError { line: self.line, msg: msg.into() })
    }

    fn next(&mut self) -> Result<&'a str, AsmError> {
        let t = self.toks.get(self.pos).copied();
        self.pos += 1;
        match t {
            Some(t) => Ok(t),
            None => self.err("unexpected end of line"),
        }
    }

    fn reg(&mut self, prefix: &str) -> Result<u8, AsmError> {
        let t = self.next()?;
        let Some(num) = t.strip_prefix(prefix) else {
            return self.err(format!("expected {prefix}N, got {t}"));
        };
        num.parse().or_else(|_| self.err(format!("bad register {t}")))
    }

    fn imm(&mut self) -> Result<i64, AsmError> {
        let t = self.next()?;
        let (neg, t) = match t.strip_prefix('-') {
            Some(rest) => (true, rest),
            None => (false, t),
        };
        let v: i64 = if let Some(hex) = t.strip_prefix("0x") {
            i64::from_str_radix(hex, 16).or_else(|_| self.err(format!("bad immediate {t}")))?
        } else {
            t.parse().or_else(|_| self.err(format!("bad immediate {t}")))?
        };
        Ok(if neg { -v } else { v })
    }

    fn done(&self) -> bool {
        self.pos >= self.toks.len()
    }
}

fn parse_ger_mnemonic(m: &str) -> Option<(GerKind, AccOp, bool)> {
    let (prefixed, rest) = match m.strip_prefix("pm") {
        Some(r) => (true, r),
        None => (false, m),
    };
    for kind in GerKind::ALL {
        if let Some(suffix) = rest.strip_prefix(kind.mnemonic()) {
            let op = match suffix {
                "" => AccOp::New,
                "s" => AccOp::NewS,
                "pp" => AccOp::PP,
                "np" => AccOp::NP,
                "pn" => AccOp::PN,
                "nn" => AccOp::NN,
                "spp" => AccOp::SPP,
                _ => continue,
            };
            return Some((kind, op, prefixed));
        }
    }
    None
}

/// Parse one assembly line (comments start with `#` or `;`).
/// Returns `None` for blank/comment lines.
pub fn parse_line(s: &str, line: usize) -> Result<Option<Inst>, AsmError> {
    let s = match s.find(['#', ';']) {
        Some(i) => &s[..i],
        None => s,
    };
    if s.trim().is_empty() {
        return Ok(None);
    }
    let mut p = LineParser::new(s, line);
    let mnem = p.next()?;
    let inst = match mnem {
        "xxsetaccz" => Inst::XxSetAccZ { acc: p.reg("a")? },
        "xxmfacc" => Inst::XxMfAcc { acc: p.reg("a")? },
        "xxmtacc" => Inst::XxMtAcc { acc: p.reg("a")? },
        "lxv" => {
            let xt = p.reg("vs")?;
            let dq = p.imm()? as i32;
            Inst::Lxv { xt, ra: p.reg("r")?, dq }
        }
        "lxvp" => {
            let xtp = p.reg("vs")?;
            let dq = p.imm()? as i32;
            Inst::Lxvp { xtp, ra: p.reg("r")?, dq }
        }
        "stxv" => {
            let xs = p.reg("vs")?;
            let dq = p.imm()? as i32;
            Inst::Stxv { xs, ra: p.reg("r")?, dq }
        }
        "stxvp" => {
            let xsp = p.reg("vs")?;
            let dq = p.imm()? as i32;
            Inst::Stxvp { xsp, ra: p.reg("r")?, dq }
        }
        "addi" => {
            let rt = p.reg("r")?;
            let ra = p.reg("r")?;
            Inst::Addi { rt, ra, si: p.imm()? as i32 }
        }
        "li" => {
            let rt = p.reg("r")?;
            Inst::Addi { rt, ra: 0, si: p.imm()? as i32 }
        }
        "xxlor" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::Xxlor { xt, xa, xb: p.reg("vs")? }
        }
        "xxlxor" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::Xxlxor { xt, xa, xb: p.reg("vs")? }
        }
        "xvmaddadp" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::XvMaddaDp { xt, xa, xb: p.reg("vs")? }
        }
        "xvmaddasp" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::XvMaddaSp { xt, xa, xb: p.reg("vs")? }
        }
        "xxspltd" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::XxSpltd { xt, xa, h: p.imm()? as u8 }
        }
        "xxspltw" => {
            let xt = p.reg("vs")?;
            let xa = p.reg("vs")?;
            Inst::XxSpltw { xt, xa, w: p.imm()? as u8 }
        }
        "mtctr" => Inst::Mtctr { rs: p.reg("r")? },
        "bdnz" => Inst::Bdnz { bd: p.imm()? as i32 },
        "blr" => Inst::Blr,
        "nop" => Inst::Nop,
        m => match parse_ger_mnemonic(m) {
            Some((kind, op, prefixed)) => {
                let acc = p.reg("a")?;
                let xa = p.reg("vs")?;
                let yb = p.reg("vs")?;
                if !prefixed {
                    Inst::Ger(Ger::new(kind, op, acc, xa, yb))
                } else {
                    let xf = p.imm()? as u32;
                    let yf = p.imm()? as u32;
                    let pw = pmsk_width(kind);
                    let pmsk = if pw > 0 {
                        field_to_msk(p.imm()? as u32, pw)
                    } else {
                        0xff
                    };
                    Inst::Ger(Ger::prefixed(
                        kind,
                        op,
                        acc,
                        xa,
                        yb,
                        field_to_msk(xf, 4),
                        field_to_msk(yf, ymsk_width(kind)),
                        pmsk,
                    ))
                }
            }
            None => return p.err(format!("unknown mnemonic {m}")),
        },
    };
    if !p.done() {
        return p.err("trailing tokens");
    }
    Ok(Some(inst))
}

/// Assemble a multi-line source into a program.
pub fn assemble(src: &str) -> Result<Vec<Inst>, AsmError> {
    let mut prog = Vec::new();
    for (i, line) in src.lines().enumerate() {
        if let Some(inst) = parse_line(line, i + 1)? {
            prog.push(inst);
        }
    }
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_syntax_round_trip() {
        let src = "\
            lxvp vs44, 64(r4)\n\
            lxvp vs32, 96(r4)\n\
            addi r5, r5, 64\n\
            addi r4, r4, 64\n\
            lxv vs40, 0(r5)\n\
            xvf64gerpp a4, vs44, vs40\n\
            bdnz -64\n\
            blr\n";
        let prog = assemble(src).unwrap();
        assert_eq!(prog.len(), 8);
        let printed = disassemble_program(&prog);
        let reparsed = assemble(&printed).unwrap();
        assert_eq!(prog, reparsed);
    }

    #[test]
    fn prefixed_masks_msb_first() {
        // x-field 13 = 0b1101 -> rows {0,1,3}; y-field 9 = 0b1001 -> cols {0,3};
        // p-field 2 = 0b10 -> product {0}
        let inst = parse_line("pmxvf16ger2pp a0, vs32, vs34, 13, 9, 2", 1).unwrap().unwrap();
        let Inst::Ger(g) = inst else { panic!() };
        assert!(g.prefixed);
        assert_eq!(g.xmsk, 0b1011);
        assert_eq!(g.ymsk, 0b1001);
        assert_eq!(g.pmsk, 0b01);
        // round trip through the printer
        let again = parse_line(&disassemble(&inst), 1).unwrap().unwrap();
        assert_eq!(again, inst);
    }

    #[test]
    fn rank1_prefixed_has_no_pmask() {
        let inst = parse_line("pmxvf64gerpp a1, vs32, vs34, 15, 2", 1).unwrap().unwrap();
        let Inst::Ger(g) = inst else { panic!() };
        assert_eq!(g.xmsk, 0b1111);
        assert_eq!(g.ymsk, 0b01); // field 2 = 0b10 -> col 0
        assert_eq!(g.pmsk, 0xff);
        assert_eq!(parse_line(&disassemble(&inst), 1).unwrap().unwrap(), inst);
    }

    #[test]
    fn comments_and_blanks() {
        let prog = assemble("# header\n\n  xxsetaccz a3  ; zero it\nblr\n").unwrap();
        assert_eq!(prog, vec![Inst::XxSetAccZ { acc: 3 }, Inst::Blr]);
    }

    #[test]
    fn li_alias() {
        let inst = parse_line("li r9, 127", 1).unwrap().unwrap();
        assert_eq!(inst, Inst::Addi { rt: 9, ra: 0, si: 127 });
        assert_eq!(disassemble(&inst), "li r9, 127");
    }

    #[test]
    fn errors() {
        assert!(parse_line("xvf99ger a0, vs1, vs2", 1).is_err());
        assert!(parse_line("lxv vs40, 0", 1).is_err());
        assert!(parse_line("blr extra", 1).is_err());
        assert!(parse_line("addi r1, 5, 3", 1).is_err());
    }

    #[test]
    fn all_ger_mnemonics_parse() {
        use crate::isa::inst::{AccOp, GerKind};
        for kind in GerKind::ALL {
            for op in [AccOp::New, AccOp::NewS, AccOp::PP, AccOp::NP, AccOp::PN, AccOp::NN, AccOp::SPP] {
                if !op.valid_for(kind) {
                    continue;
                }
                let g = Ger::new(kind, op, 2, 36, 38);
                let line = disassemble(&Inst::Ger(g));
                let back = parse_line(&line, 1).unwrap().unwrap();
                assert_eq!(back, Inst::Ger(g), "{line}");
            }
        }
    }
}
