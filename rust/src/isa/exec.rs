//! The functional interpreter: executes instruction streams over the MMA
//! register state, a GPR file, the count register and a flat memory,
//! enforcing the architectural rules of paper §II:
//!
//! * rank-k update semantics, eq. (1) integer / eq. (2) float / eq. (3)
//!   masked;
//! * the priming state machine (accumulate forms require a primed
//!   accumulator; `xxmfacc` deprimes; the VSR group of a primed accumulator
//!   must not be touched);
//! * operand constraints (X/Y VSRs must not overlap the target accumulator;
//!   the `xvf64ger` X operand is an even-odd VSR pair).
//!
//! The interpreter is the single source of truth for MMA numerics: the
//! kernel library runs on it, and the cycle model times the very same
//! instruction streams.

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::isa::regs::{Acc, RegFile, Vsr, NUM_ACCS, NUM_VSRS};
use crate::isa::types::{mod_add_i32, sat_add_i32};

/// Architectural misuse detected by the interpreter (these are programming
/// errors the paper's §II/§IV rules forbid; real hardware gives undefined
/// results — we fail loudly instead).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecError {
    /// Accumulate-form instruction on an accumulator that is not primed,
    /// or use of an accumulator after a depriming `xxmfacc`.
    UnprimedAccumulator { acc: u8, inst: String },
    /// A ger input VSR lies inside the target accumulator's VSR group
    /// ("X and Y ... must not overlap the accumulator", §II-B).
    OperandOverlapsAccumulator { acc: u8, vsr: u8 },
    /// A VSR belonging to a *primed* accumulator's group was read or
    /// written by a non-MMA instruction (§II-A).
    VsrInUseByAccumulator { vsr: u8, acc: u8 },
    /// `xvf64ger` X operand register is odd (must be an even-odd pair).
    OddF64Pair { vsr: u8 },
    /// (kind, accop) combination that Table I does not architect.
    InvalidForm { mnemonic: String },
    /// Register index out of range.
    BadRegister { what: &'static str, index: u8 },
    /// Memory access outside the machine's memory.
    MemOutOfBounds { addr: u64, len: u32 },
    /// Branch to a byte offset that is not an instruction boundary.
    BadBranchTarget { pc: u64, target: u64 },
    /// Executed `steps` instructions without reaching `blr`.
    FuelExhausted { steps: u64 },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::UnprimedAccumulator { acc, inst } => {
                write!(f, "use of unprimed accumulator acc{acc} by {inst}")
            }
            ExecError::OperandOverlapsAccumulator { acc, vsr } => {
                write!(f, "ger input vs{vsr} overlaps target accumulator acc{acc}")
            }
            ExecError::VsrInUseByAccumulator { vsr, acc } => {
                write!(f, "vs{vsr} touched while acc{acc} is primed")
            }
            ExecError::OddF64Pair { vsr } => write!(f, "xvf64ger X operand vs{vsr} is not an even pair"),
            ExecError::InvalidForm { mnemonic } => write!(f, "unarchitected instruction form {mnemonic}"),
            ExecError::BadRegister { what, index } => write!(f, "bad {what} register index {index}"),
            ExecError::MemOutOfBounds { addr, len } => write!(f, "memory access [{addr}, +{len}) out of bounds"),
            ExecError::BadBranchTarget { pc, target } => {
                write!(f, "branch from byte pc {pc} to non-boundary byte {target}")
            }
            ExecError::FuelExhausted { steps } => write!(f, "no blr after {steps} instructions"),
        }
    }
}

impl std::error::Error for ExecError {}

/// Dynamic execution statistics (consumed by the cycle and power models and
/// by flops/cycle accounting).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    pub instructions: u64,
    pub mma_instructions: u64,
    pub loads: u64,
    pub stores: u64,
    pub mem_bytes: u64,
    pub flops: u64,
    pub branches: u64,
}

/// The functional machine: MMA registers + 32 GPRs + CTR + flat memory.
///
/// Addresses held in GPRs are plain offsets into [`Machine::mem`].
pub struct Machine {
    pub regs: RegFile,
    pub gpr: [u64; 32],
    pub ctr: u64,
    pub mem: Vec<u8>,
    /// When true (default), enforce the §II-A rule that the VSR group of a
    /// primed accumulator must not be used by loads/stores or as ger inputs.
    pub strict: bool,
    pub stats: ExecStats,
}

impl Machine {
    /// Machine with `mem_size` bytes of zeroed memory.
    pub fn new(mem_size: usize) -> Self {
        Machine {
            regs: RegFile::new(),
            gpr: [0u64; 32],
            ctr: 0,
            mem: vec![0u8; mem_size],
            strict: true,
            stats: ExecStats::default(),
        }
    }

    // ---- memory helpers --------------------------------------------------

    fn check_mem(&self, addr: u64, len: u32) -> Result<usize, ExecError> {
        let end = addr.checked_add(u64::from(len)).ok_or(ExecError::MemOutOfBounds { addr, len })?;
        if end as usize > self.mem.len() {
            return Err(ExecError::MemOutOfBounds { addr, len });
        }
        Ok(addr as usize)
    }

    /// Write a `f64` slice into memory at `addr` (little-endian), a test and
    /// driver convenience.
    pub fn write_f64s(&mut self, addr: u64, data: &[f64]) {
        for (i, v) in data.iter().enumerate() {
            let o = addr as usize + 8 * i;
            self.mem[o..o + 8].copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn read_f64s(&self, addr: u64, n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| {
                let o = addr as usize + 8 * i;
                f64::from_le_bytes(self.mem[o..o + 8].try_into().unwrap())
            })
            .collect()
    }

    pub fn write_f32s(&mut self, addr: u64, data: &[f32]) {
        for (i, v) in data.iter().enumerate() {
            let o = addr as usize + 4 * i;
            self.mem[o..o + 4].copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let o = addr as usize + 4 * i;
                f32::from_le_bytes(self.mem[o..o + 4].try_into().unwrap())
            })
            .collect()
    }

    pub fn write_u16s(&mut self, addr: u64, data: &[u16]) {
        for (i, v) in data.iter().enumerate() {
            let o = addr as usize + 2 * i;
            self.mem[o..o + 2].copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn write_i32s(&mut self, addr: u64, data: &[i32]) {
        for (i, v) in data.iter().enumerate() {
            let o = addr as usize + 4 * i;
            self.mem[o..o + 4].copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn read_i32s(&self, addr: u64, n: usize) -> Vec<i32> {
        (0..n)
            .map(|i| {
                let o = addr as usize + 4 * i;
                i32::from_le_bytes(self.mem[o..o + 4].try_into().unwrap())
            })
            .collect()
    }

    // ---- VSR access with priming enforcement -----------------------------

    fn vsr_check(&self, vsr: u8, as_ger_input_for: Option<u8>) -> Result<(), ExecError> {
        if vsr as usize >= NUM_VSRS {
            return Err(ExecError::BadRegister { what: "vsr", index: vsr });
        }
        if let Some(acc) = as_ger_input_for {
            // X/Y may not overlap the target accumulator's group.
            if RegFile::acc_of_vsr(vsr) == Some(acc) {
                return Err(ExecError::OperandOverlapsAccumulator { acc, vsr });
            }
        }
        if self.strict {
            if let Some(acc) = RegFile::acc_of_vsr(vsr) {
                if self.regs.primed[acc as usize] {
                    return Err(ExecError::VsrInUseByAccumulator { vsr, acc });
                }
            }
        }
        Ok(())
    }

    fn acc_check(&self, acc: u8) -> Result<(), ExecError> {
        if acc as usize >= NUM_ACCS {
            return Err(ExecError::BadRegister { what: "acc", index: acc });
        }
        Ok(())
    }

    // ---- the rank-k update core (eq. 1-3) --------------------------------

    /// Execute a ger instruction against the register file.
    pub fn exec_ger(&mut self, g: &Ger) -> Result<(), ExecError> {
        if !g.op.valid_for(g.kind) {
            return Err(ExecError::InvalidForm { mnemonic: g.mnemonic() });
        }
        self.acc_check(g.acc)?;
        self.vsr_check(g.xa, Some(g.acc))?;
        self.vsr_check(g.yb, Some(g.acc))?;
        if g.kind == GerKind::F64Ger {
            if g.xa % 2 != 0 {
                return Err(ExecError::OddF64Pair { vsr: g.xa });
            }
            self.vsr_check(g.xa + 1, Some(g.acc))?;
        }
        let ai = g.acc as usize;
        if g.op.accumulates() && !self.regs.primed[ai] {
            return Err(ExecError::UnprimedAccumulator { acc: g.acc, inst: g.mnemonic() });
        }

        let x = self.regs.vsr[g.xa as usize];
        let y = self.regs.vsr[g.yb as usize];
        let acc_in = self.regs.acc[ai];
        let acc_out = match g.kind {
            GerKind::F64Ger => {
                let x1 = self.regs.vsr[g.xa as usize + 1];
                ger_f64(g, x, x1, y, &acc_in)
            }
            GerKind::F32Ger => ger_f32(g, x, y, &acc_in),
            GerKind::F16Ger2 => ger_f16ish(g, x, y, &acc_in, Vsr::f16),
            GerKind::Bf16Ger2 => ger_f16ish(g, x, y, &acc_in, Vsr::bf16),
            GerKind::I16Ger2 | GerKind::I8Ger4 | GerKind::I4Ger8 => ger_integer(g, x, y, &acc_in),
        };
        self.regs.acc[ai] = acc_out;
        self.regs.primed[ai] = true; // New/NewS prime; accumulate forms stay primed
        Ok(())
    }

    // ---- program execution ------------------------------------------------

    /// Execute one instruction. Branch semantics are handled by
    /// [`Machine::run`]; here `Bdnz`/`Blr` only update CTR / report.
    fn exec_straightline(&mut self, inst: &Inst) -> Result<(), ExecError> {
        match *inst {
            Inst::XxSetAccZ { acc } => {
                self.acc_check(acc)?;
                self.regs.acc[acc as usize] = Acc::zero();
                self.regs.primed[acc as usize] = true;
            }
            Inst::XxMfAcc { acc } => {
                self.acc_check(acc)?;
                if !self.regs.primed[acc as usize] {
                    return Err(ExecError::UnprimedAccumulator { acc, inst: "xxmfacc".into() });
                }
                let a = self.regs.acc[acc as usize];
                for r in 0..4 {
                    self.regs.vsr[acc as usize * 4 + r] = a.row(r);
                }
                self.regs.primed[acc as usize] = false; // depriming event (§II-B.1)
            }
            Inst::XxMtAcc { acc } => {
                self.acc_check(acc)?;
                let mut a = Acc::zero();
                for r in 0..4 {
                    a.set_row(r, self.regs.vsr[acc as usize * 4 + r]);
                }
                self.regs.acc[acc as usize] = a;
                self.regs.primed[acc as usize] = true;
            }
            Inst::Ger(ref g) => self.exec_ger(g)?,
            Inst::Lxv { xt, ra, dq } => {
                self.vsr_check(xt, None)?;
                let addr = self.gpr[ra as usize].wrapping_add(dq as i64 as u64);
                let o = self.check_mem(addr, 16)?;
                let mut b = [0u8; 16];
                b.copy_from_slice(&self.mem[o..o + 16]);
                self.regs.vsr[xt as usize] = Vsr(b);
            }
            Inst::Lxvp { xtp, ra, dq } => {
                self.vsr_check(xtp, None)?;
                self.vsr_check(xtp + 1, None)?;
                let addr = self.gpr[ra as usize].wrapping_add(dq as i64 as u64);
                let o = self.check_mem(addr, 32)?;
                let mut b0 = [0u8; 16];
                let mut b1 = [0u8; 16];
                b0.copy_from_slice(&self.mem[o..o + 16]);
                b1.copy_from_slice(&self.mem[o + 16..o + 32]);
                self.regs.vsr[xtp as usize] = Vsr(b0);
                self.regs.vsr[xtp as usize + 1] = Vsr(b1);
            }
            Inst::Stxv { xs, ra, dq } => {
                self.vsr_check(xs, None)?;
                let addr = self.gpr[ra as usize].wrapping_add(dq as i64 as u64);
                let o = self.check_mem(addr, 16)?;
                let v = self.regs.vsr[xs as usize];
                self.mem[o..o + 16].copy_from_slice(&v.0);
            }
            Inst::Stxvp { xsp, ra, dq } => {
                self.vsr_check(xsp, None)?;
                self.vsr_check(xsp + 1, None)?;
                let addr = self.gpr[ra as usize].wrapping_add(dq as i64 as u64);
                let o = self.check_mem(addr, 32)?;
                let v0 = self.regs.vsr[xsp as usize];
                let v1 = self.regs.vsr[xsp as usize + 1];
                self.mem[o..o + 16].copy_from_slice(&v0.0);
                self.mem[o + 16..o + 32].copy_from_slice(&v1.0);
            }
            Inst::XvMaddaDp { xt, xa, xb } => {
                self.vsr_check(xt, None)?;
                self.vsr_check(xa, None)?;
                self.vsr_check(xb, None)?;
                let (a, b, t) =
                    (self.regs.vsr[xa as usize], self.regs.vsr[xb as usize], self.regs.vsr[xt as usize]);
                self.regs.vsr[xt as usize] =
                    Vsr::from_f64x2([t.f64(0) + a.f64(0) * b.f64(0), t.f64(1) + a.f64(1) * b.f64(1)]);
            }
            Inst::XvMaddaSp { xt, xa, xb } => {
                self.vsr_check(xt, None)?;
                self.vsr_check(xa, None)?;
                self.vsr_check(xb, None)?;
                let (a, b, t) =
                    (self.regs.vsr[xa as usize], self.regs.vsr[xb as usize], self.regs.vsr[xt as usize]);
                let mut lanes = [0f32; 4];
                for (i, l) in lanes.iter_mut().enumerate() {
                    *l = t.f32(i) + a.f32(i) * b.f32(i);
                }
                self.regs.vsr[xt as usize] = Vsr::from_f32x4(lanes);
            }
            Inst::XxSpltd { xt, xa, h } => {
                self.vsr_check(xt, None)?;
                self.vsr_check(xa, None)?;
                let v = self.regs.vsr[xa as usize].f64(h as usize & 1);
                self.regs.vsr[xt as usize] = Vsr::from_f64x2([v, v]);
            }
            Inst::Xxlor { xt, xa, xb } | Inst::Xxlxor { xt, xa, xb } => {
                self.vsr_check(xt, None)?;
                self.vsr_check(xa, None)?;
                self.vsr_check(xb, None)?;
                let (a, b) = (self.regs.vsr[xa as usize], self.regs.vsr[xb as usize]);
                let is_or = matches!(inst, Inst::Xxlor { .. });
                let mut out = [0u8; 16];
                for i in 0..16 {
                    out[i] = if is_or { a.0[i] | b.0[i] } else { a.0[i] ^ b.0[i] };
                }
                self.regs.vsr[xt as usize] = Vsr(out);
            }
            Inst::XxSpltw { xt, xa, w } => {
                self.vsr_check(xt, None)?;
                self.vsr_check(xa, None)?;
                let v = self.regs.vsr[xa as usize].f32(w as usize & 3);
                self.regs.vsr[xt as usize] = Vsr::from_f32x4([v; 4]);
            }
            Inst::Addi { rt, ra, si } => {
                let base = if ra == 0 { 0 } else { self.gpr[ra as usize] };
                self.gpr[rt as usize] = base.wrapping_add(si as i64 as u64);
            }
            Inst::Mtctr { rs } => self.ctr = self.gpr[rs as usize],
            Inst::Bdnz { .. } | Inst::Blr | Inst::Nop => {}
        }
        Ok(())
    }

    /// Run a program (a straight slice of instructions with byte-offset
    /// branch targets) from its first instruction until `blr`.
    ///
    /// `fuel` bounds the dynamic instruction count (guards against
    /// non-terminating loops in generated kernels).
    pub fn run(&mut self, prog: &[Inst], fuel: u64) -> Result<(), ExecError> {
        // byte offset of each instruction, for bdnz displacement targets
        let mut offsets = Vec::with_capacity(prog.len() + 1);
        let mut off = 0u64;
        for inst in prog {
            offsets.push(off);
            off += u64::from(inst.size());
        }
        offsets.push(off);
        // §Perf: resolve every branch target once (the binary search per
        // taken branch showed up in the interpreter profile)
        let mut targets: Vec<Option<usize>> = vec![None; prog.len()];
        for (idx, inst) in prog.iter().enumerate() {
            if let Inst::Bdnz { bd } = inst {
                let pc = offsets[idx];
                let target = pc.wrapping_add(*bd as i64 as u64);
                let tidx = offsets
                    .binary_search(&target)
                    .map_err(|_| ExecError::BadBranchTarget { pc, target })?;
                if tidx >= prog.len() {
                    return Err(ExecError::BadBranchTarget { pc, target });
                }
                targets[idx] = Some(tidx);
            }
        }

        let mut idx = 0usize;
        let mut steps = 0u64;
        while idx < prog.len() {
            if steps >= fuel {
                return Err(ExecError::FuelExhausted { steps });
            }
            steps += 1;
            let inst = &prog[idx];
            self.account(inst);
            match *inst {
                Inst::Blr => return Ok(()),
                Inst::Bdnz { .. } => {
                    self.ctr = self.ctr.wrapping_sub(1);
                    self.stats.branches += 1;
                    if self.ctr != 0 {
                        idx = targets[idx].expect("precomputed above");
                        continue;
                    }
                }
                _ => self.exec_straightline(inst)?,
            }
            idx += 1;
        }
        Ok(())
    }

    fn account(&mut self, inst: &Inst) {
        self.stats.instructions += 1;
        if inst.is_mma() {
            self.stats.mma_instructions += 1;
        }
        match inst {
            Inst::Lxv { .. } | Inst::Lxvp { .. } => self.stats.loads += 1,
            Inst::Stxv { .. } | Inst::Stxvp { .. } => self.stats.stores += 1,
            _ => {}
        }
        self.stats.mem_bytes += u64::from(inst.mem_bytes());
        self.stats.flops += inst.flops();
    }
}

// ---- rank-k update element math --------------------------------------------

#[inline(always)]
fn mask_bit(m: u8, i: usize) -> bool {
    (m >> i) & 1 == 1
}

/// eq. (2) accumulation: `A' = (±P) (±A)` per the 2-letter float suffix.
#[inline(always)]
fn float_combine(op: AccOp, p: f64, a: f64) -> f64 {
    match op {
        AccOp::New | AccOp::NewS => p,
        AccOp::PP => p + a,
        AccOp::NP => -p + a,
        AccOp::PN => p - a,
        AccOp::NN => -p - a,
        AccOp::SPP => unreachable!("spp is integer-only"),
    }
}

#[inline(always)]
fn float_combine_f32(op: AccOp, p: f32, a: f32) -> f32 {
    match op {
        AccOp::New | AccOp::NewS => p,
        AccOp::PP => p + a,
        AccOp::NP => -p + a,
        AccOp::PN => p - a,
        AccOp::NN => -p - a,
        AccOp::SPP => unreachable!("spp is integer-only"),
    }
}

fn ger_f64(g: &Ger, x0: Vsr, x1: Vsr, y: Vsr, acc: &Acc) -> Acc {
    let xs = [x0.f64(0), x0.f64(1), x1.f64(0), x1.f64(1)];
    let ys = [y.f64(0), y.f64(1)];
    let mut out = *acc;
    if !g.prefixed {
        for i in 0..4 {
            for j in 0..2 {
                out.set_f64_at(i, j, float_combine(g.op, xs[i] * ys[j], acc.f64_at(i, j)));
            }
        }
        return out;
    }
    for i in 0..4 {
        for j in 0..2 {
            if !(mask_bit(g.xmsk, i) && mask_bit(g.ymsk, j)) {
                // disabled computations are not performed (§II-C); for the
                // priming forms the element is still written, as zero product
                if !g.op.accumulates() {
                    out.set_f64_at(i, j, 0.0);
                }
                continue;
            }
            let p = xs[i] * ys[j];
            out.set_f64_at(i, j, float_combine(g.op, p, acc.f64_at(i, j)));
        }
    }
    out
}

fn ger_f32(g: &Ger, x: Vsr, y: Vsr, acc: &Acc) -> Acc {
    let mut out = *acc;
    if !g.prefixed {
        let xs = [x.f32(0), x.f32(1), x.f32(2), x.f32(3)];
        let ys = [y.f32(0), y.f32(1), y.f32(2), y.f32(3)];
        for i in 0..4 {
            for j in 0..4 {
                out.set_f32_at(i, j, float_combine_f32(g.op, xs[i] * ys[j], acc.f32_at(i, j)));
            }
        }
        return out;
    }
    for i in 0..4 {
        for j in 0..4 {
            if !(mask_bit(g.xmsk, i) && mask_bit(g.ymsk, j)) {
                if !g.op.accumulates() {
                    out.set_f32_at(i, j, 0.0);
                }
                continue;
            }
            let p = x.f32(i) * y.f32(j);
            out.set_f32_at(i, j, float_combine_f32(g.op, p, acc.f32_at(i, j)));
        }
    }
    out
}

/// Shared fp16/bf16 rank-2 path: inputs converted to f32 (once per lane —
/// the conversion is the hot cost), the two partial products summed in f32
/// (the MME accumulates rank-2 products in single precision), then
/// combined per the suffix.
fn ger_f16ish(g: &Ger, x: Vsr, y: Vsr, acc: &Acc, lane: impl Fn(&Vsr, usize) -> f32) -> Acc {
    // pre-decode all 8 lanes of each operand exactly once
    let mut xl = [0f32; 8];
    let mut yl = [0f32; 8];
    for k in 0..8 {
        xl[k] = lane(&x, k);
        yl[k] = lane(&y, k);
    }
    let mut out = *acc;
    // fast path: conventional form (all masks enabled)
    if !g.prefixed {
        for i in 0..4 {
            for j in 0..4 {
                let p = xl[2 * i] * yl[2 * j] + xl[2 * i + 1] * yl[2 * j + 1];
                out.set_f32_at(i, j, float_combine_f32(g.op, p, acc.f32_at(i, j)));
            }
        }
        return out;
    }
    for i in 0..4 {
        for j in 0..4 {
            if !(mask_bit(g.xmsk, i) && mask_bit(g.ymsk, j)) {
                if !g.op.accumulates() {
                    out.set_f32_at(i, j, 0.0);
                }
                continue;
            }
            let mut p = 0f32;
            for k in 0..2 {
                if mask_bit(g.pmsk, k) {
                    p += xl[2 * i + k] * yl[2 * j + k];
                }
            }
            out.set_f32_at(i, j, float_combine_f32(g.op, p, acc.f32_at(i, j)));
        }
    }
    out
}

/// eq. (1) for the three integer kinds. Partial products are computed
/// exactly (i64), summed along k, then folded into the int32 accumulator
/// with the modulo or saturating model.
///
/// Perf note (§Perf): every input lane is decoded into a flat `i64` array
/// exactly once per instruction (the per-element nibble/byte extraction
/// dominated the original profile), and the conventional unmasked form
/// takes a branch-free inner loop.
fn ger_integer(g: &Ger, x: Vsr, y: Vsr, acc: &Acc) -> Acc {
    let rank = g.kind.rank();
    // pre-decode all 4*rank lanes of each operand
    let mut xl = [0i64; 32];
    let mut yl = [0i64; 32];
    match g.kind {
        GerKind::I16Ger2 => {
            for l in 0..8 {
                xl[l] = i64::from(x.i16(l));
                yl[l] = i64::from(y.i16(l));
            }
        }
        // X signed, Y unsigned (§II-B.2)
        GerKind::I8Ger4 => {
            for l in 0..16 {
                xl[l] = i64::from(x.i8(l));
                yl[l] = i64::from(y.u8(l));
            }
        }
        GerKind::I4Ger8 => {
            // unpack two nibbles per byte in one pass
            for b in 0..16 {
                let (xb, yb) = (x.0[b], y.0[b]);
                xl[2 * b] = i64::from(crate::isa::types::int4_sext(xb & 0xf));
                xl[2 * b + 1] = i64::from(crate::isa::types::int4_sext(xb >> 4));
                yl[2 * b] = i64::from(crate::isa::types::int4_sext(yb & 0xf));
                yl[2 * b + 1] = i64::from(crate::isa::types::int4_sext(yb >> 4));
            }
        }
        _ => unreachable!(),
    }
    let mut out = *acc;
    let fold = |op: AccOp, prev: i32, sum: i64| match op {
        AccOp::New => mod_add_i32(0, sum),
        AccOp::NewS => sat_add_i32(0, sum),
        AccOp::PP => mod_add_i32(prev, sum),
        AccOp::SPP => sat_add_i32(prev, sum),
        _ => unreachable!("validated in exec_ger"),
    };
    if !g.prefixed {
        // fast path: no mask tests in the inner loop
        for i in 0..4 {
            let xrow = &xl[i * rank..(i + 1) * rank];
            for j in 0..4 {
                let yrow = &yl[j * rank..(j + 1) * rank];
                let sum: i64 = xrow.iter().zip(yrow).map(|(&a, &b)| a * b).sum();
                out.set_i32_at(i, j, fold(g.op, acc.i32_at(i, j), sum));
            }
        }
        return out;
    }
    for i in 0..4 {
        for j in 0..4 {
            if !(mask_bit(g.xmsk, i) && mask_bit(g.ymsk, j)) {
                if !g.op.accumulates() {
                    out.set_i32_at(i, j, 0);
                }
                continue;
            }
            let mut sum = 0i64;
            for k in 0..rank {
                if mask_bit(g.pmsk, k) {
                    sum += xl[i * rank + k] * yl[j * rank + k];
                }
            }
            out.set_i32_at(i, j, fold(g.op, acc.i32_at(i, j), sum));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::types::{f32_to_bf16, f32_to_f16, int4_pack};

    fn m() -> Machine {
        Machine::new(4096)
    }

    /// naive oracle: 4xk times kx4 -> 4x4 (f32)
    fn outer_f32(x: &[f32], y: &[f32], k: usize) -> [[f32; 4]; 4] {
        let mut out = [[0f32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                for p in 0..k {
                    out[i][j] += x[i * k + p] * y[j * k + p];
                }
            }
        }
        out
    }

    #[test]
    fn xvf32ger_outer_product() {
        let mut mm = m();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [0.5f32, -1.0, 2.0, 0.0];
        mm.regs.vsr[32] = Vsr::from_f32x4(x);
        mm.regs.vsr[33] = Vsr::from_f32x4(y);
        mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::New, 0, 32, 33)).unwrap();
        assert_eq!(mm.regs.acc[0].to_f32_4x4(), outer_f32(&x, &y, 1));
        assert!(mm.regs.primed[0], "non-accumulate form primes");
    }

    #[test]
    fn xvf32ger_suffixes() {
        // A = +-P +- A for the four suffixes
        for (op, expect) in [
            (AccOp::PP, 2.0f32 * 3.0 + 10.0),
            (AccOp::NP, -2.0f32 * 3.0 + 10.0),
            (AccOp::PN, 2.0f32 * 3.0 - 10.0),
            (AccOp::NN, -2.0f32 * 3.0 - 10.0),
        ] {
            let mut mm = m();
            mm.regs.vsr[32] = Vsr::from_f32x4([2.0; 4]);
            mm.regs.vsr[33] = Vsr::from_f32x4([3.0; 4]);
            mm.regs.acc[1] = Acc::from_f32_4x4([[10.0; 4]; 4]);
            mm.regs.primed[1] = true;
            mm.exec_ger(&Ger::new(GerKind::F32Ger, op, 1, 32, 33)).unwrap();
            assert_eq!(mm.regs.acc[1].f32_at(2, 3), expect, "{op:?}");
        }
    }

    #[test]
    fn xvf64ger_pair_and_shape() {
        let mut mm = m();
        let x = [1.5f64, -2.0, 0.25, 8.0];
        let y = [3.0f64, -1.0];
        mm.regs.vsr[40] = Vsr::from_f64x2([x[0], x[1]]);
        mm.regs.vsr[41] = Vsr::from_f64x2([x[2], x[3]]);
        mm.regs.vsr[42] = Vsr::from_f64x2(y);
        mm.exec_ger(&Ger::new(GerKind::F64Ger, AccOp::New, 2, 40, 42)).unwrap();
        let a = mm.regs.acc[2].to_f64_4x2();
        for i in 0..4 {
            for j in 0..2 {
                assert_eq!(a[i][j], x[i] * y[j]);
            }
        }
        // odd X register is architecturally invalid
        let err = mm.exec_ger(&Ger::new(GerKind::F64Ger, AccOp::New, 2, 41, 42));
        assert_eq!(err, Err(ExecError::OddF64Pair { vsr: 41 }));
    }

    #[test]
    fn xvf16ger2_and_bf16_rank2() {
        let mut mm = m();
        // X 4x2 fp16, Y 4x2 fp16
        let xs: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 2.0).collect();
        let ys: Vec<f32> = (0..8).map(|i| 1.0 - i as f32 * 0.25).collect();
        let xh: Vec<u16> = xs.iter().map(|&v| f32_to_f16(v)).collect();
        let yh: Vec<u16> = ys.iter().map(|&v| f32_to_f16(v)).collect();
        mm.regs.vsr[34] = Vsr::from_u16x8(xh.clone().try_into().unwrap());
        mm.regs.vsr[35] = Vsr::from_u16x8(yh.clone().try_into().unwrap());
        mm.exec_ger(&Ger::new(GerKind::F16Ger2, AccOp::New, 3, 34, 35)).unwrap();
        assert_eq!(mm.regs.acc[3].to_f32_4x4(), outer_f32(&xs, &ys, 2));

        // bf16 path (values chosen exactly representable in bf16)
        let xb: Vec<u16> = xs.iter().map(|&v| f32_to_bf16(v)).collect();
        let yb: Vec<u16> = ys.iter().map(|&v| f32_to_bf16(v)).collect();
        mm.regs.vsr[36] = Vsr::from_u16x8(xb.try_into().unwrap());
        mm.regs.vsr[37] = Vsr::from_u16x8(yb.try_into().unwrap());
        mm.exec_ger(&Ger::new(GerKind::Bf16Ger2, AccOp::New, 4, 36, 37)).unwrap();
        assert_eq!(mm.regs.acc[4].to_f32_4x4(), outer_f32(&xs, &ys, 2));
    }

    #[test]
    fn xvi16ger2_modulo_and_saturating() {
        let mut mm = m();
        // choose values whose rank-2 product overflows i32: 2 * 30000*30000 = 1.8e9 ok;
        // accumulate twice to overflow
        let x = [30000i16; 8].map(|v| v as u16);
        mm.regs.vsr[38] = Vsr::from_u16x8(x);
        mm.regs.vsr[39] = Vsr::from_u16x8(x);
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::New, 5, 38, 39)).unwrap();
        let first = mm.regs.acc[5].i32_at(0, 0);
        assert_eq!(first, 2 * 30000 * 30000);
        // modulo accumulate wraps
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::PP, 5, 38, 39)).unwrap();
        assert_eq!(mm.regs.acc[5].i32_at(0, 0), first.wrapping_add(first));
        // saturating accumulate clamps
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::New, 5, 38, 39)).unwrap();
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::SPP, 5, 38, 39)).unwrap();
        assert_eq!(mm.regs.acc[5].i32_at(0, 0), i32::MAX);
        // xvi16ger2s: the non-accumulate saturating form clamps the product sum
        let big = [i16::MIN as u16; 8];
        mm.regs.vsr[38] = Vsr::from_u16x8(big);
        mm.regs.vsr[39] = Vsr::from_u16x8(big);
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::NewS, 6, 38, 39)).unwrap();
        // 2 * (-32768)^2 = 2^31 exactly -> saturates to i32::MAX
        assert_eq!(mm.regs.acc[6].i32_at(0, 0), i32::MAX);
        // while the modulo form wraps to i32::MIN
        mm.exec_ger(&Ger::new(GerKind::I16Ger2, AccOp::New, 6, 38, 39)).unwrap();
        assert_eq!(mm.regs.acc[6].i32_at(0, 0), i32::MIN);
    }

    #[test]
    fn xvi8ger4_mixed_signedness() {
        let mut mm = m();
        // X signed int8 (incl. negatives), Y UNSIGNED uint8 (values > 127)
        let mut xb = [0u8; 16];
        let mut yb = [0u8; 16];
        for i in 0..16 {
            xb[i] = (i as i32 * 17 - 120) as i8 as u8;
            yb[i] = (i * 16) as u8; // up to 240: exercises unsignedness
        }
        mm.regs.vsr[44] = Vsr::from_u8x16(xb);
        mm.regs.vsr[45] = Vsr::from_u8x16(yb);
        mm.exec_ger(&Ger::new(GerKind::I8Ger4, AccOp::New, 7, 44, 45)).unwrap();
        let mut expect = [[0i32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..4 {
                    expect[i][j] += i32::from(xb[4 * i + k] as i8) * i32::from(yb[4 * j + k]);
                }
            }
        }
        assert_eq!(mm.regs.acc[7].to_i32_4x4(), expect);
    }

    #[test]
    fn xvi4ger8_rank8() {
        let mut mm = m();
        let mut xb = [0u8; 16];
        let mut yb = [0u8; 16];
        // lanes -8..7 cycling
        for b in 0..16 {
            xb[b] = int4_pack((b as i32 % 16) - 8, ((b as i32 + 3) % 16) - 8);
            yb[b] = int4_pack(7 - (b as i32 % 16), (b as i32 % 13) - 6);
        }
        mm.regs.vsr[46] = Vsr::from_u8x16(xb);
        mm.regs.vsr[47] = Vsr::from_u8x16(yb);
        mm.exec_ger(&Ger::new(GerKind::I4Ger8, AccOp::New, 0, 46, 47)).unwrap();
        let x = mm.regs.vsr[46];
        let y = mm.regs.vsr[47];
        let mut expect = [[0i32; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                for k in 0..8 {
                    expect[i][j] += x.i4(8 * i + k) * y.i4(8 * j + k);
                }
            }
        }
        assert_eq!(mm.regs.acc[0].to_i32_4x4(), expect);
    }

    #[test]
    fn eq3_masking() {
        // pmxvf16ger2pp: x mask disables rows, y mask cols, p mask products
        let mut mm = m();
        let xs: Vec<f32> = (0..8).map(|i| (i + 1) as f32).collect();
        let ys: Vec<f32> = (0..8).map(|i| (8 - i) as f32).collect();
        let xh: Vec<u16> = xs.iter().map(|&v| f32_to_f16(v)).collect();
        let yh: Vec<u16> = ys.iter().map(|&v| f32_to_f16(v)).collect();
        mm.regs.vsr[34] = Vsr::from_u16x8(xh.try_into().unwrap());
        mm.regs.vsr[35] = Vsr::from_u16x8(yh.try_into().unwrap());
        mm.regs.acc[2] = Acc::from_f32_4x4([[100.0; 4]; 4]);
        mm.regs.primed[2] = true;
        let xmsk = 0b0101u8; // rows 0, 2
        let ymsk = 0b0011u8; // cols 0, 1
        let pmsk = 0b10u8; // product k=1 only
        mm.exec_ger(&Ger::prefixed(GerKind::F16Ger2, AccOp::PP, 2, 34, 35, xmsk, ymsk, pmsk))
            .unwrap();
        let a = mm.regs.acc[2].to_f32_4x4();
        for i in 0..4 {
            for j in 0..4 {
                let enabled = (xmsk >> i) & 1 == 1 && (ymsk >> j) & 1 == 1;
                let expect = if enabled { 100.0 + xs[2 * i + 1] * ys[2 * j + 1] } else { 100.0 };
                assert_eq!(a[i][j], expect, "({i},{j})");
            }
        }
    }

    #[test]
    fn priming_state_machine() {
        let mut mm = m();
        mm.regs.vsr[32] = Vsr::from_f32x4([1.0; 4]);
        mm.regs.vsr[33] = Vsr::from_f32x4([1.0; 4]);
        // accumulate into unprimed accumulator -> error
        let err = mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::PP, 0, 32, 33));
        assert!(matches!(err, Err(ExecError::UnprimedAccumulator { acc: 0, .. })));
        // xxsetaccz primes
        mm.exec_straightline(&Inst::XxSetAccZ { acc: 0 }).unwrap();
        mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::PP, 0, 32, 33)).unwrap();
        // xxmfacc deprimes and deposits rows into VSR[0..4]
        mm.exec_straightline(&Inst::XxMfAcc { acc: 0 }).unwrap();
        assert!(!mm.regs.primed[0]);
        assert_eq!(mm.regs.vsr[0].f32(0), 1.0);
        // accumulate after depriming -> error again
        let err = mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::PP, 0, 32, 33));
        assert!(matches!(err, Err(ExecError::UnprimedAccumulator { .. })));
        // xxmfacc on unprimed acc -> error
        let err = mm.exec_straightline(&Inst::XxMfAcc { acc: 0 });
        assert!(matches!(err, Err(ExecError::UnprimedAccumulator { .. })));
    }

    #[test]
    fn vsr_group_protection() {
        let mut mm = m();
        mm.exec_straightline(&Inst::XxSetAccZ { acc: 1 }).unwrap();
        // VSR[4..8] belong to primed acc1: loads must fail in strict mode
        let err = mm.exec_straightline(&Inst::Lxv { xt: 5, ra: 1, dq: 0 });
        assert_eq!(err, Err(ExecError::VsrInUseByAccumulator { vsr: 5, acc: 1 }));
        // and using them as inputs of a ger targeting *another* accumulator
        // must fail too (the group is owned by primed acc1)
        mm.regs.vsr[32] = Vsr::from_f32x4([1.0; 4]);
        let err = mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::New, 2, 32, 6));
        assert!(matches!(err, Err(ExecError::VsrInUseByAccumulator { vsr: 6, acc: 1 })));
        // operand overlapping the *target* accumulator is rejected even unprimed
        let mut mm = m();
        mm.regs.vsr[32] = Vsr::from_f32x4([1.0; 4]);
        let err = mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::New, 1, 32, 4));
        assert_eq!(err, Err(ExecError::OperandOverlapsAccumulator { acc: 1, vsr: 4 }));
        // VSR[32:63] never conflict (Figure 1)
        mm.exec_straightline(&Inst::XxSetAccZ { acc: 7 }).unwrap();
        mm.exec_straightline(&Inst::Lxv { xt: 63, ra: 1, dq: 0 }).unwrap();
    }

    #[test]
    fn ctr_loop_runs() {
        // a tiny program: accumulate [1,1,1,1] outer [1,1,1,1] N times
        let mut mm = m();
        mm.write_f32s(0, &[1.0; 8]);
        mm.gpr[4] = 0;
        mm.gpr[9] = 5; // N
        let prog = vec![
            Inst::Mtctr { rs: 9 },
            Inst::Lxv { xt: 32, ra: 4, dq: 0 },
            Inst::Lxv { xt: 33, ra: 4, dq: 16 },
            Inst::XxSetAccZ { acc: 0 },
            // loop body: one rank-1 update, 4 bytes; bdnz jumps back 4
            Inst::Ger(Ger::new(GerKind::F32Ger, AccOp::PP, 0, 32, 33)),
            Inst::Bdnz { bd: -4 },
            Inst::XxMfAcc { acc: 0 },
            Inst::Stxv { xs: 0, ra: 4, dq: 64 },
            Inst::Blr,
        ];
        mm.run(&prog, 1000).unwrap();
        assert_eq!(mm.read_f32s(64, 4), vec![5.0; 4]);
        assert_eq!(mm.stats.flops, 5 * 32);
        assert_eq!(mm.stats.loads, 2);
        assert_eq!(mm.stats.stores, 1);
    }

    #[test]
    fn fuel_guard() {
        let mut mm = m();
        mm.gpr[9] = 0; // mtctr 0 -> 2^64 iterations
        let prog = vec![Inst::Mtctr { rs: 9 }, Inst::Nop, Inst::Bdnz { bd: -4 }, Inst::Blr];
        let err = mm.run(&prog, 100);
        assert_eq!(err, Err(ExecError::FuelExhausted { steps: 100 }));
    }

    #[test]
    fn bad_branch_target() {
        let mut mm = m();
        mm.gpr[9] = 2;
        // bdnz -2 is not an instruction boundary
        let prog = vec![Inst::Mtctr { rs: 9 }, Inst::Bdnz { bd: -2 }, Inst::Blr];
        let err = mm.run(&prog, 100);
        assert!(matches!(err, Err(ExecError::BadBranchTarget { .. })));
    }

    #[test]
    fn mem_bounds() {
        let mut mm = Machine::new(32);
        let err = mm.exec_straightline(&Inst::Lxv { xt: 32, ra: 0, dq: 32 });
        assert!(matches!(err, Err(ExecError::MemOutOfBounds { .. })));
        let err = mm.exec_straightline(&Inst::Lxvp { xtp: 32, ra: 0, dq: 16 });
        assert!(matches!(err, Err(ExecError::MemOutOfBounds { .. })));
    }

    #[test]
    fn invalid_forms_rejected() {
        let mut mm = m();
        mm.regs.vsr[32] = Vsr::from_f32x4([1.0; 4]);
        mm.regs.vsr[33] = Vsr::from_f32x4([1.0; 4]);
        let err = mm.exec_ger(&Ger::new(GerKind::F32Ger, AccOp::SPP, 0, 32, 33));
        assert!(matches!(err, Err(ExecError::InvalidForm { .. })));
        let err = mm.exec_ger(&Ger::new(GerKind::I4Ger8, AccOp::NN, 0, 32, 33));
        assert!(matches!(err, Err(ExecError::InvalidForm { .. })));
    }

    #[test]
    fn masked_new_form_zeroes_disabled_elements() {
        // priming form with masks: disabled elements are written as zero
        let mut mm = m();
        mm.regs.vsr[32] = Vsr::from_f32x4([2.0; 4]);
        mm.regs.vsr[33] = Vsr::from_f32x4([3.0; 4]);
        mm.regs.acc[0] = Acc::from_f32_4x4([[7.0; 4]; 4]); // stale garbage
        mm.exec_ger(&Ger::prefixed(GerKind::F32Ger, AccOp::New, 0, 32, 33, 0b0001, 0b0001, 0xff))
            .unwrap();
        let a = mm.regs.acc[0].to_f32_4x4();
        assert_eq!(a[0][0], 6.0);
        for i in 0..4 {
            for j in 0..4 {
                if (i, j) != (0, 0) {
                    assert_eq!(a[i][j], 0.0, "({i},{j}) must be zeroed by the priming form");
                }
            }
        }
    }
}
