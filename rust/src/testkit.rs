//! Property-testing substrate.
//!
//! The offline build environment has no `proptest`/`quickcheck`, so this
//! module provides the pieces the test suite needs: a fast deterministic
//! PRNG (xoshiro256**), value generators, and a tiny property harness with
//! case counting and failure reporting (including the failing seed so a
//! case can be replayed).

/// xoshiro256** PRNG — deterministic, seedable, good statistical quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded PRNG. Every test should pass a fixed seed for reproducibility.
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // multiply-shift; bias negligible for test generation purposes
        ((u128::from(self.next_u64()) * u128::from(n)) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    #[inline]
    pub fn irange(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_range(f64::from(lo), f64::from(hi)) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Vector of uniform f64 in [-1, 1).
    pub fn f64_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.f64_range(-1.0, 1.0)).collect()
    }

    /// Vector of uniform f32 in [-1, 1).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(-1.0, 1.0)).collect()
    }
}

/// Run `f` for `cases` generated cases. On panic, reports the case index and
/// the per-case seed so the failure can be replayed with [`replay`].
pub fn check(name: &str, cases: u32, mut f: impl FnMut(&mut Rng)) {
    let base = crate::rt::fnv1a(name.as_bytes());
    for i in 0..cases {
        let seed = base ^ (u64::from(i) << 32) ^ u64::from(i);
        let mut rng = Rng::new(seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(payload) = r {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {i} (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one failing case of [`check`] by seed.
pub fn replay(seed: u64, mut f: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// Assert two float slices are close: `|a-b| <= atol + rtol*|b|` elementwise.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

/// `assert_allclose` for f32 slices.
#[track_caller]
pub fn assert_allclose_f32(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    let a64: Vec<f64> = a.iter().map(|&v| f64::from(v)).collect();
    let b64: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
    assert_allclose(&a64, &b64, f64::from(rtol), f64::from(atol));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn ranges_in_bounds() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let v = rng.range(3, 10);
            assert!((3..10).contains(&v));
            let f = rng.f64_range(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&f));
            let i = rng.irange(-5, 5);
            assert!((-5..=5).contains(&i));
        }
    }

    #[test]
    fn below_covers_small_domains() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[rng.below(4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn check_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 5, |_| panic!("boom"));
        });
        let payload = r.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn allclose() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-9, 2.0], 1e-6, 0.0);
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[1.1], 1e-6, 0.0));
        assert!(r.is_err());
    }
}
