//! The §IV programming model: MMA **compiler built-ins** (Table II) as a
//! `KernelBuilder` API.
//!
//! The paper advocates built-ins as "a compromise in abstraction: the
//! programmer has detailed control of the operations performed by the
//! machine while … low-level optimizations such as instruction scheduling
//! and register allocation are left to the compiler." This module plays the
//! compiler's role: each method corresponds 1:1 to a `__builtin_mma_*`
//! function and emits the matching instruction(s), while accumulator and
//! vector-scalar register allocation is handled here.
//!
//! The §IV guidelines are enforced:
//!
//! * at most 8 live accumulators (guideline 3) — a 9th allocation returns
//!   [`BuiltinError::AccumulatorPressure`] instead of silently spilling;
//! * `assemble_acc`/`disassemble_acc` are preferred over raw
//!   `xxmtacc`/`xxmfacc` (guideline 1) — both are provided, the former pair
//!   handles the VSR-group copies;
//! * accumulators must be primed before use (rule 4) — enforced at run time
//!   by [`crate::isa::Machine`].

use crate::isa::inst::{AccOp, Ger, GerKind, Inst};

/// Handle to an allocated accumulator (`__vector_quad`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccReg(pub(crate) u8);

/// Handle to an allocated 16-byte vector (`__vector unsigned char`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VecReg(pub(crate) u8);

/// Handle to an even-odd VSR pair (`__vector_pair`, the fp64 X operand).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VecPair(pub(crate) u8);

/// A general-purpose register used for addressing (caller-managed, like
/// function arguments r3..r10 in the Power ABI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Gpr(pub u8);

impl AccReg {
    /// Architected accumulator index (0..8).
    pub fn index(self) -> u8 {
        self.0
    }
}

impl VecReg {
    /// Architected VSR index.
    pub fn index(self) -> u8 {
        self.0
    }
}

impl VecPair {
    /// Even VSR index of the pair.
    pub fn index(self) -> u8 {
        self.0
    }
}

/// Register-allocation failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BuiltinError {
    /// More than 8 live accumulators (§IV guideline 3: "the programmer must
    /// be conscious of the actual number of accumulators supported by the
    /// architecture (8)").
    AccumulatorPressure,
    /// The vs32..vs63 scratch pool is exhausted.
    VsrPressure,
    /// Unarchitected (kind, op) combination.
    InvalidForm { mnemonic: String },
}

impl std::fmt::Display for BuiltinError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuiltinError::AccumulatorPressure => write!(
                f,
                "too many live accumulators: the architecture has 8; the compiler would spill (§IV)"
            ),
            BuiltinError::VsrPressure => write!(f, "out of scratch vector-scalar registers (vs32..vs63)"),
            BuiltinError::InvalidForm { mnemonic } => write!(f, "unarchitected builtin {mnemonic}"),
        }
    }
}

impl std::error::Error for BuiltinError {}

/// Emits instruction streams from builtin-level code, allocating
/// accumulators (ACC0..7) and scratch VSRs (vs32..vs63 — the registers that
/// never alias an accumulator, Figure 1).
#[derive(Default)]
pub struct KernelBuilder {
    insts: Vec<Inst>,
    byte_off: u32,
    acc_live: [bool; 8],
    vsr_live: [bool; 32], // vs32 + i
    /// High-water mark of simultaneously live accumulators.
    pub max_live_accs: usize,
}

impl KernelBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- register allocation ----------------------------------------------

    /// Allocate an accumulator (`__vector_quad` declaration).
    pub fn alloc_acc(&mut self) -> Result<AccReg, BuiltinError> {
        let Some(i) = self.acc_live.iter().position(|l| !l) else {
            return Err(BuiltinError::AccumulatorPressure);
        };
        self.acc_live[i] = true;
        let live = self.acc_live.iter().filter(|&&l| l).count();
        self.max_live_accs = self.max_live_accs.max(live);
        Ok(AccReg(i as u8))
    }

    /// Allocate all 8 accumulators at once (the Fig 4 virtual 8×8 pattern).
    pub fn alloc_all_accs(&mut self) -> Result<[AccReg; 8], BuiltinError> {
        let mut out = [AccReg(0); 8];
        for slot in out.iter_mut() {
            *slot = self.alloc_acc()?;
        }
        Ok(out)
    }

    /// Release an accumulator (end of its live range).
    pub fn free_acc(&mut self, a: AccReg) {
        self.acc_live[a.0 as usize] = false;
    }

    /// Allocate a scratch vector register from vs32..vs63.
    pub fn alloc_vec(&mut self) -> Result<VecReg, BuiltinError> {
        let Some(i) = self.vsr_live.iter().position(|l| !l) else {
            return Err(BuiltinError::VsrPressure);
        };
        self.vsr_live[i] = true;
        Ok(VecReg(32 + i as u8))
    }

    /// Allocate an even-aligned VSR pair (`__vector_pair`).
    pub fn alloc_pair(&mut self) -> Result<VecPair, BuiltinError> {
        let Some(i) = (0..31).step_by(2).find(|&i| !self.vsr_live[i] && !self.vsr_live[i + 1]) else {
            return Err(BuiltinError::VsrPressure);
        };
        self.vsr_live[i] = true;
        self.vsr_live[i + 1] = true;
        Ok(VecPair(32 + i as u8))
    }

    pub fn free_vec(&mut self, v: VecReg) {
        self.vsr_live[(v.0 - 32) as usize] = false;
    }

    pub fn free_pair(&mut self, p: VecPair) {
        self.vsr_live[(p.0 - 32) as usize] = false;
        self.vsr_live[(p.0 - 31) as usize] = false;
    }

    // ---- raw emission -------------------------------------------------------

    /// Append a raw instruction (escape hatch; prefer the builtin methods).
    pub fn emit(&mut self, inst: Inst) {
        self.byte_off += inst.size();
        self.insts.push(inst);
    }

    /// Current byte offset — use as a loop-top label for [`Self::bdnz`].
    pub fn label(&self) -> u32 {
        self.byte_off
    }

    // ---- Table II: accumulator manipulation ---------------------------------

    /// `__builtin_mma_xxsetaccz(&A)`.
    pub fn xxsetaccz(&mut self, a: AccReg) {
        self.emit(Inst::XxSetAccZ { acc: a.0 });
    }

    /// `__builtin_mma_xxmtacc(&A)` (provided for completeness; §IV
    /// recommends [`Self::assemble_acc`]).
    pub fn xxmtacc(&mut self, a: AccReg) {
        self.emit(Inst::XxMtAcc { acc: a.0 });
    }

    /// `__builtin_mma_xxmfacc(&A)` (see [`Self::disassemble_acc`]).
    pub fn xxmfacc(&mut self, a: AccReg) {
        self.emit(Inst::XxMfAcc { acc: a.0 });
    }

    /// `__builtin_mma_assemble_acc(&A, x, y, z, t)` — *gather* four
    /// arbitrary vectors into an accumulator: copies them into the
    /// accumulator's VSR group then primes with `xxmtacc` (exactly the code
    /// a compiler emits).
    pub fn assemble_acc(&mut self, a: AccReg, rows: [VecReg; 4]) {
        for (r, v) in rows.iter().enumerate() {
            let dst = a.0 * 4 + r as u8;
            self.emit(Inst::Xxlor { xt: dst, xa: v.0, xb: v.0 });
        }
        self.emit(Inst::XxMtAcc { acc: a.0 });
    }

    /// `__builtin_mma_disassemble_acc(&x, &A)` — *scatter* the accumulator
    /// into four freshly allocated vectors (deprimes the accumulator).
    pub fn disassemble_acc(&mut self, a: AccReg) -> Result<[VecReg; 4], BuiltinError> {
        self.emit(Inst::XxMfAcc { acc: a.0 });
        let mut out = [VecReg(0); 4];
        for (r, slot) in out.iter_mut().enumerate() {
            let v = self.alloc_vec()?;
            let src = a.0 * 4 + r as u8;
            self.emit(Inst::Xxlor { xt: v.0, xa: src, xb: src });
            *slot = v;
        }
        Ok(out)
    }

    // ---- Table II: rank-k updates -------------------------------------------

    /// Generic `__builtin_mma_xv…ger…(&A, x, y)` — all conventional forms.
    pub fn ger(&mut self, kind: GerKind, op: AccOp, a: AccReg, x: VecReg, y: VecReg) -> Result<(), BuiltinError> {
        if !op.valid_for(kind) {
            return Err(BuiltinError::InvalidForm {
                mnemonic: Ger::new(kind, op, a.0, x.0, y.0).mnemonic(),
            });
        }
        self.emit(Inst::Ger(Ger::new(kind, op, a.0, x.0, y.0)));
        Ok(())
    }

    /// Generic prefixed `__builtin_mma_pmxv…ger…(&A, x, y, masks…)`.
    /// Masks are LSB-first (bit i = row/col/product i).
    #[allow(clippy::too_many_arguments)]
    pub fn pm_ger(
        &mut self,
        kind: GerKind,
        op: AccOp,
        a: AccReg,
        x: VecReg,
        y: VecReg,
        xmsk: u8,
        ymsk: u8,
        pmsk: u8,
    ) -> Result<(), BuiltinError> {
        if !op.valid_for(kind) {
            return Err(BuiltinError::InvalidForm {
                mnemonic: Ger::prefixed(kind, op, a.0, x.0, y.0, xmsk, ymsk, pmsk).mnemonic(),
            });
        }
        self.emit(Inst::Ger(Ger::prefixed(kind, op, a.0, x.0, y.0, xmsk, ymsk, pmsk)));
        Ok(())
    }

    /// `__builtin_mma_xvf64ger…(&A, Q, y)` — fp64 forms take a vector pair.
    pub fn xvf64(&mut self, op: AccOp, a: AccReg, q: VecPair, y: VecReg) -> Result<(), BuiltinError> {
        if !op.valid_for(GerKind::F64Ger) {
            return Err(BuiltinError::InvalidForm {
                mnemonic: Ger::new(GerKind::F64Ger, op, a.0, q.0, y.0).mnemonic(),
            });
        }
        self.emit(Inst::Ger(Ger::new(GerKind::F64Ger, op, a.0, q.0, y.0)));
        Ok(())
    }

    /// Prefixed fp64 form (x mask 4 bits, y mask 2 bits, no product mask).
    pub fn pm_xvf64(
        &mut self,
        op: AccOp,
        a: AccReg,
        q: VecPair,
        y: VecReg,
        xmsk: u8,
        ymsk: u8,
    ) -> Result<(), BuiltinError> {
        if !op.valid_for(GerKind::F64Ger) {
            return Err(BuiltinError::InvalidForm {
                mnemonic: Ger::new(GerKind::F64Ger, op, a.0, q.0, y.0).mnemonic(),
            });
        }
        self.emit(Inst::Ger(Ger::prefixed(GerKind::F64Ger, op, a.0, q.0, y.0, xmsk, ymsk, 0xff)));
        Ok(())
    }

    // ---- memory & control (the surrounding C code of Figures 5-9) -----------

    /// `*((fp64_2*)p + d)` vector load.
    pub fn lxv(&mut self, v: VecReg, base: Gpr, disp: i32) {
        self.emit(Inst::Lxv { xt: v.0, ra: base.0, dq: disp });
    }

    /// `__vector_pair` load (32 bytes).
    pub fn lxvp(&mut self, p: VecPair, base: Gpr, disp: i32) {
        self.emit(Inst::Lxvp { xtp: p.0, ra: base.0, dq: disp });
    }

    pub fn stxv(&mut self, v: VecReg, base: Gpr, disp: i32) {
        self.emit(Inst::Stxv { xs: v.0, ra: base.0, dq: disp });
    }

    /// Store an accumulator to memory — the `mma_store_acc` macro of
    /// Figure 5: `disassemble_acc` + four 16-byte stores at
    /// `base + 16*(disp_vecs + r)`. The accumulator is deprimed.
    pub fn store_acc(&mut self, a: AccReg, base: Gpr, disp_vecs: i32) -> Result<(), BuiltinError> {
        let rows = self.disassemble_acc(a)?;
        for (r, v) in rows.iter().enumerate() {
            self.stxv(*v, base, (disp_vecs + r as i32) * 16);
            self.free_vec(*v);
        }
        Ok(())
    }

    /// `p += bytes` pointer bump.
    pub fn addi(&mut self, rt: Gpr, ra: Gpr, si: i32) {
        self.emit(Inst::Addi { rt: rt.0, ra: ra.0, si });
    }

    /// Load an immediate loop count.
    pub fn li(&mut self, rt: Gpr, si: i32) {
        self.emit(Inst::Addi { rt: rt.0, ra: 0, si });
    }

    pub fn mtctr(&mut self, rs: Gpr) {
        self.emit(Inst::Mtctr { rs: rs.0 });
    }

    /// Close a CTR loop whose top is at `label` (from [`Self::label`]).
    pub fn bdnz(&mut self, label: u32) {
        let bd = label as i64 - self.byte_off as i64;
        self.emit(Inst::Bdnz { bd: bd as i32 });
    }

    /// Finish the kernel: appends `blr` and returns the instruction stream.
    pub fn finish(mut self) -> Vec<Inst> {
        self.emit(Inst::Blr);
        self.insts
    }

    /// Instruction stream so far (for inspection in tests).
    pub fn insts(&self) -> &[Inst] {
        &self.insts
    }
}

/// Names of all Table II rank-k builtins and the (kind, op, prefixed) they
/// map to — used by the Table II coverage test and the docs.
pub fn table2_builtins() -> Vec<(String, GerKind, AccOp, bool)> {
    let ops = [AccOp::New, AccOp::NewS, AccOp::PP, AccOp::NP, AccOp::PN, AccOp::NN, AccOp::SPP];
    let mut out = Vec::new();
    for kind in GerKind::ALL {
        for op in ops {
            if !op.valid_for(kind) {
                continue;
            }
            for prefixed in [false, true] {
                let pm = if prefixed { "pm" } else { "" };
                let name = format!("__builtin_mma_{pm}{}{}", kind.mnemonic(), op.suffix());
                out.push((name, kind, op, prefixed));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::Vsr;
    use crate::isa::Machine;

    #[test]
    fn table2_coverage_every_builtin_emits_its_instruction() {
        // 29 conventional + 29 prefixed rank-k builtins
        let builtins = table2_builtins();
        assert_eq!(builtins.len(), 58);
        for (name, kind, op, prefixed) in builtins {
            let mut b = KernelBuilder::new();
            let a = b.alloc_acc().unwrap();
            if kind == GerKind::F64Ger {
                let q = b.alloc_pair().unwrap();
                let y = b.alloc_vec().unwrap();
                if prefixed {
                    b.pm_xvf64(op, a, q, y, 0xf, 0x3).unwrap();
                } else {
                    b.xvf64(op, a, q, y).unwrap();
                }
            } else {
                let x = b.alloc_vec().unwrap();
                let y = b.alloc_vec().unwrap();
                if prefixed {
                    b.pm_ger(kind, op, a, x, y, 0xf, 0xf, 0xff).unwrap();
                } else {
                    b.ger(kind, op, a, x, y).unwrap();
                }
            }
            let insts = b.insts();
            assert_eq!(insts.len(), 1, "{name}");
            let Inst::Ger(g) = insts[0] else { panic!("{name}") };
            assert_eq!(g.kind, kind, "{name}");
            assert_eq!(g.op, op, "{name}");
            assert_eq!(g.prefixed, prefixed, "{name}");
            // builtin name corresponds to the instruction mnemonic
            assert_eq!(name, format!("__builtin_mma_{}", g.mnemonic()));
        }
    }

    #[test]
    fn accumulator_pressure_guideline3() {
        let mut b = KernelBuilder::new();
        let accs = b.alloc_all_accs().unwrap();
        assert_eq!(b.max_live_accs, 8);
        assert_eq!(b.alloc_acc(), Err(BuiltinError::AccumulatorPressure));
        b.free_acc(accs[3]);
        let again = b.alloc_acc().unwrap();
        assert_eq!(again.index(), 3, "freed accumulator is reused");
    }

    #[test]
    fn pair_allocation_is_even_aligned() {
        let mut b = KernelBuilder::new();
        let _v = b.alloc_vec().unwrap(); // takes vs32
        let p = b.alloc_pair().unwrap();
        assert_eq!(p.index() % 2, 0);
        assert!(p.index() >= 34);
    }

    #[test]
    fn assemble_disassemble_round_trip_on_machine() {
        // assemble an accumulator from 4 arbitrary vectors, then
        // disassemble and store: gather -> scatter must be the identity
        let mut b = KernelBuilder::new();
        let a = b.alloc_acc().unwrap();
        let rows: Vec<VecReg> = (0..4).map(|_| b.alloc_vec().unwrap()).collect();
        let base = Gpr(3);
        for (r, v) in rows.iter().enumerate() {
            b.lxv(*v, base, 16 * r as i32);
        }
        b.assemble_acc(a, [rows[0], rows[1], rows[2], rows[3]]);
        b.store_acc(a, base, 8).unwrap();
        let prog = b.finish();

        let mut m = Machine::new(4096);
        let src: Vec<f32> = (0..16).map(|i| i as f32).collect();
        m.write_f32s(0, &src);
        m.gpr[3] = 0;
        m.run(&prog, 1_000).unwrap();
        assert_eq!(m.read_f32s(128, 16), src, "gather->scatter is the identity");
        assert!(!m.regs.primed[0], "store_acc deprimes");
    }

    #[test]
    fn assemble_acc_differs_from_xxmtacc() {
        // assemble_acc works from arbitrary vectors (vs32+), xxmtacc only
        // from the accumulator's own group — the paper's §IV distinction.
        let mut b = KernelBuilder::new();
        let a = b.alloc_acc().unwrap();
        let v = b.alloc_vec().unwrap();
        b.assemble_acc(a, [v, v, v, v]);
        let prog = b.finish();
        // the emitted stream copies into the group then primes
        assert!(matches!(prog[0], Inst::Xxlor { xt: 0, xa: 32, xb: 32 }));
        assert!(matches!(prog[4], Inst::XxMtAcc { acc: 0 }));

        let mut m = Machine::new(64);
        m.regs.vsr[32] = Vsr::from_f32x4([3.0; 4]);
        m.run(&prog, 100).unwrap();
        assert_eq!(m.regs.acc[0].to_f32_4x4(), [[3.0; 4]; 4]);
    }

    #[test]
    fn invalid_builtin_rejected() {
        let mut b = KernelBuilder::new();
        let a = b.alloc_acc().unwrap();
        let x = b.alloc_vec().unwrap();
        let y = b.alloc_vec().unwrap();
        assert!(matches!(
            b.ger(GerKind::F32Ger, AccOp::SPP, a, x, y),
            Err(BuiltinError::InvalidForm { .. })
        ));
        let q = b.alloc_pair().unwrap();
        assert!(b.xvf64(AccOp::SPP, a, q, y).is_err());
    }

    #[test]
    fn label_accounts_for_prefixed_sizes() {
        let mut b = KernelBuilder::new();
        let a = b.alloc_acc().unwrap();
        let x = b.alloc_vec().unwrap();
        let y = b.alloc_vec().unwrap();
        b.pm_ger(GerKind::F32Ger, AccOp::New, a, x, y, 0xf, 0xf, 0xff).unwrap(); // 8 bytes
        assert_eq!(b.label(), 8);
        b.ger(GerKind::F32Ger, AccOp::PP, a, x, y).unwrap(); // 4 bytes
        assert_eq!(b.label(), 12);
    }

    #[test]
    fn ctr_loop_via_builder_runs() {
        let mut b = KernelBuilder::new();
        let a = b.alloc_acc().unwrap();
        let x = b.alloc_vec().unwrap();
        let y = b.alloc_vec().unwrap();
        let (px, n) = (Gpr(4), Gpr(9));
        b.lxv(x, px, 0);
        b.lxv(y, px, 16);
        b.li(n, 7);
        b.mtctr(n);
        b.xxsetaccz(a);
        let top = b.label();
        b.ger(GerKind::F32Ger, AccOp::PP, a, x, y).unwrap();
        b.bdnz(top);
        b.store_acc(a, px, 2).unwrap();
        let prog = b.finish();

        let mut m = Machine::new(256);
        m.write_f32s(0, &[2.0; 8]);
        m.run(&prog, 1000).unwrap();
        assert_eq!(m.read_f32s(32, 4), vec![7.0 * 4.0; 4]);
    }
}
