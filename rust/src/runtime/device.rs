//! The device/session layer of the execution API: one [`Device`] per
//! process (or per explicit budget) owning the **persistent GEMM worker
//! pool** and the global thread budget, **typed tensor buffers**
//! ([`TensorRef`] / [`TensorMut`] over [`DTypeSlice`]), and the
//! per-request [`ExecCtx`] that carries both to a compiled model.
//!
//! This is the layered-context interface of the compiler-built-ins
//! papers (Moreira et al. 2021; Kuzma et al. 2023): typed buffers plus a
//! long-lived layered engine, instead of untyped flat `&[&[f32]]` slices
//! and per-call scoped thread spawns. Concretely:
//!
//! * the [`Device`] wraps one [`crate::rt::ThreadPool`] that every GEMM
//!   in the process fans out over via the blocking
//!   [`par_for`](crate::rt::ThreadPool::par_for) primitive — coordinator
//!   shards all draw from this one pool, so adding shards cannot
//!   oversubscribe cores;
//! * [`DTypeSlice`] makes the element type part of the API: `F32` slices
//!   execute directly; `Bf16` slices (stored as raw `u16` bits, the
//!   `xvbf16ger2` operand width) route to the **bf16 packed-panel
//!   engine** on the plan backend — a parameter consumed only by fused
//!   `dot_bf16` steps is packed straight from the raw bits
//!   ([`crate::blas::bf16_gemm`]), with no f32 widening anywhere on the
//!   path, and anything else widens exactly into its arena slot;
//! * the [`ExecCtx`] bundles the device handle with reusable per-request
//!   staging for backends that still need an f32 view (the interpreter
//!   oracle), so dtype conversion allocates once per context, not once
//!   per request.
//!
//! ```
//! use power_mma::runtime::{Device, TensorRef, TensorMut, DTypeSlice};
//!
//! let device = Device::new(2); // explicit 2-worker budget
//! assert_eq!(device.threads(), 2);
//! let x = [1.0f32, 2.0, 3.0, 4.0];
//! let t = TensorRef::f32(&x, &[2, 2]);
//! assert_eq!(t.elems(), 4);
//! assert!(matches!(t.data, DTypeSlice::F32(_)));
//! let mut out = [0u16; 4];
//! let mut tm = TensorMut::bf16(&mut out, &[2, 2]);
//! tm.store(&x).unwrap(); // bf16 round-to-nearest-even at the boundary
//! assert_eq!(out[0], 0x3f80); // 1.0 in bf16 bits
//! ```

use crate::bail;
use crate::error::Result;
use crate::rt::ThreadPool;
use std::sync::{Arc, OnceLock};

/// The process-level execution context: the persistent GEMM worker pool
/// plus the global worker budget. Create one with [`Device::new`] for an
/// explicit budget, or share the process-wide instance via
/// [`Device::shared`]. Every [`Runtime`](super::Runtime) holds a
/// `Arc<Device>`; coordinator shards that share a device share its pool,
/// which is what keeps the total GEMM worker count bounded no matter how
/// many engines are serving.
pub struct Device {
    pool: ThreadPool,
    threads: usize,
    tune: Arc<super::tune::TuneTable>,
}

impl Device {
    /// The default worker budget: `std::thread::available_parallelism()`
    /// clamped to 16 — the single source of the process-wide policy
    /// (previously duplicated per backend).
    pub fn default_threads() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get()).min(16)
    }

    /// A device with an explicit worker budget (the pool is spawned
    /// eagerly and lives as long as the device).
    pub fn new(threads: usize) -> Arc<Device> {
        let threads = threads.max(1);
        Arc::new(Device {
            pool: ThreadPool::new("mma-gemm", threads),
            threads,
            tune: Arc::new(super::tune::TuneTable::new()),
        })
    }

    /// The process-wide shared device (budget =
    /// [`Device::default_threads`]), created on first use and alive for
    /// the rest of the process — the "persistent GEMM worker pool" of the
    /// serving path. Idle workers cost nothing but a parked thread.
    pub fn shared() -> Arc<Device> {
        static SHARED: OnceLock<Arc<Device>> = OnceLock::new();
        SHARED.get_or_init(|| Device::new(Device::default_threads())).clone()
    }

    /// The worker budget (also the pool size).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The persistent worker pool (fan GEMM panel work out with
    /// [`ThreadPool::par_for`]).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// The device's shape-autotuning table: one memoized
    /// `class → variant` map shared by every plan compiled against this
    /// device (pass it to
    /// [`PlanOptions`](super::plan::PlanOptions)/`HloPlanBackend::
    /// with_tuning` to opt a compilation in). Lazy: it costs nothing
    /// until a tuned compilation first consults it.
    pub fn tune(&self) -> Arc<super::tune::TuneTable> {
        self.tune.clone()
    }

    /// A fresh per-request execution context on this device.
    pub fn ctx(&self) -> ExecCtx<'_> {
        ExecCtx::new(self)
    }
}

/// The bf16↔f32 conversions of the typed-tensor boundary, re-exported
/// from their single source in [`crate::isa::types`] (this module used
/// to carry its own copies): `bf16_to_f32` widens exactly (every bf16
/// value is representable), `f32_to_bf16` narrows with
/// round-to-nearest-even — the `xvbf16ger2` input contract, sharing its
/// RNE core with [`bf16_round`](super::hlo::bf16_round) (which differs
/// only in NaN policy: `bf16_round` canonicalizes, `f32_to_bf16` quiets
/// and keeps the payload).
pub use crate::isa::types::{bf16_to_f32, f32_to_bf16};

/// A typed, borrowed, read-only tensor buffer: the element storage of
/// one model input. `F32` is the native execution dtype; `Bf16` carries
/// raw bf16 bits (`u16`, the high half of the f32 layout) and is widened
/// exactly at the API boundary.
#[derive(Clone, Copy, Debug)]
pub enum DTypeSlice<'a> {
    /// Native f32 storage.
    F32(&'a [f32]),
    /// bf16 storage as raw bits (widened exactly on entry).
    Bf16(&'a [u16]),
}

impl DTypeSlice<'_> {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            DTypeSlice::F32(s) => s.len(),
            DTypeSlice::Bf16(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Human-readable dtype name (diagnostics).
    pub fn dtype(&self) -> &'static str {
        match self {
            DTypeSlice::F32(_) => "f32",
            DTypeSlice::Bf16(_) => "bf16",
        }
    }
}

/// A typed, borrowed input tensor: storage plus logical row-major dims.
/// The dims are validated against the model metadata at execute time —
/// the shape checking the untyped `&[&[f32]]` API could not do.
#[derive(Clone, Copy, Debug)]
pub struct TensorRef<'a> {
    /// Element storage.
    pub data: DTypeSlice<'a>,
    /// Logical row-major shape.
    pub dims: &'a [usize],
}

impl<'a> TensorRef<'a> {
    /// An f32 tensor view.
    pub fn f32(data: &'a [f32], dims: &'a [usize]) -> TensorRef<'a> {
        TensorRef { data: DTypeSlice::F32(data), dims }
    }

    /// A bf16 tensor view over raw bf16 bits.
    pub fn bf16(data: &'a [u16], dims: &'a [usize]) -> TensorRef<'a> {
        TensorRef { data: DTypeSlice::Bf16(data), dims }
    }

    /// Element count of the storage.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element count the dims claim (must equal [`TensorRef::len`]).
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Mutable element storage of one output tensor.
#[derive(Debug)]
pub enum DTypeSliceMut<'a> {
    /// Native f32 storage.
    F32(&'a mut [f32]),
    /// bf16 storage as raw bits (results are rounded to nearest even on
    /// the final store).
    Bf16(&'a mut [u16]),
}

impl DTypeSliceMut<'_> {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            DTypeSliceMut::F32(s) => s.len(),
            DTypeSliceMut::Bf16(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A typed, borrowed output buffer: where a compiled model writes its
/// result. An `F32` buffer receives the result verbatim; a `Bf16` buffer
/// receives it rounded to nearest even per element.
#[derive(Debug)]
pub struct TensorMut<'a> {
    /// Element storage (written by [`TensorMut::store`]).
    pub data: DTypeSliceMut<'a>,
    /// Logical row-major shape.
    pub dims: &'a [usize],
}

impl<'a> TensorMut<'a> {
    /// An f32 output buffer.
    pub fn f32(data: &'a mut [f32], dims: &'a [usize]) -> TensorMut<'a> {
        TensorMut { data: DTypeSliceMut::F32(data), dims }
    }

    /// A bf16 output buffer (results rounded on store).
    pub fn bf16(data: &'a mut [u16], dims: &'a [usize]) -> TensorMut<'a> {
        TensorMut { data: DTypeSliceMut::Bf16(data), dims }
    }

    /// Element count of the storage.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Write a finished f32 result into the buffer, converting per the
    /// buffer's dtype. Fails on length mismatch.
    pub fn store(&mut self, result: &[f32]) -> Result<()> {
        match &mut self.data {
            DTypeSliceMut::F32(dst) => {
                if dst.len() != result.len() {
                    bail!("output buffer has {} elements, result has {}", dst.len(), result.len());
                }
                dst.copy_from_slice(result);
            }
            DTypeSliceMut::Bf16(dst) => {
                if dst.len() != result.len() {
                    bail!("output buffer has {} elements, result has {}", dst.len(), result.len());
                }
                // the output contract is XLA's convert (canonical quiet
                // NaN), matching bf16_round and the packers — NOT the
                // payload-preserving ISA converter re-exported above
                for (d, &v) in dst.iter_mut().zip(result) {
                    *d = crate::isa::types::f32_to_bf16_canonical(v);
                }
            }
        }
        Ok(())
    }
}

/// Per-request execution context: the device handle (worker pool +
/// budget) plus reusable staging buffers for dtype conversion at the API
/// boundary. Create with [`Device::ctx`] (or [`ExecCtx::new`]) and reuse
/// across requests — staging capacity is retained, so steady-state
/// requests with bf16 inputs allocate nothing.
pub struct ExecCtx<'d> {
    device: &'d Device,
    /// One staging slot per input position; filled only for non-f32
    /// inputs (exact widening), reused across requests.
    staging: Vec<Vec<f32>>,
}

impl<'d> ExecCtx<'d> {
    /// A fresh context on `device` (no allocation until a non-f32 input
    /// is staged).
    pub fn new(device: &'d Device) -> ExecCtx<'d> {
        ExecCtx { device, staging: Vec::new() }
    }

    /// The device this context executes on.
    pub fn device(&self) -> &'d Device {
        self.device
    }

    /// Widen every non-f32 input into this context's staging slots;
    /// afterwards [`ExecCtx::f32_view`] yields a plain `&[f32]` for any
    /// input index.
    pub(crate) fn stage(&mut self, inputs: &[TensorRef<'_>]) {
        if self.staging.len() < inputs.len() {
            self.staging.resize_with(inputs.len(), Vec::new);
        }
        for (slot, t) in self.staging.iter_mut().zip(inputs) {
            if let DTypeSlice::Bf16(bits) = t.data {
                slot.clear();
                slot.extend(bits.iter().map(|&b| bf16_to_f32(b)));
            }
        }
    }

    /// The f32 view of input `i`: the input's own storage for `F32`
    /// tensors, the staged widening for `Bf16` tensors. Call
    /// [`ExecCtx::stage`] first.
    pub(crate) fn f32_view<'s>(&'s self, i: usize, inputs: &'s [TensorRef<'s>]) -> &'s [f32] {
        match inputs[i].data {
            DTypeSlice::F32(s) => s,
            DTypeSlice::Bf16(_) => &self.staging[i],
        }
    }

    /// Stage and collect the f32 views of all inputs (the bridge every
    /// backend uses between the typed API and the f32 execution core).
    pub(crate) fn f32_inputs<'s>(&'s mut self, inputs: &'s [TensorRef<'s>]) -> Vec<&'s [f32]> {
        self.stage(inputs);
        (0..inputs.len()).map(|i| self.f32_view(i, inputs)).collect()
    }
}

/// Validate a typed input set against parsed model metadata: input
/// count, exact dims, and storage length per input.
pub(crate) fn validate_inputs(
    name: &str,
    meta: &super::ModelMeta,
    inputs: &[TensorRef<'_>],
) -> Result<()> {
    if inputs.len() != meta.input_shapes.len() {
        bail!("{name}: expected {} inputs, got {}", meta.input_shapes.len(), inputs.len());
    }
    for (i, t) in inputs.iter().enumerate() {
        if t.dims != meta.input_shapes[i].as_slice() {
            bail!(
                "{name}: input {i} has dims {:?}, meta declares {:?}",
                t.dims,
                meta.input_shapes[i]
            );
        }
        if t.len() != t.elems() {
            bail!(
                "{name}: input {i} has {} elements, dims {:?} want {}",
                t.len(),
                t.dims,
                t.elems()
            );
        }
    }
    Ok(())
}

/// Validate a typed output buffer against parsed model metadata.
pub(crate) fn validate_output(
    name: &str,
    meta: &super::ModelMeta,
    out: &TensorMut<'_>,
) -> Result<()> {
    if out.dims != meta.output_shape.as_slice() {
        bail!(
            "{name}: output buffer has dims {:?}, meta declares {:?}",
            out.dims,
            meta.output_shape
        );
    }
    let want: usize = meta.output_shape.iter().product();
    if out.len() != want {
        bail!("{name}: output buffer has {} elements, expected {want}", out.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trip_is_exact() {
        for bits in [0u16, 0x3f80, 0xbf80, 0x4049, 0x7f80, 0xff80, 0x0001] {
            assert_eq!(f32_to_bf16(bf16_to_f32(bits)), bits, "bits {bits:#06x}");
        }
        // narrowing rounds to nearest even: 1.0 + 2^-9 is exactly halfway
        // between bf16(1.0) and the next value up -> rounds to even (1.0)
        let halfway = f32::from_bits(0x3f80_8000);
        assert_eq!(f32_to_bf16(halfway), 0x3f80);
        // ...but 1.0 + 3*2^-9 rounds up to the (even) next-next value
        let above = f32::from_bits(0x3f81_8000);
        assert_eq!(f32_to_bf16(above), 0x3f82);
    }

    #[test]
    fn tensor_views_report_shapes() {
        let d = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let t = TensorRef::f32(&d, &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.data.dtype(), "f32");
        let h = [0u16; 4];
        let t = TensorRef::bf16(&h, &[4]);
        assert_eq!(t.data.dtype(), "bf16");
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn store_converts_per_dtype() {
        let src = [1.0f32, -2.5, 0.15625];
        let mut f = [0f32; 3];
        TensorMut::f32(&mut f, &[3]).store(&src).unwrap();
        assert_eq!(f, src);
        let mut h = [0u16; 3];
        TensorMut::bf16(&mut h, &[3]).store(&src).unwrap();
        for (i, (&bits, &v)) in h.iter().zip(&src).enumerate() {
            assert_eq!(bf16_to_f32(bits), crate::runtime::hlo::bf16_round(v), "elem {i}");
        }
        // NaN results store as the *canonical* quiet NaN (the XLA
        // convert / bf16_round contract), payload dropped, sign kept
        let nans = [f32::from_bits(0x7f81_2345), f32::from_bits(0xffaa_0001)];
        let mut hn = [0u16; 2];
        TensorMut::bf16(&mut hn, &[2]).store(&nans).unwrap();
        assert_eq!(hn, [0x7fc0, 0xffc0]);
        // length mismatch rejected
        let mut short = [0f32; 2];
        assert!(TensorMut::f32(&mut short, &[2]).store(&src).is_err());
    }

    #[test]
    fn ctx_stages_bf16_inputs_exactly() {
        let device = Device::new(1);
        let mut ctx = device.ctx();
        let f = [0.5f32, -1.0];
        let h: Vec<u16> = [3.0f32, -0.125].iter().map(|&v| f32_to_bf16(v)).collect();
        let dims = [2usize];
        let inputs = [TensorRef::f32(&f, &dims), TensorRef::bf16(&h, &dims)];
        let views = ctx.f32_inputs(&inputs);
        assert_eq!(views[0], &f[..]);
        assert_eq!(views[1], &[3.0f32, -0.125][..]);
    }

    #[test]
    fn shared_device_is_a_singleton() {
        let a = Device::shared();
        let b = Device::shared();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.threads(), Device::default_threads());
        assert_eq!(a.pool().size(), a.threads());
    }
}
