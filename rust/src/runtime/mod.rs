//! Native model runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** + `.meta` shape lines + expected
//! outputs) and executes them entirely in-crate.
//!
//! The former `xla::PjRt*` FFI is gone.  Execution is organized around
//! the **device/session API** of [`device`]:
//!
//! * a [`Device`] owns the process-wide **persistent GEMM worker pool**
//!   and the global thread budget (one pool, shared by every engine and
//!   coordinator shard — see [`Device::shared`]);
//! * models execute on **typed tensors**: [`TensorRef`] /
//!   [`TensorMut`] buffers over [`DTypeSlice`] (`F32` or raw-bits
//!   `Bf16`), validated against the model metadata;
//! * an [`ExecCtx`] carries the device handle plus per-request staging
//!   into [`CompiledModel::execute`].
//!
//! Backends plug in behind the [`EngineBackend`] trait. The default
//! ([`HloPlanBackend`], behind [`Runtime::cpu`]) **compiles** each
//! artifact once at `load()` into a [`plan::Plan`] — a
//! topologically-ordered step list over a preallocated, liveness-reusing
//! buffer arena, with a rewrite pass that collapses conv graphs into
//! single im2col GEMM steps and fuses post-`dot` bias/relu tails into
//! the GEMM writeback — and executes requests against the plan on the
//! blocked parallel GEMM of [`crate::blas::block_gemm`], fanning panel
//! work out over the device pool (no scoped thread spawns on the hot
//! path). The legacy [`HloInterpreterBackend`] (per-request walk of
//! [`hlo::HloModule::evaluate`] over `ref_gemm`) is kept as the numerics
//! oracle and for `power-mma bench serve` comparisons; both produce
//! bit-identical results on the artifact set.
//!
//! The untyped [`Runtime::execute`]`(&str, &[&[f32]])` entry point stays
//! as a thin compat shim over the typed path ([`Runtime::execute_typed`])
//! so existing callers migrate incrementally.
//!
//! The coordinator still runs a [`Runtime`] on a dedicated engine thread
//! (one per shard); backends are constructed *inside* that thread via a
//! factory, so thread-confined backends remain possible. GEMM fan-out
//! drains inside each step, so nothing escapes the engine thread.

pub mod artifacts;
pub mod device;
pub mod hlo;
pub mod plan;
pub mod profile;
pub mod tune;

pub use device::{
    bf16_to_f32, f32_to_bf16, DTypeSlice, DTypeSliceMut, Device, ExecCtx, TensorMut, TensorRef,
};
pub use profile::{microkernel_fpc, InstMix, StepKernel, StepProfile, StepSpec, NOMINAL_GHZ};
pub use tune::{TuneChoice, TuneDtype, TuneEpi, TuneKey, TunePanel, TuneTable};

use crate::blas::block_gemm::Par;
use crate::error::{Context, Result};
use crate::{bail, err};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One tensor's calibration: the affine int8 quantization (`real =
/// scale · (q − zp)`) chosen for it by a calibration sweep, plus which
/// side of the `xvi8ger4` mixed-signedness split it plays (§II-B.2: the
/// X operand is signed i8, the Y operand unsigned u8). The plan's
/// `DotI8` matcher only quantizes a dot whose lhs has a *signed* entry
/// and whose rhs has an *unsigned* one.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibEntry {
    /// HLO instruction name of the tensor (e.g. `Arg_1.2`,
    /// `maximum.14`).
    pub name: String,
    /// `true` → quantizes to signed i8 (a dot lhs), `false` → unsigned
    /// u8 (a dot rhs).
    pub signed: bool,
    /// Quantization step (> 0, finite).
    pub scale: f32,
    /// Zero point, in the i8 range for signed entries / u8 for unsigned.
    pub zp: i32,
}

/// The per-tensor calibration record an int8-served model carries in its
/// [`ModelMeta`] — the optional fourth manifest field,
/// `calib:<name>=<i8|u8>@<scale>@<zp>,…`. Produced by a calibration
/// sweep ([`mlp_int8_calib`]) and consumed by the plan compiler's
/// `DotI8` matcher ([`plan::PlanOptions::int8_calib`]).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Int8Calib {
    pub entries: Vec<CalibEntry>,
}

impl Int8Calib {
    /// Look up a tensor's entry by HLO instruction name.
    pub fn get(&self, name: &str) -> Option<&CalibEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Parse the payload of a `calib:` manifest field (the part after
    /// the prefix). The whole record must parse — a truncated or
    /// malformed entry is a hard error, mirroring the trailing-field
    /// strictness of [`ModelMeta::parse`].
    pub fn parse(payload: &str) -> Result<Int8Calib> {
        if payload.trim().is_empty() {
            bail!("empty calibration record");
        }
        let mut entries = Vec::new();
        for item in payload.split(',') {
            let (name, spec) = item
                .split_once('=')
                .ok_or_else(|| err!("calibration entry '{item}' is missing '='"))?;
            if name.is_empty() {
                bail!("calibration entry '{item}' has an empty tensor name");
            }
            let mut parts = spec.split('@');
            let kind = parts.next().unwrap_or_default();
            let signed = match kind {
                "i8" => true,
                "u8" => false,
                other => bail!("calibration entry '{name}': bad kind '{other}' (want i8|u8)"),
            };
            let scale: f32 = parts
                .next()
                .ok_or_else(|| err!("calibration entry '{name}' is truncated (no scale)"))?
                .parse()
                .map_err(|_| err!("calibration entry '{name}': bad scale"))?;
            if !scale.is_finite() || scale <= 0.0 {
                bail!("calibration entry '{name}': scale must be finite and > 0");
            }
            let zp: i32 = parts
                .next()
                .ok_or_else(|| err!("calibration entry '{name}' is truncated (no zero point)"))?
                .parse()
                .map_err(|_| err!("calibration entry '{name}': bad zero point"))?;
            if let Some(extra) = parts.next() {
                bail!("calibration entry '{name}': trailing part '{extra}'");
            }
            let (lo, hi) = if signed { (-128, 127) } else { (0, 255) };
            if zp < lo || zp > hi {
                bail!("calibration entry '{name}': zero point {zp} outside [{lo},{hi}]");
            }
            entries.push(CalibEntry { name: name.to_string(), signed, scale, zp });
        }
        Ok(Int8Calib { entries })
    }

    /// Serialize as the manifest field (with the `calib:` prefix);
    /// round-trips exactly through [`Int8Calib::parse`] (Rust's shortest
    /// f32 display re-parses to the identical bits).
    pub fn manifest_field(&self) -> String {
        let body: Vec<String> = self
            .entries
            .iter()
            .map(|e| {
                format!("{}={}@{}@{}", e.name, if e.signed { "i8" } else { "u8" }, e.scale, e.zp)
            })
            .collect();
        format!("calib:{}", body.join(","))
    }
}

/// Parsed `<name>.meta` line: `name;in0shape,in1shape,…;outshape`, plus
/// an optional fourth `calib:…` field carrying the int8 calibration
/// record ([`Int8Calib`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
    /// Per-tensor int8 quantization record; `Some` marks the model as
    /// servable under `--dtype int8` (the plan backend quantizes its
    /// eligible dots when int8 mode is on).
    pub calib: Option<Int8Calib>,
}

impl ModelMeta {
    /// Parse one manifest line. Three `;`-separated fields, plus at most
    /// one optional `calib:`-prefixed calibration field — any other
    /// trailing field (`name;ins;out;junk`) is malformed and rejected,
    /// not silently truncated, and a recognized `calib:` field must
    /// parse completely (truncated records are hard errors too).
    pub fn parse(line: &str) -> Result<ModelMeta> {
        let mut parts = line.trim().split(';');
        let name = parts.next().ok_or_else(|| err!("empty manifest line"))?.to_string();
        if name.is_empty() {
            bail!("empty model name in manifest line");
        }
        let ins = parts.next().ok_or_else(|| err!("{name}: missing input shapes"))?;
        let out = parts.next().ok_or_else(|| err!("{name}: missing output shape"))?;
        let calib = match parts.next() {
            None => None,
            Some(field) => match field.strip_prefix("calib:") {
                Some(payload) => Some(
                    Int8Calib::parse(payload)
                        .map_err(|e| e.context(format!("{name}: calibration field")))?,
                ),
                None => bail!("{name}: trailing field '{field}' in manifest line"),
            },
        };
        if let Some(extra) = parts.next() {
            bail!("{name}: trailing field '{extra}' in manifest line");
        }
        let parse_shape = |s: &str| -> Result<Vec<usize>> {
            s.split('x').map(|d| d.parse::<usize>().context("bad dim")).collect()
        };
        Ok(ModelMeta {
            name,
            input_shapes: ins.split(',').map(parse_shape).collect::<Result<_>>()?,
            output_shape: parse_shape(out)?,
            calib,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// A model compiled by an [`EngineBackend`], ready to execute.
pub trait CompiledModel {
    /// Execute on typed input tensors, writing the result into the typed
    /// output buffer (rounded to the buffer's dtype). The [`ExecCtx`]
    /// supplies the device (worker pool + budget) and per-request
    /// staging; inputs are assumed validated against the model metadata
    /// (see [`Runtime::execute_typed`]).
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()>;
}

/// Pluggable execution backend: turns HLO text into executable models.
pub trait EngineBackend {
    /// Backend identifier (reported by [`Runtime::platform`]).
    fn name(&self) -> &'static str;

    /// Compile one artifact's HLO text, validating it against the meta.
    /// The device provides the worker budget compiled models size their
    /// scratch for (their `execute` draws workers from the device of the
    /// [`ExecCtx`] they are called with).
    fn compile(
        &self,
        device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>>;
}

/// Parse an artifact's HLO text and cross-check it against the meta line
/// (parameter count and element counts) — shared by every backend.
fn parse_and_validate(name: &str, hlo_text: &str, meta: &ModelMeta) -> Result<hlo::HloModule> {
    let module = hlo::HloModule::parse(hlo_text)
        .map_err(|e| e.context(format!("parsing HLO for {name}")))?;
    if module.num_parameters() != meta.input_shapes.len() {
        bail!(
            "{name}: HLO has {} parameters, meta declares {} inputs",
            module.num_parameters(),
            meta.input_shapes.len()
        );
    }
    for (i, shape) in meta.input_shapes.iter().enumerate() {
        let hlo_len: usize = module
            .parameter_dims(i)
            .ok_or_else(|| err!("{name}: HLO is missing parameter {i}"))?
            .iter()
            .product();
        let meta_len: usize = shape.iter().product();
        if hlo_len != meta_len {
            bail!("{name}: parameter {i} has {hlo_len} elements in HLO, {meta_len} in meta");
        }
    }
    Ok(module)
}

/// The legacy native backend: parses HLO text and re-interprets it per
/// request over `blas` (`ref_gemm`). Kept as the numerics oracle and the
/// baseline side of `power-mma bench serve`.
pub struct HloInterpreterBackend;

impl EngineBackend for HloInterpreterBackend {
    fn name(&self) -> &'static str {
        "native-hlo-interpreter"
    }

    fn compile(
        &self,
        _device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        Ok(Box::new(InterpretedModel { module }))
    }
}

struct InterpretedModel {
    module: hlo::HloModule,
}

impl CompiledModel for InterpretedModel {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let refs = ctx.f32_inputs(inputs);
        let mut outputs = self.module.evaluate(&refs)?;
        if outputs.is_empty() {
            return Err(err!("model produced no output"));
        }
        // aot.py lowers with return_tuple=True; a 1-tuple stores
        // directly, wider tuples (the DFT family's (re, im) pair)
        // concatenate in root order — meta.output_shape declares the
        // stacked dims, e.g. [2b, n] for two [b, n] roots
        if outputs.len() == 1 {
            let result = outputs.pop().unwrap();
            out.store(&result.data)
        } else {
            let mut data = Vec::with_capacity(outputs.iter().map(|t| t.data.len()).sum());
            for t in &outputs {
                data.extend_from_slice(&t.data);
            }
            out.store(&data)
        }
    }
}

/// The default serving backend: lowers each artifact once at `load()`
/// into a compiled [`plan::Plan`] (preallocated buffer arena, blocked
/// parallel GEMM over the device pool) and executes requests against the
/// plan. Bit-identical to [`HloInterpreterBackend`] on finite inputs,
/// several times faster on GEMM-heavy artifacts (measure with `power-mma
/// bench serve`). The worker budget comes from the [`Device`] of the
/// executing [`ExecCtx`].
pub struct HloPlanBackend {
    opts: plan::PlanOptions,
    /// `--dtype int8`: quantize the eligible dots of every model whose
    /// meta carries a calibration record (models without one still
    /// compile and serve f32 — the mixed fleet a coordinator loads).
    int8: bool,
}

impl HloPlanBackend {
    /// The plan backend with default options (thread policy lives on the
    /// device; bf16 dots accumulate widened).
    pub fn new() -> HloPlanBackend {
        HloPlanBackend { opts: plan::PlanOptions::default(), int8: false }
    }

    /// A plan backend whose `DotBf16` steps run under the given
    /// accumulation contract — the serving-mode surface for the paper's
    /// §IV-B `xvbf16ger2` rank-2 f32 chain
    /// ([`Bf16Accum::F32Pairs`](crate::blas::bf16_gemm::Bf16Accum)):
    /// `power-mma serve --bf16-accum f32-pairs` builds its engines here.
    pub fn with_bf16_accum(accum: crate::blas::bf16_gemm::Bf16Accum) -> HloPlanBackend {
        HloPlanBackend {
            opts: plan::PlanOptions { bf16_accum: accum, ..Default::default() },
            int8: false,
        }
    }

    /// The **int8 serving** backend (`power-mma serve --dtype int8`):
    /// each model whose [`ModelMeta`] carries a calibration record
    /// compiles with [`plan::PlanOptions::int8_calib`] set, so its
    /// calibrated `{1}×{0}` dots (and their bias/relu tails) lower to
    /// `dot_i8` steps on the quantized rank-4 engine
    /// ([`crate::blas::i8_gemm`]). Models without a record serve f32,
    /// unchanged.
    pub fn int8() -> HloPlanBackend {
        HloPlanBackend { opts: plan::PlanOptions::default(), int8: true }
    }

    /// Whether this backend quantizes calibrated models.
    pub fn is_int8(&self) -> bool {
        self.int8
    }

    /// Opt this backend's compilations into shape autotuning against
    /// `table` (normally [`Device::tune`]): every fused GEMM step's
    /// class is resolved through the table at compile time and the
    /// winning [`GemmVariant`](crate::blas::block_gemm::GemmVariant) is
    /// baked into the step — re-execution never re-measures. Without
    /// this, steps run the deterministic heuristic default (the
    /// canonical pre-tuner variants).
    pub fn with_tuning(mut self, table: Arc<tune::TuneTable>) -> HloPlanBackend {
        self.opts.tune = Some(table);
        self
    }
}

impl Default for HloPlanBackend {
    fn default() -> Self {
        HloPlanBackend::new()
    }
}

impl EngineBackend for HloPlanBackend {
    fn name(&self) -> &'static str {
        "native-hlo-plan"
    }

    fn compile(
        &self,
        _device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        let mut opts = self.opts.clone();
        if self.int8 {
            opts.int8_calib = meta.calib.clone();
        }
        let plan = plan::Plan::compile_with_options(&module, opts)
            .map_err(|e| e.context(format!("compiling plan for {name}")))?;
        let bufs = std::sync::Mutex::new(plan.new_buffers());
        Ok(Box::new(PlanModel { plan, bufs }))
    }
}

/// A plan plus its preallocated buffers. The buffers sit behind a
/// `Mutex` only to satisfy the `&self` execute contract; on the
/// coordinator's thread-confined engine the lock is always uncontended.
struct PlanModel {
    plan: plan::Plan,
    bufs: std::sync::Mutex<plan::ExecBuffers>,
}

impl CompiledModel for PlanModel {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let device = ctx.device();
        // dtype-aware handoff: bf16 inputs reach the plan as raw bits —
        // parameters feeding only the packed bf16 GEMM are consumed
        // straight by the panel packers (no f32 staging anywhere), the
        // rest widen exactly into their arena slots inside the plan
        let typed: Vec<plan::PlanInput<'_>> = inputs
            .iter()
            .map(|t| match t.data {
                DTypeSlice::F32(s) => plan::PlanInput::F32(s),
                DTypeSlice::Bf16(b) => plan::PlanInput::Bf16(b),
            })
            .collect();
        let mut bufs = self.bufs.lock().unwrap_or_else(|p| p.into_inner());
        let par = Par::Pool(device.pool(), device.threads());
        // zero-copy: run the steps, then store the root arena slot
        // straight into the caller's typed buffer — no intermediate
        // output tensor is materialized on the serving hot path
        self.plan.run_steps_typed(&mut bufs, &typed, par)?;
        let roots = self.plan.root_slices(&bufs);
        match roots.as_slice() {
            [] => Err(err!("model produced no output")),
            [(data, _dims)] => out.store(data),
            // multi-root plans (the DFT family's (re, im) pair) stage a
            // concatenation in root order; meta.output_shape declares
            // the stacked dims, e.g. [2b, n] for two [b, n] roots
            many => {
                let mut data = Vec::with_capacity(many.iter().map(|(s, _)| s.len()).sum());
                for (s, _) in many {
                    data.extend_from_slice(s);
                }
                out.store(&data)
            }
        }
    }
}

/// One compiled model with its metadata.
pub struct LoadedModel {
    pub meta: ModelMeta,
    exe: Box<dyn CompiledModel>,
}

/// The artifact-directory runtime with a compiled-model cache. Holds a
/// [`Device`] handle: all its models execute on that device's persistent
/// worker pool (runtimes sharing a device — e.g. coordinator shards —
/// share the pool and therefore cannot oversubscribe the budget).
pub struct Runtime {
    backend: Box<dyn EngineBackend>,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
    device: Arc<Device>,
}

impl Runtime {
    /// Runtime over an artifact directory with the default native plan
    /// backend and the process-wide shared device (the name is
    /// historical: this was the PJRT *CPU* client). Does not load
    /// anything yet.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(HloPlanBackend::new()), artifact_dir))
    }

    /// Runtime over an artifact directory with an explicit backend, on
    /// the process-wide shared device.
    pub fn with_backend(
        backend: Box<dyn EngineBackend>,
        artifact_dir: impl AsRef<Path>,
    ) -> Runtime {
        Runtime::with_device(Device::shared(), backend, artifact_dir)
    }

    /// Runtime over an artifact directory with an explicit backend *and*
    /// device (worker pool + thread budget).
    pub fn with_device(
        device: Arc<Device>,
        backend: Box<dyn EngineBackend>,
        artifact_dir: impl AsRef<Path>,
    ) -> Runtime {
        Runtime {
            backend,
            models: HashMap::new(),
            dir: artifact_dir.as_ref().to_path_buf(),
            device,
        }
    }

    /// Name of the execution backend.
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// The device this runtime executes on.
    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    /// Load + compile one artifact by name (`<dir>/<name>.hlo.txt` +
    /// `<name>.meta`). Idempotent.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta_line = std::fs::read_to_string(&meta_path).with_context(|| {
            format!("reading {} (run `power-mma gen-artifacts`?)", meta_path.display())
        })?;
        let meta = ModelMeta::parse(&meta_line)?;
        if meta.name != name {
            bail!("{}: meta file declares model '{}'", name, meta.name);
        }
        self.load_with_meta(meta)
    }

    /// Compile one artifact from an already-parsed meta line — the
    /// single-parse path `load_all` uses: the manifest line *is* the
    /// meta, so it is parsed once and passed through instead of being
    /// re-read and re-parsed from the `.meta` file per model.
    pub fn load_with_meta(&mut self, meta: ModelMeta) -> Result<()> {
        if self.models.contains_key(&meta.name) {
            return Ok(());
        }
        let hlo_path = self.dir.join(format!("{}.hlo.txt", meta.name));
        let hlo_text = std::fs::read_to_string(&hlo_path)
            .with_context(|| format!("reading {}", hlo_path.display()))?;
        let exe = self.backend.compile(&self.device, &meta.name, &hlo_text, &meta)?;
        self.models.insert(meta.name.clone(), LoadedModel { meta, exe });
        Ok(())
    }

    /// Load every artifact listed in `manifest.txt` (each line is a full
    /// meta line, parsed exactly once).
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `power-mma gen-artifacts`)")?;
        let mut names = Vec::new();
        for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ModelMeta::parse(line)?;
            let name = meta.name.clone();
            self.load_with_meta(meta)?;
            names.push(name);
        }
        Ok(names)
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.models.get(name).map(|m| &m.meta)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a model on typed tensors: inputs are validated against
    /// the metadata (count, exact dims, storage length), the result is
    /// written into `out` (rounded to its dtype). `Bf16` inputs are
    /// widened exactly through the context's staging buffers, so a bf16
    /// serving client never round-trips through caller-side conversion.
    pub fn execute_typed(
        &self,
        name: &str,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let model = self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))?;
        device::validate_inputs(name, &model.meta, inputs)?;
        device::validate_output(name, &model.meta, out)?;
        model
            .exe
            .execute(ctx, inputs, out)
            .map_err(|e| e.context(format!("execute {name}")))
    }

    /// Execute a model on flat f32 inputs (row-major); returns the flat
    /// f32 output. **Compat shim** over [`Runtime::execute_typed`]: the
    /// inputs are wrapped as f32 [`TensorRef`]s with the metadata's
    /// shapes and a fresh per-call [`ExecCtx`] on this runtime's device.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let model = self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))?;
        if inputs.len() != model.meta.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.meta.input_shapes.len(),
                inputs.len()
            );
        }
        let trefs: Vec<TensorRef<'_>> = inputs
            .iter()
            .zip(&model.meta.input_shapes)
            .map(|(d, s)| TensorRef::f32(d, s))
            .collect();
        let mut result = vec![0f32; model.meta.output_len()];
        let mut out = TensorMut::f32(&mut result, &model.meta.output_shape);
        let mut ctx = self.device.ctx();
        self.execute_typed(name, &mut ctx, &trefs, &mut out)?;
        Ok(result)
    }

    /// Compile a model from an in-memory HLO string (no artifact files on
    /// disk) — how the batch-bucket ladder is materialized at `load()`
    /// time. Idempotent by model name: an already-loaded model (e.g. the
    /// `mlp_b32` AOT fixture) is kept, not recompiled.
    pub fn load_from_text(&mut self, meta: ModelMeta, hlo_text: &str) -> Result<()> {
        if self.models.contains_key(&meta.name) {
            return Ok(());
        }
        let exe = self.backend.compile(&self.device, &meta.name, hlo_text, &meta)?;
        self.models.insert(meta.name.clone(), LoadedModel { meta, exe });
        Ok(())
    }

    /// Compile the MLP classifier at every batch size in `buckets`
    /// (`mlp_b{b}`), synthesizing each bucket's HLO with [`mlp_hlo_text`]
    /// — the same lowering as the `mlp_b32` AOT fixture, so every bucket
    /// gets the identical fused plan shape (dot+bias+relu, dot+bias) with
    /// its own arena sized for its `m`. Buckets already loaded (the b32
    /// fixture via [`Runtime::load_all`]) are kept as-is. Returns the
    /// bucket model names. Zero-sized buckets are skipped.
    pub fn load_mlp_buckets(
        &mut self,
        buckets: &[usize],
        features: usize,
        hidden: usize,
        classes: usize,
    ) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for &b in buckets {
            if b == 0 {
                continue;
            }
            let meta = mlp_meta(b, features, hidden, classes);
            let name = meta.name.clone();
            let text = mlp_hlo_text(b, features, hidden, classes);
            self.load_from_text(meta, &text)
                .map_err(|e| e.context(format!("compiling batch bucket {name}")))?;
            names.push(name);
        }
        Ok(names)
    }

    /// [`Runtime::load_mlp_buckets`] for **int8 serving**: every bucket
    /// meta carries the calibration record of [`mlp_int8_calib`]
    /// (computed once and shared — the record is per-tensor, not
    /// per-batch), so an int8 backend ([`HloPlanBackend::int8`]) lowers
    /// each bucket's dots onto the quantized rank-4 engine. Call this
    /// *before* [`Runtime::load_all`] when serving int8: loads are
    /// idempotent by name, and the calibrated bucket must win over the
    /// record-less `mlp_b32` disk fixture.
    pub fn load_mlp_buckets_int8(
        &mut self,
        buckets: &[usize],
        features: usize,
        hidden: usize,
        classes: usize,
    ) -> Result<Vec<String>> {
        let calib = mlp_int8_calib(features, hidden, classes);
        let mut names = Vec::new();
        for &b in buckets {
            if b == 0 {
                continue;
            }
            let mut meta = mlp_meta(b, features, hidden, classes);
            meta.calib = Some(calib.clone());
            let name = meta.name.clone();
            let text = mlp_hlo_text(b, features, hidden, classes);
            self.load_from_text(meta, &text)
                .map_err(|e| e.context(format!("compiling int8 batch bucket {name}")))?;
            names.push(name);
        }
        Ok(names)
    }

    /// Compile the DFT serving model at every batch size in `buckets`
    /// (`dft_b{b}`), synthesizing each bucket's HLO with
    /// [`dft_hlo_text`] — the same lowering as the `dft_b32` AOT
    /// fixture, so every bucket fuses to the identical single
    /// `dft_gemm` step over the once-packed twiddle panels with its own
    /// arena sized for its `m`. Buckets already loaded (the b32 fixture
    /// via [`Runtime::load_all`]) are kept as-is. Returns the bucket
    /// model names. Zero-sized buckets are skipped.
    pub fn load_dft_buckets(&mut self, buckets: &[usize]) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for &b in buckets {
            if b == 0 {
                continue;
            }
            let meta = dft_meta(b);
            let name = meta.name.clone();
            let text = dft_hlo_text(b);
            self.load_from_text(meta, &text)
                .map_err(|e| e.context(format!("compiling DFT batch bucket {name}")))?;
            names.push(name);
        }
        Ok(names)
    }

    /// Read the python-side expected output for the deterministic inputs.
    pub fn expected(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.expected.bin"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect())
    }
}

/// The deterministic test input of `aot.py::det_input`, reproduced
/// bit-identically: `value(i) = ((i*31 + 7*salt) % 61) / 61 − 0.5`,
/// computed in f64 and cast to f32.
pub fn det_input(len: usize, salt: u64) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let v = ((i as f64 * 31.0 + 7.0 * salt as f64) % 61.0) / 61.0 - 0.5;
            v as f32
        })
        .collect()
}

/// Deterministic inputs for every argument of a model (salt = arg index+1),
/// matching `aot.py::build_artifact`.
pub fn det_inputs(meta: &ModelMeta) -> Vec<Vec<f32>> {
    meta.input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| det_input(s.iter().product(), i as u64 + 1))
        .collect()
}

/// The serving MLP's HLO text at an arbitrary batch size — the exact
/// lowering of the `mlp_b32` AOT fixture (`jit_mlp_classifier_serving`)
/// with `m = batch` substituted: same instruction names, same
/// reshape→broadcast bias idiom, same constant-0/maximum relu, so the
/// plan compiler produces the identical fused step shape
/// (`dot_bias_relu` + `dot_bias`) for every bucket of the ladder.
pub fn mlp_hlo_text(batch: usize, features: usize, hidden: usize, classes: usize) -> String {
    let (b, f, h, c) = (batch, features, hidden, classes);
    format!(
        "HloModule jit_mlp_classifier_serving, entry_computation_layout={{(f32[{b},{f}]{{1,0}}, f32[{f},{h}]{{1,0}}, f32[{h}]{{0}}, f32[{h},{c}]{{1,0}}, f32[{c}]{{0}})->(f32[{b},{c}]{{1,0}})}}\n\
         \n\
         ENTRY main.22 {{\n\
         \x20 Arg_0.1 = f32[{b},{f}]{{1,0}} parameter(0)\n\
         \x20 Arg_1.2 = f32[{f},{h}]{{1,0}} parameter(1)\n\
         \x20 dot.8 = f32[{b},{h}]{{1,0}} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 Arg_2.3 = f32[{h}]{{0}} parameter(2)\n\
         \x20 reshape.9 = f32[1,{h}]{{1,0}} reshape(Arg_2.3)\n\
         \x20 broadcast.10 = f32[1,{h}]{{1,0}} broadcast(reshape.9), dimensions={{0,1}}\n\
         \x20 reshape.11 = f32[{h}]{{0}} reshape(broadcast.10)\n\
         \x20 broadcast.12 = f32[{b},{h}]{{1,0}} broadcast(reshape.11), dimensions={{1}}\n\
         \x20 add.13 = f32[{b},{h}]{{1,0}} add(dot.8, broadcast.12)\n\
         \x20 constant.6 = f32[] constant(0)\n\
         \x20 broadcast.7 = f32[{b},{h}]{{1,0}} broadcast(constant.6), dimensions={{}}\n\
         \x20 maximum.14 = f32[{b},{h}]{{1,0}} maximum(add.13, broadcast.7)\n\
         \x20 Arg_3.4 = f32[{h},{c}]{{1,0}} parameter(3)\n\
         \x20 dot.15 = f32[{b},{c}]{{1,0}} dot(maximum.14, Arg_3.4), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 Arg_4.5 = f32[{c}]{{0}} parameter(4)\n\
         \x20 reshape.16 = f32[1,{c}]{{1,0}} reshape(Arg_4.5)\n\
         \x20 broadcast.17 = f32[1,{c}]{{1,0}} broadcast(reshape.16), dimensions={{0,1}}\n\
         \x20 reshape.18 = f32[{c}]{{0}} reshape(broadcast.17)\n\
         \x20 broadcast.19 = f32[{b},{c}]{{1,0}} broadcast(reshape.18), dimensions={{1}}\n\
         \x20 add.20 = f32[{b},{c}]{{1,0}} add(dot.15, broadcast.19)\n\
         \x20 ROOT tuple.21 = (f32[{b},{c}]{{1,0}}) tuple(add.20)\n\
         }}\n"
    )
}

/// The meta line matching [`mlp_hlo_text`]:
/// `mlp_b{b};{b}x{f},{f}x{h},{h},{h}x{c},{c};{b}x{c}`.
pub fn mlp_meta(batch: usize, features: usize, hidden: usize, classes: usize) -> ModelMeta {
    ModelMeta {
        name: format!("mlp_b{batch}"),
        input_shapes: vec![
            vec![batch, features],
            vec![features, hidden],
            vec![hidden],
            vec![hidden, classes],
            vec![classes],
        ],
        output_shape: vec![batch, classes],
        calib: None,
    }
}

/// The serving DFT's HLO text at an arbitrary batch size — the exact
/// lowering of the `dft_b32` AOT fixture (`jit_dft16_serving`) with
/// `m = batch` substituted. The graph is the real-signal batched DFT as
/// a complex matmul over baked twiddle constants:
/// `yr = xr·Fr − xi·Fi`, `yi = xr·Fi + xi·Fr`, where the subtraction
/// lowers the XLA way (`multiply(dot, broadcast(-1))` then `add` — a
/// shape [`plan::Plan`]'s DFT matcher recognizes in either operand
/// order). Instruction order and numbering follow the real XLA printer
/// output (each twiddle constant is emitted right after the parameter
/// feeding its first dot), and twiddle literals are formatted `%.9g`
/// style — nine significant digits, trailing zeros trimmed, integers
/// bare — from the exact sqrt-derived f32 table
/// ([`crate::kernels::dft::dft16_twiddles_f32`]); nine digits uniquely
/// round-trip an f32, so the parsed constants recover the exact bits.
/// The result is byte-identical to the python AOT emitter's text at
/// every batch size, and every bucket gets the identical single
/// `dft_gemm` plan shape.
pub fn dft_hlo_text(batch: usize) -> String {
    let n = 16usize;
    let (fr, fi) = crate::kernels::dft::dft16_twiddles_f32();
    // `%.9g` for the twiddle value domain: 0 / -0 / ±1 print bare, and
    // every other magnitude lies in [0.1, 1) where nine fraction digits
    // are nine significant digits.
    let g9 = |v: f32| -> String {
        if v == 0.0 {
            return if v.is_sign_negative() { "-0".into() } else { "0".into() };
        }
        if v == v.trunc() {
            return format!("{}", v as i64);
        }
        debug_assert!((0.1..1.0).contains(&v.abs()), "unexpected twiddle magnitude {v}");
        format!("{v:.9}").trim_end_matches('0').trim_end_matches('.').to_string()
    };
    let lit = |vals: &[f32]| {
        let rows: Vec<String> = (0..n)
            .map(|j| {
                let cells: Vec<String> = vals[j * n..(j + 1) * n].iter().map(|&v| g9(v)).collect();
                format!("{{ {} }}", cells.join(", "))
            })
            .collect();
        format!("{{ {} }}", rows.join(", "))
    };
    let (b, fr_lit, fi_lit) = (batch, lit(&fr), lit(&fi));
    format!(
        "HloModule jit_dft{n}_serving, entry_computation_layout={{(f32[{b},{n}]{{1,0}}, f32[{b},{n}]{{1,0}})->(f32[{b},{n}]{{1,0}}, f32[{b},{n}]{{1,0}})}}\n\
         \n\
         ENTRY main.15 {{\n\
         \x20 Arg_0.1 = f32[{b},{n}]{{1,0}} parameter(0)\n\
         \x20 constant.5 = f32[{n},{n}]{{1,0}} constant({fr_lit})\n\
         \x20 dot.7 = f32[{b},{n}]{{1,0}} dot(Arg_0.1, constant.5), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 Arg_1.2 = f32[{b},{n}]{{1,0}} parameter(1)\n\
         \x20 constant.6 = f32[{n},{n}]{{1,0}} constant({fi_lit})\n\
         \x20 dot.8 = f32[{b},{n}]{{1,0}} dot(Arg_1.2, constant.6), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 constant.3 = f32[] constant(-1)\n\
         \x20 broadcast.4 = f32[{b},{n}]{{1,0}} broadcast(constant.3), dimensions={{}}\n\
         \x20 multiply.9 = f32[{b},{n}]{{1,0}} multiply(dot.8, broadcast.4)\n\
         \x20 add.10 = f32[{b},{n}]{{1,0}} add(dot.7, multiply.9)\n\
         \x20 dot.11 = f32[{b},{n}]{{1,0}} dot(Arg_0.1, constant.6), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 dot.12 = f32[{b},{n}]{{1,0}} dot(Arg_1.2, constant.5), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         \x20 add.13 = f32[{b},{n}]{{1,0}} add(dot.11, dot.12)\n\
         \x20 ROOT tuple.14 = (f32[{b},{n}]{{1,0}}, f32[{b},{n}]{{1,0}}) tuple(add.10, add.13)\n\
         }}\n\
         \n"
    )
}

/// The meta line matching [`dft_hlo_text`]:
/// `dft_b{b};{b}x16,{b}x16;{2b}x16` — two inputs (the real and
/// imaginary signal rows), one stacked output (`yr` rows then `yi`
/// rows; per-request row `r` scatters from output rows `r` and `b+r`).
pub fn dft_meta(batch: usize) -> ModelMeta {
    ModelMeta {
        name: format!("dft_b{batch}"),
        input_shapes: vec![vec![batch, 16], vec![batch, 16]],
        output_shape: vec![2 * batch, 16],
        calib: None,
    }
}

/// The **calibration sweep** of the int8 serving path: replay the MLP's
/// f32 forward pass over a sweep of deterministic request batches
/// ([`det_input`], the serving traffic model), track the min/max range
/// of every tensor feeding a dot — the activations `Arg_0.1` /
/// `maximum.14` (the `xvi8ger4` signed-i8 X side) and the weights
/// `Arg_1.2` / `Arg_3.4` (the unsigned-u8 Y side) — and derive each
/// tensor's asymmetric affine quantization (`scale = range/255`, zero
/// point placing `lo` at the bottom of the integer range). The entry
/// names are the instruction names of [`mlp_hlo_text`], which the plan's
/// `DotI8` matcher looks up.
pub fn mlp_int8_calib(features: usize, hidden: usize, classes: usize) -> Int8Calib {
    let (f, h, c) = (features, hidden, classes);
    let w1 = det_input(f * h, 2);
    let b1 = det_input(h, 3);
    let w2 = det_input(h * c, 4);
    let range = |v: &[f32]| {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in v {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        (lo.min(0.0), hi.max(0.0)) // affine grids must represent 0 exactly
    };
    // sweep: batches of serving traffic at several salts, batch 32
    let (mut xlo, mut xhi) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut alo, mut ahi) = (f32::INFINITY, f32::NEG_INFINITY);
    for salt in 1..=8u64 {
        let x = det_input(32 * f, salt);
        let (lo, hi) = range(&x);
        xlo = xlo.min(lo);
        xhi = xhi.max(hi);
        // h = relu(x·w1 + b1), the f32 activation the second dot consumes
        for i in 0..32 {
            for j in 0..h {
                let mut acc = 0f32;
                for kk in 0..f {
                    acc += x[i * f + kk] * w1[kk * h + j];
                }
                let v = (acc + b1[j]).max(0.0);
                alo = alo.min(v.min(0.0));
                ahi = ahi.max(v);
            }
        }
    }
    let entry = |name: &str, signed: bool, lo: f32, hi: f32| {
        let qmin = if signed { -128i32 } else { 0 };
        let span = (hi - lo).max(f32::MIN_POSITIVE);
        let scale = span / 255.0;
        let zp = qmin - (lo / scale).round() as i32;
        CalibEntry {
            name: name.to_string(),
            signed,
            scale,
            zp: zp.clamp(qmin, qmin + 255),
        }
    };
    let (w1lo, w1hi) = range(&w1);
    let (w2lo, w2hi) = range(&w2);
    Int8Calib {
        entries: vec![
            entry("Arg_0.1", true, xlo, xhi),
            entry("Arg_1.2", false, w1lo, w1hi),
            entry("maximum.14", true, alo, ahi),
            entry("Arg_3.4", false, w2lo, w2hi),
        ],
    }
}

/// [`mlp_meta`] with the int8 calibration record attached
/// ([`mlp_int8_calib`]) — the **quantized-MLP fixture**: loaded under an
/// int8 backend ([`HloPlanBackend::int8`]) both its dots lower to
/// `dot_i8` steps; under any other backend the record is inert and the
/// model serves f32.
pub fn mlp_int8_meta(batch: usize, features: usize, hidden: usize, classes: usize) -> ModelMeta {
    let mut meta = mlp_meta(batch, features, hidden, classes);
    meta.calib = Some(mlp_int8_calib(features, hidden, classes));
    meta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parsing() {
        let m = ModelMeta::parse("gemm_f32;128x128,128x128;128x128\n").unwrap();
        assert_eq!(m.name, "gemm_f32");
        assert_eq!(m.input_shapes, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(m.output_shape, vec![128, 128]);
        assert_eq!(m.input_len(0), 128 * 128);
        assert_eq!(m.output_len(), 128 * 128);

        let m = ModelMeta::parse("mlp_b32;32x64,64x128,128,128x32,32;32x32").unwrap();
        assert_eq!(m.input_shapes.len(), 5);
        assert_eq!(m.input_shapes[2], vec![128]);

        assert!(ModelMeta::parse("bad").is_err());
        assert!(ModelMeta::parse("x;1xq;2").is_err());
    }

    #[test]
    fn meta_rejects_trailing_fields() {
        // a fourth field used to parse silently (split(';') never ran
        // dry); it must be a hard error now
        let e = ModelMeta::parse("name;2x2;2x2;junk").unwrap_err().to_string();
        assert!(e.contains("trailing field"), "{e}");
        // even an *empty* trailing field is malformed
        let e = ModelMeta::parse("name;2x2;2x2;").unwrap_err().to_string();
        assert!(e.contains("trailing field"), "{e}");
        assert!(ModelMeta::parse("name;2x2;2x2;4x4;8x8").is_err());
        // the well-formed line still parses
        assert!(ModelMeta::parse("name;2x2;2x2").is_ok());
    }

    #[test]
    fn det_input_matches_python_formula() {
        let v = det_input(4, 1);
        for (i, &val) in v.iter().enumerate() {
            let expect = (((i as f64) * 31.0 + 7.0) % 61.0) / 61.0 - 0.5;
            assert_eq!(val, expect as f32);
        }
        // different salts differ
        assert_ne!(det_input(8, 1), det_input(8, 2));
    }

    #[test]
    fn runtime_loads_and_executes_embedded_artifacts() {
        let dir = std::env::temp_dir().join(format!("mma-rt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        assert_eq!(rt.platform(), "native-hlo-plan");
        let names = rt.load_all().unwrap();
        assert!(names.contains(&"gemm_f32".to_string()));
        assert!(rt.loaded().contains(&"gemm_f32"));
        let meta = rt.meta("gemm_f32").unwrap().clone();
        let ins = det_inputs(&meta);
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute("gemm_f32", &refs).unwrap();
        assert_eq!(out.len(), meta.output_len());
        // input validation
        assert!(rt.execute("gemm_f32", &[]).is_err());
        assert!(rt.execute("nonexistent", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_all_uses_the_manifest_meta_without_rereading() {
        // load_all parses each manifest line once and passes the meta
        // through; the per-model .meta file is NOT re-read. Corrupting it
        // must therefore not affect load_all...
        let dir = std::env::temp_dir().join(format!("mma-rt-meta1x-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        std::fs::write(dir.join("gemm_f32.meta"), "garbage;;junk;;\n").unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        let names = rt.load_all().unwrap();
        assert!(names.contains(&"gemm_f32".to_string()));
        // ...while the by-name path (which does read the file) fails
        let mut rt2 = Runtime::cpu(&dir).unwrap();
        assert!(rt2.load("gemm_f32").is_err(), "corrupt .meta must fail load-by-name");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn typed_execution_matches_compat_shim_bitwise() {
        let dir = std::env::temp_dir().join(format!("mma-rt-typed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let meta = rt.meta("mlp_b32").unwrap().clone();
        let ins = det_inputs(&meta);
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let shim = rt.execute("mlp_b32", &refs).unwrap();
        let trefs: Vec<TensorRef<'_>> = ins
            .iter()
            .zip(&meta.input_shapes)
            .map(|(d, s)| TensorRef::f32(d, s))
            .collect();
        let mut typed = vec![0f32; meta.output_len()];
        let mut out = TensorMut::f32(&mut typed, &meta.output_shape);
        let mut ctx = rt.device().ctx();
        rt.execute_typed("mlp_b32", &mut ctx, &trefs, &mut out).unwrap();
        assert_eq!(
            typed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            shim.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "typed path and compat shim must agree bit for bit"
        );
        // typed validation: wrong dims are rejected up front
        let bad_dims = vec![1usize, 2];
        let bad: Vec<TensorRef<'_>> =
            ins.iter().map(|d| TensorRef::f32(d, &bad_dims)).collect();
        assert!(rt.execute_typed("mlp_b32", &mut ctx, &bad, &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_typed_inputs_stage_exactly() {
        // feeding bf16 storage must equal feeding the pre-rounded f32
        // values through the f32 path, bit for bit
        let dir = std::env::temp_dir().join(format!("mma-rt-bf16-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let meta = rt.meta("gemm_f32").unwrap().clone();
        let ins = det_inputs(&meta);
        // bf16-quantize the inputs both ways
        let bits: Vec<Vec<u16>> =
            ins.iter().map(|v| v.iter().map(|&x| f32_to_bf16(x)).collect()).collect();
        let widened: Vec<Vec<f32>> =
            bits.iter().map(|v| v.iter().map(|&b| bf16_to_f32(b)).collect()).collect();
        let refs: Vec<&[f32]> = widened.iter().map(|v| v.as_slice()).collect();
        let via_f32 = rt.execute("gemm_f32", &refs).unwrap();
        let trefs: Vec<TensorRef<'_>> = bits
            .iter()
            .zip(&meta.input_shapes)
            .map(|(d, s)| TensorRef::bf16(d, s))
            .collect();
        let mut via_bf16 = vec![0f32; meta.output_len()];
        let mut out = TensorMut::f32(&mut via_bf16, &meta.output_shape);
        let mut ctx = rt.device().ctx();
        rt.execute_typed("gemm_f32", &mut ctx, &trefs, &mut out).unwrap();
        assert_eq!(
            via_bf16.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_f32.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // bf16 *output* buffers round the result on store
        let mut hout = vec![0u16; meta.output_len()];
        let mut out = TensorMut::bf16(&mut hout, &meta.output_shape);
        rt.execute_typed("gemm_f32", &mut ctx, &trefs, &mut out).unwrap();
        for (h, &v) in hout.iter().zip(&via_f32) {
            assert_eq!(*h, f32_to_bf16(v));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn generated_mlp_hlo_reproduces_the_aot_fixture() {
        // the bucket generator at b=32 must emit the fixture's lowering:
        // same text (modulo trailing whitespace), same fused plan shape,
        // bitwise-identical execution
        let fixture = artifacts::EMBEDDED
            .iter()
            .find(|a| a.name == "mlp_b32")
            .expect("embedded mlp_b32")
            .hlo_text;
        let generated = mlp_hlo_text(32, 64, 128, 32);
        assert_eq!(generated.trim_end(), fixture.trim_end(), "generator drifted from AOT");
        let plan_of = |text: &str| {
            let m = hlo::HloModule::parse(text).unwrap();
            plan::Plan::compile(&m).unwrap()
        };
        assert_eq!(
            plan_of(&generated).step_names(),
            plan_of(fixture).step_names(),
            "bucket plans must fuse identically to the fixture plan"
        );
    }

    #[test]
    fn generated_dft_hlo_reproduces_the_aot_fixture() {
        // the DFT bucket generator at b=32 must emit the fixture's
        // lowering byte for byte — the twiddle literals come from the
        // exact sqrt-derived table on both sides, so even the constant
        // text is identical — and fuse to the same single-dft_gemm plan
        let fixture = artifacts::EMBEDDED
            .iter()
            .find(|a| a.name == "dft_b32")
            .expect("embedded dft_b32")
            .hlo_text;
        let generated = dft_hlo_text(32);
        assert_eq!(generated, fixture, "DFT generator drifted from AOT fixture");
        let plan_of = |text: &str| {
            let m = hlo::HloModule::parse(text).unwrap();
            plan::Plan::compile(&m).unwrap()
        };
        let plan = plan_of(&generated);
        assert_eq!(plan.step_names(), vec!["param", "param", "dft_gemm"]);
        assert_eq!(plan.step_names(), plan_of(fixture).step_names());
    }

    #[test]
    fn dft_bucket_ladder_rows_match_b32_bitwise() {
        // DFT output rows depend only on their own input row, so a
        // window of r requests served in bucket b must reproduce, row
        // for row (both the yr half and the yi half), the bits the full
        // b32 batch produces — the second family's scatter-back
        // invariant
        let dir = std::env::temp_dir().join(format!("mma-rt-dftlad-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let names = rt.load_dft_buckets(&[1, 8, 32]).unwrap();
        assert_eq!(names, vec!["dft_b1", "dft_b8", "dft_b32"]);
        // idempotent by name: the b32 fixture stays loaded
        assert_eq!(rt.meta("dft_b32").unwrap().output_shape, vec![64, 16]);
        let n = 16usize;
        let xr = det_input(32 * n, 1);
        let xi = det_input(32 * n, 2);
        let full = rt.execute("dft_b32", &[&xr, &xi]).unwrap();
        // the fixture's expected.bin is JAX's own output (XLA CPU f32
        // dot), so like the other dot-family fixtures it is a
        // tolerance check — the bitwise contracts are plan ==
        // interpreter == f64-accumulation oracle, pinned elsewhere
        let expect = rt.expected("dft_b32").unwrap();
        assert_eq!(full.len(), expect.len());
        for (i, (&y, &e)) in full.iter().zip(&expect).enumerate() {
            assert!(
                (y - e).abs() <= 1e-5 + 1e-5 * e.abs(),
                "fused plan vs JAX expected.bin at {i}: {y} vs {e}"
            );
        }
        for (bucket, rows) in [(1usize, 1usize), (8, 3), (8, 8)] {
            let mut xrb = vec![0f32; bucket * n];
            let mut xib = vec![0f32; bucket * n];
            xrb[..rows * n].copy_from_slice(&xr[..rows * n]);
            xib[..rows * n].copy_from_slice(&xi[..rows * n]);
            let out = rt.execute(&format!("dft_b{bucket}"), &[&xrb, &xib]).unwrap();
            for r in 0..rows {
                for j in 0..n {
                    assert_eq!(
                        out[r * n + j].to_bits(),
                        full[r * n + j].to_bits(),
                        "bucket {bucket}, yr row {r}, bin {j} differs from b32"
                    );
                    assert_eq!(
                        out[(bucket + r) * n + j].to_bits(),
                        full[(32 + r) * n + j].to_bits(),
                        "bucket {bucket}, yi row {r}, bin {j} differs from b32"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bucket_ladder_rows_match_b32_bitwise() {
        // a window of r rows executed in bucket b (r <= b) must produce,
        // row for row, the bits the full b32 batch produces for the same
        // features — the invariant the continuous batcher's
        // batched-vs-singleton identity rests on: each GEMM output row
        // depends only on its own input row
        let dir = std::env::temp_dir().join(format!("mma-rt-ladder-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let names = rt.load_mlp_buckets(&[1, 8, 32], 64, 128, 32).unwrap();
        assert_eq!(names, vec!["mlp_b1", "mlp_b8", "mlp_b32"]);
        assert_eq!(rt.meta("mlp_b1").unwrap().input_shapes[0], vec![1, 64]);
        // the b32 name was already loaded from the fixture; the ladder
        // call must not have replaced it (idempotent by name)
        assert_eq!(rt.meta("mlp_b32").unwrap().input_shapes[0], vec![32, 64]);
        let (f, c) = (64usize, 32usize);
        let x = det_input(32 * f, 1);
        let w = [det_input(f * 128, 2), det_input(128, 3), det_input(128 * c, 4), det_input(c, 5)];
        let full = rt
            .execute("mlp_b32", &[&x, &w[0], &w[1], &w[2], &w[3]])
            .unwrap();
        for (bucket, rows) in [(1usize, 1usize), (8, 3), (8, 8)] {
            // pad a partial window exactly like the batcher does
            let mut xb = vec![0f32; bucket * f];
            xb[..rows * f].copy_from_slice(&x[..rows * f]);
            let out = rt
                .execute(&format!("mlp_b{bucket}"), &[&xb, &w[0], &w[1], &w[2], &w[3]])
                .unwrap();
            for r in 0..rows {
                for j in 0..c {
                    assert_eq!(
                        out[r * c + j].to_bits(),
                        full[r * c + j].to_bits(),
                        "bucket {bucket}, row {r}, logit {j} differs from the b32 batch"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn calib_field_round_trips_and_rejects_malformed_records() {
        let calib = mlp_int8_calib(8, 6, 4);
        let line = format!("q;2x8,8x6,6,6x4,4;2x4;{}", calib.manifest_field());
        let m = ModelMeta::parse(&line).unwrap();
        assert_eq!(m.calib.as_ref(), Some(&calib), "manifest round-trip must be exact");
        // a non-calib fourth field is still the PR-4 trailing-field error
        let e = ModelMeta::parse("name;2x2;2x2;junk").unwrap_err().to_string();
        assert!(e.contains("trailing field"), "{e}");
        // truncated or malformed records are hard errors (never panics,
        // never silently-partial parses)
        for bad in [
            "calib:",                 // empty record
            "calib:x",                // no '='
            "calib:=i8@0.1@0",        // empty tensor name
            "calib:x=f8@0.1@0",       // bad kind
            "calib:x=i8",             // truncated: no scale
            "calib:x=i8@zz@0",        // bad scale
            "calib:x=i8@0@0",         // scale must be > 0
            "calib:x=i8@inf@0",       // scale must be finite
            "calib:x=i8@0.1",         // truncated: no zero point
            "calib:x=i8@0.1@q",       // bad zero point
            "calib:x=i8@0.1@200",     // zp outside the i8 range
            "calib:x=u8@0.1@-1",      // zp outside the u8 range
            "calib:x=i8@0.1@0@extra", // trailing part
            "calib:a=i8@0.1@0,",      // truncated second entry
        ] {
            let line = format!("name;2x2;2x2;{bad}");
            let e = ModelMeta::parse(&line);
            assert!(e.is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn int8_backend_serves_the_calibrated_mlp_quantized() {
        use crate::blas::i8_gemm::{gemm_i8_dequant_reference, QuantParams};
        let dir = std::env::temp_dir().join(format!("mma-rt-int8-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::with_backend(Box::new(HloPlanBackend::int8()), &dir);
        assert!(rt.platform().contains("plan"));
        // the calibrated buckets load BEFORE load_all, so they win the
        // name over the record-less mlp_b32 disk fixture (loads are
        // idempotent by name)
        let (f, h, c) = (64usize, 128usize, 32usize);
        let names = rt.load_mlp_buckets_int8(&[4, 32], f, h, c).unwrap();
        assert_eq!(names, vec!["mlp_b4", "mlp_b32"]);
        rt.load_all().unwrap();
        assert!(
            rt.meta("mlp_b32").unwrap().calib.is_some(),
            "the calibrated bucket must win over the fixture meta"
        );

        // quantized serving is bitwise the composition of the int8
        // engine's own quantize→dot→dequantize reference, layer by layer
        let b = 4usize;
        let x = det_input(b * f, 1);
        let w1 = det_input(f * h, 2);
        let b1 = det_input(h, 3);
        let w2 = det_input(h * c, 4);
        let b2 = det_input(c, 5);
        let got = rt.execute("mlp_b4", &[&x, &w1, &b1, &w2, &b2]).unwrap();
        let calib = mlp_int8_calib(f, h, c);
        let qp = |an: &str, bn: &str| {
            let (ea, eb) = (calib.get(an).unwrap(), calib.get(bn).unwrap());
            QuantParams { a_scale: ea.scale, a_zp: ea.zp, b_scale: eb.scale, b_zp: eb.zp }
        };
        let hid = gemm_i8_dequant_reference(
            &x,
            &w1,
            b,
            h,
            f,
            &qp("Arg_0.1", "Arg_1.2"),
            Some(&b1),
            true,
        );
        let want = gemm_i8_dequant_reference(
            &hid,
            &w2,
            b,
            c,
            h,
            &qp("maximum.14", "Arg_3.4"),
            Some(&b2),
            false,
        );
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "int8 serving must equal the engine reference bit for bit"
        );

        // and it really is the quantized path: an f32 runtime over the
        // same artifacts produces (close but) different bits
        let mut rtf = Runtime::cpu(&dir).unwrap();
        rtf.load_mlp_buckets(&[4], f, h, c).unwrap();
        let f32_out = rtf.execute("mlp_b4", &[&x, &w1, &b1, &w2, &b2]).unwrap();
        assert_ne!(got, f32_out, "quantization must bite");
        let max_err = got
            .iter()
            .zip(&f32_out)
            .map(|(a, e)| (a - e).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 0.5, "quantization error out of family: {max_err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
