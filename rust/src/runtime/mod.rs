//! Native model runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** + `.meta` shape lines + expected
//! outputs) and executes them entirely in-crate.
//!
//! The former `xla::PjRt*` FFI is gone.  Execution is organized around
//! the **device/session API** of [`device`]:
//!
//! * a [`Device`] owns the process-wide **persistent GEMM worker pool**
//!   and the global thread budget (one pool, shared by every engine and
//!   coordinator shard — see [`Device::shared`]);
//! * models execute on **typed tensors**: [`TensorRef`] /
//!   [`TensorMut`] buffers over [`DTypeSlice`] (`F32` or raw-bits
//!   `Bf16`), validated against the model metadata;
//! * an [`ExecCtx`] carries the device handle plus per-request staging
//!   into [`CompiledModel::execute`].
//!
//! Backends plug in behind the [`EngineBackend`] trait. The default
//! ([`HloPlanBackend`], behind [`Runtime::cpu`]) **compiles** each
//! artifact once at `load()` into a [`plan::Plan`] — a
//! topologically-ordered step list over a preallocated, liveness-reusing
//! buffer arena, with a rewrite pass that collapses conv graphs into
//! single im2col GEMM steps and fuses post-`dot` bias/relu tails into
//! the GEMM writeback — and executes requests against the plan on the
//! blocked parallel GEMM of [`crate::blas::block_gemm`], fanning panel
//! work out over the device pool (no scoped thread spawns on the hot
//! path). The legacy [`HloInterpreterBackend`] (per-request walk of
//! [`hlo::HloModule::evaluate`] over `ref_gemm`) is kept as the numerics
//! oracle and for `power-mma bench serve` comparisons; both produce
//! bit-identical results on the artifact set.
//!
//! The untyped [`Runtime::execute`]`(&str, &[&[f32]])` entry point stays
//! as a thin compat shim over the typed path ([`Runtime::execute_typed`])
//! so existing callers migrate incrementally.
//!
//! The coordinator still runs a [`Runtime`] on a dedicated engine thread
//! (one per shard); backends are constructed *inside* that thread via a
//! factory, so thread-confined backends remain possible. GEMM fan-out
//! drains inside each step, so nothing escapes the engine thread.

pub mod artifacts;
pub mod device;
pub mod hlo;
pub mod plan;

pub use device::{
    bf16_to_f32, f32_to_bf16, DTypeSlice, DTypeSliceMut, Device, ExecCtx, TensorMut, TensorRef,
};

use crate::blas::block_gemm::Par;
use crate::error::{Context, Result};
use crate::{bail, err};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Parsed `<name>.meta` line: `name;in0shape,in1shape,…;outshape`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelMeta {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
}

impl ModelMeta {
    /// Parse one manifest line. Exactly three `;`-separated fields are
    /// accepted — a line with trailing fields (`name;ins;out;junk`) is
    /// malformed and rejected, not silently truncated.
    pub fn parse(line: &str) -> Result<ModelMeta> {
        let mut parts = line.trim().split(';');
        let name = parts.next().ok_or_else(|| err!("empty manifest line"))?.to_string();
        if name.is_empty() {
            bail!("empty model name in manifest line");
        }
        let ins = parts.next().ok_or_else(|| err!("{name}: missing input shapes"))?;
        let out = parts.next().ok_or_else(|| err!("{name}: missing output shape"))?;
        if let Some(extra) = parts.next() {
            bail!("{name}: trailing field '{extra}' in manifest line");
        }
        let parse_shape = |s: &str| -> Result<Vec<usize>> {
            s.split('x').map(|d| d.parse::<usize>().context("bad dim")).collect()
        };
        Ok(ModelMeta {
            name,
            input_shapes: ins.split(',').map(parse_shape).collect::<Result<_>>()?,
            output_shape: parse_shape(out)?,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// A model compiled by an [`EngineBackend`], ready to execute.
pub trait CompiledModel {
    /// Execute on typed input tensors, writing the result into the typed
    /// output buffer (rounded to the buffer's dtype). The [`ExecCtx`]
    /// supplies the device (worker pool + budget) and per-request
    /// staging; inputs are assumed validated against the model metadata
    /// (see [`Runtime::execute_typed`]).
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()>;
}

/// Pluggable execution backend: turns HLO text into executable models.
pub trait EngineBackend {
    /// Backend identifier (reported by [`Runtime::platform`]).
    fn name(&self) -> &'static str;

    /// Compile one artifact's HLO text, validating it against the meta.
    /// The device provides the worker budget compiled models size their
    /// scratch for (their `execute` draws workers from the device of the
    /// [`ExecCtx`] they are called with).
    fn compile(
        &self,
        device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>>;
}

/// Parse an artifact's HLO text and cross-check it against the meta line
/// (parameter count and element counts) — shared by every backend.
fn parse_and_validate(name: &str, hlo_text: &str, meta: &ModelMeta) -> Result<hlo::HloModule> {
    let module = hlo::HloModule::parse(hlo_text)
        .map_err(|e| e.context(format!("parsing HLO for {name}")))?;
    if module.num_parameters() != meta.input_shapes.len() {
        bail!(
            "{name}: HLO has {} parameters, meta declares {} inputs",
            module.num_parameters(),
            meta.input_shapes.len()
        );
    }
    for (i, shape) in meta.input_shapes.iter().enumerate() {
        let hlo_len: usize = module
            .parameter_dims(i)
            .ok_or_else(|| err!("{name}: HLO is missing parameter {i}"))?
            .iter()
            .product();
        let meta_len: usize = shape.iter().product();
        if hlo_len != meta_len {
            bail!("{name}: parameter {i} has {hlo_len} elements in HLO, {meta_len} in meta");
        }
    }
    Ok(module)
}

/// The legacy native backend: parses HLO text and re-interprets it per
/// request over `blas` (`ref_gemm`). Kept as the numerics oracle and the
/// baseline side of `power-mma bench serve`.
pub struct HloInterpreterBackend;

impl EngineBackend for HloInterpreterBackend {
    fn name(&self) -> &'static str {
        "native-hlo-interpreter"
    }

    fn compile(
        &self,
        _device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        Ok(Box::new(InterpretedModel { module }))
    }
}

struct InterpretedModel {
    module: hlo::HloModule,
}

impl CompiledModel for InterpretedModel {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let result = {
            let refs = ctx.f32_inputs(inputs);
            let outputs = self.module.evaluate(&refs)?;
            // aot.py lowers with return_tuple=True -> 1-tuple
            outputs.into_iter().next().ok_or_else(|| err!("model produced no output"))?
        };
        out.store(&result.data)
    }
}

/// The default serving backend: lowers each artifact once at `load()`
/// into a compiled [`plan::Plan`] (preallocated buffer arena, blocked
/// parallel GEMM over the device pool) and executes requests against the
/// plan. Bit-identical to [`HloInterpreterBackend`] on finite inputs,
/// several times faster on GEMM-heavy artifacts (measure with `power-mma
/// bench serve`). The worker budget comes from the [`Device`] of the
/// executing [`ExecCtx`].
pub struct HloPlanBackend;

impl HloPlanBackend {
    /// The plan backend (stateless: thread policy lives on the device).
    pub fn new() -> HloPlanBackend {
        HloPlanBackend
    }
}

impl Default for HloPlanBackend {
    fn default() -> Self {
        HloPlanBackend::new()
    }
}

impl EngineBackend for HloPlanBackend {
    fn name(&self) -> &'static str {
        "native-hlo-plan"
    }

    fn compile(
        &self,
        _device: &Device,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        let plan = plan::Plan::compile(&module)
            .map_err(|e| e.context(format!("compiling plan for {name}")))?;
        let bufs = std::sync::Mutex::new(plan.new_buffers());
        Ok(Box::new(PlanModel { plan, bufs }))
    }
}

/// A plan plus its preallocated buffers. The buffers sit behind a
/// `Mutex` only to satisfy the `&self` execute contract; on the
/// coordinator's thread-confined engine the lock is always uncontended.
struct PlanModel {
    plan: plan::Plan,
    bufs: std::sync::Mutex<plan::ExecBuffers>,
}

impl CompiledModel for PlanModel {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let device = ctx.device();
        // dtype-aware handoff: bf16 inputs reach the plan as raw bits —
        // parameters feeding only the packed bf16 GEMM are consumed
        // straight by the panel packers (no f32 staging anywhere), the
        // rest widen exactly into their arena slots inside the plan
        let typed: Vec<plan::PlanInput<'_>> = inputs
            .iter()
            .map(|t| match t.data {
                DTypeSlice::F32(s) => plan::PlanInput::F32(s),
                DTypeSlice::Bf16(b) => plan::PlanInput::Bf16(b),
            })
            .collect();
        let mut bufs = self.bufs.lock().unwrap_or_else(|p| p.into_inner());
        let par = Par::Pool(device.pool(), device.threads());
        // zero-copy: run the steps, then store the root arena slot
        // straight into the caller's typed buffer — no intermediate
        // output tensor is materialized on the serving hot path
        self.plan.run_steps_typed(&mut bufs, &typed, par)?;
        let roots = self.plan.root_slices(&bufs);
        let (data, _dims) =
            *roots.first().ok_or_else(|| err!("model produced no output"))?;
        out.store(data)
    }
}

/// One compiled model with its metadata.
pub struct LoadedModel {
    pub meta: ModelMeta,
    exe: Box<dyn CompiledModel>,
}

/// The artifact-directory runtime with a compiled-model cache. Holds a
/// [`Device`] handle: all its models execute on that device's persistent
/// worker pool (runtimes sharing a device — e.g. coordinator shards —
/// share the pool and therefore cannot oversubscribe the budget).
pub struct Runtime {
    backend: Box<dyn EngineBackend>,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
    device: Arc<Device>,
}

impl Runtime {
    /// Runtime over an artifact directory with the default native plan
    /// backend and the process-wide shared device (the name is
    /// historical: this was the PJRT *CPU* client). Does not load
    /// anything yet.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(HloPlanBackend::new()), artifact_dir))
    }

    /// Runtime over an artifact directory with an explicit backend, on
    /// the process-wide shared device.
    pub fn with_backend(
        backend: Box<dyn EngineBackend>,
        artifact_dir: impl AsRef<Path>,
    ) -> Runtime {
        Runtime::with_device(Device::shared(), backend, artifact_dir)
    }

    /// Runtime over an artifact directory with an explicit backend *and*
    /// device (worker pool + thread budget).
    pub fn with_device(
        device: Arc<Device>,
        backend: Box<dyn EngineBackend>,
        artifact_dir: impl AsRef<Path>,
    ) -> Runtime {
        Runtime {
            backend,
            models: HashMap::new(),
            dir: artifact_dir.as_ref().to_path_buf(),
            device,
        }
    }

    /// Name of the execution backend.
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// The device this runtime executes on.
    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    /// Load + compile one artifact by name (`<dir>/<name>.hlo.txt` +
    /// `<name>.meta`). Idempotent.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta_line = std::fs::read_to_string(&meta_path).with_context(|| {
            format!("reading {} (run `power-mma gen-artifacts`?)", meta_path.display())
        })?;
        let meta = ModelMeta::parse(&meta_line)?;
        if meta.name != name {
            bail!("{}: meta file declares model '{}'", name, meta.name);
        }
        self.load_with_meta(meta)
    }

    /// Compile one artifact from an already-parsed meta line — the
    /// single-parse path `load_all` uses: the manifest line *is* the
    /// meta, so it is parsed once and passed through instead of being
    /// re-read and re-parsed from the `.meta` file per model.
    pub fn load_with_meta(&mut self, meta: ModelMeta) -> Result<()> {
        if self.models.contains_key(&meta.name) {
            return Ok(());
        }
        let hlo_path = self.dir.join(format!("{}.hlo.txt", meta.name));
        let hlo_text = std::fs::read_to_string(&hlo_path)
            .with_context(|| format!("reading {}", hlo_path.display()))?;
        let exe = self.backend.compile(&self.device, &meta.name, &hlo_text, &meta)?;
        self.models.insert(meta.name.clone(), LoadedModel { meta, exe });
        Ok(())
    }

    /// Load every artifact listed in `manifest.txt` (each line is a full
    /// meta line, parsed exactly once).
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `power-mma gen-artifacts`)")?;
        let mut names = Vec::new();
        for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ModelMeta::parse(line)?;
            let name = meta.name.clone();
            self.load_with_meta(meta)?;
            names.push(name);
        }
        Ok(names)
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.models.get(name).map(|m| &m.meta)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a model on typed tensors: inputs are validated against
    /// the metadata (count, exact dims, storage length), the result is
    /// written into `out` (rounded to its dtype). `Bf16` inputs are
    /// widened exactly through the context's staging buffers, so a bf16
    /// serving client never round-trips through caller-side conversion.
    pub fn execute_typed(
        &self,
        name: &str,
        ctx: &mut ExecCtx<'_>,
        inputs: &[TensorRef<'_>],
        out: &mut TensorMut<'_>,
    ) -> Result<()> {
        let model = self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))?;
        device::validate_inputs(name, &model.meta, inputs)?;
        device::validate_output(name, &model.meta, out)?;
        model
            .exe
            .execute(ctx, inputs, out)
            .map_err(|e| e.context(format!("execute {name}")))
    }

    /// Execute a model on flat f32 inputs (row-major); returns the flat
    /// f32 output. **Compat shim** over [`Runtime::execute_typed`]: the
    /// inputs are wrapped as f32 [`TensorRef`]s with the metadata's
    /// shapes and a fresh per-call [`ExecCtx`] on this runtime's device.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let model = self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))?;
        if inputs.len() != model.meta.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.meta.input_shapes.len(),
                inputs.len()
            );
        }
        let trefs: Vec<TensorRef<'_>> = inputs
            .iter()
            .zip(&model.meta.input_shapes)
            .map(|(d, s)| TensorRef::f32(d, s))
            .collect();
        let mut result = vec![0f32; model.meta.output_len()];
        let mut out = TensorMut::f32(&mut result, &model.meta.output_shape);
        let mut ctx = self.device.ctx();
        self.execute_typed(name, &mut ctx, &trefs, &mut out)?;
        Ok(result)
    }

    /// Read the python-side expected output for the deterministic inputs.
    pub fn expected(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.expected.bin"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect())
    }
}

/// The deterministic test input of `aot.py::det_input`, reproduced
/// bit-identically: `value(i) = ((i*31 + 7*salt) % 61) / 61 − 0.5`,
/// computed in f64 and cast to f32.
pub fn det_input(len: usize, salt: u64) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let v = ((i as f64 * 31.0 + 7.0 * salt as f64) % 61.0) / 61.0 - 0.5;
            v as f32
        })
        .collect()
}

/// Deterministic inputs for every argument of a model (salt = arg index+1),
/// matching `aot.py::build_artifact`.
pub fn det_inputs(meta: &ModelMeta) -> Vec<Vec<f32>> {
    meta.input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| det_input(s.iter().product(), i as u64 + 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parsing() {
        let m = ModelMeta::parse("gemm_f32;128x128,128x128;128x128\n").unwrap();
        assert_eq!(m.name, "gemm_f32");
        assert_eq!(m.input_shapes, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(m.output_shape, vec![128, 128]);
        assert_eq!(m.input_len(0), 128 * 128);
        assert_eq!(m.output_len(), 128 * 128);

        let m = ModelMeta::parse("mlp_b32;32x64,64x128,128,128x32,32;32x32").unwrap();
        assert_eq!(m.input_shapes.len(), 5);
        assert_eq!(m.input_shapes[2], vec![128]);

        assert!(ModelMeta::parse("bad").is_err());
        assert!(ModelMeta::parse("x;1xq;2").is_err());
    }

    #[test]
    fn meta_rejects_trailing_fields() {
        // a fourth field used to parse silently (split(';') never ran
        // dry); it must be a hard error now
        let e = ModelMeta::parse("name;2x2;2x2;junk").unwrap_err().to_string();
        assert!(e.contains("trailing field"), "{e}");
        // even an *empty* trailing field is malformed
        let e = ModelMeta::parse("name;2x2;2x2;").unwrap_err().to_string();
        assert!(e.contains("trailing field"), "{e}");
        assert!(ModelMeta::parse("name;2x2;2x2;4x4;8x8").is_err());
        // the well-formed line still parses
        assert!(ModelMeta::parse("name;2x2;2x2").is_ok());
    }

    #[test]
    fn det_input_matches_python_formula() {
        let v = det_input(4, 1);
        for (i, &val) in v.iter().enumerate() {
            let expect = (((i as f64) * 31.0 + 7.0) % 61.0) / 61.0 - 0.5;
            assert_eq!(val, expect as f32);
        }
        // different salts differ
        assert_ne!(det_input(8, 1), det_input(8, 2));
    }

    #[test]
    fn runtime_loads_and_executes_embedded_artifacts() {
        let dir = std::env::temp_dir().join(format!("mma-rt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        assert_eq!(rt.platform(), "native-hlo-plan");
        let names = rt.load_all().unwrap();
        assert!(names.contains(&"gemm_f32".to_string()));
        assert!(rt.loaded().contains(&"gemm_f32"));
        let meta = rt.meta("gemm_f32").unwrap().clone();
        let ins = det_inputs(&meta);
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute("gemm_f32", &refs).unwrap();
        assert_eq!(out.len(), meta.output_len());
        // input validation
        assert!(rt.execute("gemm_f32", &[]).is_err());
        assert!(rt.execute("nonexistent", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_all_uses_the_manifest_meta_without_rereading() {
        // load_all parses each manifest line once and passes the meta
        // through; the per-model .meta file is NOT re-read. Corrupting it
        // must therefore not affect load_all...
        let dir = std::env::temp_dir().join(format!("mma-rt-meta1x-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        std::fs::write(dir.join("gemm_f32.meta"), "garbage;;junk;;\n").unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        let names = rt.load_all().unwrap();
        assert!(names.contains(&"gemm_f32".to_string()));
        // ...while the by-name path (which does read the file) fails
        let mut rt2 = Runtime::cpu(&dir).unwrap();
        assert!(rt2.load("gemm_f32").is_err(), "corrupt .meta must fail load-by-name");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn typed_execution_matches_compat_shim_bitwise() {
        let dir = std::env::temp_dir().join(format!("mma-rt-typed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let meta = rt.meta("mlp_b32").unwrap().clone();
        let ins = det_inputs(&meta);
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let shim = rt.execute("mlp_b32", &refs).unwrap();
        let trefs: Vec<TensorRef<'_>> = ins
            .iter()
            .zip(&meta.input_shapes)
            .map(|(d, s)| TensorRef::f32(d, s))
            .collect();
        let mut typed = vec![0f32; meta.output_len()];
        let mut out = TensorMut::f32(&mut typed, &meta.output_shape);
        let mut ctx = rt.device().ctx();
        rt.execute_typed("mlp_b32", &mut ctx, &trefs, &mut out).unwrap();
        assert_eq!(
            typed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            shim.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "typed path and compat shim must agree bit for bit"
        );
        // typed validation: wrong dims are rejected up front
        let bad_dims = vec![1usize, 2];
        let bad: Vec<TensorRef<'_>> =
            ins.iter().map(|d| TensorRef::f32(d, &bad_dims)).collect();
        assert!(rt.execute_typed("mlp_b32", &mut ctx, &bad, &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_typed_inputs_stage_exactly() {
        // feeding bf16 storage must equal feeding the pre-rounded f32
        // values through the f32 path, bit for bit
        let dir = std::env::temp_dir().join(format!("mma-rt-bf16-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        rt.load_all().unwrap();
        let meta = rt.meta("gemm_f32").unwrap().clone();
        let ins = det_inputs(&meta);
        // bf16-quantize the inputs both ways
        let bits: Vec<Vec<u16>> =
            ins.iter().map(|v| v.iter().map(|&x| f32_to_bf16(x)).collect()).collect();
        let widened: Vec<Vec<f32>> =
            bits.iter().map(|v| v.iter().map(|&b| bf16_to_f32(b)).collect()).collect();
        let refs: Vec<&[f32]> = widened.iter().map(|v| v.as_slice()).collect();
        let via_f32 = rt.execute("gemm_f32", &refs).unwrap();
        let trefs: Vec<TensorRef<'_>> = bits
            .iter()
            .zip(&meta.input_shapes)
            .map(|(d, s)| TensorRef::bf16(d, s))
            .collect();
        let mut via_bf16 = vec![0f32; meta.output_len()];
        let mut out = TensorMut::f32(&mut via_bf16, &meta.output_shape);
        let mut ctx = rt.device().ctx();
        rt.execute_typed("gemm_f32", &mut ctx, &trefs, &mut out).unwrap();
        assert_eq!(
            via_bf16.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_f32.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // bf16 *output* buffers round the result on store
        let mut hout = vec![0u16; meta.output_len()];
        let mut out = TensorMut::bf16(&mut hout, &meta.output_shape);
        rt.execute_typed("gemm_f32", &mut ctx, &trefs, &mut out).unwrap();
        for (h, &v) in hout.iter().zip(&via_f32) {
            assert_eq!(*h, f32_to_bf16(v));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
