//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see that file and /opt/xla-example/README.md for why text,
//! not serialized protos) and executes them on the CPU PJRT client.
//!
//! This is the only place the crate touches the `xla` FFI. The coordinator
//! runs a [`Runtime`] on a dedicated engine thread (the PJRT wrappers hold
//! raw C++ pointers and are kept thread-confined).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `<name>.meta` line: `name;in0shape,in1shape,…;outshape`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelMeta {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
}

impl ModelMeta {
    /// Parse one manifest line.
    pub fn parse(line: &str) -> Result<ModelMeta> {
        let mut parts = line.trim().split(';');
        let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?.to_string();
        let ins = parts.next().ok_or_else(|| anyhow!("{name}: missing input shapes"))?;
        let out = parts.next().ok_or_else(|| anyhow!("{name}: missing output shape"))?;
        let parse_shape = |s: &str| -> Result<Vec<usize>> {
            s.split('x').map(|d| d.parse::<usize>().context("bad dim")).collect()
        };
        Ok(ModelMeta {
            name,
            input_shapes: ins.split(',').map(parse_shape).collect::<Result<_>>()?,
            output_shape: parse_shape(out)?,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// One compiled model.
pub struct LoadedModel {
    pub meta: ModelMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifact directory (does not load
    /// anything yet).
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Runtime { client, models: HashMap::new(), dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name (`<dir>/<name>.hlo.txt` +
    /// `<name>.meta`). Idempotent.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta_line = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`?)"))?;
        let meta = ModelMeta::parse(&meta_line)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.models.insert(name.to_string(), LoadedModel { meta, exe });
        Ok(())
    }

    /// Load every artifact listed in `manifest.txt`.
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `make artifacts`)")?;
        let mut names = Vec::new();
        for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ModelMeta::parse(line)?;
            self.load(&meta.name)?;
            names.push(meta.name);
        }
        Ok(names)
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.models.get(name).map(|m| &m.meta)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a model on flat f32 inputs (row-major); returns the flat
    /// f32 output. Input lengths are validated against the metadata.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let model =
            self.models.get(name).ok_or_else(|| anyhow!("model {name} not loaded"))?;
        if inputs.len() != model.meta.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.meta.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let want = model.meta.input_len(i);
            if data.len() != want {
                bail!("{name}: input {i} has {} elements, expected {want}", data.len());
            }
            let dims: Vec<i64> = model.meta.input_shapes[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = model
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let vals = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if vals.len() != model.meta.output_len() {
            bail!("{name}: output has {} elements, expected {}", vals.len(), model.meta.output_len());
        }
        Ok(vals)
    }

    /// Read the python-side expected output for the deterministic inputs.
    pub fn expected(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.expected.bin"));
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect())
    }
}

/// The deterministic test input of `aot.py::det_input`, reproduced
/// bit-identically: `value(i) = ((i*31 + 7*salt) % 61) / 61 − 0.5`,
/// computed in f64 and cast to f32.
pub fn det_input(len: usize, salt: u64) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let v = ((i as f64 * 31.0 + 7.0 * salt as f64) % 61.0) / 61.0 - 0.5;
            v as f32
        })
        .collect()
}

/// Deterministic inputs for every argument of a model (salt = arg index+1),
/// matching `aot.py::build_artifact`.
pub fn det_inputs(meta: &ModelMeta) -> Vec<Vec<f32>> {
    meta.input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| det_input(s.iter().product(), i as u64 + 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parsing() {
        let m = ModelMeta::parse("gemm_f32;128x128,128x128;128x128\n").unwrap();
        assert_eq!(m.name, "gemm_f32");
        assert_eq!(m.input_shapes, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(m.output_shape, vec![128, 128]);
        assert_eq!(m.input_len(0), 128 * 128);
        assert_eq!(m.output_len(), 128 * 128);

        let m = ModelMeta::parse("mlp_b32;32x64,64x128,128,128x32,32;32x32").unwrap();
        assert_eq!(m.input_shapes.len(), 5);
        assert_eq!(m.input_shapes[2], vec![128]);

        assert!(ModelMeta::parse("bad").is_err());
        assert!(ModelMeta::parse("x;1xq;2").is_err());
    }

    #[test]
    fn det_input_matches_python_formula() {
        let v = det_input(4, 1);
        for (i, &val) in v.iter().enumerate() {
            let expect = (((i as f64) * 31.0 + 7.0) % 61.0) / 61.0 - 0.5;
            assert_eq!(val, expect as f32);
        }
        // different salts differ
        assert_ne!(det_input(8, 1), det_input(8, 2));
    }
}
