//! Native model runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** + `.meta` shape lines + expected
//! outputs) and executes them entirely in-crate.
//!
//! The former `xla::PjRt*` FFI is gone.  Execution goes through the
//! [`EngineBackend`] trait. The default backend ([`HloPlanBackend`],
//! behind [`Runtime::cpu`]) **compiles** each artifact once at `load()`
//! into a [`plan::Plan`] — a topologically-ordered step list over a
//! preallocated, liveness-reusing buffer arena, with a rewrite pass
//! that collapses conv graphs into single im2col GEMM steps and fuses
//! post-`dot` bias/relu tails into the GEMM writeback — and executes
//! requests against the plan on the blocked parallel GEMM of
//! [`crate::blas::block_gemm`].  The legacy [`HloInterpreterBackend`]
//! (per-request walk of [`hlo::HloModule::evaluate`] over `ref_gemm`) is
//! kept as the numerics oracle and for `power-mma bench serve`
//! comparisons; both produce bit-identical results on the artifact set.
//! Either way the whole request path is zero-external-dependency,
//! observable, testable rust, and other backends (e.g. one lowering onto
//! the simulated MMA kernels, or a real PJRT client) plug in behind the
//! same trait via [`Runtime::with_backend`].
//!
//! The coordinator still runs a [`Runtime`] on a dedicated engine thread;
//! backends are constructed *inside* that thread via a factory, so
//! thread-confined backends remain possible. The plan backend's GEMM
//! workers are *scoped* threads that join within each `dot`, so nothing
//! escapes the engine thread.

pub mod artifacts;
pub mod hlo;
pub mod plan;

use crate::error::{Context, Result};
use crate::{bail, err};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `<name>.meta` line: `name;in0shape,in1shape,…;outshape`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelMeta {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
}

impl ModelMeta {
    /// Parse one manifest line.
    pub fn parse(line: &str) -> Result<ModelMeta> {
        let mut parts = line.trim().split(';');
        let name = parts.next().ok_or_else(|| err!("empty manifest line"))?.to_string();
        if name.is_empty() {
            bail!("empty model name in manifest line");
        }
        let ins = parts.next().ok_or_else(|| err!("{name}: missing input shapes"))?;
        let out = parts.next().ok_or_else(|| err!("{name}: missing output shape"))?;
        let parse_shape = |s: &str| -> Result<Vec<usize>> {
            s.split('x').map(|d| d.parse::<usize>().context("bad dim")).collect()
        };
        Ok(ModelMeta {
            name,
            input_shapes: ins.split(',').map(parse_shape).collect::<Result<_>>()?,
            output_shape: parse_shape(out)?,
        })
    }

    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// A model compiled by an [`EngineBackend`], ready to execute.
pub trait CompiledModel {
    /// Execute on flat row-major f32 inputs; returns the flat f32 output.
    fn execute(&self, inputs: &[&[f32]]) -> Result<Vec<f32>>;
}

/// Pluggable execution backend: turns HLO text into executable models.
pub trait EngineBackend {
    /// Backend identifier (reported by [`Runtime::platform`]).
    fn name(&self) -> &'static str;

    /// Compile one artifact's HLO text, validating it against the meta.
    fn compile(
        &self,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>>;
}

/// Parse an artifact's HLO text and cross-check it against the meta line
/// (parameter count and element counts) — shared by every backend.
fn parse_and_validate(name: &str, hlo_text: &str, meta: &ModelMeta) -> Result<hlo::HloModule> {
    let module = hlo::HloModule::parse(hlo_text)
        .map_err(|e| e.context(format!("parsing HLO for {name}")))?;
    if module.num_parameters() != meta.input_shapes.len() {
        bail!(
            "{name}: HLO has {} parameters, meta declares {} inputs",
            module.num_parameters(),
            meta.input_shapes.len()
        );
    }
    for (i, shape) in meta.input_shapes.iter().enumerate() {
        let hlo_len: usize = module
            .parameter_dims(i)
            .ok_or_else(|| err!("{name}: HLO is missing parameter {i}"))?
            .iter()
            .product();
        let meta_len: usize = shape.iter().product();
        if hlo_len != meta_len {
            bail!("{name}: parameter {i} has {hlo_len} elements in HLO, {meta_len} in meta");
        }
    }
    Ok(module)
}

/// The legacy native backend: parses HLO text and re-interprets it per
/// request over `blas` (`ref_gemm`). Kept as the numerics oracle and the
/// baseline side of `power-mma bench serve`.
pub struct HloInterpreterBackend;

impl EngineBackend for HloInterpreterBackend {
    fn name(&self) -> &'static str {
        "native-hlo-interpreter"
    }

    fn compile(
        &self,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        Ok(Box::new(InterpretedModel { module }))
    }
}

struct InterpretedModel {
    module: hlo::HloModule,
}

impl CompiledModel for InterpretedModel {
    fn execute(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let outputs = self.module.evaluate(inputs)?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let first = outputs.into_iter().next().ok_or_else(|| err!("model produced no output"))?;
        Ok(first.data)
    }
}

/// The default serving backend: lowers each artifact once at `load()`
/// into a compiled [`plan::Plan`] (preallocated buffer arena, blocked
/// parallel GEMM) and executes requests against the plan. Bit-identical
/// to [`HloInterpreterBackend`] on finite inputs, several times faster
/// on GEMM-heavy artifacts (measure with `power-mma bench serve`).
pub struct HloPlanBackend {
    threads: usize,
}

impl HloPlanBackend {
    /// The default GEMM worker cap: `std::thread::available_parallelism()`
    /// clamped to 16 — the single source of the policy, shared with
    /// `power-mma bench serve`'s thread sweep.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism().map_or(1, |n| n.get()).min(16)
    }

    /// Plan backend with the worker cap of [`HloPlanBackend::default_threads`].
    pub fn new() -> HloPlanBackend {
        HloPlanBackend { threads: HloPlanBackend::default_threads() }
    }

    /// Plan backend with an explicit GEMM worker cap (1 = fully serial).
    pub fn with_threads(threads: usize) -> HloPlanBackend {
        HloPlanBackend { threads: threads.max(1) }
    }

    /// The configured GEMM worker cap.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Default for HloPlanBackend {
    fn default() -> Self {
        HloPlanBackend::new()
    }
}

impl EngineBackend for HloPlanBackend {
    fn name(&self) -> &'static str {
        "native-hlo-plan"
    }

    fn compile(
        &self,
        name: &str,
        hlo_text: &str,
        meta: &ModelMeta,
    ) -> Result<Box<dyn CompiledModel>> {
        let module = parse_and_validate(name, hlo_text, meta)?;
        let plan = plan::Plan::compile(&module)
            .map_err(|e| e.context(format!("compiling plan for {name}")))?;
        let bufs = std::sync::Mutex::new(plan.new_buffers());
        Ok(Box::new(PlanModel { plan, bufs, threads: self.threads }))
    }
}

/// A plan plus its preallocated buffers. The buffers sit behind a
/// `Mutex` only to satisfy the `&self` execute contract; on the
/// coordinator's thread-confined engine the lock is always uncontended.
struct PlanModel {
    plan: plan::Plan,
    bufs: std::sync::Mutex<plan::ExecBuffers>,
    threads: usize,
}

impl CompiledModel for PlanModel {
    fn execute(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let mut bufs = self.bufs.lock().unwrap_or_else(|p| p.into_inner());
        let outputs = self.plan.execute_into(&mut bufs, inputs, self.threads)?;
        let first = outputs.into_iter().next().ok_or_else(|| err!("model produced no output"))?;
        Ok(first.data)
    }
}

/// One compiled model with its metadata.
pub struct LoadedModel {
    pub meta: ModelMeta,
    exe: Box<dyn CompiledModel>,
}

/// The artifact-directory runtime with a compiled-model cache.
pub struct Runtime {
    backend: Box<dyn EngineBackend>,
    models: HashMap<String, LoadedModel>,
    dir: PathBuf,
}

impl Runtime {
    /// Runtime over an artifact directory with the default native plan
    /// backend (the name is historical: this was the PJRT *CPU* client).
    /// Does not load anything yet.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(HloPlanBackend::new()), artifact_dir))
    }

    /// Runtime over an artifact directory with an explicit backend.
    pub fn with_backend(
        backend: Box<dyn EngineBackend>,
        artifact_dir: impl AsRef<Path>,
    ) -> Runtime {
        Runtime { backend, models: HashMap::new(), dir: artifact_dir.as_ref().to_path_buf() }
    }

    /// Name of the execution backend.
    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Load + compile one artifact by name (`<dir>/<name>.hlo.txt` +
    /// `<name>.meta`). Idempotent.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.models.contains_key(name) {
            return Ok(());
        }
        let meta_path = self.dir.join(format!("{name}.meta"));
        let meta_line = std::fs::read_to_string(&meta_path).with_context(|| {
            format!("reading {} (run `power-mma gen-artifacts`?)", meta_path.display())
        })?;
        let meta = ModelMeta::parse(&meta_line)?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let hlo_text = std::fs::read_to_string(&hlo_path)
            .with_context(|| format!("reading {}", hlo_path.display()))?;
        let exe = self.backend.compile(name, &hlo_text, &meta)?;
        self.models.insert(name.to_string(), LoadedModel { meta, exe });
        Ok(())
    }

    /// Load every artifact listed in `manifest.txt`.
    pub fn load_all(&mut self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `power-mma gen-artifacts`)")?;
        let mut names = Vec::new();
        for line in manifest.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ModelMeta::parse(line)?;
            self.load(&meta.name)?;
            names.push(meta.name);
        }
        Ok(names)
    }

    pub fn meta(&self, name: &str) -> Option<&ModelMeta> {
        self.models.get(name).map(|m| &m.meta)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a model on flat f32 inputs (row-major); returns the flat
    /// f32 output. Input lengths are validated against the metadata.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let model = self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))?;
        if inputs.len() != model.meta.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.meta.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, data) in inputs.iter().enumerate() {
            let want = model.meta.input_len(i);
            if data.len() != want {
                bail!("{name}: input {i} has {} elements, expected {want}", data.len());
            }
        }
        let out = model.exe.execute(inputs).map_err(|e| e.context(format!("execute {name}")))?;
        if out.len() != model.meta.output_len() {
            bail!("{name}: output has {} elements, expected {}", out.len(), model.meta.output_len());
        }
        Ok(out)
    }

    /// Read the python-side expected output for the deterministic inputs.
    pub fn expected(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.expected.bin"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect())
    }
}

/// The deterministic test input of `aot.py::det_input`, reproduced
/// bit-identically: `value(i) = ((i*31 + 7*salt) % 61) / 61 − 0.5`,
/// computed in f64 and cast to f32.
pub fn det_input(len: usize, salt: u64) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let v = ((i as f64 * 31.0 + 7.0 * salt as f64) % 61.0) / 61.0 - 0.5;
            v as f32
        })
        .collect()
}

/// Deterministic inputs for every argument of a model (salt = arg index+1),
/// matching `aot.py::build_artifact`.
pub fn det_inputs(meta: &ModelMeta) -> Vec<Vec<f32>> {
    meta.input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| det_input(s.iter().product(), i as u64 + 1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parsing() {
        let m = ModelMeta::parse("gemm_f32;128x128,128x128;128x128\n").unwrap();
        assert_eq!(m.name, "gemm_f32");
        assert_eq!(m.input_shapes, vec![vec![128, 128], vec![128, 128]]);
        assert_eq!(m.output_shape, vec![128, 128]);
        assert_eq!(m.input_len(0), 128 * 128);
        assert_eq!(m.output_len(), 128 * 128);

        let m = ModelMeta::parse("mlp_b32;32x64,64x128,128,128x32,32;32x32").unwrap();
        assert_eq!(m.input_shapes.len(), 5);
        assert_eq!(m.input_shapes[2], vec![128]);

        assert!(ModelMeta::parse("bad").is_err());
        assert!(ModelMeta::parse("x;1xq;2").is_err());
    }

    #[test]
    fn det_input_matches_python_formula() {
        let v = det_input(4, 1);
        for (i, &val) in v.iter().enumerate() {
            let expect = (((i as f64) * 31.0 + 7.0) % 61.0) / 61.0 - 0.5;
            assert_eq!(val, expect as f32);
        }
        // different salts differ
        assert_ne!(det_input(8, 1), det_input(8, 2));
    }

    #[test]
    fn runtime_loads_and_executes_embedded_artifacts() {
        let dir = std::env::temp_dir().join(format!("mma-rt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        artifacts::write_artifacts(&dir).unwrap();
        let mut rt = Runtime::cpu(&dir).unwrap();
        assert_eq!(rt.platform(), "native-hlo-plan");
        let names = rt.load_all().unwrap();
        assert!(names.contains(&"gemm_f32".to_string()));
        assert!(rt.loaded().contains(&"gemm_f32"));
        let meta = rt.meta("gemm_f32").unwrap().clone();
        let ins = det_inputs(&meta);
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let out = rt.execute("gemm_f32", &refs).unwrap();
        assert_eq!(out.len(), meta.output_len());
        // input validation
        assert!(rt.execute("gemm_f32", &[]).is_err());
        assert!(rt.execute("nonexistent", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
