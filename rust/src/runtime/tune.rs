//! The **shape autotuner** of the plan backend: a device-level
//! [`TuneTable`] that maps a GEMM *shape class* ([`TuneKey`]: `m × n × k`
//! + dtype + fused epilogue) to the fastest [`GemmVariant`] of the
//! monomorphized microkernel family, measured once and memoized.
//!
//! This is the "generate a family, select per shape" strategy of the
//! kernel-generation literature (Hello SME!'s per-shape kernel selection;
//! Kuzma et al.'s layered data-reorganization), applied where it is
//! essentially free on our serving path: plans are compiled once and
//! executed many times, so a one-time measurement per shape class
//! amortizes to nothing.
//!
//! The contract that makes tuning *safe* is established by the engines
//! themselves and pinned by `rust/tests/tune_engine.rs`: **every variant
//! is bitwise identical to the canonical variant** under every
//! accumulation contract, because each `C` element accumulates its `k`
//! products in strictly ascending order from the same packed values no
//! matter where the register-tile or cache-block seams fall (and every
//! grid `kc` keeps the bf16 pair / i8 quad steps whole). The tuner can
//! therefore only ever change speed, never bits.
//!
//! Flow:
//!
//! 1. [`Plan`](super::plan::Plan) compilation asks the table for each
//!    fused GEMM step's class via [`TuneTable::choose`];
//! 2. on first sight of a class the table **measures** every candidate
//!    ([`GemmVariant::f32_candidates`] / [`GemmVariant::wide_candidates`])
//!    on synthetic operands of exactly that shape, serially, and memoizes
//!    the argmin (ties keep the canonical head — so `chosen_ms <=
//!    default_ms` by construction);
//! 3. the winning variant is stored **in the compiled step**, so
//!    re-execution never consults the table again, and other plans
//!    compiled against the same device reuse the memoized row;
//! 4. classes too large to measure cheaply (above
//!    [`MEASURE_FLOP_CAP`]) fall back to the deterministic heuristic
//!    default ([`heuristic_variant`]: the canonical variant per dtype)
//!    with `measured: false` — same bits, just no search.
//!
//! `--no-tune` (or simply not installing a table in
//! [`PlanOptions`](super::plan::PlanOptions)) short-circuits the whole
//! mechanism to the heuristic default, which is byte-for-byte the
//! pre-autotuner engine configuration.

use crate::blas::bf16_gemm::{gemm_bf16_tuned_into, Bf16Accum, Bf16Scratch, Bf16Src};
use crate::blas::block_gemm::{
    gemm_f32_tuned_into, Accum, BlockCfg, Epilogue, GemmScratch, GemmVariant, PanelB, Par,
};
use crate::blas::i8_gemm::{gemm_i8_packed_tuned_into, I8Accum, I8Scratch, I8SrcA, I8SrcB};
use crate::kernels::pack::{DftPanels, Im2colSpec};
use std::collections::HashMap;
use std::io::{self, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Largest `2·m·n·k` flop count the tuner will measure. Above this the
/// class gets the heuristic default (`measured: false`) — measurement
/// would cost more than it could ever save at plan-compile time. The cap
/// is two 256³ GEMMs; every MLP serving shape in the bench fixture sits
/// far below it.
pub const MEASURE_FLOP_CAP: usize = 33_554_432;

/// How many timed repetitions back the per-candidate measurement (the
/// minimum is taken; one untimed warmup precedes them).
const MEASURE_REPS: usize = 3;

/// First line of the on-disk tune-cache format ([`TuneTable::save`] /
/// [`TuneTable::load_into`]). Bump the version when the row layout
/// changes; old caches then fail closed into re-measurement.
pub const TUNE_CACHE_HEADER: &str = "power-mma-tune-table v1";

/// The dtype axis of a shape class — which engine (and so which
/// candidate family) the class tunes over.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TuneDtype {
    /// The f32 blocked engine (`dot` / im2col steps).
    F32,
    /// The bf16 packed-panel engine (`dot_bf16` steps).
    Bf16,
    /// The int8 rank-4 engine (`dot_i8` steps).
    I8,
}

impl TuneDtype {
    /// Stable lowercase name (the `tuning` JSON block's `dtype` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            TuneDtype::F32 => "f32",
            TuneDtype::Bf16 => "bf16",
            TuneDtype::I8 => "i8",
        }
    }

    /// Parse of [`TuneDtype::as_str`] (tune-cache deserialization).
    pub fn from_str_opt(s: &str) -> Option<TuneDtype> {
        match s {
            "f32" => Some(TuneDtype::F32),
            "bf16" => Some(TuneDtype::Bf16),
            "i8" => Some(TuneDtype::I8),
            _ => None,
        }
    }

    fn order(&self) -> u8 {
        match self {
            TuneDtype::F32 => 0,
            TuneDtype::Bf16 => 1,
            TuneDtype::I8 => 2,
        }
    }
}

/// The fused-epilogue axis of a shape class. The epilogue runs on the
/// single-threaded writeback pass and is geometry-independent, so it
/// never changes which variant wins — but it is part of the class key so
/// the table rows match the compiled steps one-to-one (auditable in the
/// bench's `tuning` block).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TuneEpi {
    None,
    Bias,
    BiasRelu,
}

impl TuneEpi {
    /// Stable name (the `tuning` JSON block's `epilogue` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            TuneEpi::None => "none",
            TuneEpi::Bias => "bias",
            TuneEpi::BiasRelu => "bias_relu",
        }
    }

    /// Parse of [`TuneEpi::as_str`] (tune-cache deserialization).
    pub fn from_str_opt(s: &str) -> Option<TuneEpi> {
        match s {
            "none" => Some(TuneEpi::None),
            "bias" => Some(TuneEpi::Bias),
            "bias_relu" => Some(TuneEpi::BiasRelu),
            _ => None,
        }
    }

    fn order(&self) -> u8 {
        match self {
            TuneEpi::None => 0,
            TuneEpi::Bias => 1,
            TuneEpi::BiasRelu => 2,
        }
    }
}

/// The B-panel modality axis of a shape class: how the engine sources
/// its packed panels. An im2col gather and a contiguous-matrix copy have
/// different memory behavior at the same `m×n×k`, so conv classes are
/// keyed — and **measured** — separately from plain `dot` classes
/// instead of borrowing a matrix-modality winner.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TunePanel {
    /// Contiguous row-major B ([`PanelB::Matrix`]) — `dot`-family steps.
    Matrix,
    /// Virtual im2col gather ([`PanelB::Im2col`]) — `im2col_gemm` steps.
    Im2col,
    /// Pre-packed DFT coefficient panels ([`PanelB::Packed`]) driven as
    /// the real/imag dual-GEMM×2 structure — `dft_gemm` steps. Keyed
    /// (and measured) as the full four-GEMM complex product, so the
    /// class no longer borrows a single-GEMM matrix-modality winner of
    /// the wrong shape.
    DftPacked,
}

impl TunePanel {
    /// Stable name (the `tuning` JSON block's `panel` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            TunePanel::Matrix => "matrix",
            TunePanel::Im2col => "im2col",
            TunePanel::DftPacked => "dft_packed",
        }
    }

    /// Parse of [`TunePanel::as_str`] (tune-cache deserialization).
    pub fn from_str_opt(s: &str) -> Option<TunePanel> {
        match s {
            "matrix" => Some(TunePanel::Matrix),
            "im2col" => Some(TunePanel::Im2col),
            "dft_packed" => Some(TunePanel::DftPacked),
            _ => None,
        }
    }

    fn order(&self) -> u8 {
        match self {
            TunePanel::Matrix => 0,
            TunePanel::Im2col => 1,
            TunePanel::DftPacked => 2,
        }
    }
}

/// One GEMM shape class: everything that determines which variant is
/// fastest (shape + engine), plus the epilogue for step-level audit
/// identity. This is the explicit key stored next to the chosen variant
/// in the compiled step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TuneKey {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: TuneDtype,
    pub epi: TuneEpi,
    /// B-panel modality ([`TunePanel::Im2col`] only for f32 conv steps).
    pub panel: TunePanel,
}

impl TuneKey {
    fn sort_idx(&self) -> (u8, u8, usize, usize, usize, u8) {
        (self.dtype.order(), self.panel.order(), self.m, self.n, self.k, self.epi.order())
    }
}

/// The memoized decision for one class: the winning variant plus the
/// audit trail (`chosen_ms` vs the canonical `default_ms`, and whether a
/// measurement actually ran or the heuristic default was used).
#[derive(Clone, Copy, Debug)]
pub struct TuneChoice {
    /// The variant compiled into the plan step.
    pub variant: GemmVariant,
    /// Best measured milliseconds of `variant` (0.0 when `!measured`).
    pub chosen_ms: f64,
    /// Best measured milliseconds of the canonical default variant
    /// (0.0 when `!measured`). `chosen_ms <= default_ms` always: the
    /// candidate list is canonical-first and ties keep the head.
    pub default_ms: f64,
    /// Whether a measurement ran (`false`: heuristic default, either
    /// because tuning was off for this class or the class is above
    /// [`MEASURE_FLOP_CAP`]).
    pub measured: bool,
}

/// The deterministic no-measurement default for a dtype: exactly the
/// canonical variant the engines shipped with, so an untuned plan is
/// byte-for-byte the pre-autotuner engine configuration.
pub fn heuristic_variant(dtype: TuneDtype) -> GemmVariant {
    match dtype {
        TuneDtype::F32 => GemmVariant::CANONICAL_F32,
        TuneDtype::Bf16 | TuneDtype::I8 => GemmVariant::CANONICAL_WIDE,
    }
}

/// The device-level memoized `class → variant` table. Shared behind an
/// `Arc` by every plan compiled against one
/// [`Device`](super::device::Device); interior-mutable so concurrent
/// compilations can tune (a racing class is measured at most once per
/// racer, and the first insert wins — both measure the same winner on
/// the same synthetic inputs anyway).
#[derive(Default)]
pub struct TuneTable {
    entries: Mutex<HashMap<TuneKey, TuneChoice>>,
    measures: AtomicUsize,
}

impl std::fmt::Debug for TuneTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TuneTable")
            .field("classes", &self.len())
            .field("measured", &self.measure_count())
            .finish()
    }
}

impl TuneTable {
    /// An empty table (classes tune lazily on first sight).
    pub fn new() -> TuneTable {
        TuneTable::default()
    }

    /// The memoized choice for `key`, measuring the candidate family
    /// first if this is the class's first sight (see the module docs for
    /// the measure-vs-heuristic rule).
    pub fn choose(&self, key: TuneKey) -> TuneChoice {
        if let Some(c) = self.entries.lock().unwrap_or_else(|p| p.into_inner()).get(&key) {
            return *c;
        }
        let fresh = self.measure_class(key);
        let mut map = self.entries.lock().unwrap_or_else(|p| p.into_inner());
        *map.entry(key).or_insert(fresh)
    }

    /// The memoized choice if the class has been seen, without tuning.
    pub fn lookup(&self, key: TuneKey) -> Option<TuneChoice> {
        self.entries.lock().unwrap_or_else(|p| p.into_inner()).get(&key).copied()
    }

    /// Pre-seed (or override) a class — the escape hatch tests use to
    /// force specific variants through the plan path, and what a future
    /// serialized-table load would call.
    pub fn insert(&self, key: TuneKey, choice: TuneChoice) {
        self.entries.lock().unwrap_or_else(|p| p.into_inner()).insert(key, choice);
    }

    /// Number of memoized classes.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many classes have actually been *measured* (memoized lookups
    /// and heuristic fallbacks don't count) — the "re-execution never
    /// re-measures" property, observable.
    pub fn measure_count(&self) -> usize {
        self.measures.load(Ordering::Relaxed)
    }

    /// Every memoized row in deterministic order (dtype, then m, n, k,
    /// then epilogue) — the bench's `tuning` JSON table.
    pub fn snapshot(&self) -> Vec<(TuneKey, TuneChoice)> {
        let mut rows: Vec<(TuneKey, TuneChoice)> = self
            .entries
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .map(|(k, c)| (*k, *c))
            .collect();
        rows.sort_by_key(|(k, _)| k.sort_idx());
        rows
    }

    /// Persist every **measured** row to `path` in the versioned
    /// plain-text tune-cache format (see [`TUNE_CACHE_HEADER`]).
    /// Heuristic fallbacks are not persisted — they are free to
    /// recompute and may depend on the measure cap. Returns the number
    /// of rows written.
    pub fn save(&self, path: &Path) -> io::Result<usize> {
        let mut out = String::from(TUNE_CACHE_HEADER);
        out.push('\n');
        let mut rows = 0usize;
        for (key, c) in self.snapshot() {
            if !c.measured {
                continue;
            }
            let v = c.variant;
            out.push_str(&format!(
                "{} {} {} {} {} {} {} {} {} {} {} {} {}\n",
                key.m,
                key.n,
                key.k,
                key.dtype.as_str(),
                key.epi.as_str(),
                key.panel.as_str(),
                v.mr,
                v.nr,
                v.block.mc,
                v.block.kc,
                v.block.nc,
                c.chosen_ms,
                c.default_ms,
            ));
            rows += 1;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())?;
        Ok(rows)
    }

    /// Load a tune cache written by [`TuneTable::save`] into this
    /// table (rows arrive pre-measured, so re-execution skips the
    /// measurement entirely). A missing header, version mismatch, or
    /// any malformed row fails the whole load with `InvalidData` —
    /// callers treat that as "no cache" and fall back to measuring.
    /// Returns the number of rows inserted.
    pub fn load_into(&self, path: &Path) -> io::Result<usize> {
        let text = std::fs::read_to_string(path)?;
        let corrupt = |what: &str, line: usize| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("tune cache {}: {} at line {}", path.display(), what, line),
            )
        };
        let mut lines = text.lines().enumerate();
        match lines.next() {
            Some((_, h)) if h.trim() == TUNE_CACHE_HEADER => {}
            _ => return Err(corrupt("bad or missing version header", 1)),
        }
        let mut rows = 0usize;
        for (i, line) in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 13 {
                return Err(corrupt("wrong field count", i + 1));
            }
            let num = |s: &str| s.parse::<usize>().ok();
            let (Some(m), Some(n), Some(k)) = (num(f[0]), num(f[1]), num(f[2])) else {
                return Err(corrupt("unparsable shape", i + 1));
            };
            let Some(dtype) = TuneDtype::from_str_opt(f[3]) else {
                return Err(corrupt("unknown dtype", i + 1));
            };
            let Some(epi) = TuneEpi::from_str_opt(f[4]) else {
                return Err(corrupt("unknown epilogue", i + 1));
            };
            let Some(panel) = TunePanel::from_str_opt(f[5]) else {
                return Err(corrupt("unknown panel class", i + 1));
            };
            let (Some(mr), Some(nr), Some(mc), Some(kc), Some(nc)) =
                (num(f[6]), num(f[7]), num(f[8]), num(f[9]), num(f[10]))
            else {
                return Err(corrupt("unparsable variant", i + 1));
            };
            if mr == 0 || nr == 0 || mc % mr != 0 || nc % nr != 0 || kc == 0 {
                return Err(corrupt("inconsistent variant blocking", i + 1));
            }
            let (Ok(chosen_ms), Ok(default_ms)) = (f[11].parse::<f64>(), f[12].parse::<f64>())
            else {
                return Err(corrupt("unparsable timing", i + 1));
            };
            self.insert(
                TuneKey { m, n, k, dtype, epi, panel },
                TuneChoice {
                    variant: GemmVariant { mr, nr, block: BlockCfg { mc, kc, nc } },
                    chosen_ms,
                    default_ms,
                    measured: true,
                },
            );
            rows += 1;
        }
        Ok(rows)
    }

    fn measure_class(&self, key: TuneKey) -> TuneChoice {
        let default_v = heuristic_variant(key.dtype);
        let flops =
            2usize.saturating_mul(key.m).saturating_mul(key.n).saturating_mul(key.k);
        // a DFT class replays the full complex product — four GEMMs of
        // the shape — so its measurement cost is 4× the nominal flops
        let flops = if key.panel == TunePanel::DftPacked {
            flops.saturating_mul(4)
        } else {
            flops
        };
        if key.m == 0 || key.n == 0 || key.k == 0 || flops > MEASURE_FLOP_CAP {
            let (chosen_ms, default_ms) = (0.0, 0.0);
            return TuneChoice { variant: default_v, chosen_ms, default_ms, measured: false };
        }
        self.measures.fetch_add(1, Ordering::Relaxed);
        let (m, n, k) = (key.m, key.n, key.k);
        // synthetic operands: deterministic, value-independent for speed
        // (timing depends only on shape), measured serially so the search
        // never fights the serving pool for cores
        let timings: Vec<(GemmVariant, f64)> = match key.dtype {
            TuneDtype::F32 if key.panel == TunePanel::DftPacked => {
                // the `dft_gemm` step is four f32 GEMMs over pre-packed
                // coefficient panels (re/im), the last two fused with the
                // `DftCombine` writeback — measure exactly that
                // structure. Packing stays outside the timed region:
                // panels are compile-time artifacts pinned in the plan,
                // and their geometry (`nr`, `kc`) follows the candidate.
                let xr = fill_f32(m * k, 0x5eed_0007);
                let xi = fill_f32(m * k, 0x5eed_0008);
                let fr = fill_f32(k * n, 0x5eed_0009);
                let fi = fill_f32(k * n, 0x5eed_000a);
                let mut t_ii = vec![0f32; m * n];
                let mut t_ir = vec![0f32; m * n];
                let mut out_re = vec![0f32; m * n];
                let mut out_im = vec![0f32; m * n];
                let mut scratch = GemmScratch::new();
                GemmVariant::f32_candidates()
                    .into_iter()
                    .map(|v| {
                        let panels = DftPanels::pack(&fr, &fi, k, n, v.nr, v.block.kc);
                        let ms = time_ms(|| {
                            gemm_f32_tuned_into(
                                &mut t_ii,
                                &xi,
                                PanelB::Packed(&panels.im),
                                m,
                                n,
                                k,
                                Accum::F64,
                                Epilogue::None,
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                            gemm_f32_tuned_into(
                                &mut t_ir,
                                &xi,
                                PanelB::Packed(&panels.re),
                                m,
                                n,
                                k,
                                Accum::F64,
                                Epilogue::None,
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                            gemm_f32_tuned_into(
                                &mut out_re,
                                &xr,
                                PanelB::Packed(&panels.re),
                                m,
                                n,
                                k,
                                Accum::F64,
                                Epilogue::DftCombine { other: &t_ii, sub: true },
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                            gemm_f32_tuned_into(
                                &mut out_im,
                                &xr,
                                PanelB::Packed(&panels.im),
                                m,
                                n,
                                k,
                                Accum::F64,
                                Epilogue::DftCombine { other: &t_ir, sub: false },
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                        });
                        (v, ms)
                    })
                    .collect()
            }
            TuneDtype::F32 => {
                let a = fill_f32(m * k, 0x5eed_0001);
                let b = fill_f32(k * n, 0x5eed_0002);
                // im2col classes measure through the *gather* panel
                // source (a synthetic k-row spec over a k×n image, one
                // base per row), so the timing reflects im2col packing
                // cost rather than the contiguous-matrix memcpy
                let spec = Im2colSpec { bases: (0..k).map(|p| p * n).collect(), img_w: n, out_w: n };
                let mut c = vec![0f32; m * n];
                let mut scratch = GemmScratch::new();
                GemmVariant::f32_candidates()
                    .into_iter()
                    .map(|v| {
                        let ms = time_ms(|| {
                            let src = match key.panel {
                                TunePanel::Im2col => PanelB::Im2col { img: &b, spec: &spec },
                                _ => PanelB::Matrix(&b),
                            };
                            gemm_f32_tuned_into(
                                &mut c,
                                &a,
                                src,
                                m,
                                n,
                                k,
                                Accum::F64,
                                Epilogue::None,
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                        });
                        (v, ms)
                    })
                    .collect()
            }
            TuneDtype::Bf16 => {
                let a = fill_f32(m * k, 0x5eed_0003);
                let b = fill_f32(k * n, 0x5eed_0004);
                let mut c = vec![0f32; m * n];
                let mut scratch = Bf16Scratch::new();
                GemmVariant::wide_candidates()
                    .into_iter()
                    .map(|v| {
                        let ms = time_ms(|| {
                            gemm_bf16_tuned_into(
                                &mut c,
                                Bf16Src::F32(&a),
                                Bf16Src::F32(&b),
                                m,
                                n,
                                k,
                                Bf16Accum::Widened,
                                Epilogue::None,
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                        });
                        (v, ms)
                    })
                    .collect()
            }
            TuneDtype::I8 => {
                let a = fill_i8(m * k, 0x5eed_0005);
                let b = fill_u8(k * n, 0x5eed_0006);
                let mut c = vec![0i32; m * n];
                let mut scratch = I8Scratch::new();
                GemmVariant::wide_candidates()
                    .into_iter()
                    .map(|v| {
                        let ms = time_ms(|| {
                            gemm_i8_packed_tuned_into(
                                &mut c,
                                I8SrcA::Q(&a),
                                I8SrcB::Q(&b),
                                m,
                                n,
                                k,
                                I8Accum::Wrapping,
                                Par::Seq,
                                &mut scratch,
                                v,
                            );
                        });
                        (v, ms)
                    })
                    .collect()
            }
        };
        // argmin with strict `<`: ties keep the earlier candidate, and
        // the head is canonical — so chosen_ms <= default_ms always
        let default_ms = timings[0].1;
        let mut best = timings[0];
        for &t in &timings[1..] {
            if t.1 < best.1 {
                best = t;
            }
        }
        TuneChoice { variant: best.0, chosen_ms: best.1, default_ms, measured: true }
    }
}

/// Minimum of [`MEASURE_REPS`] timed runs after one untimed warmup, in
/// milliseconds.
fn time_ms(mut run: impl FnMut()) -> f64 {
    run();
    let mut best = f64::INFINITY;
    for _ in 0..MEASURE_REPS {
        let t0 = Instant::now();
        run();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    best
}

fn lcg(state: &mut u32) -> u32 {
    *state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
    *state
}

fn fill_f32(len: usize, seed: u32) -> Vec<f32> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 8) as f32 / (1u32 << 24) as f32 - 0.5).collect()
}

fn fill_i8(len: usize, seed: u32) -> Vec<i8> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 16) as i8).collect()
}

fn fill_u8(len: usize, seed: u32) -> Vec<u8> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 16) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize, n: usize, k: usize, dtype: TuneDtype) -> TuneKey {
        TuneKey { m, n, k, dtype, epi: TuneEpi::None, panel: TunePanel::Matrix }
    }

    #[test]
    fn first_sight_measures_and_memoizes() {
        let table = TuneTable::new();
        let k1 = key(8, 16, 16, TuneDtype::F32);
        let c1 = table.choose(k1);
        assert!(c1.measured);
        assert!(c1.chosen_ms <= c1.default_ms, "ties must keep the canonical head");
        assert_eq!(table.measure_count(), 1);
        assert_eq!(table.len(), 1);
        // second sight: memoized, no re-measure, identical row
        let c2 = table.choose(k1);
        assert_eq!(table.measure_count(), 1);
        assert_eq!(c2.variant, c1.variant);
        assert_eq!(c2.chosen_ms.to_bits(), c1.chosen_ms.to_bits());
    }

    #[test]
    fn classes_above_the_flop_cap_take_the_heuristic() {
        let table = TuneTable::new();
        for dtype in [TuneDtype::F32, TuneDtype::Bf16, TuneDtype::I8] {
            let c = table.choose(key(512, 512, 512, dtype));
            assert!(!c.measured, "{dtype:?}");
            assert_eq!(c.variant, heuristic_variant(dtype));
            assert_eq!(c.chosen_ms, 0.0);
        }
        assert_eq!(table.measure_count(), 0);
        // degenerate shapes also never measure
        let c = table.choose(key(0, 8, 8, TuneDtype::F32));
        assert!(!c.measured);
        assert_eq!(table.measure_count(), 0);
    }

    #[test]
    fn preseeded_rows_are_honored_verbatim() {
        let table = TuneTable::new();
        let k1 = key(4, 8, 8, TuneDtype::Bf16);
        let forced = GemmVariant::wide_candidates()[3];
        table.insert(
            k1,
            TuneChoice { variant: forced, chosen_ms: 1.0, default_ms: 2.0, measured: true },
        );
        let c = table.choose(k1);
        assert_eq!(c.variant, forced);
        assert_eq!(table.measure_count(), 0, "pre-seeded classes never measure");
        assert_eq!(table.lookup(k1).unwrap().variant, forced);
        assert!(table.lookup(key(9, 9, 9, TuneDtype::F32)).is_none());
    }

    #[test]
    fn snapshot_is_deterministically_ordered() {
        let table = TuneTable::new();
        let keys = [
            key(2, 2, 1024 * 1024 * 16, TuneDtype::I8),
            key(1, 8, 8, TuneDtype::F32),
            TuneKey {
                m: 1,
                n: 8,
                k: 8,
                dtype: TuneDtype::F32,
                epi: TuneEpi::BiasRelu,
                panel: TunePanel::Matrix,
            },
            key(2, 2, 1024 * 1024 * 16, TuneDtype::Bf16),
        ];
        for k in keys {
            table.choose(k);
        }
        let rows = table.snapshot();
        assert_eq!(rows.len(), 4);
        let idx: Vec<_> = rows.iter().map(|(k, _)| k.sort_idx()).collect();
        let mut sorted = idx.clone();
        sorted.sort();
        assert_eq!(idx, sorted);
        assert_eq!(rows[0].0.dtype, TuneDtype::F32);
        assert_eq!(rows[0].0.epi, TuneEpi::None);
        assert_eq!(rows[1].0.epi, TuneEpi::BiasRelu);
    }

    #[test]
    fn im2col_classes_are_keyed_and_measured_separately() {
        let table = TuneTable::new();
        let km = key(8, 9, 12, TuneDtype::F32);
        let kc = TuneKey { panel: TunePanel::Im2col, ..km };
        let cm = table.choose(km);
        let cc = table.choose(kc);
        assert!(cm.measured && cc.measured);
        assert_eq!(table.len(), 2, "same shape, distinct modality rows");
        assert_eq!(table.measure_count(), 2);
        // memoized independently
        table.choose(kc);
        assert_eq!(table.measure_count(), 2);
        // deterministic order puts matrix before im2col at equal shape
        let rows = table.snapshot();
        assert_eq!(rows[0].0.panel, TunePanel::Matrix);
        assert_eq!(rows[1].0.panel, TunePanel::Im2col);
    }

    #[test]
    fn heuristic_matches_the_canonical_engines() {
        assert_eq!(heuristic_variant(TuneDtype::F32), GemmVariant::CANONICAL_F32);
        assert_eq!(heuristic_variant(TuneDtype::Bf16), GemmVariant::CANONICAL_WIDE);
        assert_eq!(heuristic_variant(TuneDtype::I8), GemmVariant::CANONICAL_WIDE);
    }
}
