//! Native HLO-text parser and interpreter — the engine behind the serving
//! runtime since the external PJRT/XLA FFI was excised.
//!
//! `python/compile/aot.py` lowers the jnp serving graphs of
//! `python/compile/model.py` to HLO **text**, a stable, human-auditable
//! grammar.  The graphs use a closed op set —
//!
//! > `parameter`, `constant`, `convert` (f32↔bf16), `dot`, `add`,
//! > `multiply`, `maximum`, `broadcast`, `reshape`, `slice`, `tuple`
//!
//! — which this module parses into an [`HloModule`] and evaluates with
//! [`HloModule::evaluate`].  `dot` executes over the crate's own BLAS
//! substrate ([`crate::blas::gemm::ref_gemm`]), so the whole request path
//! is self-hosted: Pallas → JAX → HLO text → this interpreter → `blas`.
//! The bf16 `convert` reproduces the `xvbf16ger2` input contract
//! (round-to-nearest-even to bf16, accumulate wide) via [`bf16_round`].
//!
//! The parser is strict where numerics depend on it (shapes, operand
//! resolution, attribute values) and tolerant elsewhere (layout
//! annotations `{1,0}` are ignored: literals are logical row-major on
//! both the python and rust side; non-entry computations are skipped —
//! executing one would need `call`, which is outside the op set and
//! rejected at evaluation).

use crate::blas::gemm::ref_gemm;
use crate::error::Result;
use crate::{bail, err};
use std::collections::HashMap;

/// Element type of an HLO value. Tensors are stored as `f32` regardless
/// (`Bf16` values are f32 already rounded onto the bf16 grid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    Bf16,
    /// A tuple-shaped value (only the ROOT tuple in practice).
    Tuple,
    /// Anything else (`pred`, `s32`, …): parseable, rejected at evaluate.
    Other,
}

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// One parsed HLO instruction of the entry computation. Shared with the
/// plan compiler ([`super::plan`]), which lowers the same instruction
/// list into a preallocated execution plan.
#[derive(Clone, Debug)]
pub(crate) struct Instr {
    pub(crate) name: String,
    pub(crate) opcode: String,
    pub(crate) dtype: DType,
    pub(crate) dims: Vec<usize>,
    /// Operand indices into the instruction list (resolved after parse).
    pub(crate) operands: Vec<usize>,
    /// `parameter(N)` index.
    pub(crate) param: usize,
    /// `dimensions={…}` attribute (broadcast).
    pub(crate) dims_attr: Option<Vec<usize>>,
    /// `lhs_contracting_dims={…}` / `rhs_contracting_dims={…}` (dot).
    pub(crate) lhs_contracting: Option<usize>,
    pub(crate) rhs_contracting: Option<usize>,
    /// `slice={[start:stop(:stride)], …}` attribute.
    pub(crate) slice_bounds: Option<Vec<(usize, usize, usize)>>,
    /// Literal payload of `constant(…)`.
    pub(crate) const_vals: Vec<f32>,
    pub(crate) is_root: bool,
}

/// A parsed HLO module: the entry computation as a topologically-ordered
/// instruction list (HLO text is SSA and defines before use).
#[derive(Debug)]
pub struct HloModule {
    /// Module name from the `HloModule` header line.
    pub name: String,
    pub(crate) instrs: Vec<Instr>,
    /// Number of distinct `parameter(N)` instructions.
    num_params: usize,
}

/// Round an f32 to the nearest bf16 value (round-to-nearest-even), kept
/// in f32 — the `xvbf16ger2` input contract and XLA's `convert` to bf16
/// (NaNs collapse to the sign-preserved canonical quiet NaN). A thin
/// wrapper over the crate's single f32→bf16 rounding source,
/// [`crate::isa::types::f32_to_bf16_canonical`].
pub fn bf16_round(x: f32) -> f32 {
    crate::isa::types::bf16_to_f32(crate::isa::types::f32_to_bf16_canonical(x))
}

/// Parse `f32[128,128]{1,0}` / `bf16[8]{0}` / `f32[]` into dtype + dims.
/// The layout annotation is ignored (values are logical row-major).
fn parse_plain_shape(s: &str) -> Result<(DType, Vec<usize>)> {
    let lb = s.find('[').ok_or_else(|| err!("shape without dimensions: '{s}'"))?;
    let dtype = match &s[..lb] {
        "f32" => DType::F32,
        "bf16" => DType::Bf16,
        _ => DType::Other,
    };
    let rb = s[lb..]
        .find(']')
        .map(|i| i + lb)
        .ok_or_else(|| err!("unterminated shape: '{s}'"))?;
    let inner = &s[lb + 1..rb];
    let mut dims = Vec::new();
    if !inner.trim().is_empty() {
        for d in inner.split(',') {
            let d = d.trim();
            dims.push(d.parse::<usize>().map_err(|_| err!("bad dimension '{d}' in '{s}'"))?);
        }
    }
    Ok((dtype, dims))
}

/// Extract the ints of a `key={a,b,…}` attribute (`Some(vec![])` for
/// `key={}`); `None` when the key is absent.
fn braced_list(attrs: &str, key: &str) -> Result<Option<Vec<usize>>> {
    let tag = format!("{key}={{");
    let Some(i) = attrs.find(tag.as_str()) else {
        return Ok(None);
    };
    let rest = &attrs[i + tag.len()..];
    let j = rest.find('}').ok_or_else(|| err!("unterminated {key} attribute"))?;
    let mut out = Vec::new();
    for t in rest[..j].split(',') {
        let t = t.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse::<usize>().map_err(|_| err!("bad {key} entry '{t}'"))?);
    }
    Ok(Some(out))
}

/// Parse the `slice={[0:8], [1:2]}` attribute (optional `[a:b:stride]`).
fn parse_slice_attr(attrs: &str) -> Result<Option<Vec<(usize, usize, usize)>>> {
    let tag = "slice={";
    let Some(i) = attrs.find(tag) else {
        return Ok(None);
    };
    let rest = &attrs[i + tag.len()..];
    let j = rest.find('}').ok_or_else(|| err!("unterminated slice attribute"))?;
    let mut inner = &rest[..j];
    let mut out = Vec::new();
    while let Some(a) = inner.find('[') {
        let b = inner[a..]
            .find(']')
            .map(|k| k + a)
            .ok_or_else(|| err!("unterminated slice bound"))?;
        let parts: Vec<&str> = inner[a + 1..b].split(':').collect();
        if parts.len() < 2 || parts.len() > 3 {
            bail!("bad slice bound '[{}]'", &inner[a + 1..b]);
        }
        let parse = |t: &str| {
            t.trim().parse::<usize>().map_err(|_| err!("bad slice number '{}'", t.trim()))
        };
        let start = parse(parts[0])?;
        let stop = parse(parts[1])?;
        let stride = if parts.len() == 3 { parse(parts[2])? } else { 1 };
        if stride == 0 {
            bail!("zero slice stride");
        }
        out.push((start, stop, stride));
        inner = &inner[b + 1..];
    }
    Ok(Some(out))
}

/// Parse the payload of `constant(…)`: a scalar (`0`, `-1.5e-3`, `inf`)
/// or a braced list (`{1, 2, 3}`, nested braces for higher rank).
fn parse_constant(args: &str) -> Result<Vec<f32>> {
    let cleaned: String =
        args.chars().map(|c| if c == '{' || c == '}' || c == ',' { ' ' } else { c }).collect();
    let mut out = Vec::new();
    for tok in cleaned.split_whitespace() {
        let v = match tok {
            "inf" => f32::INFINITY,
            "-inf" => f32::NEG_INFINITY,
            "nan" | "-nan" => f32::NAN,
            "true" => 1.0,
            "false" => 0.0,
            _ => tok.parse::<f32>().map_err(|_| err!("bad constant literal '{tok}'"))?,
        };
        out.push(v);
    }
    Ok(out)
}

impl HloModule {
    /// Parse HLO text into the entry computation.
    pub fn parse(text: &str) -> Result<HloModule> {
        let mut module_name = String::new();
        let mut instrs: Vec<Instr> = Vec::new();
        let mut names: Vec<String> = Vec::new(); // operand names, pre-resolution
        let mut operand_names: Vec<Vec<String>> = Vec::new();
        let mut in_entry = false;
        let mut saw_entry = false;

        for raw in text.lines() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("HloModule ") {
                module_name =
                    rest.split(|c: char| c == ',' || c == ' ').next().unwrap_or("").to_string();
                continue;
            }
            if line.ends_with('{') && !line.contains(" = ") {
                // computation header: `ENTRY main.5 {` or `region_0.49 {`
                in_entry = line.starts_with("ENTRY");
                saw_entry = saw_entry || in_entry;
                continue;
            }
            if line == "}" {
                in_entry = false;
                continue;
            }
            if !in_entry {
                continue;
            }

            // instruction: `[ROOT ]name = shape opcode(args)[, attrs]`
            let (is_root, line) = match line.strip_prefix("ROOT ") {
                Some(rest) => (true, rest),
                None => (false, line),
            };
            let eq = line.find(" = ").ok_or_else(|| err!("bad HLO line: '{line}'"))?;
            let name = line[..eq].to_string();
            let rest = &line[eq + 3..];

            // result shape: `(tuple, of, shapes)` or a plain token
            let (dtype, dims, rest) = if let Some(stripped) = rest.strip_prefix('(') {
                let close =
                    stripped.find(')').ok_or_else(|| err!("unterminated tuple shape: '{rest}'"))?;
                (DType::Tuple, Vec::new(), stripped[close + 1..].trim_start())
            } else {
                let sp = rest.find(' ').ok_or_else(|| err!("missing opcode: '{rest}'"))?;
                let (dt, dims) = parse_plain_shape(&rest[..sp])?;
                (dt, dims, &rest[sp + 1..])
            };

            // `opcode(args)` — constant payloads never contain parentheses
            let lp = rest.find('(').ok_or_else(|| err!("missing operand list: '{rest}'"))?;
            let opcode = rest[..lp].trim().to_string();
            let rp = rest[lp..]
                .find(')')
                .map(|i| i + lp)
                .ok_or_else(|| err!("unterminated operand list: '{rest}'"))?;
            let args = &rest[lp + 1..rp];
            let attrs = &rest[rp + 1..];

            let mut param = 0usize;
            let mut const_vals = Vec::new();
            let mut ops = Vec::new();
            match opcode.as_str() {
                "parameter" => {
                    param = args
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| err!("bad parameter index '{args}'"))?;
                }
                "constant" => {
                    const_vals = parse_constant(args)?;
                }
                _ => {
                    ops = args
                        .split(',')
                        .map(|a| a.trim().to_string())
                        .filter(|a| !a.is_empty())
                        .collect();
                }
            }

            let lhs_c = braced_list(attrs, "lhs_contracting_dims")?.map(|v| v.first().copied());
            let rhs_c = braced_list(attrs, "rhs_contracting_dims")?.map(|v| v.first().copied());
            instrs.push(Instr {
                name: name.clone(),
                opcode,
                dtype,
                dims,
                operands: Vec::new(),
                param,
                dims_attr: braced_list(attrs, "dimensions")?,
                lhs_contracting: lhs_c.flatten(),
                rhs_contracting: rhs_c.flatten(),
                slice_bounds: parse_slice_attr(attrs)?,
                const_vals,
                is_root,
            });
            names.push(name);
            operand_names.push(ops);
        }

        if !saw_entry {
            bail!("no ENTRY computation found (not HLO text?)");
        }
        if instrs.is_empty() {
            bail!("empty ENTRY computation");
        }

        // resolve operand names -> indices (defs precede uses in HLO text)
        let index: HashMap<&str, usize> =
            names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        let mut num_params = 0usize;
        for (i, ops) in operand_names.iter().enumerate() {
            for op in ops {
                let Some(&j) = index.get(op.as_str()) else {
                    bail!("instruction {} references unknown operand '{op}'", instrs[i].name);
                };
                if j >= i {
                    bail!("instruction {} uses '{op}' before its definition", instrs[i].name);
                }
                instrs[i].operands.push(j);
            }
            if instrs[i].opcode == "parameter" {
                num_params = num_params.max(instrs[i].param + 1);
            }
        }
        if !instrs.iter().any(|i| i.is_root) {
            bail!("entry computation has no ROOT instruction");
        }

        Ok(HloModule { name: module_name, instrs, num_params })
    }

    /// Number of entry parameters (`parameter(N)` max index + 1).
    pub fn num_parameters(&self) -> usize {
        self.num_params
    }

    /// Instruction count of the entry computation.
    pub fn num_instructions(&self) -> usize {
        self.instrs.len()
    }

    /// Logical dims of parameter `i`, if that parameter exists.
    pub fn parameter_dims(&self, i: usize) -> Option<&[usize]> {
        self.instrs
            .iter()
            .find(|ins| ins.opcode == "parameter" && ins.param == i)
            .map(|ins| ins.dims.as_slice())
    }

    /// Evaluate the entry computation on flat row-major f32 inputs.
    /// Returns the ROOT tuple elements (a 1-element vec for scalar roots).
    pub fn evaluate(&self, inputs: &[&[f32]]) -> Result<Vec<Tensor>> {
        let mut vals: Vec<Option<Tensor>> = vec![None; self.instrs.len()];
        let mut root: Option<Vec<usize>> = None;

        fn get<'a>(vals: &'a [Option<Tensor>], idx: usize, user: &str) -> Result<&'a Tensor> {
            vals[idx]
                .as_ref()
                .ok_or_else(|| err!("{user}: operand not evaluated (tuple operand?)"))
        }

        for (i, ins) in self.instrs.iter().enumerate() {
            if ins.dtype == DType::Other {
                bail!("{}: unsupported element type", ins.name);
            }
            let need = match ins.opcode.as_str() {
                "dot" | "add" | "multiply" | "maximum" => 2,
                "convert" | "reshape" | "broadcast" | "slice" => 1,
                _ => 0,
            };
            if ins.operands.len() < need {
                bail!(
                    "{}: {} needs {need} operand(s), got {}",
                    ins.name,
                    ins.opcode,
                    ins.operands.len()
                );
            }
            let want: usize = ins.dims.iter().product();

            let out = match ins.opcode.as_str() {
                "parameter" => {
                    let data = *inputs
                        .get(ins.param)
                        .ok_or_else(|| err!("{}: missing input {}", ins.name, ins.param))?;
                    if data.len() != want {
                        bail!(
                            "{}: input {} has {} elements, shape wants {want}",
                            ins.name,
                            ins.param,
                            data.len()
                        );
                    }
                    Tensor { dims: ins.dims.clone(), data: data.to_vec() }
                }
                "constant" => {
                    if ins.const_vals.len() != want {
                        bail!(
                            "{}: constant has {} literals, shape wants {want}",
                            ins.name,
                            ins.const_vals.len()
                        );
                    }
                    Tensor { dims: ins.dims.clone(), data: ins.const_vals.clone() }
                }
                "convert" => {
                    let src = get(&vals, ins.operands[0], &ins.name)?;
                    if src.data.len() != want {
                        bail!(
                            "{}: convert operand has {} elements, shape wants {want}",
                            ins.name,
                            src.data.len()
                        );
                    }
                    let data = match ins.dtype {
                        DType::Bf16 => src.data.iter().map(|&v| bf16_round(v)).collect(),
                        _ => src.data.clone(),
                    };
                    Tensor { dims: ins.dims.clone(), data }
                }
                "dot" => {
                    let a = get(&vals, ins.operands[0], &ins.name)?;
                    let b = get(&vals, ins.operands[1], &ins.name)?;
                    self.eval_dot(ins, a, b)?
                }
                "add" | "multiply" | "maximum" => {
                    let a = get(&vals, ins.operands[0], &ins.name)?;
                    let b = get(&vals, ins.operands[1], &ins.name)?;
                    if a.dims != b.dims || a.dims != ins.dims {
                        bail!(
                            "{}: elementwise shape mismatch {:?} vs {:?} -> {:?}",
                            ins.name,
                            a.dims,
                            b.dims,
                            ins.dims
                        );
                    }
                    let f: fn(f32, f32) -> f32 = match ins.opcode.as_str() {
                        "add" => |x, y| x + y,
                        "multiply" => |x, y| x * y,
                        _ => f32::max,
                    };
                    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
                    Tensor { dims: ins.dims.clone(), data }
                }
                "broadcast" => {
                    let src = get(&vals, ins.operands[0], &ins.name)?;
                    self.eval_broadcast(ins, src)?
                }
                "reshape" => {
                    let src = get(&vals, ins.operands[0], &ins.name)?;
                    if src.data.len() != want {
                        bail!(
                            "{}: reshape {:?} -> {:?} changes element count",
                            ins.name,
                            src.dims,
                            ins.dims
                        );
                    }
                    Tensor { dims: ins.dims.clone(), data: src.data.clone() }
                }
                "slice" => {
                    let src = get(&vals, ins.operands[0], &ins.name)?;
                    self.eval_slice(ins, src)?
                }
                "tuple" => {
                    if ins.is_root {
                        root = Some(ins.operands.clone());
                    }
                    // placeholder value: tuples are only consumed as ROOT
                    Tensor { dims: Vec::new(), data: Vec::new() }
                }
                other => bail!(
                    "{}: unsupported HLO opcode '{other}' (the serving op set is \
                     parameter/constant/convert/dot/add/multiply/maximum/broadcast/\
                     reshape/slice/tuple)",
                    ins.name
                ),
            };

            if ins.is_root && ins.opcode != "tuple" {
                root = Some(vec![i]);
            }
            vals[i] = Some(out);
        }

        let root = root.ok_or_else(|| err!("no ROOT value produced"))?;
        let mut out = Vec::with_capacity(root.len());
        for idx in root {
            // clone, not take: a ROOT tuple may reference one value twice
            out.push(
                vals[idx]
                    .clone()
                    .ok_or_else(|| err!("ROOT references unevaluated instruction"))?,
            );
        }
        Ok(out)
    }

    /// `dot` over the BLAS substrate: `[m,k] × [k,n]` with contracting
    /// dims `{1}`/`{0}` (what jnp.dot lowers to), f64 accumulation via
    /// [`ref_gemm`] — wider than XLA's f32 path, within every artifact
    /// tolerance.
    fn eval_dot(&self, ins: &Instr, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        if a.dims.len() != 2 || b.dims.len() != 2 {
            bail!("{}: only rank-2 dot supported, got {:?} x {:?}", ins.name, a.dims, b.dims);
        }
        if ins.lhs_contracting != Some(1) || ins.rhs_contracting != Some(0) {
            bail!(
                "{}: only lhs_contracting_dims={{1}} rhs_contracting_dims={{0}} supported",
                ins.name
            );
        }
        let (m, k) = (a.dims[0], a.dims[1]);
        let (k2, n) = (b.dims[0], b.dims[1]);
        if k != k2 {
            bail!("{}: contraction mismatch {k} vs {k2}", ins.name);
        }
        if ins.dims != [m, n] {
            bail!("{}: dot result shape {:?} != [{m},{n}]", ins.name, ins.dims);
        }
        let af: Vec<f64> = a.data.iter().map(|&v| f64::from(v)).collect();
        let bf: Vec<f64> = b.data.iter().map(|&v| f64::from(v)).collect();
        let c = ref_gemm(&af, &bf, m, n, k);
        Ok(Tensor { dims: vec![m, n], data: c.iter().map(|&v| v as f32).collect() })
    }

    /// `broadcast(src), dimensions={…}`: `dimensions[ax]` names the output
    /// dim that source axis `ax` maps to; all other output dims replicate.
    fn eval_broadcast(&self, ins: &Instr, src: &Tensor) -> Result<Tensor> {
        let dims_attr = ins.dims_attr.clone().unwrap_or_default();
        if dims_attr.len() != src.dims.len() {
            bail!(
                "{}: broadcast dimensions {:?} do not match source rank {}",
                ins.name,
                dims_attr,
                src.dims.len()
            );
        }
        let nd = ins.dims.len();
        let mut ostrides = vec![1usize; nd];
        for d in (0..nd.saturating_sub(1)).rev() {
            ostrides[d] = ostrides[d + 1] * ins.dims[d + 1];
        }
        let snd = src.dims.len();
        let mut sstrides = vec![1usize; snd];
        for d in (0..snd.saturating_sub(1)).rev() {
            sstrides[d] = sstrides[d + 1] * src.dims[d + 1];
        }
        // contribution of each output dim to the source flat index
        let mut contrib = vec![0usize; nd];
        for (ax, &d) in dims_attr.iter().enumerate() {
            if d >= nd {
                bail!("{}: broadcast dimension {d} out of range", ins.name);
            }
            if src.dims[ax] != ins.dims[d] {
                bail!(
                    "{}: broadcast source dim {ax} ({}) != output dim {d} ({})",
                    ins.name,
                    src.dims[ax],
                    ins.dims[d]
                );
            }
            contrib[d] = sstrides[ax];
        }
        let total: usize = ins.dims.iter().product();
        let mut data = vec![0f32; total];
        for (flat, slot) in data.iter_mut().enumerate() {
            let mut src_flat = 0usize;
            for d in 0..nd {
                src_flat += (flat / ostrides[d]) % ins.dims[d] * contrib[d];
            }
            *slot = src.data[src_flat];
        }
        Ok(Tensor { dims: ins.dims.clone(), data })
    }

    /// `slice(src), slice={[a:b(:s)], …}` — one bound per source dim.
    fn eval_slice(&self, ins: &Instr, src: &Tensor) -> Result<Tensor> {
        let bounds = ins
            .slice_bounds
            .as_ref()
            .ok_or_else(|| err!("{}: slice without slice attribute", ins.name))?;
        if bounds.len() != src.dims.len() {
            bail!(
                "{}: {} slice bounds for rank-{} source",
                ins.name,
                bounds.len(),
                src.dims.len()
            );
        }
        let nd = src.dims.len();
        let mut out_dims = Vec::with_capacity(nd);
        for (d, &(start, stop, stride)) in bounds.iter().enumerate() {
            if start > stop || stop > src.dims[d] {
                bail!(
                    "{}: slice bound [{start}:{stop}] out of range for dim {d} ({})",
                    ins.name,
                    src.dims[d]
                );
            }
            out_dims.push((stop - start).div_ceil(stride));
        }
        if out_dims != ins.dims {
            bail!("{}: slice result {:?} != declared {:?}", ins.name, out_dims, ins.dims);
        }
        let mut sstrides = vec![1usize; nd];
        for d in (0..nd.saturating_sub(1)).rev() {
            sstrides[d] = sstrides[d + 1] * src.dims[d + 1];
        }
        let mut ostrides = vec![1usize; nd];
        for d in (0..nd.saturating_sub(1)).rev() {
            ostrides[d] = ostrides[d + 1] * out_dims[d + 1];
        }
        let total: usize = out_dims.iter().product();
        let mut data = vec![0f32; total];
        for (flat, slot) in data.iter_mut().enumerate() {
            let mut src_flat = 0usize;
            for d in 0..nd {
                let idx = (flat / ostrides[d]) % out_dims[d];
                src_flat += (bounds[d].0 + idx * bounds[d].2) * sstrides[d];
            }
            *slot = src.data[src_flat];
        }
        Ok(Tensor { dims: out_dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_allclose_f32;

    const TINY: &str = r#"
HloModule jit_tiny, entry_computation_layout={(f32[2,3]{1,0}, f32[3,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.5 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.4 = (f32[2,2]{1,0}) tuple(dot.3)
}
"#;

    #[test]
    fn parses_and_runs_a_dot_module() {
        let m = HloModule::parse(TINY).unwrap();
        assert_eq!(m.name, "jit_tiny");
        assert_eq!(m.num_parameters(), 2);
        assert_eq!(m.num_instructions(), 4);
        assert_eq!(m.parameter_dims(0), Some(&[2usize, 3][..]));
        let a = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let b = [1f32, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3x2
        let out = m.evaluate(&[&a, &b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![2, 2]);
        // [[1+3, 2+3], [4+6, 5+6]]
        assert_eq!(out[0].data, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn relu_bias_graph_with_broadcast_and_constant() {
        let text = r#"
HloModule jit_relu

ENTRY main.9 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.2 = f32[2]{0} parameter(1)
  broadcast.3 = f32[2,2]{1,0} broadcast(Arg_1.2), dimensions={1}
  add.4 = f32[2,2]{1,0} add(Arg_0.1, broadcast.3)
  constant.5 = f32[] constant(0)
  broadcast.6 = f32[2,2]{1,0} broadcast(constant.5), dimensions={}
  ROOT maximum.7 = f32[2,2]{1,0} maximum(add.4, broadcast.6)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let x = [1f32, -5.0, -1.0, 2.0];
        let bias = [0.5f32, 1.0];
        let out = m.evaluate(&[&x, &bias]).unwrap();
        assert_eq!(out.len(), 1, "non-tuple ROOT yields one output");
        assert_eq!(out[0].data, vec![1.5, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn slice_and_reshape_and_multiply() {
        let text = r#"
HloModule jit_slices

ENTRY main.7 {
  Arg_0.1 = f32[2,4]{1,0} parameter(0)
  slice.2 = f32[2,2]{1,0} slice(Arg_0.1), slice={[0:2], [1:3]}
  reshape.3 = f32[4]{0} reshape(slice.2)
  slice.4 = f32[2,2]{1,0} slice(Arg_0.1), slice={[0:2], [0:4:2]}
  reshape.5 = f32[4]{0} reshape(slice.4)
  ROOT multiply.6 = f32[4]{0} multiply(reshape.3, reshape.5)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let x = [0f32, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let out = m.evaluate(&[&x]).unwrap();
        // slice a = [[1,2],[5,6]]; strided slice b = [[0,2],[4,6]]
        assert_eq!(out[0].data, vec![0.0, 4.0, 20.0, 36.0]);
    }

    #[test]
    fn bf16_round_matches_known_values() {
        // 1.0 and short dyadics are exact in bf16
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-2.5), -2.5);
        // bf16 spacing at 1.0 is 2^-7 (7 stored significand bits)
        let step = f32::powi(2.0, -7);
        assert_eq!(bf16_round(1.0 + 0.5 * step), 1.0, "halfway rounds to even (down)");
        assert_eq!(bf16_round(1.0 + 1.5 * step), 1.0 + 2.0 * step, "halfway rounds to even (up)");
        assert_eq!(bf16_round(1.0 + 0.6 * step), 1.0 + step, "above halfway rounds up");
        // monotone and idempotent over a sweep
        let mut prev = f32::NEG_INFINITY;
        for i in -1000..1000 {
            let x = i as f32 * 0.013;
            let r = bf16_round(x);
            assert_eq!(bf16_round(r), r, "idempotent at {x}");
            assert!(r >= prev, "monotone at {x}");
            prev = r;
        }
        // relative error bound: 2^-8
        for i in 1..500 {
            let x = i as f32 * 0.37;
            assert!((bf16_round(x) - x).abs() <= x.abs() * f32::powi(2.0, -8));
        }
    }

    #[test]
    fn convert_roundtrip_applies_bf16_grid() {
        let text = r#"
HloModule jit_bf16

ENTRY main.5 {
  Arg_0.1 = f32[4]{0} parameter(0)
  convert.2 = bf16[4]{0} convert(Arg_0.1)
  ROOT convert.3 = f32[4]{0} convert(convert.2)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let x = [1.0f32, 1.001, 3.14159, -0.4997];
        let out = m.evaluate(&[&x]).unwrap();
        for (i, &v) in out[0].data.iter().enumerate() {
            assert_eq!(v, bf16_round(x[i]));
        }
        assert_allclose_f32(&out[0].data, &x, 1e-2, 1e-3);
    }

    #[test]
    fn malformed_text_is_rejected() {
        assert!(HloModule::parse("this is not HLO").is_err());
        assert!(HloModule::parse("").is_err());
        // entry with an undefined operand
        let bad = "ENTRY main {\n  ROOT add.1 = f32[2]{0} add(ghost.7, ghost.8)\n}\n";
        let e = HloModule::parse(bad).unwrap_err().to_string();
        assert!(e.contains("unknown operand"), "{e}");
        // supported parse, unsupported opcode fails at evaluate
        let unsup = "ENTRY main {\n  Arg_0.1 = f32[2]{0} parameter(0)\n  ROOT neg.2 = f32[2]{0} negate(Arg_0.1)\n}\n";
        let m = HloModule::parse(unsup).unwrap();
        let e = m.evaluate(&[&[1.0, 2.0]]).unwrap_err().to_string();
        assert!(e.contains("unsupported HLO opcode"), "{e}");
    }

    #[test]
    fn input_validation() {
        let m = HloModule::parse(TINY).unwrap();
        let short = [0f32; 3];
        assert!(m.evaluate(&[&short, &short]).is_err(), "wrong input length");
        assert!(m.evaluate(&[&[0f32; 6]]).is_err(), "missing input");
    }

    #[test]
    fn dtype_mismatched_and_malformed_dots_error_instead_of_panicking() {
        // integer element types parse (DType::Other) but must be
        // rejected with an error at evaluation, never a panic
        let s32 = "ENTRY main {\n  Arg_0.1 = s32[2,3]{1,0} parameter(0)\n  Arg_1.2 = s32[3,2]{1,0} parameter(1)\n  ROOT dot.3 = s32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(s32).unwrap();
        let e = m.evaluate(&[&[0f32; 6], &[0f32; 6]]).unwrap_err().to_string();
        assert!(e.contains("unsupported element type"), "{e}");

        // contraction mismatch: [2,3] × [4,2]
        let bad_k = "ENTRY main {\n  Arg_0.1 = f32[2,3]{1,0} parameter(0)\n  Arg_1.2 = f32[4,2]{1,0} parameter(1)\n  ROOT dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(bad_k).unwrap();
        let e = m.evaluate(&[&[0f32; 6], &[0f32; 8]]).unwrap_err().to_string();
        assert!(e.contains("contraction mismatch"), "{e}");

        // unsupported contracting-dim layout
        let bad_dims = "ENTRY main {\n  Arg_0.1 = f32[2,3]{1,0} parameter(0)\n  Arg_1.2 = f32[2,3]{1,0} parameter(1)\n  ROOT dot.3 = f32[3,3]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={0}, rhs_contracting_dims={1}\n}\n";
        let m = HloModule::parse(bad_dims).unwrap();
        let e = m.evaluate(&[&[0f32; 6], &[0f32; 6]]).unwrap_err().to_string();
        assert!(e.contains("lhs_contracting_dims"), "{e}");

        // rank-1 operands
        let rank1 = "ENTRY main {\n  Arg_0.1 = f32[3]{0} parameter(0)\n  Arg_1.2 = f32[3]{0} parameter(1)\n  ROOT dot.3 = f32[]{} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(rank1).unwrap();
        let e = m.evaluate(&[&[0f32; 3], &[0f32; 3]]).unwrap_err().to_string();
        assert!(e.contains("rank-2"), "{e}");

        // declared result shape lies about the operand shapes
        let bad_out = "ENTRY main {\n  Arg_0.1 = f32[2,3]{1,0} parameter(0)\n  Arg_1.2 = f32[3,2]{1,0} parameter(1)\n  ROOT dot.3 = f32[3,3]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(bad_out).unwrap();
        let e = m.evaluate(&[&[0f32; 6], &[0f32; 6]]).unwrap_err().to_string();
        assert!(e.contains("dot result shape"), "{e}");

        // truncated shapes and attributes are parse-time errors
        for bad in [
            "ENTRY main {\n  ROOT Arg_0.1 = f32[2, parameter(0)\n}\n",
            "ENTRY main {\n  ROOT Arg_0.1 = f32[2,]{1,0} parameter(0)\n}\n",
            "ENTRY main {\n  Arg_0.1 = f32[2,2]{1,0} parameter(0)\n  ROOT dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_0.1), lhs_contracting_dims={1\n}\n",
        ] {
            assert!(HloModule::parse(bad).is_err(), "must reject: {bad}");
        }
    }
}
