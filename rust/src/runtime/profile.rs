//! **Per-step roofline observability**: the bridge between the compiled
//! [`Plan`](super::plan::Plan), the ISA surface ([`crate::isa`]), and the
//! cycle-approximate core model ([`crate::core_model`]).
//!
//! For every GEMM-bearing plan step this module synthesizes the MMA
//! instruction stream the step's *executed kernel* corresponds to — the
//! exact `(m, n, k, dtype, variant, epilogue)` the engine ran, reported
//! by the engine itself as an
//! [`ExecutedKernel`](crate::blas::block_gemm::ExecutedKernel) — walks
//! it for an exact [`InstMix`] (per-opcode dynamic counts, MACs, memory
//! traffic, accumulator transfers), and runs it through [`CoreSim`]
//! under [`MachineConfig::power10`] for a **simulated MACs/cycle
//! ceiling** plus per-resource occupancies and a bound classification.
//! Wall-clock engine replays of the same kernel convert to **achieved
//! MACs/cycle** at [`NOMINAL_GHZ`], which yields the roofline verdict:
//!
//! ```text
//! plan step ──(ExecutedKernel)──▶ synthesized Inst stream
//!     ──▶ InstMix (exact: Σ ger MACs == gemms·m·n·k)
//!     ──▶ CoreSim(power10) ──▶ ceiling MACs/cycle, occupancies, bound
//!     ──▶ achieved / ceiling / Table-I peak  (the roofline row)
//! ```
//!
//! The synthesis mirrors the blocked engines exactly: the tuner-chosen
//! [`GemmVariant`] drives the `jc → pc → ic → jr → ir` loop nest, the
//! register tile maps onto the 4×4 accumulator grid in the same
//! `[0, 1, 4, 5, 2, 3, 6, 7]` order as
//! [`rp_gemm_program`](crate::kernels::gemm_rp::rp_gemm_program), cache
//! blocks re-load/re-store the C tile through `xxmtacc`/`xxmfacc`, and
//! m/n/k tails issue the prefixed masked (`pm…`) forms, so the stream's
//! MAC count matches the step's `m·n·k` arithmetic *exactly* (pinned by
//! `rust/tests/profile_engine.rs`). A `DftGemm` step profiles as its
//! real packed-panel **dual-GEMM×2 structure** (4 f32 GEMMs, the last
//! two with the `DftCombine` writeback), not as one f32 GEMM.
//!
//! [`microkernel_fpc`] is the generalized form of the three ad-hoc
//! Table-I ratio probes `bench serve` used to compute inline; the bench
//! now calls it, and the harness proves the reproduction is bit-for-bit.

use crate::blas::bf16_gemm::{gemm_bf16_tuned_into, Bf16Accum, Bf16Scratch, Bf16Src};
use crate::blas::block_gemm::{
    gemm_f32_tuned_into, Accum, Epilogue, ExecutedKernel, GemmScratch, GemmVariant, PanelB, Par,
};
use crate::blas::i8_gemm::{gemm_i8_packed_tuned_into, I8Accum, I8Scratch, I8SrcA, I8SrcB};
use crate::core_model::{CoreSim, MachineConfig, SimReport};
use crate::isa::inst::{AccOp, Ger, GerKind, Inst};
use crate::kernels::gemm_rp::rp_gemm_program;
use crate::kernels::pack::{DftPanels, Im2colSpec};
use crate::runtime::tune::{TuneEpi, TunePanel};
use std::collections::BTreeMap;
use std::time::Instant;

/// Nominal clock used to convert wall-clock engine replays to
/// MACs/cycle — the ~4 GHz class of the paper's POWER10 measurement
/// parts. The roofline's *achieved* axis is honest about being a
/// host-measured proxy: it is exact in MACs and nominal in cycles.
pub const NOMINAL_GHZ: f64 = 4.0;

/// Fuel for the synthesized-stream simulations (streams are loop-free,
/// so dynamic count == static length, well under this).
const SIM_FUEL: u64 = 1 << 26;

/// MAC budget for the *simulated* stream. The [`InstMix`] is always
/// exact for the full `m·n·k`; only the ceiling simulation clamps the
/// shape (to whole cache blocks, keeping the variant's blocking and
/// revisit structure) so profiling a large model stays fast.
const SIM_MAC_CAP: usize = 1 << 22;

/// Accumulator assignment order of the 8-accumulator register tiles
/// (matches [`rp_gemm_program`]'s interleaved pattern).
const ACC_ORDER8: [u8; 8] = [0, 1, 4, 5, 2, 3, 6, 7];

/// The fused epilogue a synthesized GEMM stream models at the final
/// C-tile writeback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EpiModel {
    None,
    Bias,
    BiasRelu,
    /// The DFT `±other` combine (a load + vector add per output row).
    DftCombine,
}

impl EpiModel {
    fn of(epi: TuneEpi) -> EpiModel {
        match epi {
            TuneEpi::None => EpiModel::None,
            TuneEpi::Bias => EpiModel::Bias,
            TuneEpi::BiasRelu => EpiModel::BiasRelu,
        }
    }
}

/// What one plan step executes, as reported by the step itself — the
/// input to both the stream synthesis and the wall-clock replay.
#[derive(Clone, Debug)]
pub enum StepKernel {
    /// A GEMM-bearing step: the engine's executed-kernel descriptor,
    /// its fused epilogue, its B-panel modality, and how many GEMMs of
    /// that shape the step runs (4 for `dft_gemm`, else 1).
    Gemm { ek: ExecutedKernel, epi: TuneEpi, panel: TunePanel, gemms: usize },
    /// A pure data-movement step (param materialization, copies,
    /// conversions, gathers, elementwise tails): bytes in/out plus any
    /// vector FMA work, profiled as a load/store stream.
    Mem { load_bytes: usize, store_bytes: usize, fma_ops: usize },
}

/// One plan step's profiling input: its position, display name, and
/// executed kernel.
#[derive(Clone, Debug)]
pub struct StepSpec {
    pub index: usize,
    pub step: String,
    pub kernel: StepKernel,
}

/// Exact dynamic instruction mix of a synthesized stream.
#[derive(Clone, Debug, Default)]
pub struct InstMix {
    /// Per-opcode dynamic counts, mnemonic-sorted (e.g.
    /// `("pmxvf32gerpp", 12)`).
    pub counts: Vec<(String, u64)>,
    /// Total dynamic instructions.
    pub insts: u64,
    /// Multiply-accumulates retired by `ger` instructions — exactly
    /// `gemms · m · n · k` for a GEMM step (masked forms count only
    /// enabled products, §II-C).
    pub macs: u64,
    /// Dynamic load instructions (`lxv`/`lxvp`).
    pub loads: u64,
    /// Dynamic store instructions (`stxv`/`stxvp`).
    pub stores: u64,
    /// Bytes loaded.
    pub load_bytes: u64,
    /// Bytes stored.
    pub store_bytes: u64,
    /// Accumulator transfers (`xxmtacc` + `xxmfacc` + `xxsetaccz`) —
    /// the §III priming/depriming traffic.
    pub acc_xfers: u64,
}

impl InstMix {
    /// The `count` highest-frequency opcodes, formatted `name:count`.
    pub fn top_opcodes(&self, count: usize) -> String {
        let mut rows: Vec<&(String, u64)> = self.counts.iter().collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        rows.iter()
            .take(count)
            .map(|(n, c)| format!("{n}:{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Streaming [`InstMix`] accumulator.
#[derive(Default)]
struct MixBuilder {
    counts: BTreeMap<String, u64>,
    mix: InstMix,
}

impl MixBuilder {
    fn observe(&mut self, inst: &Inst) {
        *self.counts.entry(opcode_name(inst)).or_insert(0) += 1;
        self.mix.insts += 1;
        match inst {
            Inst::Ger(_) => self.mix.macs += inst.flops() / 2,
            Inst::Lxv { .. } | Inst::Lxvp { .. } => {
                self.mix.loads += 1;
                self.mix.load_bytes += u64::from(inst.mem_bytes());
            }
            Inst::Stxv { .. } | Inst::Stxvp { .. } => {
                self.mix.stores += 1;
                self.mix.store_bytes += u64::from(inst.mem_bytes());
            }
            Inst::XxMtAcc { .. } | Inst::XxMfAcc { .. } | Inst::XxSetAccZ { .. } => {
                self.mix.acc_xfers += 1;
            }
            _ => {}
        }
    }

    fn finish(mut self) -> InstMix {
        self.mix.counts = self.counts.into_iter().collect();
        self.mix
    }
}

/// Mnemonic of any modeled instruction (`ger` forms include their
/// `pm` prefix and accumulate suffix).
pub fn opcode_name(inst: &Inst) -> String {
    match inst {
        Inst::Ger(g) => g.mnemonic(),
        Inst::XxSetAccZ { .. } => "xxsetaccz".into(),
        Inst::XxMfAcc { .. } => "xxmfacc".into(),
        Inst::XxMtAcc { .. } => "xxmtacc".into(),
        Inst::Lxv { .. } => "lxv".into(),
        Inst::Lxvp { .. } => "lxvp".into(),
        Inst::Stxv { .. } => "stxv".into(),
        Inst::Stxvp { .. } => "stxvp".into(),
        Inst::XvMaddaDp { .. } => "xvmaddadp".into(),
        Inst::XvMaddaSp { .. } => "xvmaddasp".into(),
        Inst::XxSpltd { .. } => "xxspltd".into(),
        Inst::XxSpltw { .. } => "xxspltw".into(),
        Inst::Xxlor { .. } => "xxlor".into(),
        Inst::Xxlxor { .. } => "xxlxor".into(),
        Inst::Addi { .. } => "addi".into(),
        Inst::Mtctr { .. } => "mtctr".into(),
        Inst::Bdnz { .. } => "bdnz".into(),
        Inst::Blr => "blr".into(),
        Inst::Nop => "nop".into(),
    }
}

/// One step's roofline row: instruction mix, simulated ceiling,
/// occupancies + bound, Table-I peak, and (when measured) achieved
/// MACs/cycle.
#[derive(Clone, Debug)]
pub struct StepProfile {
    /// Plan step index.
    pub index: usize,
    /// Plan step name (e.g. `dot_i8`).
    pub step: String,
    /// Executed dtype (`f32` / `bf16` / `i8`), `-` for mem steps.
    pub dtype: &'static str,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// The tuner-chosen variant a GEMM step ran under.
    pub variant: Option<GemmVariant>,
    /// GEMMs of shape `m×n×k` the step runs (4 for `dft_gemm`).
    pub gemms: usize,
    /// Exact mix of the full synthesized stream.
    pub mix: InstMix,
    /// Simulated cycles / dynamic instructions of the (possibly
    /// shape-clamped, see [`SIM_MAC_CAP`]) ceiling stream.
    pub sim_cycles: u64,
    pub sim_insts: u64,
    /// The simulated MACs/cycle ceiling of the synthesized kernel on
    /// [`MachineConfig::power10`] (0 for mem steps).
    pub sim_macs_per_cycle: f64,
    /// The dtype's Table-I architectural peak (`mma_pipes · 16 · rank`).
    pub table1_peak_macs_per_cycle: f64,
    /// Per-resource busy fractions from the ceiling simulation.
    pub occupancies: [(&'static str, f64); 4],
    /// The unit class that bounds the simulated stream.
    pub bound_unit: &'static str,
    /// `compute` (VSU/MME) vs `load` (LSU ports) vs `fixed-point`.
    pub bound: &'static str,
    /// Achieved MACs/cycle from a wall-clock engine replay at
    /// [`NOMINAL_GHZ`] (filled by [`measure_achieved`]; `None` for mem
    /// steps or unmeasured profiles).
    pub achieved_macs_per_cycle: Option<f64>,
}

impl StepProfile {
    /// Whether this step carries GEMM work (the roofline rows).
    pub fn is_gemm(&self) -> bool {
        self.gemms > 0
    }

    /// `achieved / ceiling`, when both sides exist.
    pub fn pct_of_ceiling(&self) -> Option<f64> {
        match self.achieved_macs_per_cycle {
            Some(a) if self.sim_macs_per_cycle > 0.0 => Some(a / self.sim_macs_per_cycle),
            _ => None,
        }
    }
}

/// Bound classification of a [`SimReport::bottleneck`] unit class.
fn bound_class(unit: &'static str) -> &'static str {
    match unit {
        "lsu" => "load",
        "fxu" => "fixed-point",
        _ => "compute",
    }
}

/// The Table I rank-k instruction a packed engine's microkernel maps to.
fn ger_kind(ek: &ExecutedKernel) -> GerKind {
    match ek.elem {
        "bf16" => GerKind::Bf16Ger2,
        "i8" => GerKind::I8Ger4,
        _ => GerKind::F32Ger,
    }
}

/// Architectural Table-I peak MACs/cycle for a rank-`rank` update:
/// `mma_pipes × (4×4 tile) × rank`.
pub fn table1_peak(cfg: &MachineConfig, rank: usize) -> f64 {
    f64::from(cfg.mma_pipes) * 16.0 * rank as f64
}

/// LSB-first enable mask over `bits` elements.
fn mask(bits: usize) -> u8 {
    ((1u16 << bits) - 1) as u8
}

/// Synthesize the full instruction stream of one tuned GEMM — the
/// variant's `jc → pc → ic → jr → ir` blocked loop nest, fully unrolled
/// (dynamic counts == static counts) — into `emit`. Addresses mirror
/// the packed-panel layouts: A micropanels re-play across `jr` (the
/// panel reuse the cache model should see), B panels re-play across
/// `ic`, and the C tile is stored/reloaded at every cache-block revisit.
fn gen_gemm_stream(ek: &ExecutedKernel, epi: EpiModel, emit: &mut dyn FnMut(Inst)) {
    let kind = ger_kind(ek);
    let rank = ek.rank;
    let (m, n, k) = (ek.m, ek.n, ek.k);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let v = ek.v;
    let (mr, nr) = (v.mr, v.nr);
    let (mc, kc, nc) = (v.block.mc, v.block.kc, v.block.nc);
    // lxv instructions per k-step to feed the X (rows) and Y (cols)
    // operand registers — packed panels are zero-padded to the full
    // tile, so the loads always move whole panel steps
    let lx = (mr * rank * ek.esize).div_ceil(16);
    let ly = (nr * rank * ek.esize).div_ceil(16);
    let ktotal = k.div_ceil(rank);
    for jc in (0..n).step_by(nc) {
        let ncols = nc.min(n - jc);
        let mut pc = 0usize;
        while pc < k {
            let kb = kc.min(k - pc);
            let ksteps = kb.div_ceil(rank);
            // kc is a multiple of every rank in the family, so only the
            // final block can carry a partial (masked) last step
            let step0 = pc / rank;
            let last_block = pc + kb >= k;
            for ic in (0..m).step_by(mc) {
                let mrows = mc.min(m - ic);
                for jr in (0..ncols).step_by(nr) {
                    let tn = nr.min(ncols - jr);
                    let col_panel = (jc + jr) / nr;
                    for ir in (0..mrows).step_by(mr) {
                        let tm = mr.min(mrows - ir);
                        let row_panel = (ic + ir) / mr;
                        let ar = tm.div_ceil(4);
                        let ac = tn.div_ceil(4);
                        let accs = ar * ac;
                        let acc_at = |a: usize| -> u8 {
                            if accs == 8 {
                                ACC_ORDER8[a]
                            } else {
                                a as u8
                            }
                        };
                        let c_dq = |a: usize, r: usize| -> i32 {
                            let (ai, aj) = (a / ac, a % ac);
                            let row = ic + ir + ai * 4 + r;
                            let col = jc + jr + aj * 4;
                            (((row * n + col) * 4) as i32) & !15
                        };
                        // cache-block revisit: reload the C tile into
                        // the accumulators ("two cycles to transfer
                        // four VSRs to an accumulator", §III)
                        if pc > 0 {
                            for a in 0..accs {
                                let acc = acc_at(a);
                                for r in 0..4 {
                                    emit(Inst::Lxv { xt: acc * 4 + r, ra: 3, dq: c_dq(a, r) });
                                }
                                emit(Inst::XxMtAcc { acc });
                            }
                        }
                        for s in 0..ksteps {
                            let prods = rank.min(kb - s * rank);
                            let gstep = step0 + s;
                            for i in 0..lx {
                                let dq = (((row_panel * ktotal + gstep) * lx + i) * 16) as i32;
                                emit(Inst::Lxv { xt: 32 + i as u8, ra: 4, dq });
                            }
                            for j in 0..ly {
                                let dq = (((col_panel * ktotal + gstep) * ly + j) * 16) as i32;
                                emit(Inst::Lxv { xt: 36 + j as u8, ra: 5, dq });
                            }
                            for a in 0..accs {
                                let (ai, aj) = (a / ac, a % ac);
                                let rows = 4.min(tm - ai * 4);
                                let cols = 4.min(tn - aj * 4);
                                let op = if pc == 0 && s == 0 { AccOp::New } else { AccOp::PP };
                                let (xa, yb) = (32 + ai as u8, 36 + aj as u8);
                                let g = if rows == 4 && cols == 4 && prods == rank {
                                    Ger::new(kind, op, acc_at(a), xa, yb)
                                } else {
                                    Ger::prefixed(
                                        kind,
                                        op,
                                        acc_at(a),
                                        xa,
                                        yb,
                                        mask(rows),
                                        mask(cols),
                                        mask(prods),
                                    )
                                };
                                emit(Inst::Ger(g));
                            }
                            emit(Inst::Addi { rt: 4, ra: 4, si: (lx * 16) as i32 });
                            emit(Inst::Addi { rt: 5, ra: 5, si: (ly * 16) as i32 });
                        }
                        // writeback: deprime ("four cycles to transfer
                        // one accumulator to 4 VSRs"), fused epilogue on
                        // the final block, store the C tile
                        for a in 0..accs {
                            let acc = acc_at(a);
                            emit(Inst::XxMfAcc { acc });
                            if last_block {
                                match epi {
                                    EpiModel::None => {}
                                    EpiModel::Bias | EpiModel::BiasRelu => {
                                        let aj = (a % ac) as u8;
                                        emit(Inst::Lxv { xt: 40 + aj, ra: 6, dq: i32::from(aj) * 16 });
                                        for r in 0..4u8 {
                                            emit(Inst::XvMaddaSp {
                                                xt: acc * 4 + r,
                                                xa: 40 + aj,
                                                xb: 44,
                                            });
                                            if epi == EpiModel::BiasRelu {
                                                emit(Inst::Xxlor {
                                                    xt: acc * 4 + r,
                                                    xa: acc * 4 + r,
                                                    xb: 45,
                                                });
                                            }
                                        }
                                    }
                                    EpiModel::DftCombine => {
                                        for r in 0..4u8 {
                                            emit(Inst::Lxv {
                                                xt: 46,
                                                ra: 7,
                                                dq: c_dq(a, r as usize),
                                            });
                                            emit(Inst::XvMaddaSp {
                                                xt: acc * 4 + r,
                                                xa: 46,
                                                xb: 44,
                                            });
                                        }
                                    }
                                }
                            }
                            for r in 0..4 {
                                emit(Inst::Stxv { xs: acc * 4 + r, ra: 3, dq: c_dq(a, usize::from(r)) });
                            }
                        }
                    }
                }
            }
            pc += kb;
        }
    }
}

/// The per-GEMM epilogue sequence of a step: `dft_gemm` runs 4 GEMMs —
/// two plain temporaries, then the two `DftCombine` writebacks.
fn gemm_epis(spec_epi: TuneEpi, panel: TunePanel, gemms: usize) -> Vec<EpiModel> {
    if panel == TunePanel::DftPacked {
        vec![EpiModel::None, EpiModel::None, EpiModel::DftCombine, EpiModel::DftCombine]
    } else {
        vec![EpiModel::of(spec_epi); gemms]
    }
}

/// Shape-clamp a kernel for the ceiling simulation: whole cache blocks
/// (so the revisit structure survives), shrunk in tile multiples until
/// the MAC volume fits [`SIM_MAC_CAP`].
fn sim_kernel(ek: &ExecutedKernel) -> ExecutedKernel {
    let mut s = *ek;
    s.m = s.m.min(s.v.block.mc);
    s.n = s.n.min(s.v.block.nc);
    s.k = s.k.min(2 * s.v.block.kc);
    while s.m.saturating_mul(s.n).saturating_mul(s.k) > SIM_MAC_CAP && s.m > s.v.mr {
        s.m = (s.m / 2).max(s.v.mr);
    }
    while s.m.saturating_mul(s.n).saturating_mul(s.k) > SIM_MAC_CAP && s.n > s.v.nr {
        s.n = (s.n / 2).max(s.v.nr);
    }
    s
}

/// Run a synthesized stream through [`CoreSim`] on POWER10, with
/// disjoint operand/result address bases.
fn simulate(prog: &[Inst]) -> (SimReport, MachineConfig) {
    let cfg = MachineConfig::power10();
    let mut sim = CoreSim::new(cfg);
    sim.gpr[3] = 1 << 28; // C
    sim.gpr[4] = 1 << 26; // packed A
    sim.gpr[5] = 1 << 27; // packed B
    sim.gpr[6] = 3 << 28; // bias
    sim.gpr[7] = 1 << 29; // DFT combine operand
    let report = sim.run(prog, SIM_FUEL);
    (report, cfg)
}

/// Profile one step: exact mix of the full stream, then the ceiling
/// simulation (shape-clamped when large). Pure simulation — no
/// wall-clock measurement (see [`measure_achieved`]).
pub fn profile_step(spec: &StepSpec) -> StepProfile {
    match &spec.kernel {
        StepKernel::Gemm { ek, epi, panel, gemms } => {
            let epis = gemm_epis(*epi, *panel, *gemms);
            let mut mb = MixBuilder::default();
            for e in &epis {
                gen_gemm_stream(ek, *e, &mut |i| mb.observe(&i));
            }
            let mix = mb.finish();
            let sek = sim_kernel(ek);
            let mut prog = Vec::new();
            for e in &epis {
                gen_gemm_stream(&sek, *e, &mut |i| prog.push(i));
            }
            prog.push(Inst::Blr);
            let sim_macs: u64 = prog
                .iter()
                .map(|i| if matches!(i, Inst::Ger(_)) { i.flops() / 2 } else { 0 })
                .sum();
            let (report, cfg) = simulate(&prog);
            let (bound_unit, _) = report.bottleneck(&cfg);
            StepProfile {
                index: spec.index,
                step: spec.step.clone(),
                dtype: ek.elem,
                m: ek.m,
                n: ek.n,
                k: ek.k,
                variant: Some(ek.v),
                gemms: *gemms,
                mix,
                sim_cycles: report.cycles,
                sim_insts: report.instructions,
                sim_macs_per_cycle: sim_macs as f64 / report.cycles.max(1) as f64,
                table1_peak_macs_per_cycle: table1_peak(&cfg, ek.rank),
                occupancies: report.occupancies(&cfg),
                bound_unit,
                bound: bound_class(bound_unit),
                achieved_macs_per_cycle: None,
            }
        }
        StepKernel::Mem { load_bytes, store_bytes, fma_ops } => {
            let mut mb = MixBuilder::default();
            gen_mem_stream(*load_bytes, *store_bytes, *fma_ops, usize::MAX, &mut |i| {
                mb.observe(&i)
            });
            let mix = mb.finish();
            let mut prog = Vec::new();
            gen_mem_stream(*load_bytes, *store_bytes, *fma_ops, 1 << 16, &mut |i| prog.push(i));
            prog.push(Inst::Blr);
            let (report, cfg) = simulate(&prog);
            let (bound_unit, _) = report.bottleneck(&cfg);
            StepProfile {
                index: spec.index,
                step: spec.step.clone(),
                dtype: "-",
                m: 0,
                n: 0,
                k: 0,
                variant: None,
                gemms: 0,
                mix,
                sim_cycles: report.cycles,
                sim_insts: report.instructions,
                sim_macs_per_cycle: 0.0,
                table1_peak_macs_per_cycle: 0.0,
                occupancies: report.occupancies(&cfg),
                bound_unit,
                bound: bound_class(bound_unit),
                achieved_macs_per_cycle: None,
            }
        }
    }
}

/// Synthesize a data-movement stream: a 16-byte load/store (and
/// optional vector-FMA) pipeline cycling through disjoint registers.
/// `cap` clamps the per-class instruction count for simulation; pass
/// `usize::MAX` for the exact mix.
fn gen_mem_stream(
    load_bytes: usize,
    store_bytes: usize,
    fma_ops: usize,
    cap: usize,
    emit: &mut dyn FnMut(Inst),
) {
    let loads = load_bytes.div_ceil(16).min(cap);
    let stores = store_bytes.div_ceil(16).min(cap);
    let fmas = fma_ops.min(cap);
    let iters = loads.max(stores).max(fmas);
    for i in 0..iters {
        let r = (i % 8) as u8;
        if i < loads {
            emit(Inst::Lxv { xt: 32 + r, ra: 4, dq: (i * 16) as i32 });
        }
        if i < fmas {
            emit(Inst::XvMaddaSp { xt: 48 + r, xa: 32 + r, xb: 44 });
        }
        if i < stores {
            emit(Inst::Stxv { xs: if i < fmas { 48 + r } else { 32 + r }, ra: 3, dq: (i * 16) as i32 });
        }
    }
}

/// Profile every step of a plan (pure simulation).
pub fn profile_steps(specs: &[StepSpec]) -> Vec<StepProfile> {
    specs.iter().map(profile_step).collect()
}

/// Profile every step and fill achieved MACs/cycle for the GEMM-bearing
/// ones via wall-clock engine replays.
pub fn profile_steps_measured(specs: &[StepSpec]) -> Vec<StepProfile> {
    specs
        .iter()
        .map(|s| {
            let mut p = profile_step(s);
            p.achieved_macs_per_cycle = measure_achieved(s);
            p
        })
        .collect()
}

/// Replay a GEMM step's executed kernel on synthetic operands of its
/// exact shape (serially, like the autotuner's measurement), and
/// convert the best wall-clock to achieved MACs/cycle at
/// [`NOMINAL_GHZ`]. `None` for mem steps and degenerate shapes.
pub fn measure_achieved(spec: &StepSpec) -> Option<f64> {
    let StepKernel::Gemm { ek, epi, panel, gemms } = &spec.kernel else {
        return None;
    };
    let (m, n, k) = (ek.m, ek.n, ek.k);
    if m == 0 || n == 0 || k == 0 {
        return None;
    }
    let v = ek.v;
    let bias = fill_f32(n, 0x0b5e_0001);
    let secs = match (ek.elem, *panel) {
        ("f32", TunePanel::DftPacked) => {
            let xr = fill_f32(m * k, 0x0b5e_0002);
            let xi = fill_f32(m * k, 0x0b5e_0003);
            let fr = fill_f32(k * n, 0x0b5e_0004);
            let fi = fill_f32(k * n, 0x0b5e_0005);
            // panels packed once, pinned alongside the plan — packing is
            // compile-time work, so it stays outside the timed region
            let panels = DftPanels::pack(&fr, &fi, k, n, v.nr, v.block.kc);
            let mut t_ii = vec![0f32; m * n];
            let mut t_ir = vec![0f32; m * n];
            let mut out_re = vec![0f32; m * n];
            let mut out_im = vec![0f32; m * n];
            let mut scratch = GemmScratch::new();
            time_secs(|| {
                gemm_f32_tuned_into(
                    &mut t_ii,
                    &xi,
                    PanelB::Packed(&panels.im),
                    m,
                    n,
                    k,
                    Accum::F64,
                    Epilogue::None,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                gemm_f32_tuned_into(
                    &mut t_ir,
                    &xi,
                    PanelB::Packed(&panels.re),
                    m,
                    n,
                    k,
                    Accum::F64,
                    Epilogue::None,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                gemm_f32_tuned_into(
                    &mut out_re,
                    &xr,
                    PanelB::Packed(&panels.re),
                    m,
                    n,
                    k,
                    Accum::F64,
                    Epilogue::DftCombine { other: &t_ii, sub: true },
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                gemm_f32_tuned_into(
                    &mut out_im,
                    &xr,
                    PanelB::Packed(&panels.im),
                    m,
                    n,
                    k,
                    Accum::F64,
                    Epilogue::DftCombine { other: &t_ir, sub: false },
                    Par::Seq,
                    &mut scratch,
                    v,
                );
            })
        }
        ("f32", p) => {
            let a = fill_f32(m * k, 0x0b5e_0006);
            let b = fill_f32(k * n, 0x0b5e_0007);
            let spec_b =
                Im2colSpec { bases: (0..k).map(|p| p * n).collect(), img_w: n, out_w: n };
            let mut c = vec![0f32; m * n];
            let mut scratch = GemmScratch::new();
            time_secs(|| {
                let src = match p {
                    TunePanel::Im2col => PanelB::Im2col { img: &b, spec: &spec_b },
                    _ => PanelB::Matrix(&b),
                };
                gemm_f32_tuned_into(
                    &mut c,
                    &a,
                    src,
                    m,
                    n,
                    k,
                    Accum::F64,
                    epilogue_of(*epi, &bias),
                    Par::Seq,
                    &mut scratch,
                    v,
                );
            })
        }
        ("bf16", _) => {
            let a = fill_f32(m * k, 0x0b5e_0008);
            let b = fill_f32(k * n, 0x0b5e_0009);
            let mut c = vec![0f32; m * n];
            let mut scratch = Bf16Scratch::new();
            time_secs(|| {
                gemm_bf16_tuned_into(
                    &mut c,
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    Bf16Accum::Widened,
                    epilogue_of(*epi, &bias),
                    Par::Seq,
                    &mut scratch,
                    v,
                );
            })
        }
        _ => {
            let a = fill_i8(m * k, 0x0b5e_000a);
            let b = fill_u8(k * n, 0x0b5e_000b);
            let mut c = vec![0i32; m * n];
            let mut scratch = I8Scratch::new();
            time_secs(|| {
                gemm_i8_packed_tuned_into(
                    &mut c,
                    I8SrcA::Q(&a),
                    I8SrcB::Q(&b),
                    m,
                    n,
                    k,
                    I8Accum::Wrapping,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
            })
        }
    };
    let macs = (*gemms as f64) * (m as f64) * (n as f64) * (k as f64);
    Some(macs / (secs.max(1e-9) * NOMINAL_GHZ * 1e9))
}

fn epilogue_of(epi: TuneEpi, bias: &[f32]) -> Epilogue<'_> {
    match epi {
        TuneEpi::None => Epilogue::None,
        TuneEpi::Bias => Epilogue::Bias(bias),
        TuneEpi::BiasRelu => Epilogue::BiasRelu(bias),
    }
}

/// The generalized form of the bench's ad-hoc Table-I probes: simulated
/// flops/cycle of the register-resident rank-k microkernel
/// ([`rp_gemm_program`], `steps` unrolled steps) on POWER10 —
/// *bit-for-bit* the value the inline closures used to compute
/// (identical program, identical simulator construction, identical
/// fuel).
pub fn microkernel_fpc(kind: GerKind, steps: usize) -> f64 {
    let mut sim = CoreSim::new(MachineConfig::power10());
    sim.run(&rp_gemm_program(kind, steps, None), 1 << 22).flops_per_cycle()
}

/// Minimum of 3 timed runs after one untimed warmup, in seconds.
fn time_secs(mut run: impl FnMut()) -> f64 {
    run();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        run();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn lcg(state: &mut u32) -> u32 {
    *state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
    *state
}

fn fill_f32(len: usize, seed: u32) -> Vec<f32> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 8) as f32 / (1u32 << 24) as f32 - 0.5).collect()
}

fn fill_i8(len: usize, seed: u32) -> Vec<i8> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 16) as i8).collect()
}

fn fill_u8(len: usize, seed: u32) -> Vec<u8> {
    let mut s = seed;
    (0..len).map(|_| (lcg(&mut s) >> 16) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::block_gemm::executed_kernel_f32;

    fn gemm_spec(m: usize, n: usize, k: usize) -> StepSpec {
        StepSpec {
            index: 0,
            step: "dot".into(),
            kernel: StepKernel::Gemm {
                ek: executed_kernel_f32(m, n, k, GemmVariant::CANONICAL_F32),
                epi: TuneEpi::None,
                panel: TunePanel::Matrix,
                gemms: 1,
            },
        }
    }

    #[test]
    fn mac_count_is_exact_at_tile_seams() {
        for (m, n, k) in [(1usize, 1usize, 1usize), (7, 9, 5), (8, 8, 256), (33, 17, 129)] {
            let p = profile_step(&gemm_spec(m, n, k));
            assert_eq!(p.mix.macs, (m * n * k) as u64, "{m}x{n}x{k}");
            assert!(p.sim_macs_per_cycle > 0.0);
            assert!(p.sim_macs_per_cycle <= p.table1_peak_macs_per_cycle);
        }
    }

    #[test]
    fn mem_steps_profile_without_macs() {
        let spec = StepSpec {
            index: 1,
            step: "copy".into(),
            kernel: StepKernel::Mem { load_bytes: 4096, store_bytes: 4096, fma_ops: 0 },
        };
        let p = profile_step(&spec);
        assert_eq!(p.mix.macs, 0);
        assert_eq!(p.mix.loads, 256);
        assert_eq!(p.mix.stores, 256);
        assert_eq!(p.sim_macs_per_cycle, 0.0);
        assert!(!p.is_gemm());
    }

    #[test]
    fn microkernel_fpc_is_positive_and_ordered() {
        let f32_fpc = microkernel_fpc(GerKind::F32Ger, 32);
        let bf16_fpc = microkernel_fpc(GerKind::Bf16Ger2, 32);
        assert!(f32_fpc > 0.0);
        assert!(bf16_fpc > f32_fpc, "rank-2 must beat rank-1 flops/cycle");
    }
}
