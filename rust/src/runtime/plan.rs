//! Compiled execution plans: **compile once at `load()`, don't interpret
//! per request.**
//!
//! The legacy path ([`HloModule::evaluate`](super::hlo::HloModule::evaluate))
//! re-walks the instruction list on every request, re-deriving shapes,
//! strides, and operand checks, and allocating a fresh tensor per
//! instruction. This module lowers a parsed [`HloModule`] **once** into a
//! [`Plan`]:
//!
//! * every shape/attribute/operand check happens at compile time, so a
//!   malformed artifact fails at `load()` and the request path is
//!   branch-light;
//! * `broadcast`/`slice` are lowered to precomputed affine **gather**
//!   specs (base + per-axis stride coefficients), `reshape`/`convert`
//!   to flat copies, `dot` to the blocked parallel GEMM of
//!   [`crate::blas::block_gemm`];
//! * intermediate values live in a **preallocated buffer arena** with
//!   liveness-based slot reuse: a slot is recycled as soon as its value's
//!   last consumer has executed, and an instruction's output slot is
//!   never a slot of a still-live value (no aliasing, see
//!   [`Plan::assignments`]). Executing a request performs **no
//!   per-request allocation** beyond the returned output tensors — the
//!   arena, the GEMM `f64` accumulation image, and the packed-panel
//!   buffers are all owned by [`ExecBuffers`] and reused.
//!
//! Between validation and arena assignment, a **pattern-rewrite pass**
//! collapses the subgraph shapes the AOT graphs spend their time in
//! (the layered-reorganization strategy of the paper's Figure 9 SCONV,
//! applied at the plan level):
//!
//! * the shifted multiply-add chain a 3×3 convolution lowers to
//!   (`9·Cin` taps of `slice`/`broadcast`/`multiply` folded by `add`s —
//!   299 instructions in the `conv2d_k3` fixture) becomes **one**
//!   `Im2colGemm` step: a precompiled im2col gather spec
//!   ([`crate::kernels::pack::Im2colSpec`]) feeding the blocked GEMM,
//!   packing the shifted image windows straight into B panels;
//! * trailing `broadcast`+`add` (bias) and `maximum(0)` (relu) chains
//!   after a `dot` fuse into the GEMM's writeback
//!   [`Epilogue`](crate::blas::block_gemm::Epilogue), eliminating the
//!   output-sized memory sweeps of the MLP's post-dot instructions;
//! * a `convert(bf16) → convert(f32) → dot` round-trip (the graph a
//!   bf16 matmul over f32 storage lowers to — the `gemm_bf16` fixture)
//!   becomes one `dot_bf16` step on the **bf16 packed-panel engine**
//!   ([`crate::blas::bf16_gemm`]): both rounding converts fuse into the
//!   pair-interleaved panel packers (the `xvbf16ger2` rank-2 operand
//!   layout), so the bf16 grid values never materialize as tensors —
//!   and a raw-bf16 request input ([`PlanInput::Bf16`]) is packed
//!   straight from its bits with no f32 widening anywhere.
//!
//! Fused interior values are never materialized: they get no steps and
//! no arena slots, so the rewrite also shrinks the arena (the conv
//! fixture compiles to 3 steps — two parameter loads and the fused
//! GEMM — over 3 slots).
//!
//! Numerics are **bit-identical** to the interpreter walk on finite
//! inputs: elementwise ops use the same scalar functions, gathers compute
//! the same index arithmetic, and the blocked GEMM replays each
//! interpreter path's exact rounding — `dot` as ascending-`k` `f64`
//! accumulation ([`ref_gemm`](crate::blas::gemm::ref_gemm)'s order),
//! fused conv chains as ascending-tap `f32` chains, and fused epilogues
//! in `f32` after the accumulator narrows (see
//! [`crate::blas::block_gemm`]'s numerics contract; tested per fixture).
//!
//! Threading: [`Plan::execute_par`] takes a worker policy
//! ([`Par`](crate::blas::block_gemm::Par)); each GEMM step decides via
//! the policy's flop threshold whether to fan its column-chunk loop out.
//! On the serving path the policy is [`Par::Pool`] over the persistent
//! worker pool of a [`Device`](super::device::Device) — **no scoped
//! thread is spawned on the `dot`/`Im2colGemm` hot path** — and every
//! dispatch drains before the step returns, so a plan is still safe to
//! drive from the coordinator's thread-confined engine thread.
//!
//! ```
//! use power_mma::runtime::hlo::HloModule;
//! use power_mma::runtime::plan::Plan;
//!
//! // dot → bias add → relu: three output-sized sweeps in the
//! // interpreter, one epilogued GEMM step in the plan
//! let text = "\
//! ENTRY main {
//!   x = f32[2,2]{1,0} parameter(0)
//!   w = f32[2,2]{1,0} parameter(1)
//!   bias = f32[2]{0} parameter(2)
//!   dot.1 = f32[2,2]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
//!   bb.2 = f32[2,2]{1,0} broadcast(bias), dimensions={1}
//!   add.3 = f32[2,2]{1,0} add(dot.1, bb.2)
//!   zero.4 = f32[] constant(0)
//!   zb.5 = f32[2,2]{1,0} broadcast(zero.4), dimensions={}
//!   ROOT max.6 = f32[2,2]{1,0} maximum(add.3, zb.5)
//! }";
//! let plan = Plan::compile(&HloModule::parse(text).unwrap()).unwrap();
//! assert_eq!(plan.step_names(), ["param", "param", "param", "dot_bias_relu"]);
//! let out = plan
//!     .execute(&[&[1.0, 0.0, 0.0, 1.0], &[2.0, -3.0, 4.0, 5.0], &[0.5, 0.5]], 1)
//!     .unwrap();
//! assert_eq!(out[0].data, [2.5, 0.0, 4.5, 5.5]);
//! ```

use super::hlo::{bf16_round, DType, HloModule, Instr, Tensor};
use super::profile::{self, StepKernel, StepProfile, StepSpec};
use super::tune::{heuristic_variant, TuneDtype, TuneEpi, TuneKey, TunePanel, TuneTable};
use super::Int8Calib;
use crate::blas::bf16_gemm::{executed_kernel_bf16, gemm_bf16_tuned_into, Bf16Accum, Bf16Scratch, Bf16Src};
use crate::blas::i8_gemm::{
    executed_kernel_i8, gemm_i8_dequant_tuned_into, I8Epilogue, I8Scratch, QuantParams,
};
use crate::blas::block_gemm::{
    executed_kernel_f32, gemm_f32_tuned_into, threads_for_pooled, Accum, Epilogue, GemmScratch,
    GemmVariant, PanelB, Par,
};
use crate::error::Result;
use crate::isa::types::bf16_to_f32;
use crate::kernels::pack::{DftPanels, Im2colSpec};
use crate::{bail, err};

/// Elementwise operator of a [`Plan`] step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BinOp {
    Add,
    Multiply,
    Maximum,
}

/// Precomputed affine gather: `out[flat] = src[base + Σ_d ((flat /
/// ostrides[d]) % odims[d]) · coefs[d]]` — the compile-time form of both
/// `broadcast` (base 0, coefficients from the `dimensions` attribute) and
/// `slice` (base/coefficients from the slice bounds).
#[derive(Clone, Debug)]
struct GatherSpec {
    base: usize,
    odims: Vec<usize>,
    ostrides: Vec<usize>,
    coefs: Vec<usize>,
    len: usize,
}

/// Fused writeback epilogue of a GEMM step; the slot holds the bias
/// vector (`n` elements), applied per output column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StepEpi {
    None,
    Bias(usize),
    BiasRelu(usize),
}

impl StepEpi {
    /// The autotuner's epilogue class of this step epilogue.
    fn tune_epi(&self) -> TuneEpi {
        match self {
            StepEpi::None => TuneEpi::None,
            StepEpi::Bias(_) => TuneEpi::Bias,
            StepEpi::BiasRelu(_) => TuneEpi::BiasRelu,
        }
    }
}

/// Resolve the microkernel/blocking variant for one fused GEMM step at
/// compile time: consult the installed [`TuneTable`] (measuring the
/// class on first sight, memoized lookup after), or fall back to the
/// deterministic heuristic default — the canonical pre-tuner variant.
fn tuned_variant(
    tune: &Option<std::sync::Arc<TuneTable>>,
    m: usize,
    n: usize,
    k: usize,
    dtype: TuneDtype,
    epi: TuneEpi,
    panel: TunePanel,
) -> GemmVariant {
    match tune {
        Some(t) => t.choose(TuneKey { m, n, k, dtype, epi, panel }).variant,
        None => heuristic_variant(dtype),
    }
}

/// One compiled step of a [`Plan`]. Slot indices refer to the arena of
/// [`ExecBuffers`].
#[derive(Clone, Debug)]
enum Step {
    /// Copy entry input `index` (validated to `len` elements) into `out`.
    Param { index: usize, len: usize, out: usize },
    /// Flat copy (`reshape`, f32 `convert`).
    Copy { src: usize, len: usize, out: usize },
    /// bf16 round-to-nearest-even of every element (`convert` to bf16).
    Bf16 { src: usize, len: usize, out: usize },
    /// Elementwise binary op over equal-shaped operands.
    Binary { op: BinOp, a: usize, b: usize, len: usize, out: usize },
    /// `[m,k] × [k,n]` matmul on the blocked parallel GEMM, with an
    /// optional fused bias/relu epilogue (the rewrite pass's compiled
    /// form of trailing `broadcast+add` / `maximum(0)` instructions).
    /// `v` is the microkernel/blocking variant the autotuner resolved
    /// for this step's shape class at compile time (the canonical
    /// variant when tuning is off) — execution never re-measures.
    Dot {
        a: usize,
        b: usize,
        out: usize,
        m: usize,
        n: usize,
        k: usize,
        epi: StepEpi,
        v: GemmVariant,
    },
    /// A whole conv-as-shifted-multiply-add chain collapsed to one
    /// im2col-gathered GEMM: weights `[m,k]` × the virtual `[k,n]`
    /// im2col view of the padded image in slot `img` (`f32`-chain
    /// accumulation — bit-identical to the elementwise sweep it
    /// replaces).
    Im2colGemm {
        w: usize,
        img: usize,
        out: usize,
        m: usize,
        n: usize,
        k: usize,
        spec: Im2colSpec,
        v: GemmVariant,
    },
    /// A `convert(bf16) → convert(f32) → dot` subgraph collapsed to one
    /// step on the **bf16 packed engine**
    /// ([`crate::blas::bf16_gemm`]): both rounding converts are fused
    /// into the pair-interleaved panel packers, the rank-2 microkernel
    /// accumulates in the widened contract — bit-identical to the
    /// interpreter executing the three instructions separately. When an
    /// operand slot holds a raw-bf16 request input
    /// ([`PlanInput::Bf16`]), the bits feed the packers directly (no
    /// widening staging at all). Trailing bias/relu chains fuse into the
    /// writeback epilogue exactly like the f32 `Dot` step.
    DotBf16 {
        a: usize,
        b: usize,
        out: usize,
        m: usize,
        n: usize,
        k: usize,
        epi: StepEpi,
        v: GemmVariant,
    },
    /// A batched real-signal DFT — the lowered complex matmul
    /// `(xr + i·xi)·(Fr + i·Fi)` — collapsed from its four real dots
    /// plus `±` combines into **one step over pre-packed Fourier
    /// panels** ([`DftPanels`], packed once at compile time from the
    /// graph's constant twiddle matrices and pinned beside the plan).
    /// Executes four blocked GEMMs reusing the packed re/im B panels
    /// (zero per-request B packing) with the `±` combination fused into
    /// the last two writebacks
    /// ([`Epilogue::DftCombine`](crate::blas::block_gemm::Epilogue)) —
    /// bit-identical to the interpreter running the seven instructions
    /// separately. Writes `yr` to `out_re` and `yi` to `out_im`.
    DftGemm {
        xr: usize,
        xi: usize,
        out_re: usize,
        out_im: usize,
        m: usize,
        n: usize,
        k: usize,
        /// Index into [`Plan::dft_panels`].
        panels: usize,
        v: GemmVariant,
    },
    /// A calibrated dot (plus any fused bias/relu tail) lowered onto the
    /// **int8 rank-4 quantized engine** ([`crate::blas::i8_gemm`]): the
    /// whole quantize→dot→dequantize pipeline runs inside one step —
    /// both f32 operands are affine-quantized (signed-i8 lhs /
    /// unsigned-u8 rhs, the `xvi8ger4` §II-B.2 split, parameters from
    /// the model's calibration record) *during* panel packing, the
    /// rank-4 wrapping i32 dot is bitwise the Machine's `xvi8ger4pp`
    /// chain, and the C writeback dequantizes with the exact zero-point
    /// correction before applying the epilogue.
    DotI8 {
        a: usize,
        b: usize,
        out: usize,
        m: usize,
        n: usize,
        k: usize,
        epi: StepEpi,
        q: QuantParams,
        v: GemmVariant,
    },
    /// Affine gather (`broadcast` / `slice`).
    Gather { src: usize, out: usize, spec: GatherSpec },
}

/// One instruction's arena assignment — exposed so tests and tools can
/// audit the allocator (see the no-aliasing invariant on
/// [`Plan::assignments`]).
#[derive(Clone, Debug)]
pub struct SlotAssign {
    /// Index of the instruction in the entry computation.
    pub instr: usize,
    /// HLO instruction name (for diagnostics).
    pub name: String,
    /// Arena slot the value was assigned.
    pub slot: usize,
    /// Value size in elements.
    pub elems: usize,
    /// Instruction index at which the value is defined.
    pub def: usize,
    /// Instruction index of the last consumer (`usize::MAX` when the
    /// value is a request output and stays live to the end).
    pub last_use: usize,
    /// Whether the slot is pinned (constants): baked at buffer creation,
    /// never recycled — the compile-time recycler asserts this.
    pub pinned: bool,
}

/// A compiled execution plan: topologically-ordered steps over a
/// preallocated buffer arena. Build with [`Plan::compile`], execute with
/// [`Plan::execute_into`] against reusable [`ExecBuffers`].
#[derive(Debug)]
pub struct Plan {
    steps: Vec<Step>,
    /// Constant payloads baked into their slots at buffer creation;
    /// their slots are pinned (never recycled, never rewritten).
    consts: Vec<(usize, Vec<f32>)>,
    slot_caps: Vec<usize>,
    /// Output values: `(slot, dims)` per ROOT (tuple) element.
    root: Vec<(usize, Vec<usize>)>,
    num_params: usize,
    assigns: Vec<SlotAssign>,
    /// Largest `m`/`n`/`k` over all dot steps (sizes the GEMM scratch).
    max_dot: (usize, usize, usize),
    /// Largest `m`/`n`/`k` over all `DotBf16` steps (sizes the bf16
    /// packed-panel scratch).
    max_bf16: (usize, usize, usize),
    /// Largest `m`/`n`/`k` over all `DotI8` steps (sizes the int8
    /// packed-panel scratch).
    max_i8: (usize, usize, usize),
    /// Per-parameter: true when every read of the parameter's value is a
    /// `DotBf16` operand, so a raw-bf16 request input
    /// ([`PlanInput::Bf16`]) can feed the packers directly — no widening
    /// copy into the arena at all (see [`Plan::run_steps_typed`]).
    param_pack_bf16: Vec<bool>,
    /// Accumulation contract every `DotBf16` step executes under (from
    /// [`PlanOptions`]).
    bf16_accum: Bf16Accum,
    /// Pre-packed Fourier-matrix panel pairs, one per `DftGemm` step
    /// (indexed by the step's `panels` field): packed once at compile
    /// time from the graph's constant twiddle matrices for the step's
    /// exact variant geometry, pinned here for the plan's lifetime — the
    /// constants themselves are dead after fusion and never enter the
    /// arena.
    dft_panels: Vec<DftPanels>,
}

/// Compile-time options for [`Plan::compile_with_options`].
#[derive(Clone, Debug, Default)]
pub struct PlanOptions {
    /// Accumulation contract for `DotBf16` steps: the default
    /// [`Bf16Accum::Widened`] (f64 image, checked against
    /// `gemm_bf16_reference`) or [`Bf16Accum::F32Pairs`] (the paper's
    /// §IV-B `xvbf16ger2pp` rank-2 f32 chain, checked against
    /// `gemm_bf16_reference_pairs`) — the serving-mode switch behind
    /// `power-mma serve --bf16-accum`.
    pub bf16_accum: Bf16Accum,
    /// Per-tensor int8 calibration (`Some` = int8 serving mode, the
    /// switch behind `power-mma serve --dtype int8`): every `{1}×{0}`
    /// rank-2 dot whose lhs has a *signed* entry and whose rhs has an
    /// *unsigned* entry — by HLO instruction name — lowers to a
    /// [`Step::DotI8`] on the quantized rank-4 engine, bias/relu tails
    /// included. Uncalibrated dots keep their f32 lowering.
    pub int8_calib: Option<Int8Calib>,
    /// Shape-autotuning table (normally [`Device::tune`]
    /// (super::device::Device::tune), installed via
    /// `HloPlanBackend::with_tuning`): when set, every fused GEMM step's
    /// `(m, n, k, dtype, epilogue)` class is resolved through
    /// [`TuneTable::choose`] at compile time and the winning variant is
    /// baked into the step. `None` (the default, and the `--no-tune`
    /// escape hatch) compiles the deterministic heuristic default —
    /// byte-for-byte the pre-autotuner engine configuration. Either way
    /// the bits are identical; only speed can differ.
    pub tune: Option<std::sync::Arc<TuneTable>>,
}

/// Reusable per-model execution state: the arena slots, the GEMM
/// scratch of each engine (f32, packed bf16, packed i8/u8), and the
/// per-request raw-input routing table. One `ExecBuffers` serves any number of
/// sequential requests with no allocation; create with
/// [`Plan::new_buffers`].
pub struct ExecBuffers {
    slots: Vec<Vec<f32>>,
    scratch: GemmScratch,
    bf16_scratch: Bf16Scratch,
    i8_scratch: I8Scratch,
    /// Per-slot: `param index + 1` while the slot logically holds a
    /// raw-bf16 request input that skipped its widening copy (consumed
    /// directly by `DotBf16` packers), 0 otherwise. Reset each request.
    raw_param: Vec<u32>,
    /// Staging for the two cross-products of a `DftGemm` step
    /// (`xi·Fi` then `xi·Fr`, `2·m·n` elements) — combined into the
    /// output slots by the fused `±` writeback of the last two GEMMs.
    dft_tmp: Vec<f32>,
}

/// One typed request input at the plan boundary: the dtype-aware
/// counterpart of the flat `&[f32]` the legacy entry points take.
/// `Bf16` carries raw bf16 bits (the `DTypeSlice::Bf16` storage of the
/// device API): for a parameter consumed only by `DotBf16` steps the
/// bits feed the pair-interleaved panel packers directly — **no f32
/// widening anywhere on the path** — and for any other parameter they
/// are widened exactly into the arena slot (still no staging
/// allocation).
#[derive(Clone, Copy, Debug)]
pub enum PlanInput<'a> {
    /// Flat row-major f32 storage.
    F32(&'a [f32]),
    /// Flat row-major raw bf16 bits.
    Bf16(&'a [u16]),
}

impl PlanInput<'_> {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            PlanInput::F32(s) => s.len(),
            PlanInput::Bf16(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for d in (0..dims.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * dims[d + 1];
    }
    s
}

/// Pick an arena slot of at least `want` elements: best-fit from the free
/// list, else grow the largest free slot, else open a new slot.
fn alloc_slot(want: usize, caps: &mut Vec<usize>, free: &mut Vec<usize>) -> usize {
    let best = free
        .iter()
        .enumerate()
        .filter(|&(_, &s)| caps[s] >= want)
        .min_by_key(|&(_, &s)| caps[s])
        .map(|(p, _)| p);
    if let Some(p) = best {
        return free.swap_remove(p);
    }
    let largest = free.iter().enumerate().max_by_key(|&(_, &s)| caps[s]).map(|(p, _)| p);
    if let Some(p) = largest {
        let s = free.swap_remove(p);
        caps[s] = want;
        return s;
    }
    caps.push(want);
    caps.len() - 1
}

// ---------------------------------------------------------------------
// The pattern-rewrite pass: recognize conv-as-shifted-multiply-add
// chains and dot bias/relu tails on the *instruction graph* (before
// arena assignment) and replace each with one fused GEMM step. Interior
// nodes of a match are consumed — they must be single-use, `f32`, and
// not request outputs, so skipping them cannot change any observable
// value. Anything that does not match falls back to the elementwise
// lowering unchanged (and keeps its full compile-time validation).
// ---------------------------------------------------------------------

/// A fusion decision for one root instruction.
enum Fuse {
    /// A shifted multiply-add conv chain: `out[m,h,w] = Σ_k W[:,k] ⊗
    /// window_k(img)` becomes one im2col GEMM over inputs `(w, img)`.
    Conv { w: usize, img: usize, m: usize, n: usize, k: usize, spec: Im2colSpec },
    /// `dot` + broadcast-bias `add` (+ `maximum(0)`): one epilogued dot
    /// over inputs `(a, b, bias)`.
    DotEpi { a: usize, b: usize, bias: usize, relu: bool, m: usize, n: usize, k: usize },
    /// A dot over two `convert(bf16) → convert(f32)` chains (plus any
    /// broadcast-bias `add` / `maximum(0)` tail): one packed bf16 GEMM
    /// over inputs `(a, b[, bias])`, the rounding fused into packing and
    /// the tail into the writeback epilogue.
    DotBf16 { a: usize, b: usize, bias: Option<usize>, relu: bool, m: usize, n: usize, k: usize },
    /// The lowered complex matmul of a batched DFT: the four real dots
    /// of `(xr + i·xi)·(Fr + i·Fi)` plus the `±` combines collapsed to
    /// one split re/im packed-panel step over inputs `(xr, xi)`. `fr` /
    /// `fi` are the constant twiddle-matrix instructions (packed at
    /// compile time, dead thereafter); `im` is the companion
    /// imaginary-part `add` (the second root), marked [`Fuse::DftIm`]
    /// by `rewrite`.
    Dft { xr: usize, xi: usize, fr: usize, fi: usize, im: usize, m: usize, n: usize, k: usize },
    /// The imaginary-part root of a matched [`Fuse::Dft`]: its value is
    /// written by the real root's `DftGemm` step into a slot that arm
    /// pre-assigns, so this instruction compiles to no step at all.
    DftIm,
    /// A calibrated dot (with any bias/relu tail) routed to the int8
    /// rank-4 quantized engine: quantize→dot→dequantize in one step.
    DotI8 {
        a: usize,
        b: usize,
        bias: Option<usize>,
        relu: bool,
        m: usize,
        n: usize,
        k: usize,
        q: QuantParams,
    },
}

impl Fuse {
    /// The instructions whose values the fused step reads.
    fn inputs(&self) -> Vec<usize> {
        match self {
            Fuse::Conv { w, img, .. } => vec![*w, *img],
            Fuse::DotEpi { a, b, bias, .. } => vec![*a, *b, *bias],
            Fuse::DotBf16 { a, b, bias, .. } | Fuse::DotI8 { a, b, bias, .. } => {
                let mut v = vec![*a, *b];
                if let Some(s) = bias {
                    v.push(*s);
                }
                v
            }
            Fuse::Dft { xr, xi, .. } => vec![*xr, *xi],
            Fuse::DftIm => vec![],
        }
    }
}

/// One matched conv tap: column `t` of the weight matrix times the
/// image window at offset `off = (c, dy, dx)`.
struct Tap {
    w: usize,
    t: usize,
    img: usize,
    off: (usize, usize, usize),
    consumed: Vec<usize>,
}

fn build_users(instrs: &[Instr]) -> Vec<Vec<usize>> {
    let mut users: Vec<Vec<usize>> = vec![Vec::new(); instrs.len()];
    for (i, ins) in instrs.iter().enumerate() {
        for &op in &ins.operands {
            users[op].push(i);
        }
    }
    users
}

/// A shape-preserving no-op on flat data: `reshape` (element count kept)
/// or a broadcast whose axis map is the identity.
fn is_identity(instrs: &[Instr], idx: usize) -> bool {
    let ins = &instrs[idx];
    let Some(&src) = ins.operands.first() else {
        return false;
    };
    match ins.opcode.as_str() {
        "reshape" => {
            instrs[src].dims.iter().product::<usize>() == ins.dims.iter().product::<usize>()
        }
        "broadcast" => {
            instrs[src].dims == ins.dims
                && matches!(&ins.dims_attr, Some(d) if d.len() == ins.dims.len()
                    && d.iter().enumerate().all(|(ax, &v)| v == ax))
        }
        _ => false,
    }
}

/// Walk through single-use identity nodes; returns the base value and
/// the peeled (consumable) nodes, or `None` if a chain node is shared.
fn peel(instrs: &[Instr], users: &[Vec<usize>], mut idx: usize) -> Option<(usize, Vec<usize>)> {
    let mut consumed = Vec::new();
    while is_identity(instrs, idx) {
        if users[idx].len() != 1 {
            return None;
        }
        consumed.push(idx);
        idx = instrs[idx].operands[0];
    }
    Some((idx, consumed))
}

fn unit_bound(b: &(usize, usize, usize)) -> bool {
    b.2 == 1 && b.0.checked_add(1) == Some(b.1)
}

/// The weight side of a tap: `broadcast(vec[m] → [m,h,w], dims={0})`
/// over an identity chain down to `slice(W)[0:m, t:t+1]`.
fn match_w_side(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
    out_dims: &[usize],
) -> Option<(usize, usize, Vec<usize>)> {
    let ins = &instrs[idx];
    if ins.opcode != "broadcast" || users[idx].len() != 1 || ins.dims != out_dims {
        return None;
    }
    if ins.dims_attr.as_deref() != Some(&[0usize][..]) {
        return None;
    }
    let src = *ins.operands.first()?;
    if instrs[src].dims != [out_dims[0]] {
        return None;
    }
    let (base, mut consumed) = peel(instrs, users, src)?;
    let sl = &instrs[base];
    if sl.opcode != "slice" || users[base].len() != 1 {
        return None;
    }
    let wsrc = *sl.operands.first()?;
    let wdims = &instrs[wsrc].dims;
    let b = sl.slice_bounds.as_ref()?;
    if wdims.len() != 2 || b.len() != 2 || wdims[0] != out_dims[0] {
        return None;
    }
    if b[0] != (0, wdims[0], 1) || !unit_bound(&b[1]) {
        return None;
    }
    consumed.push(idx);
    consumed.push(base);
    Some((wsrc, b[1].0, consumed))
}

/// The image side of a tap: `broadcast([h,w] → [m,h,w], dims={1,2})`
/// over an identity chain down to the shifted window
/// `slice(img)[c:c+1, dy:dy+h, dx:dx+w]`.
fn match_i_side(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
    out_dims: &[usize],
) -> Option<(usize, (usize, usize, usize), Vec<usize>)> {
    let ins = &instrs[idx];
    if ins.opcode != "broadcast" || users[idx].len() != 1 || ins.dims != out_dims {
        return None;
    }
    if ins.dims_attr.as_deref() != Some(&[1usize, 2][..]) {
        return None;
    }
    let src = *ins.operands.first()?;
    if instrs[src].dims != out_dims[1..] {
        return None;
    }
    let (base, mut consumed) = peel(instrs, users, src)?;
    let sl = &instrs[base];
    if sl.opcode != "slice" || users[base].len() != 1 {
        return None;
    }
    let isrc = *sl.operands.first()?;
    if instrs[isrc].dims.len() != 3 {
        return None;
    }
    let b = sl.slice_bounds.as_ref()?;
    if b.len() != 3 || !unit_bound(&b[0]) || b[1].2 != 1 || b[2].2 != 1 {
        return None;
    }
    // window extents must equal the output spatial dims (checked without
    // subtraction: a malformed stop < start must not underflow)
    if b[1].0.checked_add(out_dims[1]) != Some(b[1].1)
        || b[2].0.checked_add(out_dims[2]) != Some(b[2].1)
    {
        return None;
    }
    consumed.push(idx);
    consumed.push(base);
    Some((isrc, (b[0].0, b[1].0, b[2].0), consumed))
}

/// One conv tap: a single-use `multiply` of a weight side and an image
/// side (either operand order — `f32` multiplication commutes bitwise).
fn match_tap(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
    out_dims: &[usize],
) -> Option<Tap> {
    let ins = &instrs[idx];
    if ins.opcode != "multiply" || users[idx].len() != 1 || ins.dims != out_dims {
        return None;
    }
    let (x, y) = (*ins.operands.first()?, *ins.operands.get(1)?);
    for (ws, is) in [(x, y), (y, x)] {
        if let (Some((w, t, wc)), Some((img, off, ic))) = (
            match_w_side(instrs, users, ws, out_dims),
            match_i_side(instrs, users, is, out_dims),
        ) {
            let mut consumed = vec![idx];
            consumed.extend(wc);
            consumed.extend(ic);
            return Some(Tap { w, t, img, off, consumed });
        }
    }
    None
}

/// Match a whole shifted multiply-add conv chain rooted at `add` `i`:
/// flatten the chain of single-use `add`s, match every term as a [`Tap`],
/// and require the taps to walk the weight columns `0..k` in chain order
/// over one shared weight matrix and one shared padded image — exactly
/// the graph `conv2d_k3` lowers to.
fn match_conv(instrs: &[Instr], users: &[Vec<usize>], i: usize) -> Option<(Fuse, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode != "add" || ins.dims.len() != 3 {
        return None;
    }
    let out_dims = ins.dims.clone();
    let mut taps_rev: Vec<Tap> = Vec::new();
    let mut consumed: Vec<usize> = Vec::new();
    let mut cur = i;
    loop {
        let (l, r) = (*instrs[cur].operands.first()?, *instrs[cur].operands.get(1)?);
        // interior chain adds must carry the output shape too, so a
        // shape-mismatched (malformed) chain falls back to the strict
        // elementwise lowering instead of being silently consumed
        let is_chain = |x: usize| {
            instrs[x].opcode == "add" && users[x].len() == 1 && instrs[x].dims == out_dims
        };
        let (cont, tap_op) = if is_chain(l) {
            (Some(l), r)
        } else if is_chain(r) {
            (Some(r), l)
        } else {
            (None, r)
        };
        match cont {
            Some(c) => {
                taps_rev.push(match_tap(instrs, users, tap_op, &out_dims)?);
                consumed.push(c);
                cur = c;
            }
            None => {
                // chain start: both operands are taps (first two products
                // commute bitwise under f32 addition, so either order
                // yields the interpreter's exact chain)
                taps_rev.push(match_tap(instrs, users, r, &out_dims)?);
                taps_rev.push(match_tap(instrs, users, l, &out_dims)?);
                break;
            }
        }
    }
    taps_rev.reverse();
    let taps = taps_rev;
    let (w, img) = (taps[0].w, taps[0].img);
    if taps.iter().any(|t| t.w != w || t.img != img) {
        return None;
    }
    // tap j must read weight column j: the GEMM consumes W as-is
    if taps.iter().enumerate().any(|(j, t)| t.t != j) {
        return None;
    }
    let k = taps.len();
    let (wdims, idims) = (&instrs[w].dims, &instrs[img].dims);
    if *wdims != [out_dims[0], k] || idims.len() != 3 {
        return None;
    }
    let (cin, ih, iw) = (idims[0], idims[1], idims[2]);
    let (h, wout) = (out_dims[1], out_dims[2]);
    for t in &taps {
        let (c, dy, dx) = t.off;
        if c >= cin || dy + h > ih || dx + wout > iw {
            return None;
        }
    }
    let bases = taps.iter().map(|t| t.off.0 * ih * iw + t.off.1 * iw + t.off.2).collect();
    for t in taps {
        consumed.extend(t.consumed);
    }
    let fuse = Fuse::Conv {
        w,
        img,
        m: out_dims[0],
        n: h * wout,
        k,
        spec: Im2colSpec { bases, img_w: iw, out_w: wout },
    };
    Some((fuse, consumed))
}

/// A fusable `dot`: single-use, rank-2, the `{1}×{0}` contraction the
/// plan supports.
fn match_fusable_dot(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
) -> Option<(usize, usize, usize, usize, usize)> {
    let d = &instrs[idx];
    if d.opcode != "dot" || users[idx].len() != 1 {
        return None;
    }
    if d.lhs_contracting != Some(1) || d.rhs_contracting != Some(0) {
        return None;
    }
    let (a, b) = (*d.operands.first()?, *d.operands.get(1)?);
    let (ad, bd) = (&instrs[a].dims, &instrs[b].dims);
    if ad.len() != 2 || bd.len() != 2 || ad[1] != bd[0] || d.dims != [ad[0], bd[1]] {
        return None;
    }
    Some((a, b, ad[0], bd[1], ad[1]))
}

/// `add(dot, broadcast(bias[n], dims={1}))` in either operand order
/// (f32 addition commutes bitwise). Returns the dot's operands/shape,
/// the bias source, and the consumed interior nodes.
#[allow(clippy::type_complexity)]
fn match_bias_add(
    instrs: &[Instr],
    users: &[Vec<usize>],
    i: usize,
) -> Option<(usize, usize, usize, usize, usize, usize, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode != "add" || ins.dims.len() != 2 {
        return None;
    }
    let (p0, p1) = (*ins.operands.first()?, *ins.operands.get(1)?);
    for (p, q) in [(p0, p1), (p1, p0)] {
        let Some((a, b, m, n, k)) = match_fusable_dot(instrs, users, p) else {
            continue;
        };
        // the add must produce exactly the dot's shape — fusing a
        // shape-mismatched add would skip the elementwise validation the
        // unfused lowering performs (and mis-size the output slot)
        if ins.dims != [m, n] {
            continue;
        }
        let bb = &instrs[q];
        if bb.opcode != "broadcast" || users[q].len() != 1 || bb.dims != ins.dims {
            continue;
        }
        if bb.dims_attr.as_deref() != Some(&[1usize][..]) {
            continue;
        }
        let Some(&src) = bb.operands.first() else {
            continue;
        };
        if instrs[src].dims != [n] {
            continue;
        }
        let Some((bias, chain)) = peel(instrs, users, src) else {
            continue;
        };
        let mut consumed = vec![p, q];
        consumed.extend(chain);
        return Some((a, b, m, n, k, bias, consumed));
    }
    None
}

/// `broadcast(constant(+0.0), dimensions={})` — the relu threshold.
fn is_zero_broadcast(instrs: &[Instr], users: &[Vec<usize>], idx: usize) -> bool {
    let ins = &instrs[idx];
    ins.opcode == "broadcast"
        && users[idx].len() == 1
        && matches!(ins.dims_attr.as_deref(), Some(d) if d.is_empty())
        && ins.operands.first().is_some_and(|&c| {
            let cst = &instrs[c];
            cst.opcode == "constant"
                && cst.dims.is_empty()
                && cst.const_vals.len() == 1
                && cst.const_vals[0].to_bits() == 0.0f32.to_bits()
        })
}

/// Match a dot-epilogue tail rooted at `i`: `add(dot, bias)` →
/// [`Fuse::DotEpi`] with `relu: false`, or `maximum(add(dot, bias),
/// broadcast(0))` → `relu: true`. The `maximum`'s operand order is
/// required (value first): `max(-0.0, 0.0)` and `max(0.0, -0.0)` differ
/// bitwise, and the epilogue computes `v.max(0.0)`.
fn match_dot_epi(instrs: &[Instr], users: &[Vec<usize>], i: usize) -> Option<(Fuse, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode == "maximum" && ins.dims.len() == 2 {
        let (x, z) = (*ins.operands.first()?, *ins.operands.get(1)?);
        if instrs[z].dims != ins.dims || !is_zero_broadcast(instrs, users, z) {
            return None;
        }
        if instrs[x].opcode != "add" || users[x].len() != 1 || instrs[x].dims != ins.dims {
            return None;
        }
        let (a, b, m, n, k, bias, mut consumed) = match_bias_add(instrs, users, x)?;
        consumed.push(x);
        consumed.push(z);
        return Some((Fuse::DotEpi { a, b, bias, relu: true, m, n, k }, consumed));
    }
    if ins.opcode == "add" {
        let (a, b, m, n, k, bias, consumed) = match_bias_add(instrs, users, i)?;
        return Some((Fuse::DotEpi { a, b, bias, relu: false, m, n, k }, consumed));
    }
    None
}

/// One side of a bf16 dot: a single-use `convert` to f32 over a
/// single-use `convert` to bf16 over an f32 base value, every link
/// shape-preserving — the round-trip XLA emits for a bf16 matmul over
/// f32 storage. Returns the base and the two consumed converts.
fn match_bf16_side(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
) -> Option<(usize, Vec<usize>)> {
    let outer = &instrs[idx];
    if outer.opcode != "convert" || outer.dtype != DType::F32 || users[idx].len() != 1 {
        return None;
    }
    let inner_i = *outer.operands.first()?;
    let inner = &instrs[inner_i];
    if inner.opcode != "convert" || inner.dtype != DType::Bf16 || users[inner_i].len() != 1 {
        return None;
    }
    let base = *inner.operands.first()?;
    if instrs[base].dtype != DType::F32 {
        return None;
    }
    // converts preserve shape; require it so the dot's m/n/k derived
    // from the base are the validated ones
    if inner.dims != outer.dims || instrs[base].dims != outer.dims {
        return None;
    }
    Some((base, vec![idx, inner_i]))
}

/// Match a bf16 dot rooted at `i`: `dot(convert_f32(convert_bf16(a)),
/// convert_f32(convert_bf16(b)))` with the `{1}×{0}` rank-2 contraction
/// the plan supports. Both sides must round (a mixed f32/bf16 dot has no
/// packed-kernel equivalent and falls back to the elementwise lowering).
/// The dot itself is the fusion root — it may be multi-use or a request
/// output; only the four interior converts are consumed.
fn match_dot_bf16(instrs: &[Instr], users: &[Vec<usize>], i: usize) -> Option<(Fuse, Vec<usize>)> {
    let d = &instrs[i];
    if d.opcode != "dot" {
        return None;
    }
    if d.lhs_contracting != Some(1) || d.rhs_contracting != Some(0) {
        return None;
    }
    let (x, y) = (*d.operands.first()?, *d.operands.get(1)?);
    let (a, ca) = match_bf16_side(instrs, users, x)?;
    let (b, cb) = match_bf16_side(instrs, users, y)?;
    let (ad, bd) = (&instrs[a].dims, &instrs[b].dims);
    if ad.len() != 2 || bd.len() != 2 || ad[1] != bd[0] || d.dims != [ad[0], bd[1]] {
        return None;
    }
    let mut consumed = ca;
    consumed.extend(cb);
    Some((
        Fuse::DotBf16 { a, b, bias: None, relu: false, m: ad[0], n: bd[1], k: ad[1] },
        consumed,
    ))
}

/// `add(bf16-round-trip dot, broadcast(bias[n], dims={1}))` in either
/// operand order — the bf16 twin of [`match_bias_add`]. The dot must be
/// single-use (it is consumed along with its four interior converts).
#[allow(clippy::type_complexity)]
fn match_bf16_bias_add(
    instrs: &[Instr],
    users: &[Vec<usize>],
    i: usize,
) -> Option<(usize, usize, usize, usize, usize, usize, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode != "add" || ins.dims.len() != 2 {
        return None;
    }
    let (p0, p1) = (*ins.operands.first()?, *ins.operands.get(1)?);
    for (p, q) in [(p0, p1), (p1, p0)] {
        if users[p].len() != 1 {
            continue;
        }
        let Some((Fuse::DotBf16 { a, b, m, n, k, .. }, dot_consumed)) =
            match_dot_bf16(instrs, users, p)
        else {
            continue;
        };
        if ins.dims != [m, n] {
            continue;
        }
        let bb = &instrs[q];
        if bb.opcode != "broadcast" || users[q].len() != 1 || bb.dims != ins.dims {
            continue;
        }
        if bb.dims_attr.as_deref() != Some(&[1usize][..]) {
            continue;
        }
        let Some(&src) = bb.operands.first() else {
            continue;
        };
        if instrs[src].dims != [n] {
            continue;
        }
        let Some((bias, chain)) = peel(instrs, users, src) else {
            continue;
        };
        let mut consumed = vec![p, q];
        consumed.extend(dot_consumed);
        consumed.extend(chain);
        return Some((a, b, m, n, k, bias, consumed));
    }
    None
}

/// Match a bias/relu tail behind a **bf16 round-trip dot** rooted at
/// `i` — the composition of [`match_dot_bf16`] and [`match_dot_epi`]:
/// `add(dot_bf16, bias)` or `maximum(add(dot_bf16, bias), broadcast(0))`
/// collapses to one `DotBf16` step with the tail fused into the packed
/// engine's writeback epilogue. Must run *before* [`match_dot_epi`] in
/// the matcher chain: the plain matcher would accept the same `add`
/// (the round-trip dot's operands are rank-2 f32 converts) and strand
/// the converts as materialized steps.
fn match_dot_bf16_epi(
    instrs: &[Instr],
    users: &[Vec<usize>],
    i: usize,
) -> Option<(Fuse, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode == "maximum" && ins.dims.len() == 2 {
        let (x, z) = (*ins.operands.first()?, *ins.operands.get(1)?);
        if instrs[z].dims != ins.dims || !is_zero_broadcast(instrs, users, z) {
            return None;
        }
        if instrs[x].opcode != "add" || users[x].len() != 1 || instrs[x].dims != ins.dims {
            return None;
        }
        let (a, b, m, n, k, bias, mut consumed) = match_bf16_bias_add(instrs, users, x)?;
        consumed.push(x);
        consumed.push(z);
        return Some((Fuse::DotBf16 { a, b, bias: Some(bias), relu: true, m, n, k }, consumed));
    }
    if ins.opcode == "add" {
        let (a, b, m, n, k, bias, consumed) = match_bf16_bias_add(instrs, users, i)?;
        return Some((Fuse::DotBf16 { a, b, bias: Some(bias), relu: false, m, n, k }, consumed));
    }
    None
}

/// `broadcast(constant(-1), dimensions={})` of shape `dims` — the
/// negation the XLA `subtract` lowering multiplies by.
fn is_neg_one_broadcast(
    instrs: &[Instr],
    users: &[Vec<usize>],
    idx: usize,
    dims: &[usize],
) -> bool {
    let ins = &instrs[idx];
    ins.opcode == "broadcast"
        && ins.dims == dims
        && users[idx].len() == 1
        && matches!(ins.dims_attr.as_deref(), Some(d) if d.is_empty())
        && ins.operands.first().is_some_and(|&c| {
            let cst = &instrs[c];
            cst.opcode == "constant"
                && cst.dims.is_empty()
                && cst.const_vals.len() == 1
                && cst.const_vals[0].to_bits() == (-1.0f32).to_bits()
        })
}

/// Match the lowered batched-DFT structure rooted at the **real-part**
/// `add` `i`:
///
/// ```text
/// yr(i)  = add(dot(xr, Fr), multiply(dot(xi, Fi), broadcast(-1)))
/// yi(im) = add(dot(xr, Fi), dot(xi, Fr))     // sought at some im > i
/// ```
///
/// with `Fr`/`Fi` constant `k×n` matrices shared between the halves and
/// all four dots the `{1}×{0}` rank-2 contraction over the same
/// `(xr, xi)` pair. Both combines commute bitwise (IEEE `a − b ≡
/// a + (−1·b)` and f32 `add` is commutative), so either operand order
/// matches. Consumes the four dots, the multiply, and the `−1`
/// broadcast; the twiddle constants and the scalar `−1` die by DCE, and
/// the companion `yi` add is *not* consumed — `rewrite` marks it
/// [`Fuse::DftIm`] so it keeps its (root) slot without a step.
fn match_dft(instrs: &[Instr], users: &[Vec<usize>], i: usize) -> Option<(Fuse, Vec<usize>)> {
    let ins = &instrs[i];
    if ins.opcode != "add" || ins.dims.len() != 2 {
        return None;
    }
    let (p0, p1) = (*ins.operands.first()?, *ins.operands.get(1)?);
    for (dp, mp) in [(p0, p1), (p1, p0)] {
        // the positive half: dot(xr, Fr)
        let Some((xr, fr, m, n, k)) = match_fusable_dot(instrs, users, dp) else {
            continue;
        };
        if ins.dims != [m, n] {
            continue;
        }
        // the negated half: multiply(dot(xi, Fi), broadcast(-1)) —
        // either operand order
        let mul = &instrs[mp];
        if mul.opcode != "multiply" || users[mp].len() != 1 || mul.dims != ins.dims {
            continue;
        }
        let (q0, q1) = (*mul.operands.first()?, *mul.operands.get(1)?);
        for (bc, dii) in [(q0, q1), (q1, q0)] {
            if !is_neg_one_broadcast(instrs, users, bc, &ins.dims) {
                continue;
            }
            let Some((xi, fi, m2, n2, k2)) = match_fusable_dot(instrs, users, dii) else {
                continue;
            };
            if (m2, n2, k2) != (m, n, k) || xi == xr || fi == fr {
                continue;
            }
            // the twiddles must be graph constants: they are packed into
            // pinned panels at compile time and never enter the arena
            let is_twiddle = |c: usize| {
                instrs[c].opcode == "constant"
                    && instrs[c].dtype == DType::F32
                    && instrs[c].const_vals.len() == k * n
            };
            if !is_twiddle(fr) || !is_twiddle(fi) {
                continue;
            }
            // the companion imaginary root: add(dot(xr, Fi), dot(xi, Fr))
            // over the *same* four values, anywhere later in the program
            for (im, cand) in instrs.iter().enumerate().skip(i + 1) {
                if cand.opcode != "add" || cand.dims != ins.dims || cand.operands.len() != 2 {
                    continue;
                }
                let (c0, c1) = (cand.operands[0], cand.operands[1]);
                let matched = [(c0, c1), (c1, c0)].into_iter().any(|(u, v)| {
                    matches!(match_fusable_dot(instrs, users, u),
                             Some((x, f, mm, nn, kk)) if (x, f, mm, nn, kk) == (xr, fi, m, n, k))
                        && matches!(match_fusable_dot(instrs, users, v),
                             Some((x, f, mm, nn, kk)) if (x, f, mm, nn, kk) == (xi, fr, m, n, k))
                });
                if !matched {
                    continue;
                }
                let consumed = vec![dp, mp, bc, dii, c0, c1];
                return Some((Fuse::Dft { xr, xi, fr, fi, im, m, n, k }, consumed));
            }
        }
    }
    None
}

/// Both dot operands calibrated with the right `xvi8ger4` signedness
/// (signed lhs, unsigned rhs), looked up by HLO instruction name →
/// the step's [`QuantParams`]. `None` (f32 fallback) otherwise.
fn i8_quant_params(
    instrs: &[Instr],
    calib: &Int8Calib,
    a: usize,
    b: usize,
) -> Option<QuantParams> {
    if instrs[a].dtype != DType::F32 || instrs[b].dtype != DType::F32 {
        return None;
    }
    let ea = calib.get(&instrs[a].name)?;
    let eb = calib.get(&instrs[b].name)?;
    if !ea.signed || eb.signed {
        return None;
    }
    Some(QuantParams { a_scale: ea.scale, a_zp: ea.zp, b_scale: eb.scale, b_zp: eb.zp })
}

/// Match a quantizable dot rooted at `i` (int8 serving mode only): an
/// epilogued dot (`add(dot, bias)` / `maximum(add(dot, bias), 0)`) or a
/// bare `{1}×{0}` rank-2 dot, whose operands both carry calibration
/// entries of the right signedness. The bias/relu tail fuses *behind*
/// the dequantized writeback — quantize→dot→dequantize(+bias/relu) is
/// one step. A structurally-matching dot without calibration returns
/// `None` so the f32 matchers keep it.
fn match_dot_i8(
    instrs: &[Instr],
    users: &[Vec<usize>],
    i: usize,
    calib: Option<&Int8Calib>,
) -> Option<(Fuse, Vec<usize>)> {
    let calib = calib?;
    if let Some((Fuse::DotEpi { a, b, bias, relu, m, n, k }, consumed)) =
        match_dot_epi(instrs, users, i)
    {
        let q = i8_quant_params(instrs, calib, a, b)?;
        return Some((Fuse::DotI8 { a, b, bias: Some(bias), relu, m, n, k, q }, consumed));
    }
    // a bare calibrated dot: the dot itself is the root (it may be
    // multi-use or a request output), nothing is consumed
    let d = &instrs[i];
    if d.opcode != "dot" || d.lhs_contracting != Some(1) || d.rhs_contracting != Some(0) {
        return None;
    }
    let (a, b) = (*d.operands.first()?, *d.operands.get(1)?);
    let (ad, bd) = (&instrs[a].dims, &instrs[b].dims);
    if ad.len() != 2 || bd.len() != 2 || ad[1] != bd[0] || d.dims != [ad[0], bd[1]] {
        return None;
    }
    let q = i8_quant_params(instrs, calib, a, b)?;
    Some((
        Fuse::DotI8 { a, b, bias: None, relu: false, m: ad[0], n: bd[1], k: ad[1], q },
        vec![],
    ))
}

/// Run the rewrite over the whole entry computation (outermost roots
/// first, so a sub-chain never steals a match from the chain containing
/// it). Returns the per-instruction fusion decisions and the consumed
/// set; a match is dropped whenever consuming it would hide a request
/// output, a non-`f32` value, or a node another match already claimed.
/// In int8 serving mode (`calib` present) the quantized matcher runs
/// first, so a calibrated dot+bias tail becomes `DotI8` rather than the
/// f32 `DotEpi`.
fn rewrite(
    instrs: &[Instr],
    is_out: &[bool],
    calib: Option<&Int8Calib>,
) -> (Vec<Option<Fuse>>, Vec<bool>) {
    let users = build_users(instrs);
    let n = instrs.len();
    let mut fused: Vec<Option<Fuse>> = (0..n).map(|_| None).collect();
    let mut consumed = vec![false; n];
    for i in (0..n).rev() {
        if consumed[i] || instrs[i].dtype != DType::F32 {
            continue;
        }
        let m = match_dot_i8(instrs, &users, i, calib)
            .or_else(|| match_dft(instrs, &users, i))
            .or_else(|| match_dot_bf16_epi(instrs, &users, i))
            .or_else(|| match_dot_epi(instrs, &users, i))
            .or_else(|| match_conv(instrs, &users, i))
            .or_else(|| match_dot_bf16(instrs, &users, i));
        let Some((f, cons)) = m else {
            continue;
        };
        // a consumed interior must be invisible: not already claimed,
        // not a request output, and f32 — except the bf16 `convert`s the
        // DotBf16 matcher explicitly vouches for (their rounding is what
        // the fused step's packers reproduce)
        if cons.iter().any(|&c| {
            consumed[c]
                || is_out[c]
                || (instrs[c].dtype != DType::F32
                    && !(instrs[c].dtype == DType::Bf16 && instrs[c].opcode == "convert"))
        }) {
            continue;
        }
        // a DFT's imaginary root must still be free to take the marker
        // (the descending walk visits it before the real root, so a
        // competing claim would already be recorded)
        if let Fuse::Dft { im, .. } = f {
            if consumed[im] || fused[im].is_some() {
                continue;
            }
            fused[im] = Some(Fuse::DftIm);
        }
        for &c in &cons {
            consumed[c] = true;
        }
        fused[i] = Some(f);
    }
    (fused, consumed)
}

/// Which parameters may arrive as **raw bf16 bits** and skip the arena
/// entirely: walk the compiled steps tracking which arena slot currently
/// holds which parameter's value (a slot stops holding a parameter the
/// moment any other step writes it — slots are recycled), and demote a
/// parameter whenever anything but a `DotBf16` operand reads it. Request
/// outputs read the root slots at the end, so a parameter that *is* an
/// output also demotes. Raw inputs for the surviving parameters feed the
/// bf16 panel packers directly (bitwise identical to widening first:
/// packing canonicalizes NaNs exactly like round-after-widen does).
fn param_pack_flags(
    steps: &[Step],
    num_slots: usize,
    num_params: usize,
    root: &[(usize, Vec<usize>)],
) -> Vec<bool> {
    let mut ok = vec![true; num_params];
    let mut holder: Vec<Option<usize>> = vec![None; num_slots];
    for step in steps {
        // f32 reads demote; `DotBf16` operand reads are the one kind
        // that keeps a parameter packable (its packers accept raw bits —
        // though its fused *bias* is read in f32 at the writeback)
        let (reads, outs): (Vec<usize>, Vec<usize>) = match step {
            Step::Param { out, .. } => (vec![], vec![*out]),
            Step::Copy { src, out, .. } | Step::Bf16 { src, out, .. } => {
                (vec![*src], vec![*out])
            }
            Step::Binary { a, b, out, .. } => (vec![*a, *b], vec![*out]),
            Step::Dot { a, b, out, epi, .. } => {
                let mut r = vec![*a, *b];
                match epi {
                    StepEpi::Bias(s) | StepEpi::BiasRelu(s) => r.push(*s),
                    StepEpi::None => {}
                }
                (r, vec![*out])
            }
            Step::Im2colGemm { w, img, out, .. } => (vec![*w, *img], vec![*out]),
            Step::DotBf16 { out, epi, .. } => {
                let mut r = vec![];
                match epi {
                    StepEpi::Bias(s) | StepEpi::BiasRelu(s) => r.push(*s),
                    StepEpi::None => {}
                }
                (r, vec![*out])
            }
            Step::DotI8 { a, b, out, epi, .. } => {
                // DotI8 packers quantize from f32 slots, so its reads
                // demote like any other f32 read
                let mut r = vec![*a, *b];
                match epi {
                    StepEpi::Bias(s) | StepEpi::BiasRelu(s) => r.push(*s),
                    StepEpi::None => {}
                }
                (r, vec![*out])
            }
            Step::DftGemm { xr, xi, out_re, out_im, .. } => {
                (vec![*xr, *xi], vec![*out_re, *out_im])
            }
            Step::Gather { src, out, .. } => (vec![*src], vec![*out]),
        };
        for slot in reads {
            if let Some(p) = holder[slot] {
                ok[p] = false;
            }
        }
        for out in outs {
            holder[out] = match step {
                Step::Param { index, .. } => Some(*index),
                _ => None,
            };
        }
    }
    for (slot, _) in root {
        if let Some(p) = holder[*slot] {
            ok[p] = false; // the root copy-out reads f32
        }
    }
    ok
}

impl Plan {
    /// Lower a parsed module into an execution plan, performing every
    /// shape/attribute/operand validation the interpreter would do per
    /// request, then running the fusion rewrite (see the module docs).
    /// Fails on anything outside the serving op set. Uses the default
    /// [`PlanOptions`] (widened bf16 accumulation).
    pub fn compile(module: &HloModule) -> Result<Plan> {
        Plan::compile_with_options(module, PlanOptions::default())
    }

    /// [`Plan::compile`] with explicit [`PlanOptions`].
    pub fn compile_with_options(module: &HloModule, opts: PlanOptions) -> Result<Plan> {
        let instrs = &module.instrs;
        let n = instrs.len();

        let mut root_ids: Vec<usize> = Vec::new();
        for (i, ins) in instrs.iter().enumerate() {
            if ins.is_root {
                root_ids = if ins.opcode == "tuple" { ins.operands.clone() } else { vec![i] };
            }
        }
        if root_ids.is_empty() {
            bail!("entry computation has no ROOT instruction");
        }
        let mut is_out = vec![false; n];
        for &r in &root_ids {
            is_out[r] = true;
        }

        // -- rewrite: fuse conv chains and dot epilogue tails ------------
        let (fused, mut consumed) = rewrite(instrs, &is_out, opts.int8_calib.as_ref());

        // effective operands after fusion: what the emitted step actually
        // reads (fused roots read the fusion inputs; consumed interior
        // nodes read nothing — they never execute)
        let mut eff: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            eff.push(if consumed[i] {
                Vec::new()
            } else if let Some(f) = &fused[i] {
                f.inputs()
            } else {
                instrs[i].operands.clone()
            });
        }

        // dead-code elimination for fusion orphans: the only values a
        // match leaves dangling are constants (the relu zero — its
        // broadcast is consumed structurally by the matcher). Only a
        // *well-formed* constant is dropped, so compile-time strictness
        // is untouched: anything else dead still lowers and validates
        // (or bails) below.
        let mut use_cnt = vec![0usize; n];
        for ops in &eff {
            for &op in ops {
                use_cnt[op] += 1;
            }
        }
        for i in 0..n {
            let ins = &instrs[i];
            if consumed[i] || is_out[i] || use_cnt[i] > 0 {
                continue;
            }
            if ins.opcode == "constant"
                && ins.dtype == DType::F32
                && ins.const_vals.len() == ins.dims.iter().product::<usize>()
            {
                consumed[i] = true;
                eff[i].clear();
            }
        }

        // -- liveness: last consumer of every value ----------------------
        let mut last_use: Vec<usize> = (0..n).collect();
        for (i, ops) in eff.iter().enumerate() {
            for &op in ops {
                last_use[op] = last_use[op].max(i);
            }
        }
        for &r in &root_ids {
            last_use[r] = usize::MAX;
        }

        // -- lower instructions, assigning arena slots -------------------
        let mut slot_caps: Vec<usize> = Vec::new();
        // per-slot pin flags: a pinned (constant) slot must never reach
        // the recycler's free list — asserted at every free-list push
        let mut pinned_slot: Vec<bool> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_of: Vec<Option<usize>> = vec![None; n];
        let mut pinned: Vec<bool> = vec![false; n];
        let mut steps: Vec<Step> = Vec::new();
        let mut consts: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut assigns: Vec<SlotAssign> = Vec::new();
        let mut max_dot = (0usize, 0usize, 0usize);
        let mut max_bf16 = (0usize, 0usize, 0usize);
        let mut max_i8 = (0usize, 0usize, 0usize);
        let mut dft_panels: Vec<DftPanels> = Vec::new();

        // Recycle the slots of values whose last consumer is step `i`
        // (its operands, or an output nobody consumes). Runs only *after*
        // the output slot was taken, so an output never aliases a live
        // operand; pinned (constant) slots never free — the assert
        // enforces at compile what `Plan::assignments` lets tests audit.
        fn recycle(
            i: usize,
            eff_i: &[usize],
            last_use: &[usize],
            pinned: &[bool],
            pinned_slot: &[bool],
            slot_of: &mut [Option<usize>],
            free: &mut Vec<usize>,
        ) {
            for &op in eff_i.iter().chain(std::iter::once(&i)) {
                if last_use[op] == i && !pinned[op] {
                    if let Some(s) = slot_of[op].take() {
                        assert!(
                            !pinned_slot[s],
                            "arena recycler was handed pinned constant slot {s}"
                        );
                        free.push(s);
                    }
                }
            }
        }

        for (i, ins) in instrs.iter().enumerate() {
            if consumed[i] {
                continue; // interior of a fused subgraph: never executes
            }
            if ins.dtype == DType::Other {
                bail!("{}: unsupported element type", ins.name);
            }
            if ins.opcode == "tuple" {
                if !ins.is_root {
                    bail!("{}: tuple is only supported as ROOT", ins.name);
                }
                continue;
            }
            let want: usize = ins.dims.iter().product();

            // a fused root lowers to one GEMM step over the fusion inputs
            if let Some(f) = &fused[i] {
                // the imaginary DFT root's value is written by its
                // partner's DftGemm step into a slot that arm already
                // assigned (along with the SlotAssign): no step here
                if matches!(f, Fuse::DftIm) {
                    if slot_of[i].is_none() {
                        bail!("{}: DFT imaginary root has no pre-assigned slot", ins.name);
                    }
                    recycle(i, &eff[i], &last_use, &pinned, &pinned_slot, &mut slot_of, &mut free);
                    continue;
                }
                for &inp in &eff[i] {
                    if slot_of[inp].is_none() {
                        bail!("{}: fused input has no value", ins.name);
                    }
                }
                let out = alloc_slot(want, &mut slot_caps, &mut free);
                pinned_slot.resize(slot_caps.len(), false);
                slot_of[i] = Some(out);
                assigns.push(SlotAssign {
                    instr: i,
                    name: ins.name.clone(),
                    slot: out,
                    elems: want,
                    def: i,
                    last_use: last_use[i],
                    pinned: false,
                });
                match f {
                    Fuse::Conv { w, img, m, n: nn, k, spec } => {
                        max_dot = (max_dot.0.max(*m), max_dot.1.max(*nn), max_dot.2.max(*k));
                        // conv classes tune through the im2col modality:
                        // same shape as a plain dot, different panel
                        // sourcing — measured separately (PR 8 follow-up)
                        let v = tuned_variant(
                            &opts.tune,
                            *m,
                            *nn,
                            *k,
                            TuneDtype::F32,
                            TuneEpi::None,
                            TunePanel::Im2col,
                        );
                        steps.push(Step::Im2colGemm {
                            w: slot_of[*w].unwrap(),
                            img: slot_of[*img].unwrap(),
                            out,
                            m: *m,
                            n: *nn,
                            k: *k,
                            spec: spec.clone(),
                            v,
                        });
                    }
                    Fuse::DotEpi { a, b, bias, relu, m, n: nn, k } => {
                        max_dot = (max_dot.0.max(*m), max_dot.1.max(*nn), max_dot.2.max(*k));
                        let bias_slot = slot_of[*bias].unwrap();
                        let epi = if *relu {
                            StepEpi::BiasRelu(bias_slot)
                        } else {
                            StepEpi::Bias(bias_slot)
                        };
                        let v = tuned_variant(
                            &opts.tune,
                            *m,
                            *nn,
                            *k,
                            TuneDtype::F32,
                            epi.tune_epi(),
                            TunePanel::Matrix,
                        );
                        steps.push(Step::Dot {
                            a: slot_of[*a].unwrap(),
                            b: slot_of[*b].unwrap(),
                            out,
                            m: *m,
                            n: *nn,
                            k: *k,
                            epi,
                            v,
                        });
                    }
                    Fuse::DotBf16 { a, b, bias, relu, m, n: nn, k } => {
                        max_bf16 = (max_bf16.0.max(*m), max_bf16.1.max(*nn), max_bf16.2.max(*k));
                        let epi = match (bias, relu) {
                            (None, _) => StepEpi::None,
                            (Some(s), false) => StepEpi::Bias(slot_of[*s].unwrap()),
                            (Some(s), true) => StepEpi::BiasRelu(slot_of[*s].unwrap()),
                        };
                        let v = tuned_variant(
                            &opts.tune,
                            *m,
                            *nn,
                            *k,
                            TuneDtype::Bf16,
                            epi.tune_epi(),
                            TunePanel::Matrix,
                        );
                        steps.push(Step::DotBf16 {
                            a: slot_of[*a].unwrap(),
                            b: slot_of[*b].unwrap(),
                            out,
                            m: *m,
                            n: *nn,
                            k: *k,
                            epi,
                            v,
                        });
                    }
                    Fuse::Dft { xr, xi, fr, fi, im, m, n: nn, k } => {
                        max_dot = (max_dot.0.max(*m), max_dot.1.max(*nn), max_dot.2.max(*k));
                        // keyed (and measured) as the packed-panel
                        // complex dual-GEMM it actually executes, not as
                        // a single matrix-modality GEMM of this shape
                        let v = tuned_variant(
                            &opts.tune,
                            *m,
                            *nn,
                            *k,
                            TuneDtype::F32,
                            TuneEpi::None,
                            TunePanel::DftPacked,
                        );
                        // the imaginary root's slot, assigned here so the
                        // one DftGemm step can write both halves (its own
                        // compile turn skips allocation — see DftIm above)
                        let want_im: usize = instrs[*im].dims.iter().product();
                        let out_im = alloc_slot(want_im, &mut slot_caps, &mut free);
                        pinned_slot.resize(slot_caps.len(), false);
                        slot_of[*im] = Some(out_im);
                        assigns.push(SlotAssign {
                            instr: *im,
                            name: instrs[*im].name.clone(),
                            slot: out_im,
                            elems: want_im,
                            def: i,
                            last_use: last_use[*im],
                            pinned: false,
                        });
                        // pack the constant twiddle matrices once, for
                        // exactly this step's variant geometry; the
                        // constants are dead after this and never get
                        // arena slots
                        let panels = dft_panels.len();
                        dft_panels.push(DftPanels::pack(
                            &instrs[*fr].const_vals,
                            &instrs[*fi].const_vals,
                            *k,
                            *nn,
                            v.nr,
                            v.block.kc,
                        ));
                        steps.push(Step::DftGemm {
                            xr: slot_of[*xr].unwrap(),
                            xi: slot_of[*xi].unwrap(),
                            out_re: out,
                            out_im,
                            m: *m,
                            n: *nn,
                            k: *k,
                            panels,
                            v,
                        });
                    }
                    Fuse::DftIm => unreachable!("intercepted before the fused-root arm"),
                    Fuse::DotI8 { a, b, bias, relu, m, n: nn, k, q } => {
                        max_i8 = (max_i8.0.max(*m), max_i8.1.max(*nn), max_i8.2.max(*k));
                        let epi = match (bias, relu) {
                            (None, _) => StepEpi::None,
                            (Some(s), false) => StepEpi::Bias(slot_of[*s].unwrap()),
                            (Some(s), true) => StepEpi::BiasRelu(slot_of[*s].unwrap()),
                        };
                        let v = tuned_variant(
                            &opts.tune,
                            *m,
                            *nn,
                            *k,
                            TuneDtype::I8,
                            epi.tune_epi(),
                            TunePanel::Matrix,
                        );
                        steps.push(Step::DotI8 {
                            a: slot_of[*a].unwrap(),
                            b: slot_of[*b].unwrap(),
                            out,
                            m: *m,
                            n: *nn,
                            k: *k,
                            epi,
                            q: *q,
                            v,
                        });
                    }
                }
                recycle(i, &eff[i], &last_use, &pinned, &pinned_slot, &mut slot_of, &mut free);
                continue;
            }

            let need = match ins.opcode.as_str() {
                "dot" | "add" | "multiply" | "maximum" => 2,
                "convert" | "reshape" | "broadcast" | "slice" => 1,
                _ => 0,
            };
            if ins.operands.len() < need {
                bail!(
                    "{}: {} needs {need} operand(s), got {}",
                    ins.name,
                    ins.opcode,
                    ins.operands.len()
                );
            }
            for j in 0..need {
                if slot_of[ins.operands[j]].is_none() {
                    bail!("{}: operand has no value (tuple operand?)", ins.name);
                }
            }
            // Constants are baked into their slot when buffers are
            // created, so they are live from step 0 of *every* request:
            // they get a dedicated slot outside the recycling pool (a
            // recycled slot would be clobbered by whichever earlier step
            // previously owned it).
            let is_const = ins.opcode == "constant";
            let out = if is_const {
                slot_caps.push(want);
                pinned_slot.push(true);
                slot_caps.len() - 1
            } else {
                let s = alloc_slot(want, &mut slot_caps, &mut free);
                pinned_slot.resize(slot_caps.len(), false);
                s
            };
            slot_of[i] = Some(out);
            assigns.push(SlotAssign {
                instr: i,
                name: ins.name.clone(),
                slot: out,
                elems: want,
                def: if is_const { 0 } else { i },
                last_use: if is_const { usize::MAX } else { last_use[i] },
                pinned: is_const,
            });

            match ins.opcode.as_str() {
                "parameter" => {
                    steps.push(Step::Param { index: ins.param, len: want, out });
                }
                "constant" => {
                    if ins.const_vals.len() != want {
                        bail!(
                            "{}: constant has {} literals, shape wants {want}",
                            ins.name,
                            ins.const_vals.len()
                        );
                    }
                    pinned[i] = true;
                    consts.push((out, ins.const_vals.clone()));
                }
                "convert" => {
                    let srclen: usize = instrs[ins.operands[0]].dims.iter().product();
                    if srclen != want {
                        bail!(
                            "{}: convert operand has {srclen} elements, shape wants {want}",
                            ins.name
                        );
                    }
                    let src = slot_of[ins.operands[0]].unwrap();
                    steps.push(match ins.dtype {
                        DType::Bf16 => Step::Bf16 { src, len: want, out },
                        _ => Step::Copy { src, len: want, out },
                    });
                }
                "reshape" => {
                    let sdims = &instrs[ins.operands[0]].dims;
                    if sdims.iter().product::<usize>() != want {
                        bail!(
                            "{}: reshape {sdims:?} -> {:?} changes element count",
                            ins.name,
                            ins.dims
                        );
                    }
                    let src = slot_of[ins.operands[0]].unwrap();
                    steps.push(Step::Copy { src, len: want, out });
                }
                "add" | "multiply" | "maximum" => {
                    let (a, b) = (&instrs[ins.operands[0]], &instrs[ins.operands[1]]);
                    if a.dims != b.dims || a.dims != ins.dims {
                        bail!(
                            "{}: elementwise shape mismatch {:?} vs {:?} -> {:?}",
                            ins.name,
                            a.dims,
                            b.dims,
                            ins.dims
                        );
                    }
                    let op = match ins.opcode.as_str() {
                        "add" => BinOp::Add,
                        "multiply" => BinOp::Multiply,
                        _ => BinOp::Maximum,
                    };
                    steps.push(Step::Binary {
                        op,
                        a: slot_of[ins.operands[0]].unwrap(),
                        b: slot_of[ins.operands[1]].unwrap(),
                        len: want,
                        out,
                    });
                }
                "dot" => {
                    let (a, b) = (&instrs[ins.operands[0]], &instrs[ins.operands[1]]);
                    if a.dims.len() != 2 || b.dims.len() != 2 {
                        bail!(
                            "{}: only rank-2 dot supported, got {:?} x {:?}",
                            ins.name,
                            a.dims,
                            b.dims
                        );
                    }
                    if ins.lhs_contracting != Some(1) || ins.rhs_contracting != Some(0) {
                        bail!(
                            "{}: only lhs_contracting_dims={{1}} rhs_contracting_dims={{0}} supported",
                            ins.name
                        );
                    }
                    let (m, k) = (a.dims[0], a.dims[1]);
                    let (k2, nn) = (b.dims[0], b.dims[1]);
                    if k != k2 {
                        bail!("{}: contraction mismatch {k} vs {k2}", ins.name);
                    }
                    if ins.dims != [m, nn] {
                        bail!("{}: dot result shape {:?} != [{m},{nn}]", ins.name, ins.dims);
                    }
                    max_dot = (max_dot.0.max(m), max_dot.1.max(nn), max_dot.2.max(k));
                    let v = tuned_variant(
                        &opts.tune,
                        m,
                        nn,
                        k,
                        TuneDtype::F32,
                        TuneEpi::None,
                        TunePanel::Matrix,
                    );
                    steps.push(Step::Dot {
                        a: slot_of[ins.operands[0]].unwrap(),
                        b: slot_of[ins.operands[1]].unwrap(),
                        out,
                        m,
                        n: nn,
                        k,
                        epi: StepEpi::None,
                        v,
                    });
                }
                "broadcast" => {
                    let src = &instrs[ins.operands[0]];
                    let dims_attr = ins.dims_attr.clone().unwrap_or_default();
                    if dims_attr.len() != src.dims.len() {
                        bail!(
                            "{}: broadcast dimensions {:?} do not match source rank {}",
                            ins.name,
                            dims_attr,
                            src.dims.len()
                        );
                    }
                    let nd = ins.dims.len();
                    let sstrides = row_major_strides(&src.dims);
                    let mut coefs = vec![0usize; nd];
                    for (ax, &d) in dims_attr.iter().enumerate() {
                        if d >= nd {
                            bail!("{}: broadcast dimension {d} out of range", ins.name);
                        }
                        if src.dims[ax] != ins.dims[d] {
                            bail!(
                                "{}: broadcast source dim {ax} ({}) != output dim {d} ({})",
                                ins.name,
                                src.dims[ax],
                                ins.dims[d]
                            );
                        }
                        coefs[d] = sstrides[ax];
                    }
                    steps.push(Step::Gather {
                        src: slot_of[ins.operands[0]].unwrap(),
                        out,
                        spec: GatherSpec {
                            base: 0,
                            odims: ins.dims.clone(),
                            ostrides: row_major_strides(&ins.dims),
                            coefs,
                            len: want,
                        },
                    });
                }
                "slice" => {
                    let src = &instrs[ins.operands[0]];
                    let bounds = ins
                        .slice_bounds
                        .as_ref()
                        .ok_or_else(|| err!("{}: slice without slice attribute", ins.name))?;
                    if bounds.len() != src.dims.len() {
                        bail!(
                            "{}: {} slice bounds for rank-{} source",
                            ins.name,
                            bounds.len(),
                            src.dims.len()
                        );
                    }
                    let nd = src.dims.len();
                    let sstrides = row_major_strides(&src.dims);
                    let mut out_dims = Vec::with_capacity(nd);
                    let mut base = 0usize;
                    let mut coefs = Vec::with_capacity(nd);
                    for (d, &(start, stop, stride)) in bounds.iter().enumerate() {
                        if start > stop || stop > src.dims[d] {
                            bail!(
                                "{}: slice bound [{start}:{stop}] out of range for dim {d} ({})",
                                ins.name,
                                src.dims[d]
                            );
                        }
                        out_dims.push((stop - start).div_ceil(stride));
                        base += start * sstrides[d];
                        coefs.push(stride * sstrides[d]);
                    }
                    if out_dims != ins.dims {
                        bail!(
                            "{}: slice result {:?} != declared {:?}",
                            ins.name,
                            out_dims,
                            ins.dims
                        );
                    }
                    steps.push(Step::Gather {
                        src: slot_of[ins.operands[0]].unwrap(),
                        out,
                        spec: GatherSpec {
                            base,
                            ostrides: row_major_strides(&out_dims),
                            odims: out_dims,
                            coefs,
                            len: want,
                        },
                    });
                }
                other => bail!(
                    "{}: unsupported HLO opcode '{other}' (the serving op set is \
                     parameter/constant/convert/dot/add/multiply/maximum/broadcast/\
                     reshape/slice/tuple)",
                    ins.name
                ),
            }

            recycle(i, &eff[i], &last_use, &pinned, &pinned_slot, &mut slot_of, &mut free);
        }

        let mut root = Vec::with_capacity(root_ids.len());
        for &r in &root_ids {
            let slot = slot_of[r]
                .ok_or_else(|| err!("ROOT references a value without storage (nested tuple?)"))?;
            root.push((slot, instrs[r].dims.clone()));
        }

        let num_params = module.num_parameters();
        let param_pack_bf16 = param_pack_flags(&steps, slot_caps.len(), num_params, &root);

        Ok(Plan {
            steps,
            consts,
            slot_caps,
            root,
            num_params,
            assigns,
            max_dot,
            max_bf16,
            max_i8,
            param_pack_bf16,
            bf16_accum: opts.bf16_accum,
            dft_panels,
        })
    }

    /// The bf16 accumulation contract this plan's `DotBf16` steps run
    /// under (from the [`PlanOptions`] it was compiled with).
    pub fn bf16_accum(&self) -> Bf16Accum {
        self.bf16_accum
    }

    /// Number of compiled steps (≤ instruction count: constants and the
    /// ROOT tuple fold away, and the rewrite pass collapses whole fused
    /// subgraphs into single steps).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Step kinds in program order — the observable shape of the
    /// compiled plan, for tests and the bench smoke: `"param"`,
    /// `"copy"`, `"bf16"`, `"binary"`, `"dot"`, `"dot_bias"`,
    /// `"dot_bias_relu"`, `"dot_bf16"`, `"dot_bf16_bias"`,
    /// `"dot_bf16_bias_relu"`, `"dot_i8"`, `"dot_i8_bias"`,
    /// `"dot_i8_bias_relu"`, `"im2col_gemm"`, `"dft_gemm"`, `"gather"`.
    pub fn step_names(&self) -> Vec<&'static str> {
        self.steps
            .iter()
            .map(|s| match s {
                Step::Param { .. } => "param",
                Step::Copy { .. } => "copy",
                Step::Bf16 { .. } => "bf16",
                Step::Binary { .. } => "binary",
                Step::Dot { epi: StepEpi::None, .. } => "dot",
                Step::Dot { epi: StepEpi::Bias(_), .. } => "dot_bias",
                Step::Dot { epi: StepEpi::BiasRelu(_), .. } => "dot_bias_relu",
                Step::DotBf16 { epi: StepEpi::None, .. } => "dot_bf16",
                Step::DotBf16 { epi: StepEpi::Bias(_), .. } => "dot_bf16_bias",
                Step::DotBf16 { epi: StepEpi::BiasRelu(_), .. } => "dot_bf16_bias_relu",
                Step::DotI8 { epi: StepEpi::None, .. } => "dot_i8",
                Step::DotI8 { epi: StepEpi::Bias(_), .. } => "dot_i8_bias",
                Step::DotI8 { epi: StepEpi::BiasRelu(_), .. } => "dot_i8_bias_relu",
                Step::Im2colGemm { .. } => "im2col_gemm",
                Step::DftGemm { .. } => "dft_gemm",
                Step::Gather { .. } => "gather",
            })
            .collect()
    }

    /// Whether parameter `i` may be fed as raw bf16 bits with **no
    /// widening anywhere**: every read of its value is a `DotBf16`
    /// packing operand. A raw input for any other parameter still works
    /// — it is widened (exactly) straight into the parameter's arena
    /// slot.
    pub fn param_packs_bf16(&self, i: usize) -> bool {
        self.param_pack_bf16.get(i).copied().unwrap_or(false)
    }

    /// Number of arena slots (≤ live values at the widest point, not the
    /// instruction count — the liveness win).
    pub fn num_slots(&self) -> usize {
        self.slot_caps.len()
    }

    /// Total arena capacity in f32 elements.
    pub fn arena_elems(&self) -> usize {
        self.slot_caps.iter().sum()
    }

    /// Per-slot capacities in f32 elements (slot id is the index).
    pub fn slot_caps(&self) -> &[usize] {
        &self.slot_caps
    }

    /// Entry parameter count the plan expects.
    pub fn num_params(&self) -> usize {
        self.num_params
    }

    /// Per-instruction slot assignments, in program order. Invariants the
    /// allocator guarantees (and `rust/tests/plan_exec.rs` audits): two
    /// assignments sharing a slot have disjoint live ranges (the earlier
    /// value's `last_use` precedes the later value's `def`), and every
    /// slot's capacity covers every value assigned to it.
    pub fn assignments(&self) -> &[SlotAssign] {
        &self.assigns
    }

    /// Largest `(m, n, k)` over the f32 (dot + im2col), bf16, and i8
    /// fused GEMM steps, in that order — the scratch-sizing envelope
    /// (each step additionally reserves for its own tuned variant's
    /// blocking; see [`Plan::new_buffers`]).
    pub fn max_gemm_shapes(&self) -> [(usize, usize, usize); 3] {
        [self.max_dot, self.max_bf16, self.max_i8]
    }

    /// The autotuner's audit surface: the `(shape class, resolved
    /// variant)` of every fused GEMM step, in program order — what the
    /// bench's `tuning` block cross-checks against the device table and
    /// `tests/tune_engine.rs` uses to observe compiled choices.
    pub fn gemm_variants(&self) -> Vec<(TuneKey, GemmVariant)> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::Dot { m, n, k, epi, v, .. } => {
                    let key = TuneKey {
                        m: *m,
                        n: *n,
                        k: *k,
                        dtype: TuneDtype::F32,
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                    };
                    Some((key, *v))
                }
                Step::Im2colGemm { m, n, k, v, .. } => {
                    let key = TuneKey {
                        m: *m,
                        n: *n,
                        k: *k,
                        dtype: TuneDtype::F32,
                        epi: TuneEpi::None,
                        panel: TunePanel::Im2col,
                    };
                    Some((key, *v))
                }
                Step::DftGemm { m, n, k, v, .. } => {
                    let key = TuneKey {
                        m: *m,
                        n: *n,
                        k: *k,
                        dtype: TuneDtype::F32,
                        epi: TuneEpi::None,
                        panel: TunePanel::DftPacked,
                    };
                    Some((key, *v))
                }
                Step::DotBf16 { m, n, k, epi, v, .. } => {
                    let key = TuneKey {
                        m: *m,
                        n: *n,
                        k: *k,
                        dtype: TuneDtype::Bf16,
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                    };
                    Some((key, *v))
                }
                Step::DotI8 { m, n, k, epi, v, .. } => {
                    let key = TuneKey {
                        m: *m,
                        n: *n,
                        k: *k,
                        dtype: TuneDtype::I8,
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                    };
                    Some((key, *v))
                }
                _ => None,
            })
            .collect()
    }

    /// The roofline observability surface: every compiled step's
    /// executed-kernel descriptor, as the profile layer's input. GEMM
    /// steps carry the engine's [`ExecutedKernel`] (the exact
    /// `(m, n, k, dtype, variant)` it ran, with the tuner-chosen
    /// blocking), the fused epilogue class, the B-panel modality, and
    /// the GEMM count (4 for `dft_gemm`'s packed-panel complex product);
    /// data-movement steps carry their byte traffic.
    ///
    /// [`ExecutedKernel`]: crate::blas::block_gemm::ExecutedKernel
    pub fn profile_specs(&self) -> Vec<StepSpec> {
        let names = self.step_names();
        self.steps
            .iter()
            .zip(names)
            .enumerate()
            .map(|(index, (s, name))| {
                let kernel = match s {
                    Step::Dot { m, n, k, epi, v, .. } => StepKernel::Gemm {
                        ek: executed_kernel_f32(*m, *n, *k, *v),
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                        gemms: 1,
                    },
                    Step::Im2colGemm { m, n, k, v, .. } => StepKernel::Gemm {
                        ek: executed_kernel_f32(*m, *n, *k, *v),
                        epi: TuneEpi::None,
                        panel: TunePanel::Im2col,
                        gemms: 1,
                    },
                    Step::DftGemm { m, n, k, v, .. } => StepKernel::Gemm {
                        ek: executed_kernel_f32(*m, *n, *k, *v),
                        epi: TuneEpi::None,
                        panel: TunePanel::DftPacked,
                        gemms: 4,
                    },
                    Step::DotBf16 { m, n, k, epi, v, .. } => StepKernel::Gemm {
                        ek: executed_kernel_bf16(*m, *n, *k, *v),
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                        gemms: 1,
                    },
                    Step::DotI8 { m, n, k, epi, v, .. } => StepKernel::Gemm {
                        ek: executed_kernel_i8(*m, *n, *k, *v),
                        epi: epi.tune_epi(),
                        panel: TunePanel::Matrix,
                        gemms: 1,
                    },
                    Step::Param { len, .. } | Step::Copy { len, .. } => StepKernel::Mem {
                        load_bytes: len * 4,
                        store_bytes: len * 4,
                        fma_ops: 0,
                    },
                    Step::Bf16 { len, .. } => StepKernel::Mem {
                        load_bytes: len * 4,
                        store_bytes: len * 4,
                        fma_ops: len.div_ceil(4),
                    },
                    Step::Binary { len, .. } => StepKernel::Mem {
                        load_bytes: 2 * len * 4,
                        store_bytes: len * 4,
                        fma_ops: len.div_ceil(4),
                    },
                    Step::Gather { spec, .. } => StepKernel::Mem {
                        load_bytes: spec.len * 4,
                        store_bytes: spec.len * 4,
                        fma_ops: 0,
                    },
                };
                StepSpec { index, step: name.to_string(), kernel }
            })
            .collect()
    }

    /// Profile every step through the core model: synthesize each
    /// step's MMA instruction stream, collect its exact [`InstMix`],
    /// and simulate the MACs/cycle ceiling plus bound classification on
    /// POWER10. Pure simulation — no wall-clock replays (see
    /// [`Plan::profile_measured`]).
    ///
    /// [`InstMix`]: super::profile::InstMix
    pub fn profile(&self) -> Vec<StepProfile> {
        profile::profile_steps(&self.profile_specs())
    }

    /// [`Plan::profile`] plus achieved MACs/cycle: each GEMM step's
    /// executed kernel is replayed on synthetic operands of its exact
    /// shape and converted at the nominal clock
    /// ([`profile::NOMINAL_GHZ`]) — the roofline's measured axis.
    pub fn profile_measured(&self) -> Vec<StepProfile> {
        profile::profile_steps_measured(&self.profile_specs())
    }

    /// Preallocate execution buffers for this plan: all arena slots at
    /// full capacity, constants baked in, GEMM scratch (f32, packed
    /// bf16, packed i8/u8) sized per fused GEMM step for the **variant
    /// the step was compiled with** (panel buffers depend on the
    /// blocking config, so a tuned step reserves its own geometry; the
    /// canonical `max_dot`-style reserve is just the special case where
    /// every step is canonical). Request execution then allocates
    /// nothing.
    pub fn new_buffers(&self) -> ExecBuffers {
        let mut slots: Vec<Vec<f32>> = self.slot_caps.iter().map(|&c| vec![0f32; c]).collect();
        for (slot, data) in &self.consts {
            slots[*slot][..data.len()].copy_from_slice(data);
        }
        // reserve for the default device budget; a larger explicit
        // cap grows the per-worker chunk buffers lazily, once
        let cap = super::device::Device::default_threads();
        let mut scratch = GemmScratch::new();
        let mut bf16_scratch = Bf16Scratch::new();
        let mut i8_scratch = I8Scratch::new();
        let mut dft_tmp_len = 0usize;
        for s in &self.steps {
            match s {
                Step::Dot { m, n, k, v, .. } | Step::Im2colGemm { m, n, k, v, .. } => {
                    scratch.reserve_for(*m, *n, *k, threads_for_pooled(*m, *n, *k, cap), *v);
                }
                Step::DftGemm { m, n, k, v, .. } => {
                    scratch.reserve_for(*m, *n, *k, threads_for_pooled(*m, *n, *k, cap), *v);
                    dft_tmp_len = dft_tmp_len.max(2 * *m * *n);
                }
                Step::DotBf16 { m, n, k, v, .. } => {
                    bf16_scratch.reserve_for(*m, *n, *k, threads_for_pooled(*m, *n, *k, cap), *v);
                }
                Step::DotI8 { m, n, k, v, .. } => {
                    i8_scratch.reserve_for(*m, *n, *k, threads_for_pooled(*m, *n, *k, cap), *v);
                }
                _ => {}
            }
        }
        ExecBuffers {
            slots,
            scratch,
            bf16_scratch,
            i8_scratch,
            raw_param: vec![0u32; self.slot_caps.len()],
            dft_tmp: vec![0f32; dft_tmp_len],
        }
    }

    /// Execute the plan on flat row-major f32 inputs, reusing `bufs`.
    /// `threads` caps the worker count of each dot step; for `threads >
    /// 1` the workers are drawn from the **process-wide persistent
    /// pool** ([`Device::shared`](super::device::Device::shared)), while
    /// `threads <= 1` runs fully serial without instantiating the global
    /// pool. This is a convenience over [`Plan::execute_par`], which
    /// takes the full policy (an explicit device pool, scoped threads,
    /// or serial).
    pub fn execute_into(
        &self,
        bufs: &mut ExecBuffers,
        inputs: &[&[f32]],
        threads: usize,
    ) -> Result<Vec<Tensor>> {
        if threads <= 1 {
            return self.execute_par(bufs, inputs, Par::Seq);
        }
        let device = super::device::Device::shared();
        self.execute_par(bufs, inputs, Par::Pool(device.pool(), threads))
    }

    /// Execute the plan on flat row-major f32 inputs, reusing `bufs`,
    /// with an explicit GEMM worker policy. Returns the ROOT tuple
    /// elements (the only per-request allocation). Allocation-free
    /// callers (the typed serving path) use [`Plan::run_steps`] +
    /// [`Plan::root_slices`] instead and copy the root slot straight
    /// into their own output buffer.
    pub fn execute_par(
        &self,
        bufs: &mut ExecBuffers,
        inputs: &[&[f32]],
        par: Par<'_>,
    ) -> Result<Vec<Tensor>> {
        self.run_steps(bufs, inputs, par)?;
        let mut out = Vec::with_capacity(self.root.len());
        for (slot, dims) in &self.root {
            let len: usize = dims.iter().product();
            out.push(Tensor { dims: dims.clone(), data: bufs.slots[*slot][..len].to_vec() });
        }
        Ok(out)
    }

    /// Borrowed views `(data, dims)` of the ROOT tuple values, valid
    /// after [`Plan::run_steps`] on the same `bufs` — the zero-copy way
    /// to read results (the arena slots stay owned by `bufs`).
    pub fn root_slices<'b>(&'b self, bufs: &'b ExecBuffers) -> Vec<(&'b [f32], &'b [usize])> {
        self.root
            .iter()
            .map(|(slot, dims)| {
                let len: usize = dims.iter().product();
                (&bufs.slots[*slot][..len], dims.as_slice())
            })
            .collect()
    }

    /// Run the compiled step list against `bufs` without materializing
    /// output tensors; read the results with [`Plan::root_slices`].
    /// Convenience over [`Plan::run_steps_typed`] for all-f32 inputs.
    pub fn run_steps(
        &self,
        bufs: &mut ExecBuffers,
        inputs: &[&[f32]],
        par: Par<'_>,
    ) -> Result<()> {
        let typed: Vec<PlanInput<'_>> = inputs.iter().map(|&d| PlanInput::F32(d)).collect();
        self.run_steps_typed(bufs, &typed, par)
    }

    /// Run the compiled step list on **dtype-aware** inputs, reusing
    /// `bufs`; read the results with [`Plan::root_slices`]. This is the
    /// serving hot path: [`PlanInput::Bf16`] inputs for parameters that
    /// feed only `DotBf16` steps ([`Plan::param_packs_bf16`]) skip the
    /// arena entirely — their raw bits are packed straight into bf16
    /// panels by the GEMM step — and every other bf16 input is widened
    /// exactly into its parameter's arena slot. Both routes are bitwise
    /// identical to pre-widening on the caller side.
    pub fn run_steps_typed(
        &self,
        bufs: &mut ExecBuffers,
        inputs: &[PlanInput<'_>],
        par: Par<'_>,
    ) -> Result<()> {
        if inputs.len() != self.num_params {
            bail!("plan expects {} inputs, got {}", self.num_params, inputs.len());
        }
        // clear any raw-input routing left by a previous request
        bufs.raw_param.fill(0);
        for step in &self.steps {
            // Every step fully (re)writes its output slot, so whatever
            // raw-input routing that slot carried is dead the moment the
            // step starts — invalidate it HERE, once, so no step arm can
            // forget to. The Param arm below re-flags its slot when a
            // raw bf16 input legitimately skips the widening copy.
            match step {
                Step::Param { out, .. }
                | Step::Copy { out, .. }
                | Step::Bf16 { out, .. }
                | Step::Binary { out, .. }
                | Step::Dot { out, .. }
                | Step::DotBf16 { out, .. }
                | Step::DotI8 { out, .. }
                | Step::Im2colGemm { out, .. }
                | Step::Gather { out, .. } => bufs.raw_param[*out] = 0,
                Step::DftGemm { out_re, out_im, .. } => {
                    bufs.raw_param[*out_re] = 0;
                    bufs.raw_param[*out_im] = 0;
                }
            }
            match step {
                Step::Param { index, len, out } => {
                    let data = *inputs
                        .get(*index)
                        .ok_or_else(|| err!("missing input {index}"))?;
                    if data.len() != *len {
                        bail!("input {index} has {} elements, plan wants {len}", data.len());
                    }
                    match data {
                        PlanInput::F32(d) => {
                            bufs.slots[*out][..*len].copy_from_slice(d);
                        }
                        PlanInput::Bf16(bits) => {
                            if self.param_pack_bf16[*index] {
                                // consumed raw by DotBf16 packers: no copy
                                bufs.raw_param[*out] = *index as u32 + 1;
                            } else {
                                for (dst, &b) in
                                    bufs.slots[*out][..*len].iter_mut().zip(bits)
                                {
                                    *dst = bf16_to_f32(b);
                                }
                            }
                        }
                    }
                }
                Step::Copy { src, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    o[..*len].copy_from_slice(&bufs.slots[*src][..*len]);
                    bufs.slots[*out] = o;
                }
                Step::Bf16 { src, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    for (dst, &v) in o[..*len].iter_mut().zip(&bufs.slots[*src][..*len]) {
                        *dst = bf16_round(v);
                    }
                    bufs.slots[*out] = o;
                }
                Step::Binary { op, a, b, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let f: fn(f32, f32) -> f32 = match op {
                        BinOp::Add => |x, y| x + y,
                        BinOp::Multiply => |x, y| x * y,
                        BinOp::Maximum => f32::max,
                    };
                    let av = &bufs.slots[*a][..*len];
                    let bv = &bufs.slots[*b][..*len];
                    for (dst, (&x, &y)) in o[..*len].iter_mut().zip(av.iter().zip(bv)) {
                        *dst = f(x, y);
                    }
                    bufs.slots[*out] = o;
                }
                Step::Dot { a, b, out, m, n, k, epi, v } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let step_par = par.for_gemm(*m, *n, *k);
                    let slots = &bufs.slots;
                    let epilogue = match epi {
                        StepEpi::None => Epilogue::None,
                        StepEpi::Bias(s) => Epilogue::Bias(&slots[*s][..*n]),
                        StepEpi::BiasRelu(s) => Epilogue::BiasRelu(&slots[*s][..*n]),
                    };
                    gemm_f32_tuned_into(
                        &mut o[..m * n],
                        &slots[*a][..m * k],
                        PanelB::Matrix(&slots[*b][..k * n]),
                        *m,
                        *n,
                        *k,
                        Accum::F64,
                        epilogue,
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    bufs.slots[*out] = o;
                }
                Step::DotBf16 { a, b, out, m, n, k, epi, v } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let step_par = par.for_gemm(*m, *n, *k);
                    let slots = &bufs.slots;
                    let raw = &bufs.raw_param;
                    // fused epilogue biases live widened in f32 slots
                    // (param_pack_flags demotes them from raw routing)
                    let epilogue = match epi {
                        StepEpi::None => Epilogue::None,
                        StepEpi::Bias(s) => Epilogue::Bias(&slots[*s][..*n]),
                        StepEpi::BiasRelu(s) => Epilogue::BiasRelu(&slots[*s][..*n]),
                    };
                    // an operand slot flagged raw holds no f32 value —
                    // the request input's bf16 bits are packed directly
                    fn src<'s>(
                        raw: &[u32],
                        slots: &'s [Vec<f32>],
                        inputs: &[PlanInput<'s>],
                        slot: usize,
                        len: usize,
                    ) -> Result<Bf16Src<'s>> {
                        if raw[slot] != 0 {
                            let idx = (raw[slot] - 1) as usize;
                            match inputs[idx] {
                                PlanInput::Bf16(bits) => Ok(Bf16Src::Bits(bits)),
                                PlanInput::F32(_) => {
                                    bail!("raw-input routing points at an f32 input")
                                }
                            }
                        } else {
                            Ok(Bf16Src::F32(&slots[slot][..len]))
                        }
                    }
                    let asrc = src(raw, slots, inputs, *a, m * k)?;
                    let bsrc = src(raw, slots, inputs, *b, k * n)?;
                    gemm_bf16_tuned_into(
                        &mut o[..m * n],
                        asrc,
                        bsrc,
                        *m,
                        *n,
                        *k,
                        self.bf16_accum,
                        epilogue,
                        step_par,
                        &mut bufs.bf16_scratch,
                        *v,
                    );
                    bufs.slots[*out] = o;
                }
                Step::DftGemm { xr, xi, out_re, out_im, m, n, k, panels, v } => {
                    // Four real GEMMs over the pinned Fourier panels; the
                    // ± combine runs inside the C writeback of the last
                    // two, which is bitwise the interpreter's
                    // multiply(-1)+add / add pair (IEEE a−b ≡ a+(−1·b)).
                    let mn = *m * *n;
                    let mut ore = std::mem::take(&mut bufs.slots[*out_re]);
                    let mut oim = std::mem::take(&mut bufs.slots[*out_im]);
                    let mut tmp = std::mem::take(&mut bufs.dft_tmp);
                    let step_par = par.for_gemm(*m, *n, *k);
                    let slots = &bufs.slots;
                    let dp = &self.dft_panels[*panels];
                    let (t_ii, t_ir) = tmp[..2 * mn].split_at_mut(mn);
                    let xrv = &slots[*xr][..*m * *k];
                    let xiv = &slots[*xi][..*m * *k];
                    gemm_f32_tuned_into(
                        t_ii,
                        xiv,
                        PanelB::Packed(&dp.im),
                        *m,
                        *n,
                        *k,
                        Accum::F64,
                        Epilogue::None,
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    gemm_f32_tuned_into(
                        t_ir,
                        xiv,
                        PanelB::Packed(&dp.re),
                        *m,
                        *n,
                        *k,
                        Accum::F64,
                        Epilogue::None,
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    gemm_f32_tuned_into(
                        &mut ore[..mn],
                        xrv,
                        PanelB::Packed(&dp.re),
                        *m,
                        *n,
                        *k,
                        Accum::F64,
                        Epilogue::DftCombine { other: t_ii, sub: true },
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    gemm_f32_tuned_into(
                        &mut oim[..mn],
                        xrv,
                        PanelB::Packed(&dp.im),
                        *m,
                        *n,
                        *k,
                        Accum::F64,
                        Epilogue::DftCombine { other: t_ir, sub: false },
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    bufs.dft_tmp = tmp;
                    bufs.slots[*out_re] = ore;
                    bufs.slots[*out_im] = oim;
                }
                Step::DotI8 { a, b, out, m, n, k, epi, q, v } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let step_par = par.for_gemm(*m, *n, *k);
                    let slots = &bufs.slots;
                    let epilogue = match epi {
                        StepEpi::None => I8Epilogue::None,
                        StepEpi::Bias(s) => I8Epilogue::Bias(&slots[*s][..*n]),
                        StepEpi::BiasRelu(s) => I8Epilogue::BiasRelu(&slots[*s][..*n]),
                    };
                    gemm_i8_dequant_tuned_into(
                        &mut o[..m * n],
                        &slots[*a][..m * k],
                        &slots[*b][..k * n],
                        *m,
                        *n,
                        *k,
                        q,
                        epilogue,
                        step_par,
                        &mut bufs.i8_scratch,
                        *v,
                    );
                    bufs.slots[*out] = o;
                }
                Step::Im2colGemm { w, img, out, m, n, k, spec, v } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let step_par = par.for_gemm(*m, *n, *k);
                    let slots = &bufs.slots;
                    gemm_f32_tuned_into(
                        &mut o[..m * n],
                        &slots[*w][..m * k],
                        PanelB::Im2col { img: &slots[*img], spec },
                        *m,
                        *n,
                        *k,
                        Accum::F32,
                        Epilogue::None,
                        step_par,
                        &mut bufs.scratch,
                        *v,
                    );
                    bufs.slots[*out] = o;
                }
                Step::Gather { src, out, spec } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let sv = &bufs.slots[*src][..];
                    let nd = spec.odims.len();
                    for (flat, slot) in o[..spec.len].iter_mut().enumerate() {
                        let mut s = spec.base;
                        for d in 0..nd {
                            s += (flat / spec.ostrides[d]) % spec.odims[d] * spec.coefs[d];
                        }
                        *slot = sv[s];
                    }
                    bufs.slots[*out] = o;
                }
            }
        }
        Ok(())
    }

    /// Convenience: execute with fresh buffers (tests, one-shot tools).
    pub fn execute(&self, inputs: &[&[f32]], threads: usize) -> Result<Vec<Tensor>> {
        let mut bufs = self.new_buffers();
        self.execute_into(&mut bufs, inputs, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
HloModule jit_tiny

ENTRY main.5 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.4 = (f32[2,2]{1,0}) tuple(dot.3)
}
"#;

    #[test]
    fn compiles_and_runs_a_dot_module() {
        let m = HloModule::parse(TINY).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(plan.num_params(), 2);
        assert_eq!(plan.num_steps(), 3, "two params + one dot; ROOT tuple folds away");
        let a = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let out = plan.execute(&[&a, &b], 1).unwrap();
        assert_eq!(out[0].dims, vec![2, 2]);
        assert_eq!(out[0].data, vec![4.0, 5.0, 10.0, 11.0]);
        // identical to the interpreter walk
        assert_eq!(out[0].data, m.evaluate(&[&a, &b]).unwrap()[0].data);
    }

    #[test]
    fn slot_reuse_shrinks_the_arena() {
        // a chain of elementwise ops: values die immediately, so the
        // arena needs far fewer slots than there are instructions
        let text = r#"
HloModule jit_chain

ENTRY main {
  Arg_0.1 = f32[8]{0} parameter(0)
  add.2 = f32[8]{0} add(Arg_0.1, Arg_0.1)
  add.3 = f32[8]{0} add(add.2, add.2)
  add.4 = f32[8]{0} add(add.3, add.3)
  add.5 = f32[8]{0} add(add.4, add.4)
  ROOT add.6 = f32[8]{0} add(add.5, add.5)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert!(plan.num_slots() <= 3, "6 values, {} slots", plan.num_slots());
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let out = plan.execute(&[&x], 1).unwrap();
        let expect: Vec<f32> = x.iter().map(|v| v * 32.0).collect();
        assert_eq!(out[0].data, expect);
    }

    #[test]
    fn constants_survive_slot_recycling_across_requests() {
        let text = r#"
HloModule jit_const

ENTRY main {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = f32[2]{0} constant({10, 20})
  add.3 = f32[2]{0} add(Arg_0.1, constant.2)
  ROOT multiply.4 = f32[2]{0} multiply(add.3, constant.2)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let mut bufs = plan.new_buffers();
        for round in 0..3 {
            let x = [round as f32, -1.0];
            let out = plan.execute_into(&mut bufs, &[&x], 1).unwrap();
            let expect = vec![(round as f32 + 10.0) * 10.0, 19.0 * 20.0];
            assert_eq!(out[0].data, expect, "round {round}");
        }
    }

    #[test]
    fn validates_inputs_at_execute() {
        let m = HloModule::parse(TINY).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert!(plan.execute(&[&[0.0; 6][..]], 1).is_err(), "missing input");
        assert!(plan.execute(&[&[0.0; 5][..], &[0.0; 6][..]], 1).is_err(), "wrong length");
    }

    const MLP_TAIL: &str = r#"
ENTRY main {
  x = f32[2,3]{1,0} parameter(0)
  w = f32[3,4]{1,0} parameter(1)
  bias = f32[4]{0} parameter(2)
  dot.1 = f32[2,4]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  rb.2 = f32[1,4]{1,0} reshape(bias)
  ib.3 = f32[1,4]{1,0} broadcast(rb.2), dimensions={0,1}
  rb2.4 = f32[4]{0} reshape(ib.3)
  bb.5 = f32[2,4]{1,0} broadcast(rb2.4), dimensions={1}
  add.6 = f32[2,4]{1,0} add(dot.1, bb.5)
  zero.7 = f32[] constant(0)
  zb.8 = f32[2,4]{1,0} broadcast(zero.7), dimensions={}
  ROOT max.9 = f32[2,4]{1,0} maximum(add.6, zb.8)
}
"#;

    #[test]
    fn fuses_dot_bias_relu_and_dce_drops_the_zero_constant() {
        let m = HloModule::parse(MLP_TAIL).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(
            plan.step_names(),
            ["param", "param", "param", "dot_bias_relu"],
            "identity-chain bias broadcast, the zero constant, and its \
             broadcast must all fold into the epilogue"
        );
        // bit-identical to the interpreter on relu-active data
        let x = [1f32, -2.0, 3.0, -4.0, 5.0, -6.0];
        let w = [0.5f32; 12];
        let bias = [-1.0f32, 0.25, 0.0, 2.0];
        let got = plan.execute(&[&x, &w, &bias], 1).unwrap();
        let want = m.evaluate(&[&x, &w, &bias]).unwrap();
        assert_eq!(got[0].data, want[0].data);
        assert!(got[0].data.iter().any(|&v| v == 0.0), "relu clamped something");
    }

    /// A 2-tap shifted multiply-add chain (the conv pattern at its
    /// smallest): weights [2,2] × shifted windows of a [1,2,3] image.
    const CONV_2TAP: &str = r#"
ENTRY main {
  w = f32[2,2]{1,0} parameter(0)
  img = f32[1,2,3]{2,1,0} parameter(1)
  s0 = f32[2,1]{1,0} slice(w), slice={[0:2], [0:1]}
  r0 = f32[2]{0} reshape(s0)
  bw0 = f32[2,1,2]{2,1,0} broadcast(r0), dimensions={0}
  si0 = f32[1,1,2]{2,1,0} slice(img), slice={[0:1], [0:1], [0:2]}
  ri0 = f32[1,2]{1,0} reshape(si0)
  bi0 = f32[2,1,2]{2,1,0} broadcast(ri0), dimensions={1,2}
  m0 = f32[2,1,2]{2,1,0} multiply(bw0, bi0)
  s1 = f32[2,1]{1,0} slice(w), slice={[0:2], [1:2]}
  r1 = f32[2]{0} reshape(s1)
  bw1 = f32[2,1,2]{2,1,0} broadcast(r1), dimensions={0}
  si1 = f32[1,1,2]{2,1,0} slice(img), slice={[0:1], [1:2], [1:3]}
  ri1 = f32[1,2]{1,0} reshape(si1)
  bi1 = f32[2,1,2]{2,1,0} broadcast(ri1), dimensions={1,2}
  m1 = f32[2,1,2]{2,1,0} multiply(bw1, bi1)
  ROOT acc = f32[2,1,2]{2,1,0} add(m0, m1)
}
"#;

    #[test]
    fn fuses_conv_chain_to_one_im2col_gemm() {
        let m = HloModule::parse(CONV_2TAP).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(plan.step_names(), ["param", "param", "im2col_gemm"]);
        assert_eq!(plan.num_slots(), 3, "fused interiors take no arena slots");
        let w = [2f32, 10.0, -3.0, 100.0];
        let img = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let got = plan.execute(&[&w, &img], 1).unwrap();
        // out[co, 0, x] = w[co,0]*img[0,0,x] + w[co,1]*img[0,1,1+x]
        assert_eq!(got[0].dims, vec![2, 1, 2]);
        assert_eq!(got[0].data, vec![52.0, 64.0, 497.0, 594.0]);
        assert_eq!(got[0].data, m.evaluate(&[&w, &img]).unwrap()[0].data);
    }

    #[test]
    fn shared_intermediates_block_fusion_but_stay_correct() {
        // the dot feeds both the bias add AND the root tuple: fusing
        // would hide a request output, so the rewrite must decline
        let text = r#"
ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  w = f32[2,2]{1,0} parameter(1)
  bias = f32[2]{0} parameter(2)
  dot.1 = f32[2,2]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  bb.2 = f32[2,2]{1,0} broadcast(bias), dimensions={1}
  add.3 = f32[2,2]{1,0} add(dot.1, bb.2)
  ROOT t = (f32[2,2]{1,0}, f32[2,2]{1,0}) tuple(add.3, dot.1)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert!(
            plan.step_names().iter().all(|&s| s != "dot_bias"),
            "multi-use dot must not fuse: {:?}",
            plan.step_names()
        );
        let x = [1f32, 2.0, 3.0, 4.0];
        let w = [1f32, 0.0, 0.0, 1.0];
        let bias = [10f32, 20.0];
        let got = plan.execute(&[&x, &w, &bias], 1).unwrap();
        let want = m.evaluate(&[&x, &w, &bias]).unwrap();
        assert_eq!(got[0].data, want[0].data);
        assert_eq!(got[1].data, want[1].data);
    }

    #[test]
    fn swapped_maximum_operands_do_not_fuse_as_relu() {
        // maximum(broadcast(0), value) is NOT fused (zero-sign exactness);
        // the bias add below it still fuses and the result stays correct
        let text = r#"
ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  w = f32[2,2]{1,0} parameter(1)
  bias = f32[2]{0} parameter(2)
  dot.1 = f32[2,2]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  bb.2 = f32[2,2]{1,0} broadcast(bias), dimensions={1}
  add.3 = f32[2,2]{1,0} add(dot.1, bb.2)
  zero.4 = f32[] constant(0)
  zb.5 = f32[2,2]{1,0} broadcast(zero.4), dimensions={}
  ROOT max.6 = f32[2,2]{1,0} maximum(zb.5, add.3)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|&s| s != "dot_bias_relu"), "{names:?}");
        assert!(names.iter().any(|&s| s == "dot_bias"), "{names:?}");
        let x = [-1f32, 0.0, 0.0, -1.0];
        let w = [5f32, -7.0, 2.0, 9.0];
        let bias = [0.5f32, -0.5];
        let got = plan.execute(&[&x, &w, &bias], 1).unwrap();
        assert_eq!(got[0].data, m.evaluate(&[&x, &w, &bias]).unwrap()[0].data);
    }

    #[test]
    fn mismatched_bias_add_is_rejected_not_fused() {
        // add.3 declares [3,2] over a [2,2] dot: the matcher must decline
        // (its dims differ from the dot's [m,n]) so the strict elementwise
        // lowering still reports the shape mismatch at compile time
        let text = r#"
ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  w = f32[2,2]{1,0} parameter(1)
  bias = f32[2]{0} parameter(2)
  dot.1 = f32[2,2]{1,0} dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  bb.2 = f32[3,2]{1,0} broadcast(bias), dimensions={1}
  ROOT add.3 = f32[3,2]{1,0} add(dot.1, bb.2)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let e = Plan::compile(&m).unwrap_err().to_string();
        assert!(e.contains("shape mismatch"), "{e}");
    }

    /// The bf16 serving graph at its smallest: both dot operands round
    /// through bf16 (the double-convert chain `aot.py` lowers).
    const BF16_DOT: &str = r#"
HloModule jit_bf16_dot

ENTRY main.9 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  convert.3 = bf16[2,3]{1,0} convert(Arg_0.1)
  convert.4 = f32[2,3]{1,0} convert(convert.3)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  convert.5 = bf16[3,2]{1,0} convert(Arg_1.2)
  convert.6 = f32[3,2]{1,0} convert(convert.5)
  dot.7 = f32[2,2]{1,0} dot(convert.4, convert.6), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.8 = (f32[2,2]{1,0}) tuple(dot.7)
}
"#;

    #[test]
    fn fuses_bf16_convert_dot_to_one_packed_step() {
        let m = HloModule::parse(BF16_DOT).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(
            plan.step_names(),
            ["param", "param", "dot_bf16"],
            "all four converts must fold into the packed GEMM"
        );
        assert_eq!(plan.num_slots(), 3, "fused converts take no arena slots");
        assert!(plan.param_packs_bf16(0) && plan.param_packs_bf16(1));
        // bitwise identical to the interpreter walking the five
        // instructions (values chosen off the bf16 grid so rounding bites)
        let x = [1.0f32, 0.3004, -2.5, 0.1, 7.0, -0.0625];
        let w = [0.5f32, -1.5, 2.25, 0.3004, -4.0, 8.0];
        let got = plan.execute(&[&x, &w], 1).unwrap();
        let want = m.evaluate(&[&x, &w]).unwrap();
        let gb: Vec<u32> = got[0].data.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want[0].data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb);
    }

    #[test]
    fn raw_bf16_inputs_skip_the_arena_and_match_the_widened_path() {
        use crate::isa::types::f32_to_bf16_canonical;
        let m = HloModule::parse(BF16_DOT).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let x = [1.0f32, 0.3004, -2.5, 0.1, 7.0, -0.0625];
        let w = [0.5f32, -1.5, 2.25, 0.3004, -4.0, 8.0];
        let via_f32 = plan.execute(&[&x, &w], 1).unwrap();
        // the same values as raw bf16 bits (pre-rounded) through the
        // typed entry point: no widening happens anywhere, yet the
        // result is bitwise identical
        let xb: Vec<u16> = x.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
        let wb: Vec<u16> = w.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
        let mut bufs = plan.new_buffers();
        for inputs in [
            [PlanInput::Bf16(&xb), PlanInput::Bf16(&wb)],
            [PlanInput::Bf16(&xb), PlanInput::F32(&w)],
            [PlanInput::F32(&x), PlanInput::Bf16(&wb)],
        ] {
            plan.run_steps_typed(&mut bufs, &inputs, Par::Seq).unwrap();
            let roots = plan.root_slices(&bufs);
            let (data, dims) = roots[0];
            assert_eq!(dims, &[2, 2]);
            let gb: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
            let eb: Vec<u32> = via_f32[0].data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, eb);
        }
        // wrong raw-input length is rejected like any other input
        let short = [0u16; 3];
        assert!(plan
            .run_steps_typed(
                &mut bufs,
                &[PlanInput::Bf16(&short), PlanInput::F32(&w)],
                Par::Seq
            )
            .is_err());
    }

    #[test]
    fn one_sided_bf16_convert_does_not_fuse() {
        // only the lhs rounds: there is no packed-kernel equivalent, so
        // the plan must keep the elementwise lowering (and stay correct)
        let text = r#"
ENTRY main {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  convert.2 = bf16[2,2]{1,0} convert(Arg_0.1)
  convert.3 = f32[2,2]{1,0} convert(convert.2)
  Arg_1.4 = f32[2,2]{1,0} parameter(1)
  ROOT dot.5 = f32[2,2]{1,0} dot(convert.3, Arg_1.4), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|&s| s != "dot_bf16"), "{names:?}");
        assert!(names.contains(&"bf16"), "the convert still lowers: {names:?}");
        let x = [0.3004f32, 1.0, -2.0, 4.0];
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let got = plan.execute(&[&x, &w], 1).unwrap();
        assert_eq!(got[0].data, m.evaluate(&[&x, &w]).unwrap()[0].data);
    }

    #[test]
    fn bf16_convert_with_another_consumer_does_not_fuse() {
        // the widened value also escapes as a request output: consuming
        // it would hide the output, so the matcher must decline
        let text = r#"
ENTRY main {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  convert.2 = bf16[2,2]{1,0} convert(Arg_0.1)
  convert.3 = f32[2,2]{1,0} convert(convert.2)
  Arg_1.4 = f32[2,2]{1,0} parameter(1)
  convert.5 = bf16[2,2]{1,0} convert(Arg_1.4)
  convert.6 = f32[2,2]{1,0} convert(convert.5)
  dot.7 = f32[2,2]{1,0} dot(convert.3, convert.6), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT t = (f32[2,2]{1,0}, f32[2,2]{1,0}) tuple(dot.7, convert.3)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|&s| s != "dot_bf16"), "{names:?}");
        let x = [0.3004f32, 1.0, -2.0, 4.0];
        let w = [1.0f32, 0.5, -0.25, 1.0];
        let got = plan.execute(&[&x, &w], 1).unwrap();
        let want = m.evaluate(&[&x, &w]).unwrap();
        assert_eq!(got[0].data, want[0].data);
        assert_eq!(got[1].data, want[1].data);
    }

    #[test]
    fn rejects_unsupported_opcodes_at_compile() {
        let text = "ENTRY main {\n  Arg_0.1 = f32[2]{0} parameter(0)\n  ROOT neg.2 = f32[2]{0} negate(Arg_0.1)\n}\n";
        let m = HloModule::parse(text).unwrap();
        let e = Plan::compile(&m).unwrap_err().to_string();
        assert!(e.contains("unsupported HLO opcode"), "{e}");
    }

    fn int8_opts(calib: crate::runtime::Int8Calib) -> PlanOptions {
        PlanOptions { int8_calib: Some(calib), ..Default::default() }
    }

    #[test]
    fn int8_calibration_lowers_both_mlp_dots_to_quantized_steps() {
        use crate::blas::i8_gemm::gemm_i8_dequant_reference;
        use crate::runtime::{det_input, mlp_hlo_text, mlp_int8_calib};

        let (b, f, h, c) = (4usize, 6usize, 5usize, 3usize);
        let m = HloModule::parse(&mlp_hlo_text(b, f, h, c)).unwrap();
        let calib = mlp_int8_calib(f, h, c);
        let plan = Plan::compile_with_options(&m, int8_opts(calib.clone())).unwrap();
        let names = plan.step_names();
        assert!(names.contains(&"dot_i8_bias_relu"), "layer 1: {names:?}");
        assert!(names.contains(&"dot_i8_bias"), "layer 2: {names:?}");
        assert!(
            names.iter().all(|s| !s.starts_with("dot_bias") && *s != "dot"),
            "no f32 dot survives under full calibration: {names:?}"
        );

        // execution is bitwise the composition of the engine's own
        // quantize→dot→dequantize reference, layer by layer
        let x = det_input(b * f, 1);
        let w1 = det_input(f * h, 2);
        let b1 = det_input(h, 3);
        let w2 = det_input(h * c, 4);
        let b2 = det_input(c, 5);
        let qp = |an: &str, bn: &str| {
            let (ea, eb) = (calib.get(an).unwrap(), calib.get(bn).unwrap());
            assert!(ea.signed && !eb.signed);
            QuantParams { a_scale: ea.scale, a_zp: ea.zp, b_scale: eb.scale, b_zp: eb.zp }
        };
        let hid = gemm_i8_dequant_reference(
            &x,
            &w1,
            b,
            h,
            f,
            &qp("Arg_0.1", "Arg_1.2"),
            Some(&b1),
            true,
        );
        let want = gemm_i8_dequant_reference(
            &hid,
            &w2,
            b,
            c,
            h,
            &qp("maximum.14", "Arg_3.4"),
            Some(&b2),
            false,
        );
        let got = plan.execute(&[&x, &w1, &b1, &w2, &b2], 1).unwrap();
        assert_eq!(got[0].dims, vec![b, c]);
        let gb: Vec<u32> = got[0].data.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb);

        // the quantized path really ran: it differs from f32 serving,
        // but only by quantization-grid error
        let f32_out = Plan::compile(&m).unwrap().execute(&[&x, &w1, &b1, &w2, &b2], 1).unwrap();
        assert_ne!(got[0].data, f32_out[0].data, "quantization must bite");
        let max_err = got[0]
            .data
            .iter()
            .zip(&f32_out[0].data)
            .map(|(a, e)| (a - e).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 0.25, "quantization error out of family: {max_err}");
    }

    #[test]
    fn empty_int8_options_compile_the_unchanged_f32_plan() {
        use crate::runtime::mlp_hlo_text;
        let m = HloModule::parse(&mlp_hlo_text(2, 3, 4, 2)).unwrap();
        let with_none = Plan::compile_with_options(&m, PlanOptions::default()).unwrap();
        assert_eq!(
            with_none.step_names(),
            Plan::compile(&m).unwrap().step_names(),
            "no calibration record → the f32 lowering, untouched"
        );
        assert!(with_none.step_names().contains(&"dot_bias_relu"));
    }

    #[test]
    fn partially_calibrated_or_missigned_dots_fall_back_to_f32() {
        use crate::runtime::{CalibEntry, Int8Calib, mlp_hlo_text, mlp_int8_calib};
        let m = HloModule::parse(&mlp_hlo_text(2, 3, 4, 2)).unwrap();

        // only the lhs of layer 1 calibrated: neither dot may lower
        let partial = Int8Calib {
            entries: vec![CalibEntry {
                name: "Arg_0.1".into(),
                signed: true,
                scale: 0.01,
                zp: 0,
            }],
        };
        let plan = Plan::compile_with_options(&m, int8_opts(partial)).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|s| !s.starts_with("dot_i8")), "{names:?}");
        assert!(names.contains(&"dot_bias_relu"), "{names:?}");

        // signedness swapped on layer 1's operands (lhs must be the
        // signed i8 side, rhs the unsigned u8 side): layer 1 stays f32
        // while the still-valid layer 2 lowers
        let mut swapped = mlp_int8_calib(3, 4, 2);
        for e in &mut swapped.entries {
            if e.name == "Arg_0.1" {
                e.signed = false;
                e.zp = 128;
            }
        }
        let plan = Plan::compile_with_options(&m, int8_opts(swapped)).unwrap();
        let names = plan.step_names();
        assert!(names.contains(&"dot_bias_relu"), "layer 1 falls back: {names:?}");
        assert!(names.contains(&"dot_i8_bias"), "layer 2 still lowers: {names:?}");
    }

    #[test]
    fn dtype_mismatched_dots_error_or_fall_back_never_panic() {
        use crate::runtime::{CalibEntry, Int8Calib};
        let entry = |name: &str, signed: bool| CalibEntry {
            name: name.into(),
            signed,
            scale: 0.01,
            zp: if signed { 0 } else { 128 },
        };
        let calib = Int8Calib {
            entries: vec![entry("Arg_0.1", true), entry("Arg_1.2", false)],
        };

        // integer-typed operands: parseable (DType::Other) but the plan
        // must reject them with an error, calibrated or not
        let s32 = "ENTRY main {\n  Arg_0.1 = s32[2,3]{1,0} parameter(0)\n  Arg_1.2 = s32[3,2]{1,0} parameter(1)\n  ROOT dot.3 = s32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(s32).unwrap();
        let e = Plan::compile_with_options(&m, int8_opts(calib.clone())).unwrap_err().to_string();
        assert!(e.contains("unsupported element type"), "{e}");
        assert!(Plan::compile(&m).is_err());

        // contraction mismatch under calibration: the quantized matcher
        // must skip the malformed dot and the bare lowering reports it
        let bad_k = "ENTRY main {\n  Arg_0.1 = f32[2,3]{1,0} parameter(0)\n  Arg_1.2 = f32[4,2]{1,0} parameter(1)\n  ROOT dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(bad_k).unwrap();
        let e = Plan::compile_with_options(&m, int8_opts(calib.clone())).unwrap_err().to_string();
        assert!(e.contains("contraction mismatch"), "{e}");

        // a bf16-typed lhs with calibration entries present for *both*
        // operand names: dtype rules out quantization (the matcher
        // requires f32 operands) — the dot must fall back to the f32
        // step, not lower to dot_i8 and not panic
        let bf16_lhs = "ENTRY main {\n  Arg_0.1 = bf16[2,3]{1,0} parameter(0)\n  Arg_1.2 = f32[3,2]{1,0} parameter(1)\n  ROOT dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let m = HloModule::parse(bf16_lhs).unwrap();
        let plan = Plan::compile_with_options(&m, int8_opts(calib)).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|s| !s.starts_with("dot_i8")), "must not quantize: {names:?}");
        assert!(names.contains(&"dot"), "the f32 fallback dot runs instead: {names:?}");
    }

    /// The lowered complex-matmul DFT structure of the `dft_b32` fixture
    /// at a toy size: twiddle constants are arbitrary here (the matcher
    /// keys on structure, not values), and `multiply.9` deliberately
    /// flips the real lowering's `multiply(dot, broadcast)` operand
    /// order — the matcher must accept both.
    const DFT_TINY: &str = r#"
HloModule jit_dft_tiny

ENTRY main.15 {
  Arg_0.1 = f32[3,2]{1,0} parameter(0)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  constant.3 = f32[2,2]{1,0} constant({ { 1, 1 }, { 1, -1 } })
  constant.4 = f32[2,2]{1,0} constant({ { 0, 0.5 }, { -0.25, 0 } })
  dot.5 = f32[3,2]{1,0} dot(Arg_0.1, constant.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  dot.6 = f32[3,2]{1,0} dot(Arg_1.2, constant.4), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.7 = f32[] constant(-1)
  broadcast.8 = f32[3,2]{1,0} broadcast(constant.7), dimensions={}
  multiply.9 = f32[3,2]{1,0} multiply(broadcast.8, dot.6)
  add.10 = f32[3,2]{1,0} add(dot.5, multiply.9)
  dot.11 = f32[3,2]{1,0} dot(Arg_0.1, constant.4), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  dot.12 = f32[3,2]{1,0} dot(Arg_1.2, constant.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  add.13 = f32[3,2]{1,0} add(dot.11, dot.12)
  ROOT tuple.14 = (f32[3,2]{1,0}, f32[3,2]{1,0}) tuple(add.10, add.13)
}
"#;

    #[test]
    fn fuses_dft_graph_to_one_packed_gemm_step() {
        let m = HloModule::parse(DFT_TINY).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(
            plan.step_names(),
            ["param", "param", "dft_gemm"],
            "four dots + combine collapse to one step; twiddles and the -1 die by DCE"
        );
        let xr = [0.5f32, -1.25, 2.0, 0.125, -0.75, 3.5];
        let xi = [1.5f32, 0.25, -2.5, 0.0625, 4.0, -0.5];
        let got = plan.execute(&[&xr, &xi], 1).unwrap();
        let want = m.evaluate(&[&xr, &xi]).unwrap();
        assert_eq!(got.len(), 2, "both tuple roots");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.dims, w.dims);
            let gb: Vec<u32> = g.data.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = w.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "fused DftGemm must be bitwise the interpreter");
        }
    }

    #[test]
    fn dft_with_parameter_twiddles_does_not_fuse_but_stays_exact() {
        // Fi arrives as a parameter instead of a constant: the matcher
        // must decline (panels pack at compile time from constants only)
        // and the generic lowering must still match the interpreter
        let text = DFT_TINY.replace(
            "  constant.4 = f32[2,2]{1,0} constant({ { 0, 0.5 }, { -0.25, 0 } })",
            "  constant.4 = f32[2,2]{1,0} parameter(2)",
        );
        let m = HloModule::parse(&text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|&s| s != "dft_gemm"), "{names:?}");
        let xr = [0.5f32, -1.25, 2.0, 0.125, -0.75, 3.5];
        let xi = [1.5f32, 0.25, -2.5, 0.0625, 4.0, -0.5];
        let fi = [0.0f32, 0.5, -0.25, 0.0];
        let got = plan.execute(&[&xr, &xi, &fi], 1).unwrap();
        let want = m.evaluate(&[&xr, &xi, &fi]).unwrap();
        for (g, w) in got.iter().zip(&want) {
            let gb: Vec<u32> = g.data.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = w.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb);
        }
    }

    #[test]
    fn dft_with_shared_interior_dot_does_not_fuse_but_stays_exact() {
        // dot.6 gains a second consumer surfaced as a third root: the
        // interior is no longer invisible, so the match must fall apart
        // and everything lowers generically — bitwise the interpreter
        let text = DFT_TINY.replace(
            "  ROOT tuple.14 = (f32[3,2]{1,0}, f32[3,2]{1,0}) tuple(add.10, add.13)",
            "  ROOT tuple.14 = (f32[3,2]{1,0}, f32[3,2]{1,0}, f32[3,2]{1,0}) tuple(add.10, add.13, dot.6)",
        );
        let m = HloModule::parse(&text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let names = plan.step_names();
        assert!(names.iter().all(|&s| s != "dft_gemm"), "{names:?}");
        let xr = [0.5f32, -1.25, 2.0, 0.125, -0.75, 3.5];
        let xi = [1.5f32, 0.25, -2.5, 0.0625, 4.0, -0.5];
        let got = plan.execute(&[&xr, &xi], 1).unwrap();
        let want = m.evaluate(&[&xr, &xi]).unwrap();
        assert_eq!(got.len(), 3);
        for (g, w) in got.iter().zip(&want) {
            let gb: Vec<u32> = g.data.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = w.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb);
        }
    }
}
