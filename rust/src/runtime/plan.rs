//! Compiled execution plans: **compile once at `load()`, don't interpret
//! per request.**
//!
//! The legacy path ([`HloModule::evaluate`](super::hlo::HloModule::evaluate))
//! re-walks the instruction list on every request, re-deriving shapes,
//! strides, and operand checks, and allocating a fresh tensor per
//! instruction. This module lowers a parsed [`HloModule`] **once** into a
//! [`Plan`]:
//!
//! * every shape/attribute/operand check happens at compile time, so a
//!   malformed artifact fails at `load()` and the request path is
//!   branch-light;
//! * `broadcast`/`slice` are lowered to precomputed affine **gather**
//!   specs (base + per-axis stride coefficients), `reshape`/`convert`
//!   to flat copies, `dot` to the blocked parallel GEMM of
//!   [`crate::blas::block_gemm`];
//! * intermediate values live in a **preallocated buffer arena** with
//!   liveness-based slot reuse: a slot is recycled as soon as its value's
//!   last consumer has executed, and an instruction's output slot is
//!   never a slot of a still-live value (no aliasing, see
//!   [`Plan::assignments`]). Executing a request performs **no
//!   per-request allocation** beyond the returned output tensors — the
//!   arena, the GEMM `f64` accumulation image, and the packed-panel
//!   buffers are all owned by [`ExecBuffers`] and reused.
//!
//! Numerics are **bit-identical** to the interpreter walk on finite
//! inputs: elementwise ops use the same scalar functions, gathers compute
//! the same index arithmetic, and the blocked GEMM carries the same
//! ascending-`k` `f64` accumulation as the interpreter's
//! [`ref_gemm`](crate::blas::gemm::ref_gemm) path (the contract is tested
//! per fixture).
//!
//! Threading: [`Plan::execute_into`] takes a worker cap; each `dot`
//! decides via [`threads_for`] whether to fan its M-panel loop out over
//! scoped threads. Workers never outlive the call, so a plan is safe to
//! drive from the coordinator's thread-confined engine thread.

use super::hlo::{bf16_round, DType, HloModule, Tensor};
use crate::blas::block_gemm::{gemm_f32_into, threads_for, GemmScratch};
use crate::error::Result;
use crate::{bail, err};

/// Elementwise operator of a [`Plan`] step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BinOp {
    Add,
    Multiply,
    Maximum,
}

/// Precomputed affine gather: `out[flat] = src[base + Σ_d ((flat /
/// ostrides[d]) % odims[d]) · coefs[d]]` — the compile-time form of both
/// `broadcast` (base 0, coefficients from the `dimensions` attribute) and
/// `slice` (base/coefficients from the slice bounds).
#[derive(Clone, Debug)]
struct GatherSpec {
    base: usize,
    odims: Vec<usize>,
    ostrides: Vec<usize>,
    coefs: Vec<usize>,
    len: usize,
}

/// One compiled step of a [`Plan`]. Slot indices refer to the arena of
/// [`ExecBuffers`].
#[derive(Clone, Debug)]
enum Step {
    /// Copy entry input `index` (validated to `len` elements) into `out`.
    Param { index: usize, len: usize, out: usize },
    /// Flat copy (`reshape`, f32 `convert`).
    Copy { src: usize, len: usize, out: usize },
    /// bf16 round-to-nearest-even of every element (`convert` to bf16).
    Bf16 { src: usize, len: usize, out: usize },
    /// Elementwise binary op over equal-shaped operands.
    Binary { op: BinOp, a: usize, b: usize, len: usize, out: usize },
    /// `[m,k] × [k,n]` matmul on the blocked parallel GEMM.
    Dot { a: usize, b: usize, out: usize, m: usize, n: usize, k: usize },
    /// Affine gather (`broadcast` / `slice`).
    Gather { src: usize, out: usize, spec: GatherSpec },
}

/// One instruction's arena assignment — exposed so tests and tools can
/// audit the allocator (see the no-aliasing invariant on
/// [`Plan::assignments`]).
#[derive(Clone, Debug)]
pub struct SlotAssign {
    /// Index of the instruction in the entry computation.
    pub instr: usize,
    /// HLO instruction name (for diagnostics).
    pub name: String,
    /// Arena slot the value was assigned.
    pub slot: usize,
    /// Value size in elements.
    pub elems: usize,
    /// Instruction index at which the value is defined.
    pub def: usize,
    /// Instruction index of the last consumer (`usize::MAX` when the
    /// value is a request output and stays live to the end).
    pub last_use: usize,
}

/// A compiled execution plan: topologically-ordered steps over a
/// preallocated buffer arena. Build with [`Plan::compile`], execute with
/// [`Plan::execute_into`] against reusable [`ExecBuffers`].
pub struct Plan {
    steps: Vec<Step>,
    /// Constant payloads baked into their slots at buffer creation;
    /// their slots are pinned (never recycled, never rewritten).
    consts: Vec<(usize, Vec<f32>)>,
    slot_caps: Vec<usize>,
    /// Output values: `(slot, dims)` per ROOT (tuple) element.
    root: Vec<(usize, Vec<usize>)>,
    num_params: usize,
    assigns: Vec<SlotAssign>,
    /// Largest `m`/`n`/`k` over all dot steps (sizes the GEMM scratch).
    max_dot: (usize, usize, usize),
}

/// Reusable per-model execution state: the arena slots plus the GEMM
/// scratch. One `ExecBuffers` serves any number of sequential requests
/// with no allocation; create with [`Plan::new_buffers`].
pub struct ExecBuffers {
    slots: Vec<Vec<f32>>,
    scratch: GemmScratch,
}

fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for d in (0..dims.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * dims[d + 1];
    }
    s
}

/// Pick an arena slot of at least `want` elements: best-fit from the free
/// list, else grow the largest free slot, else open a new slot.
fn alloc_slot(want: usize, caps: &mut Vec<usize>, free: &mut Vec<usize>) -> usize {
    let best = free
        .iter()
        .enumerate()
        .filter(|&(_, &s)| caps[s] >= want)
        .min_by_key(|&(_, &s)| caps[s])
        .map(|(p, _)| p);
    if let Some(p) = best {
        return free.swap_remove(p);
    }
    let largest = free.iter().enumerate().max_by_key(|&(_, &s)| caps[s]).map(|(p, _)| p);
    if let Some(p) = largest {
        let s = free.swap_remove(p);
        caps[s] = want;
        return s;
    }
    caps.push(want);
    caps.len() - 1
}

impl Plan {
    /// Lower a parsed module into an execution plan, performing every
    /// shape/attribute/operand validation the interpreter would do per
    /// request. Fails on anything outside the serving op set.
    pub fn compile(module: &HloModule) -> Result<Plan> {
        let instrs = &module.instrs;
        let n = instrs.len();

        // -- liveness: last consumer of every value ----------------------
        let mut last_use: Vec<usize> = (0..n).collect();
        for (i, ins) in instrs.iter().enumerate() {
            for &op in &ins.operands {
                last_use[op] = last_use[op].max(i);
            }
        }
        let mut root_ids: Vec<usize> = Vec::new();
        for (i, ins) in instrs.iter().enumerate() {
            if ins.is_root {
                root_ids = if ins.opcode == "tuple" { ins.operands.clone() } else { vec![i] };
            }
        }
        if root_ids.is_empty() {
            bail!("entry computation has no ROOT instruction");
        }
        for &r in &root_ids {
            last_use[r] = usize::MAX;
        }

        // -- lower instructions, assigning arena slots -------------------
        let mut slot_caps: Vec<usize> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_of: Vec<Option<usize>> = vec![None; n];
        let mut pinned: Vec<bool> = vec![false; n];
        let mut steps: Vec<Step> = Vec::new();
        let mut consts: Vec<(usize, Vec<f32>)> = Vec::new();
        let mut assigns: Vec<SlotAssign> = Vec::new();
        let mut max_dot = (0usize, 0usize, 0usize);

        for (i, ins) in instrs.iter().enumerate() {
            if ins.dtype == DType::Other {
                bail!("{}: unsupported element type", ins.name);
            }
            if ins.opcode == "tuple" {
                if !ins.is_root {
                    bail!("{}: tuple is only supported as ROOT", ins.name);
                }
                continue;
            }
            let want: usize = ins.dims.iter().product();
            let need = match ins.opcode.as_str() {
                "dot" | "add" | "multiply" | "maximum" => 2,
                "convert" | "reshape" | "broadcast" | "slice" => 1,
                _ => 0,
            };
            if ins.operands.len() < need {
                bail!(
                    "{}: {} needs {need} operand(s), got {}",
                    ins.name,
                    ins.opcode,
                    ins.operands.len()
                );
            }
            for j in 0..need {
                if slot_of[ins.operands[j]].is_none() {
                    bail!("{}: operand has no value (tuple operand?)", ins.name);
                }
            }
            // Constants are baked into their slot when buffers are
            // created, so they are live from step 0 of *every* request:
            // they get a dedicated slot outside the recycling pool (a
            // recycled slot would be clobbered by whichever earlier step
            // previously owned it).
            let is_const = ins.opcode == "constant";
            let out = if is_const {
                slot_caps.push(want);
                slot_caps.len() - 1
            } else {
                alloc_slot(want, &mut slot_caps, &mut free)
            };
            slot_of[i] = Some(out);
            assigns.push(SlotAssign {
                instr: i,
                name: ins.name.clone(),
                slot: out,
                elems: want,
                def: if is_const { 0 } else { i },
                last_use: if is_const { usize::MAX } else { last_use[i] },
            });

            match ins.opcode.as_str() {
                "parameter" => {
                    steps.push(Step::Param { index: ins.param, len: want, out });
                }
                "constant" => {
                    if ins.const_vals.len() != want {
                        bail!(
                            "{}: constant has {} literals, shape wants {want}",
                            ins.name,
                            ins.const_vals.len()
                        );
                    }
                    pinned[i] = true;
                    consts.push((out, ins.const_vals.clone()));
                }
                "convert" => {
                    let srclen: usize = instrs[ins.operands[0]].dims.iter().product();
                    if srclen != want {
                        bail!(
                            "{}: convert operand has {srclen} elements, shape wants {want}",
                            ins.name
                        );
                    }
                    let src = slot_of[ins.operands[0]].unwrap();
                    steps.push(match ins.dtype {
                        DType::Bf16 => Step::Bf16 { src, len: want, out },
                        _ => Step::Copy { src, len: want, out },
                    });
                }
                "reshape" => {
                    let sdims = &instrs[ins.operands[0]].dims;
                    if sdims.iter().product::<usize>() != want {
                        bail!(
                            "{}: reshape {sdims:?} -> {:?} changes element count",
                            ins.name,
                            ins.dims
                        );
                    }
                    let src = slot_of[ins.operands[0]].unwrap();
                    steps.push(Step::Copy { src, len: want, out });
                }
                "add" | "multiply" | "maximum" => {
                    let (a, b) = (&instrs[ins.operands[0]], &instrs[ins.operands[1]]);
                    if a.dims != b.dims || a.dims != ins.dims {
                        bail!(
                            "{}: elementwise shape mismatch {:?} vs {:?} -> {:?}",
                            ins.name,
                            a.dims,
                            b.dims,
                            ins.dims
                        );
                    }
                    let op = match ins.opcode.as_str() {
                        "add" => BinOp::Add,
                        "multiply" => BinOp::Multiply,
                        _ => BinOp::Maximum,
                    };
                    steps.push(Step::Binary {
                        op,
                        a: slot_of[ins.operands[0]].unwrap(),
                        b: slot_of[ins.operands[1]].unwrap(),
                        len: want,
                        out,
                    });
                }
                "dot" => {
                    let (a, b) = (&instrs[ins.operands[0]], &instrs[ins.operands[1]]);
                    if a.dims.len() != 2 || b.dims.len() != 2 {
                        bail!(
                            "{}: only rank-2 dot supported, got {:?} x {:?}",
                            ins.name,
                            a.dims,
                            b.dims
                        );
                    }
                    if ins.lhs_contracting != Some(1) || ins.rhs_contracting != Some(0) {
                        bail!(
                            "{}: only lhs_contracting_dims={{1}} rhs_contracting_dims={{0}} supported",
                            ins.name
                        );
                    }
                    let (m, k) = (a.dims[0], a.dims[1]);
                    let (k2, nn) = (b.dims[0], b.dims[1]);
                    if k != k2 {
                        bail!("{}: contraction mismatch {k} vs {k2}", ins.name);
                    }
                    if ins.dims != [m, nn] {
                        bail!("{}: dot result shape {:?} != [{m},{nn}]", ins.name, ins.dims);
                    }
                    max_dot = (max_dot.0.max(m), max_dot.1.max(nn), max_dot.2.max(k));
                    steps.push(Step::Dot {
                        a: slot_of[ins.operands[0]].unwrap(),
                        b: slot_of[ins.operands[1]].unwrap(),
                        out,
                        m,
                        n: nn,
                        k,
                    });
                }
                "broadcast" => {
                    let src = &instrs[ins.operands[0]];
                    let dims_attr = ins.dims_attr.clone().unwrap_or_default();
                    if dims_attr.len() != src.dims.len() {
                        bail!(
                            "{}: broadcast dimensions {:?} do not match source rank {}",
                            ins.name,
                            dims_attr,
                            src.dims.len()
                        );
                    }
                    let nd = ins.dims.len();
                    let sstrides = row_major_strides(&src.dims);
                    let mut coefs = vec![0usize; nd];
                    for (ax, &d) in dims_attr.iter().enumerate() {
                        if d >= nd {
                            bail!("{}: broadcast dimension {d} out of range", ins.name);
                        }
                        if src.dims[ax] != ins.dims[d] {
                            bail!(
                                "{}: broadcast source dim {ax} ({}) != output dim {d} ({})",
                                ins.name,
                                src.dims[ax],
                                ins.dims[d]
                            );
                        }
                        coefs[d] = sstrides[ax];
                    }
                    steps.push(Step::Gather {
                        src: slot_of[ins.operands[0]].unwrap(),
                        out,
                        spec: GatherSpec {
                            base: 0,
                            odims: ins.dims.clone(),
                            ostrides: row_major_strides(&ins.dims),
                            coefs,
                            len: want,
                        },
                    });
                }
                "slice" => {
                    let src = &instrs[ins.operands[0]];
                    let bounds = ins
                        .slice_bounds
                        .as_ref()
                        .ok_or_else(|| err!("{}: slice without slice attribute", ins.name))?;
                    if bounds.len() != src.dims.len() {
                        bail!(
                            "{}: {} slice bounds for rank-{} source",
                            ins.name,
                            bounds.len(),
                            src.dims.len()
                        );
                    }
                    let nd = src.dims.len();
                    let sstrides = row_major_strides(&src.dims);
                    let mut out_dims = Vec::with_capacity(nd);
                    let mut base = 0usize;
                    let mut coefs = Vec::with_capacity(nd);
                    for (d, &(start, stop, stride)) in bounds.iter().enumerate() {
                        if start > stop || stop > src.dims[d] {
                            bail!(
                                "{}: slice bound [{start}:{stop}] out of range for dim {d} ({})",
                                ins.name,
                                src.dims[d]
                            );
                        }
                        out_dims.push((stop - start).div_ceil(stride));
                        base += start * sstrides[d];
                        coefs.push(stride * sstrides[d]);
                    }
                    if out_dims != ins.dims {
                        bail!(
                            "{}: slice result {:?} != declared {:?}",
                            ins.name,
                            out_dims,
                            ins.dims
                        );
                    }
                    steps.push(Step::Gather {
                        src: slot_of[ins.operands[0]].unwrap(),
                        out,
                        spec: GatherSpec {
                            base,
                            ostrides: row_major_strides(&out_dims),
                            odims: out_dims,
                            coefs,
                            len: want,
                        },
                    });
                }
                other => bail!(
                    "{}: unsupported HLO opcode '{other}' (the serving op set is \
                     parameter/constant/convert/dot/add/multiply/maximum/broadcast/\
                     reshape/slice/tuple)",
                    ins.name
                ),
            }

            // recycle slots whose values die here (operands last used by
            // this instruction, or an output nobody consumes). Freed only
            // *after* the output slot was taken, so an output never
            // aliases a live operand; pinned (constant) slots never free.
            for &op in &ins.operands {
                if last_use[op] == i && !pinned[op] {
                    if let Some(s) = slot_of[op].take() {
                        free.push(s);
                    }
                }
            }
            if last_use[i] == i && !pinned[i] {
                if let Some(s) = slot_of[i].take() {
                    free.push(s);
                }
            }
        }

        let mut root = Vec::with_capacity(root_ids.len());
        for &r in &root_ids {
            let slot = slot_of[r]
                .ok_or_else(|| err!("ROOT references a value without storage (nested tuple?)"))?;
            root.push((slot, instrs[r].dims.clone()));
        }

        Ok(Plan {
            steps,
            consts,
            slot_caps,
            root,
            num_params: module.num_parameters(),
            assigns,
            max_dot,
        })
    }

    /// Number of compiled steps (≤ instruction count: constants and the
    /// ROOT tuple are folded away).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Number of arena slots (≤ live values at the widest point, not the
    /// instruction count — the liveness win).
    pub fn num_slots(&self) -> usize {
        self.slot_caps.len()
    }

    /// Total arena capacity in f32 elements.
    pub fn arena_elems(&self) -> usize {
        self.slot_caps.iter().sum()
    }

    /// Per-slot capacities in f32 elements (slot id is the index).
    pub fn slot_caps(&self) -> &[usize] {
        &self.slot_caps
    }

    /// Entry parameter count the plan expects.
    pub fn num_params(&self) -> usize {
        self.num_params
    }

    /// Per-instruction slot assignments, in program order. Invariants the
    /// allocator guarantees (and `rust/tests/plan_exec.rs` audits): two
    /// assignments sharing a slot have disjoint live ranges (the earlier
    /// value's `last_use` precedes the later value's `def`), and every
    /// slot's capacity covers every value assigned to it.
    pub fn assignments(&self) -> &[SlotAssign] {
        &self.assigns
    }

    /// Preallocate execution buffers for this plan: all arena slots at
    /// full capacity, constants baked in, GEMM scratch sized for the
    /// largest dot. Request execution then allocates nothing.
    pub fn new_buffers(&self) -> ExecBuffers {
        let mut slots: Vec<Vec<f32>> = self.slot_caps.iter().map(|&c| vec![0f32; c]).collect();
        for (slot, data) in &self.consts {
            slots[*slot][..data.len()].copy_from_slice(data);
        }
        let mut scratch = GemmScratch::new();
        let (m, n, k) = self.max_dot;
        if m > 0 {
            // reserve for the default worker cap; a larger explicit cap
            // grows the per-worker A-panel buffers lazily, once
            let cap = super::HloPlanBackend::default_threads();
            scratch.reserve(m, n, k, threads_for(m, n, k, cap));
        }
        ExecBuffers { slots, scratch }
    }

    /// Execute the plan on flat row-major f32 inputs, reusing `bufs`.
    /// Returns the ROOT tuple elements (the only per-request allocation).
    /// `threads` caps the worker count of each dot step (see
    /// [`threads_for`]).
    pub fn execute_into(
        &self,
        bufs: &mut ExecBuffers,
        inputs: &[&[f32]],
        threads: usize,
    ) -> Result<Vec<Tensor>> {
        if inputs.len() != self.num_params {
            bail!("plan expects {} inputs, got {}", self.num_params, inputs.len());
        }
        for step in &self.steps {
            match step {
                Step::Param { index, len, out } => {
                    let data = *inputs
                        .get(*index)
                        .ok_or_else(|| err!("missing input {index}"))?;
                    if data.len() != *len {
                        bail!("input {index} has {} elements, plan wants {len}", data.len());
                    }
                    bufs.slots[*out][..*len].copy_from_slice(data);
                }
                Step::Copy { src, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    o[..*len].copy_from_slice(&bufs.slots[*src][..*len]);
                    bufs.slots[*out] = o;
                }
                Step::Bf16 { src, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    for (dst, &v) in o[..*len].iter_mut().zip(&bufs.slots[*src][..*len]) {
                        *dst = bf16_round(v);
                    }
                    bufs.slots[*out] = o;
                }
                Step::Binary { op, a, b, len, out } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let f: fn(f32, f32) -> f32 = match op {
                        BinOp::Add => |x, y| x + y,
                        BinOp::Multiply => |x, y| x * y,
                        BinOp::Maximum => f32::max,
                    };
                    let av = &bufs.slots[*a][..*len];
                    let bv = &bufs.slots[*b][..*len];
                    for (dst, (&x, &y)) in o[..*len].iter_mut().zip(av.iter().zip(bv)) {
                        *dst = f(x, y);
                    }
                    bufs.slots[*out] = o;
                }
                Step::Dot { a, b, out, m, n, k } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let nthreads = threads_for(*m, *n, *k, threads);
                    gemm_f32_into(
                        &mut o[..m * n],
                        &bufs.slots[*a][..m * k],
                        &bufs.slots[*b][..k * n],
                        *m,
                        *n,
                        *k,
                        nthreads,
                        &mut bufs.scratch,
                    );
                    bufs.slots[*out] = o;
                }
                Step::Gather { src, out, spec } => {
                    let mut o = std::mem::take(&mut bufs.slots[*out]);
                    let sv = &bufs.slots[*src][..];
                    let nd = spec.odims.len();
                    for (flat, slot) in o[..spec.len].iter_mut().enumerate() {
                        let mut s = spec.base;
                        for d in 0..nd {
                            s += (flat / spec.ostrides[d]) % spec.odims[d] * spec.coefs[d];
                        }
                        *slot = sv[s];
                    }
                    bufs.slots[*out] = o;
                }
            }
        }
        let mut out = Vec::with_capacity(self.root.len());
        for (slot, dims) in &self.root {
            let len: usize = dims.iter().product();
            out.push(Tensor { dims: dims.clone(), data: bufs.slots[*slot][..len].to_vec() });
        }
        Ok(out)
    }

    /// Convenience: execute with fresh buffers (tests, one-shot tools).
    pub fn execute(&self, inputs: &[&[f32]], threads: usize) -> Result<Vec<Tensor>> {
        let mut bufs = self.new_buffers();
        self.execute_into(&mut bufs, inputs, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
HloModule jit_tiny

ENTRY main.5 {
  Arg_0.1 = f32[2,3]{1,0} parameter(0)
  Arg_1.2 = f32[3,2]{1,0} parameter(1)
  dot.3 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT tuple.4 = (f32[2,2]{1,0}) tuple(dot.3)
}
"#;

    #[test]
    fn compiles_and_runs_a_dot_module() {
        let m = HloModule::parse(TINY).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert_eq!(plan.num_params(), 2);
        assert_eq!(plan.num_steps(), 3, "two params + one dot; ROOT tuple folds away");
        let a = [1f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let out = plan.execute(&[&a, &b], 1).unwrap();
        assert_eq!(out[0].dims, vec![2, 2]);
        assert_eq!(out[0].data, vec![4.0, 5.0, 10.0, 11.0]);
        // identical to the interpreter walk
        assert_eq!(out[0].data, m.evaluate(&[&a, &b]).unwrap()[0].data);
    }

    #[test]
    fn slot_reuse_shrinks_the_arena() {
        // a chain of elementwise ops: values die immediately, so the
        // arena needs far fewer slots than there are instructions
        let text = r#"
HloModule jit_chain

ENTRY main {
  Arg_0.1 = f32[8]{0} parameter(0)
  add.2 = f32[8]{0} add(Arg_0.1, Arg_0.1)
  add.3 = f32[8]{0} add(add.2, add.2)
  add.4 = f32[8]{0} add(add.3, add.3)
  add.5 = f32[8]{0} add(add.4, add.4)
  ROOT add.6 = f32[8]{0} add(add.5, add.5)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert!(plan.num_slots() <= 3, "6 values, {} slots", plan.num_slots());
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let out = plan.execute(&[&x], 1).unwrap();
        let expect: Vec<f32> = x.iter().map(|v| v * 32.0).collect();
        assert_eq!(out[0].data, expect);
    }

    #[test]
    fn constants_survive_slot_recycling_across_requests() {
        let text = r#"
HloModule jit_const

ENTRY main {
  Arg_0.1 = f32[2]{0} parameter(0)
  constant.2 = f32[2]{0} constant({10, 20})
  add.3 = f32[2]{0} add(Arg_0.1, constant.2)
  ROOT multiply.4 = f32[2]{0} multiply(add.3, constant.2)
}
"#;
        let m = HloModule::parse(text).unwrap();
        let plan = Plan::compile(&m).unwrap();
        let mut bufs = plan.new_buffers();
        for round in 0..3 {
            let x = [round as f32, -1.0];
            let out = plan.execute_into(&mut bufs, &[&x], 1).unwrap();
            let expect = vec![(round as f32 + 10.0) * 10.0, 19.0 * 20.0];
            assert_eq!(out[0].data, expect, "round {round}");
        }
    }

    #[test]
    fn validates_inputs_at_execute() {
        let m = HloModule::parse(TINY).unwrap();
        let plan = Plan::compile(&m).unwrap();
        assert!(plan.execute(&[&[0.0; 6][..]], 1).is_err(), "missing input");
        assert!(plan.execute(&[&[0.0; 5][..], &[0.0; 6][..]], 1).is_err(), "wrong length");
    }

    #[test]
    fn rejects_unsupported_opcodes_at_compile() {
        let text = "ENTRY main {\n  Arg_0.1 = f32[2]{0} parameter(0)\n  ROOT neg.2 = f32[2]{0} negate(Arg_0.1)\n}\n";
        let m = HloModule::parse(text).unwrap();
        let e = Plan::compile(&m).unwrap_err().to_string();
        assert!(e.contains("unsupported HLO opcode"), "{e}");
    }
}
