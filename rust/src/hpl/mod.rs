//! HPL (High-Performance Linpack) driver — the paper's §VI benchmark and
//! the generator of **Figure 10**.
//!
//! Two layers:
//!
//! * [`hpl_run`] — a *functional* HPL: random dense system, blocked LU with
//!   partial pivoting, triangular solve, and the HPL correctness residual
//!   `‖Ax−b‖∞ / (ε·(‖A‖∞‖x‖∞ + ‖b‖∞)·n)`. The trailing update can run on
//!   any [`GemmBackend`], including the instruction-level MMA simulator —
//!   the end-to-end composition proof.
//! * [`hpl_cycles`] — the *timing* layer: replays the factorization's work
//!   profile (every trailing-GEMM shape plus the panel/trsm flops) against
//!   per-kernel cycle costs measured on the [`CoreSim`] timing model, and
//!   reports flops/cycle for the three §VI configurations. This is the
//!   trace-driven method the reproduction uses for problem sizes where
//!   instruction-level simulation of every MAC would be prohibitive.

use crate::blas::gemm::GemmBackend;
use crate::blas::level1::dlange_inf;
use crate::blas::lu::{dgetrf, lu_solve, LuProfile};
use crate::core_model::{CoreSim, MachineConfig, SimReport};
use crate::isa::ExecError;
use crate::kernels::dgemm::dgemm_8xnx8_program;
use crate::kernels::vsx::vsx_dgemm_8x4_program;
use crate::testkit::Rng;
use std::collections::HashMap;

/// Result of a functional HPL run.
#[derive(Clone, Debug)]
pub struct HplResult {
    pub n: usize,
    /// The HPL residual; `< 16` is the standard pass threshold.
    pub residual: f64,
    pub profile: LuProfile,
}

impl HplResult {
    pub fn passed(&self) -> bool {
        self.residual < 16.0
    }

    /// HPL's nominal flop count `2/3·n³ + 2·n²`.
    pub fn nominal_flops(&self) -> f64 {
        let n = self.n as f64;
        2.0 / 3.0 * n * n * n + 2.0 * n * n
    }
}

/// Run HPL functionally at size `n` with panel width `nb` on a backend.
pub fn hpl_run(n: usize, nb: usize, seed: u64, backend: &mut dyn GemmBackend) -> Result<HplResult, ExecError> {
    let mut rng = Rng::new(seed);
    let a0: Vec<f64> = (0..n * n).map(|_| rng.f64_range(-0.5, 0.5)).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.f64_range(-0.5, 0.5)).collect();
    let mut a = a0.clone();
    let (piv, profile) = dgetrf(&mut a, n, nb, backend)?;
    let x = lu_solve(&a, n, &piv, &b);
    // residual ‖Ax − b‖∞ / (ε (‖A‖‖x‖ + ‖b‖) n)
    let mut rmax = 0.0f64;
    let mut xmax = 0.0f64;
    let mut bmax = 0.0f64;
    for i in 0..n {
        let ax: f64 = (0..n).map(|j| a0[i * n + j] * x[j]).sum();
        rmax = rmax.max((ax - b[i]).abs());
        xmax = xmax.max(x[i].abs());
        bmax = bmax.max(b[i].abs());
    }
    let anorm = dlange_inf(&a0, n, n, n);
    let residual = rmax / (f64::EPSILON * (anorm * xmax + bmax) * n as f64);
    Ok(HplResult { n, residual, profile })
}

/// Which code runs on which machine — the three §VI measurement setups.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Setup {
    Power9Vsx,
    Power10Vsx,
    Power10Mma,
}

impl Setup {
    pub const ALL: [Setup; 3] = [Setup::Power9Vsx, Setup::Power10Vsx, Setup::Power10Mma];

    pub fn label(self) -> &'static str {
        match self {
            Setup::Power9Vsx => "POWER9",
            Setup::Power10Vsx => "POWER10-VSX",
            Setup::Power10Mma => "POWER10-MMA",
        }
    }

    pub fn config(self) -> MachineConfig {
        match self {
            Setup::Power9Vsx => MachineConfig::power9(),
            _ => MachineConfig::power10(),
        }
    }

    /// Peak fp64 flops/cycle of the datapath this setup exercises.
    pub fn peak(self) -> f64 {
        match self {
            Setup::Power9Vsx => 8.0,
            Setup::Power10Vsx => 16.0,
            Setup::Power10Mma => 32.0,
        }
    }
}

/// Trace-driven cycle cost model: measures each distinct kernel shape once
/// on the timing simulator and caches cycles-per-call.
pub struct CycleCost {
    setup: Setup,
    sim: CoreSim,
    /// cycles for one MMA 8×k×8 call / one VSX 8×k×4 call, keyed by k.
    per_call: HashMap<usize, u64>,
    /// flops/cycle the setup achieves on BLAS2-class panel work (bandwidth
    /// bound: ~0.25 of vector peak — panel work is `daxpy`-like with one
    /// load per flop).
    panel_rate: f64,
}

impl CycleCost {
    pub fn new(setup: Setup) -> Self {
        let sim = CoreSim::new(setup.config());
        let panel_rate = match setup {
            Setup::Power9Vsx => 2.0,
            // P10 has twice the LSU ports/bandwidth
            Setup::Power10Vsx | Setup::Power10Mma => 4.0,
        };
        CycleCost { setup, sim, per_call: HashMap::new(), panel_rate }
    }

    /// Cycles for one micro-kernel call with inner dimension `k`.
    fn kernel_call_cycles(&mut self, k: usize) -> u64 {
        if let Some(&c) = self.per_call.get(&k) {
            return c;
        }
        let prog = match self.setup {
            Setup::Power10Mma => dgemm_8xnx8_program(k),
            _ => vsx_dgemm_8x4_program(k),
        };
        let r = self.sim.run(&prog, 1 << 26);
        self.per_call.insert(k, r.cycles);
        r.cycles
    }

    /// Cycles for a full `m×n×k` DGEMM on this setup (blocked over the
    /// micro-kernel tile).
    pub fn dgemm_cycles(&mut self, m: usize, n: usize, k: usize) -> u64 {
        let per = self.kernel_call_cycles(k);
        let calls = match self.setup {
            Setup::Power10Mma => m.div_ceil(8) as u64 * n.div_ceil(8) as u64,
            _ => m.div_ceil(8) as u64 * n.div_ceil(4) as u64,
        };
        calls * per
    }

    /// Cycles for `flops` of BLAS1/2-class panel work.
    pub fn panel_cycles(&self, flops: u64) -> u64 {
        (flops as f64 / self.panel_rate) as u64
    }

    /// Measured timing report for one micro-kernel call (for Figure 12).
    pub fn kernel_report(&mut self, k: usize) -> SimReport {
        let prog = match self.setup {
            Setup::Power10Mma => dgemm_8xnx8_program(k),
            _ => vsx_dgemm_8x4_program(k),
        };
        self.sim.run(&prog, 1 << 26)
    }

    pub fn sim_mut(&mut self) -> &mut CoreSim {
        &mut self.sim
    }
}

/// Figure 10 datapoint: replay an LU work profile against the cycle model.
#[derive(Clone, Debug)]
pub struct HplTiming {
    pub setup: Setup,
    pub n: usize,
    pub cycles: u64,
    pub flops: f64,
}

impl HplTiming {
    pub fn flops_per_cycle(&self) -> f64 {
        self.flops / self.cycles.max(1) as f64
    }
}

/// Compute the LU work profile for size `n` *analytically* (same blocking
/// as [`dgetrf`], no numerics) — lets Figure 10 sweep to sizes where a
/// functional factorization would be slow.
pub fn lu_profile_analytic(n: usize, nb: usize) -> LuProfile {
    let mut prof = LuProfile::default();
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        let m = n - j0;
        // dgetf2 flops (as accounted in lu.rs)
        for jj in 0..jb {
            let col = j0 + jj;
            let rows_below = (j0 + m - col - 1) as u64;
            prof.panel_flops += rows_below * (1 + 2 * (j0 + jb - col - 1) as u64);
        }
        let rest = n - j0 - jb;
        if rest > 0 {
            prof.trsm_flops += (jb * (jb - 1)) as u64 * rest as u64;
            let mrows = n - j0 - jb;
            prof.gemm_flops += 2 * (mrows * rest * jb) as u64;
            prof.gemm_calls.push((mrows, rest, jb));
        }
        j0 += jb;
    }
    prof
}

/// The Figure 10 experiment: HPL flops/cycle at size `n` on a setup.
pub fn hpl_cycles(setup: Setup, n: usize, nb: usize, cost: &mut CycleCost) -> HplTiming {
    let prof = lu_profile_analytic(n, nb);
    let mut cycles = 0u64;
    for &(m, nn, k) in &prof.gemm_calls {
        cycles += cost.dgemm_cycles(m, nn, k);
    }
    // trsm runs as BLAS3 at roughly the GEMM rate; charge it via an
    // equivalent-flops GEMM on the same kernel (conservative: panel rate
    // for P9-class machines is already memory-bound)
    cycles += (prof.trsm_flops as f64 / (setup.peak() * 0.6)) as u64;
    cycles += cost.panel_cycles(prof.panel_flops);
    let nf = 2.0 / 3.0 * (n as f64).powi(3) + 2.0 * (n as f64).powi(2);
    HplTiming { setup, n, cycles, flops: nf }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::gemm::{RefGemm, SimMmaGemm};

    #[test]
    fn hpl_functional_passes_reference() {
        let r = hpl_run(200, 64, 42, &mut RefGemm).unwrap();
        assert!(r.passed(), "residual {}", r.residual);
        let total = r.profile.total_flops() as f64;
        assert!((total / (2.0 / 3.0 * 200f64.powi(3)) - 1.0).abs() < 0.25);
    }

    #[test]
    fn hpl_functional_on_simulated_mma() {
        // end-to-end: HPL where every trailing MAC executes as simulated
        // MMA instructions
        let mut sim = SimMmaGemm::default();
        let r = hpl_run(96, 32, 7, &mut sim).unwrap();
        assert!(r.passed(), "residual {}", r.residual);
        assert!(sim.stats.mma_instructions > 1000);
    }

    #[test]
    fn analytic_profile_matches_functional() {
        let n = 160;
        let nb = 64;
        let mut a = {
            let mut rng = Rng::new(3);
            rng.f64_vec(n * n)
        };
        let (_, actual) = dgetrf(&mut a, n, nb, &mut RefGemm).unwrap();
        let analytic = lu_profile_analytic(n, nb);
        assert_eq!(analytic.gemm_calls, actual.gemm_calls);
        assert_eq!(analytic.gemm_flops, actual.gemm_flops);
        assert_eq!(analytic.trsm_flops, actual.trsm_flops);
        assert_eq!(analytic.panel_flops, actual.panel_flops);
    }

    #[test]
    fn fig10_shape_small_sweep() {
        // rising curve; MMA > VSX > P9 at every size; ~4x at large N
        let mut last = HashMap::new();
        for setup in Setup::ALL {
            let mut cost = CycleCost::new(setup);
            let mut prev = 0.0;
            for n in [256usize, 512, 1024] {
                let t = hpl_cycles(setup, n, 128, &mut cost);
                let fpc = t.flops_per_cycle();
                assert!(fpc >= prev * 0.98, "{:?} n={n}: {fpc:.2} dropped below {prev:.2}", setup);
                prev = fpc;
                last.insert((setup, n), fpc);
            }
        }
        let p9 = last[&(Setup::Power9Vsx, 1024)];
        let vsx = last[&(Setup::Power10Vsx, 1024)];
        let mma = last[&(Setup::Power10Mma, 1024)];
        assert!(vsx > p9 * 1.4, "P10-VSX {vsx:.2} vs P9 {p9:.2}");
        assert!(mma > vsx * 1.5, "P10-MMA {mma:.2} vs P10-VSX {vsx:.2}");
        assert!(mma / p9 > 3.0, "paper: 4x per-core HPL gain, got {:.2}", mma / p9);
        assert!(mma < 32.0 && vsx < 16.0 && p9 < 8.0, "below peak");
    }
}
