//! Declarative command-line parsing substrate (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with typed accessors and defaults, positional arguments, and generated
//! usage text. Used by `power-mma` (the main binary) and the examples.

use std::collections::HashMap;

/// Parse error with the usage text attached.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Specification of one option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative command: options + positionals + usage rendering.
#[derive(Clone, Debug, Default)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), ..Default::default() }
    }

    /// `--key <value>` option with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(|s| s.into()),
            is_flag: false,
        });
        self
    }

    /// Boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec { name: name.into(), help: help.into(), default: None, is_flag: true });
        self
    }

    /// Required positional argument.
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nusage: {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            s.push_str(" [options]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\narguments:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\noptions:\n");
            for o in &self.opts {
                let head = if o.is_flag { format!("--{}", o.name) } else { format!("--{} <v>", o.name) };
                let dflt = o.default.as_ref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  {head:<24} {}{}\n", o.help, dflt));
            }
        }
        s
    }

    /// Parse `args` (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: HashMap<String, String> = HashMap::new();
        let mut flags: HashMap<String, bool> = HashMap::new();
        let mut pos: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            }
        }
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(rest) = arg.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let Some(spec) = self.opts.iter().find(|o| o.name == key) else {
                    return Err(CliError(format!("unknown option --{key}\n\n{}", self.usage())));
                };
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{key} takes no value")));
                    }
                    flags.insert(key, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("option --{key} requires a value")))?
                            .clone(),
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(arg.clone());
            }
        }
        if pos.len() != self.positionals.len() {
            return Err(CliError(format!(
                "expected {} positional argument(s), got {}\n\n{}",
                self.positionals.len(),
                pos.len(),
                self.usage()
            )));
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                return Err(CliError(format!("missing required option --{}", o.name)));
            }
        }
        Ok(Matches { values, flags, positionals: pos })
    }
}

/// Parsed argument values with typed accessors.
#[derive(Clone, Debug)]
pub struct Matches {
    values: HashMap<String, String>,
    flags: HashMap<String, bool>,
    positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name).parse().map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name).parse().map_err(|_| CliError(format!("--{name} expects an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name).parse().map_err(|_| CliError(format!("--{name} expects a number")))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn positional(&self, i: usize) -> &str {
        &self.positionals[i]
    }

    /// Comma-separated list of integers (`--sizes 128,256,512`).
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.get(name)
            .split(',')
            .map(|t| t.trim().parse().map_err(|_| CliError(format!("--{name}: bad integer '{t}'"))))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("sim", "run a simulation")
            .opt("size", Some("128"), "problem size")
            .opt("machine", Some("p10-mma"), "machine config")
            .opt("sizes", Some("1,2"), "sweep list")
            .flag("verbose", "chatty output")
            .positional("kernel", "kernel name")
    }

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let m = cmd().parse(&v(&["dgemm"])).unwrap();
        assert_eq!(m.get_usize("size").unwrap(), 128);
        assert_eq!(m.get("machine"), "p10-mma");
        assert!(!m.flag("verbose"));
        assert_eq!(m.positional(0), "dgemm");

        let m = cmd().parse(&v(&["--size", "512", "--verbose", "sconv"])).unwrap();
        assert_eq!(m.get_usize("size").unwrap(), 512);
        assert!(m.flag("verbose"));
        assert_eq!(m.positional(0), "sconv");
    }

    #[test]
    fn equals_syntax_and_lists() {
        let m = cmd().parse(&v(&["--sizes=128,256,512", "k"])).unwrap();
        assert_eq!(m.get_usize_list("sizes").unwrap(), vec![128, 256, 512]);
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&v(&["--bogus", "x", "k"])).is_err());
        assert!(cmd().parse(&v(&["--size"])).is_err());
        assert!(cmd().parse(&v(&[])).is_err()); // missing positional
        assert!(cmd().parse(&v(&["--verbose=1", "k"])).is_err());
        let err = cmd().parse(&v(&["--help"])).unwrap_err();
        assert!(err.0.contains("usage:"));
    }

    #[test]
    fn usage_lists_everything() {
        let u = cmd().usage();
        assert!(u.contains("--size"));
        assert!(u.contains("--verbose"));
        assert!(u.contains("<kernel>"));
        assert!(u.contains("[default: 128]"));
    }
}
