//! # power-mma
//!
//! A full-system reproduction of *"A matrix math facility for Power ISA™
//! processors"* (Moreira et al., 2021) — the POWER10 **Matrix-Multiply Assist
//! (MMA)** facility.
//!
//! The crate contains:
//!
//! * [`isa`] — a bit-exact functional simulator of the MMA instruction family
//!   (Power ISA v3.1 §"VSX Matrix-Multiply Assist"), including the eight
//!   512-bit accumulator registers, the priming state machine, every rank-k
//!   update instruction of Table I (all suffix and saturating forms), the
//!   64-bit *prefixed* masked variants, and binary encode/decode validated
//!   against the object-code listing of the paper's Figure 7.
//! * [`builtins`] — the §IV programming model: `__builtin_mma_*` equivalents
//!   (Table II) as a `KernelBuilder` API that emits instruction streams and
//!   performs accumulator/VSR allocation.
//! * [`kernels`] — the paper's hand-written kernels: the DGEMM `8×N×8`
//!   kernel of Figure 6, the SCONV `8×27×16` kernel of Figure 9, the blocked
//!   `128×128×128` DGEMM kernel of §VI, reduced-precision GEMM kernels
//!   (bf16 / fp16 / int16 / int8 / int4), and POWER9-compliant VSX baseline
//!   kernels.
//! * [`core_model`] — a cycle-approximate model of the POWER9 and POWER10
//!   core backends (execution slices, VSU pipes, the Matrix Math Engine of
//!   Figures 2–3, operand/result bus timing, LSU + cache hierarchy) plus the
//!   event-based power model used for Figure 12.
//! * [`blas`] / [`hpl`] — the numerical substrate: reference BLAS, blocked
//!   GEMM over the simulated kernels, the panel-packed multithreaded
//!   serving GEMM ([`blas::block_gemm`]), the bf16 packed-panel engine
//!   ([`blas::bf16_gemm`]: rank-2 microkernel over k-pair-interleaved
//!   bf16 panels — the `xvbf16ger2` Table I fast path), and an HPL (LU)
//!   driver for Figure 10.
//! * [`runtime`] — the native serving runtime: loads the AOT-compiled
//!   JAX artifacts (`artifacts/*.hlo.txt`) produced by
//!   `python/compile/aot.py`, parses the HLO text ([`runtime::hlo`]), and
//!   by default **compiles** it into an execution plan
//!   ([`runtime::plan`]: preallocated buffer arena + blocked parallel
//!   GEMM) behind the pluggable [`runtime::EngineBackend`] trait; the
//!   legacy per-request interpreter remains as the numerics oracle.
//!   Execution is organized around the device/session layer of
//!   [`runtime::device`]: a [`Device`](runtime::Device) owning the
//!   process-wide persistent GEMM worker pool + thread budget, typed
//!   [`TensorRef`](runtime::TensorRef)/[`TensorMut`](runtime::TensorMut)
//!   buffers (f32 or raw-bits bf16), and per-request
//!   [`ExecCtx`](runtime::ExecCtx)s. The former PJRT/XLA FFI is gone —
//!   the whole request path is self-hosted rust.
//! * [`coordinator`] — the "data-in-flight business analytics" serving layer
//!   of §I: request router + dynamic batcher over the native runtime,
//!   sharded across engine threads that share one device pool, with
//!   sticky model→shard routing (cache affinity) by default.
//! * [`rt`], [`cli`], [`error`], [`testkit`], [`benchkit`], [`metrics`] —
//!   substrates (thread pool with blocking `par_for`, argument parser,
//!   error chain, property testing, benchmark harness, metrics) built
//!   from `std` because the build environment is offline and the crate
//!   has zero dependencies.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod benchkit;
pub mod blas;
pub mod builtins;
pub mod cli;
pub mod coordinator;
pub mod core_model;
pub mod error;
pub mod hpl;
pub mod isa;
pub mod kernels;
pub mod metrics;
pub mod rt;
pub mod runtime;
pub mod testkit;
