//! Blocked, panel-packed, multithreaded f32 GEMM — the serving-runtime
//! counterpart of the paper's register-blocked outer-product pipeline
//! (Figures 3–5): pack → block → microkernel.
//!
//! Structure (BLIS-style cache tiling):
//!
//! * the **column (jc) loop is the parallel axis**: the `n` output
//!   columns are split into per-worker chunks of whole `NR` panels, and
//!   each worker owns everything for its chunk — packing the `KC ×
//!   chunk` B panels (including the im2col gather of a fused
//!   convolution, so small-`Cout` conv shapes parallelize even when `m`
//!   is a single `MR` panel), packing `MR × KC` A micropanels with
//!   [`crate::kernels::pack::pack_a_panel_f32`], and running the
//!   **`MR×NR` microkernel**: per `k` step, one packed A column and one
//!   packed B row feed a rank-1 update of an `MR×NR` accumulator block,
//!   exactly the `xvf32ger` shape of the paper scaled up to registers;
//! * **NC / KC / MC loops** walk each chunk in cache-sized blocks, `kc`
//!   ascending inside the chunk so every `C` element still accumulates
//!   in strictly ascending `k` order;
//! * **how workers run is a policy**, [`Par`]: inline ([`Par::Seq`]),
//!   legacy per-call scoped threads ([`Par::Scoped`], kept for the
//!   `bench serve` comparison), or — the serving default — the
//!   **persistent worker pool** of a
//!   [`Device`](crate::runtime::device::Device) via the blocking
//!   [`par_for`](crate::rt::ThreadPool::par_for) primitive
//!   ([`Par::Pool`]): no thread is spawned or joined on the hot path.
//!
//! **Numerics contract:** every `C` element accumulates its `k` products
//! in strictly ascending order (the microkernel loads the running sum
//! before a `k` block and stores it after), in one of two accumulation
//! modes that each replicate one interpreter path bit for bit — tiling,
//! packing, worker count, *and worker mode* never change a ULP (each
//! element is computed by exactly one worker, in the same order, from
//! the same packed values):
//!
//! * [`Accum::F64`] (the `dot` mode): products and sums carried in `f64`,
//!   one final narrowing store — bit-identical to the `f64`-widened
//!   reference path of the legacy HLO-interpreter `dot`
//!   ([`crate::blas::gemm::ref_gemm`] over converted inputs);
//! * [`Accum::F32`] (the fused-convolution mode): each product rounded to
//!   `f32` and chained with `f32` adds, the first product *assigned* (so
//!   even the sign of a zero matches) — bit-identical to the
//!   interpreter's elementwise `multiply`/`add` sweep over the same tap
//!   order, which is what the conv rewrite pass of
//!   [`crate::runtime::plan`] replaces.
//!
//! The optional [`Epilogue`] (bias add / bias+relu) runs at the final `C`
//! writeback, **after** the accumulator is narrowed to `f32` and in `f32`
//! arithmetic — the same double-rounding the interpreter performs when it
//! executes the trailing `add`/`maximum` as separate instructions, so
//! fused and unfused graphs stay bit-identical.
//!
//! The B operand is abstracted behind [`PanelB`]: a plain row-major
//! matrix, or a *virtual* im2col view of a padded image
//! ([`crate::kernels::pack::Im2colSpec`]) whose shifted windows are
//! gathered directly into the packed panels — the im2col matrix is never
//! materialized.
//!
//! ```
//! use power_mma::blas::block_gemm::{
//!     gemm_f32_fused_into, Accum, Epilogue, GemmScratch, PanelB, Par,
//! };
//!
//! // C = relu(A·B + bias) in one pass: the bias add and the relu happen
//! // at the C-tile writeback, not as extra output-sized sweeps.
//! let a = [1.0f32, -2.0, 3.0, 4.0]; // 2×2
//! let b = [1.0f32, 0.0, 0.0, 1.0]; // identity
//! let bias = [0.5f32, -10.0];
//! let mut c = [0.0f32; 4];
//! let mut scratch = GemmScratch::new();
//! gemm_f32_fused_into(
//!     &mut c, &a, PanelB::Matrix(&b), 2, 2, 2,
//!     Accum::F64, Epilogue::BiasRelu(&bias), Par::Seq, &mut scratch,
//! );
//! assert_eq!(c, [1.5, 0.0, 3.5, 0.0]);
//! ```

use crate::kernels::pack::{
    pack_a_panel_f32, pack_b_im2col_f32, pack_b_panel_f32, Im2colSpec, PackedB,
};
use crate::rt::ThreadPool;
use std::sync::Mutex;

/// Microkernel register-block rows (the 8 of the paper's `8×8` DGEMM and
/// `8×16` SGEMM virtual accumulators).
pub const MR: usize = 8;
/// Microkernel register-block columns.
pub const NR: usize = 8;
/// Cache-block rows of A per worker pass (L2 residency).
pub const MC: usize = 128;
/// Cache-block depth of the packed panels (L1/L2 residency).
pub const KC: usize = 256;
/// Cache-block columns of the packed B block (L2/L3 residency).
pub const NC: usize = 512;

/// One cache-blocking configuration (the MC/KC/NC triple) a tuned GEMM
/// runs under. [`BlockCfg::DEFAULT`] is the hand-picked canonical
/// blocking every engine shipped with before the autotuner existed; the
/// autotuner ([`crate::runtime::tune`]) searches [`BlockCfg::GRID`].
/// Blocking never changes bits — every `C` element still accumulates its
/// `k` products in strictly ascending order regardless of where the
/// KC/NC/MC seams fall — so the tuner can only ever change speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockCfg {
    /// Cache-block rows of A per worker pass.
    pub mc: usize,
    /// Cache-block depth of the packed panels.
    pub kc: usize,
    /// Cache-block columns of the packed B block.
    pub nc: usize,
}

impl BlockCfg {
    /// The canonical blocking ([`MC`]/[`KC`]/[`NC`]).
    pub const DEFAULT: BlockCfg = BlockCfg { mc: MC, kc: KC, nc: NC };

    /// The autotuner's blocking search grid. Every `kc` is a multiple of
    /// 4 (the i8 quad-interleave stride, which also covers the bf16 pair
    /// stride), and every `nc` / `mc` is a multiple of every `nr` / `mr`
    /// in the kernel family, so panel slicing never straddles a block
    /// boundary (the scratch-sizing invariant `reserve_for` relies on).
    pub const GRID: [BlockCfg; 8] = [
        BlockCfg { mc: 64, kc: 128, nc: 256 },
        BlockCfg { mc: 64, kc: 128, nc: 512 },
        BlockCfg { mc: 64, kc: 256, nc: 256 },
        BlockCfg { mc: 64, kc: 256, nc: 512 },
        BlockCfg { mc: 128, kc: 128, nc: 256 },
        BlockCfg { mc: 128, kc: 128, nc: 512 },
        BlockCfg { mc: 128, kc: 256, nc: 256 },
        BlockCfg { mc: 128, kc: 256, nc: 512 },
    ];
}

/// One monomorphized GEMM variant: a register-tile geometry (`mr × nr`,
/// the paper's virtual-accumulator shape) plus a cache-blocking
/// configuration. The dispatchers monomorphize a small family per dtype
/// (f32: 4×8 / 8×8 / 8×16; bf16 and i8: 8×8 / 8×16) — every member is
/// bitwise identical to the canonical variant under every accumulation
/// contract, so the autotuner selects purely on speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmVariant {
    /// Register-block rows.
    pub mr: usize,
    /// Register-block columns.
    pub nr: usize,
    /// Cache-blocking configuration.
    pub block: BlockCfg,
}

impl GemmVariant {
    /// The canonical f32 variant ([`MR`]×[`NR`], default blocking) — the
    /// exact engine every pre-tuner caller ran, and the deterministic
    /// heuristic default when tuning is off.
    pub const CANONICAL_F32: GemmVariant =
        GemmVariant { mr: MR, nr: NR, block: BlockCfg::DEFAULT };

    /// The canonical 8×16 variant the bf16 and i8 engines ship with
    /// (the Figure 8 / `xvi8ger4` virtual-accumulator width).
    pub const CANONICAL_WIDE: GemmVariant =
        GemmVariant { mr: 8, nr: 16, block: BlockCfg::DEFAULT };

    /// The f32 register tiles the dispatcher monomorphizes.
    pub const F32_KERNELS: [(usize, usize); 3] = [(8, 8), (4, 8), (8, 16)];
    /// The bf16/i8 register tiles (canonical 8×16 plus the narrow 8×8).
    pub const WIDE_KERNELS: [(usize, usize); 2] = [(8, 16), (8, 8)];

    /// Every f32 candidate, **canonical first** (the tuner breaks ties
    /// toward the head of the list, so equal timings keep the default).
    pub fn f32_candidates() -> Vec<GemmVariant> {
        GemmVariant::family(&Self::F32_KERNELS, Self::CANONICAL_F32)
    }

    /// Every bf16/i8 candidate, canonical (8×16, default blocking) first.
    pub fn wide_candidates() -> Vec<GemmVariant> {
        GemmVariant::family(&Self::WIDE_KERNELS, Self::CANONICAL_WIDE)
    }

    fn family(kernels: &[(usize, usize)], canonical: GemmVariant) -> Vec<GemmVariant> {
        let mut out = vec![canonical];
        for &(mr, nr) in kernels {
            for block in BlockCfg::GRID {
                let v = GemmVariant { mr, nr, block };
                if v != canonical {
                    out.push(v);
                }
            }
        }
        out
    }

    /// Human-readable identity, e.g. `"8x8/mc128kc256nc512"` — the form
    /// the `bench serve` tuning table and test failures print.
    pub fn name(&self) -> String {
        format!(
            "{}x{}/mc{}kc{}nc{}",
            self.mr, self.nr, self.block.mc, self.block.kc, self.block.nc
        )
    }
}

/// What a tuned GEMM call actually executes, as seen by the MMA
/// hardware: the register-tile and cache-blocking geometry plus the
/// Table I rank-k instruction the microkernel's inner update corresponds
/// to. Each packed engine reports its own descriptor
/// ([`executed_kernel_f32`], [`crate::blas::bf16_gemm::executed_kernel_bf16`],
/// [`crate::blas::i8_gemm::executed_kernel_i8`]); the roofline layer
/// ([`crate::runtime::profile`]) synthesizes the equivalent instruction
/// stream from it, so the profiled kernel is exactly the executed one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutedKernel {
    /// Packed-panel element type, e.g. `"f32"`.
    pub elem: &'static str,
    /// Base mnemonic of the rank-k update the microkernel maps to
    /// (Table I), e.g. `"xvf32ger"`.
    pub ger: &'static str,
    /// Rank of that update (products per instruction per element).
    pub rank: usize,
    /// Bytes per packed-panel element (what one `lxv` moves 16 of).
    pub esize: usize,
    /// Problem shape.
    pub m: usize,
    /// Problem shape.
    pub n: usize,
    /// Problem shape.
    pub k: usize,
    /// The tuner-chosen register tile and cache blocking the call ran.
    pub v: GemmVariant,
}

/// The descriptor of a tuned f32 GEMM call: `xvf32ger` (rank 1) over
/// 4-byte packed panels, under the given variant's blocking.
pub fn executed_kernel_f32(m: usize, n: usize, k: usize, v: GemmVariant) -> ExecutedKernel {
    ExecutedKernel { elem: "f32", ger: "xvf32ger", rank: 1, esize: 4, m, n, k, v }
}

/// Approximate flop count (`2·m·n·k`) below which a **scoped-spawn** GEMM
/// runs inline instead of spawning workers — spawning and joining OS
/// threads only pays for 128³-and-up tiles.
pub const PAR_FLOP_THRESHOLD: usize = 2_000_000;

/// The lower fan-out bar for the **persistent pool** ([`Par::Pool`]):
/// dispatch is a queue push, not a thread spawn, so conv-shaped im2col
/// GEMMs (`m=8, n=H·W, k=9·Cin` ≈ 0.9 Mflop) fan out while batched-MLP
/// dots (≈ 0.5 Mflop) stay on the serial latency path.
pub const POOL_PAR_FLOP_THRESHOLD: usize = 600_000;

/// How a GEMM call runs its column-chunk workers — the execution policy
/// the caller (normally [`crate::runtime::plan::Plan`] via a
/// [`Device`](crate::runtime::device::Device)) picks per step.
#[derive(Clone, Copy)]
pub enum Par<'a> {
    /// Serial on the calling thread.
    Seq,
    /// Spawn scoped threads for this call and join them before returning
    /// (the legacy pre-device behavior, kept for pool-less callers and
    /// for `bench serve`'s scoped-vs-persistent comparison).
    Scoped(usize),
    /// Fan out over a persistent worker pool (the device pool), capped
    /// at the given worker count. The calling thread participates, so
    /// several engines sharing one pool all make progress.
    Pool(&'a ThreadPool, usize),
}

impl<'a> Par<'a> {
    /// The worker cap of this policy (1 for [`Par::Seq`]).
    pub fn cap(&self) -> usize {
        match *self {
            Par::Seq => 1,
            Par::Scoped(t) | Par::Pool(_, t) => t.max(1),
        }
    }

    /// Apply the per-GEMM fan-out policy for an `m×n×k` problem: below
    /// the mode's flop threshold the step runs serial ([`Par::Seq`]),
    /// otherwise the cap is clamped to the column-panel count (the
    /// parallel axis — see [`threads_for`] / [`threads_for_pooled`]).
    pub fn for_gemm(&self, m: usize, n: usize, k: usize) -> Par<'a> {
        match *self {
            Par::Seq => Par::Seq,
            Par::Scoped(t) => match threads_for(m, n, k, t) {
                1 => Par::Seq,
                w => Par::Scoped(w),
            },
            Par::Pool(p, t) => match threads_for_pooled(m, n, k, t) {
                1 => Par::Seq,
                w => Par::Pool(p, w),
            },
        }
    }

    /// Run `f(0..tasks)` to completion under this policy (shared with
    /// the bf16 packed engine, [`crate::blas::bf16_gemm`]).
    pub(crate) fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        match *self {
            Par::Seq => {
                for i in 0..tasks {
                    f(i);
                }
            }
            Par::Scoped(_) => std::thread::scope(|s| {
                for i in 1..tasks {
                    s.spawn(move || f(i));
                }
                f(0);
            }),
            Par::Pool(pool, _) => pool.par_for(tasks, f),
        }
    }
}

/// Reusable scratch for [`gemm_f32_fused_into`]: the `f64` accumulation
/// image of `C` (column-chunk-blocked during the parallel phase) and one
/// packed-B-block plus packed-A-panel buffer **per column-chunk worker**
/// (each worker packs its own columns — including im2col gathers — so
/// there is no shared packing phase to serialize on). Holding one per
/// compiled plan means a serving request performs **no GEMM-sized
/// allocation** — buffers are grown once ([`GemmScratch::reserve`], or
/// lazily on first use) and reused for every request.
#[derive(Default)]
pub struct GemmScratch {
    c64: Vec<f64>,
    bp: Vec<Vec<f32>>,
    ap: Vec<Vec<f32>>,
}

impl GemmScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Grow the buffers so a subsequent `m×n×k` GEMM on up to `threads`
    /// workers allocates nothing (canonical variant).
    pub fn reserve(&mut self, m: usize, n: usize, k: usize, threads: usize) {
        self.reserve_for(m, n, k, threads, GemmVariant::CANONICAL_F32);
    }

    /// [`GemmScratch::reserve`] for an explicit variant: panel sizes are
    /// derived from the variant's blocking config, not the fixed
    /// [`KC`]/[`NC`] constants — the satellite fix for the latent
    /// scratch-sizing assumption.
    pub fn reserve_for(&mut self, m: usize, n: usize, k: usize, threads: usize, v: GemmVariant) {
        let (nchunks, cols_per) = chunk_plan_nr(n, threads.max(1), v.nr);
        self.reserve_chunks(m, n, k, nchunks, cols_per, v);
    }

    fn reserve_chunks(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        nchunks: usize,
        cols_per: usize,
        v: GemmVariant,
    ) {
        let c_need = m * n;
        if self.c64.len() < c_need {
            self.c64.resize(c_need, 0.0);
        }
        let kc = v.block.kc.min(k.max(1));
        let bp_need = kc * v.block.nc.min(cols_per.max(v.nr));
        if self.bp.len() < nchunks {
            self.bp.resize_with(nchunks, Vec::new);
        }
        for b in &mut self.bp[..nchunks] {
            if b.len() < bp_need {
                b.resize(bp_need, 0.0);
            }
        }
        let ap_need = kc * v.mr;
        if self.ap.len() < nchunks {
            self.ap.resize_with(nchunks, Vec::new);
        }
        for a in &mut self.ap[..nchunks] {
            if a.len() < ap_need {
                a.resize(ap_need, 0.0);
            }
        }
    }
}

/// The column-chunk decomposition of an `n`-column GEMM over up to `cap`
/// workers for a microkernel `nr` columns wide: each chunk is a whole
/// number of `nr` panels, and `(nchunks, cols_per)` satisfies
/// `nchunks <= cap` and `nchunks * cols_per >= n` with `cols_per % nr ==
/// 0`. Shared by every engine — this module's f32 engine, the bf16 and
/// i8 packed engines, and every tuned variant (`nr` ∈ {8, 16}); the
/// coverage/no-overlap/clamp properties are pinned for the whole family
/// by `rust/tests/tune_engine.rs`.
pub fn chunk_plan_nr(n: usize, cap: usize, nr: usize) -> (usize, usize) {
    let col_panels = n.max(1).div_ceil(nr);
    let cap = cap.clamp(1, col_panels);
    let cols_per = col_panels.div_ceil(cap) * nr;
    (n.max(1).div_ceil(cols_per), cols_per)
}

/// Accumulation mode of the microkernel — each mode is bit-identical to
/// one interpreter path (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Accum {
    /// `f64` products and sums, one final narrowing store (the `dot`
    /// contract of [`crate::blas::gemm::ref_gemm`]).
    F64,
    /// `f32`-rounded products chained with `f32` adds, first product
    /// assigned (the elementwise multiply/add-sweep contract the conv
    /// rewrite replaces).
    F32,
}

/// Fused post-GEMM epilogue, applied per element at the final `C`
/// writeback in `f32` (after the accumulator narrows): the compiled form
/// of the trailing `broadcast+add` / `maximum(0)` instructions the plan
/// rewrite pass removes. The slices are indexed by output column and
/// must hold at least `n` elements.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Store `c = (f32)acc` unchanged.
    None,
    /// `c = (f32)acc + bias[j]`.
    Bias(&'a [f32]),
    /// `c = max((f32)acc + bias[j], 0.0)` — bias add then relu, the
    /// MLP's fused `dot → add → maximum` tail.
    BiasRelu(&'a [f32]),
    /// `c = (f32)acc ∓ other[i·n+j]` — combine with a same-shaped `m×n`
    /// matrix at the writeback, the DFT step's fused `±` tail
    /// (`yr = xr·Fr − xi·Fi`, `yi = xr·Fi + xi·Fr`). `sub == true`
    /// subtracts; IEEE `a − b` is bit-identical to the interpreter's
    /// lowered `a + (−1·b)` for every input, so the fused form matches
    /// the oracle exactly.
    DftCombine {
        /// The already-computed other product, `m×n` row-major.
        other: &'a [f32],
        /// Subtract (`true`, the `yr` real combine) or add (`false`,
        /// the `yi` imaginary combine).
        sub: bool,
    },
}

impl Epilogue<'_> {
    /// Apply the epilogue to one already-narrowed element of column `j`
    /// at linear output index `idx` (`i·n + j`). Shared with the bf16
    /// engine, whose writeback fuses the same tails.
    #[inline]
    pub(crate) fn apply(&self, v: f32, j: usize, idx: usize) -> f32 {
        match self {
            Epilogue::None => v,
            Epilogue::Bias(bias) => v + bias[j],
            Epilogue::BiasRelu(bias) => (v + bias[j]).max(0.0),
            Epilogue::DftCombine { other, sub: true } => v - other[idx],
            Epilogue::DftCombine { other, sub: false } => v + other[idx],
        }
    }
}

/// Where the packed B panels come from.
pub enum PanelB<'a> {
    /// A plain `k×n` row-major matrix (the `dot` path).
    Matrix(&'a [f32]),
    /// A virtual `k×n` im2col view over a padded image: row `k` is the
    /// shifted window `spec.bases[k]` (see
    /// [`Im2colSpec`](crate::kernels::pack::Im2colSpec)); panels are
    /// gathered straight from `img`, the matrix is never materialized.
    Im2col {
        /// Flat padded image (`Cin·IH·IW` elements).
        img: &'a [f32],
        /// The precompiled gather (one base offset per `k` row).
        spec: &'a Im2colSpec,
    },
    /// A `k×n` matrix pre-packed at plan-compile time
    /// ([`PackedB`](crate::kernels::pack::PackedB)): panel queries are
    /// straight copies of the stored grid cells. The grid must have been
    /// built for this GEMM's exact `(k, n, nr, kc)` geometry — the DFT
    /// step's pinned Fourier panels.
    Packed(&'a PackedB),
}

impl PanelB<'_> {
    /// Pack rows `k0..k0+kc` × columns `j0..j0+cols` into an `nr`-wide
    /// panel (zero-padded n-tail), whatever the source.
    #[allow(clippy::too_many_arguments)]
    fn pack(
        &self,
        ldb: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        cols: usize,
        nr: usize,
        out: &mut [f32],
    ) {
        match self {
            PanelB::Matrix(b) => pack_b_panel_f32(b, ldb, k0, kc, j0, cols, nr, out),
            PanelB::Im2col { img, spec } => {
                pack_b_im2col_f32(img, spec, k0, kc, j0, cols, nr, out)
            }
            PanelB::Packed(pb) => {
                debug_assert_eq!(pb.geometry().1, ldb, "packed B built for a different n");
                debug_assert!(cols <= nr);
                out[..kc * nr].copy_from_slice(pb.panel(k0, kc, j0));
            }
        }
    }
}

/// The shared fan-out rule: 1 worker below `threshold` flops
/// (`2·m·n·k`), otherwise `max_threads` clamped to the `NR`-column
/// panel count (the parallel axis).
fn threads_for_with(m: usize, n: usize, k: usize, max_threads: usize, threshold: usize) -> usize {
    let work = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if work < threshold {
        return 1;
    }
    max_threads.clamp(1, n.div_ceil(NR).max(1))
}

/// Pick the **scoped-spawn** worker count for an `m×n×k` GEMM: at most
/// `max_threads`, at most one worker per `NR`-column panel (the parallel
/// axis), and 1 when the problem is below [`PAR_FLOP_THRESHOLD`].
pub fn threads_for(m: usize, n: usize, k: usize, max_threads: usize) -> usize {
    threads_for_with(m, n, k, max_threads, PAR_FLOP_THRESHOLD)
}

/// Pick the **persistent-pool** worker count for an `m×n×k` GEMM: same
/// clamps as [`threads_for`] but with the lower
/// [`POOL_PAR_FLOP_THRESHOLD`] bar — pool dispatch is cheap enough that
/// conv-shaped im2col GEMMs fan out.
pub fn threads_for_pooled(m: usize, n: usize, k: usize, max_threads: usize) -> usize {
    threads_for_with(m, n, k, max_threads, POOL_PAR_FLOP_THRESHOLD)
}

/// `C = A·B` into a caller-provided `c` (`m×n`, row-major, fully
/// overwritten). `a` is `m×k`, `b` is `k×n`, both row-major and
/// contiguous. Legacy scoped-thread entry point: `threads` workers are
/// spawned per call (1 runs inline) and joined before the call returns —
/// callers pick the policy, typically via [`threads_for`]. Shorthand for
/// [`gemm_f32_fused_into`] with a plain matrix B, `f64` accumulation, no
/// epilogue, and [`Par::Scoped`]; the serving path passes [`Par::Pool`]
/// instead. See the module docs for the numerics contract (both modes
/// produce identical bits).
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    let par = if threads <= 1 { Par::Seq } else { Par::Scoped(threads) };
    gemm_f32_fused_into(c, a, PanelB::Matrix(b), m, n, k, Accum::F64, Epilogue::None, par, scratch);
}

/// The full fused GEMM: `C = epilogue(A·B)` with a pluggable B-panel
/// source ([`PanelB`]), accumulation mode ([`Accum`]), writeback
/// epilogue ([`Epilogue`]), and worker policy ([`Par`]). `c` is `m×n`
/// row-major (fully overwritten), `a` is `m×k` row-major contiguous.
/// The column chunks are distributed per `par` and joined (or drained)
/// before the call returns; the epilogue runs on the final
/// single-threaded narrowing pass, so workers never see it.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_fused_into(
    c: &mut [f32],
    a: &[f32],
    b: PanelB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: Accum,
    epilogue: Epilogue<'_>,
    par: Par<'_>,
    scratch: &mut GemmScratch,
) {
    gemm_f32_tuned_into(
        c,
        a,
        b,
        m,
        n,
        k,
        accum,
        epilogue,
        par,
        scratch,
        GemmVariant::CANONICAL_F32,
    );
}

/// [`gemm_f32_fused_into`] with an explicit [`GemmVariant`] — the entry
/// point the autotuned plan steps call. **Every variant produces the
/// same bits as [`GemmVariant::CANONICAL_F32`]** under both [`Accum`]
/// contracts: each `C` element is computed by exactly one worker from
/// the same packed values in the same strictly-ascending-`k` order, so
/// the register-tile geometry and the KC/NC/MC seams only move work
/// around, never reassociate it (`rust/tests/tune_engine.rs` pins this
/// across the full family).
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_tuned_into(
    c: &mut [f32],
    a: &[f32],
    b: PanelB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: Accum,
    epilogue: Epilogue<'_>,
    par: Par<'_>,
    scratch: &mut GemmScratch,
    v: GemmVariant,
) {
    assert!(
        v.block.nc % v.nr == 0 && v.block.mc % v.mr == 0,
        "blocking must be tile-aligned: {}",
        v.name()
    );
    assert_eq!(a.len(), m * k, "A must be m*k");
    assert_eq!(c.len(), m * n, "C must be m*n");
    match &b {
        PanelB::Matrix(bm) => assert_eq!(bm.len(), k * n, "B must be k*n"),
        PanelB::Im2col { spec, .. } => {
            assert!(spec.bases.len() >= k, "im2col spec must cover all k rows");
        }
        PanelB::Packed(pb) => assert_eq!(
            pb.geometry(),
            (k, n, v.nr, v.block.kc),
            "packed B geometry must match this GEMM's shape and variant"
        ),
    }
    match epilogue {
        Epilogue::Bias(bias) | Epilogue::BiasRelu(bias) => {
            assert!(bias.len() >= n, "bias must cover all n columns");
        }
        Epilogue::DftCombine { other, .. } => {
            assert!(other.len() >= m * n, "combine operand must cover the m*n output");
        }
        Epilogue::None => {}
    }
    if m == 0 || n == 0 {
        return;
    }
    let (nchunks, cols_per) = chunk_plan_nr(n, par.cap(), v.nr);
    scratch.reserve_chunks(m, n, k, nchunks, cols_per, v);
    let c64 = &mut scratch.c64[..m * n];
    c64.fill(0.0);
    if k > 0 {
        // Per-chunk mutable state, handed to the shared dispatch closure
        // through per-index mutexes (worker w locks only entry w, so the
        // locks are uncontended — they exist to keep the closure `Fn`).
        // During the parallel phase the f64 image is *column-chunk
        // blocked*: chunk w owns the contiguous region
        // c64[m*cols_per*w ..][..m*wcols], an m×wcols row-major block of
        // the columns [w*cols_per, w*cols_per + wcols).
        struct Chunk<'s> {
            c64: &'s mut [f64],
            bp: &'s mut [f32],
            ap: &'s mut [f32],
        }
        let mut chunks: Vec<Mutex<Chunk<'_>>> = Vec::with_capacity(nchunks);
        let mut rest: &mut [f64] = c64;
        for (w, (bpb, apb)) in
            scratch.bp.iter_mut().zip(scratch.ap.iter_mut()).take(nchunks).enumerate()
        {
            let wcols = cols_per.min(n - w * cols_per);
            let (cw, r) = rest.split_at_mut(m * wcols);
            rest = r;
            chunks.push(Mutex::new(Chunk { c64: cw, bp: bpb, ap: apb }));
        }
        let chunks = &chunks;
        let b = &b;
        par.run(nchunks, &|w| {
            let mut guard = chunks[w].lock().unwrap_or_else(|p| p.into_inner());
            let ch = &mut *guard;
            let j0 = w * cols_per;
            let wcols = cols_per.min(n - j0);
            col_worker(ch.c64, a, b, ch.bp, ch.ap, m, n, k, j0, wcols, accum, v);
        });
    }
    // the C-tile writeback: narrow, then apply the fused epilogue in f32
    // (bit-identical to the interpreter running the trailing add/maximum
    // as separate instructions), de-blocking the column chunks back into
    // the row-major output
    let c64 = &scratch.c64;
    for w in 0..nchunks {
        let j0 = w * cols_per;
        let wcols = cols_per.min(n - j0);
        let cw = &c64[m * cols_per * w..m * cols_per * w + m * wcols];
        for i in 0..m {
            let crow = &mut c[i * n + j0..i * n + j0 + wcols];
            let srow = &cw[i * wcols..(i + 1) * wcols];
            for (jl, (dst, &src)) in crow.iter_mut().zip(srow).enumerate() {
                *dst = epilogue.apply(src as f32, j0 + jl, i * n + j0 + jl);
            }
        }
    }
}

/// Convenience wrapper over [`gemm_f32_into`] that owns its result and
/// scratch.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    let mut scratch = GemmScratch::new();
    gemm_f32_into(&mut c, a, b, m, n, k, threads, &mut scratch);
    c
}

/// One worker's share: the full `m` rows of columns `j0 .. j0+wcols`
/// (passed as the chunk-owned `m×wcols` block `c64`), the whole `k`
/// depth. Walks its columns in `v.block.nc` cache blocks, `kc` ascending
/// inside (the bit-identity order), packs its own B panels per (nc, kc)
/// block — including the im2col gather — and sweeps each packed
/// `mr×kcl` A micropanel across the chunk's `nr` panels.
#[allow(clippy::too_many_arguments)]
fn col_worker(
    c64: &mut [f64],
    a: &[f32],
    b: &PanelB<'_>,
    bp: &mut [f32],
    ap: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    wcols: usize,
    accum: Accum,
    v: GemmVariant,
) {
    let (mr, nr) = (v.mr, v.nr);
    let BlockCfg { mc, kc, nc } = v.block;
    for jc in (0..wcols).step_by(nc) {
        let ncl = nc.min(wcols - jc);
        let n_panels = ncl.div_ceil(nr);
        for kc0 in (0..k).step_by(kc) {
            let kcl = kc.min(k - kc0);
            // the F32 chain *assigns* its first product (kc0 == 0)
            // instead of accumulating into the zeroed image, so even
            // the sign of a zero product matches the interpreter
            let first = accum == Accum::F32 && kc0 == 0;
            // pack the kc×ncl sub-block of B into nr-wide row panels:
            // panel jp at bp[jp*kcl*nr ..], element (p, j) at p*nr + j
            let bpl = &mut bp[..n_panels * kcl * nr];
            for jp in 0..n_panels {
                let jabs = j0 + jc + jp * nr;
                let cols = nr.min(j0 + jc + ncl - jabs);
                let panel = &mut bpl[jp * kcl * nr..(jp + 1) * kcl * nr];
                b.pack(n, kc0, kcl, jabs, cols, nr, panel);
            }
            let bpl = &*bpl;
            let apl = &mut ap[..kcl * mr];
            for ic in (0..m).step_by(mc) {
                let mcl = mc.min(m - ic);
                for ir in (0..mcl).step_by(mr) {
                    let gi = ic + ir;
                    let mrl = mr.min(m - gi);
                    pack_a_panel_f32(a, k, gi, mrl, kc0, kcl, mr, apl);
                    for jp in 0..n_panels {
                        let jloc = jc + jp * nr;
                        let nrl = nr.min(wcols - jloc);
                        let bpp = &bpl[jp * kcl * nr..(jp + 1) * kcl * nr];
                        match accum {
                            Accum::F64 => {
                                microkernel_f64_v(v, c64, gi, jloc, wcols, apl, bpp, kcl, mrl, nrl)
                            }
                            Accum::F32 => microkernel_f32_v(
                                v, c64, gi, jloc, wcols, apl, bpp, kcl, mrl, nrl, first,
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Dispatch one f64-contract register tile to its monomorphized kernel.
#[allow(clippy::too_many_arguments)]
fn microkernel_f64_v(
    v: GemmVariant,
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
) {
    match (v.mr, v.nr) {
        (4, 8) => microkernel_g::<4, 8>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl),
        (8, 8) => microkernel_g::<8, 8>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl),
        (8, 16) => microkernel_g::<8, 16>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl),
        (mr, nr) => unreachable!("no monomorphized f32 register tile {mr}x{nr}"),
    }
}

/// Dispatch one f32-chain register tile to its monomorphized kernel.
#[allow(clippy::too_many_arguments)]
fn microkernel_f32_v(
    v: GemmVariant,
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
    first: bool,
) {
    match (v.mr, v.nr) {
        (4, 8) => microkernel_f32_g::<4, 8>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl, first),
        (8, 8) => microkernel_f32_g::<8, 8>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl, first),
        (8, 16) => microkernel_f32_g::<8, 16>(c64, ci, j0, ld, ap, bp, kcl, mrl, nrl, first),
        (mr, nr) => unreachable!("no monomorphized f32 register tile {mr}x{nr}"),
    }
}

/// The `MR_×NR_` f64 microkernel, monomorphized per register tile: loads
/// the running `f64` sums of one `C` register block (row stride `ld`),
/// applies `kcl` rank-1 updates from the packed panels in ascending `k`
/// order, and stores the sums back. Only the `mrl×nrl` valid corner is
/// loaded/stored (tail handling); the zero-padded panel lanes are
/// computed and discarded — so a tile *taller* than `mrl` burns rows,
/// which is exactly the asymmetry the autotuner exploits (4×8 beats 8×8
/// on `m = 1` classes).
#[allow(clippy::too_many_arguments)]
fn microkernel_g<const MR_: usize, const NR_: usize>(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
) {
    let mut acc = [[0f64; NR_]; MR_];
    for i in 0..mrl {
        let crow = &c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        acc[i][..nrl].copy_from_slice(crow);
    }
    for p in 0..kcl {
        let ac = &ap[p * MR_..(p + 1) * MR_];
        let br = &bp[p * NR_..(p + 1) * NR_];
        for (row, &araw) in acc.iter_mut().zip(ac) {
            let av = f64::from(araw);
            for (slot, &bv) in row.iter_mut().zip(br) {
                *slot += av * f64::from(bv);
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        crow.copy_from_slice(&acc[i][..nrl]);
    }
}

/// The `MR_×NR_` f32-chain microkernel ([`Accum::F32`]), monomorphized
/// per register tile: the running sums are exact `f32` values stored
/// widened in the `c64` image (load and store round-trip losslessly),
/// each product is rounded to `f32`, and the chain advances with `f32`
/// adds in ascending `k` order. When `first` is set (the `k = 0` block),
/// the first product is *assigned* rather than added to the zero image —
/// `fl32(0 + x)` would turn a `-0.0` product into `+0.0` and break
/// bit-identity with the interpreter's elementwise sweep.
#[allow(clippy::too_many_arguments)]
fn microkernel_f32_g<const MR_: usize, const NR_: usize>(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
    first: bool,
) {
    let mut acc = [[0f32; NR_]; MR_];
    if !first {
        for i in 0..mrl {
            let crow = &c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
            for (slot, &v) in acc[i][..nrl].iter_mut().zip(crow) {
                *slot = v as f32; // exact: the image holds f32 values
            }
        }
    }
    for p in 0..kcl {
        let ac = &ap[p * MR_..(p + 1) * MR_];
        let br = &bp[p * NR_..(p + 1) * NR_];
        for (row, &av) in acc.iter_mut().zip(ac) {
            if first && p == 0 {
                for (slot, &bv) in row.iter_mut().zip(br) {
                    *slot = av * bv;
                }
            } else {
                for (slot, &bv) in row.iter_mut().zip(br) {
                    *slot += av * bv;
                }
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        for (slot, &v) in crow.iter_mut().zip(&acc[i][..nrl]) {
            *slot = f64::from(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::gemm::ref_gemm;
    use crate::testkit::{assert_allclose_f32, check, Rng};

    /// The legacy interpreter dot path: widen to f64, ref_gemm, narrow.
    fn ref_path(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let af: Vec<f64> = a.iter().map(|&v| f64::from(v)).collect();
        let bf: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
        ref_gemm(&af, &bf, m, n, k).iter().map(|&v| v as f32).collect()
    }

    fn chunk_plan(n: usize, cap: usize) -> (usize, usize) {
        chunk_plan_nr(n, cap, NR)
    }

    #[test]
    fn chunk_plan_partitions_whole_panels() {
        for (n, cap, want_chunks, want_cols) in [
            (2048usize, 8usize, 8usize, 256usize),
            (70, 8, 5, 16),
            (1, 8, 1, 8),
            (8, 4, 1, 8),
            (512, 1, 1, 512),
            (17, 3, 3, 8),
        ] {
            let (nchunks, cols_per) = chunk_plan(n, cap);
            assert_eq!((nchunks, cols_per), (want_chunks, want_cols), "n={n} cap={cap}");
            assert!(nchunks * cols_per >= n);
            assert!(cols_per % NR == 0);
            assert!(nchunks <= cap.max(1));
        }
    }

    #[test]
    fn exhaustive_small_shape_sweep_with_tails() {
        // every combination straddling the MR/NR/KC boundaries, incl.
        // m/n/k not multiples of the block sizes
        let ms = [1, 2, 3, 7, 8, 9, 15, 16, 17];
        let ns = [1, 2, 5, 7, 8, 9, 16, 17];
        let ks = [1, 2, 3, 8, 9, 31, 33];
        let mut rng = Rng::new(0xb10c);
        for &m in &ms {
            for &n in &ns {
                for &k in &ks {
                    let a = rng.f32_vec(m * k);
                    let b = rng.f32_vec(k * n);
                    let expect = ref_path(&a, &b, m, n, k);
                    for threads in [1, 4] {
                        let got = gemm_f32(&a, &b, m, n, k, threads);
                        assert_eq!(
                            got, expect,
                            "bit-identity broken at m={m} n={n} k={k} threads={threads}"
                        );
                        assert_allclose_f32(&got, &expect, 1e-5, 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    fn crosses_kc_and_nc_boundaries() {
        // k > KC forces multiple packed B blocks; n > NC forces several
        // cache blocks inside one worker chunk
        let (m, n, k) = (33, NC + 70, KC + 37);
        let mut rng = Rng::new(7);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let expect = ref_path(&a, &b, m, n, k);
        for threads in [1, 2, 3, 8] {
            assert_eq!(gemm_f32(&a, &b, m, n, k, threads), expect, "threads={threads}");
        }
    }

    #[test]
    fn thread_count_never_changes_bits() {
        check("blocked gemm thread invariance", 6, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 80), rng.range(1, 80), rng.range(1, 80));
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let t1 = gemm_f32(&a, &b, m, n, k, 1);
            assert_eq!(t1, ref_path(&a, &b, m, n, k));
            for threads in [2, 5] {
                assert_eq!(t1, gemm_f32(&a, &b, m, n, k, threads));
            }
        });
    }

    #[test]
    fn pool_scoped_and_seq_are_bit_identical() {
        // the three worker policies must agree bit for bit, in both
        // accumulation modes, across shapes straddling the chunk grid
        let pool = ThreadPool::new("bg-test", 4);
        let mut rng = Rng::new(0x9001);
        for &(m, n, k) in &[(1usize, 1usize, 3usize), (8, 20, 27), (33, 70, 40), (16, 300, 9)] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            for accum in [Accum::F64, Accum::F32] {
                let mut outs: Vec<Vec<f32>> = Vec::new();
                for par in [Par::Seq, Par::Scoped(3), Par::Pool(&pool, 3), Par::Pool(&pool, 4)] {
                    let mut c = vec![0f32; m * n];
                    let mut scratch = GemmScratch::new();
                    gemm_f32_fused_into(
                        &mut c,
                        &a,
                        PanelB::Matrix(&b),
                        m,
                        n,
                        k,
                        accum,
                        Epilogue::None,
                        par,
                        &mut scratch,
                    );
                    outs.push(c);
                }
                for o in &outs[1..] {
                    assert_eq!(o, &outs[0], "m={m} n={n} k={k} {accum:?}");
                }
            }
        }
        pool.shutdown();
    }

    #[test]
    fn pool_reuse_across_sequential_gemms_is_bit_identical() {
        // satellite acceptance: one pool + one scratch reused across a
        // sequence of GEMMs must reproduce the scoped-spawn results
        let pool = ThreadPool::new("bg-seq", 3);
        let mut rng = Rng::new(0x5e9);
        let mut scratch = GemmScratch::new();
        for round in 0..6 {
            let (m, n, k) = (rng.range(1, 60), rng.range(1, 90), rng.range(1, 70));
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let mut c_pool = vec![0f32; m * n];
            gemm_f32_fused_into(
                &mut c_pool,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::None,
                Par::Pool(&pool, 3),
                &mut scratch,
            );
            let c_scoped = gemm_f32(&a, &b, m, n, k, 3);
            assert_eq!(c_pool, c_scoped, "round {round} m={m} n={n} k={k}");
            assert_eq!(c_pool, ref_path(&a, &b, m, n, k), "round {round}");
        }
        pool.shutdown();
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // a big GEMM followed by a small one through the same scratch must
        // not leak stale accumulation state
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(11);
        let (a1, b1) = (rng.f32_vec(40 * 24), rng.f32_vec(24 * 36));
        let mut c1 = vec![0f32; 40 * 36];
        gemm_f32_into(&mut c1, &a1, &b1, 40, 36, 24, 2, &mut scratch);
        let (a2, b2) = (rng.f32_vec(3 * 5), rng.f32_vec(5 * 4));
        let mut c2 = vec![0f32; 3 * 4];
        gemm_f32_into(&mut c2, &a2, &b2, 3, 4, 5, 1, &mut scratch);
        assert_eq!(c2, ref_path(&a2, &b2, 3, 4, 5));
        assert_eq!(c1, ref_path(&a1, &b1, 40, 36, 24));
    }

    #[test]
    fn degenerate_shapes() {
        // k = 0 -> all zeros; 1×1×1 -> plain product
        let mut c = vec![9f32; 6];
        gemm_f32_into(&mut c, &[], &[], 2, 3, 0, 4, &mut GemmScratch::new());
        assert_eq!(c, vec![0.0; 6]);
        assert_eq!(gemm_f32(&[2.0], &[3.5], 1, 1, 1, 1), vec![7.0]);
    }

    /// The interpreter's elementwise conv sweep: f32 products, f32 chain
    /// adds in ascending k, first product assigned.
    fn ref_f32_chain(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = a[i * k] * b[j];
                for p in 1..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn f32_chain_matches_elementwise_sweep_bitwise() {
        let pool = ThreadPool::new("bg-f32", 3);
        let mut rng = Rng::new(0xc0a);
        for &(m, n, k) in &[(1, 1, 2), (3, 5, 9), (8, 16, 27), (9, 17, KC + 3), (8, 2048, 27)] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let expect = ref_f32_chain(&a, &b, m, n, k);
            let mut scratch = GemmScratch::new();
            for par in [Par::Seq, Par::Scoped(3), Par::Pool(&pool, 3)] {
                let mut c = vec![0f32; m * n];
                gemm_f32_fused_into(
                    &mut c,
                    &a,
                    PanelB::Matrix(&b),
                    m,
                    n,
                    k,
                    Accum::F32,
                    Epilogue::None,
                    par,
                    &mut scratch,
                );
                assert_eq!(c, expect, "m={m} n={n} k={k}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn f32_chain_preserves_negative_zero_first_product() {
        // both products are -0.0: the assigned start keeps the sign
        // through the chain (-0.0 + -0.0 = -0.0) while a naive
        // zero-initialized accumulator would give 0 + (-0.0) = +0.0.
        // (The previous vector used a = [-1, 0], whose *second* product
        // is +0.0 — and IEEE says -0.0 + +0.0 = +0.0, so that test could
        // never pass; it predates a rust toolchain being available.)
        let a = [-1.0f32, -1.0];
        let b = [0.0f32, 0.0];
        let mut c = [9f32; 1];
        gemm_f32_fused_into(
            &mut c,
            &a,
            PanelB::Matrix(&b),
            1,
            1,
            2,
            Accum::F32,
            Epilogue::None,
            Par::Seq,
            &mut GemmScratch::new(),
        );
        assert_eq!(c[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn epilogue_matches_separate_sweeps_bitwise() {
        // fused bias / bias+relu must equal "gemm, then add, then max"
        // done as separate f32 passes (the interpreter instruction order)
        let pool = ThreadPool::new("bg-epi", 4);
        let mut rng = Rng::new(0xe91);
        let (m, n, k) = (13, 21, 40);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let plain = gemm_f32(&a, &b, m, n, k, 1);
        let biased: Vec<f32> =
            plain.iter().enumerate().map(|(f, &v)| v + bias[f % n]).collect();
        let relued: Vec<f32> = biased.iter().map(|&v| v.max(0.0)).collect();
        let mut scratch = GemmScratch::new();
        for par in [Par::Seq, Par::Scoped(4), Par::Pool(&pool, 4)] {
            let mut c = vec![0f32; m * n];
            gemm_f32_fused_into(
                &mut c,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::Bias(&bias),
                par,
                &mut scratch,
            );
            assert_eq!(c, biased, "bias");
            gemm_f32_fused_into(
                &mut c,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::BiasRelu(&bias),
                par,
                &mut scratch,
            );
            assert_eq!(c, relued, "bias_relu");
        }
        pool.shutdown();
    }

    #[test]
    fn im2col_panels_equal_materialized_matrix() {
        use crate::kernels::pack::Im2colSpec;
        // padded 2-channel 6x7 image, 3x3 taps, 4x5 output (n = 20):
        // the im2col gather must match the materialized matrix bit for
        // bit under every worker policy (each pool worker packs its own
        // columns — the parallel-packing satellite)
        let pool = ThreadPool::new("bg-im2col", 3);
        let (cin, ih, iw, h, w) = (2usize, 6usize, 7usize, 4usize, 5usize);
        let mut rng = Rng::new(0x132c);
        let img = rng.f32_vec(cin * ih * iw);
        let mut bases = Vec::new();
        for c in 0..cin {
            for dy in 0..3 {
                for dx in 0..3 {
                    bases.push(c * ih * iw + dy * iw + dx);
                }
            }
        }
        let k = bases.len();
        let n = h * w;
        let spec = Im2colSpec { bases: bases.clone(), img_w: iw, out_w: w };
        // materialize the im2col matrix and compare both paths bitwise
        let mut bmat = vec![0f32; k * n];
        for (p, &base) in bases.iter().enumerate() {
            for col in 0..n {
                bmat[p * n + col] = img[base + (col / w) * iw + (col % w)];
            }
        }
        let m = 8;
        let a = rng.f32_vec(m * k);
        let mut scratch = GemmScratch::new();
        let mut c1 = vec![0f32; m * n];
        let mut c2 = vec![0f32; m * n];
        for accum in [Accum::F64, Accum::F32] {
            for par in [Par::Seq, Par::Pool(&pool, 3)] {
                gemm_f32_fused_into(
                    &mut c1,
                    &a,
                    PanelB::Im2col { img: &img, spec: &spec },
                    m,
                    n,
                    k,
                    accum,
                    Epilogue::None,
                    par,
                    &mut scratch,
                );
                gemm_f32_fused_into(
                    &mut c2,
                    &a,
                    PanelB::Matrix(&bmat),
                    m,
                    n,
                    k,
                    accum,
                    Epilogue::None,
                    par,
                    &mut scratch,
                );
                assert_eq!(c1, c2, "{accum:?}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn variant_family_shape_and_order() {
        let f32v = GemmVariant::f32_candidates();
        let wide = GemmVariant::wide_candidates();
        // canonical first (tie-breaking), no duplicates, expected counts
        assert_eq!(f32v[0], GemmVariant::CANONICAL_F32);
        assert_eq!(wide[0], GemmVariant::CANONICAL_WIDE);
        assert_eq!(f32v.len(), 3 * BlockCfg::GRID.len());
        assert_eq!(wide.len(), 2 * BlockCfg::GRID.len());
        for (i, v) in f32v.iter().enumerate() {
            assert!(!f32v[..i].contains(v), "duplicate {}", v.name());
            // the scratch-sizing invariant: blocking aligned to the tile
            assert_eq!(v.block.nc % v.nr, 0, "{}", v.name());
            assert_eq!(v.block.mc % v.mr, 0, "{}", v.name());
            assert_eq!(v.block.kc % 4, 0, "{}", v.name());
        }
        assert_eq!(GemmVariant::CANONICAL_F32.name(), "8x8/mc128kc256nc512");
    }

    #[test]
    fn every_f32_variant_matches_canonical_bitwise_spot() {
        // the full sweep lives in tests/tune_engine.rs; this in-module
        // spot check keeps the invariant visible next to the kernels
        let mut rng = Rng::new(0x7a11);
        let (m, n, k) = (9, 17, 33);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let expect = ref_path(&a, &b, m, n, k);
        for v in GemmVariant::f32_candidates() {
            let mut c = vec![0f32; m * n];
            let mut scratch = GemmScratch::new();
            gemm_f32_tuned_into(
                &mut c,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::None,
                Par::Seq,
                &mut scratch,
                v,
            );
            assert_eq!(c, expect, "variant {}", v.name());
        }
    }

    #[test]
    fn threads_for_policy() {
        assert_eq!(threads_for(32, 64, 128, 8), 1, "MLP-sized dot stays inline (scoped)");
        assert_eq!(threads_for(512, 512, 512, 8), 8, "512-class GEMM fans out");
        assert!(threads_for(512, 512, 512, 64) <= 512usize.div_ceil(NR));
        // the column split unlocks short-wide shapes (one MR row panel)
        assert_eq!(threads_for(8, 4096, 4096, 16), 16, "N-split parallelizes m=8");
        // pool policy: conv-shaped im2col GEMMs fan out, MLP dots do not
        assert_eq!(threads_for_pooled(8, 2048, 27, 8), 8, "conv shape uses the pool");
        assert_eq!(threads_for_pooled(32, 128, 64, 8), 1, "mlp layer stays serial");
        assert_eq!(threads_for_pooled(8, 16, 27, 8), 1, "tiny conv stays serial");
    }
}
