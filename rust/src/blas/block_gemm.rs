//! Blocked, panel-packed, multithreaded f32 GEMM — the serving-runtime
//! counterpart of the paper's register-blocked outer-product pipeline
//! (Figures 3–5): pack → block → microkernel.
//!
//! Structure (BLIS-style cache tiling):
//!
//! * **NC / KC / MC loops** walk `C = A·B` in cache-sized blocks;
//! * the **B block** (`KC × NC`) is packed once per (jc, kc) iteration
//!   into `NR`-wide row panels and shared (read-only) by all workers;
//! * each worker packs its **A micropanels** (`MR × KC`, column-major)
//!   with [`crate::kernels::pack::pack_a_panel_f32`] — the same layout
//!   machinery the MMA kernel hosts use — and runs the
//!   **`MR×NR` microkernel**: per `k` step, one packed A column and one
//!   packed B row feed a rank-1 update of an `MR×NR` accumulator block,
//!   exactly the `xvf32ger` shape of the paper scaled up to registers;
//! * the **M-panel loop is parallelized** over a scoped `std::thread`
//!   worker pool sized from `available_parallelism()`. Workers own
//!   disjoint row ranges of `C`, join before the call returns, and no
//!   `Send` requirement leaks to the caller — the threading model is
//!   compatible with the coordinator's thread-confined engine.
//!
//! **Numerics contract:** every `C` element accumulates its `k` products
//! in strictly ascending order (the microkernel loads the running sum
//! before a `k` block and stores it after), in one of two accumulation
//! modes that each replicate one interpreter path bit for bit — tiling,
//! packing, and thread count never change a ULP:
//!
//! * [`Accum::F64`] (the `dot` mode): products and sums carried in `f64`,
//!   one final narrowing store — bit-identical to the `f64`-widened
//!   reference path of the legacy HLO-interpreter `dot`
//!   ([`crate::blas::gemm::ref_gemm`] over converted inputs);
//! * [`Accum::F32`] (the fused-convolution mode): each product rounded to
//!   `f32` and chained with `f32` adds, the first product *assigned* (so
//!   even the sign of a zero matches) — bit-identical to the
//!   interpreter's elementwise `multiply`/`add` sweep over the same tap
//!   order, which is what the conv rewrite pass of
//!   [`crate::runtime::plan`] replaces.
//!
//! The optional [`Epilogue`] (bias add / bias+relu) runs at the final `C`
//! writeback, **after** the accumulator is narrowed to `f32` and in `f32`
//! arithmetic — the same double-rounding the interpreter performs when it
//! executes the trailing `add`/`maximum` as separate instructions, so
//! fused and unfused graphs stay bit-identical.
//!
//! The B operand is abstracted behind [`PanelB`]: a plain row-major
//! matrix, or a *virtual* im2col view of a padded image
//! ([`crate::kernels::pack::Im2colSpec`]) whose shifted windows are
//! gathered directly into the packed panels — the im2col matrix is never
//! materialized.
//!
//! ```
//! use power_mma::blas::block_gemm::{gemm_f32_fused_into, Accum, Epilogue, GemmScratch, PanelB};
//!
//! // C = relu(A·B + bias) in one pass: the bias add and the relu happen
//! // at the C-tile writeback, not as extra output-sized sweeps.
//! let a = [1.0f32, -2.0, 3.0, 4.0]; // 2×2
//! let b = [1.0f32, 0.0, 0.0, 1.0]; // identity
//! let bias = [0.5f32, -10.0];
//! let mut c = [0.0f32; 4];
//! let mut scratch = GemmScratch::new();
//! gemm_f32_fused_into(
//!     &mut c, &a, PanelB::Matrix(&b), 2, 2, 2,
//!     Accum::F64, Epilogue::BiasRelu(&bias), 1, &mut scratch,
//! );
//! assert_eq!(c, [1.5, 0.0, 3.5, 0.0]);
//! ```

use crate::kernels::pack::{pack_a_panel_f32, pack_b_im2col_f32, pack_b_panel_f32, Im2colSpec};

/// Microkernel register-block rows (the 8 of the paper's `8×8` DGEMM and
/// `8×16` SGEMM virtual accumulators).
pub const MR: usize = 8;
/// Microkernel register-block columns.
pub const NR: usize = 8;
/// Cache-block rows of A per worker pass (L2 residency).
pub const MC: usize = 128;
/// Cache-block depth of the packed panels (L1/L2 residency).
pub const KC: usize = 256;
/// Cache-block columns of the packed B block (L2/L3 residency).
pub const NC: usize = 512;

/// Approximate flop count (`2·m·n·k`) below which the M-panel loop runs
/// inline instead of spawning workers — batched-MLP-sized dots stay on
/// the latency path, 128³-and-up GEMM tiles fan out.
pub const PAR_FLOP_THRESHOLD: usize = 2_000_000;

/// Reusable scratch for [`gemm_f32_into`]: the `f64` accumulation image
/// of `C`, the packed B block, and one packed-A-panel buffer per worker.
/// Holding one per compiled plan means a serving request performs **no
/// GEMM-sized allocation** — buffers are grown once
/// ([`GemmScratch::reserve`], or lazily on first use) and reused for
/// every request.
#[derive(Default)]
pub struct GemmScratch {
    c64: Vec<f64>,
    bp: Vec<f32>,
    ap: Vec<Vec<f32>>,
}

impl GemmScratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Grow the buffers so a subsequent `m×n×k` GEMM on up to `threads`
    /// workers allocates nothing.
    pub fn reserve(&mut self, m: usize, n: usize, k: usize, threads: usize) {
        let c_need = m * n;
        if self.c64.len() < c_need {
            self.c64.resize(c_need, 0.0);
        }
        let bp_need = KC.min(k.max(1)) * n.min(NC).div_ceil(NR) * NR;
        if self.bp.len() < bp_need {
            self.bp.resize(bp_need, 0.0);
        }
        let workers = threads.clamp(1, m.max(1).div_ceil(MR));
        if self.ap.len() < workers {
            self.ap.resize_with(workers, Vec::new);
        }
        let ap_need = KC.min(k.max(1)) * MR;
        for apb in &mut self.ap[..workers] {
            if apb.len() < ap_need {
                apb.resize(ap_need, 0.0);
            }
        }
    }
}

/// Accumulation mode of the microkernel — each mode is bit-identical to
/// one interpreter path (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Accum {
    /// `f64` products and sums, one final narrowing store (the `dot`
    /// contract of [`crate::blas::gemm::ref_gemm`]).
    F64,
    /// `f32`-rounded products chained with `f32` adds, first product
    /// assigned (the elementwise multiply/add-sweep contract the conv
    /// rewrite replaces).
    F32,
}

/// Fused post-GEMM epilogue, applied per element at the final `C`
/// writeback in `f32` (after the accumulator narrows): the compiled form
/// of the trailing `broadcast+add` / `maximum(0)` instructions the plan
/// rewrite pass removes. The slices are indexed by output column and
/// must hold at least `n` elements.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Store `c = (f32)acc` unchanged.
    None,
    /// `c = (f32)acc + bias[j]`.
    Bias(&'a [f32]),
    /// `c = max((f32)acc + bias[j], 0.0)` — bias add then relu, the
    /// MLP's fused `dot → add → maximum` tail.
    BiasRelu(&'a [f32]),
}

impl Epilogue<'_> {
    /// Apply the epilogue to one already-narrowed element of column `j`.
    #[inline]
    fn apply(&self, v: f32, j: usize) -> f32 {
        match self {
            Epilogue::None => v,
            Epilogue::Bias(bias) => v + bias[j],
            Epilogue::BiasRelu(bias) => (v + bias[j]).max(0.0),
        }
    }
}

/// Where the packed B panels come from.
pub enum PanelB<'a> {
    /// A plain `k×n` row-major matrix (the `dot` path).
    Matrix(&'a [f32]),
    /// A virtual `k×n` im2col view over a padded image: row `k` is the
    /// shifted window `spec.bases[k]` (see
    /// [`Im2colSpec`](crate::kernels::pack::Im2colSpec)); panels are
    /// gathered straight from `img`, the matrix is never materialized.
    Im2col {
        /// Flat padded image (`Cin·IH·IW` elements).
        img: &'a [f32],
        /// The precompiled gather (one base offset per `k` row).
        spec: &'a Im2colSpec,
    },
}

impl PanelB<'_> {
    /// Pack rows `k0..k0+kc` × columns `j0..j0+cols` into an `nr`-wide
    /// panel (zero-padded n-tail), whatever the source.
    #[allow(clippy::too_many_arguments)]
    fn pack(
        &self,
        ldb: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        cols: usize,
        nr: usize,
        out: &mut [f32],
    ) {
        match self {
            PanelB::Matrix(b) => pack_b_panel_f32(b, ldb, k0, kc, j0, cols, nr, out),
            PanelB::Im2col { img, spec } => {
                pack_b_im2col_f32(img, spec, k0, kc, j0, cols, nr, out)
            }
        }
    }
}

/// Pick the worker count for an `m×n×k` GEMM: at most `max_threads`, at
/// most one worker per `MR`-row panel, and 1 when the problem is below
/// [`PAR_FLOP_THRESHOLD`].
pub fn threads_for(m: usize, n: usize, k: usize, max_threads: usize) -> usize {
    let work = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if work < PAR_FLOP_THRESHOLD {
        return 1;
    }
    max_threads.clamp(1, m.div_ceil(MR))
}

/// `C = A·B` into a caller-provided `c` (`m×n`, row-major, fully
/// overwritten). `a` is `m×k`, `b` is `k×n`, both row-major and
/// contiguous. Exactly `threads` scoped workers are used (clamped to the
/// number of `MR`-row panels; 1 runs inline without spawning) and joined
/// before the call returns — callers pick the policy, typically via
/// [`threads_for`]. Shorthand for [`gemm_f32_fused_into`] with a plain
/// matrix B, `f64` accumulation, and no epilogue; see the module docs for
/// the numerics contract.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    gemm_f32_fused_into(
        c,
        a,
        PanelB::Matrix(b),
        m,
        n,
        k,
        Accum::F64,
        Epilogue::None,
        threads,
        scratch,
    );
}

/// The full fused GEMM: `C = epilogue(A·B)` with a pluggable B-panel
/// source ([`PanelB`]), accumulation mode ([`Accum`]), and writeback
/// epilogue ([`Epilogue`]). `c` is `m×n` row-major (fully overwritten),
/// `a` is `m×k` row-major contiguous. Threading as in
/// [`gemm_f32_into`]; the epilogue runs on the final single-threaded
/// narrowing pass, so workers never see it.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_fused_into(
    c: &mut [f32],
    a: &[f32],
    b: PanelB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: Accum,
    epilogue: Epilogue<'_>,
    threads: usize,
    scratch: &mut GemmScratch,
) {
    assert_eq!(a.len(), m * k, "A must be m*k");
    assert_eq!(c.len(), m * n, "C must be m*n");
    match &b {
        PanelB::Matrix(bm) => assert_eq!(bm.len(), k * n, "B must be k*n"),
        PanelB::Im2col { spec, .. } => {
            assert!(spec.bases.len() >= k, "im2col spec must cover all k rows");
        }
    }
    match epilogue {
        Epilogue::Bias(bias) | Epilogue::BiasRelu(bias) => {
            assert!(bias.len() >= n, "bias must cover all n columns");
        }
        Epilogue::None => {}
    }
    if m == 0 || n == 0 {
        return;
    }
    scratch.reserve(m, n, k, threads);
    let c64 = &mut scratch.c64[..m * n];
    c64.fill(0.0);
    if k > 0 {
        let nthreads = threads.clamp(1, m.div_ceil(MR));
        // rows per worker, rounded up to whole MR panels
        let rows_per = m.div_ceil(MR).div_ceil(nthreads) * MR;
        let ap_slots = &mut scratch.ap[..nthreads];
        for jc in (0..n).step_by(NC) {
            let ncl = NC.min(n - jc);
            for kc0 in (0..k).step_by(KC) {
                let kcl = KC.min(k - kc0);
                // the F32 chain *assigns* its first product (kc0 == 0)
                // instead of accumulating into the zeroed image, so even
                // the sign of a zero product matches the interpreter
                let first = accum == Accum::F32 && kc0 == 0;
                // pack the KC×NC block of B into NR-wide row panels:
                // panel jp at bp[jp*kcl*NR ..], element (p, j) at p*NR + j
                let n_panels = ncl.div_ceil(NR);
                let bp = &mut scratch.bp[..n_panels * kcl * NR];
                for jp in 0..n_panels {
                    let j0 = jc + jp * NR;
                    let cols = NR.min(n - j0);
                    let panel = &mut bp[jp * kcl * NR..(jp + 1) * kcl * NR];
                    b.pack(n, kc0, kcl, j0, cols, NR, panel);
                }
                let bp = &*bp;
                if nthreads == 1 {
                    let ap0 = &mut ap_slots[0];
                    worker(c64, a, bp, ap0, 0, m, m, k, n, kc0, kcl, jc, ncl, accum, first);
                } else {
                    std::thread::scope(|s| {
                        let chunks = c64.chunks_mut(rows_per * n);
                        for ((w, chunk), apb) in chunks.enumerate().zip(ap_slots.iter_mut()) {
                            let i0 = w * rows_per;
                            let rows = chunk.len() / n;
                            s.spawn(move || {
                                worker(
                                    chunk, a, bp, apb, i0, rows, m, k, n, kc0, kcl, jc, ncl,
                                    accum, first,
                                );
                            });
                        }
                    });
                }
            }
        }
    }
    // the C-tile writeback: narrow, then apply the fused epilogue in f32
    // (bit-identical to the interpreter running the trailing add/maximum
    // as separate instructions)
    for (row, crow) in c.chunks_mut(n).zip(c64.chunks(n)) {
        for (j, (dst, &src)) in row.iter_mut().zip(crow.iter()).enumerate() {
            *dst = epilogue.apply(src as f32, j);
        }
    }
}

/// Convenience wrapper over [`gemm_f32_into`] that owns its result and
/// scratch.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    let mut scratch = GemmScratch::new();
    gemm_f32_into(&mut c, a, b, m, n, k, threads, &mut scratch);
    c
}

/// One worker's share: rows `i0 .. i0+rows` of `C` (passed as the
/// worker-owned slice `c64` whose row 0 is global row `i0`), one (jc, kc)
/// block. Walks MC row blocks, packs each `MR×kcl` A micropanel once, and
/// sweeps it across all `NR` panels of the packed B block.
#[allow(clippy::too_many_arguments)]
fn worker(
    c64: &mut [f64],
    a: &[f32],
    bp: &[f32],
    ap: &mut [f32],
    i0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    kc0: usize,
    kcl: usize,
    jc: usize,
    ncl: usize,
    accum: Accum,
    first: bool,
) {
    let ap = &mut ap[..kcl * MR];
    for ic in (0..rows).step_by(MC) {
        let mcl = MC.min(rows - ic);
        for ir in (0..mcl).step_by(MR) {
            let gi = i0 + ic + ir; // global row of this micropanel
            let mrl = MR.min(m - gi);
            pack_a_panel_f32(a, k, gi, mrl, kc0, kcl, MR, ap);
            for jp in 0..ncl.div_ceil(NR) {
                let j0 = jc + jp * NR;
                let nrl = NR.min(jc + ncl - j0);
                let bpp = &bp[jp * kcl * NR..(jp + 1) * kcl * NR];
                match accum {
                    Accum::F64 => microkernel(c64, ic + ir, j0, n, ap, bpp, kcl, mrl, nrl),
                    Accum::F32 => {
                        microkernel_f32(c64, ic + ir, j0, n, ap, bpp, kcl, mrl, nrl, first)
                    }
                }
            }
        }
    }
}

/// The `MR×NR` f64 microkernel: loads the running `f64` sums of one `C`
/// register block, applies `kcl` rank-1 updates from the packed panels in
/// ascending `k` order, and stores the sums back. Only the `mrl×nrl`
/// valid corner is loaded/stored (tail handling); the zero-padded panel
/// lanes are computed and discarded.
#[allow(clippy::too_many_arguments)]
fn microkernel(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    n: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
) {
    let mut acc = [0f64; MR * NR];
    for i in 0..mrl {
        let crow = &c64[(ci + i) * n + j0..(ci + i) * n + j0 + nrl];
        acc[i * NR..i * NR + nrl].copy_from_slice(crow);
    }
    for p in 0..kcl {
        let ac = &ap[p * MR..(p + 1) * MR];
        let br = &bp[p * NR..(p + 1) * NR];
        for i in 0..MR {
            let av = f64::from(ac[i]);
            let row = &mut acc[i * NR..(i + 1) * NR];
            for (slot, &bv) in row.iter_mut().zip(br) {
                *slot += av * f64::from(bv);
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * n + j0..(ci + i) * n + j0 + nrl];
        crow.copy_from_slice(&acc[i * NR..i * NR + nrl]);
    }
}

/// The `MR×NR` f32-chain microkernel ([`Accum::F32`]): the running sums
/// are exact `f32` values stored widened in the `c64` image (load and
/// store round-trip losslessly), each product is rounded to `f32`, and
/// the chain advances with `f32` adds in ascending `k` order. When
/// `first` is set (the `k = 0` block), the first product is *assigned*
/// rather than added to the zero image — `fl32(0 + x)` would turn a
/// `-0.0` product into `+0.0` and break bit-identity with the
/// interpreter's elementwise sweep.
#[allow(clippy::too_many_arguments)]
fn microkernel_f32(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    n: usize,
    ap: &[f32],
    bp: &[f32],
    kcl: usize,
    mrl: usize,
    nrl: usize,
    first: bool,
) {
    let mut acc = [0f32; MR * NR];
    if !first {
        for i in 0..mrl {
            let crow = &c64[(ci + i) * n + j0..(ci + i) * n + j0 + nrl];
            for (slot, &v) in acc[i * NR..i * NR + nrl].iter_mut().zip(crow) {
                *slot = v as f32; // exact: the image holds f32 values
            }
        }
    }
    for p in 0..kcl {
        let ac = &ap[p * MR..(p + 1) * MR];
        let br = &bp[p * NR..(p + 1) * NR];
        for i in 0..MR {
            let av = ac[i];
            let row = &mut acc[i * NR..(i + 1) * NR];
            if first && p == 0 {
                for (slot, &bv) in row.iter_mut().zip(br) {
                    *slot = av * bv;
                }
            } else {
                for (slot, &bv) in row.iter_mut().zip(br) {
                    *slot += av * bv;
                }
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * n + j0..(ci + i) * n + j0 + nrl];
        for (slot, &v) in crow.iter_mut().zip(&acc[i * NR..i * NR + nrl]) {
            *slot = f64::from(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::gemm::ref_gemm;
    use crate::testkit::{assert_allclose_f32, check, Rng};

    /// The legacy interpreter dot path: widen to f64, ref_gemm, narrow.
    fn ref_path(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let af: Vec<f64> = a.iter().map(|&v| f64::from(v)).collect();
        let bf: Vec<f64> = b.iter().map(|&v| f64::from(v)).collect();
        ref_gemm(&af, &bf, m, n, k).iter().map(|&v| v as f32).collect()
    }

    #[test]
    fn exhaustive_small_shape_sweep_with_tails() {
        // every combination straddling the MR/NR/KC boundaries, incl.
        // m/n/k not multiples of the block sizes
        let ms = [1, 2, 3, 7, 8, 9, 15, 16, 17];
        let ns = [1, 2, 5, 7, 8, 9, 16, 17];
        let ks = [1, 2, 3, 8, 9, 31, 33];
        let mut rng = Rng::new(0xb10c);
        for &m in &ms {
            for &n in &ns {
                for &k in &ks {
                    let a = rng.f32_vec(m * k);
                    let b = rng.f32_vec(k * n);
                    let expect = ref_path(&a, &b, m, n, k);
                    for threads in [1, 4] {
                        let got = gemm_f32(&a, &b, m, n, k, threads);
                        assert_eq!(
                            got, expect,
                            "bit-identity broken at m={m} n={n} k={k} threads={threads}"
                        );
                        assert_allclose_f32(&got, &expect, 1e-5, 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    fn crosses_kc_and_nc_boundaries() {
        // k > KC forces multiple packed B blocks; n > NR*several panels
        let (m, n, k) = (33, 70, KC + 37);
        let mut rng = Rng::new(7);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let expect = ref_path(&a, &b, m, n, k);
        for threads in [1, 2, 3, 8] {
            assert_eq!(gemm_f32(&a, &b, m, n, k, threads), expect, "threads={threads}");
        }
    }

    #[test]
    fn thread_count_never_changes_bits() {
        check("blocked gemm thread invariance", 6, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 80), rng.range(1, 80), rng.range(1, 80));
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let t1 = gemm_f32(&a, &b, m, n, k, 1);
            assert_eq!(t1, ref_path(&a, &b, m, n, k));
            for threads in [2, 5] {
                assert_eq!(t1, gemm_f32(&a, &b, m, n, k, threads));
            }
        });
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // a big GEMM followed by a small one through the same scratch must
        // not leak stale accumulation state
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(11);
        let (a1, b1) = (rng.f32_vec(40 * 24), rng.f32_vec(24 * 36));
        let mut c1 = vec![0f32; 40 * 36];
        gemm_f32_into(&mut c1, &a1, &b1, 40, 36, 24, 2, &mut scratch);
        let (a2, b2) = (rng.f32_vec(3 * 5), rng.f32_vec(5 * 4));
        let mut c2 = vec![0f32; 3 * 4];
        gemm_f32_into(&mut c2, &a2, &b2, 3, 4, 5, 1, &mut scratch);
        assert_eq!(c2, ref_path(&a2, &b2, 3, 4, 5));
        assert_eq!(c1, ref_path(&a1, &b1, 40, 36, 24));
    }

    #[test]
    fn degenerate_shapes() {
        // k = 0 -> all zeros; 1×1×1 -> plain product
        let mut c = vec![9f32; 6];
        gemm_f32_into(&mut c, &[], &[], 2, 3, 0, 4, &mut GemmScratch::new());
        assert_eq!(c, vec![0.0; 6]);
        assert_eq!(gemm_f32(&[2.0], &[3.5], 1, 1, 1, 1), vec![7.0]);
    }

    /// The interpreter's elementwise conv sweep: f32 products, f32 chain
    /// adds in ascending k, first product assigned.
    fn ref_f32_chain(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = a[i * k] * b[j];
                for p in 1..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn f32_chain_matches_elementwise_sweep_bitwise() {
        let mut rng = Rng::new(0xc0a);
        for &(m, n, k) in &[(1, 1, 2), (3, 5, 9), (8, 16, 27), (9, 17, KC + 3), (8, 2048, 27)] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let expect = ref_f32_chain(&a, &b, m, n, k);
            let mut scratch = GemmScratch::new();
            for threads in [1usize, 3] {
                let mut c = vec![0f32; m * n];
                gemm_f32_fused_into(
                    &mut c,
                    &a,
                    PanelB::Matrix(&b),
                    m,
                    n,
                    k,
                    Accum::F32,
                    Epilogue::None,
                    threads,
                    &mut scratch,
                );
                assert_eq!(c, expect, "m={m} n={n} k={k} threads={threads}");
            }
        }
    }

    #[test]
    fn f32_chain_preserves_negative_zero_first_product() {
        // (-1) * 0 = -0.0; a naive 0 + (-0.0) start would give +0.0
        let a = [-1.0f32, 0.0];
        let b = [0.0f32, 0.0];
        let mut c = [9f32; 1];
        gemm_f32_fused_into(
            &mut c,
            &a,
            PanelB::Matrix(&b),
            1,
            1,
            2,
            Accum::F32,
            Epilogue::None,
            1,
            &mut GemmScratch::new(),
        );
        assert_eq!(c[0].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn epilogue_matches_separate_sweeps_bitwise() {
        // fused bias / bias+relu must equal "gemm, then add, then max"
        // done as separate f32 passes (the interpreter instruction order)
        let mut rng = Rng::new(0xe91);
        let (m, n, k) = (13, 21, 40);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let plain = gemm_f32(&a, &b, m, n, k, 1);
        let biased: Vec<f32> =
            plain.iter().enumerate().map(|(f, &v)| v + bias[f % n]).collect();
        let relued: Vec<f32> = biased.iter().map(|&v| v.max(0.0)).collect();
        let mut scratch = GemmScratch::new();
        for threads in [1usize, 4] {
            let mut c = vec![0f32; m * n];
            gemm_f32_fused_into(
                &mut c,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::Bias(&bias),
                threads,
                &mut scratch,
            );
            assert_eq!(c, biased, "bias threads={threads}");
            gemm_f32_fused_into(
                &mut c,
                &a,
                PanelB::Matrix(&b),
                m,
                n,
                k,
                Accum::F64,
                Epilogue::BiasRelu(&bias),
                threads,
                &mut scratch,
            );
            assert_eq!(c, relued, "bias_relu threads={threads}");
        }
    }

    #[test]
    fn im2col_panels_equal_materialized_matrix() {
        use crate::kernels::pack::Im2colSpec;
        // padded 2-channel 6x7 image, 3x3 taps, 4x5 output (n = 20)
        let (cin, ih, iw, h, w) = (2usize, 6usize, 7usize, 4usize, 5usize);
        let mut rng = Rng::new(0x132c);
        let img = rng.f32_vec(cin * ih * iw);
        let mut bases = Vec::new();
        for c in 0..cin {
            for dy in 0..3 {
                for dx in 0..3 {
                    bases.push(c * ih * iw + dy * iw + dx);
                }
            }
        }
        let k = bases.len();
        let n = h * w;
        let spec = Im2colSpec { bases: bases.clone(), img_w: iw, out_w: w };
        // materialize the im2col matrix and compare both paths bitwise
        let mut bmat = vec![0f32; k * n];
        for (p, &base) in bases.iter().enumerate() {
            for col in 0..n {
                bmat[p * n + col] = img[base + (col / w) * iw + (col % w)];
            }
        }
        let m = 8;
        let a = rng.f32_vec(m * k);
        let mut scratch = GemmScratch::new();
        let mut c1 = vec![0f32; m * n];
        let mut c2 = vec![0f32; m * n];
        for accum in [Accum::F64, Accum::F32] {
            gemm_f32_fused_into(
                &mut c1,
                &a,
                PanelB::Im2col { img: &img, spec: &spec },
                m,
                n,
                k,
                accum,
                Epilogue::None,
                1,
                &mut scratch,
            );
            gemm_f32_fused_into(
                &mut c2,
                &a,
                PanelB::Matrix(&bmat),
                m,
                n,
                k,
                accum,
                Epilogue::None,
                1,
                &mut scratch,
            );
            assert_eq!(c1, c2, "{accum:?}");
        }
    }

    #[test]
    fn threads_for_policy() {
        assert_eq!(threads_for(32, 64, 128, 8), 1, "MLP-sized dot stays inline");
        assert!(threads_for(512, 512, 512, 8) == 8, "512-class GEMM fans out");
        assert!(threads_for(512, 512, 512, 64) <= 512usize.div_ceil(MR));
        assert_eq!(threads_for(8, 4096, 4096, 16), 1, "one row panel -> one worker");
    }
}
