//! BLAS level-2: matrix-vector operations (row-major).

/// Rank-1 update `A += alpha * x yᵀ` on an `m×n` row-major matrix with row
/// stride `lda` — the scalar cousin of the MMA `ger` instructions.
pub fn dger(alpha: f64, x: &[f64], y: &[f64], a: &mut [f64], lda: usize, m: usize, n: usize) {
    for i in 0..m {
        let xi = alpha * x[i];
        let row = &mut a[i * lda..i * lda + n];
        for (aij, &yj) in row.iter_mut().zip(&y[..n]) {
            *aij += xi * yj;
        }
    }
}

/// `y = alpha*A·x + beta*y` for a row-major `m×n` A.
pub fn dgemv(alpha: f64, a: &[f64], lda: usize, x: &[f64], beta: f64, y: &mut [f64], m: usize, n: usize) {
    for i in 0..m {
        let dot: f64 = (0..n).map(|j| a[i * lda + j] * x[j]).sum();
        y[i] = alpha * dot + beta * y[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_allclose, Rng};

    #[test]
    fn ger_small() {
        let mut a = vec![0.0; 6];
        dger(2.0, &[1.0, 2.0], &[10.0, 20.0, 30.0], &mut a, 3, 2, 3);
        assert_eq!(a, vec![20.0, 40.0, 60.0, 40.0, 80.0, 120.0]);
    }

    #[test]
    fn gemv_vs_manual() {
        let mut rng = Rng::new(11);
        let (m, n) = (5, 7);
        let a = rng.f64_vec(m * n);
        let x = rng.f64_vec(n);
        let mut y = rng.f64_vec(m);
        let y0 = y.clone();
        dgemv(1.5, &a, n, &x, -0.5, &mut y, m, n);
        let expect: Vec<f64> = (0..m)
            .map(|i| 1.5 * (0..n).map(|j| a[i * n + j] * x[j]).sum::<f64>() - 0.5 * y0[i])
            .collect();
        assert_allclose(&y, &expect, 1e-12, 1e-14);
    }

    #[test]
    fn ger_respects_lda() {
        // 2x2 update inside a 2x4 matrix
        let mut a = vec![0.0; 8];
        dger(1.0, &[1.0, 1.0], &[5.0, 6.0], &mut a, 4, 2, 2);
        assert_eq!(a, vec![5.0, 6.0, 0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
    }
}
