//! Integer **int8 rank-4 packed-panel GEMM engine** — the serving-side
//! realization of the paper's Table I claim that `xvi8ger4` retires 4
//! MACs per instruction per lane with i32 accumulation (§II-B.2's
//! mixed-signedness deep-learning path: signed i8 X, unsigned u8 Y),
//! built exactly the way the bf16 engine was built: the win lives in the
//! **packing layer**, which interleaves the operands as *k-quads* so
//! every microkernel step consumes four inner-dimension values per fused
//! update.
//!
//! Structure (the BLIS-style skeleton of [`crate::blas::block_gemm`],
//! re-instantiated for byte-wide element types):
//!
//! * operands arrive as [`I8SrcA`] / [`I8SrcB`]: **quantized bytes**
//!   (`i8` A / `u8` B — packed verbatim) or f32 with the affine
//!   quantization (scale + zero-point, round-to-nearest) **fused into
//!   packing** ([`crate::kernels::pack::quantize_i8`] is the scalar
//!   contract), so the quantized tensor never materializes;
//! * panels are **k-quad-interleaved** (`kernels::pack::
//!   {pack_a_panel_i8, pack_b_panel_u8}` and their `_f32_` fused
//!   variants): step `s` of an A panel holds `MR` adjacent i8 quads for
//!   `k = 4s .. 4s+3`, a B-panel step holds `NR` u8 quads — the
//!   `xvi8ger4pp` rank-4 operand layout of [`crate::kernels::gemm_rp`]
//!   scaled to the blocked engine's micropanels;
//! * the **`MR×NR = 8×16` microkernel** applies one rank-4 update per
//!   step over an i32 accumulator tile held in registers across the
//!   packed `KC` depth;
//! * the **column (jc) loop is the parallel axis**: whole-`NR` column
//!   chunks fan out under the same [`Par`] policy as the f32 and bf16
//!   engines — on the serving path that is the persistent device pool.
//!
//! ## Numerics: two contracts, both bit-exact against the Machine
//!
//! Per rank-4 step the four mixed-sign products are summed **exactly**
//! in `i64` (max magnitude `4·128·255 = 130_560`, far inside `i64`) and
//! folded into the i32 accumulator with one of the ISA's two integer
//! accumulate ops ([`crate::isa::types`]):
//!
//! * [`I8Accum::Wrapping`] — `mod_add_i32` per step: bit-identical to
//!   the Machine executing the `xvi8ger4` prime + `xvi8ger4pp` chain of
//!   [`rp_gemm_program`](crate::kernels::gemm_rp::rp_gemm_program)
//!   (tested against [`gemm_i8_8x16`](crate::kernels::gemm_rp::gemm_i8_8x16));
//! * [`I8Accum::Saturating`] — `sat_add_i32` per step: bit-identical to
//!   the `xvi8ger4` prime + `xvi8ger4spp` chain (§II-B.2's "do not wrap
//!   around" accumulate; tested against
//!   [`gemm_i8_8x16_sat`](crate::kernels::gemm_rp::gemm_i8_8x16_sat)).
//!
//! No first-step special case is needed in either mode (unlike the bf16
//! `F32Pairs` contract, whose `AccOp::New` prime is observable in zero
//! signs): a single step's exact sum always fits i32, so folding it into
//! a zero accumulator — wrapping or saturating — produces exactly the
//! value `AccOp::New` assigns. The `k % 4` tail needs no masked special
//! case either: the packers zero-fill the pad lanes, a zero product adds
//! `+0` to the step's exact sum, and that equals the Machine's prefixed
//! `pmsk` form (whose disabled products are simply absent from the same
//! exact sum). And because `KC % 4 == 0`, cache blocks never split a
//! quad step, so the blocked chain IS the flat chain: the i32 tile is
//! stored to the image between KC blocks and reloaded bit-for-bit.
//!
//! ## Dequantization epilogue
//!
//! [`gemm_i8_dequant_into`] serves the quantized f32→f32 path: quantize
//! fused into packing, the raw Wrapping dot, then at C writeback the
//! exact affine correction
//!
//! ```text
//! real[i][j] = sa·sb·(dot[i][j] − zp_b·rowsum_a[i] − zp_a·colsum_b[j]
//!              + k·zp_a·zp_b)  (+ bias[j], then relu)
//! ```
//!
//! with `rowsum_a`/`colsum_b` computed in `i64` by re-quantizing the f32
//! sources elementwise with the *same* scalar quantizers the packers use
//! (`O(m·k + k·n)` — cheap next to the `O(m·n·k)` dot). The correction
//! is exact as long as the true dot does not wrap i32, i.e. for
//! `k < 2³¹ / 130_560 ≈ 16_448` quads (`k ≲ 65_790`) — far beyond any
//! serving shape; [`gemm_i8_dequant_reference`] spells the whole
//! contract out elementwise for tests and the bench accuracy probe.

use crate::blas::block_gemm::{chunk_plan_nr, ExecutedKernel, GemmVariant, Par, KC};
use crate::isa::types::{mod_add_i32, sat_add_i32};
use crate::kernels::pack::{
    pack_a_panel_f32_i8, pack_a_panel_i8, pack_b_panel_f32_u8, pack_b_panel_u8, quantize_i8,
    quantize_u8,
};
use std::sync::Mutex;

/// Microkernel register-block rows (the 8 of the Figure 8 `8×16` virtual
/// accumulator).
pub const MR: usize = 8;
/// Microkernel register-block columns (16: four 4-wide accumulators side
/// by side).
pub const NR: usize = 16;

// KC blocks must cover whole k-quads: a non-multiple-of-4 block boundary
// would split a rank-4 step (and force a masked pad mid-chain).
const _: () = assert!(KC % 4 == 0, "KC must be a multiple of 4: packed int8 steps cover k-quads");

/// The descriptor of a tuned int8 GEMM call: `xvi8ger4` (rank 4) over
/// 1-byte quad-interleaved panels, under the given variant's blocking.
pub fn executed_kernel_i8(m: usize, n: usize, k: usize, v: GemmVariant) -> ExecutedKernel {
    ExecutedKernel { elem: "i8", ger: "xvi8ger4", rank: 4, esize: 1, m, n, k, v }
}

/// Per-tensor affine quantization parameters of one int8 GEMM: A
/// quantizes to signed i8 with `(a_scale, a_zp)`, B to unsigned u8 with
/// `(b_scale, b_zp)` — the §II-B.2 mixed-signedness operand split.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub a_scale: f32,
    pub a_zp: i32,
    pub b_scale: f32,
    pub b_zp: i32,
}

/// Where the signed A operand comes from. Both variants pack to the same
/// quad-interleaved i8 panels.
#[derive(Clone, Copy)]
pub enum I8SrcA<'a> {
    /// Row-major f32 storage; the affine f32→i8 quantization is fused
    /// into packing ([`quantize_i8`]).
    F32 { data: &'a [f32], scale: f32, zp: i32 },
    /// Row-major pre-quantized i8 bytes, packed verbatim.
    Q(&'a [i8]),
}

/// Where the unsigned B operand comes from (see [`I8SrcA`]).
#[derive(Clone, Copy)]
pub enum I8SrcB<'a> {
    F32 { data: &'a [f32], scale: f32, zp: i32 },
    Q(&'a [u8]),
}

impl I8SrcA<'_> {
    /// Number of elements in the backing storage.
    pub fn len(&self) -> usize {
        match self {
            I8SrcA::F32 { data, .. } => data.len(),
            I8SrcA::Q(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pack an A micropanel (rows `i0..i0+rows` × columns `k0..k0+kc`).
    #[allow(clippy::too_many_arguments)]
    fn pack_a(
        &self,
        lda: usize,
        i0: usize,
        rows: usize,
        k0: usize,
        kc: usize,
        mr: usize,
        out: &mut [i8],
    ) {
        match self {
            I8SrcA::F32 { data, scale, zp } => {
                pack_a_panel_f32_i8(data, *scale, *zp, lda, i0, rows, k0, kc, mr, out)
            }
            I8SrcA::Q(a) => pack_a_panel_i8(a, lda, i0, rows, k0, kc, mr, out),
        }
    }
}

impl I8SrcB<'_> {
    /// Number of elements in the backing storage.
    pub fn len(&self) -> usize {
        match self {
            I8SrcB::F32 { data, .. } => data.len(),
            I8SrcB::Q(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pack a B micropanel (rows `k0..k0+kc` × columns `j0..j0+cols`).
    #[allow(clippy::too_many_arguments)]
    fn pack_b(
        &self,
        ldb: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        cols: usize,
        nr: usize,
        out: &mut [u8],
    ) {
        match self {
            I8SrcB::F32 { data, scale, zp } => {
                pack_b_panel_f32_u8(data, *scale, *zp, ldb, k0, kc, j0, cols, nr, out)
            }
            I8SrcB::Q(b) => pack_b_panel_u8(b, ldb, k0, kc, j0, cols, nr, out),
        }
    }
}

/// Accumulation mode of the int8 microkernel — each mode is bit-exact
/// against one Machine chain (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum I8Accum {
    /// 32-bit modulo accumulate per rank-4 step (`mod_add_i32`) — the
    /// `xvi8ger4pp` chain, the default integer accumulation model and
    /// what the plan's `DotI8` step executes.
    #[default]
    Wrapping,
    /// Saturating accumulate per rank-4 step (`sat_add_i32`) — the
    /// `xvi8ger4spp` chain (§II-B.2's "do not wrap around" form).
    Saturating,
}

/// Reusable scratch for the int8 engine: the i32 accumulation image of
/// `C` (column-chunk-blocked during the parallel phase) plus one
/// packed-B-block and packed-A-panel buffer per column-chunk worker —
/// panels are bytes, a quarter the footprint of the f32 engine's — and
/// the `i64` row/column quantized sums of the dequantize correction.
/// Hold one per compiled plan and steady-state requests allocate
/// nothing.
#[derive(Default)]
pub struct I8Scratch {
    ci32: Vec<i32>,
    bp: Vec<Vec<u8>>,
    ap: Vec<Vec<i8>>,
    rs: Vec<i64>,
    cs: Vec<i64>,
}

impl I8Scratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> I8Scratch {
        I8Scratch::default()
    }

    /// Grow the buffers so a subsequent `m×n×k` GEMM on up to `threads`
    /// workers allocates nothing (canonical variant).
    pub fn reserve(&mut self, m: usize, n: usize, k: usize, threads: usize) {
        self.reserve_for(m, n, k, threads, GemmVariant::CANONICAL_WIDE);
    }

    /// Variant-aware reserve: sizes the panel buffers for the blocking
    /// config `v` actually executes with, not the fixed defaults.
    pub fn reserve_for(&mut self, m: usize, n: usize, k: usize, threads: usize, v: GemmVariant) {
        let (nchunks, cols_per) = chunk_plan_nr(n, threads.max(1), v.nr);
        self.reserve_chunks(m, n, k, nchunks, cols_per, v);
        if self.rs.len() < m {
            self.rs.resize(m, 0);
        }
        if self.cs.len() < n {
            self.cs.resize(n, 0);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn reserve_chunks(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        nchunks: usize,
        cols_per: usize,
        v: GemmVariant,
    ) {
        let c_need = m * n;
        if self.ci32.len() < c_need {
            self.ci32.resize(c_need, 0);
        }
        let steps = v.block.kc.min(k.max(1)).div_ceil(4);
        let bp_need = steps * 4 * v.block.nc.min(cols_per.max(v.nr));
        if self.bp.len() < nchunks {
            self.bp.resize_with(nchunks, Vec::new);
        }
        for b in &mut self.bp[..nchunks] {
            if b.len() < bp_need {
                b.resize(bp_need, 0);
            }
        }
        let ap_need = steps * 4 * v.mr;
        if self.ap.len() < nchunks {
            self.ap.resize_with(nchunks, Vec::new);
        }
        for a in &mut self.ap[..nchunks] {
            if a.len() < ap_need {
                a.resize(ap_need, 0);
            }
        }
    }
}

/// The stepwise reference of both integer contracts, spelled out without
/// packing or tiling: per output element, walk the k-quads in ascending
/// order, sum each quad's four mixed-sign products **exactly** in `i64`
/// (pad lanes of the `k % 4` tail contribute `+0`), and fold the step
/// sum into the i32 accumulator with the contract's accumulate op. This
/// flat chain IS the blocked chain (`KC % 4 == 0`, so cache blocks never
/// split a quad), and it replays the Machine's `xvi8ger4` prime +
/// `xvi8ger4[s]pp` loop exactly (a single step sum always fits i32, so
/// fold-into-zero equals `AccOp::New`). The packed engine must match
/// this bit for bit; tests additionally pin it to `isa::exec` via
/// [`gemm_i8_8x16`](crate::kernels::gemm_rp::gemm_i8_8x16).
pub fn gemm_i8_reference(
    a: &[i8],
    b: &[u8],
    m: usize,
    n: usize,
    k: usize,
    accum: I8Accum,
) -> Vec<i32> {
    let steps = k.div_ceil(4);
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for s in 0..steps {
                let mut sum = 0i64;
                for kl in 0..4 {
                    let kk = 4 * s + kl;
                    if kk < k {
                        sum += i64::from(a[i * k + kk]) * i64::from(b[kk * n + j]);
                    }
                }
                acc = match accum {
                    I8Accum::Wrapping => mod_add_i32(acc, sum),
                    I8Accum::Saturating => sat_add_i32(acc, sum),
                };
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// The elementwise reference of the **quantized f32→f32 serving path**
/// ([`gemm_i8_dequant_into`]): quantize both operands with the scalar
/// quantizers, run the Wrapping integer dot ([`gemm_i8_reference`]),
/// then apply the exact affine correction and the optional bias/relu
/// epilogue. The scale product is formed in `f64` and narrowed once per
/// element; bias adds and relu happen in f32 after the narrowing — the
/// packed engine's writeback must match this bit for bit.
pub fn gemm_i8_dequant_reference(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    q: &QuantParams,
    bias: Option<&[f32]>,
    relu: bool,
) -> Vec<f32> {
    let qa: Vec<i8> = a.iter().map(|&v| quantize_i8(v, q.a_scale, q.a_zp)).collect();
    let qb: Vec<u8> = b.iter().map(|&v| quantize_u8(v, q.b_scale, q.b_zp)).collect();
    let dot = gemm_i8_reference(&qa, &qb, m, n, k, I8Accum::Wrapping);
    let rs: Vec<i64> =
        (0..m).map(|i| qa[i * k..(i + 1) * k].iter().map(|&v| i64::from(v)).sum()).collect();
    let cs: Vec<i64> =
        (0..n).map(|j| (0..k).map(|kk| i64::from(qb[kk * n + j])).sum()).collect();
    let (za, zb) = (i64::from(q.a_zp), i64::from(q.b_zp));
    let ss = f64::from(q.a_scale) * f64::from(q.b_scale);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let centered =
                i64::from(dot[i * n + j]) - zb * rs[i] - za * cs[j] + (k as i64) * za * zb;
            let mut v = (ss * centered as f64) as f32;
            if let Some(bias) = bias {
                v += bias[j];
            }
            if relu {
                v = v.max(0.0);
            }
            c[i * n + j] = v;
        }
    }
    c
}

/// `C = A·B` over quad-interleaved int8 panels into a caller-provided
/// raw **i32** `c` (`m×n`, row-major, fully overwritten) — the
/// Machine-parity surface. `a` is `m×k` signed, `b` is `k×n` unsigned,
/// both row-major and contiguous, each either pre-quantized bytes or f32
/// quantized during packing ([`I8SrcA`]/[`I8SrcB`]). The column chunks
/// are distributed per `par` and drained before the call returns. See
/// [`I8Accum`] for the two bit-exact accumulation contracts.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_packed_into(
    c: &mut [i32],
    a: I8SrcA<'_>,
    b: I8SrcB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: I8Accum,
    par: Par<'_>,
    scratch: &mut I8Scratch,
) {
    gemm_i8_packed_tuned_into(c, a, b, m, n, k, accum, par, scratch, GemmVariant::CANONICAL_WIDE);
}

/// [`gemm_i8_packed_into`] with an explicit microkernel/blocking variant
/// (the autotuner's entry point). Bitwise identical to the canonical
/// engine for every variant in [`GemmVariant::wide_candidates`]: both
/// integer contracts are per-element ascending-quad chains, and every
/// grid `kc` is a multiple of 4 so blocking never splits a quad step.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_packed_tuned_into(
    c: &mut [i32],
    a: I8SrcA<'_>,
    b: I8SrcB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: I8Accum,
    par: Par<'_>,
    scratch: &mut I8Scratch,
    v: GemmVariant,
) {
    assert_eq!(c.len(), m * n, "C must be m*n");
    let (nchunks, cols_per) = run_chunks(a, b, m, n, k, accum, par, scratch, v);
    if m == 0 || n == 0 {
        return;
    }
    // writeback: de-block the column chunks of the i32 image
    let ci32 = &scratch.ci32;
    for w in 0..nchunks {
        let j0 = w * cols_per;
        let wcols = cols_per.min(n - j0);
        let cw = &ci32[m * cols_per * w..m * cols_per * w + m * wcols];
        for i in 0..m {
            c[i * n + j0..i * n + j0 + wcols].copy_from_slice(&cw[i * wcols..(i + 1) * wcols]);
        }
    }
}

/// Optional fused writeback tail of the dequantized path — the same
/// bias/relu shapes the f32 engine's `Epilogue` fuses behind a `dot`.
#[derive(Clone, Copy)]
pub enum I8Epilogue<'a> {
    None,
    /// `+ bias[j]` per output column (`bias.len() == n`).
    Bias(&'a [f32]),
    /// `max(0, · + bias[j])`.
    BiasRelu(&'a [f32]),
}

/// The quantized **f32→f32 serving path**: affine-quantize both f32
/// operands during packing (`q`), run the Wrapping rank-4 integer dot,
/// and dequantize at C writeback with the exact zero-point correction
/// (plus the optional bias/relu tail). Bitwise equal to
/// [`gemm_i8_dequant_reference`] on the same inputs — and the integer
/// dot underneath is the same Machine-parity chain
/// [`gemm_i8_packed_into`] exposes raw.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_dequant_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    q: &QuantParams,
    epi: I8Epilogue<'_>,
    par: Par<'_>,
    scratch: &mut I8Scratch,
) {
    gemm_i8_dequant_tuned_into(c, a, b, m, n, k, q, epi, par, scratch, GemmVariant::CANONICAL_WIDE);
}

/// [`gemm_i8_dequant_into`] with an explicit microkernel/blocking
/// variant (the autotuner's entry point): the variant only steers the
/// integer dot underneath — the dequantize correction and epilogue are
/// geometry-independent, so every variant stays bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_dequant_tuned_into(
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    q: &QuantParams,
    epi: I8Epilogue<'_>,
    par: Par<'_>,
    scratch: &mut I8Scratch,
    v: GemmVariant,
) {
    assert_eq!(c.len(), m * n, "C must be m*n");
    let sa = I8SrcA::F32 { data: a, scale: q.a_scale, zp: q.a_zp };
    let sb = I8SrcB::F32 { data: b, scale: q.b_scale, zp: q.b_zp };
    let (nchunks, cols_per) = run_chunks(sa, sb, m, n, k, I8Accum::Wrapping, par, scratch, v);
    if m == 0 || n == 0 {
        return;
    }
    // the correction's row/column sums: re-quantize the f32 sources with
    // the same scalar quantizers the packers used — identical values by
    // construction, O(m·k + k·n)
    if scratch.rs.len() < m {
        scratch.rs.resize(m, 0);
    }
    if scratch.cs.len() < n {
        scratch.cs.resize(n, 0);
    }
    for (i, slot) in scratch.rs[..m].iter_mut().enumerate() {
        *slot = a[i * k..(i + 1) * k]
            .iter()
            .map(|&v| i64::from(quantize_i8(v, q.a_scale, q.a_zp)))
            .sum();
    }
    for (j, slot) in scratch.cs[..n].iter_mut().enumerate() {
        *slot = (0..k).map(|kk| i64::from(quantize_u8(b[kk * n + j], q.b_scale, q.b_zp))).sum();
    }
    let (za, zb) = (i64::from(q.a_zp), i64::from(q.b_zp));
    let ss = f64::from(q.a_scale) * f64::from(q.b_scale);
    let (ci32, rs, cs) = (&scratch.ci32, &scratch.rs, &scratch.cs);
    for w in 0..nchunks {
        let j0 = w * cols_per;
        let wcols = cols_per.min(n - j0);
        let cw = &ci32[m * cols_per * w..m * cols_per * w + m * wcols];
        for i in 0..m {
            let crow = &mut c[i * n + j0..i * n + j0 + wcols];
            let srow = &cw[i * wcols..(i + 1) * wcols];
            for (jl, (dst, &dot)) in crow.iter_mut().zip(srow).enumerate() {
                let j = j0 + jl;
                let centered =
                    i64::from(dot) - zb * rs[i] - za * cs[j] + (k as i64) * za * zb;
                let mut v = (ss * centered as f64) as f32;
                match epi {
                    I8Epilogue::None => {}
                    I8Epilogue::Bias(bias) => v += bias[j],
                    I8Epilogue::BiasRelu(bias) => v = (v + bias[j]).max(0.0),
                }
                *dst = v;
            }
        }
    }
}

/// The shared parallel phase: pack, fan the column chunks out per `par`,
/// and leave the accumulated i32 image chunk-blocked in `scratch.ci32`.
/// Returns the chunk plan so each caller can de-block its own writeback.
#[allow(clippy::too_many_arguments)]
fn run_chunks(
    a: I8SrcA<'_>,
    b: I8SrcB<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: I8Accum,
    par: Par<'_>,
    scratch: &mut I8Scratch,
    v: GemmVariant,
) -> (usize, usize) {
    assert!(
        v.block.kc % 4 == 0,
        "int8 kc must be a multiple of 4: steps cover k-quads ({})",
        v.name()
    );
    assert!(
        v.block.nc % v.nr == 0 && v.block.mc % v.mr == 0,
        "blocking must be tile-aligned: {}",
        v.name()
    );
    assert_eq!(a.len(), m * k, "A must be m*k");
    assert_eq!(b.len(), k * n, "B must be k*n");
    if m == 0 || n == 0 {
        return (0, 0);
    }
    let (nchunks, cols_per) = chunk_plan_nr(n, par.cap(), v.nr);
    scratch.reserve_chunks(m, n, k, nchunks, cols_per, v);
    let ci32 = &mut scratch.ci32[..m * n];
    ci32.fill(0);
    if k > 0 {
        // Per-chunk state behind per-index mutexes (worker w locks only
        // entry w — uncontended, they exist to keep the closure `Fn`);
        // chunk w owns the contiguous m×wcols block of the i32 image for
        // columns [w*cols_per, w*cols_per + wcols), like the f32 engine.
        struct Chunk<'s> {
            ci32: &'s mut [i32],
            bp: &'s mut [u8],
            ap: &'s mut [i8],
        }
        let mut chunks: Vec<Mutex<Chunk<'_>>> = Vec::with_capacity(nchunks);
        let mut rest: &mut [i32] = ci32;
        for (w, (bpb, apb)) in
            scratch.bp.iter_mut().zip(scratch.ap.iter_mut()).take(nchunks).enumerate()
        {
            let wcols = cols_per.min(n - w * cols_per);
            let (cw, r) = rest.split_at_mut(m * wcols);
            rest = r;
            chunks.push(Mutex::new(Chunk { ci32: cw, bp: bpb, ap: apb }));
        }
        let chunks = &chunks;
        par.run(nchunks, &|w| {
            let mut guard = chunks[w].lock().unwrap_or_else(|p| p.into_inner());
            let ch = &mut *guard;
            let j0 = w * cols_per;
            let wcols = cols_per.min(n - j0);
            col_worker(ch.ci32, &a, &b, ch.bp, ch.ap, m, n, k, j0, wcols, accum, v);
        });
    }
    (nchunks, cols_per)
}

/// One worker's share: all `m` rows of columns `j0 .. j0+wcols`, the
/// whole `k` depth, walked in NC/KC cache blocks with `kc` ascending
/// (the bit-exactness order). The worker packs its own quad-interleaved
/// B panels per (NC, kc) block and sweeps each packed `MR×kc` A
/// micropanel across the chunk's `NR` panels.
#[allow(clippy::too_many_arguments)]
fn col_worker(
    ci32: &mut [i32],
    a: &I8SrcA<'_>,
    b: &I8SrcB<'_>,
    bp: &mut [u8],
    ap: &mut [i8],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    wcols: usize,
    accum: I8Accum,
    v: GemmVariant,
) {
    let (mr, nr) = (v.mr, v.nr);
    let (mc, kc, nc) = (v.block.mc, v.block.kc, v.block.nc);
    for jc in (0..wcols).step_by(nc) {
        let ncl = nc.min(wcols - jc);
        let n_panels = ncl.div_ceil(nr);
        for kc0 in (0..k).step_by(kc) {
            let kcl = kc.min(k - kc0);
            let steps = kcl.div_ceil(4);
            let bpl = &mut bp[..n_panels * steps * nr * 4];
            for jp in 0..n_panels {
                let jabs = j0 + jc + jp * nr;
                let cols = nr.min(j0 + jc + ncl - jabs);
                let panel = &mut bpl[jp * steps * nr * 4..(jp + 1) * steps * nr * 4];
                b.pack_b(n, kc0, kcl, jabs, cols, nr, panel);
            }
            let bpl = &*bpl;
            let apl = &mut ap[..steps * mr * 4];
            for ic in (0..m).step_by(mc) {
                let mcl = mc.min(m - ic);
                for ir in (0..mcl).step_by(mr) {
                    let gi = ic + ir;
                    let mrl = mr.min(m - gi);
                    a.pack_a(k, gi, mrl, kc0, kcl, mr, apl);
                    for jp in 0..n_panels {
                        let jloc = jc + jp * nr;
                        let nrl = nr.min(wcols - jloc);
                        let bpp = &bpl[jp * steps * nr * 4..(jp + 1) * steps * nr * 4];
                        microkernel_i8_v(
                            v, ci32, gi, jloc, wcols, apl, bpp, steps, mrl, nrl, accum,
                        );
                    }
                }
            }
        }
    }
}

/// Dispatch to the monomorphized rank-4 microkernel for `v`'s register
/// tile. The family shares one generic body ([`microkernel_i8_g`]); only
/// tiles in [`GemmVariant::WIDE_KERNELS`] are instantiated.
#[allow(clippy::too_many_arguments)]
fn microkernel_i8_v(
    v: GemmVariant,
    ci32: &mut [i32],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[i8],
    bp: &[u8],
    steps: usize,
    mrl: usize,
    nrl: usize,
    accum: I8Accum,
) {
    match (v.mr, v.nr) {
        (8, 8) => microkernel_i8_g::<8, 8>(ci32, ci, j0, ld, ap, bp, steps, mrl, nrl, accum),
        (8, 16) => microkernel_i8_g::<8, 16>(ci32, ci, j0, ld, ap, bp, steps, mrl, nrl, accum),
        (mr, nr) => unreachable!("no monomorphized int8 register tile {mr}x{nr}"),
    }
}

/// The `MR_×NR_` rank-4 microkernel: loads the running i32 sums of one
/// `C` register block, applies `steps` rank-4 updates from the
/// quad-interleaved panels — each step's four products summed exactly in
/// `i64` and folded with the contract's accumulate op — and stores the
/// sums back. Only the `mrl×nrl` valid corner is loaded/stored;
/// zero-padded panel lanes are computed and discarded.
#[allow(clippy::too_many_arguments)]
fn microkernel_i8_g<const MR_: usize, const NR_: usize>(
    ci32: &mut [i32],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[i8],
    bp: &[u8],
    steps: usize,
    mrl: usize,
    nrl: usize,
    accum: I8Accum,
) {
    let mut acc = [[0i32; NR_]; MR_];
    for (i, row) in acc.iter_mut().enumerate().take(mrl) {
        let crow = &ci32[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        row[..nrl].copy_from_slice(crow);
    }
    for s in 0..steps {
        let ar = &ap[s * MR_ * 4..(s + 1) * MR_ * 4];
        let br = &bp[s * NR_ * 4..(s + 1) * NR_ * 4];
        // widen each lane exactly once per step
        let mut bw = [[0i64; 4]; NR_];
        for (slot, quad) in bw.iter_mut().zip(br.chunks_exact(4)) {
            slot[0] = i64::from(quad[0]);
            slot[1] = i64::from(quad[1]);
            slot[2] = i64::from(quad[2]);
            slot[3] = i64::from(quad[3]);
        }
        for (i, row) in acc.iter_mut().enumerate() {
            let x0 = i64::from(ar[i * 4]);
            let x1 = i64::from(ar[i * 4 + 1]);
            let x2 = i64::from(ar[i * 4 + 2]);
            let x3 = i64::from(ar[i * 4 + 3]);
            match accum {
                I8Accum::Wrapping => {
                    for (slot, bwq) in row.iter_mut().zip(&bw) {
                        let sum = x0 * bwq[0] + x1 * bwq[1] + x2 * bwq[2] + x3 * bwq[3];
                        *slot = mod_add_i32(*slot, sum);
                    }
                }
                I8Accum::Saturating => {
                    for (slot, bwq) in row.iter_mut().zip(&bw) {
                        let sum = x0 * bwq[0] + x1 * bwq[1] + x2 * bwq[2] + x3 * bwq[3];
                        *slot = sat_add_i32(*slot, sum);
                    }
                }
            }
        }
    }
    for (i, row) in acc.iter().enumerate().take(mrl) {
        let crow = &mut ci32[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        crow.copy_from_slice(&row[..nrl]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm_rp::{gemm_i8_8x16, gemm_i8_8x16_sat};
    use crate::rt::ThreadPool;
    use crate::testkit::{check, Rng};

    fn run_packed(
        a: I8SrcA<'_>,
        b: I8SrcB<'_>,
        m: usize,
        n: usize,
        k: usize,
        accum: I8Accum,
        par: Par<'_>,
    ) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        let mut scratch = I8Scratch::new();
        gemm_i8_packed_into(&mut c, a, b, m, n, k, accum, par, &mut scratch);
        c
    }

    fn rand_q(rng: &mut Rng, m: usize, n: usize, k: usize) -> (Vec<i8>, Vec<u8>) {
        let a: Vec<i8> = (0..m * k).map(|_| rng.irange(-128, 127) as i8).collect();
        let b: Vec<u8> = (0..k * n).map(|_| rng.irange(0, 255) as u8).collect();
        (a, b)
    }

    #[test]
    fn both_contracts_match_reference_across_shapes_and_policies() {
        // shapes straddling MR/NR/KC boundaries, k % 4 tails included
        let pool = ThreadPool::new("i8-test", 4);
        let mut rng = Rng::new(0x18a4);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 1, 5),
            (3, 5, 9),
            (8, 16, 27),
            (9, 17, 31),
            (16, 33, KC + 3),
            (8, 300, 9),
            (33, 70, 40),
        ] {
            let (a, b) = rand_q(&mut rng, m, n, k);
            for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
                let expect = gemm_i8_reference(&a, &b, m, n, k, accum);
                for par in [Par::Seq, Par::Scoped(3), Par::Pool(&pool, 3), Par::Pool(&pool, 4)] {
                    let got = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), m, n, k, accum, par);
                    assert_eq!(got, expect, "m={m} n={n} k={k} {accum:?}");
                }
            }
        }
        pool.shutdown();
    }

    #[test]
    fn f32_and_quantized_sources_are_bit_identical() {
        // feeding f32 sources (quantize fused into packing) must equal
        // pre-quantizing with the scalar contract and feeding raw bytes
        check("i8 f32 vs quantized sources", 6, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 20), rng.range(1, 40), rng.range(1, 30));
            let (qp_a, zp_a) = (0.043f32, rng.irange(-16, 16) as i32);
            let (qp_b, zp_b) = (0.021f32, rng.irange(96, 160) as i32);
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let qa: Vec<i8> = a.iter().map(|&v| quantize_i8(v, qp_a, zp_a)).collect();
            let qb: Vec<u8> = b.iter().map(|&v| quantize_u8(v, qp_b, zp_b)).collect();
            for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
                let from_f32 = run_packed(
                    I8SrcA::F32 { data: &a, scale: qp_a, zp: zp_a },
                    I8SrcB::F32 { data: &b, scale: qp_b, zp: zp_b },
                    m,
                    n,
                    k,
                    accum,
                    Par::Seq,
                );
                let from_q = run_packed(I8SrcA::Q(&qa), I8SrcB::Q(&qb), m, n, k, accum, Par::Seq);
                assert_eq!(from_f32, from_q, "m={m} n={n} k={k} {accum:?}");
            }
        });
    }

    #[test]
    fn wrapping_matches_the_machine_kernel_bitwise() {
        // the Machine-parity contract on its native 8xKx16 tile: the
        // packed engine must reproduce the xvi8ger4(pp) chain of
        // isa::exec exactly — including k % 4, which the Machine handles
        // with the prefixed pmsk form and we handle with zero-padded
        // quad lanes
        let mut rng = Rng::new(0x8416);
        for &k in &[1usize, 2, 3, 4, 5, 7, 8, 11, 16, 24] {
            let x: Vec<i8> = (0..8 * k).map(|_| rng.irange(-128, 127) as i8).collect();
            let y: Vec<u8> = (0..16 * k).map(|_| rng.irange(0, 255) as u8).collect();
            let machine = gemm_i8_8x16(&x, &y, k).unwrap();
            // engine B is k x n: transpose y (16 x k row-major)
            let mut b = vec![0u8; k * 16];
            for j in 0..16 {
                for kk in 0..k {
                    b[kk * 16 + j] = y[j * k + kk];
                }
            }
            let got = run_packed(I8SrcA::Q(&x), I8SrcB::Q(&b), 8, 16, k, I8Accum::Wrapping, Par::Seq);
            for i in 0..8 {
                for j in 0..16 {
                    assert_eq!(got[i * 16 + j], machine[i][j], "k={k} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn saturating_matches_the_machine_sat_kernel_where_it_bites() {
        // drive the exact chain past i32::MIN so spp visibly clamps:
        // every product pinned at -128*255, plus a random tail
        let mut rng = Rng::new(0x54a7);
        let k = 4 * 16_500 + 3; // wraps i32 ~16.4k steps in, then a pmsk tail
        let mut x = vec![-128i8; 8 * k];
        let mut y = vec![255u8; 16 * k];
        // randomize every row's k % 4 tail so the pmsk/zero-pad step
        // carries non-constant values
        for i in 0..8 {
            for kk in k - 3..k {
                x[i * k + kk] = rng.irange(-128, 127) as i8;
            }
        }
        for j in 0..16 {
            for kk in k - 3..k {
                y[j * k + kk] = rng.irange(0, 255) as u8;
            }
        }
        let machine = gemm_i8_8x16_sat(&x, &y, k).unwrap();
        let mut b = vec![0u8; k * 16];
        for j in 0..16 {
            for kk in 0..k {
                b[kk * 16 + j] = y[j * k + kk];
            }
        }
        let got = run_packed(I8SrcA::Q(&x), I8SrcB::Q(&b), 8, 16, k, I8Accum::Saturating, Par::Seq);
        for i in 0..8 {
            for j in 0..16 {
                assert_eq!(got[i * 16 + j], machine[i][j], "({i},{j})");
            }
        }
        // and the contracts genuinely diverged on this input
        let wrapped = run_packed(I8SrcA::Q(&x), I8SrcB::Q(&b), 8, 16, k, I8Accum::Wrapping, Par::Seq);
        assert_ne!(got, wrapped, "saturation must be observable");
    }

    #[test]
    fn dequant_epilogue_matches_reference_bitwise() {
        let mut rng = Rng::new(0xdeca);
        let q = QuantParams { a_scale: 0.019, a_zp: -5, b_scale: 0.037, b_zp: 131 };
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 9), (9, 17, 31), (8, 300, 9)] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let bias = rng.f32_vec(n);
            let mut scratch = I8Scratch::new();
            for (epi, want_bias, want_relu) in [
                (I8Epilogue::None, None, false),
                (I8Epilogue::Bias(&bias), Some(&bias[..]), false),
                (I8Epilogue::BiasRelu(&bias), Some(&bias[..]), true),
            ] {
                let mut c = vec![0f32; m * n];
                gemm_i8_dequant_into(&mut c, &a, &b, m, n, k, &q, epi, Par::Seq, &mut scratch);
                let expect =
                    gemm_i8_dequant_reference(&a, &b, m, n, k, &q, want_bias, want_relu);
                let gb: Vec<u32> = c.iter().map(|v| v.to_bits()).collect();
                let eb: Vec<u32> = expect.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, eb, "m={m} n={n} k={k} relu={want_relu}");
            }
        }
    }

    #[test]
    fn every_wide_variant_matches_reference_bitwise_spot() {
        // the full sweep lives in tests/tune_engine.rs; this in-module
        // spot check pins the whole wide family (both register tiles x
        // the blocking grid) on one seam-heavy shape for both contracts
        // and the dequant path
        let mut rng = Rng::new(0x1e8a);
        let (m, n, k) = (9usize, 17usize, 31usize);
        let (a, b) = rand_q(&mut rng, m, n, k);
        let af = rng.f32_vec(m * k);
        let bf = rng.f32_vec(k * n);
        let bias = rng.f32_vec(n);
        let q = QuantParams { a_scale: 0.031, a_zp: -3, b_scale: 0.027, b_zp: 125 };
        let dq_expect = gemm_i8_dequant_reference(&af, &bf, m, n, k, &q, Some(&bias), true);
        for v in GemmVariant::wide_candidates() {
            for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
                let expect = gemm_i8_reference(&a, &b, m, n, k, accum);
                let mut c = vec![0i32; m * n];
                let mut scratch = I8Scratch::new();
                gemm_i8_packed_tuned_into(
                    &mut c,
                    I8SrcA::Q(&a),
                    I8SrcB::Q(&b),
                    m,
                    n,
                    k,
                    accum,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                assert_eq!(c, expect, "variant {} {accum:?}", v.name());
            }
            let mut c = vec![0f32; m * n];
            let mut scratch = I8Scratch::new();
            gemm_i8_dequant_tuned_into(
                &mut c,
                &af,
                &bf,
                m,
                n,
                k,
                &q,
                I8Epilogue::BiasRelu(&bias),
                Par::Seq,
                &mut scratch,
                v,
            );
            let gb: Vec<u32> = c.iter().map(|x| x.to_bits()).collect();
            let eb: Vec<u32> = dq_expect.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, eb, "dequant variant {}", v.name());
        }
    }

    #[test]
    fn worker_policy_never_changes_bits() {
        let pool = ThreadPool::new("i8-par", 3);
        let mut rng = Rng::new(0x7a12);
        for accum in [I8Accum::Wrapping, I8Accum::Saturating] {
            for &(m, n, k) in &[(8usize, 48usize, 27usize), (16, 300, 9), (5, 33, 64)] {
                let (a, b) = rand_q(&mut rng, m, n, k);
                let seq = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), m, n, k, accum, Par::Seq);
                for par in [Par::Scoped(3), Par::Pool(&pool, 2), Par::Pool(&pool, 3)] {
                    let got = run_packed(I8SrcA::Q(&a), I8SrcB::Q(&b), m, n, k, accum, par);
                    assert_eq!(got, seq, "m={m} n={n} k={k} {accum:?}");
                }
            }
        }
        pool.shutdown();
    }

    #[test]
    fn scratch_reuse_is_clean_and_degenerate_shapes_work() {
        let mut scratch = I8Scratch::new();
        let mut rng = Rng::new(0x5d);
        let (a1, b1) = rand_q(&mut rng, 20, 36, 24);
        let mut c1 = vec![0i32; 20 * 36];
        gemm_i8_packed_into(
            &mut c1,
            I8SrcA::Q(&a1),
            I8SrcB::Q(&b1),
            20,
            36,
            24,
            I8Accum::Wrapping,
            Par::Seq,
            &mut scratch,
        );
        let (a2, b2) = rand_q(&mut rng, 3, 4, 5);
        let mut c2 = vec![0i32; 3 * 4];
        gemm_i8_packed_into(
            &mut c2,
            I8SrcA::Q(&a2),
            I8SrcB::Q(&b2),
            3,
            4,
            5,
            I8Accum::Wrapping,
            Par::Seq,
            &mut scratch,
        );
        assert_eq!(c1, gemm_i8_reference(&a1, &b1, 20, 36, 24, I8Accum::Wrapping));
        assert_eq!(c2, gemm_i8_reference(&a2, &b2, 3, 4, 5, I8Accum::Wrapping));
        // k = 0 -> all zeros (the empty-sum contract)
        let mut c = vec![9i32; 6];
        gemm_i8_packed_into(
            &mut c,
            I8SrcA::Q(&[]),
            I8SrcB::Q(&[]),
            2,
            3,
            0,
            I8Accum::Wrapping,
            Par::Seq,
            &mut scratch,
        );
        assert_eq!(c, vec![0i32; 6]);
    }

    #[test]
    fn quantization_actually_bites() {
        // a value off the int8 grid must quantize before multiplying —
        // the packed path models xvi8ger4 inputs, not f32 inputs
        let q = QuantParams { a_scale: 0.1, a_zp: 0, b_scale: 1.0, b_zp: 0 };
        let a = [0.333f32];
        let b = [1.0f32];
        let mut c = [0f32; 1];
        let mut scratch = I8Scratch::new();
        gemm_i8_dequant_into(&mut c, &a, &b, 1, 1, 1, &q, I8Epilogue::None, Par::Seq, &mut scratch);
        assert_eq!(c[0].to_bits(), 0.3f32.to_bits(), "0.333 lands on the 0.1-step grid");
        assert_ne!(c[0].to_bits(), 0.333f32.to_bits());
    }
}
