//! Numerical linear algebra substrate: the BLAS layers the paper's
//! evaluation stands on ("we use the standard OpenBLAS in our distribution
//! of Linux but we hand write the DGEMM kernel", §VI).
//!
//! * [`level1`] — vector ops (`daxpy`, `ddot`, `dscal`, `idamax`, swaps):
//!   the BLAS1 class the POWER10 vector pipes already handle (§I).
//! * [`level2`] — `dger`, `dgemv`: the BLAS2 class.
//! * [`gemm`] — reference blocked DGEMM/SGEMM plus the [`gemm::GemmBackend`]
//!   abstraction that lets LU run its trailing update either natively or
//!   through the instruction-level MMA simulator.
//! * [`block_gemm`] — the serving fast path: panel-packed, cache-tiled
//!   (MC/KC/NC), register-blocked (`MR×NR` microkernel) f32 GEMM with
//!   scoped-thread M-panel parallelism, bit-identical to the widened
//!   reference path (see its module docs for the numerics contract).
//! * [`lu`] — blocked right-looking LU with partial pivoting (`dgetrf`,
//!   `dgetf2`, `dtrsm`, `dlaswp`) and triangular solves: the computational
//!   core of HPL.

pub mod block_gemm;
pub mod gemm;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod lu;
