//! Numerical linear algebra substrate: the BLAS layers the paper's
//! evaluation stands on ("we use the standard OpenBLAS in our distribution
//! of Linux but we hand write the DGEMM kernel", §VI).
//!
//! * [`level1`] — vector ops (`daxpy`, `ddot`, `dscal`, `idamax`, swaps):
//!   the BLAS1 class the POWER10 vector pipes already handle (§I).
//! * [`level2`] — `dger`, `dgemv`: the BLAS2 class.
//! * [`gemm`] — reference blocked DGEMM/SGEMM plus the [`gemm::GemmBackend`]
//!   abstraction that lets LU run its trailing update either natively or
//!   through the instruction-level MMA simulator.
//! * [`block_gemm`] — the serving fast path: panel-packed, cache-tiled
//!   (MC/KC/NC), register-blocked (`MR×NR` microkernel) f32 GEMM with
//!   scoped-thread M-panel parallelism, bit-identical to the widened
//!   reference path (see its module docs for the numerics contract).
//! * [`bf16_gemm`] — the reduced-precision packed engine: `8×16`
//!   rank-2 microkernel over k-pair-interleaved bf16 panels (the
//!   `xvbf16ger2` operand layout, Table I's 2× MACs-per-instruction
//!   path), packing straight from raw bf16 bits or fusing the f32→bf16
//!   round into the packers; two bit-exact accumulation contracts (see
//!   its module docs).
//! * [`i8_gemm`] — the integer quantized engine: `8×16` rank-4
//!   microkernel over quad-interleaved i8/u8 panels (the `xvi8ger4`
//!   operand layout, Table I's 4× MACs-per-instruction path) with i32
//!   accumulators, affine quantize fused into packing from f32 sources,
//!   two Machine-bit-exact accumulation contracts (wrapping
//!   `xvi8ger4pp` / saturating `xvi8ger4spp`), and a dequantize (+
//!   bias/relu) epilogue at C writeback.
//! * [`lu`] — blocked right-looking LU with partial pivoting (`dgetrf`,
//!   `dgetf2`, `dtrsm`, `dlaswp`) and triangular solves: the computational
//!   core of HPL.

pub mod bf16_gemm;
pub mod block_gemm;
pub mod i8_gemm;
pub mod gemm;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod lu;
