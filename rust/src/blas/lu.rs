//! Blocked right-looking LU factorization with partial pivoting — the
//! computational core of HPL (§VI: "most (over 90% for large enough
//! problems) of execution time spent on a double-precision matrix multiply
//! kernel").
//!
//! `dgetrf` factors a row-major `n×n` matrix in place (`A = P·L·U`), with
//! the trailing update routed through a [`GemmBackend`] so the whole
//! factorization can run over the instruction-level MMA simulator.

use crate::blas::gemm::GemmBackend;
use crate::blas::level1::{dswap_rows, idamax};
use crate::isa::ExecError;

/// Panel width (the paper's hand-written kernel is 128×128×128, §VI).
pub const NB: usize = 128;

/// Work accounting for the HPL cycle model: flops done in each phase.
#[derive(Clone, Debug, Default)]
pub struct LuProfile {
    /// BLAS1/2 flops in the panel factorizations (`dgetf2`).
    pub panel_flops: u64,
    /// BLAS3 flops in the triangular solves (`dtrsm`).
    pub trsm_flops: u64,
    /// BLAS3 flops in the trailing GEMM updates, with shapes.
    pub gemm_flops: u64,
    /// Every trailing-update call as (m, n, k) — consumed by the Figure 10
    /// cycle model.
    pub gemm_calls: Vec<(usize, usize, usize)>,
    pub swaps: u64,
}

impl LuProfile {
    pub fn total_flops(&self) -> u64 {
        self.panel_flops + self.trsm_flops + self.gemm_flops
    }
}

/// Unblocked panel factorization with partial pivoting over an `m×jb`
/// panel whose top-left is `A[j0][j0]`; pivot indices (absolute rows) are
/// appended to `piv`. Row swaps apply to the **whole** matrix (HPL's
/// `dlaswp` is folded in).
fn dgetf2(
    a: &mut [f64],
    lda: usize,
    n_total: usize,
    j0: usize,
    m: usize,
    jb: usize,
    piv: &mut Vec<usize>,
    prof: &mut LuProfile,
) {
    for jj in 0..jb {
        let col = j0 + jj;
        // pivot search in column `col`, rows col..j0+m
        let rows = m - jj;
        let p = idamax(&a[(col) * lda + col..], lda, rows) + col;
        piv.push(p);
        if p != col {
            dswap_rows(a, lda, p, col, n_total);
            prof.swaps += 1;
        }
        let pivot = a[col * lda + col];
        if pivot == 0.0 {
            continue; // singular column: skip elimination (HPL checks residual)
        }
        // scale multipliers and rank-1 update the remainder of the panel
        for i in (col + 1)..(j0 + m) {
            let l = a[i * lda + col] / pivot;
            a[i * lda + col] = l;
            for j in (col + 1)..(j0 + jb) {
                a[i * lda + j] -= l * a[col * lda + j];
            }
        }
        let rows_below = (j0 + m - col - 1) as u64;
        prof.panel_flops += rows_below * (1 + 2 * (j0 + jb - col - 1) as u64);
    }
}

/// `dtrsm` (left, lower, unit-diagonal): solve `L11 · X = B` in place,
/// where `L11` is the `jb×jb` unit-lower block at `A[j0][j0]` and `B` is
/// the `jb×n` block row at `A[j0][j0+jb]`.
fn dtrsm_left_lower_unit(a: &mut [f64], lda: usize, j0: usize, jb: usize, n: usize, prof: &mut LuProfile) {
    for i in 1..jb {
        for kk in 0..i {
            let l = a[(j0 + i) * lda + j0 + kk];
            if l == 0.0 {
                continue;
            }
            for j in 0..n {
                let u = a[(j0 + kk) * lda + j0 + jb + j];
                a[(j0 + i) * lda + j0 + jb + j] -= l * u;
            }
        }
    }
    prof.trsm_flops += (jb * (jb - 1)) as u64 * n as u64;
}

/// Blocked LU with partial pivoting: factors row-major `n×n` `a` in place.
/// Returns the pivot vector (`piv[j]` = row swapped into row `j` at step
/// `j`) and the per-phase work profile.
pub fn dgetrf(
    a: &mut [f64],
    n: usize,
    nb: usize,
    backend: &mut dyn GemmBackend,
) -> Result<(Vec<usize>, LuProfile), ExecError> {
    let mut piv = Vec::with_capacity(n);
    let mut prof = LuProfile::default();
    let lda = n;
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        let m = n - j0;
        dgetf2(a, lda, n, j0, m, jb, &mut piv, &mut prof);
        let rest = n - j0 - jb;
        if rest > 0 {
            dtrsm_left_lower_unit(a, lda, j0, jb, rest, &mut prof);
            // trailing update: A22 -= L21 * U12
            let mrows = n - j0 - jb;
            // split borrows: we need A21 (rows j0+jb.., cols j0..j0+jb),
            // U12 (rows j0..j0+jb, cols j0+jb..) and C=A22 (both trailing).
            // copy the two factor blocks, update C in place.
            let mut l21 = vec![0.0; mrows * jb];
            for i in 0..mrows {
                for j in 0..jb {
                    l21[i * jb + j] = a[(j0 + jb + i) * lda + j0 + j];
                }
            }
            let mut u12 = vec![0.0; jb * rest];
            for i in 0..jb {
                for j in 0..rest {
                    u12[i * rest + j] = a[(j0 + i) * lda + j0 + jb + j];
                }
            }
            let coff = (j0 + jb) * lda + j0 + jb;
            backend.gemm_minus(&mut a[coff..], lda, &l21, jb, &u12, rest, mrows, rest, jb)?;
            prof.gemm_flops += 2 * (mrows * rest * jb) as u64;
            prof.gemm_calls.push((mrows, rest, jb));
        }
        j0 += jb;
    }
    Ok((piv, prof))
}

/// Solve `A·x = b` given the in-place LU factors and pivots from
/// [`dgetrf`] (forward + backward substitution).
pub fn lu_solve(lu: &[f64], n: usize, piv: &[usize], b: &[f64]) -> Vec<f64> {
    let lda = n;
    let mut x = b.to_vec();
    // apply pivots
    for (j, &p) in piv.iter().enumerate() {
        if p != j {
            x.swap(j, p);
        }
    }
    // forward: L y = Pb (unit lower)
    for i in 0..n {
        let mut s = x[i];
        for j in 0..i {
            s -= lu[i * lda + j] * x[j];
        }
        x[i] = s;
    }
    // backward: U x = y
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in (i + 1)..n {
            s -= lu[i * lda + j] * x[j];
        }
        x[i] = s / lu[i * lda + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::gemm::{ref_gemm, RefGemm, SimMmaGemm};
    use crate::blas::level1::dlange_inf;
    use crate::testkit::Rng;

    /// ‖P·A − L·U‖∞ / (‖A‖∞ · n · ε) — the LAPACK-style factorization
    /// residual; < 30 is comfortably correct.
    fn factor_residual(a0: &[f64], lu: &[f64], piv: &[usize], n: usize) -> f64 {
        // build PA
        let mut pa = a0.to_vec();
        for (j, &p) in piv.iter().enumerate() {
            if p != j {
                crate::blas::level1::dswap_rows(&mut pa, n, j, p, n);
            }
        }
        // L (unit lower), U
        let mut l = vec![0.0; n * n];
        let mut u = vec![0.0; n * n];
        for i in 0..n {
            l[i * n + i] = 1.0;
            for j in 0..i.min(n) {
                l[i * n + j] = lu[i * n + j];
            }
            for j in i..n {
                u[i * n + j] = lu[i * n + j];
            }
        }
        let prod = ref_gemm(&l, &u, n, n, n);
        let mut maxdiff = 0.0f64;
        for i in 0..n * n {
            maxdiff = maxdiff.max((pa[i] - prod[i]).abs());
        }
        maxdiff / (dlange_inf(a0, n, n, n) * n as f64 * f64::EPSILON)
    }

    #[test]
    fn lu_residual_reference_backend() {
        for n in [13usize, 64, 96, 130] {
            let mut rng = Rng::new(n as u64);
            let a0 = rng.f64_vec(n * n);
            let mut a = a0.clone();
            let (piv, prof) = dgetrf(&mut a, n, 32, &mut RefGemm).unwrap();
            let r = factor_residual(&a0, &a, &piv, n);
            assert!(r < 30.0, "n={n}: residual {r}");
            assert_eq!(piv.len(), n);
            // flops accounting ~ 2/3 n^3
            let expect = 2.0 / 3.0 * (n as f64).powi(3);
            let total = prof.total_flops() as f64;
            assert!((total / expect - 1.0).abs() < 0.35, "n={n}: flops {total} vs {expect}");
        }
    }

    #[test]
    fn lu_solve_recovers_x() {
        let n = 80;
        let mut rng = Rng::new(7);
        let a0 = rng.f64_vec(n * n);
        let xtrue = rng.f64_vec(n);
        let mut b = vec![0.0; n];
        for i in 0..n {
            b[i] = (0..n).map(|j| a0[i * n + j] * xtrue[j]).sum();
        }
        let mut a = a0.clone();
        let (piv, _) = dgetrf(&mut a, n, 32, &mut RefGemm).unwrap();
        let x = lu_solve(&a, n, &piv, &b);
        for i in 0..n {
            assert!((x[i] - xtrue[i]).abs() < 1e-8, "x[{i}] = {} vs {}", x[i], xtrue[i]);
        }
    }

    #[test]
    fn lu_on_simulated_mma_backend_matches_reference() {
        // the full factorization with every trailing MAC executed by
        // simulated xvf64gerpp instructions
        let n = 64;
        let mut rng = Rng::new(99);
        let a0 = rng.f64_vec(n * n);
        let mut a_ref = a0.clone();
        let (piv_ref, _) = dgetrf(&mut a_ref, n, 16, &mut RefGemm).unwrap();
        let mut a_sim = a0.clone();
        let mut sim = SimMmaGemm::default();
        let (piv_sim, prof) = dgetrf(&mut a_sim, n, 16, &mut sim).unwrap();
        assert_eq!(piv_ref, piv_sim, "identical pivoting");
        for i in 0..n * n {
            assert!((a_ref[i] - a_sim[i]).abs() < 1e-9, "factor element {i}");
        }
        assert!(sim.stats.mma_instructions > 0, "MMA instructions actually executed");
        assert_eq!(sim.stats.flops, prof.gemm_flops, "every trailing MAC went through the MME");
    }

    #[test]
    fn gemm_call_shapes_recorded() {
        let n = 96;
        let mut rng = Rng::new(5);
        let mut a = rng.f64_vec(n * n);
        let (_, prof) = dgetrf(&mut a, n, 32, &mut RefGemm).unwrap();
        // steps at j0=0,32,64: trailing calls (64,64,32) and (32,32,32)
        assert_eq!(prof.gemm_calls, vec![(64, 64, 32), (32, 32, 32)]);
    }

    #[test]
    fn pathological_singular_matrix_does_not_panic() {
        let n = 16;
        let mut a = vec![0.0; n * n]; // all-zero matrix
        let (piv, _) = dgetrf(&mut a, n, 8, &mut RefGemm).unwrap();
        assert_eq!(piv.len(), n);
    }
}
