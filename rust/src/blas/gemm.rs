//! Blocked reference GEMM and the backend abstraction that lets higher
//! layers (LU / HPL) run their trailing updates either natively or through
//! the instruction-level MMA simulator.

use crate::isa::ExecError;
use crate::kernels::dgemm::dgemm_sim;

/// `C -= A·B` where all matrices are row-major views with row strides
/// `lda`/`ldb`/`ldc` (the LU trailing-update shape).
pub trait GemmBackend {
    #[allow(clippy::too_many_arguments)]
    fn gemm_minus(
        &mut self,
        c: &mut [f64],
        ldc: usize,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<(), ExecError>;

    fn name(&self) -> &'static str;
}

/// Cache-blocked native DGEMM (the correctness oracle and fast path).
#[derive(Default)]
pub struct RefGemm;

/// `C ± A·B` blocked over 64×64×64 tiles with a 4-wide inner kernel.
#[allow(clippy::too_many_arguments)]
fn ref_gemm_acc(
    sign: f64,
    c: &mut [f64],
    ldc: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    const MB: usize = 64;
    const NB: usize = 64;
    const KB: usize = 64;
    for i0 in (0..m).step_by(MB) {
        let im = (i0 + MB).min(m);
        for k0 in (0..k).step_by(KB) {
            let km = (k0 + KB).min(k);
            for j0 in (0..n).step_by(NB) {
                let jm = (j0 + NB).min(n);
                for i in i0..im {
                    for kk in k0..km {
                        let aik = sign * a[i * lda + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * ldb + j0..kk * ldb + jm];
                        let crow = &mut c[i * ldc + j0..i * ldc + jm];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

impl GemmBackend for RefGemm {
    fn gemm_minus(
        &mut self,
        c: &mut [f64],
        ldc: usize,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<(), ExecError> {
        ref_gemm_acc(-1.0, c, ldc, a, lda, b, ldb, m, n, k);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

/// `C += A·B` convenience over [`RefGemm`]'s kernel.
#[allow(clippy::too_many_arguments)]
pub fn ref_gemm_plus(
    c: &mut [f64],
    ldc: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    ref_gemm_acc(1.0, c, ldc, a, lda, b, ldb, m, n, k);
}

/// Plain `C = A·B` (row-major, contiguous) via the reference kernel.
pub fn ref_gemm(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    ref_gemm_plus(&mut c, n, a, k, b, n, m, n, k);
    c
}

/// Trailing updates routed through the **instruction-level MMA simulator**:
/// every multiply-add is executed by simulated `xvf64gerpp` instructions
/// (the POWER10-MMA datapath). Requires `m`, `n` multiples of 8.
#[derive(Default)]
pub struct SimMmaGemm {
    /// Aggregated functional-machine stats across all calls.
    pub stats: crate::isa::exec::ExecStats,
}

impl GemmBackend for SimMmaGemm {
    fn gemm_minus(
        &mut self,
        c: &mut [f64],
        ldc: usize,
        a: &[f64],
        lda: usize,
        b: &[f64],
        ldb: usize,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<(), ExecError> {
        // gather contiguous copies (the packing layers of a real DGEMM)
        let mut ac = vec![0.0; m * k];
        for i in 0..m {
            ac[i * k..(i + 1) * k].copy_from_slice(&a[i * lda..i * lda + k]);
        }
        let mut bc = vec![0.0; k * n];
        for i in 0..k {
            bc[i * n..(i + 1) * n].copy_from_slice(&b[i * ldb..i * ldb + n]);
        }
        let (p, st) = dgemm_sim(&ac, &bc, m, n, k)?;
        self.stats.instructions += st.instructions;
        self.stats.mma_instructions += st.mma_instructions;
        self.stats.flops += st.flops;
        self.stats.loads += st.loads;
        self.stats.stores += st.stores;
        self.stats.mem_bytes += st.mem_bytes;
        for i in 0..m {
            for j in 0..n {
                c[i * ldc + j] -= p[i * n + j];
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "simulated-mma"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_allclose, check, Rng};

    fn naive(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                c[i * n + j] = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
            }
        }
        c
    }

    #[test]
    fn ref_gemm_matches_naive() {
        check("ref gemm", 12, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 90), rng.range(1, 90), rng.range(1, 90));
            let a = rng.f64_vec(m * k);
            let b = rng.f64_vec(k * n);
            assert_allclose(&ref_gemm(&a, &b, m, n, k), &naive(&a, &b, m, n, k), 1e-12, 1e-13);
        });
    }

    #[test]
    fn backends_agree() {
        check("ref vs simulated-mma backend", 5, |rng: &mut Rng| {
            let (m, n, k) = (8 * rng.range(1, 3), 8 * rng.range(1, 3), rng.range(1, 24));
            let a = rng.f64_vec(m * k);
            let b = rng.f64_vec(k * n);
            let base = rng.f64_vec(m * n);
            let mut c1 = base.clone();
            let mut c2 = base.clone();
            RefGemm.gemm_minus(&mut c1, n, &a, k, &b, n, m, n, k).unwrap();
            let mut simb = SimMmaGemm::default();
            simb.gemm_minus(&mut c2, n, &a, k, &b, n, m, n, k).unwrap();
            assert_allclose(&c2, &c1, 1e-12, 1e-13);
            assert_eq!(simb.stats.flops, (2 * m * n * k) as u64);
        });
    }

    #[test]
    fn strided_views() {
        // update a 2x2 corner inside 4x4 matrices
        let a = vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0]; // lda 4, 2x2 used
        let b = vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0]; // ldb 4, 2x2 identity
        let mut c = vec![10.0; 16];
        RefGemm.gemm_minus(&mut c, 4, &a, 4, &b, 4, 2, 2, 2).unwrap();
        assert_eq!(&c[0..2], &[9.0, 8.0]);
        assert_eq!(&c[4..6], &[7.0, 6.0]);
        assert!(c[8..].iter().all(|&v| v == 10.0));
    }
}
