//! BLAS level-1 vector operations (row-major, stride-1 slices with an
//! optional element stride for matrix columns).

/// `y += alpha * x` over strided views.
pub fn daxpy(alpha: f64, x: &[f64], incx: usize, y: &mut [f64], incy: usize, n: usize) {
    for i in 0..n {
        y[i * incy] += alpha * x[i * incx];
    }
}

/// Dot product over strided views.
pub fn ddot(x: &[f64], incx: usize, y: &[f64], incy: usize, n: usize) -> f64 {
    (0..n).map(|i| x[i * incx] * y[i * incy]).sum()
}

/// `x *= alpha`.
pub fn dscal(alpha: f64, x: &mut [f64], incx: usize, n: usize) {
    for i in 0..n {
        x[i * incx] *= alpha;
    }
}

/// Index of the element with maximum absolute value (the LU pivot search).
pub fn idamax(x: &[f64], incx: usize, n: usize) -> usize {
    let mut best = 0;
    let mut bestv = 0.0f64;
    for i in 0..n {
        let v = x[i * incx].abs();
        if v > bestv {
            bestv = v;
            best = i;
        }
    }
    best
}

/// Swap two rows of a row-major matrix with row stride `lda`.
pub fn dswap_rows(a: &mut [f64], lda: usize, r1: usize, r2: usize, cols: usize) {
    if r1 == r2 {
        return;
    }
    for j in 0..cols {
        a.swap(r1 * lda + j, r2 * lda + j);
    }
}

/// Infinity norm of a row-major `m×n` matrix.
pub fn dlange_inf(a: &[f64], lda: usize, m: usize, n: usize) -> f64 {
    (0..m)
        .map(|i| (0..n).map(|j| a[i * lda + j].abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_dot_scal() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        daxpy(2.0, &x, 1, &mut y, 1, 3);
        assert_eq!(y, [12.0, 24.0, 36.0]);
        assert_eq!(ddot(&x, 1, &x, 1, 3), 14.0);
        let mut z = [1.0, 2.0];
        dscal(-3.0, &mut z, 1, 2);
        assert_eq!(z, [-3.0, -6.0]);
    }

    #[test]
    fn strided_column_access() {
        // a 3x3 row-major matrix; column 1 has stride 3
        let a = [1.0, 10.0, 2.0, 3.0, -40.0, 4.0, 5.0, 20.0, 6.0];
        assert_eq!(ddot(&a[1..], 3, &a[1..], 3, 3), 100.0 + 1600.0 + 400.0);
        assert_eq!(idamax(&a[1..], 3, 3), 1, "pivot finds -40");
    }

    #[test]
    fn row_swap_and_norm() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        dswap_rows(&mut a, 3, 0, 1, 3);
        assert_eq!(a, vec![4.0, 5.0, 6.0, 1.0, 2.0, 3.0]);
        assert_eq!(dlange_inf(&a, 3, 2, 3), 15.0);
        dswap_rows(&mut a, 3, 1, 1, 3); // no-op
        assert_eq!(a[3], 1.0);
    }

    #[test]
    fn idamax_first_max_wins() {
        assert_eq!(idamax(&[3.0, -3.0, 3.0], 1, 3), 0);
        assert_eq!(idamax(&[0.0; 4], 1, 4), 0);
    }
}
