//! BLAS level-3: the full `dgemm` of the paper's equation (4) —
//! `C ← α·op(A)·op(B) + β·C` with optional transposes — plus the
//! triangular solve (`dtrsm`) and symmetric rank-k update (`dsyrk`)
//! routines HPL-class workloads lean on. All routines accept a
//! [`GemmBackend`] so their inner multiplications can run through the
//! instruction-level MMA simulator.

use crate::blas::gemm::{ref_gemm_plus, GemmBackend};
use crate::isa::ExecError;

/// Transpose selector for [`dgemm_full`] (the `A^[T]` of eq. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    N,
    T,
}

/// Materialize `op(M)` as a contiguous row-major `rows×cols` matrix.
fn materialize(m: &[f64], ld: usize, rows: usize, cols: usize, t: Trans) -> Vec<f64> {
    let mut out = vec![0f64; rows * cols];
    match t {
        Trans::N => {
            for i in 0..rows {
                out[i * cols..(i + 1) * cols].copy_from_slice(&m[i * ld..i * ld + cols]);
            }
        }
        Trans::T => {
            for i in 0..rows {
                for j in 0..cols {
                    out[i * cols + j] = m[j * ld + i];
                }
            }
        }
    }
    out
}

/// Equation (4): `C ← α·op(A)·op(B) + β·C` (row-major, contiguous C).
///
/// `m×k = op(A)`, `k×n = op(B)`. The multiply runs on `backend`; the α/β
/// scaling is the thin host layer every BLAS wraps around its kernel.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_full(
    alpha: f64,
    a: &[f64],
    lda: usize,
    ta: Trans,
    b: &[f64],
    ldb: usize,
    tb: Trans,
    beta: f64,
    c: &mut [f64],
    m: usize,
    n: usize,
    k: usize,
    backend: &mut dyn GemmBackend,
) -> Result<(), ExecError> {
    let aop = materialize(a, lda, m, k, ta);
    let bop = materialize(b, ldb, k, n, tb);
    // C ← β·C − (−α)·A·B, expressed through the backend's `C -= A·B`
    for v in c.iter_mut() {
        *v *= beta;
    }
    if alpha == 0.0 || k == 0 {
        return Ok(());
    }
    let scaled: Vec<f64> = aop.iter().map(|&v| -alpha * v).collect();
    backend.gemm_minus(c, n, &scaled, k, &bop, n, m, n, k)
}

/// `dtrsm` (left, lower, non-unit or unit diagonal): solve
/// `op(L)·X = α·B` in place over the row-major `m×n` B.
#[allow(clippy::too_many_arguments)]
pub fn dtrsm_left_lower(
    alpha: f64,
    l: &[f64],
    ldl: usize,
    unit_diag: bool,
    b: &mut [f64],
    ldb: usize,
    m: usize,
    n: usize,
) {
    for v in b.iter_mut().take((m - 1) * ldb + n) {
        *v *= alpha;
    }
    for i in 0..m {
        for kk in 0..i {
            let lik = l[i * ldl + kk];
            if lik == 0.0 {
                continue;
            }
            for j in 0..n {
                let bkj = b[kk * ldb + j];
                b[i * ldb + j] -= lik * bkj;
            }
        }
        if !unit_diag {
            let d = l[i * ldl + i];
            for j in 0..n {
                b[i * ldb + j] /= d;
            }
        }
    }
}

/// `dtrsm` (right, upper, non-unit diagonal): solve `X·op(U) = α·B` in
/// place — the other panel solve HPL needs.
#[allow(clippy::too_many_arguments)]
pub fn dtrsm_right_upper(
    alpha: f64,
    u: &[f64],
    ldu: usize,
    b: &mut [f64],
    ldb: usize,
    m: usize,
    n: usize,
) {
    for v in b.iter_mut().take((m - 1) * ldb + n) {
        *v *= alpha;
    }
    for j in 0..n {
        let d = u[j * ldu + j];
        for i in 0..m {
            let mut s = b[i * ldb + j];
            for kk in 0..j {
                s -= b[i * ldb + kk] * u[kk * ldu + j];
            }
            b[i * ldb + j] = s / d;
        }
    }
}

/// `dsyrk` (lower): `C ← α·A·Aᵀ + β·C`, updating only the lower triangle
/// of the `n×n` C (A is `n×k` row-major).
pub fn dsyrk_lower(
    alpha: f64,
    a: &[f64],
    k: usize,
    beta: f64,
    c: &mut [f64],
    n: usize,
) {
    for i in 0..n {
        for j in 0..=i {
            let dot: f64 = (0..k).map(|kk| a[i * k + kk] * a[j * k + kk]).sum();
            c[i * n + j] = alpha * dot + beta * c[i * n + j];
        }
    }
}

/// Full `C = A·B` convenience on the reference path (used by oracles).
pub fn matmul(a: &[f64], b: &[f64], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    ref_gemm_plus(&mut c, n, a, k, b, n, m, n, k);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::gemm::{RefGemm, SimMmaGemm};
    use crate::testkit::{assert_allclose, check, Rng};

    fn naive_opmul(
        alpha: f64,
        a: &[f64],
        lda: usize,
        ta: Trans,
        b: &[f64],
        ldb: usize,
        tb: Trans,
        beta: f64,
        c0: &[f64],
        m: usize,
        n: usize,
        k: usize,
    ) -> Vec<f64> {
        let mut c = c0.to_vec();
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    let av = match ta {
                        Trans::N => a[i * lda + kk],
                        Trans::T => a[kk * lda + i],
                    };
                    let bv = match tb {
                        Trans::N => b[kk * ldb + j],
                        Trans::T => b[j * ldb + kk],
                    };
                    s += av * bv;
                }
                c[i * n + j] = alpha * s + beta * c0[i * n + j];
            }
        }
        c
    }

    #[test]
    fn eq4_all_transpose_combinations() {
        check("dgemm_full == eq.4", 16, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 20), rng.range(1, 20), rng.range(1, 20));
            let ta = if rng.bool() { Trans::N } else { Trans::T };
            let tb = if rng.bool() { Trans::N } else { Trans::T };
            let (alpha, beta) = (rng.f64_range(-2.0, 2.0), rng.f64_range(-2.0, 2.0));
            let lda = if ta == Trans::N { k } else { m };
            let ldb = if tb == Trans::N { n } else { k };
            let a = rng.f64_vec(m.max(k) * lda);
            let b = rng.f64_vec(k.max(n) * ldb);
            let c0 = rng.f64_vec(m * n);
            let mut c = c0.clone();
            dgemm_full(alpha, &a, lda, ta, &b, ldb, tb, beta, &mut c, m, n, k, &mut RefGemm)
                .unwrap();
            let expect = naive_opmul(alpha, &a, lda, ta, &b, ldb, tb, beta, &c0, m, n, k);
            assert_allclose(&c, &expect, 1e-12, 1e-12);
        });
    }

    #[test]
    fn eq4_on_simulated_mma() {
        // alpha/beta/transpose GEMM with the multiply running as MMA
        // instruction streams
        let mut rng = Rng::new(4);
        let (m, n, k) = (16, 8, 12);
        let a = rng.f64_vec(m * k);
        let b = rng.f64_vec(n * k); // will be transposed: op(B) = B^T (k x n)
        let c0 = rng.f64_vec(m * n);
        let mut c = c0.clone();
        let mut sim = SimMmaGemm::default();
        dgemm_full(1.5, &a, k, Trans::N, &b, k, Trans::T, -0.5, &mut c, m, n, k, &mut sim).unwrap();
        let expect = naive_opmul(1.5, &a, k, Trans::N, &b, k, Trans::T, -0.5, &c0, m, n, k);
        assert_allclose(&c, &expect, 1e-12, 1e-12);
        assert!(sim.stats.mma_instructions > 0);
    }

    #[test]
    fn trsm_left_lower_solves() {
        check("dtrsm ll", 10, |rng: &mut Rng| {
            let m = rng.range(1, 12);
            let n = rng.range(1, 12);
            // well-conditioned lower-triangular L
            let mut l = vec![0f64; m * m];
            for i in 0..m {
                for j in 0..i {
                    l[i * m + j] = rng.f64_range(-0.5, 0.5);
                }
                l[i * m + i] = rng.f64_range(1.0, 2.0);
            }
            let x_true = rng.f64_vec(m * n);
            // B = L X
            let b0 = matmul(&l, &x_true, m, n, m);
            let mut b = b0.clone();
            dtrsm_left_lower(1.0, &l, m, false, &mut b, n, m, n);
            assert_allclose(&b, &x_true, 1e-9, 1e-10);
        });
    }

    #[test]
    fn trsm_right_upper_solves() {
        check("dtrsm ru", 10, |rng: &mut Rng| {
            let m = rng.range(1, 12);
            let n = rng.range(1, 12);
            let mut u = vec![0f64; n * n];
            for i in 0..n {
                u[i * n + i] = rng.f64_range(1.0, 2.0);
                for j in (i + 1)..n {
                    u[i * n + j] = rng.f64_range(-0.5, 0.5);
                }
            }
            let x_true = rng.f64_vec(m * n);
            let b0 = matmul(&x_true, &u, m, n, n);
            let mut b = b0.clone();
            dtrsm_right_upper(1.0, &u, n, &mut b, n, m, n);
            assert_allclose(&b, &x_true, 1e-9, 1e-10);
        });
    }

    #[test]
    fn trsm_unit_diag_ignores_diagonal() {
        let m = 4;
        // unit-diag solve must not read the stored diagonal
        let mut l = vec![0f64; m * m];
        for i in 0..m {
            l[i * m + i] = 999.0; // garbage diagonal
            for j in 0..i {
                l[i * m + j] = 0.25;
            }
        }
        let x_true = vec![1.0, 2.0, 3.0, 4.0];
        // B = unit-lower(L) * x
        let mut b = vec![0.0; m];
        for i in 0..m {
            b[i] = x_true[i] + (0..i).map(|j| 0.25 * x_true[j]).sum::<f64>();
        }
        dtrsm_left_lower(1.0, &l, m, true, &mut b, 1, m, 1);
        assert_allclose(&b, &x_true, 1e-12, 1e-12);
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Rng::new(8);
        let (n, k) = (7, 5);
        let a = rng.f64_vec(n * k);
        let c0 = rng.f64_vec(n * n);
        let mut c = c0.clone();
        dsyrk_lower(2.0, &a, k, 0.5, &mut c, n);
        // oracle: full gemm A * A^T
        let mut at = vec![0f64; k * n];
        for i in 0..n {
            for j in 0..k {
                at[j * n + i] = a[i * k + j];
            }
        }
        let full = matmul(&a, &at, n, n, k);
        for i in 0..n {
            for j in 0..n {
                if j <= i {
                    let expect = 2.0 * full[i * n + j] + 0.5 * c0[i * n + j];
                    assert!((c[i * n + j] - expect).abs() < 1e-10);
                } else {
                    assert_eq!(c[i * n + j], c0[i * n + j], "upper triangle untouched");
                }
            }
        }
    }
}
