//! Reduced-precision **bf16 packed-panel GEMM engine** — the serving-side
//! realization of the paper's Table I claim that `xvbf16ger2` rank-2
//! updates double the MACs per instruction over `xvf32ger` (§II-B), built
//! the way Kuzma et al.'s layered-reorganization work realizes it: the
//! win lives in the **packing layer**, which interleaves the operands as
//! bf16 *k-pairs* so every microkernel step consumes two inner-dimension
//! values per fused update.
//!
//! Structure (the BLIS-style skeleton of [`crate::blas::block_gemm`],
//! re-instantiated for a half-width element type):
//!
//! * operands arrive as [`Bf16Src`]: **raw bf16 bits** (`u16`, the
//!   `xvbf16ger2` operand width — packed straight into panels, no f32
//!   widening round-trip) or f32 with the bf16 round-to-nearest-even
//!   **fused into packing** (the compiled form of a `convert(bf16)`
//!   feeding a `dot` — see the `DotBf16` lowering in
//!   [`crate::runtime::plan`]);
//! * panels are **k-pair-interleaved** (`kernels::pack::
//!   {pack_a_panel_bf16, pack_b_panel_bf16}` and their `_f32_` fused
//!   variants): step `s` of an A panel holds `MR` adjacent (lo, hi)
//!   pairs for `k = 2s, 2s+1`, a B-panel step holds `NR` pairs — the
//!   `xvbf16ger2pp` rank-2 operand layout of [`crate::kernels::gemm_rp`]
//!   scaled to the blocked engine's micropanels;
//! * the **`MR×NR = 8×16` microkernel** (the Figure 8 virtual
//!   accumulator shape) applies one rank-2 update per step and keeps the
//!   accumulator tile in registers across the packed `KC` depth;
//! * the **column (jc) loop is the parallel axis**: whole-`NR` column
//!   chunks fan out under the same [`Par`] policy (and flop thresholds)
//!   as the f32 engine — on the serving path that is the persistent
//!   device pool, so the bf16 path parallelizes from day one.
//!
//! ## Numerics: two contracts, both bit-exact
//!
//! * [`Bf16Accum::Widened`] — the **serving contract**: every packed
//!   bf16 value widens exactly, products are exact in `f64`, and each
//!   `C` element accumulates in strictly ascending `k` order in `f64`
//!   with one final narrowing store. On finite inputs this is
//!   bit-identical to the legacy interpreter executing
//!   `convert(bf16) → convert(f32) → dot` (elementwise rounding followed
//!   by the [`ref_gemm`](crate::blas::gemm::ref_gemm) `f64` path), which
//!   is exactly the subgraph the plan rewrite collapses into a
//!   `DotBf16` step. [`gemm_bf16_reference`] is that contract in
//!   20 lines, for tests and the bench identity probe.
//! * [`Bf16Accum::F32Pairs`] — the **MME contract**: each step's pair of
//!   products is summed low-then-high in `f32` and chained onto an `f32`
//!   accumulator, the first step *assigned* (`AccOp::New` primes the
//!   accumulator) — bit-identical to the functional Machine executing
//!   the `xvbf16ger2`/`xvbf16ger2pp` kernel of
//!   [`gemm_rp::rp_gemm_program`](crate::kernels::gemm_rp), masked tail
//!   included (tested against [`gemm_bf16_8x16`](crate::kernels::gemm_rp::gemm_bf16_8x16)).
//!
//! The odd-`k` tail needs no masked special case in either mode: the
//! packers zero-fill the pad lane, and a zero pair product contributes
//! `+0.0` *after* the real product of its step — `x + 0.0` preserves
//! every `x` the chain can produce (the accumulator can never be `-0.0`:
//! it starts at `+0.0`, and IEEE round-to-nearest addition only yields
//! `-0.0` from `-0.0 + -0.0`), and it matches the Machine's prefixed
//! `pmsk` form bit for bit (the masked sum starts from `+0.0` there,
//! with the same effect on zero signs).
//!
//! NaN policy: packing canonicalizes bf16 NaN bits (sign-preserved
//! `0x7fc0`), so the raw-bits path and the widen-then-round path agree
//! bitwise even on NaN payloads — the XLA `convert` contract of
//! [`bf16_round`](crate::runtime::hlo::bf16_round).
//!
//! ```
//! use power_mma::blas::bf16_gemm::{
//!     gemm_bf16_packed_into, gemm_bf16_reference, Bf16Accum, Bf16Scratch, Bf16Src,
//! };
//! use power_mma::blas::block_gemm::Par;
//!
//! // 2x2: the convert-to-bf16 is fused into packing, so 0.3004 rounds
//! // to the bf16 grid on its way into the panel
//! let a = [1.0f32, 2.0, 3.0, 4.0];
//! let b = [0.3004f32, 0.0, 0.0, 1.0];
//! let mut c = [0.0f32; 4];
//! let mut scratch = Bf16Scratch::new();
//! gemm_bf16_packed_into(
//!     &mut c, Bf16Src::F32(&a), Bf16Src::F32(&b), 2, 2, 2,
//!     Bf16Accum::Widened, Par::Seq, &mut scratch,
//! );
//! assert_eq!(c.to_vec(), gemm_bf16_reference(&a, &b, 2, 2, 2));
//! assert_eq!(c[0], 0.30078125, "bf16 grid, not 0.3004");
//! ```

use crate::blas::block_gemm::{chunk_plan_nr, Epilogue, ExecutedKernel, GemmVariant, Par, KC};
use crate::isa::types::bf16_to_f32;
use crate::kernels::pack::{
    pack_a_panel_bf16, pack_a_panel_f32_bf16, pack_b_panel_bf16, pack_b_panel_f32_bf16,
};
use std::sync::Mutex;

/// Microkernel register-block rows (the 8 of the Figure 8 `8×16` virtual
/// accumulator).
pub const MR: usize = 8;
/// Microkernel register-block columns (16: four 4-wide accumulators
/// side by side, the SGEMM/bf16 shape of Figure 8).
pub const NR: usize = 16;

// KC blocks must cover whole k-pairs: an odd block boundary would split
// a rank-2 step (and force a masked pad mid-chain).
const _: () = assert!(KC % 2 == 0, "KC must be even: packed bf16 steps cover k-pairs");

/// The descriptor of a tuned bf16 GEMM call: `xvbf16ger2` (rank 2) over
/// 2-byte pair-interleaved panels, under the given variant's blocking.
pub fn executed_kernel_bf16(m: usize, n: usize, k: usize, v: GemmVariant) -> ExecutedKernel {
    ExecutedKernel { elem: "bf16", ger: "xvbf16ger2", rank: 2, esize: 2, m, n, k, v }
}

/// Where a bf16 GEMM operand comes from. Both variants pack to the same
/// pair-interleaved bf16 panels; neither widens the operand to an f32
/// tensor first.
#[derive(Clone, Copy)]
pub enum Bf16Src<'a> {
    /// Row-major f32 storage; the bf16 round-to-nearest-even is fused
    /// into packing (canonical NaNs — the XLA `convert` contract).
    F32(&'a [f32]),
    /// Row-major raw bf16 bits (the `DTypeSlice::Bf16` serving input);
    /// packed verbatim with NaN canonicalization.
    Bits(&'a [u16]),
}

impl Bf16Src<'_> {
    /// Number of elements in the backing storage.
    pub fn len(&self) -> usize {
        match self {
            Bf16Src::F32(s) => s.len(),
            Bf16Src::Bits(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pack an A micropanel (rows `i0..i0+rows` × columns `k0..k0+kc`).
    #[allow(clippy::too_many_arguments)]
    fn pack_a(
        &self,
        lda: usize,
        i0: usize,
        rows: usize,
        k0: usize,
        kc: usize,
        mr: usize,
        out: &mut [u16],
    ) {
        match self {
            Bf16Src::F32(a) => pack_a_panel_f32_bf16(a, lda, i0, rows, k0, kc, mr, out),
            Bf16Src::Bits(a) => pack_a_panel_bf16(a, lda, i0, rows, k0, kc, mr, out),
        }
    }

    /// Pack a B micropanel (rows `k0..k0+kc` × columns `j0..j0+cols`).
    #[allow(clippy::too_many_arguments)]
    fn pack_b(
        &self,
        ldb: usize,
        k0: usize,
        kc: usize,
        j0: usize,
        cols: usize,
        nr: usize,
        out: &mut [u16],
    ) {
        match self {
            Bf16Src::F32(b) => pack_b_panel_f32_bf16(b, ldb, k0, kc, j0, cols, nr, out),
            Bf16Src::Bits(b) => pack_b_panel_bf16(b, ldb, k0, kc, j0, cols, nr, out),
        }
    }
}

/// Accumulation mode of the bf16 microkernel — each mode is bit-exact
/// against one existing oracle (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Bf16Accum {
    /// Exact widening, `f64` products and ascending-`k` `f64` sums, one
    /// narrowing store — the interpreter's `convert → dot` contract
    /// (what [`crate::runtime::plan`]'s `DotBf16` step uses by default).
    #[default]
    Widened,
    /// `f32` pair products summed low-then-high, chained in `f32` with
    /// the first step assigned — the `xvbf16ger2(pp)` Machine contract
    /// of [`crate::kernels::gemm_rp`].
    F32Pairs,
}

/// Reusable scratch for [`gemm_bf16_packed_into`]: the `f64` accumulation
/// image of `C` (column-chunk-blocked during the parallel phase; for
/// [`Bf16Accum::F32Pairs`] it carries exact f32 values widened) plus one
/// packed-B-block and packed-A-panel buffer per column-chunk worker —
/// panels are `u16`, half the footprint of the f32 engine's. Hold one
/// per compiled plan and steady-state requests allocate nothing.
#[derive(Default)]
pub struct Bf16Scratch {
    c64: Vec<f64>,
    bp: Vec<Vec<u16>>,
    ap: Vec<Vec<u16>>,
}

impl Bf16Scratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> Bf16Scratch {
        Bf16Scratch::default()
    }

    /// Grow the buffers so a subsequent `m×n×k` GEMM on up to `threads`
    /// workers allocates nothing (canonical 8×16 variant).
    pub fn reserve(&mut self, m: usize, n: usize, k: usize, threads: usize) {
        self.reserve_for(m, n, k, threads, GemmVariant::CANONICAL_WIDE);
    }

    /// [`Bf16Scratch::reserve`] for an explicit variant: panel sizes are
    /// derived from the variant's blocking config, not the fixed
    /// `KC`/`NC` constants.
    pub fn reserve_for(&mut self, m: usize, n: usize, k: usize, threads: usize, v: GemmVariant) {
        let (nchunks, cols_per) = chunk_plan_nr(n, threads.max(1), v.nr);
        self.reserve_chunks(m, n, k, nchunks, cols_per, v);
    }

    fn reserve_chunks(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        nchunks: usize,
        cols_per: usize,
        v: GemmVariant,
    ) {
        let c_need = m * n;
        if self.c64.len() < c_need {
            self.c64.resize(c_need, 0.0);
        }
        let steps = v.block.kc.min(k.max(1)).div_ceil(2);
        let bp_need = steps * 2 * v.block.nc.min(cols_per.max(v.nr));
        if self.bp.len() < nchunks {
            self.bp.resize_with(nchunks, Vec::new);
        }
        for b in &mut self.bp[..nchunks] {
            if b.len() < bp_need {
                b.resize(bp_need, 0);
            }
        }
        let ap_need = steps * 2 * v.mr;
        if self.ap.len() < nchunks {
            self.ap.resize_with(nchunks, Vec::new);
        }
        for a in &mut self.ap[..nchunks] {
            if a.len() < ap_need {
                a.resize(ap_need, 0);
            }
        }
    }
}

/// The elementwise-rounding reference of the **widened contract**: round
/// both operands to the bf16 grid (canonical NaNs), widen exactly, and
/// accumulate each element's products in strictly ascending `k` order in
/// `f64`, narrowing once — what the legacy interpreter computes for
/// `convert(bf16) → convert(f32) → dot`, spelled out without packing or
/// tiling. (The interpreter's `ref_gemm` additionally skips products
/// whose A element is exactly zero — an optimization that is bitwise
/// invisible unless a zero A element meets a non-finite B element, the
/// same already-documented caveat the f32 blocked engine carries.) The
/// packed engine in [`Bf16Accum::Widened`] mode must match this bit for
/// bit on *all* inputs, NaN payloads included; tests and `bench serve`'s
/// `bf16` identity probe hold it to that.
pub fn gemm_bf16_reference(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    use crate::isa::types::f32_to_bf16_canonical as rnd;
    let ar: Vec<f64> = a.iter().map(|&v| f64::from(bf16_to_f32(rnd(v)))).collect();
    let br: Vec<f64> = b.iter().map(|&v| f64::from(bf16_to_f32(rnd(v)))).collect();
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for kk in 0..k {
                acc += ar[i * k + kk] * br[kk * n + j];
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

/// The elementwise-rounding reference of the **`F32Pairs` contract**
/// ([`Bf16Accum::F32Pairs`]): round both operands to the bf16 grid
/// (canonical NaNs), then per output element walk the `k` pairs in
/// ascending order computing each rank-2 pair product
/// `a₀·b₀ + a₁·b₁` in `f32` (bf16 products are exact in `f32`; the pair
/// sum rounds once) and chaining in `f32` — the first pair *assigns*
/// (the Machine's `AccOp::New`), every later pair adds `p + acc` in that
/// operand order. An odd `k` contributes a literal `+0.0` high-lane
/// product (not skipped: `-0.0 + 0.0` is `+0.0`, so the padding term is
/// observable in zero signs), and `k = 0` yields `0.0` — all exactly
/// what the packed engine's zero-padded panels compute. Because `KC` is
/// even, the engine's cache blocks never split a pair, so this flat
/// chain IS the blocked chain; the packed engine in
/// [`Bf16Accum::F32Pairs`] mode must match this bit for bit.
pub fn gemm_bf16_reference_pairs(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    use crate::isa::types::f32_to_bf16_canonical as rnd;
    let ar: Vec<f32> = a.iter().map(|&v| bf16_to_f32(rnd(v))).collect();
    let br: Vec<f32> = b.iter().map(|&v| bf16_to_f32(rnd(v))).collect();
    let pairs = k.div_ceil(2);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for p in 0..pairs {
                let (k0, k1) = (2 * p, 2 * p + 1);
                let a0 = ar[i * k + k0];
                let b0 = br[k0 * n + j];
                let (a1, b1) = if k1 < k { (ar[i * k + k1], br[k1 * n + j]) } else { (0.0, 0.0) };
                let prod = a0 * b0 + a1 * b1;
                acc = if p == 0 { prod } else { prod + acc };
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// `C = A·B` over pair-interleaved bf16 panels into a caller-provided
/// `c` (`m×n`, row-major, fully overwritten). `a` is `m×k`, `b` is
/// `k×n`, both row-major and contiguous, each either raw bf16 bits or
/// f32 rounded during packing ([`Bf16Src`]). The column chunks are
/// distributed per `par` (callers pick the per-step policy with
/// [`Par::for_gemm`], exactly like the f32 engine) and drained before
/// the call returns. See [`Bf16Accum`] for the two bit-exact
/// accumulation contracts.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bf16_packed_into(
    c: &mut [f32],
    a: Bf16Src<'_>,
    b: Bf16Src<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: Bf16Accum,
    par: Par<'_>,
    scratch: &mut Bf16Scratch,
) {
    gemm_bf16_tuned_into(
        c,
        a,
        b,
        m,
        n,
        k,
        accum,
        Epilogue::None,
        par,
        scratch,
        GemmVariant::CANONICAL_WIDE,
    );
}

/// [`gemm_bf16_packed_into`] with an explicit [`GemmVariant`] and fused
/// [`Epilogue`] — the entry point the autotuned plan steps call. Every
/// variant produces the same bits as [`GemmVariant::CANONICAL_WIDE`]
/// under both [`Bf16Accum`] contracts: the variant's `kc` must stay even
/// (cache blocks never split a rank-2 pair), so each `C` element replays
/// the same ascending-`k` pair chain from the same rounded values
/// whatever the tile geometry (`rust/tests/tune_engine.rs` pins this
/// across the family). The epilogue applies per element at the final
/// narrowed `f32` writeback, exactly like the f32 engine's — so a fused
/// `dot → add(bias) → maximum(0)` tail is bitwise the interpreter's
/// separate instructions.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bf16_tuned_into(
    c: &mut [f32],
    a: Bf16Src<'_>,
    b: Bf16Src<'_>,
    m: usize,
    n: usize,
    k: usize,
    accum: Bf16Accum,
    epilogue: Epilogue<'_>,
    par: Par<'_>,
    scratch: &mut Bf16Scratch,
    v: GemmVariant,
) {
    assert!(v.block.kc % 2 == 0, "bf16 kc must be even: steps cover k-pairs ({})", v.name());
    assert!(
        v.block.nc % v.nr == 0 && v.block.mc % v.mr == 0,
        "blocking must be tile-aligned: {}",
        v.name()
    );
    assert_eq!(a.len(), m * k, "A must be m*k");
    assert_eq!(b.len(), k * n, "B must be k*n");
    assert_eq!(c.len(), m * n, "C must be m*n");
    match epilogue {
        Epilogue::Bias(bias) | Epilogue::BiasRelu(bias) => {
            assert!(bias.len() >= n, "bias must cover all n columns");
        }
        Epilogue::DftCombine { other, .. } => {
            assert!(other.len() >= m * n, "combine operand must cover the m*n output");
        }
        Epilogue::None => {}
    }
    if m == 0 || n == 0 {
        return;
    }
    let (nchunks, cols_per) = chunk_plan_nr(n, par.cap(), v.nr);
    scratch.reserve_chunks(m, n, k, nchunks, cols_per, v);
    let c64 = &mut scratch.c64[..m * n];
    c64.fill(0.0);
    if k > 0 {
        // Per-chunk state behind per-index mutexes (worker w locks only
        // entry w — uncontended, they exist to keep the closure `Fn`);
        // chunk w owns the contiguous m×wcols block of the f64 image for
        // columns [w*cols_per, w*cols_per + wcols), like the f32 engine.
        struct Chunk<'s> {
            c64: &'s mut [f64],
            bp: &'s mut [u16],
            ap: &'s mut [u16],
        }
        let mut chunks: Vec<Mutex<Chunk<'_>>> = Vec::with_capacity(nchunks);
        let mut rest: &mut [f64] = c64;
        for (w, (bpb, apb)) in
            scratch.bp.iter_mut().zip(scratch.ap.iter_mut()).take(nchunks).enumerate()
        {
            let wcols = cols_per.min(n - w * cols_per);
            let (cw, r) = rest.split_at_mut(m * wcols);
            rest = r;
            chunks.push(Mutex::new(Chunk { c64: cw, bp: bpb, ap: apb }));
        }
        let chunks = &chunks;
        par.run(nchunks, &|w| {
            let mut guard = chunks[w].lock().unwrap_or_else(|p| p.into_inner());
            let ch = &mut *guard;
            let j0 = w * cols_per;
            let wcols = cols_per.min(n - j0);
            col_worker(ch.c64, &a, &b, ch.bp, ch.ap, m, n, k, j0, wcols, accum, v);
        });
    }
    // writeback: narrow the f64 image (exact for F32Pairs — it carries
    // f32 values widened), apply the fused epilogue per element, and
    // de-block the column chunks
    let c64 = &scratch.c64;
    for w in 0..nchunks {
        let j0 = w * cols_per;
        let wcols = cols_per.min(n - j0);
        let cw = &c64[m * cols_per * w..m * cols_per * w + m * wcols];
        for i in 0..m {
            let crow = &mut c[i * n + j0..i * n + j0 + wcols];
            let srow = &cw[i * wcols..(i + 1) * wcols];
            for (jl, (dst, &src)) in crow.iter_mut().zip(srow).enumerate() {
                *dst = epilogue.apply(src as f32, j0 + jl, i * n + j0 + jl);
            }
        }
    }
}

/// One worker's share: all `m` rows of columns `j0 .. j0+wcols`, the
/// whole `k` depth, walked in `v.block.nc`/`v.block.kc` cache blocks
/// with `kc` ascending (the bit-exactness order). The worker packs its
/// own pair-interleaved B panels per (nc, kc) block and sweeps each
/// packed `mr×kc` A micropanel across the chunk's `nr` panels.
#[allow(clippy::too_many_arguments)]
fn col_worker(
    c64: &mut [f64],
    a: &Bf16Src<'_>,
    b: &Bf16Src<'_>,
    bp: &mut [u16],
    ap: &mut [u16],
    m: usize,
    n: usize,
    k: usize,
    j0: usize,
    wcols: usize,
    accum: Bf16Accum,
    v: GemmVariant,
) {
    let (mr, nr) = (v.mr, v.nr);
    let (mc, kc, nc) = (v.block.mc, v.block.kc, v.block.nc);
    for jc in (0..wcols).step_by(nc) {
        let ncl = nc.min(wcols - jc);
        let n_panels = ncl.div_ceil(nr);
        for kc0 in (0..k).step_by(kc) {
            let kcl = kc.min(k - kc0);
            let steps = kcl.div_ceil(2);
            // the F32Pairs chain *assigns* its first pair product
            // (AccOp::New primes the accumulators on the Machine)
            let first = accum == Bf16Accum::F32Pairs && kc0 == 0;
            let bpl = &mut bp[..n_panels * steps * nr * 2];
            for jp in 0..n_panels {
                let jabs = j0 + jc + jp * nr;
                let cols = nr.min(j0 + jc + ncl - jabs);
                let panel = &mut bpl[jp * steps * nr * 2..(jp + 1) * steps * nr * 2];
                b.pack_b(n, kc0, kcl, jabs, cols, nr, panel);
            }
            let bpl = &*bpl;
            let apl = &mut ap[..steps * mr * 2];
            for ic in (0..m).step_by(mc) {
                let mcl = mc.min(m - ic);
                for ir in (0..mcl).step_by(mr) {
                    let gi = ic + ir;
                    let mrl = mr.min(m - gi);
                    a.pack_a(k, gi, mrl, kc0, kcl, mr, apl);
                    for jp in 0..n_panels {
                        let jloc = jc + jp * nr;
                        let nrl = nr.min(wcols - jloc);
                        let bpp = &bpl[jp * steps * nr * 2..(jp + 1) * steps * nr * 2];
                        match accum {
                            Bf16Accum::Widened => microkernel_widened_v(
                                v, c64, gi, jloc, wcols, apl, bpp, steps, mrl, nrl,
                            ),
                            Bf16Accum::F32Pairs => microkernel_pairs_v(
                                v, c64, gi, jloc, wcols, apl, bpp, steps, mrl, nrl, first,
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Dispatch one widened-contract register tile to its monomorphized
/// kernel.
#[allow(clippy::too_many_arguments)]
fn microkernel_widened_v(
    v: GemmVariant,
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[u16],
    bp: &[u16],
    steps: usize,
    mrl: usize,
    nrl: usize,
) {
    match (v.mr, v.nr) {
        (8, 8) => microkernel_widened_g::<8, 8>(c64, ci, j0, ld, ap, bp, steps, mrl, nrl),
        (8, 16) => microkernel_widened_g::<8, 16>(c64, ci, j0, ld, ap, bp, steps, mrl, nrl),
        (mr, nr) => unreachable!("no monomorphized bf16 register tile {mr}x{nr}"),
    }
}

/// Dispatch one MME-contract register tile to its monomorphized kernel.
#[allow(clippy::too_many_arguments)]
fn microkernel_pairs_v(
    v: GemmVariant,
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[u16],
    bp: &[u16],
    steps: usize,
    mrl: usize,
    nrl: usize,
    first: bool,
) {
    match (v.mr, v.nr) {
        (8, 8) => microkernel_pairs_g::<8, 8>(c64, ci, j0, ld, ap, bp, steps, mrl, nrl, first),
        (8, 16) => microkernel_pairs_g::<8, 16>(c64, ci, j0, ld, ap, bp, steps, mrl, nrl, first),
        (mr, nr) => unreachable!("no monomorphized bf16 register tile {mr}x{nr}"),
    }
}

/// The `MR_×NR_` widened-contract microkernel, monomorphized per
/// register tile: loads the running `f64` sums of one `C` register
/// block, applies `steps` rank-2 updates from the pair-interleaved
/// panels — each pair's products added in ascending `k` order (low lane,
/// then high) so the whole chain replays the interpreter's `f64`
/// accumulation — and stores the sums back. Only the `mrl×nrl` valid
/// corner is loaded/stored; zero-padded panel lanes are computed and
/// discarded.
#[allow(clippy::too_many_arguments)]
fn microkernel_widened_g<const MR_: usize, const NR_: usize>(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[u16],
    bp: &[u16],
    steps: usize,
    mrl: usize,
    nrl: usize,
) {
    let mut acc = [[0f64; NR_]; MR_];
    for i in 0..mrl {
        let crow = &c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        acc[i][..nrl].copy_from_slice(crow);
    }
    for s in 0..steps {
        let ar = &ap[s * MR_ * 2..(s + 1) * MR_ * 2];
        let br = &bp[s * NR_ * 2..(s + 1) * NR_ * 2];
        // widen each lane exactly once per step (one (lo, hi) pair per
        // output column — the [[f64; 2]; NR_] shape keeps the length a
        // plain const on stable)
        let mut bw = [[0f64; 2]; NR_];
        for (slot, pair) in bw.iter_mut().zip(br.chunks_exact(2)) {
            slot[0] = f64::from(bf16_to_f32(pair[0]));
            slot[1] = f64::from(bf16_to_f32(pair[1]));
        }
        for (i, row) in acc.iter_mut().enumerate() {
            let a0 = f64::from(bf16_to_f32(ar[i * 2]));
            let a1 = f64::from(bf16_to_f32(ar[i * 2 + 1]));
            for (slot, bwp) in row.iter_mut().zip(&bw) {
                *slot += a0 * bwp[0];
                *slot += a1 * bwp[1];
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        crow.copy_from_slice(&acc[i][..nrl]);
    }
}

/// The `MR_×NR_` MME-contract microkernel ([`Bf16Accum::F32Pairs`]),
/// monomorphized per register tile: the running sums are exact `f32`
/// values stored widened in the `f64` image (lossless round-trip), each
/// step computes the rank-2 pair product `x₀·y₀ + x₁·y₁` in `f32` (bf16
/// products are exact in `f32`; the pair sum rounds once — the MME's
/// single-precision rank-2 accumulate) and chains it with an `f32` add.
/// When `first` is set (the `k = 0` block), step 0 *assigns* its pair
/// product — `AccOp::New` on the Machine — so even the sign of a zero
/// matches `xvbf16ger2`.
#[allow(clippy::too_many_arguments)]
fn microkernel_pairs_g<const MR_: usize, const NR_: usize>(
    c64: &mut [f64],
    ci: usize,
    j0: usize,
    ld: usize,
    ap: &[u16],
    bp: &[u16],
    steps: usize,
    mrl: usize,
    nrl: usize,
    first: bool,
) {
    let mut acc = [[0f32; NR_]; MR_];
    if !first {
        for i in 0..mrl {
            let crow = &c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
            for (slot, &v) in acc[i][..nrl].iter_mut().zip(crow) {
                *slot = v as f32; // exact: the image holds f32 values
            }
        }
    }
    for s in 0..steps {
        let ar = &ap[s * MR_ * 2..(s + 1) * MR_ * 2];
        let br = &bp[s * NR_ * 2..(s + 1) * NR_ * 2];
        let mut bw = [[0f32; 2]; NR_];
        for (slot, pair) in bw.iter_mut().zip(br.chunks_exact(2)) {
            slot[0] = bf16_to_f32(pair[0]);
            slot[1] = bf16_to_f32(pair[1]);
        }
        for (i, row) in acc.iter_mut().enumerate() {
            let a0 = bf16_to_f32(ar[i * 2]);
            let a1 = bf16_to_f32(ar[i * 2 + 1]);
            if first && s == 0 {
                for (slot, bwp) in row.iter_mut().zip(&bw) {
                    *slot = a0 * bwp[0] + a1 * bwp[1];
                }
            } else {
                for (slot, bwp) in row.iter_mut().zip(&bw) {
                    let p = a0 * bwp[0] + a1 * bwp[1];
                    *slot = p + *slot;
                }
            }
        }
    }
    for i in 0..mrl {
        let crow = &mut c64[(ci + i) * ld + j0..(ci + i) * ld + j0 + nrl];
        for (slot, &v) in crow.iter_mut().zip(&acc[i][..nrl]) {
            *slot = f64::from(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::types::{f32_to_bf16, f32_to_bf16_canonical};
    use crate::kernels::gemm_rp::gemm_bf16_8x16;
    use crate::rt::ThreadPool;
    use crate::testkit::{check, Rng};

    fn run_packed(
        a: Bf16Src<'_>,
        b: Bf16Src<'_>,
        m: usize,
        n: usize,
        k: usize,
        accum: Bf16Accum,
        par: Par<'_>,
    ) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        let mut scratch = Bf16Scratch::new();
        gemm_bf16_packed_into(&mut c, a, b, m, n, k, accum, par, &mut scratch);
        c
    }

    #[test]
    fn widened_matches_reference_across_shapes_and_policies() {
        // shapes straddling MR/NR/KC boundaries, odd k included
        let pool = ThreadPool::new("bf16-test", 4);
        let mut rng = Rng::new(0xbf16);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 1, 3),
            (3, 5, 9),
            (8, 16, 27),
            (9, 17, 31),
            (16, 33, KC + 3),
            (8, 300, 9),
            (33, 70, 40),
        ] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let expect = gemm_bf16_reference(&a, &b, m, n, k);
            for par in [Par::Seq, Par::Scoped(3), Par::Pool(&pool, 3), Par::Pool(&pool, 4)] {
                let got = run_packed(
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    Bf16Accum::Widened,
                    par,
                );
                assert_eq!(got, expect, "m={m} n={n} k={k}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn f32pairs_matches_reference_across_shapes_and_policies() {
        // the elementwise pairs oracle IS the blocked pairs chain (KC is
        // even, so cache blocks never split a pair) — across MR/NR/KC
        // boundary shapes, odd k, and every worker policy
        let pool = ThreadPool::new("bf16-pairs-test", 4);
        let mut rng = Rng::new(0xf32a);
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (1, 1, 3),
            (3, 5, 9),
            (8, 16, 27),
            (9, 17, 31),
            (16, 33, KC + 3),
            (8, 300, 9),
            (33, 70, 40),
        ] {
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let expect = gemm_bf16_reference_pairs(&a, &b, m, n, k);
            for par in [Par::Seq, Par::Scoped(3), Par::Pool(&pool, 3), Par::Pool(&pool, 4)] {
                let got = run_packed(
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    Bf16Accum::F32Pairs,
                    par,
                );
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let eb: Vec<u32> = expect.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, eb, "m={m} n={n} k={k}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn raw_bits_and_f32_sources_are_bit_identical() {
        // feeding pre-rounded raw bits must equal feeding the f32
        // originals (round fused into packing) — per operand side
        check("bf16 raw vs f32 sources", 6, |rng: &mut Rng| {
            let (m, n, k) = (rng.range(1, 20), rng.range(1, 40), rng.range(1, 30));
            let a = rng.f32_vec(m * k);
            let b = rng.f32_vec(k * n);
            let ab: Vec<u16> = a.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
            let bb: Vec<u16> = b.iter().map(|&v| f32_to_bf16_canonical(v)).collect();
            for accum in [Bf16Accum::Widened, Bf16Accum::F32Pairs] {
                let base = run_packed(
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    accum,
                    Par::Seq,
                );
                for (sa, sb) in [
                    (Bf16Src::Bits(&ab), Bf16Src::F32(&b)),
                    (Bf16Src::F32(&a), Bf16Src::Bits(&bb)),
                    (Bf16Src::Bits(&ab), Bf16Src::Bits(&bb)),
                ] {
                    let got = run_packed(sa, sb, m, n, k, accum, Par::Seq);
                    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    let eb: Vec<u32> = base.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, eb, "m={m} n={n} k={k} {accum:?}");
                }
            }
        });
    }

    #[test]
    fn f32pairs_matches_the_machine_kernel_bitwise() {
        // the MME contract: on the Machine's native 8xKx16 tile, the
        // scalar rank-2 kernel must reproduce xvbf16ger2(pp) exactly —
        // including odd k, which the Machine handles with the prefixed
        // pmsk form and we handle with the zero-padded pair lane
        let mut rng = Rng::new(0x9e12);
        for &k in &[1usize, 2, 3, 7, 8, 15, 16, 24] {
            let x = rng.f32_vec(8 * k);
            let y = rng.f32_vec(16 * k);
            let machine = gemm_bf16_8x16(&x, &y, k).unwrap();
            // engine B is k x n: transpose y (16 x k row-major)
            let mut b = vec![0f32; k * 16];
            for j in 0..16 {
                for kk in 0..k {
                    b[kk * 16 + j] = y[j * k + kk];
                }
            }
            let got = run_packed(
                Bf16Src::F32(&x),
                Bf16Src::F32(&b),
                8,
                16,
                k,
                Bf16Accum::F32Pairs,
                Par::Seq,
            );
            for i in 0..8 {
                for j in 0..16 {
                    assert_eq!(
                        got[i * 16 + j].to_bits(),
                        machine[i][j].to_bits(),
                        "k={k} ({i},{j}): {} vs {}",
                        got[i * 16 + j],
                        machine[i][j]
                    );
                }
            }
        }
    }

    #[test]
    fn worker_policy_never_changes_bits() {
        let pool = ThreadPool::new("bf16-par", 3);
        let mut rng = Rng::new(0x7a11);
        for accum in [Bf16Accum::Widened, Bf16Accum::F32Pairs] {
            for &(m, n, k) in &[(8usize, 48usize, 27usize), (16, 300, 9), (5, 33, 64)] {
                let a = rng.f32_vec(m * k);
                let b = rng.f32_vec(k * n);
                let seq =
                    run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), m, n, k, accum, Par::Seq);
                for par in [Par::Scoped(3), Par::Pool(&pool, 2), Par::Pool(&pool, 3)] {
                    let got = run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), m, n, k, accum, par);
                    assert_eq!(got, seq, "m={m} n={n} k={k} {accum:?}");
                }
            }
        }
        pool.shutdown();
    }

    #[test]
    fn scratch_reuse_is_clean_and_degenerate_shapes_work() {
        let mut scratch = Bf16Scratch::new();
        let mut rng = Rng::new(0x5c);
        let (a1, b1) = (rng.f32_vec(20 * 24), rng.f32_vec(24 * 36));
        let mut c1 = vec![0f32; 20 * 36];
        gemm_bf16_packed_into(
            &mut c1,
            Bf16Src::F32(&a1),
            Bf16Src::F32(&b1),
            20,
            36,
            24,
            Bf16Accum::Widened,
            Par::Seq,
            &mut scratch,
        );
        let (a2, b2) = (rng.f32_vec(3 * 5), rng.f32_vec(5 * 4));
        let mut c2 = vec![0f32; 3 * 4];
        gemm_bf16_packed_into(
            &mut c2,
            Bf16Src::F32(&a2),
            Bf16Src::F32(&b2),
            3,
            4,
            5,
            Bf16Accum::Widened,
            Par::Seq,
            &mut scratch,
        );
        assert_eq!(c1, gemm_bf16_reference(&a1, &b1, 20, 36, 24));
        assert_eq!(c2, gemm_bf16_reference(&a2, &b2, 3, 4, 5));
        // k = 0 -> all zeros (the empty-sum contract)
        let mut c = vec![9f32; 6];
        gemm_bf16_packed_into(
            &mut c,
            Bf16Src::F32(&[]),
            Bf16Src::F32(&[]),
            2,
            3,
            0,
            Bf16Accum::Widened,
            Par::Seq,
            &mut scratch,
        );
        assert_eq!(c, vec![0.0; 6]);
    }

    #[test]
    fn every_wide_variant_matches_reference_bitwise_spot() {
        // the full sweep lives in tests/tune_engine.rs; this in-module
        // spot check keeps the invariant visible next to the kernels
        let mut rng = Rng::new(0x77de);
        let (m, n, k) = (9, 17, 31);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        for accum in [Bf16Accum::Widened, Bf16Accum::F32Pairs] {
            let expect = match accum {
                Bf16Accum::Widened => gemm_bf16_reference(&a, &b, m, n, k),
                Bf16Accum::F32Pairs => gemm_bf16_reference_pairs(&a, &b, m, n, k),
            };
            for v in GemmVariant::wide_candidates() {
                let mut c = vec![0f32; m * n];
                let mut scratch = Bf16Scratch::new();
                gemm_bf16_tuned_into(
                    &mut c,
                    Bf16Src::F32(&a),
                    Bf16Src::F32(&b),
                    m,
                    n,
                    k,
                    accum,
                    Epilogue::None,
                    Par::Seq,
                    &mut scratch,
                    v,
                );
                let gb: Vec<u32> = c.iter().map(|x| x.to_bits()).collect();
                let eb: Vec<u32> = expect.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, eb, "variant {} {accum:?}", v.name());
            }
        }
    }

    #[test]
    fn rounding_actually_bites() {
        // a value off the bf16 grid must be rounded before multiplying —
        // the packed path models xvbf16ger2 inputs, not f32 inputs
        let a = [0.3004f32];
        let b = [1.0f32];
        let got =
            run_packed(Bf16Src::F32(&a), Bf16Src::F32(&b), 1, 1, 1, Bf16Accum::Widened, Par::Seq);
        let grid = bf16_to_f32(f32_to_bf16(0.3004));
        assert_eq!(got[0], grid);
        assert_ne!(got[0], 0.3004);
    }
}
