//! In-crate error substrate (`anyhow` is unavailable offline): a single
//! message-carrying [`Error`] with an outermost-first context chain, a
//! crate-wide [`Result`] alias, the [`err!`](crate::err)/[`bail!`](crate::bail)
//! macros, and a [`Context`] extension trait for `Result`/`Option`.
//!
//! The idiom mirrors `anyhow` deliberately so call sites read the same:
//!
//! ```
//! use power_mma::error::{Context, Result};
//!
//! fn parse_port(s: &str) -> Result<u16> {
//!     if s.is_empty() {
//!         power_mma::bail!("empty port string");
//!     }
//!     s.parse::<u16>().with_context(|| format!("bad port {s:?}"))
//! }
//!
//! assert!(parse_port("8080").is_ok());
//! assert!(parse_port("x").unwrap_err().to_string().contains("bad port"));
//! ```

use std::fmt;

/// A human-readable error: one message string, built outermost-context
/// first (`"loading gemm_f32: parsing HLO: bad dim 'q'"`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Wrap with outer context: `"{ctx}: {self}"`.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias (defaults the error type to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e.to_string())
    }
}

impl From<crate::isa::ExecError> for Error {
    fn from(e: crate::isa::ExecError) -> Error {
        Error::new(e.to_string())
    }
}

impl From<crate::builtins::BuiltinError> for Error {
    fn from(e: crate::builtins::BuiltinError) -> Error {
        Error::new(e.to_string())
    }
}

impl From<crate::isa::encode::CodecError> for Error {
    fn from(e: crate::isa::encode::CodecError) -> Error {
        Error::new(e.to_string())
    }
}

impl From<crate::cli::CliError> for Error {
    fn from(e: crate::cli::CliError) -> Error {
        Error::new(e.to_string())
    }
}

/// `anyhow::Context`-style extension: attach context to any fallible value.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;

    /// Wrap the error with a lazily-built context message.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::new(format!("{ctx}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::new(ctx.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Build an [`Error`] from a format string: `err!("model {name} not loaded")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("expected {n} inputs")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(err!("inner {}", 42))
    }

    #[test]
    fn message_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        let e = e.context("outermost");
        assert_eq!(e.to_string(), "outermost: outer: inner 42");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, std::num::ParseIntError> = "7".parse();
        let got = ok.with_context(|| -> String { unreachable!("not evaluated on Ok") });
        assert_eq!(got.unwrap(), 7);

        let bad: Result<u32, _> = "x".parse::<u32>().with_context(|| format!("parsing {}", "x"));
        assert!(bad.unwrap_err().to_string().starts_with("parsing x:"));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing value").unwrap_err().to_string(), "missing value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn bail_macro_returns() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(0)
        }
        assert_eq!(f(false).unwrap(), 0);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }

    #[test]
    fn from_impls_carry_messages() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }
}
